"""Workload-graph plans: MoE expert routing, SSM scan chains, paged-KV
decode steps, and steady-state sampling of composed plans.

The invariants here are the PR's acceptance criteria: per-expert page
accounting matches routed-token pages under capacity, every new plan
class validates and matches its model-reference numerics, decode-plan
page traffic equals the live paged-KV pool traffic, and a sampled
composed replay agrees with the exact replay while walking an order of
magnitude fewer events.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import paging
from repro.core import plan as P
from repro.core import streaming
from repro.core.modes import MemoryMode


# ----------------------------------------------------------------- MoE
def _moe_setup(n=16, d=32, E=4, k=2, f=64, capacity=16, seed=0):
    rng = np.random.default_rng(seed)
    plan = P.moe_layer_plan(n, d, E, k, f, np.float32, capacity=capacity)
    x = rng.standard_normal((n, d)).astype(np.float32) * 0.5
    router = rng.standard_normal((d, E)).astype(np.float32) / np.sqrt(d)
    wg = rng.standard_normal((E, d, f)).astype(np.float32) / np.sqrt(d)
    wu = rng.standard_normal((E, d, f)).astype(np.float32) / np.sqrt(d)
    wo = rng.standard_normal((E, f, d)).astype(np.float32) / np.sqrt(f)
    tensors = {"M0.router": router}
    for e in range(E):
        tensors[f"M0.e{e}.wg"] = wg[e]
        tensors[f"M0.e{e}.wu"] = wu[e]
        tensors[f"M0.e{e}.wo"] = wo[e]
    return plan, x, tensors, (router, wg, wu, wo)


def test_moe_plan_matches_apply_moe_reference():
    """Functional execution of the expert-routed plan == the model's
    grouped-GEMM dispatch (lossless capacity)."""
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models.moe import apply_moe
    n, d, E, k, f, C = 16, 32, 4, 2, 64, 16
    plan, x, tensors, (router, wg, wu, wo) = _moe_setup(n, d, E, k, f, C)
    plan.validate()
    outs, _ = streaming.execute_plan(plan, {"x": x, **tensors},
                                     MemoryMode.DM)
    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=d, n_heads=4,
        n_kv_heads=4, d_ff=f, vocab_size=64,
        moe=MoEConfig(n_routed_experts=E, top_k=k, d_ff_expert=f))
    p = {"router": jnp.asarray(router), "wi_gate": jnp.asarray(wg),
         "wi_up": jnp.asarray(wu), "wo": jnp.asarray(wo)}
    want, aux = apply_moe(p, jnp.asarray(x)[None], cfg, capacity=C)
    np.testing.assert_allclose(outs["M0.out"], np.asarray(want)[0],
                               rtol=2e-3, atol=2e-3)
    assert float(aux) >= 0.0


def test_moe_per_expert_page_accounting():
    """Sum of the per-expert page sets == the pages of the E x C routed
    token block — capacity sizes the page traffic, exactly as the
    grouped-GEMM buffers size the activation traffic."""
    from repro.models.moe import routed_capacity
    n, d, E, k, f, cap = 16, 32, 4, 2, 64, 16
    plan, _, _, _ = _moe_setup(n, d, E, k, f, cap)
    C = routed_capacity(n * k, E, cap)
    lay = paging.layout_for((C, d), plan.dtype, "A", plan.page_bytes)
    expert_pages = sum(
        plan._role_pages(plan.tensors[f"M0.e{e}.buf"], "A")
        for e in range(E))
    assert expert_pages == E * lay.n_pages
    routed_lay = paging.layout_for((E * C, d), plan.dtype, "A",
                                   plan.page_bytes)
    assert expert_pages == routed_lay.n_pages     # C % 16 == 0 here
    # every expert's buffer is streamed for its three FFN GEMMs: page
    # loads per expert are identical (capacity-shaped, not data-shaped)
    counts = plan.counts()["dma_in"]
    loads = {e: counts[f"M0.e{e}.buf"] for e in range(E)}
    assert len(set(loads.values())) == 1
    assert all(v > 0 for v in loads.values())


# ----------------------------------------------------------------- SSM
def test_ssm_plan_matches_chunked_reference():
    from repro.models.ssm import chunked_linear_attention
    rng = np.random.default_rng(1)
    T, d, H, chunk = 32, 64, 4, 16
    N = d // H
    plan = P.ssm_layer_plan(T, d, H, np.float32, chunk=chunk)
    plan.validate()
    x = rng.standard_normal((T, d)).astype(np.float32) * 0.3
    w = {name: rng.standard_normal(s).astype(np.float32) / np.sqrt(s[0])
         for name, s in P.ssm_layer_weights(d).items()}
    logw = -np.abs(rng.standard_normal((T, d))).astype(np.float32) * 0.5
    s0 = np.zeros((H * N, N), np.float32)
    outs, _ = streaming.execute_plan(
        plan, {"x": x, "S0.logw": logw, "S0.s0": s0, **w},
        MemoryMode.DC)
    r = jnp.asarray(x @ w["S0.wr"]).reshape(1, T, H, N)
    k = jnp.asarray(x @ w["S0.wk"]).reshape(1, T, H, N)
    v = jnp.asarray(x @ w["S0.wv"]).reshape(1, T, H, N)
    lw = jnp.asarray(logw).reshape(1, T, H, N)
    ref, _ = chunked_linear_attention(r, k, v, lw,
                                      jnp.zeros((1, H, N, N)),
                                      chunk=chunk, inclusive=True)
    want = np.asarray(ref).reshape(T, d) @ w["S0.wo"]
    np.testing.assert_allclose(outs["S0.out"], want, rtol=2e-3,
                               atol=2e-3)


def test_ssm_plan_has_scan_dependency_chain():
    """Each scan chunk's COMPUTE depends (transitively through the
    event order) on the previous chunk's carry state producer."""
    plan = P.ssm_layer_plan(64, 32, 2, np.float32, chunk=16)
    scans = [ev for ev in plan.events if ev.op == "ssm_scan"]
    assert len(scans) == 4
    for prev, cur in zip(scans, scans[1:]):
        # the carry tensor names chain c0.s -> c1.s -> ...
        assert prev.meta["outs"][1] in cur.meta["inputs"]
        assert prev.eid < cur.eid and cur.deps


# -------------------------------------------------------------- decode
def _churned_cache(dtype="float32", seed=2):
    from repro.serving.kv_cache import PagedCacheConfig, PagedKVCache
    rng = np.random.default_rng(seed)
    ccfg = PagedCacheConfig(n_pages=32, page_tokens=8, n_kv_heads=2,
                            head_dim=16, max_pages_per_seq=4,
                            dtype=dtype)
    cache = PagedKVCache(ccfg, max_seqs=3)
    mk = lambda t: jnp.asarray(
        rng.standard_normal((t, 2, 16)), jnp.dtype(dtype))
    for slot, ln in enumerate((20, 9, 17)):
        assert cache.alloc_seq(slot, ln)
        cache.write_prompt(slot, mk(ln), mk(ln))
    # churn: appends cross page boundaries, one retire + readmit
    cache.append_token(np.array([0, 1, 2]), mk(3).reshape(3, 2, 16),
                       mk(3).reshape(3, 2, 16))
    cache.free_seq(1)
    assert cache.alloc_seq(1, 12)
    cache.write_prompt(1, mk(12), mk(12))
    return cache


def test_decode_plan_page_ids_match_live_tables_after_churn():
    cache = _churned_cache()
    slots = [0, 1, 2]
    plan = cache.decode_step_plan(slots)
    plan.validate()
    want = {int(p) for s in slots
            for p in cache.tables[s, :int(cache.held[s])]}
    for pool in ("k", "v"):
        got = {ev.page[1] for ev in plan.events
               if ev.kind is P.EventKind.DMA_IN and ev.page[0] == pool}
        assert got == want
        assert plan.tensors[pool].pages == len(want)
    # DMA_IN bytes == paged-KV bytes actually resident for the batch
    dma = sum(ev.nbytes for ev in plan.events
              if ev.kind is P.EventKind.DMA_IN)
    resident = 2 * sum(int(cache.held[s]) for s in slots) \
        * cache.cfg.page_bytes
    assert dma == resident


def test_decode_plan_matches_paged_attention_reference():
    cache = _churned_cache()
    rng = np.random.default_rng(3)
    slots = [0, 1, 2]
    plan = cache.decode_step_plan(slots)
    q = rng.standard_normal((3, 2 * 16)).astype(np.float32)
    kd, vd = cache.page_dicts(slots)
    outs, store = streaming.execute_plan(plan, {"q": q}, MemoryMode.DM,
                                         paged={"k": kd, "v": vd})
    out = outs["decode_out"].reshape(3, 2, 16)
    for b, s in enumerate(slots):
        L = int(cache.lens[s])
        tbl = cache.tables[s, :int(cache.held[s])]
        K = np.concatenate([np.asarray(cache.k_pages[p])
                            for p in tbl])[:L].astype(np.float32)
        V = np.concatenate([np.asarray(cache.v_pages[p])
                            for p in tbl])[:L].astype(np.float32)
        qb = q[b].reshape(2, 16)
        sc = np.einsum("hd,thd->ht", qb, K) * (16 ** -0.5)
        pr = np.exp(sc - sc.max(-1, keepdims=True))
        pr /= pr.sum(-1, keepdims=True)
        want = np.einsum("ht,thd->hd", pr, V)
        np.testing.assert_allclose(out[b], want, rtol=1e-4, atol=1e-5)
    # DM streams every resident page exactly once
    assert store.stats.lookups == 2 * sum(int(cache.held[s])
                                          for s in slots)


def test_decode_plan_replays_with_fig2_buckets():
    from repro.accesys.pipeline import replay
    from repro.accesys.system import default_system
    cache = _churned_cache()
    plan = cache.decode_step_plan([0, 1, 2])
    for mode in ("DM", "DC", "DevMem"):
        r = replay(default_system(mode), plan)
        assert r.total_s > 0 and r.compute_s > 0 and r.host_s > 0
        assert all(v >= 0 for v in r.buckets().values())


def test_page_bytes_is_numpy_only():
    """PagedCacheConfig.page_bytes must resolve element sizes without
    jnp (driver-side bookkeeping) — including for bfloat16."""
    from repro.serving.kv_cache import PagedCacheConfig, _np_itemsize
    assert _np_itemsize("float32") == 4
    assert _np_itemsize("bfloat16") == 2
    cfg = PagedCacheConfig(n_pages=4, page_tokens=8, n_kv_heads=2,
                           head_dim=16, max_pages_per_seq=2,
                           dtype="bfloat16")
    assert cfg.page_bytes == 8 * 2 * 16 * 2


# -------------------------------------------- steady-state sampling
def test_model_schedule_counts_match_exact_plan():
    sched = P.model_schedule(32, 64, 2, 256, 3, "int8")
    exact = P.model_plan(32, 64, 2, 256, 3, "int8")
    sched.validate()
    assert sched.exact_events == len(exact.events)
    assert sched.macs == exact.macs
    assert sched.n_calls == exact.n_calls
    assert sched.sampled_events * 3 == sched.exact_events


def test_sampled_composed_bert_base_matches_exact_replay():
    """THE sampling acceptance criterion: a composed BERT-Base replay
    from the steady-state schedule matches the exact replay within 2%
    while walking >= 10x fewer events."""
    from repro.accesys.pipeline import replay
    from repro.accesys.system import (default_system, model_stream_plan,
                                      model_stream_schedule)
    plan = model_stream_plan("bert-base")
    sched = model_stream_schedule("bert-base")
    assert plan.n_exact_events == sched.exact_events
    assert len(plan.events) >= 10 * sched.sampled_events
    for mode in ("DM", "DC"):
        exact = replay(default_system(mode), plan)
        samp = replay(default_system(mode), sched)
        assert abs(samp.total_s - exact.total_s) / exact.total_s < 0.02,\
            (mode, exact.total_s, samp.total_s)
        assert abs(samp.host_s - exact.host_s) / exact.host_s < 0.02


def test_strided_schedule_stays_close_and_cuts_more_events():
    """Intra-GEMM striding on top of the layer window: fewer events
    still, host time untouched, total within a few percent."""
    from repro.accesys.pipeline import replay
    from repro.accesys.system import default_system
    base = P.model_schedule(128, 512, 8, 2048, 8, "int8")
    strided = P.model_schedule(128, 512, 8, 2048, 8, "int8",
                               sample_stride=3)
    assert strided.sampled_events < base.sampled_events
    r_base = replay(default_system("DC"), base)
    r_str = replay(default_system("DC"), strided)
    assert abs(r_str.total_s - r_base.total_s) / r_base.total_s < 0.05
    assert r_str.host_s == pytest.approx(r_base.host_s, rel=1e-9)


def test_moe_and_ssm_schedules_keep_host_time_unstrided():
    """Striding the GEMM windows must not scale the host-op segments
    (dispatch/combine/scan run in full either way)."""
    from repro.accesys.pipeline import replay
    from repro.accesys.system import default_system
    for mk in (lambda s: P.moe_schedule(256, 256, 4, 2, 512, 4,
                                        "int8", sample_stride=s),
               lambda s: P.ssm_schedule(256, 256, 4, 4, "int8",
                                        sample_stride=s)):
        r1 = replay(default_system("DC"), mk(1))
        r4 = replay(default_system("DC"), mk(4))
        assert r4.host_s == pytest.approx(r1.host_s, rel=1e-9)
        assert abs(r4.total_s - r1.total_s) / r1.total_s < 0.1
