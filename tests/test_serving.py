import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import PagedCacheConfig, PagedKVCache


def _requests(n, rng):
    return [Request(uid=i,
                    prompt=rng.integers(1, 250, size=int(rng.integers(4, 12))
                                        ).astype(np.int32),
                    max_new_tokens=5) for i in range(n)]


def test_continuous_batching_matches_sequential():
    """Greedy outputs must be independent of slot count / batching."""
    cfg = get_reduced("qwen2_0_5b")
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    outs = {}
    for slots in (1, 3):
        rng = np.random.default_rng(7)
        eng = ServingEngine(cfg, params, slots=slots, max_seq=64)
        reqs = _requests(5, rng)
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        outs[slots] = [tuple(r.output) for r in reqs]
    assert outs[1] == outs[3]


def test_engine_throughput_and_latency_fields():
    cfg = get_reduced("qwen1_5_32b")
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=2, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = _requests(3, rng)
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.tokens_out == sum(r.max_new_tokens for r in reqs)
    assert all(r.first_token_s is not None and r.done_s is not None
               for r in reqs)


def test_paged_cache_alloc_free_invariants():
    cfg = PagedCacheConfig(n_pages=16, page_tokens=8, n_kv_heads=2,
                           head_dim=16, max_pages_per_seq=4)
    cache = PagedKVCache(cfg, max_seqs=3)
    assert cache.alloc_seq(0, prompt_len=20)     # 3 pages
    k = jnp.ones((20, 2, 16))
    cache.write_prompt(0, k, k)
    assert cache.pages_in_use == 3
    cache.append_token(np.array([0]), jnp.ones((1, 2, 16)),
                       jnp.ones((1, 2, 16)))
    assert int(cache.lens[0]) == 21
    cache.free_seq(0)
    assert cache.pages_in_use == 0
    # exhaustion: can't allocate more pages than the pool holds
    assert cache.alloc_seq(1, prompt_len=32)
    assert not cache.alloc_seq(2, prompt_len=32 * 8)
