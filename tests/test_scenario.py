"""Scenario API: registry smoke matrix (every config family lowers and
replays with event/compiled parity), name resolution errors, the
SimResult JSON schema, plan-cache sharing, and the heterogeneous
(zamba2) schedule structure."""
import dataclasses
import json

import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core import scenario as SC
from repro.core.plan import PlanSchedule
from repro.core.scenario import (Scenario, SimResult,
                                 UnsupportedScenario, as_params,
                                 resolve, sampling_error, scenario_names,
                                 scenario_plan, simulate, smoke_matrix,
                                 sweep)

MODES = ("DM", "DC", "DevMem")


# ------------------------------------------------ registry smoke matrix
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_registry_smoke_reduced(arch):
    """Every ``configs/*.py`` ``CONFIG.reduced()`` builds a plan via
    the registry and replays in DM/DC/DevMem with event/compiled
    parity (asserted inside ``simulate(engine="both")``)."""
    name = get_reduced(arch).name
    for mode in MODES:
        res = simulate(Scenario(model=name, seq=32, mode=mode,
                                engine="both"))
        assert res.total_s > 0
        assert res.events_replayed > 0
        assert abs(sum(res.buckets().values())) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_registry_smoke_full_size(arch):
    """Full-size configs lower and replay too (steady-state sampled,
    strided, DC only — the stacks are deep and wide)."""
    name = get_config(arch).name
    res = simulate(Scenario(model=name, seq=32, mode="DC",
                            sample_stride=16, engine="compiled"))
    assert res.total_s > 0


def test_unknown_name_did_you_mean():
    with pytest.raises(UnsupportedScenario) as ei:
        resolve("zamba2")
    msg = str(ei.value)
    assert "did you mean" in msg and "zamba2-7b" in msg
    assert "KeyError" not in msg
    # the full valid list is spelled out
    assert "bert-base" in msg


def test_unknown_family_raises_unsupported():
    cfg = dataclasses.replace(get_reduced("qwen2_0_5b"),
                              family="quantum")
    with pytest.raises(UnsupportedScenario) as ei:
        SC._config_stack(cfg, 32, "int8", 2, 1, SC.PAGE_BYTES)
    assert "quantum" in str(ei.value)
    assert "supported families" in str(ei.value)


def test_bad_mode_and_engine_raise_unsupported():
    with pytest.raises(UnsupportedScenario):
        Scenario(model="bert", mode="HBM")
    with pytest.raises(UnsupportedScenario):
        Scenario(model="bert", engine="turbo")
    with pytest.raises(UnsupportedScenario):
        Scenario(model="bert", sampling="approximate")


def test_scenario_names_cover_zoo_and_classes():
    names = scenario_names()
    for expected in ("bert", "vit", "moe", "ssm", "decode", "serve",
                     "gemm", "bert-base", "zamba2-7b-reduced",
                     "deepseek-v3-671b"):
        assert expected in names


def test_smoke_matrix_one_per_family():
    matrix = smoke_matrix()
    families = set()
    for sc in matrix[:-1]:         # last entry is the decode class
        families.add(resolve(sc.model).config.family)
        assert sc.engine == "both"
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm",
                        "audio"}


# -------------------------------------------- heterogeneous schedules
def test_zamba2_schedule_one_window_per_class():
    """The mamba/attention interleave lowers to one steady window per
    layer CLASS with its own repeat: 4 mamba layers + attn every 2."""
    plan, label, replayed, total = scenario_plan(
        Scenario(model="zamba2-7b-reduced", seq=32))
    assert isinstance(plan, PlanSchedule)
    reps = {}
    for p, rep in plan.segments:
        cls = p.name.split("0.")[0].split(".")[0]
        for key in ("mamba", "attn"):
            if key in p.name or any(key in t for t in p.tensors):
                reps.setdefault(key, set()).add(rep)
    assert 4 in {r for rs in reps.values() for r in rs}
    assert 2 in {r for rs in reps.values() for r in rs}
    assert replayed < total            # sampling actually samples


def test_zamba2_exact_interleaves_classes():
    plan, _, _, _ = scenario_plan(
        Scenario(model="zamba2-7b-reduced", seq=32, sampling="exact"))
    names = set()
    for t in plan.tensors:
        names.add(t.split(".")[0])
    # 4 mamba blocks and 2 shared attention blocks, distinct prefixes
    assert sum(1 for n in names if n.startswith("mamba")) == 4
    assert sum(1 for n in names if n.startswith("attn")) == 2


def test_zamba2_sampling_error_bars():
    res = sampling_error(Scenario(model="zamba2-7b-reduced", seq=32,
                                  mode="DC", engine="compiled"))
    err = res.sampling_error
    assert err is not None
    assert err["events_sampled"] < err["events_exact"]
    # the two-pass schedule replay tracks the exact composed replay
    assert err["rel_err_total"] < 0.02
    assert set(err["abs_err_bucket_shares"]) == \
        set(res.buckets().keys())


def test_deepseek_first_dense_layers_honored():
    """deepseek-v3-reduced has first_dense_layers=1: the 2-layer stack
    lowers to one dense window + one MoE window."""
    plan, _, _, _ = scenario_plan(
        Scenario(model="deepseek-v3-reduced", seq=32))
    classes = {p.name.split("W.")[0].split(".")[0]
               for p, _ in plan.segments}
    tensors = {t for p, _ in plan.segments for t in p.tensors}
    assert any(t.startswith("dense0.") for t in tensors)
    assert any(t.startswith("M0.e0.") for t in tensors)  # routed experts
    assert any(".se." in t for t in tensors)             # shared expert


# --------------------------------------------------- façade mechanics
def test_simresult_json_schema_stable():
    res = simulate(Scenario(model="qwen2-0.5b-reduced", seq=32))
    j = res.to_json()
    assert j["schema"] == "simresult/v1"
    for key in ("scenario", "label", "mode", "engine", "total_us",
                "buckets", "tlb", "macs", "gops", "events", "wall_s",
                "events_per_s", "serving", "sampling_error"):
        assert key in j, key
    assert set(j["buckets"]) == {"descriptor", "translation",
                                 "transfer", "compute", "drain", "host",
                                 "collective"}
    assert set(j["tlb"]) == {"lookups", "misses", "walks"}
    assert set(j["events"]) == {"replayed", "total", "speedup"}
    json.dumps(j)                      # round-trips


def test_sweep_shares_plan_across_modes():
    SC.clear_caches()
    sweep([Scenario(model="granite-20b-reduced", seq=32, mode=m)
           for m in MODES])
    assert SC.cache_misses == 1        # one lowering ...
    assert SC.cache_hits == 2          # ... reused by the other modes


def test_gemm_scenario_matches_simulate_gemm():
    """The scenario GEMM path uses the same auto-sampling rule as
    ``pipeline.simulate_gemm`` — seed GEMM numbers stay pinned."""
    from repro.accesys.pipeline import simulate_gemm
    from repro.accesys.system import default_system
    res = simulate(Scenario(model="gemm", mode="DC",
                            params=as_params(m=512, n=512, k=512)))
    ref = simulate_gemm(default_system("DC"), 512, 512, 512)
    assert res.total_s == pytest.approx(ref.total_s, rel=1e-12)
    assert res.result.tlb_misses == ref.tlb_misses


def test_decode_scenario_no_jax_pools():
    """The decode class builds from a driver-side PageTable (page ids
    verbatim); multi-layer sampled lowers to a schedule."""
    res = simulate(Scenario(model="decode", dtype="fp16",
                            engine="both"))
    assert res.events_replayed > 0
    plan, _, replayed, total = scenario_plan(
        Scenario(model="decode", dtype="fp16", n_layers=3))
    assert isinstance(plan, PlanSchedule)
    assert total == 3 * replayed


def test_cli_routes_through_registry(capsys):
    from repro.launch import simulate as cli
    with pytest.raises(SystemExit) as ei:
        cli.main(["--workload", "zamba"])
    assert ei.value.code == 2
    err = capsys.readouterr().err
    assert "did you mean" in err and "zamba2-7b" in err
    assert cli.main(["--workload", "rwkv6-7b-reduced", "--seq", "32",
                     "--modes", "DC"]) == 0
    assert "rwkv6-7b-reduced" in capsys.readouterr().out
