"""Differential parity suite for the compiled (array-form) replay
engine: ``replay_compiled`` must reproduce ``replay(engine="event")``
on EVERY ``GemmResult`` field, for every workload class x memory mode x
sampling treatment — plus a property test over random event streams
that exercises group shapes no builder emits.

This is the PR's acceptance criterion: the compiled engine is only
allowed to be fast because it is numerically interchangeable.
"""
import dataclasses

import numpy as np
import pytest

from repro.accesys import components as C
from repro.accesys.components import DRAM
from repro.accesys.pipeline import replay, replay_compiled
from repro.accesys.system import default_system
from repro.core import plan as P

MODES = [("DM", None), ("DC", None), ("DevMem", "HBM2")]


def _sys(mode, dram):
    return default_system(mode, dram=DRAM(dram) if dram else None)


def assert_parity(plan, mode="DC", dram=None, recur=None, rtol=1e-9):
    ev = replay(_sys(mode, dram), plan, engine="event")
    co = replay_compiled(_sys(mode, dram), plan, _recur=recur)
    for f in dataclasses.fields(ev):
        a, b = getattr(ev, f.name), getattr(co, f.name)
        if isinstance(a, int):
            assert a == b, (f.name, a, b)
        else:
            assert b == pytest.approx(a, rel=rtol, abs=1e-30), \
                (f.name, a, b)


# ------------------------------------------------------------ workloads
def _page_table():
    from repro.serving.kv_cache import PagedCacheConfig, PageTable
    pt = PageTable(PagedCacheConfig(
        n_pages=32, page_tokens=8, n_kv_heads=2, head_dim=16,
        max_pages_per_seq=4, dtype="float16"), max_seqs=3)
    for slot, ln in enumerate((20, 9, 17)):
        assert pt.alloc_seq(slot, ln)
        pt.note_tokens(slot, ln)
    pt.free_seq(1)
    assert pt.alloc_seq(1, 12)          # churned page ids
    pt.note_tokens(1, 12)
    return pt


def _decode_plan(**kw):
    return _page_table().decode_step_plan([0, 1, 2], **kw)


def _prefill_plan():
    return _page_table().prefill_plan(0, 20, n_q_heads=4, d_model=32,
                                      d_ff=64, n_layers=2)


WORKLOADS = {
    "gemm": lambda: P.gemm_plan(192, 160, 512, "int8"),
    "bert": lambda: P.model_plan(32, 64, 2, 256, 2, "int8"),
    "vit": lambda: P.model_plan(48, 96, 3, 384, 2, "int8"),
    "moe": lambda: P.moe_layer_plan(64, 128, 8, 2, 256, "int8"),
    "ssm": lambda: P.ssm_layer_plan(128, 128, 4, "int8", chunk=16),
    "decode": _decode_plan,
    "decode_gqa": lambda: _decode_plan(n_q_heads=8, n_layers=3),
    "prefill": _prefill_plan,
}

SCHEDULES = {
    "gemm": lambda: P.gemm_plan(512, 512, 512, "int8", sample_stride=3),
    "bert": lambda: P.model_schedule(32, 64, 2, 256, 3, "int8"),
    "vit": lambda: P.model_schedule(48, 96, 3, 384, 4, "int8",
                                    sample_stride=2),
    "moe": lambda: P.moe_schedule(64, 128, 8, 2, 256, 4, "int8"),
    "ssm": lambda: P.ssm_schedule(128, 128, 4, 4, "int8"),
    "decode": lambda: P.PlanSchedule(
        "decode_x5", [(_decode_plan(), 5)]),
    "serve_trace": lambda: P.PlanSchedule(
        "trace", [(_prefill_plan(), 1),
                  (_decode_plan(n_q_heads=4, n_layers=2), 1),
                  (_decode_plan(n_q_heads=4, n_layers=2), 1)]),
}


@pytest.mark.parametrize("mode,dram", MODES)
@pytest.mark.parametrize("wl", sorted(WORKLOADS))
def test_exact_parity(wl, mode, dram):
    assert_parity(WORKLOADS[wl](), mode, dram)


@pytest.mark.parametrize("mode,dram", MODES)
@pytest.mark.parametrize("wl", sorted(SCHEDULES))
def test_sampled_parity(wl, mode, dram):
    assert_parity(SCHEDULES[wl](), mode, dram)


@pytest.mark.parametrize("recur", ["loop", "vec"])
def test_both_recurrence_impls_match_event_engine(recur):
    """The scalar-loop and the vectorized (max-plus segmented) timeline
    recurrences are interchangeable — both are compared against the
    event engine on a plan with host barriers, drains and stores."""
    assert_parity(P.model_plan(32, 64, 2, 256, 1, "int8"), "DC",
                  recur=recur)
    assert_parity(P.model_schedule(32, 64, 2, 256, 3, "int8"), "DM",
                  recur=recur)


def test_replay_auto_routes_compiled_and_seed_numbers_hold():
    """The default engine must route large plans through the compiled
    path and still reproduce the event engine bit-tight (the pinned
    seed GEMM numbers in test_accesys_claims run through this path)."""
    plan = P.gemm_plan(512, 512, 512, "int8")
    assert len(plan.events) >= 3000
    r_auto = replay(default_system("DC"), plan)
    r_event = replay(default_system("DC"), plan, engine="event")
    assert r_auto.total_s == pytest.approx(r_event.total_s, rel=1e-9)
    assert (r_auto.tlb_lookups, r_auto.tlb_misses, r_auto.ptw_walks) \
        == (r_event.tlb_lookups, r_event.tlb_misses, r_event.ptw_walks)


def test_compiled_leaves_equivalent_component_state():
    """After a compiled replay the SMMU/LLC LRU contents and counters
    must equal what the sequential sweep leaves behind, so later
    sequential accesses continue identically."""
    plan = P.gemm_plan(96, 96, 256, "int8")
    cfg_e, cfg_c = default_system("DC"), default_system("DC")
    replay(cfg_e, plan, engine="event")
    replay_compiled(cfg_c, plan)
    assert list(cfg_e.smmu._tlb) == list(cfg_c.smmu._tlb)
    assert list(cfg_e.smmu._l2) == list(cfg_c.smmu._l2)
    assert list(cfg_e.llc._lru) == list(cfg_c.llc._lru)
    assert (cfg_e.smmu.lookups, cfg_e.smmu.misses, cfg_e.smmu.walks) \
        == (cfg_c.smmu.lookups, cfg_c.smmu.misses, cfg_c.smmu.walks)
    assert (cfg_e.llc.hits, cfg_e.llc.misses) \
        == (cfg_c.llc.hits, cfg_c.llc.misses)


def test_memoized_builders_share_plans_and_compiled_form():
    a = P.gemm_plan_cached(256, 256, 256, "int8")
    b = P.gemm_plan_cached(256, 256, 256, "int8")
    assert a is b                       # one build per geometry
    assert a.compile() is b.compile()   # one lowering too
    s1 = P.gemm_tile_steps_cached(128, 128, 256, "int8")
    s2 = P.gemm_tile_steps_cached(128, 128, 256, "int8")
    assert s1 is s2
    assert list(s1) == list(P.gemm_tile_steps(128, 128, 256, "int8"))


# ------------------------------------------------- batch LRU machinery
def _ref_lru_hits(ids, cap):
    import collections
    od = collections.OrderedDict()
    hits = np.zeros(len(ids), bool)
    for i, p in enumerate(ids):
        if p in od:
            od.move_to_end(p)
            hits[i] = True
        else:
            od[p] = True
            while len(od) > cap:
                od.popitem(last=False)
    return hits


def test_stack_distance_pass_reproduces_sequential_lru():
    rng = np.random.default_rng(0)
    for _ in range(25):
        n = int(rng.integers(1, 3000))
        ids = rng.integers(0, int(rng.integers(1, 70)), n).astype(
            np.int32)
        prev = C.prev_occurrence(ids)
        sd = C.lru_stack_distances(prev)
        for cap in (1, 2, 7, 64, 300):
            got = (prev >= 0) & (sd < cap)
            assert np.array_equal(got, _ref_lru_hits(ids, cap))


# ------------------------------------------------------- property test
def _random_plan(rng) -> P.StreamPlan:
    """Random event stream: arbitrary interleavings of fetches on
    random lanes, SA/host computes and stores — shapes no builder
    emits (empty groups, back-to-back stores, trailing fetches)."""
    n_pages = int(rng.integers(1, 12))
    events = []
    eid = 0
    for _ in range(int(rng.integers(1, 60))):
        r = rng.random()
        if r < 0.45:
            events.append(P.Event(
                eid, P.EventKind.DMA_IN,
                nbytes=int(rng.integers(64, 4096)),
                page=("t", int(rng.integers(0, n_pages))),
                lane=int(rng.integers(0, 3)), op="load"))
        elif r < 0.70:
            events.append(P.Event(
                eid, P.EventKind.COMPUTE, op="gemm", unit="sa",
                meta={"depth": int(rng.integers(1, 256))}))
        elif r < 0.85:
            events.append(P.Event(
                eid, P.EventKind.COMPUTE, op="softmax", unit="host",
                meta={"inputs": (), "out": None,
                      "elems": int(rng.integers(1, 4096))}))
        else:
            events.append(P.Event(
                eid, P.EventKind.DMA_OUT,
                nbytes=int(rng.integers(64, 1024)),
                page=("c", int(rng.integers(0, n_pages))), op="store"))
        eid += 1
    return P.StreamPlan("random", "int8", 4096, events,
                        {"t": P.TensorSpec(64, 64, {"A"})},
                        macs=1, n_calls=1)


@pytest.mark.parametrize("recur", ["loop", "vec"])
def test_random_plans_parity(recur):
    rng = np.random.default_rng(7)
    for i in range(40):
        plan = _random_plan(rng)
        mode, dram = MODES[i % 3]
        assert_parity(plan, mode, dram, recur=recur)


def test_random_schedules_parity():
    rng = np.random.default_rng(11)
    for i in range(12):
        segs = [(_random_plan(rng), int(rng.integers(1, 5)))
                for _ in range(int(rng.integers(1, 4)))]
        sched = P.PlanSchedule("random_sched", segs)
        mode, dram = MODES[i % 3]
        assert_parity(sched, mode, dram,
                      recur="loop" if i % 2 else "vec")
