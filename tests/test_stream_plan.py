"""StreamPlan IR invariants: coverage, page-load accounting, functional
execution vs jnp oracles, composition, and the timing replayer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan as P
from repro.core import streaming
from repro.core.modes import MemoryMode

SHAPES = [(33, 41, 100), (64, 64, 64), (17, 100, 300), (1, 1, 1)]


# ------------------------------------------------------------ structure
@pytest.mark.parametrize("m,n,k", SHAPES)
def test_plan_covers_every_output_tile_exactly_once(m, n, k):
    plan = P.gemm_plan(m, n, k, np.float32)
    plan.validate()
    counts = streaming.tile_counts(m, n, k, np.float32)
    seen = {}
    for ev in plan.events:
        if ev.kind is P.EventKind.COMPUTE:
            key = (ev.meta["i"], ev.meta["j"])
            if ev.meta["first_k"]:
                assert key not in seen
                seen[key] = 0
            seen[key] += 1
    assert len(seen) == counts["out_tiles"]
    assert all(v == counts["k_steps"] for v in seen.values())
    stores = [ev.page[1] for ev in plan.events
              if ev.kind is P.EventKind.DMA_OUT]
    assert sorted(stores) == sorted(seen)          # one drain per tile


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", [np.int8, np.float16, np.float32])
def test_plan_page_loads_match_tile_counts(m, n, k, dtype):
    plan = P.gemm_plan(m, n, k, dtype)
    counts = streaming.tile_counts(m, n, k, dtype)
    c = plan.counts()
    assert c["dma_in"]["a"] == counts["a_page_loads"]
    assert c["dma_in"]["b"] == counts["b_page_loads"]
    assert c["dma_out"]["c"] == counts["c_page_stores"]
    assert c["sa_computes"] == counts["inner_steps"]
    assert plan.total_steps == counts["inner_steps"]
    assert plan.footprint_pages == counts["a_pages"] \
        + counts["b_pages"] + counts["c_page_stores"]


def test_compute_events_depend_on_their_dma_ins():
    plan = P.gemm_plan(40, 50, 130, np.float32)
    by_eid = {ev.eid: ev for ev in plan.events}
    for ev in plan.events:
        if ev.kind is not P.EventKind.COMPUTE:
            continue
        kinds = {by_eid[d].kind for d in ev.deps}
        assert P.EventKind.DMA_IN in kinds
        in_pages = {by_eid[d].page for d in ev.deps
                    if by_eid[d].kind is P.EventKind.DMA_IN}
        assert in_pages == {("a", ev.meta["a_page"]),
                            ("b", ev.meta["b_page"])}
        if not ev.meta["first_k"]:    # output-stationary accumulator chain
            assert any(by_eid[d].kind is P.EventKind.COMPUTE
                       for d in ev.deps)


def test_lanes_split_a_and_b_channels():
    plan = P.gemm_plan(64, 64, 300, np.float16)
    lanes = {ev.page[0]: ev.lane for ev in plan.events
             if ev.kind is P.EventKind.DMA_IN}
    assert lanes == {"a": 0, "b": 1}


def test_sampled_plan_keeps_first_and_last_k():
    m = n = k = 512
    full = P.gemm_plan(m, n, k, np.float32)
    sampled = P.gemm_plan(m, n, k, np.float32, sample_stride=7)
    assert 0 < sampled.sampled_steps < full.sampled_steps
    assert sampled.total_steps == full.total_steps
    firsts = {(e.meta["i"], e.meta["j"]) for e in sampled.events
              if e.kind is P.EventKind.COMPUTE and e.meta["first_k"]}
    lasts = {(e.meta["i"], e.meta["j"]) for e in sampled.events
             if e.kind is P.EventKind.COMPUTE and e.meta["last_k"]}
    full_tiles = {e.page[1] for e in full.events
                  if e.kind is P.EventKind.DMA_OUT}
    samp_tiles = {e.page[1] for e in sampled.events
                  if e.kind is P.EventKind.DMA_OUT}
    # every tile keeps its first-k (accumulator init) and last-k
    # (drain) steps, and still drains exactly once
    assert firsts == lasts == samp_tiles == full_tiles


def test_concat_renumbers_and_merges():
    g1 = P.gemm_plan(16, 16, 64, np.float32, c="t")
    g2 = P.gemm_plan(16, 16, 16, np.float32, a="t", b="w", c="out")
    comp = P.concat([g1, g2])
    comp.validate()
    assert comp.n_calls == 2
    assert comp.macs == g1.macs + g2.macs
    # "t" carries both its producer (C) and consumer (A) roles
    assert comp.tensors["t"].roles == {"C", "A"}
    # barrier: second sub-plan's first event depends on the first's last
    first_of_g2 = comp.events[len(g1.events)]
    assert comp.events[len(g1.events) - 1].eid in first_of_g2.deps


# ------------------------------------------------------------ execution
@pytest.mark.parametrize("dtype", [np.int8, np.float16, np.float32])
def test_executed_gemm_plan_matches_jnp_dot(dtype):
    rng = np.random.default_rng(3)
    if np.issubdtype(dtype, np.integer):
        a = rng.integers(-100, 100, (45, 70)).astype(dtype)
        b = rng.integers(-100, 100, (70, 52)).astype(dtype)
        acc = jnp.int32
    else:
        a = (rng.standard_normal((45, 70))).astype(dtype)
        b = (rng.standard_normal((70, 52))).astype(dtype)
        acc = jnp.float32
    want = np.asarray(jnp.dot(jnp.asarray(a), jnp.asarray(b),
                              preferred_element_type=acc), np.float64)
    for mode in MemoryMode:
        out, _ = streaming.gemm_streamed(a, b, mode, cache_pages=8)
        tol = 1e-2 if dtype == np.float16 else 1e-5
        np.testing.assert_allclose(out, want, rtol=tol, atol=tol)


def test_executed_attention_plan_matches_reference():
    rng = np.random.default_rng(5)
    S, hd = 24, 16
    q = rng.standard_normal((S, hd)).astype(np.float32)
    k = rng.standard_normal((S, hd)).astype(np.float32)
    v = rng.standard_normal((S, hd)).astype(np.float32)
    plan = P.attention_plan(S, hd, np.float32)
    plan.validate()
    outs, store = streaming.execute_plan(
        plan, {"q": q, "kT": np.ascontiguousarray(k.T), "v": v},
        MemoryMode.DM)
    ref = np.asarray(jax.nn.softmax(jnp.asarray(q @ k.T), axis=-1)) @ v
    np.testing.assert_allclose(outs["attn"], ref, rtol=1e-4, atol=1e-5)
    # DM streams every page: Q, K pages for QK^T plus P, V pages for PV
    assert store.stats.host_to_device_bytes > 0
    assert store.stats.cache_hits == 0


def test_executed_transformer_layer_matches_reference():
    rng = np.random.default_rng(0)
    S, d, h, dff = 16, 32, 2, 64
    x = rng.standard_normal((S, d)).astype(np.float32) * 0.5
    w = {name: (rng.standard_normal(shape).astype(np.float32)
                / np.sqrt(shape[0]))
         for name, shape in P.layer_weights(d, dff).items()}
    plan = P.transformer_layer_plan(S, d, h, dff, np.float32)
    plan.validate()
    outs, _ = streaming.execute_plan(plan, {"x": x, **w}, MemoryMode.DC)

    def ln(z, eps=1e-5):
        z = np.asarray(z, np.float64)
        return (z - z.mean(-1, keepdims=True)) \
            / np.sqrt(z.var(-1, keepdims=True) + eps)

    qkv = x @ w["L0.wqkv"]
    hd = d // h
    heads = []
    for i in range(h):
        q = qkv[:, i * hd:(i + 1) * hd]
        k = qkv[:, d + i * hd:d + (i + 1) * hd]
        v = qkv[:, 2 * d + i * hd:2 * d + (i + 1) * hd]
        p = np.asarray(jax.nn.softmax(jnp.asarray(q @ k.T), axis=-1))
        heads.append(p @ v)
    res1 = ln(x + np.concatenate(heads, axis=1) @ w["L0.wo"])
    ff = np.asarray(jax.nn.gelu(jnp.asarray(
        (res1 @ w["L0.w1"]).astype(np.float32))))
    want = ln(res1 + ff @ w["L0.w2"])
    np.testing.assert_allclose(outs["L0.out"], want, rtol=2e-3, atol=5e-4)


def test_traffic_ordering_across_modes():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((33, 100)).astype(np.float32)
    b = rng.standard_normal((100, 41)).astype(np.float32)
    _, dm = streaming.gemm_streamed(a, b, MemoryMode.DM)
    _, dc = streaming.gemm_streamed(a, b, MemoryMode.DC, cache_pages=64)
    _, dv = streaming.gemm_streamed(a, b, MemoryMode.DEVMEM)
    assert dm.stats.host_to_device_bytes >= dc.stats.host_to_device_bytes
    assert dv.stats.host_to_device_bytes == 0
