"""Config-batched pricing + design-space search.

The contract under test: ``replay_batch(cfgs, plan)`` returns, per
config, the SAME GemmResult a sequential ``replay_compiled`` sweep
produces (rtol <= 1e-9 on every field, over random plans x random
``SystemConfig`` batches), and ``tune()`` searches a knob space whose
paper point lowers to the exact default system — so the co-design
frontier is priced by the same numbers every other test pins.
"""
import dataclasses

import numpy as np
import pytest

from repro.accesys import components as C
from repro.accesys.pipeline import (SystemConfig, replay_batch,
                                    replay_compiled)
from repro.accesys.system import default_system
from repro.core import design_space as DS
from repro.core import plan as P
from repro.core import scenario as SC
from repro.core.scenario import Scenario, as_params, simulate, tune

from test_compiled_replay import _random_plan

MODES = ("DM", "DC", "DevMem")


def _random_cfg(rng) -> SystemConfig:
    return SystemConfig(
        sa=C.SystolicArray(
            dtype="int8", w=int(rng.choice([4, 8, 16, 32]))),
        pcie=C.PCIeLink(lanes=int(rng.choice([4, 8, 16])),
                        gbps_per_lane=float(rng.choice([8, 16, 32,
                                                        64]))),
        dram=C.DRAM(str(rng.choice(list(C.DRAM_TECH)))),
        dma=C.DMAEngine(read_channels=int(rng.integers(1, 4)),
                        doorbell_ns=float(rng.choice([400, 800]))),
        smmu=C.SMMU(tlb_entries=int(rng.choice([2, 16, 64])),
                    l2_entries=int(rng.choice([64, 8192]))),
        llc=C.LLC(size_bytes=int(rng.choice([64, 512, 2048])) * 1024),
        mode=str(rng.choice(MODES)))


def assert_batch_parity(cfgs, plan, rtol=1e-9, **kw):
    batch = replay_batch(cfgs, plan, **kw)
    assert len(batch) == len(cfgs)
    for cfg, got in zip(cfgs, batch):
        # force the vectorized recurrence: replay_batch's pricing is
        # its leading-axis form, so parity is bitwise, not just rtol
        ref = replay_compiled(dataclasses.replace(cfg), plan,
                              _recur="vec")
        for f in dataclasses.fields(ref):
            a, b = getattr(ref, f.name), getattr(got, f.name)
            if isinstance(a, int):
                assert a == b, (f.name, a, b)
            else:
                assert b == pytest.approx(a, rel=rtol, abs=1e-30), \
                    (f.name, a, b)


# ------------------------------------------------- batched == sequential
@pytest.mark.parametrize("wl,build", [
    ("gemm", lambda: P.gemm_plan(192, 160, 512, "int8")),
    ("bert", lambda: P.model_plan(32, 64, 2, 256, 2, "int8")),
    ("moe", lambda: P.moe_layer_plan(64, 128, 8, 2, 256, "int8")),
    ("ssm", lambda: P.ssm_layer_plan(128, 128, 4, "int8", chunk=16)),
])
def test_builder_plans_batch_parity(wl, build):
    rng = np.random.default_rng(hash(wl) % 2**32)
    cfgs = [default_system(m) for m in MODES] + \
        [_random_cfg(rng) for _ in range(8)]
    assert_batch_parity(cfgs, build())


@pytest.mark.parametrize("wl,build", [
    ("bert", lambda: P.model_schedule(32, 64, 2, 256, 3, "int8")),
    ("gemm", lambda: P.gemm_plan(512, 512, 512, "int8",
                                 sample_stride=3)),
    ("moe", lambda: P.moe_schedule(64, 128, 8, 2, 256, 4, "int8")),
])
def test_builder_schedules_batch_parity(wl, build):
    rng = np.random.default_rng(hash(wl) % 2**31)
    cfgs = [default_system(m) for m in MODES] + \
        [_random_cfg(rng) for _ in range(8)]
    assert_batch_parity(cfgs, build())


def test_random_plans_random_config_batches():
    rng = np.random.default_rng(21)
    for _ in range(15):
        plan = _random_plan(rng)
        cfgs = [_random_cfg(rng)
                for _ in range(int(rng.integers(1, 9)))]
        assert_batch_parity(cfgs, plan)


def test_random_schedules_random_config_batches():
    rng = np.random.default_rng(23)
    for _ in range(8):
        segs = [(_random_plan(rng), int(rng.integers(1, 5)))
                for _ in range(int(rng.integers(1, 4)))]
        sched = P.PlanSchedule("random_sched", segs)
        cfgs = [_random_cfg(rng)
                for _ in range(int(rng.integers(1, 7)))]
        assert_batch_parity(cfgs, sched)


def test_chunked_batches_match_unchunked():
    """Tiny max_chunk_elems forces many recurrence chunks; results must
    not change."""
    rng = np.random.default_rng(29)
    plan = P.model_plan(32, 64, 2, 256, 2, "int8")
    sched = P.model_schedule(32, 64, 2, 256, 3, "int8")
    cfgs = [_random_cfg(rng) for _ in range(9)]
    for pl in (plan, sched):
        assert_batch_parity(cfgs, pl, max_chunk_elems=1)


def test_duplicate_configs_share_one_replay():
    """Equal-keyed configs must return equal (deduped) results, and
    distinct GemmResult objects per slot."""
    plan = P.gemm_plan(192, 160, 512, "int8")
    cfgs = [default_system("DC"), default_system("DC"),
            default_system("DM"), default_system("DC")]
    out = replay_batch(cfgs, plan)
    assert out[0] == out[1] == out[3]
    assert out[0] is not out[1]
    assert out[2] != out[0]


def test_replay_batch_is_pure():
    """Unlike the sequential entry points, batched pricing never
    touches the configs' SMMU/LLC state or counters."""
    plan = P.gemm_plan(96, 96, 256, "int8")
    cfg = default_system("DC")
    replay_batch([cfg], plan)
    assert cfg.smmu.lookups == 0 and not cfg.smmu._tlb
    assert cfg.llc.hits == cfg.llc.misses == 0 and not cfg.llc._lru
    assert replay_batch([], plan) == []


# -------------------------------------------------- SA variant modeling
def test_sa_pass_model():
    for w, passes in ((4, 16), (8, 4), (16, 1), (32, 1)):
        sa = C.SystolicArray(dtype="int8", w=w, tile_w=16)
        assert sa.passes == passes
    # seed numbers: default 16x16 over depth 256 stays 256 + 2*15
    assert C.SystolicArray().tile_cycles(256) == 286
    assert C.SystolicArray(w=8).tile_cycles(256) == 4 * (256 + 14)


def test_sa_variant_interpolation():
    # table anchors pass through verbatim
    assert C.sa_variant("int8", 16) == C.SA_VARIANTS[("int8", 16)]
    assert C.sa_variant("int8", 4) == C.SA_VARIANTS[("int8", 4)]
    # interpolated widths: peak throughput scales as 2 w^2 f
    f8, area8, pow8, gops8 = C.sa_variant("int8", 8)
    assert gops8 == pytest.approx(2 * 8 * 8 * f8 / 1e9)
    areas = [C.sa_variant("int8", w)[1] for w in (4, 8, 16, 32)]
    powers = [C.sa_variant("int8", w)[2] for w in (4, 8, 16, 32)]
    assert areas == sorted(areas) and powers == sorted(powers)
    # the log-log law hits both anchors
    assert C.sa_variant("fp16", 32)[1] > C.SA_VARIANTS[("fp16", 16)][1]


# ------------------------------------------------------ knob space model
def test_default_point_is_the_paper_system():
    p = DS.DesignPoint()
    assert (p.sa_w, p.page_bytes) == (16, 4096)
    assert 18.0 <= p.required_buffer_kb <= p.buffer_kb == 20
    assert DS.system_for_point(p) == default_system("DC")


def test_paper_point_in_default_grid():
    grid = list(DS.default_space().grid())
    assert DS.DesignPoint() in grid
    assert all(p.feasible for p in grid)
    # canonicalization dedups don't-care axes
    assert len(grid) == len(set(grid))
    dm = DS.DesignPoint(mode="DM", llc_kb=64, devmem_dram="GDDR6")
    assert dm.canonical() == DS.DesignPoint(mode="DM")


def test_infeasible_points_filtered():
    tiny = DS.DesignPoint(page_bytes=16384, buffer_kb=20)
    assert not tiny.feasible
    assert tiny not in list(DS.default_space().grid())
    space = DS.DesignSpace(page_bytes=(16384,), buffer_kb=(20,))
    assert space.size() == 0
    with pytest.raises(SC.UnsupportedScenario):
        tune(Scenario(model="gemm"), space)


def test_sample_is_deterministic_and_feasible():
    space = DS.default_space()
    a = space.sample(12, seed=3)
    assert a == space.sample(12, seed=3)
    assert len(a) == len(set(a)) == 12
    assert all(p.feasible for p in a)


def test_bench_grid_shape():
    grid = DS.bench_grid()
    assert len(grid) == 64
    assert len({DS.system_for_point(p).sa.w for p in grid}) == 4
    assert all(p.feasible and p.page_bytes == 4096 for p in grid)


def test_pareto_front_non_dominated():
    pts = [(1.0, 5.0), (2.0, 3.0), (3.0, 4.0), (0.5, 9.0),
           (2.5, 3.0), (4.0, 1.0)]
    keep = DS.pareto_front(pts)
    assert keep == [0, 1, 3, 5]
    for i in keep:
        t, a = pts[i]
        assert not any((t2 <= t and a2 <= a) and (t2 < t or a2 < a)
                       for j, (t2, a2) in enumerate(pts) if j != i)


# ------------------------------------------------------------- tune()
def test_tune_matches_sweep_mode_ordering():
    """The mode axis of tune() reproduces simulate()/sweep() values at
    rtol 1e-9 — DM/DC/DevMem ordering cannot disagree."""
    SC.clear_caches()
    sc = Scenario(model="gemm", params=as_params(m=256, n=256, k=256))
    res = tune(sc, [DS.DesignPoint(mode=m) for m in MODES])
    totals = {}
    for tp, mode in zip(res.points, MODES):
        ref = simulate(dataclasses.replace(sc, mode=mode))
        assert tp.total_s == pytest.approx(ref.total_s, rel=1e-9)
        totals[mode] = tp.total_s
    order = sorted(MODES, key=totals.get)
    ref_order = sorted(MODES, key=lambda m: simulate(
        dataclasses.replace(sc, mode=m)).total_s)
    assert order == ref_order


def test_tune_smoke_grid():
    sc = Scenario(model="qwen2-0.5b-reduced", seq=32)
    space = DS.DesignSpace(sa_w=(8, 16), page_bytes=(4096,),
                           buffer_kb=(20, 72), tlb_entries=(16, 64),
                           mode=("DC", "DevMem"))
    res = tune(sc, space)
    assert DS.DesignPoint() in [tp.point for tp in res.points]
    assert res.n_infeasible == 0
    assert len(res.points) == space.size()
    best = res.best
    assert best.score == min(tp.score for tp in res.points)
    # the frontier is mutually non-dominated and contains the fastest
    front = res.pareto
    assert front
    assert min(tp.total_s for tp in front) == \
        min(tp.total_s for tp in res.points)
    for tp in front:
        assert not any(
            (o.total_s <= tp.total_s and o.area_um2 <= tp.area_um2)
            and (o.total_s < tp.total_s or o.area_um2 < tp.area_um2)
            for o in res.points if o is not tp)
    j = res.to_json()
    assert j["schema"] == "tuneresult/v1"
    import json
    json.dumps(j)


def test_tune_custom_objective_and_serve_rejected():
    sc = Scenario(model="gemm", params=as_params(m=256, n=256, k=256))
    pts = [DS.DesignPoint(sa_w=w, buffer_kb=72) for w in (8, 16)]

    def area_latency(point, r):
        return r.total_s * DS.point_area_um2(point)

    res = tune(sc, pts, objective=area_latency)
    assert res.objective == "area_latency"
    assert res.best.score == min(tp.score for tp in res.points)
    with pytest.raises(SC.UnsupportedScenario):
        tune(Scenario(model="serve"))
    with pytest.raises(SC.UnsupportedScenario):
        tune(sc, pts, objective="throughput")


# ---------------------------------------------- scenario page_bytes knob
def test_scenario_page_bytes_threads_to_plan_and_llc():
    SC.clear_caches()
    a = simulate(Scenario(model="qwen2-0.5b-reduced", seq=32))
    b = simulate(Scenario(model="qwen2-0.5b-reduced", seq=32,
                          page_bytes=1024))
    assert SC.cache_misses == 2        # distinct plans per page size
    assert a.total_s != b.total_s
    cfg = SC.system_for(Scenario(model="gemm", page_bytes=1024))
    assert cfg.page_bytes == 1024 and cfg.llc.page_bytes == 1024
    with pytest.raises(SC.UnsupportedScenario):
        Scenario(model="gemm", page_bytes=100)


# ------------------------------------------------------- true-LRU cache
def test_plan_cache_is_true_lru():
    from collections import OrderedDict
    cache: OrderedDict = OrderedDict()
    for k in "abc":
        SC._cache_put(cache, 3, k, k.upper())
    assert SC._cache_get(cache, "a") == "A"   # refreshes recency
    SC._cache_put(cache, 3, "d", "D")         # evicts b, not a
    assert list(cache) == ["c", "a", "d"]
    SC._cache_put(cache, 3, "c", "C2")        # overwrite refreshes too
    SC._cache_put(cache, 3, "e", "E")
    assert list(cache) == ["d", "c", "e"]
    assert SC._cache_get(cache, "zz") is None


def test_interleaved_sweep_keeps_hot_plan():
    """A mode sweep interleaved with other scenarios must keep hitting
    its own plan: LRU recency refresh on every hit."""
    SC.clear_caches()
    hot = Scenario(model="qwen2-0.5b-reduced", seq=32)
    fillers = [Scenario(model="gemm",
                        params=as_params(m=64 * (i + 1), n=64, k=64))
               for i in range(SC._PLAN_CACHE_MAX - 1)]
    simulate(hot)
    for i, f in enumerate(fillers):
        simulate(f)
        simulate(hot)                  # refresh between evict pressure
    assert SC.cache_misses == 1 + len(fillers)
    assert SC.cache_hits == len(fillers)
