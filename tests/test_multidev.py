"""Multi-device sharded plans (``core.multidev`` + the Scenario tp/ep
lowering): fabric parsing, partitioner agreement with
``sharding.logical.spec_for``, ring/crossbar collective volume
conservation, coupled N-rank replay, the tp=1/ep=1 bitwise-degeneracy
guard, and collective-aware serving attribution identities."""
import dataclasses

import numpy as np
import pytest

from repro.accesys.components import DRAM, Fabric
from repro.accesys.pipeline import replay, replay_compiled
from repro.accesys.system import default_system
from repro.core import multidev as MD
from repro.core import plan as P
from repro.core import scenario as SC
from repro.core.scenario import Scenario, UnsupportedScenario, simulate
from repro.sharding import logical

MODES = ("DM", "DC", "DevMem")


def _system(mode):
    return default_system(mode, dram=DRAM("HBM2")
                          if mode == "DevMem" else None)


# ------------------------------------------------------------- fabric
def test_parse_fabric_forms():
    f = MD.parse_fabric("ring")
    assert f.topology == "ring" and f.hop_latency_ns == \
        Fabric().hop_latency_ns
    f = MD.parse_fabric("alltoall:64")
    assert f.topology == "alltoall"
    # raw 64 GB/s minus TLP header overhead: effective is ~0.9x raw
    assert 0.85 * 64e9 < f.link.effective_bw < 64e9
    f = MD.parse_fabric("ring:16:800")
    assert f.hop_latency_ns == 800.0
    assert MD.parse_fabric(Fabric(topology="alltoall")).topology == \
        "alltoall"
    with pytest.raises(ValueError):
        MD.parse_fabric("mesh")


def test_fabric_hop_time_is_link_plus_latency():
    f = MD.parse_fabric("ring:16:500")
    assert f.hop_time(1 << 20) == pytest.approx(
        (1 << 20) / f.link.effective_bw + 500e-9)


# --------------------------- partitioner == logical rule table (sat 2)
@pytest.mark.parametrize("name,size,p", [
    (name, size, p)
    for name in ("heads", "kv_heads", "mlp", "expert", "qkv", "vocab",
                 "head_dim", "embed_act")
    for size, p in ((64, 8), (60, 8), (7, 7), (128, 3), (256, 2))])
def test_tp_split_matches_spec_for(name, size, p):
    """Plan-level sharding decisions must be EXACTLY ``spec_for``'s:
    shard iff the rule table maps the dim to the model axis and the
    size divides — never a padded shard, never a private rule."""
    rules = logical.make_rules(multi_pod=False)
    spec = logical.spec_for((name,), (size,), rules, {"model": p})
    entry = spec[0]
    claimed = entry is not None and "model" in (
        entry if isinstance(entry, tuple) else (entry,))
    got = MD.tp_split(size, name, p)
    if claimed:
        assert got == size // p
        assert got * p == size        # exact: no silent padding
    else:
        assert got is None


def test_tp_shard_plan_replicates_indivisible():
    sh = MD.tp_shard_plan(8, heads=32, kv_heads=4, mlp=11008,
                          head_dim=128)
    assert sh["heads"] == (4, True)
    assert sh["kv_heads"] == (4, False)      # 4 % 8 != 0: replicated
    assert sh["mlp"] == (1376, True)
    assert sh["head_dim"] == (128, False)    # rule table: never sharded


def test_ep_shard_plan_divides_or_raises():
    assert MD.ep_shard_plan(8, 64) == 8
    assert MD.ep_shard_plan(1, 7) == 7
    with pytest.raises(ValueError):
        MD.ep_shard_plan(6, 64)


# --------------------------- collective volume conservation (sat 3)
def test_ring_collective_moves_p_minus_1_over_p():
    """Ring AG/RS volume: each rank forwards p-1 hops of one shard —
    exactly (p-1)/p of the gathered tensor."""
    shard, p = 4096, 8
    for builder in (MD.ag_plan, MD.rs_plan):
        pl = builder(shard, p, "ring", "int8")
        c = pl.counts()
        assert c["collectives"] == p - 1
        assert c["collective_bytes"] == (p - 1) * shard
        assert c["collective_bytes"] == (p - 1) / p * (shard * p)


def test_alltoall_same_bytes_fewer_hops():
    shard, p = 4096, 8
    ring = MD.ag_plan(shard, p, "ring", "int8").counts()
    xbar = MD.ag_plan(shard, p, "alltoall", "int8").counts()
    assert ring["collective_bytes"] == xbar["collective_bytes"]
    assert xbar["collectives"] == 1 and ring["collectives"] == p - 1


def test_a2a_dispatch_equals_combine_bytes():
    shard, p = 2048, 4
    d = MD.a2a_plan(shard, p, "ring", "int8", op="a2a_dispatch")
    c = MD.a2a_plan(shard, p, "ring", "int8", op="a2a_combine")
    assert d.counts()["collective_bytes"] == \
        c.counts()["collective_bytes"]
    assert {ev.op for ev in d.events} == {"a2a_dispatch"}


def test_degree_one_collectives_are_none():
    assert MD.ag_plan(4096, 1, "ring", "int8") is None
    assert MD.rs_plan(0, 8, "ring", "int8") is None
    assert MD.a2a_plan(4096, 1, "alltoall", "int8") is None


# ------------------------------------------- collective hop pricing
@pytest.mark.parametrize("mode", MODES)
def test_collective_priced_on_fabric_not_host_link(mode):
    """coll_s is analytic hop time on the FABRIC link and engine
    parity holds; the host-link knob must not touch it."""
    gemm = P.gemm_plan(256, 256, 256, "int8")
    coll = MD.ag_plan(4096, 4, "ring", "int8")
    plan = P.concat([gemm, coll], name="g+ag")
    cfg = _system(mode)
    r_ev = replay(cfg, plan, engine="event")
    r_cp = replay(cfg, plan, engine="compiled")
    f = cfg.fabric
    want = 3 * (4096 / f.link.effective_bw + f.hop_latency_ns * 1e-9)
    assert r_ev.coll_s == pytest.approx(want, rel=1e-12)
    assert r_cp.coll_s == pytest.approx(r_ev.coll_s, rel=1e-9)
    assert r_cp.total_s == pytest.approx(r_ev.total_s, rel=1e-9)
    # fabric bandwidth moves coll_s only; compute/transfer untouched
    fast = _system(mode)
    fast.fabric = MD.parse_fabric("ring:256")
    r_fast = replay(fast, plan, engine="compiled")
    assert r_fast.coll_s < r_cp.coll_s
    assert r_fast.compute_s == r_cp.compute_s
    assert r_fast.transfer_s == r_cp.transfer_s


# ------------------------------------------------ coupled N-rank replay
def _rank_plan(n, tag):
    g = P.gemm_plan(n, n, n, "int8", a=f"{tag}a", b=f"{tag}b",
                    c=f"{tag}c")
    coll = MD.rs_plan(2048, 4, "ring", "int8", name=f"{tag}rs")
    g2 = P.gemm_plan(n, n, n, "int8", a=f"{tag}c", b=f"{tag}b2",
                     c=f"{tag}d")
    return P.concat([g, coll, g2], name=f"{tag}step")


def test_replay_multidev_symmetric_is_bitwise_solo():
    """Symmetric ranks never bind the barrier: every rank's coupled
    result is BITWISE the solo compiled replay of its own plan — the
    property that lets Scenario price one rank for the whole group."""
    plan = _rank_plan(128, "")
    cfg = _system("DC")
    solo = replay_compiled(cfg, plan, _recur="loop")
    ranks = MD.replay_multidev(cfg, [plan, plan, plan])
    for r in ranks:
        for f in dataclasses.fields(solo):
            assert getattr(r, f.name) == getattr(solo, f.name), f.name


def test_replay_multidev_asymmetric_barrier_drags():
    cfg = _system("DC")
    slow, fast = _rank_plan(192, "s."), _rank_plan(96, "f.")
    solo_fast = replay_compiled(cfg, fast, _recur="loop")
    r_slow, r_fast = MD.replay_multidev(cfg, [slow, fast])
    assert r_fast.total_s > solo_fast.total_s      # waited at barrier
    assert r_slow.total_s == pytest.approx(
        replay_compiled(cfg, slow, _recur="loop").total_s, rel=1e-9)


def test_replay_multidev_collective_count_mismatch_raises():
    cfg = _system("DC")
    with_coll = _rank_plan(96, "a.")
    without = P.gemm_plan(96, 96, 96, "int8")
    with pytest.raises(ValueError):
        MD.replay_multidev(cfg, [with_coll, without])


def test_rank_instances_disjoint_pages_shared_trace():
    plan = _rank_plan(96, "")
    insts = MD.rank_instances(plan, 3)
    assert len(insts) == 3
    assert insts[1].trace_ids is insts[0].trace_ids
    keys = [set(cp.page_keys) for cp in insts]
    assert not (keys[0] & keys[1]) and not (keys[1] & keys[2])


# --------------------------- tp=1/ep=1 bitwise degeneracy (sat 1)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("sampling", ("sampled", "exact"))
def test_tp1_ep1_bitwise_identical_dense(mode, sampling):
    base = Scenario(model="qwen2-0.5b-reduced", seq=32, mode=mode,
                    sampling=sampling)
    SC.clear_caches()
    a = simulate(base)
    SC.clear_caches()               # force a fresh lowering
    b = simulate(dataclasses.replace(base, tp=1, ep=1))
    for f in dataclasses.fields(a.result):
        assert getattr(a.result, f.name) == \
            getattr(b.result, f.name), f.name


def test_tp1_ep1_bitwise_identical_moe():
    base = Scenario(model="qwen2-moe-a2.7b-reduced", seq=32,
                    sampling="exact")
    SC.clear_caches()
    a = simulate(base)
    SC.clear_caches()
    b = simulate(dataclasses.replace(base, tp=1, ep=1))
    for f in dataclasses.fields(a.result):
        assert getattr(a.result, f.name) == \
            getattr(b.result, f.name), f.name
    assert a.result.coll_s == 0.0


# --------------------------- plan-level sharding == spec_for (sat 2)
def test_indivisible_tp_degree_replicates_whole_stack():
    """qwen2-0.5b-reduced has 4 heads / d_ff 128: tp=3 divides
    neither, so spec_for replicates everything — the sharded plan must
    be the unsharded plan (no collectives, identical pricing), not a
    padded shard."""
    SC.clear_caches()
    a = simulate(Scenario(model="qwen2-0.5b-reduced", seq=32))
    SC.clear_caches()
    b = simulate(Scenario(model="qwen2-0.5b-reduced", seq=32, tp=3))
    assert b.result.coll_s == 0.0
    assert b.result.total_s == a.result.total_s
    assert b.result.macs == a.result.macs


def test_tp2_shards_and_inserts_megatron_collectives():
    """tp=2 divides heads (4), kv heads (2) and d_ff (128): the exact
    plan carries one AG + one RS per attention and per MLP block, each
    moving the ring volume (p-1) * (S*d*elem/p)."""
    sc = Scenario(model="qwen2-0.5b-reduced", seq=32, tp=2,
                  sampling="exact")
    plan, _, _, _ = SC.scenario_plan(sc)
    c = plan.counts()
    n_layers, S, d, p = 2, 32, 64, 2
    per_coll = (p - 1) * (S * d * 1 // p)      # int8: 1 B/elem
    assert c["collectives"] == n_layers * 4 * (p - 1)
    assert c["collective_bytes"] == n_layers * 4 * per_coll
    # and the sharded GEMMs really shrank: a rank holds half the macs
    SC.clear_caches()
    full = SC.scenario_plan(Scenario(model="qwen2-0.5b-reduced",
                                     seq=32, sampling="exact"))[0]
    assert plan.macs < full.macs


def test_ep2_a2a_dispatch_equals_combine_in_plan():
    """qwen2-moe-a2.7b-reduced at ep=2: per-rank experts halve and the
    exact plan's a2a dispatch bytes equal its combine bytes."""
    sc = Scenario(model="qwen2-moe-a2.7b-reduced", seq=32, ep=2,
                  sampling="exact")
    plan, _, _, _ = SC.scenario_plan(sc)
    disp = sum(ev.nbytes for ev in plan.events
               if ev.kind is P.EventKind.COLLECTIVE and
               ev.op == "a2a_dispatch")
    comb = sum(ev.nbytes for ev in plan.events
               if ev.kind is P.EventKind.COLLECTIVE and
               ev.op == "a2a_combine")
    assert disp > 0 and disp == comb
    # per-rank expert count halved: count distinct expert buffers
    e_bufs = {t for t in plan.tensors if ".e" in t and
              t.endswith(".buf")}
    assert len(e_bufs) == 2 * (8 // 2)         # 2 layers x E/ep


def test_ep_indivisible_raises_unsupported():
    with pytest.raises((UnsupportedScenario, ValueError)):
        simulate(Scenario(model="qwen2-moe-a2.7b-reduced", seq=32,
                          ep=3))


# --------------------------- serving attribution identities (sat 3)
def test_serving_attribution_additive_with_collectives():
    """Collective-bearing record plans flow through the serving
    replayer untouched: per-event durations still sum to the total and
    the per-request additive TTFT/e2e identities hold exactly."""
    from repro.serving.engine import PlanRecord
    from repro.serving.sim_report import simulate_serving_trace

    def rec(kind, i, uid, plan, arrival=0):
        return PlanRecord(kind=kind, step_idx=i, slots=(0,),
                          uids=(uid,), plan=plan,
                          arrival_event=arrival)

    def sharded_step(tag):
        g = P.gemm_plan(64, 64, 64, "int8", a=f"{tag}a", b=f"{tag}b",
                        c=f"{tag}c")
        ag = MD.ag_plan(1024, 4, "ring", "int8", name=f"{tag}ag")
        return P.concat([ag, g], name=f"{tag}step")

    trace = [rec("prefill", 0, 0, sharded_step("p0.")),
             rec("decode", 1, 0, sharded_step("d0.")),
             rec("prefill", 2, 1, sharded_step("p1."), arrival=1),
             rec("decode", 3, 1, sharded_step("d1."))]
    rep = simulate_serving_trace(_system("DC"), trace)
    assert rep.result.coll_s > 0
    assert float(np.sum(rep.per_event_s)) == pytest.approx(
        rep.result.total_s, rel=1e-6)
    for r in rep.requests:
        assert r.ttft_s == pytest.approx(
            r.queue_s + r.prefill_s + r.swap_pre_s, abs=1e-15)
        assert r.e2e_s == pytest.approx(
            r.ttft_s + r.decode_s + r.swap_post_s + r.stall_s,
            abs=1e-12)


# ------------------------------------------------------ sweep plumbing
def test_sweep_tp_degrees_crosses_scenarios():
    res = SC.sweep([Scenario(model="qwen2-0.5b-reduced", seq=32)],
                   tp_degrees=[1, 2])
    assert [r.scenario.tp for r in res] == [1, 2]
    assert res[0].result.coll_s == 0.0
    assert res[1].result.coll_s > 0.0


def test_full_size_deepseek_tp8_ep8_prices():
    """Acceptance: the full 671B deepseek-v3 config lowers and prices
    end-to-end at tp=8 x ep=8 (sampled, strided)."""
    res = simulate(Scenario(model="deepseek-v3-671b", seq=32, tp=8,
                            ep=8, sample_stride=16,
                            engine="compiled"))
    assert res.total_s > 0
    assert res.result.coll_s > 0
    SC.clear_caches()               # full-size plans are order-100MB
