"""Request-centric serving simulation: GQA / multi-layer decode plans,
prefill plans from the same PageTable pages, batched trace replay with
per-plan attribution, simulated TTFT/TPOT percentiles, deferred
admission under pool pressure, and PageTable churn invariants.

These are the PR's acceptance criteria: KV bytes stay accounted per
KV head under q-head fan-out, prefill streams exactly the pages the
page table names, one batched compiled replay equals the sequential
event replay plan-for-plan, simulated latency folds back onto
individual requests, and the engine defers (never crashes) when the
shadow pool fills.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import plan as P
from repro.core import streaming
from repro.core.modes import MemoryMode
from repro.serving.kv_cache import PagedCacheConfig, PagedKVCache, \
    PageTable


def _dma_bytes(plan, pools=None):
    return sum(ev.nbytes for ev in plan.events
               if ev.kind is P.EventKind.DMA_IN and
               (pools is None or ev.page[0] in pools))


# ------------------------------------------------------------------ GQA
def test_gqa_decode_kv_bytes_per_kv_head_and_compute_fanout():
    """n_q_heads > n_kv_heads must NOT change KV page traffic (pages
    are fetched once, bytes per KV head) while SA passes scale with the
    q-head fan-out."""
    tables, lens = [[3, 7, 1], [5, 2]], [20, 12]
    mha = P.decode_step_plan(tables, lens, 8, 2, 16, 2)
    gqa = P.decode_step_plan(tables, lens, 8, 2, 16, 2, n_q_heads=8)
    for pl in (mha, gqa):
        pl.validate()
        assert _dma_bytes(pl) == 2 * 5 * pl.page_bytes
    n_sa = lambda pl: sum(1 for e in pl.events
                          if e.kind is P.EventKind.COMPUTE
                          and e.unit == "sa")
    assert n_sa(gqa) == 4 * n_sa(mha)          # group = 8 // 2
    assert gqa.macs == 4 * mha.macs
    # score / output drains scale with the query heads too
    out_bytes = lambda pl: sum(e.nbytes for e in pl.events
                               if e.kind is P.EventKind.DMA_OUT)
    assert out_bytes(gqa) == 4 * out_bytes(mha)


def test_gqa_decode_matches_grouped_reference():
    """Functional execution of a GQA decode plan == per-q-head paged
    attention with kv head h // group."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    HQ, KH, hd, group = 4, 2, 16, 2
    ccfg = PagedCacheConfig(n_pages=32, page_tokens=8, n_kv_heads=KH,
                            head_dim=hd, max_pages_per_seq=4,
                            dtype="float32")
    cache = PagedKVCache(ccfg, max_seqs=3)
    mk = lambda t: jnp.asarray(rng.standard_normal((t, KH, hd)),
                               jnp.float32)
    for slot, ln in enumerate((20, 9, 17)):
        assert cache.alloc_seq(slot, ln)
        cache.write_prompt(slot, mk(ln), mk(ln))
    plan = cache.decode_step_plan([0, 1, 2], n_q_heads=HQ)
    plan.validate()
    q = rng.standard_normal((3, HQ * hd)).astype(np.float32)
    kd, vd = cache.page_dicts([0, 1, 2])
    outs, store = streaming.execute_plan(plan, {"q": q}, MemoryMode.DM,
                                         paged={"k": kd, "v": vd})
    out = outs["decode_out"].reshape(3, HQ, hd)
    for b, s in enumerate([0, 1, 2]):
        L = int(cache.lens[s])
        tbl = cache.tables[s, :int(cache.held[s])]
        K = np.concatenate([np.asarray(cache.k_pages[p])
                            for p in tbl])[:L]
        V = np.concatenate([np.asarray(cache.v_pages[p])
                            for p in tbl])[:L]
        qb = q[b].reshape(HQ, hd)
        for h in range(HQ):
            kvh = h // group
            sc = (qb[h] @ K[:, kvh].T) * hd ** -0.5
            pr = np.exp(sc - sc.max())
            pr /= pr.sum()
            np.testing.assert_allclose(out[b, h], pr @ V[:, kvh],
                                       rtol=1e-4, atol=1e-5)
    # each page fetched once despite the fan-out
    assert store.stats.lookups == 2 * sum(int(cache.held[s])
                                          for s in [0, 1, 2])


# ---------------------------------------------------------- multi-layer
def test_multi_layer_decode_per_layer_page_namespaces():
    tables, lens = [[3, 7], [5]], [12, 6]
    one = P.decode_step_plan(tables, lens, 8, 2, 16, 2, n_q_heads=4)
    three = P.decode_step_plan(tables, lens, 8, 2, 16, 2, n_q_heads=4,
                               n_layers=3)
    three.validate()
    assert len(three.events) == 3 * len(one.events)
    assert three.macs == 3 * one.macs
    assert _dma_bytes(three) == 3 * _dma_bytes(one)
    pools = {e.page[0] for e in three.events
             if e.kind is P.EventKind.DMA_IN}
    assert pools == {f"L{i}.{t}" for i in range(3) for t in ("k", "v")}
    # same physical page ids per layer, distinct SMMU namespaces
    for i in range(3):
        ids = {e.page[1] for e in three.events
               if e.kind is P.EventKind.DMA_IN
               and e.page[0] == f"L{i}.k"}
        assert ids == {3, 7, 5}


def test_decode_step_schedule_footprint_counts_layers():
    tables, lens = [[3, 7], [5]], [12, 6]
    sched = P.decode_step_schedule(tables, lens, 8, 2, 16, 2, 4,
                                   n_q_heads=4)
    sched.validate()
    one = P.decode_step_plan(tables, lens, 8, 2, 16, 2, n_q_heads=4)
    assert sched.footprint_pages == 4 * one.footprint_pages
    assert sched.exact_events == 4 * len(one.events)


# -------------------------------------------------------------- prefill
def _held_table():
    pt = PageTable(PagedCacheConfig(
        n_pages=16, page_tokens=8, n_kv_heads=2, head_dim=16,
        max_pages_per_seq=4, dtype="float16"), max_seqs=2)
    assert pt.alloc_seq(0, 20)
    pt.note_tokens(0, 20)
    return pt


def test_prefill_plan_streams_exactly_the_table_pages():
    pt = _held_table()
    plan = pt.prefill_plan(0, 20, n_q_heads=4, d_model=64, d_ff=128)
    plan.validate()
    held = {int(p) for p in pt.tables[0, :int(pt.held[0])]}
    for pool in ("k", "v"):
        read = {e.page[1] for e in plan.events
                if e.kind is P.EventKind.DMA_IN and e.page[0] == pool}
        written = {e.page[1] for e in plan.events
                   if e.kind is P.EventKind.DMA_OUT
                   and e.page[0] == pool}
        assert read == held and written == held
    # chunk-causal structure: chunk i streams i+1 K pages, so QK passes
    # per pool page sum to group * (1 + 2 + ... + npg)
    group, npg = 4 // 2, 3
    qk = sum(1 for e in plan.events if e.op == "prefill_qk")
    assert qk == group * npg * (npg + 1) // 2
    # weight-streaming GEMMs present for every projection
    weights = {n for n, s in plan.tensors.items() if s.kind == "weight"}
    assert weights == {"wqkv", "wo", "w1", "w2"}


def test_prefill_plan_multi_layer_chains_and_replays():
    from repro.accesys.pipeline import replay
    from repro.accesys.system import default_system
    pt = _held_table()
    plan = pt.prefill_plan(0, 20, n_q_heads=4, d_model=64, d_ff=128,
                           n_layers=2)
    plan.validate()
    assert "L0.wqkv" in plan.tensors and "L1.wqkv" in plan.tensors
    # layer 0 output feeds layer 1's QKV projection
    assert plan.tensors["L0.prefill_out"].rows == 20
    for mode in ("DM", "DC", "DevMem"):
        r = replay(default_system(mode, dtype="fp16"), plan)
        assert r.total_s > 0 and r.compute_s > 0 and r.host_s > 0
        assert all(v >= 0 for v in r.buckets().values())


# -------------------------------------------------------- batched trace
def _mixed_trace_plans():
    pt = PageTable(PagedCacheConfig(
        n_pages=32, page_tokens=8, n_kv_heads=2, head_dim=16,
        max_pages_per_seq=4, dtype="float16"), max_seqs=3)
    plans = []
    for slot, ln in enumerate((20, 9, 17)):
        assert pt.alloc_seq(slot, ln)
        pt.note_tokens(slot, ln)
        plans.append(pt.prefill_plan(slot, ln, n_q_heads=4,
                                     d_model=64, d_ff=128, n_layers=2))
    for step in range(4):
        plans.append(pt.decode_step_plan([0, 1, 2], n_q_heads=4,
                                         n_layers=2))
    return plans


@pytest.mark.parametrize("mode,dram", [("DM", None), ("DC", None),
                                       ("DevMem", "HBM2")])
def test_replay_trace_engine_parity_and_attribution(mode, dram):
    """ONE batched compiled replay of a mixed prefill+decode trace must
    equal the sequential event replay on every aggregate field AND on
    every per-plan duration; durations sum to the total."""
    from repro.accesys.components import DRAM
    from repro.accesys.pipeline import replay_trace
    from repro.accesys.system import default_system
    plans = _mixed_trace_plans()
    mk = lambda: default_system(mode, dtype="fp16",
                                dram=DRAM(dram) if dram else None)
    r_e, per_e = replay_trace(mk(), plans, engine="event")
    r_c, per_c = replay_trace(mk(), plans, engine="compiled")
    np.testing.assert_allclose(per_c, per_e, rtol=1e-9)
    for f in dataclasses.fields(r_e):
        a, b = getattr(r_e, f.name), getattr(r_c, f.name)
        if isinstance(a, int):
            assert a == b, (f.name, a, b)
        else:
            assert b == pytest.approx(a, rel=1e-9, abs=1e-30), \
                (f.name, a, b)
    assert np.all(per_c > 0)
    assert per_c.sum() == pytest.approx(r_c.total_s, rel=1e-9)


def test_replay_trace_shares_page_interning_across_steps():
    """The batched replay's SMMU footprint is the union of pages the
    trace touches, not the per-plan sum — consecutive steps re-stream
    the same resident pool."""
    from repro.accesys.pipeline import replay_trace
    from repro.accesys.system import default_system
    plans = _mixed_trace_plans()
    sched = P.PlanSchedule("trace", [(p, 1) for p in plans])
    cp = sched.compile()
    assert len(cp.page_keys) < sum(len({e.page for e in p.events
                                        if e.page is not None})
                                   for p in plans)
    r, per = replay_trace(default_system("DC", dtype="fp16"), sched)
    assert len(per) == len(plans) and r.total_s > 0


def test_replay_trace_rejects_sampled_plans():
    from repro.accesys.pipeline import replay_trace
    from repro.accesys.system import default_system
    sampled = P.gemm_plan(256, 256, 2048, "int8", sample_stride=3)
    assert sampled.sampled_steps < sampled.total_steps
    with pytest.raises(ValueError, match="sampled"):
        replay_trace(default_system("DC"), [sampled])


# -------------------------------------------------- engine + sim report
@pytest.fixture(scope="module")
def reduced_engine_setup():
    import jax
    from repro.configs import get_reduced
    from repro.models.model import Model
    cfg = get_reduced("qwen2_0_5b")
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    return cfg, params


def _run_recorded(cfg, params, n_req=6, **engine_kw):
    from repro.serving.engine import Request, ServingEngine
    rng = np.random.default_rng(3)
    eng = ServingEngine(cfg, params, record_plans=True, **engine_kw)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, 250, size=6).astype(np.int32),
                    max_new_tokens=3) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps=500)
    return eng, reqs


def test_engine_records_request_centric_trace(reduced_engine_setup):
    cfg, params = reduced_engine_setup
    eng, reqs = _run_recorded(cfg, params, slots=2, max_seq=32)
    pre = [r for r in eng.trace if r.kind == "prefill"]
    dec = [r for r in eng.trace if r.kind == "decode"]
    assert len(pre) == len(reqs)
    assert {r.uids[0] for r in pre} == {r.uid for r in reqs}
    # decode plans are multi-layer GQA: model has n_heads > n_kv_heads
    assert cfg.n_heads > cfg.n_kv_heads
    pools = {e.page[0] for e in dec[0].plan.events
             if e.kind is P.EventKind.DMA_IN}
    assert f"L{cfg.n_layers - 1}.k" in pools
    # every decode token is attributed to a live uid at that step
    for rec in dec:
        assert len(rec.slots) == len(rec.uids) >= 1
    assert eng.step_plans == [r.plan for r in dec]


def test_simulated_ttft_tpot_fold_back_onto_requests(
        reduced_engine_setup):
    from repro.accesys.system import default_system
    from repro.serving.sim_report import simulate_serving_trace
    cfg, params = reduced_engine_setup
    eng, reqs = _run_recorded(cfg, params, slots=2, max_seq=32)
    rep = simulate_serving_trace(default_system("DC", dtype="fp16"),
                                 eng.trace)
    assert len(rep.requests) == len(reqs)
    by_uid = {r.uid: r for r in rep.requests}
    dec_steps = {u: 0 for u in by_uid}
    for rec in eng.trace:
        if rec.kind == "decode":
            for u in rec.uids:
                dec_steps[u] += 1
    for r in reqs:
        sim = by_uid[r.uid]
        assert sim.ttft_s > 0
        assert sim.n_tokens == 1 + dec_steps[r.uid] == len(r.output)
        if dec_steps[r.uid]:
            assert sim.tpot_s > 0
    # queueing shows up: with 2 slots and 6 requests, the last-admitted
    # request waits behind earlier completions
    ttfts = [by_uid[r.uid].ttft_s for r in reqs]
    assert max(ttfts) > min(ttfts)
    pct = rep.percentiles()
    assert pct["requests"] == len(reqs)
    assert pct["ttft_p99_us"] >= pct["ttft_p50_us"] > 0
    assert pct["tpot_p99_us"] >= pct["tpot_p50_us"] > 0
    assert rep.per_event_s.sum() == pytest.approx(rep.total_s,
                                                  rel=1e-9)


def test_engine_defers_admission_when_pool_full_then_readmits(
        reduced_engine_setup):
    """full -> drain -> re-admit: a shadow pool holding only 2 prompts
    defers the rest of the queue instead of raising, retirements free
    pages, every request still completes, and outputs match the
    unconstrained engine (greedy decode is batch-invariant)."""
    cfg, params = reduced_engine_setup
    # prompts are 6 tokens, max_new_tokens=3 -> final len 8 == one page
    eng, reqs = _run_recorded(cfg, params, slots=4, max_seq=16,
                              kv_pool_pages=2)
    assert eng.deferred_admissions > 0
    assert all(len(r.output) == r.max_new_tokens for r in reqs)
    assert not eng.queue
    # page reuse across re-admissions: never more than 2 pages live
    assert eng._table.pages_in_use == 0
    eng._table.validate()              # partitions intact post-drain
    prefills = [r for r in eng.trace if r.kind == "prefill"]
    assert len(prefills) == len(reqs)
    free_eng, free_reqs = _run_recorded(cfg, params, slots=4,
                                        max_seq=16)
    assert free_eng.deferred_admissions == 0
    assert [r.output for r in reqs] == [r.output for r in free_reqs]


def test_conservative_admission_survives_decode_growth(
        reduced_engine_setup):
    """A capped pool with requests whose decode growth crosses a page
    boundary must never crash mid-run: admission reserves the max
    length, so only one request runs at a time here and the rest
    defer until it retires."""
    from repro.serving.engine import Request, ServingEngine
    cfg, params = reduced_engine_setup
    rng = np.random.default_rng(5)
    eng = ServingEngine(cfg, params, slots=4, max_seq=16,
                        record_plans=True, kv_pool_pages=2)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, 250, size=6).astype(np.int32),
                    max_new_tokens=5)        # final len 10 -> 2 pages
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps=500)     # must not RuntimeError
    assert all(len(r.output) == r.max_new_tokens for r in reqs)
    assert eng.deferred_admissions > 0
    assert eng._table.pages_in_use == 0
    eng._table.validate()


def test_never_fitting_request_raises_instead_of_livelocking(
        reduced_engine_setup):
    from repro.serving.engine import Request, ServingEngine
    cfg, params = reduced_engine_setup
    eng = ServingEngine(cfg, params, slots=2, max_seq=32,
                        record_plans=True, kv_pool_pages=1)
    eng.submit(Request(uid=0,
                       prompt=np.arange(1, 13).astype(np.int32),
                       max_new_tokens=4))    # needs 2 pages, pool has 1
    with pytest.raises(ValueError, match="can never hold"):
        eng.run_until_drained(max_steps=50)


# ------------------------------------------------------ PageTable churn
def test_page_table_growth_across_boundaries_and_exhaustion_no_leak():
    pt = PageTable(PagedCacheConfig(
        n_pages=4, page_tokens=8, n_kv_heads=2, head_dim=16,
        max_pages_per_seq=4, dtype="float16"), max_seqs=2)
    assert pt.alloc_seq(0, 5)                  # 1 page
    assert pt.note_tokens(0, 8) and pt.held[0] == 1
    assert pt.note_tokens(0, 9) and pt.held[0] == 2   # crossed boundary
    assert pt.note_tokens(0, 17) and pt.held[0] == 3
    assert pt.alloc_seq(1, 3)                  # last free page
    assert pt.pages_in_use == 4
    # exhausted: growth fails but must not leak the pages already held
    assert not pt.note_tokens(1, 9)
    assert pt.held[1] == 1 and pt.pages_in_use == 4
    pt.free_seq(0)
    assert pt.pages_in_use == 1
    assert pt.note_tokens(1, 9) and pt.held[1] == 2   # drain -> regrow
    pt.validate()                      # free/owned partition the pool


def test_recorded_decode_plan_never_references_freed_pages():
    pt = PageTable(PagedCacheConfig(
        n_pages=8, page_tokens=8, n_kv_heads=2, head_dim=16,
        max_pages_per_seq=4, dtype="float16"), max_seqs=3)
    for slot, ln in enumerate((20, 9, 17)):
        assert pt.alloc_seq(slot, ln)
        pt.note_tokens(slot, ln)
    freed = {int(p) for p in pt.tables[1, :int(pt.held[1])]}
    pt.free_seq(1)
    plan = pt.decode_step_plan([0, 2], n_q_heads=4, n_layers=2)
    touched = {e.page[1] for e in plan.events
               if e.kind is P.EventKind.DMA_IN}
    assert not touched & freed
    # re-admission reuses the freed physical pages (LIFO free list)
    assert pt.alloc_seq(1, 9)
    reused = {int(p) for p in pt.tables[1, :int(pt.held[1])]}
    assert reused <= freed
    plan2 = pt.decode_step_plan([0, 1, 2])
    touched2 = {e.page[1] for e in plan2.events
                if e.kind is P.EventKind.DMA_IN}
    assert reused <= touched2
    pt.validate()
