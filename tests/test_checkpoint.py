import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16), jnp.bfloat16),
                       "b": jnp.arange(16, dtype=jnp.float32)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    st = _state()
    mgr.save(st, 3)
    restored, step = mgr.restore(jax.eval_shape(lambda: st))
    assert step == 3
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 5, 9):
        mgr.save(_state(s), s)
    assert mgr.all_steps() == [5, 9]
    assert mgr.latest_step() == 9


def test_async_save_waits(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(_state(), 1)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dirs never count as checkpoints (atomicity)."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    (tmp_path / ".tmp_step_00000007").mkdir()
    assert mgr.latest_step() is None
