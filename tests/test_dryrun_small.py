"""The dry-run machinery end-to-end in a subprocess with 8 fake devices
(a scaled-down production mesh) — proves lower+compile+roofline works
outside the big sweep."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src
    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax
from repro.configs import get_config, SHAPES
from repro.configs.base import RunConfig
from repro.launch.steps import build_step
from repro.launch import roofline as RL
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(data=2, model=4)
run = RunConfig(model=get_config("qwen2_0_5b"), shape=SHAPES["decode_32k"])
built = build_step(run, mesh)
with mesh:
    lowered = jax.jit(built.fn, in_shardings=built.in_shardings,
                      out_shardings=built.out_shardings,
                      donate_argnums=built.donate_argnums).lower(*built.abstract_inputs)
    compiled = lowered.compile()
cost = compiled.cost_analysis()
rl = RL.compute_roofline(cost, compiled.as_text(), 8,
                         RL.model_flops_for(run.model, run.shape),
                         compiled.memory_analysis())
assert rl.compute_s > 0 and rl.bytes_per_device > 0
print("DRYRUN_OK", rl.bottleneck)
"""
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=560)
    assert "DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
