"""The dry-run profiler must multiply loop bodies by trip count."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze


def _compile_scan(n_iters):
    w = jnp.zeros((64, 64), jnp.float32)

    def step(x, _):
        return jnp.tanh(x @ w), None

    def fn(x):
        y, _ = jax.lax.scan(step, x, None, length=n_iters)
        return y

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((8, 64), jnp.float32)).compile()


def test_flops_scale_with_trip_count():
    c2 = analyze(_compile_scan(2).as_text())
    c8 = analyze(_compile_scan(8).as_text())
    # per-iteration dot = 2*8*64*64; the 8-iter module must report ~4x
    ratio = c8.flops / max(c2.flops, 1)
    assert 3.0 < ratio < 5.0, ratio


def test_collectives_parsed_with_groups():
    text = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  ROOT %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    c = analyze(text)
    assert c.coll_count == 1
    assert c.coll_bytes == 16 * 16 * 4
    # ring all-reduce: 2 * bytes * (g-1)/g
    assert abs(c.coll_effective - 2 * 1024 * 0.75) < 1e-6
