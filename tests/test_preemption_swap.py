"""Preemption, KV swap-to-host, and graceful degradation under
memory pressure.

The contract under test, layer by layer:

* ``core.plan.swap_plan`` — page-aligned DMA plans on the dedicated
  swap lane, one stable ``(uid, page)`` namespace per KV pool so an
  out/in round trip re-touches identical page keys;
* ``PageTable.swap_out / validate / seize_pages`` — device pages are
  released exactly when the swap plan is emitted and the free/owned/
  prefix/seized partitions never overlap or leak;
* ``ServingEngine(preempt=...)`` — eviction moves work, never loses
  or repeats it: every prompt token prefilled exactly once and every
  token decoded exactly once across any number of preemptions, the
  pool drains to empty, and per-step invariants hold under seeded
  fault injection (burst storms, adversarial mixes, mid-run pool
  shrinkage);
* swap-bearing traces price BITWISE identically streamed vs
  monolithic at any chunk size, and ``sim_report`` splits each
  request's latency into additive components whose sums reproduce
  TTFT and end-to-end time exactly.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.accesys.pipeline import replay_trace, replay_trace_streamed
from repro.core import plan as plan_ir
from repro.core.scenario import MODES, Scenario, system_for
from repro.serving import faults, invariants
from repro.serving.engine import Request, ServingEngine
from repro.serving.kv_cache import PagedCacheConfig, PageTable
from repro.serving.sim_report import simulate_serving_trace


def _cfgs():
    return [system_for(Scenario(model="serve", mode=m)) for m in MODES]


def _engine(**kw):
    from repro.configs import get_reduced
    kw.setdefault("slots", 3)
    kw.setdefault("max_seq", 64)
    kw.setdefault("kv_page_tokens", 8)
    return ServingEngine(get_reduced("qwen2_0_5b"), plan_only=True,
                         **kw)


def _req(uid, n_prompt, max_new=4):
    return Request(uid=uid,
                   prompt=np.arange(1, n_prompt + 1, dtype=np.int32),
                   max_new_tokens=max_new)


def _overload(seed, policy, **kw):
    eng, reqs = faults.overload_run(seed, preempt=policy, **kw)
    assert eng.stats.drained
    return eng, reqs


# ================================================= swap_plan builder
class TestSwapPlan:
    def test_events_namespace_lane_and_kinds(self):
        for direction, kind in (("out", plan_ir.EventKind.DMA_OUT),
                                ("in", plan_ir.EventKind.DMA_IN)):
            p = plan_ir.swap_plan(3, 8, 2, 16, 2, direction=direction,
                                  tag=42, n_layers=2)
            # n_layers * (K + V) pools, one event per page each
            assert len(p.tensors) == 4
            assert len(p.events) == 3 * 4
            assert set(p.tensors) == {"L0.k.swap", "L0.v.swap",
                                      "L1.k.swap", "L1.v.swap"}
            for ev in p.events:
                assert ev.kind is kind
                assert ev.lane == plan_ir.SWAP_LANE
                assert ev.op == f"swap_{direction}"
                assert ev.nbytes == p.page_bytes
                ns, key = ev.page
                assert ns.endswith(".swap") and key[0] == 42
            pages = {ev.page for ev in p.events}
            assert len(pages) == len(p.events)   # no duplicate keys

    def test_out_in_round_trip_touches_identical_pages(self):
        out = plan_ir.swap_plan(2, 8, 2, 16, 2, direction="out", tag=7)
        back = plan_ir.swap_plan(2, 8, 2, 16, 2, direction="in", tag=7)
        assert {e.page for e in out.events} == \
            {e.page for e in back.events}
        assert plan_ir.trace_footprint([out, back]) == len(out.events)

    def test_page_bytes_and_footprint(self):
        p = plan_ir.swap_plan(5, 8, 2, 16, 2, direction="out", tag=0)
        assert p.page_bytes == 8 * 2 * 16 * 2
        assert sum(e.nbytes for e in p.events) == 2 * 5 * p.page_bytes
        assert plan_ir.trace_footprint([p]) == 10   # 5 pages x K,V

    def test_rejects_bad_direction_and_empty(self):
        with pytest.raises(ValueError, match="direction"):
            plan_ir.swap_plan(1, 8, 2, 16, 2, direction="up", tag=0)
        with pytest.raises(ValueError, match=">= 1 page"):
            plan_ir.swap_plan(0, 8, 2, 16, 2, direction="out", tag=0)

    def test_replays_standalone(self):
        p = plan_ir.swap_plan(4, 8, 2, 16, 2, direction="out", tag=1,
                              n_layers=2)
        res, per = replay_trace(_cfgs()[0], [p])
        assert res.total_s > 0 and per.shape == (1,)


# ======================================= PageTable swap + accounting
def _table(n_pages=12, page_tokens=8, max_seqs=3):
    return PageTable(PagedCacheConfig(
        n_pages=n_pages, page_tokens=page_tokens, n_kv_heads=2,
        head_dim=16, max_pages_per_seq=8, dtype="float16"),
        max_seqs=max_seqs)


class TestPageTableSwap:
    def test_written_own_pages_excludes_shared_and_unwritten(self):
        t = _table()
        t.alloc_seq(0, 20)             # 3 pages held, 0 shared
        assert t.written_own_pages(0, 0) == 0
        assert t.written_own_pages(0, 9) == 2
        assert t.written_own_pages(0, 20) == 3
        assert t.written_own_pages(0, 999) == 3   # capped at held

    def test_swap_out_frees_pages_and_emits_matching_plan(self):
        t = _table()
        t.alloc_seq(0, 20)
        before = t.pages_in_use
        plan, n = t.swap_out(0, 17, tag=5, n_layers=2)
        assert n == 3 and before == 3
        assert t.pages_in_use == 0
        # 3 pages x 2 layers x (K, V)
        assert len(plan.events) == 3 * 4
        assert all(e.kind is plan_ir.EventKind.DMA_OUT
                   for e in plan.events)
        t.validate()

    def test_swap_out_nothing_written_returns_no_plan(self):
        t = _table()
        t.alloc_seq(0, 8)
        plan, n = t.swap_out(0, 0, tag=1)
        assert plan is None and n == 0
        assert t.pages_in_use == 0
        t.validate()

    def test_seize_restore_round_trip(self):
        t = _table()
        assert t.seize_pages(5) == 5
        t.validate()
        assert t.pages_in_use == 5
        t.alloc_seq(0, 40)             # 5 pages from the 7 left
        t.validate()
        assert t.restore_pages() == 5
        t.validate()
        assert t.pages_in_use == 5     # only the slot's own pages

    def test_seize_is_clamped_to_free(self):
        t = _table()
        t.alloc_seq(0, 40)             # 5 of 12 pages
        assert t.seize_pages(99) == 7
        t.validate()

    def test_validate_catches_double_free(self):
        t = _table()
        t.alloc_seq(0, 16)
        t._free.append(int(t.tables[0, 0]))    # corrupt: page in both
        with pytest.raises(AssertionError):
            t.validate()

    def test_validate_catches_leak(self):
        t = _table()
        t.alloc_seq(0, 16)
        t._free.pop()                  # corrupt: page vanishes
        with pytest.raises(AssertionError):
            t.validate()


# ================================================ engine preemption
class TestEnginePreemption:
    @pytest.mark.parametrize("policy", ["lifo", "longest"])
    def test_conservation_across_preemptions(self, policy):
        eng, reqs = _overload(0, policy)
        assert eng.stats.preemptions > 0
        assert eng.stats.swapped_pages > 0
        invariants.check_drained(eng)
        tally = invariants.check_trace_conservation(
            eng.trace, reqs, max_seq=eng.max_seq)
        # every swap_out round-tripped, page counts matched
        assert any(v["swap_outs"] for v in tally.values())
        for v in tally.values():
            assert v["swap_outs"] == v["swap_ins"]
            assert v["swap_out_pages"] == v["swap_in_pages"]

    def test_preempts_only_running_request(self):
        # one monster holds nearly the whole pool; a second request
        # cannot reserve its worst case until the monster is evicted
        eng = _engine(slots=2, max_seq=64, kv_pool_pages=9)
        reqs = [_req(0, 40, max_new=8), _req(1, 24, max_new=8)]
        eng.run_open_loop(reqs, np.array([0.0, 0.0]),
                          prefill_chunk_tokens=8, est_step_s=1e-4,
                          est_prefill_s_per_token=1e-5,
                          preempt="lifo", debug_invariants=True)
        assert eng.stats.drained and eng.n_finished == 2
        assert eng.stats.preemptions >= 1
        first = next(i for i, r in enumerate(eng.trace)
                     if r.kind == "swap_out")
        assert eng.trace[first].uids == (0,)
        # everything before that eviction belongs to uid 0: it was
        # the ONLY running request when it was preempted
        assert all(r.uids == (0,) for r in eng.trace[:first])
        invariants.check_trace_conservation(eng.trace, reqs,
                                            max_seq=eng.max_seq)

    def test_chunk_boundary_preemption_mid_prefill(self):
        # small chunks + lifo + a spare slot: the newest runner is
        # evicted BETWEEN prefill chunks (admission-triggered
        # preemption needs a free slot) and resumes where it stopped
        eng = _engine(slots=3, max_seq=64, kv_pool_pages=9)
        reqs = [_req(0, 40, max_new=2), _req(1, 20, max_new=2),
                _req(2, 20, max_new=2)]
        eng.run_open_loop(reqs, np.zeros(3), prefill_chunk_tokens=8,
                          est_step_s=1e-4,
                          est_prefill_s_per_token=1e-5,
                          preempt="lifo", debug_invariants=True)
        assert eng.stats.drained
        per_uid: dict = {}
        mid_prefill = set()
        for rec in eng.trace:
            if rec.kind == "prefill":
                per_uid.setdefault(rec.uids[0], []).append(
                    rec.n_tokens)
            elif rec.kind == "swap_out":
                uid = rec.uids[0]
                done = sum(per_uid.get(uid, []))
                if 0 < done < len(reqs[uid].prompt):
                    mid_prefill.add(uid)
        assert mid_prefill               # someone was evicted mid-prefill
        for uid, chunks in per_uid.items():
            assert sum(chunks) == len(reqs[uid].prompt), \
                (uid, chunks)
        invariants.check_trace_conservation(eng.trace, reqs,
                                            max_seq=eng.max_seq)

    def test_swap_in_racing_retire(self):
        # requests preempted mid-decode with few tokens left must
        # resume and retire immediately without double-producing
        found = False
        for seed in range(6):
            eng, reqs = _overload(seed, "lifo", n_requests=40)
            tally = invariants.check_trace_conservation(
                eng.trace, reqs, max_seq=eng.max_seq)
            # a uid whose LAST swap_in is followed by at most one of
            # its decode records: resume raced straight into retire
            for uid, v in tally.items():
                if not v["swap_ins"]:
                    continue
                last_in = max(i for i, r in enumerate(eng.trace)
                              if r.kind == "swap_in"
                              and r.uids[0] == uid)
                after = sum(1 for r in eng.trace[last_in + 1:]
                            if r.kind == "decode" and uid in r.uids)
                if after <= 1:
                    found = True
        assert found

    def test_policy_validation(self):
        eng = _engine()
        with pytest.raises(ValueError, match="preempt"):
            eng.run_open_loop([_req(0, 8)], np.zeros(1),
                              preempt="fifo")
        with pytest.raises(ValueError, match="stall_budget"):
            eng.run_open_loop([_req(0, 8)], np.zeros(1),
                              preempt="lifo", stall_budget_s=-1.0)

    def test_no_preemption_without_policy(self):
        # same pressured pool and storm, no policy armed: the engine
        # defers instead of evicting and still drains cleanly
        eng = _engine(slots=3, max_seq=64, kv_pool_pages=13)
        reqs = faults.adversarial_requests(30, seed=0, max_seq=64)
        arr = faults.storm_arrivals(30, 400.0, seed=0)
        eng.run_open_loop(reqs, arr, prefill_chunk_tokens=8,
                          est_step_s=1e-4,
                          est_prefill_s_per_token=1e-5,
                          debug_invariants=True)
        assert eng.stats.drained
        assert eng.stats.preemptions == 0
        assert not any(r.kind.startswith("swap") for r in eng.trace)
        assert eng.deferred_admissions > 0
        invariants.check_trace_conservation(eng.trace, reqs,
                                            max_seq=eng.max_seq)


# ======================================== non-drained exit surfacing
class TestDrainedFlag:
    def test_truncated_open_loop_reports_not_drained(self):
        eng = _engine()
        reqs = [_req(i, 16, max_new=8) for i in range(8)]
        eng.run_open_loop(reqs, np.zeros(8), prefill_chunk_tokens=8,
                          max_steps=3)
        assert not eng.stats.drained
        assert eng.unfinished_uids()

    def test_full_open_loop_reports_drained(self):
        eng = _engine()
        reqs = [_req(i, 16, max_new=4) for i in range(4)]
        eng.run_open_loop(reqs, np.zeros(4), prefill_chunk_tokens=8)
        assert eng.stats.drained
        assert not eng.unfinished_uids()


# =========================================== fault-injection harness
class TestFaultInjection:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("policy", ["lifo", "longest"])
    def test_overload_properties(self, seed, policy):
        """Bounded queue, full drain, per-step invariants (checked
        inside the run), trace conservation — per seed and policy."""
        eng, reqs = _overload(seed, policy, n_requests=48)
        assert eng.n_finished == len(reqs)
        invariants.check_drained(eng)
        invariants.check_trace_conservation(eng.trace, reqs,
                                            max_seq=eng.max_seq)

    def test_storm_arrivals_shape(self):
        arr = faults.storm_arrivals(100, 50.0, seed=3, storms=4)
        assert arr.shape == (100,) and np.all(np.diff(arr) >= 0)
        # zero-width spikes: repeated identical instants
        _, counts = np.unique(arr, return_counts=True)
        assert counts.max() >= 100 * 0.5 / 4
        assert np.array_equal(arr,
                              faults.storm_arrivals(100, 50.0, seed=3,
                                                    storms=4))

    def test_adversarial_mix_fits_budget(self):
        reqs = faults.adversarial_requests(64, seed=1, max_seq=64,
                                           max_new_hi=8)
        assert {r.uid for r in reqs} == set(range(64))
        assert all(len(r.prompt) + r.max_new_tokens <= 64
                   for r in reqs)
        big = sum(len(r.prompt) >= 42 for r in reqs)
        assert 0 < big < 64            # a mix, not a monoculture

    def test_pool_shrink_fault_seizes_and_restores(self):
        eng = _engine(kv_pool_pages=10)
        f = faults.PoolShrinkFault(at_step=0, n_pages=4,
                                   restore_step=2)
        f.on_step(eng, 0)
        assert f.seized == 4 and eng._table.pages_in_use == 4
        f.on_step(eng, 1)
        assert eng._table.pages_in_use == 4
        f.on_step(eng, 2)
        assert f.restored and eng._table.pages_in_use == 0

    def test_smoke_main_exits_clean(self):
        assert faults.main(["--seeds", "0", "--requests", "24"]) == 0


# ================================= bitwise streamed parity with swap
class TestSwapTraceParity:
    def test_streamed_matches_monolithic_all_modes(self):
        eng, _ = _overload(1, "lifo", n_requests=40)
        assert eng.stats.preemptions > 0
        plans = [r.plan for r in eng.trace]
        cfgs = _cfgs()
        mono = [replay_trace(c, plans) for c in cfgs]
        for chunk in (1, 311, 10**9):
            res, pers = replay_trace_streamed(cfgs, plans,
                                              chunk_events=chunk)
            for (mr, mp), r, p in zip(mono, res, pers):
                for f in dataclasses.fields(mr):
                    assert getattr(mr, f.name) == getattr(r, f.name), \
                        (chunk, f.name)
                assert np.array_equal(mp, p), chunk


# ================================================ latency attribution
class TestSwapAttribution:
    def _report(self, seed=0, policy="lifo"):
        eng, reqs = _overload(seed, policy)
        cfg = system_for(Scenario(model="serve", mode="DC"))
        return eng, simulate_serving_trace(cfg, eng.trace)

    def test_components_sum_exactly(self):
        eng, rep = self._report()
        assert any(r.n_preempt for r in rep.requests)
        for r in rep.requests:
            if not math.isnan(r.ttft_s):
                assert abs(r.queue_s + r.prefill_s + r.swap_pre_s
                           - r.ttft_s) < 1e-12
                assert r.queue_s >= -1e-12
                assert r.prefill_s > 0 and r.swap_pre_s >= 0
            if not math.isnan(r.e2e_s):
                total = r.queue_s + r.prefill_s + r.swap_pre_s + \
                    r.decode_s + r.swap_post_s + r.stall_s
                assert abs(total - r.e2e_s) < 1e-12
                assert r.stall_s >= -1e-12 and r.swap_post_s >= 0

    def test_swap_time_conserved_and_attributed(self):
        eng, rep = self._report()
        rec_swap = sum(d for d, rec in zip(rep.per_event_s, eng.trace)
                       if rec.kind.startswith("swap"))
        attr = sum(r.swap_s for r in rep.requests
                   if not math.isnan(r.swap_s))
        assert rec_swap > 0
        assert abs(rec_swap - attr) < 1e-12
        for r in rep.requests:
            if math.isnan(r.swap_s):
                continue
            assert (r.swap_s > 0) == (r.n_preempt > 0), r

    def test_percentiles_carry_swap_and_queue_tails(self):
        _, rep = self._report()
        pct = rep.percentiles()
        assert pct["n_preempted"] > 0
        assert pct["preemptions"] >= pct["n_preempted"]
        assert pct["swap_s_total"] > 0
        for key in ("swap_p50_us", "swap_p99_us", "queue_p50_us",
                    "queue_p99_us"):
            assert not math.isnan(pct[key])
        assert pct["swap_p99_us"] >= pct["swap_p50_us"] >= 0


# ====================================================== load sweep
class TestPreemptionSweep:
    def test_sweep_prices_past_the_knee_with_swap(self):
        from repro.core.scenario import sweep_load
        res = sweep_load(n_requests=40, preempt="lifo",
                         modes=("DC",), slots=3, max_seq=64,
                         prompt_lo=8, prompt_hi=24,
                         prefill_chunk_tokens=8)
        assert res.preempt == "lifo"
        assert res.kv_pool_pages is not None
        assert res.kv_pool_pages < 3 * (64 // 8)   # pressured
        k = res.knee_qps["DC"]
        assert k is not None
        past = [pt for pt in res.curve("DC") if pt.qps > k]
        assert past                    # >=1 priced point past the knee
        assert any(pt.percentiles["preemptions"] > 0 for pt in past)
        assert all(pt.drained for pt in res.curve("DC"))
        assert "preempt" in res.to_json()
