"""Template-compiled plan instancing: property + parity tests.

A ``PlanTemplate`` compiles each decode/prefill/swap geometry ONCE to
a ``CompiledPlan`` skeleton; per-step instances are cheap page-id
relabels.  The contract under test:

  * an instance's compiled arrays EQUAL a freshly built plan's —
    every column, dtype, and the interned page-key order — for random
    geometries and page maps (including shared pages, empty slots,
    partial pages, chunked-prefill spans, swap both directions);
  * instance memos carry only page-id-independent entries, so the
    cross-chunk LRU seeding stays exact;
  * a templated serving trace replays BITWISE identically (rtol 0,
    every ``GemmResult`` field, all three modes) to its event-built
    twin at chunk sizes 1 / odd / inf, including swap-bearing
    preemption traces;
  * per-request attribution (``RequestSim`` additive identities) is
    invariant under templating;
  * ``sweep_load(workers=N)`` / ``tune(workers=N)`` equal workers=1.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.accesys.pipeline import replay_trace, replay_trace_streamed
from repro.core import plan as plan_ir
from repro.core.plan import (PLAN_TEMPLATES, PlanTemplate,
                             _GEOMETRY_MEMO_KEYS, _plan_n_events,
                             trace_footprint)
from repro.core.scenario import MODES, Scenario, system_for
from repro.serving.engine import Request, ServingEngine, arrival_times

ELEM = 1
COMPILED_COLS = ("trace_ids", "trace_nbytes", "trace_is_out",
                 "in_lane", "op_kind", "op_val", "grp_end", "n_lanes",
                 "seg_op", "seg_trace")


def _cfgs():
    return [system_for(Scenario(model="serve", mode=m)) for m in MODES]


def _assert_compiled_equal(a, b, label=""):
    assert a.n_events == b.n_events, label
    assert a.page_keys == b.page_keys, label
    for f in COMPILED_COLS:
        x, y = getattr(a, f), getattr(b, f)
        assert x.dtype == y.dtype, (label, f, x.dtype, y.dtype)
        assert np.array_equal(x, y), (label, f)


def _assert_bitwise(res_a, per_a, res_b, per_b, label=""):
    for f in dataclasses.fields(res_a):
        a, b = getattr(res_a, f.name), getattr(res_b, f.name)
        assert a == b, (label, f.name, a, b)
    assert np.array_equal(per_a, per_b), (label, "per_plan")


# ==================================== instance == fresh, per builder
class TestInstanceEqualsFresh:
    def test_decode_random_geometries_and_page_maps(self):
        """25 random decode geometries x random page tables (shared
        pages, empty slots, partial last pages) through ONE template
        cache: instance compile == fresh-plan compile."""
        rng = np.random.default_rng(0)
        tpl = PlanTemplate()
        for trial in range(25):
            n_layers = int(rng.integers(1, 4))
            pt = int(rng.choice([4, 8]))
            kh = int(rng.choice([1, 2, 4]))
            hq = kh * int(rng.choice([1, 2]))
            hd = int(rng.choice([8, 16]))
            shared = list(rng.choice(500, size=2, replace=False))
            tables, lens = [], []
            for _ in range(int(rng.integers(1, 5))):
                own = int(rng.integers(0, 4))
                t = ([int(p) for p in shared] if rng.random() < 0.3
                     else []) + \
                    [int(p) for p in rng.choice(
                        np.arange(500, 900), size=own, replace=False)]
                tables.append(t)
                lens.append(0 if not t else
                            len(t) * pt - int(rng.integers(0, pt)))
            if not any(tables):
                tables[0], lens[0] = [int(rng.integers(500))], pt
            inst = tpl.decode_step(tables, lens, pt, kh, hd, ELEM,
                                   n_q_heads=hq, n_layers=n_layers)
            fresh = plan_ir.decode_step_plan(
                tables, lens, pt, kh, hd, ELEM, n_q_heads=hq,
                n_layers=n_layers)
            _assert_compiled_equal(inst.compile(), fresh.compile(),
                                   label=f"decode trial {trial}")
            assert _plan_n_events(inst) == len(fresh.events)
        # same geometry, new page ids -> a cache hit, still exact
        hits0 = tpl.hits
        remap = [[p + 1000 for p in t] for t in tables]
        inst = tpl.decode_step(remap, lens, pt, kh, hd, ELEM,
                               n_q_heads=hq, n_layers=n_layers)
        fresh = plan_ir.decode_step_plan(remap, lens, pt, kh, hd,
                                         ELEM, n_q_heads=hq,
                                         n_layers=n_layers)
        _assert_compiled_equal(inst.compile(), fresh.compile(),
                               label="decode cache-hit remap")
        assert tpl.hits == hits0 + 1

    def test_prefill_random_geometries_including_spans(self):
        rng = np.random.default_rng(1)
        tpl = PlanTemplate()
        for trial in range(20):
            pt = int(rng.choice([4, 8]))
            T = int(rng.integers(1, 5 * pt))
            npg = -(-T // pt)
            tbl = [int(p) for p in rng.choice(700, size=npg,
                                              replace=False)]
            kh, hd = 2, 8
            n_layers = int(rng.integers(1, 3))
            span = None
            if npg > 1 and rng.random() < 0.5:
                s0 = pt * int(rng.integers(0, npg - 1))
                s1 = T if rng.random() < 0.5 else \
                    pt * int(rng.integers(s0 // pt + 1, npg))
                span = (s0, s1)
            kw = dict(n_q_heads=4, n_layers=n_layers, span=span)
            inst = tpl.prefill(tbl, T, pt, kh, hd, ELEM, **kw)
            fresh = plan_ir.prefill_plan(tbl, T, pt, kh, hd, ELEM,
                                         **kw)
            _assert_compiled_equal(
                inst.compile(), fresh.compile(),
                label=f"prefill trial {trial} span={span}")

    def test_swap_both_directions(self):
        tpl = PlanTemplate()
        for direction in ("out", "in"):
            for n_pages in (1, 3):
                for tag in (0, 7):
                    inst = tpl.swap(n_pages, 8, 2, 16, ELEM,
                                    direction=direction, tag=tag,
                                    n_layers=2)
                    fresh = plan_ir.swap_plan(
                        n_pages, 8, 2, 16, ELEM, direction=direction,
                        tag=tag, n_layers=2)
                    _assert_compiled_equal(
                        inst.compile(), fresh.compile(),
                        label=f"swap {direction} {n_pages}p tag{tag}")

    def test_instance_memo_is_geometry_only(self):
        """Relabeled instances must not carry page-id-dependent memo
        entries — ``_stream_seed_memo`` would otherwise seed chunked
        LRU state from the WRONG page ids."""
        tpl = PlanTemplate()
        inst = tpl.decode_step([[3, 9], [12]], [16, 8], 8, 2, 16, ELEM)
        memo = inst.compile().memo
        assert set(memo) <= set(_GEOMETRY_MEMO_KEYS), set(memo)
        assert "prev" not in memo and "sd" not in memo

    def test_events_materialize_on_demand(self):
        """``.events`` on a template instance rebuilds the true event
        graph — identical to the fresh builder's."""
        tbls, lens = [[5, 42], [17]], [16, 8]
        inst = PLAN_TEMPLATES.decode_step(tbls, lens, 8, 2, 16, ELEM)
        fresh = plan_ir.decode_step_plan(tbls, lens, 8, 2, 16, ELEM)
        assert len(inst.events) == len(fresh.events)
        for a, b in zip(inst.events, fresh.events):
            assert (a.kind, a.nbytes, a.page, a.op) == \
                (b.kind, b.nbytes, b.page, b.op)
        assert trace_footprint([inst]) == trace_footprint([fresh])


# ==================================== trace-level bitwise parity
def _requests(n, seed=11):
    rng = np.random.default_rng(seed)
    return [Request(
        uid=i,
        prompt=rng.integers(1, 250,
                            size=int(rng.integers(4, 16))
                            ).astype(np.int32),
        max_new_tokens=int(rng.integers(1, 5)))
        for i in range(n)]


def _trace(templated, n=40, preempt=False):
    from repro.configs import get_reduced
    kw = dict(kv_pool_pages=4) if preempt else {}
    eng = ServingEngine(get_reduced("qwen2_0_5b"), plan_only=True,
                        slots=3, max_seq=48, kv_page_tokens=8,
                        templated=templated, **kw)
    arr = arrival_times("poisson", n, 400.0, seed=3)
    eng.run_open_loop(_requests(n), arr, prefill_chunk_tokens=8,
                      est_step_s=1e-4, est_prefill_s_per_token=1e-5,
                      **(dict(preempt="lifo") if preempt else {}))
    return eng


class TestTemplatedTraceParity:
    @pytest.mark.parametrize("preempt", [False, True])
    def test_replay_bitwise_all_chunk_sizes(self, preempt):
        """Templated trace vs event-built twin: same record/event
        counts, bitwise GemmResults at chunk 1 / odd / inf, all three
        modes — including the swap-bearing preemption trace."""
        ev, tp = _trace(False, preempt=preempt), \
            _trace(True, preempt=preempt)
        plans_ev = [r.plan for r in ev.trace]
        plans_tp = [r.plan for r in tp.trace]
        assert len(plans_ev) == len(plans_tp)
        assert [r.kind for r in ev.trace] == [r.kind for r in tp.trace]
        assert sum(len(p.events) for p in plans_ev) == \
            sum(_plan_n_events(p) for p in plans_tp)
        if preempt:
            assert tp.stats.preemptions == ev.stats.preemptions > 0
            assert any(getattr(p, "skeleton", None) is not None
                       and "swap" in p.name for p in plans_tp)
        cfgs = _cfgs()
        mono = [replay_trace(c, plans_ev) for c in cfgs]
        for chunk in (1, 777, 10**9):
            res, pers = replay_trace_streamed(cfgs, plans_tp,
                                              chunk_events=chunk)
            for (mr, mp), r, p, c in zip(mono, res, pers, cfgs):
                _assert_bitwise(
                    mr, mp, r, p,
                    label=f"chunk={chunk} mode={c.mode} "
                          f"preempt={preempt}")

    def test_request_attribution_invariant(self):
        """Satellite: ``RequestSim`` per-request attribution must be
        invariant under templating — identical folds AND the additive
        TTFT / e2e identities on the templated swap-bearing trace."""
        from repro.serving.sim_report import simulate_serving_trace
        ev, tp = _trace(False, preempt=True), _trace(True, preempt=True)
        cfg = _cfgs()[1]                              # DC
        rep_ev = simulate_serving_trace(cfg, ev.trace)
        rep_tp = simulate_serving_trace(cfg, tp.trace)
        assert rep_tp.percentiles() == rep_ev.percentiles()
        assert rep_tp.total_s == rep_ev.total_s
        for a, b in zip(rep_ev.requests, rep_tp.requests):
            for f in dataclasses.fields(a):
                x, y = getattr(a, f.name), getattr(b, f.name)
                assert x == y or (isinstance(x, float)
                                  and math.isnan(x) and math.isnan(y)), \
                    (a.uid, f.name, x, y)
        got_ttft = got_e2e = 0
        for r in rep_tp.requests:
            if not math.isnan(r.ttft_s):
                assert abs(r.queue_s + r.prefill_s + r.swap_pre_s
                           - r.ttft_s) <= 1e-12 + 1e-9 * r.ttft_s
                got_ttft += 1
            if not math.isnan(r.e2e_s) and not math.isnan(r.decode_s):
                assert abs(r.ttft_s + r.decode_s + r.stall_s
                           + r.swap_post_s - r.e2e_s) \
                    <= 1e-12 + 1e-9 * r.e2e_s
                got_e2e += 1
        assert got_ttft > 0 and got_e2e > 0


# ==================================== parallel sweep parity
class TestParallelSweeps:
    def test_sweep_load_workers_parity(self):
        from repro.core.scenario import sweep_load
        kw = dict(qps=(10.0, 30.0), n_requests=16)
        j1 = sweep_load(**kw).to_json()
        j2 = sweep_load(workers=2, **kw).to_json()
        j1.pop("wall_s"), j2.pop("wall_s")
        assert j1 == j2

    def test_sweep_load_templated_matches_event_built(self):
        from repro.core.scenario import sweep_load
        kw = dict(qps=(10.0, 30.0), n_requests=16)
        j1 = sweep_load(**kw).to_json()
        j2 = sweep_load(templated=False, **kw).to_json()
        j1.pop("wall_s"), j2.pop("wall_s")
        assert j1 == j2

    def test_tune_workers_parity(self):
        from repro.core import design_space as DS
        from repro.core.scenario import tune
        pts = [DS.DesignPoint(dtype=dt, page_bytes=pb)
               for dt in ("int8", "fp16") for pb in (2048, 4096)]
        sc = Scenario(model="bert-base", seq=32)
        r1 = tune(sc, space=pts)
        r2 = tune(sc, space=pts, workers=2)
        for a, b in zip(r1.points, r2.points):
            assert a.result == b.result and a.score == b.score
            assert a.on_pareto == b.on_pareto
