from repro.sharding.logical import make_rules, spec_for

MS = {"data": 16, "model": 16}
MS3 = {"pod": 2, "data": 16, "model": 16}


def rules(**kw):
    return make_rules(multi_pod=False, **kw)


def test_weight_fsdp_plus_tp():
    spec = spec_for(("embed", "mlp"), (4096, 16384), rules(), MS)
    assert tuple(spec) == ("data", "model")


def test_conflict_resolution_expert_wins_over_mlp():
    spec = spec_for(("expert", "embed", "mlp"), (64, 512, 2048),
                    rules(), MS)
    assert tuple(spec) == ("model", "data", None)


def test_non_divisible_replicates():
    spec = spec_for(("embed", "heads", "head_dim"), (896, 14, 64),
                    rules(), MS)
    assert tuple(spec) == ("data", None, None)


def test_kv_heads_fall_back_to_cache_seq():
    # kv=2 cannot take model; cache_seq claims it instead
    spec = spec_for(("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                    (128, 32768, 2, 64), rules(), MS)
    assert tuple(spec) == ("data", "model", None, None)


def test_long_context_shards_cache_seq_over_data():
    r = make_rules(multi_pod=False, long_context=True)
    spec = spec_for(("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                    (1, 524288, 32, 112), r, MS)
    assert tuple(spec) == (None, "data", "model", None)


def test_multi_pod_batch_takes_pod_and_data():
    r = make_rules(multi_pod=True)
    spec = spec_for(("batch", "seq"), (256, 4096), r, MS3)
    assert tuple(spec) == (("pod", "data"), None)


def test_seq_q_only_when_heads_cannot():
    r = rules()
    # heads divisible -> heads get model, seq_q drops
    s1 = spec_for(("batch", "kv_heads", "heads", "seq_q", None),
                  (16, 16, 1, 512, 64), r, MS)
    assert tuple(s1)[1] == "model" and tuple(s1)[3] is None
    # heads NOT divisible -> seq_q takes model
    s2 = spec_for(("batch", "kv_heads", "heads", "seq_q", None),
                  (16, 2, 7, 512, 64), r, MS)
    assert tuple(s2)[3] == "model"
