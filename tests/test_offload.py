import jax
import jax.numpy as jnp
import numpy as np

from repro.core.modes import MemoryMode
from repro.core.offload import LayerStreamer


def test_layer_streaming_all_modes_equal():
    L, d = 6, 32
    stacked = {"w": jax.random.normal(jax.random.PRNGKey(0), (L, d, d),
                                      jnp.float32) * 0.2}
    x0 = jax.random.normal(jax.random.PRNGKey(1), (4, d), jnp.float32)
    fn = lambda p, x: jnp.tanh(x @ p["w"])
    outs, reports = {}, {}
    for mode in MemoryMode:
        streamer = LayerStreamer(stacked, L, mode, cache_layers=2)
        out, rep = streamer.run(fn, x0)
        outs[mode] = np.asarray(out)
        reports[mode] = rep
    np.testing.assert_allclose(outs[MemoryMode.DM],
                               outs[MemoryMode.DEVMEM], rtol=1e-6)
    np.testing.assert_allclose(outs[MemoryMode.DC],
                               outs[MemoryMode.DEVMEM], rtol=1e-6)
    assert reports[MemoryMode.DEVMEM].bytes_streamed == 0
    assert reports[MemoryMode.DM].bytes_streamed >= \
        reports[MemoryMode.DC].bytes_streamed
