import os
import sys

# tests see exactly ONE device (the dry-run sets its own count in a
# subprocess); keep memory modest on the CI box
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
