import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import compression as GC


def test_error_feedback_is_unbiased_over_time():
    """Accumulated dequantized grads converge to accumulated true grads."""
    g = {"w": jnp.full((32, 32), 0.001, jnp.float32) +
         jax.random.normal(jax.random.PRNGKey(0), (32, 32)) * 1e-5}
    ef = GC.init_ef(g)
    total_dq = jnp.zeros((32, 32))
    n = 50
    for _ in range(n):
        dq, ef = GC.apply_compression(g, ef)
        total_dq = total_dq + dq["w"]
    np.testing.assert_allclose(total_dq / n, g["w"], rtol=0.02, atol=1e-5)


def test_quantization_error_bounded():
    x = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,)) * 3}
    qs, scales, _ = GC.compress(x, GC.init_ef(x))
    dq = GC.decompress(qs, scales)
    err = jnp.abs(dq["w"] - x["w"]).max()
    assert float(err) <= float(scales["w"]) * 0.5 + 1e-6
