"""Chunked linear attention must equal the exact sequential recurrence —
the invariant that makes the paged/chunked streaming path trustworthy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (chunked_linear_attention,
                              linear_attention_step)


@pytest.mark.parametrize("inclusive", [True, False])
@pytest.mark.parametrize("T,chunk", [(32, 16), (48, 16), (16, 16), (64, 8)])
def test_chunked_equals_sequential(inclusive, T, chunk):
    B, H, N, M = 2, 3, 8, 5
    r = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, N))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, N))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, M))
    logw = -jax.nn.softplus(
        jax.random.normal(jax.random.PRNGKey(3), (B, T, H, N)))
    u = None if inclusive else jnp.abs(
        jax.random.normal(jax.random.PRNGKey(4), (H, N)))
    s0 = jax.random.normal(jax.random.PRNGKey(5), (B, H, N, M))

    out_c, sT_c = chunked_linear_attention(r, k, v, logw, s0, u=u,
                                           chunk=chunk,
                                           inclusive=inclusive)
    s = s0.astype(jnp.float32)
    outs = []
    for t in range(T):
        o, s = linear_attention_step(r[:, t], k[:, t], v[:, t],
                                     logw[:, t], s, u=u,
                                     inclusive=inclusive)
        outs.append(o)
    out_s = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(out_c, out_s, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(sT_c, s, rtol=2e-4, atol=2e-4)


def test_rwkv_chunked_vs_step_through_block():
    """Full rwkv block: chunked forward state == replayed per-token."""
    from repro.configs import get_reduced
    from repro.models.params import init_tree
    from repro.models import ssm as SSM
    cfg = get_reduced("rwkv6_7b")
    p = init_tree(SSM.rwkv_pspecs(cfg), jax.random.PRNGKey(0),
                  jnp.float32)["time"]
    B, T = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (B, T, cfg.d_model), jnp.float32) * 0.3
    st = {"s": jnp.zeros((B, cfg.n_heads, cfg.d_model // cfg.n_heads,
                          cfg.d_model // cfg.n_heads), jnp.float32),
          "shift": jnp.zeros((B, cfg.d_model), jnp.float32)}
    out_c, st_c = SSM.rwkv_time_mix(p, x, cfg, st)
    st_s = st
    outs = []
    for t in range(T):
        o, st_s = SSM.rwkv_time_mix_step(p, x[:, t], cfg, st_s)
        outs.append(o)
    out_s = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(out_c, out_s, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(st_c["s"], st_s["s"], rtol=2e-3, atol=2e-3)


def test_mamba_chunked_vs_step():
    from repro.configs import get_reduced
    from repro.models.params import init_tree
    from repro.models import ssm as SSM
    cfg = get_reduced("zamba2_7b")
    p = init_tree(SSM.mamba2_pspecs(cfg), jax.random.PRNGKey(0),
                  jnp.float32)
    B, T = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (B, T, cfg.d_model), jnp.float32) * 0.3
    st = SSM.init_mamba_state(cfg, B, jnp.float32)
    out_c, st_c = SSM.mamba2_forward(p, x, cfg, st)
    st_s = SSM.init_mamba_state(cfg, B, jnp.float32)
    outs = []
    for t in range(T):
        o, st_s = SSM.mamba2_step(p, x[:, t], cfg, st_s)
        outs.append(o)
    out_s = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(out_c, out_s, rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(st_c["s"], st_s["s"], rtol=3e-3, atol=3e-3)
