"""Hypothesis property tests on the paging/tiling invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import overlap, paging, streaming
from repro.core.modes import MemoryMode

DTYPES = [np.float32, np.float16, np.int8]


@settings(max_examples=30, deadline=None)
@given(r=st.integers(1, 200), c=st.integers(1, 200),
       dt=st.sampled_from(DTYPES), op=st.sampled_from(["A", "B"]))
def test_pack_unpack_roundtrip(r, c, dt, op):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((r, c)) * 10).astype(dt)
    lay = paging.layout_for(x.shape, x.dtype, op)
    pages = paging.pack_pages(jnp.asarray(x), lay)
    assert pages.shape[0] == lay.n_pages
    # every page holds exactly one OS page worth of elements
    assert pages.shape[1] * pages.shape[2] * x.dtype.itemsize == \
        paging.PAGE_BYTES
    back = paging.unpack_pages(pages, lay)
    np.testing.assert_array_equal(np.asarray(back), x)


@settings(max_examples=20, deadline=None)
@given(r=st.integers(1, 300), c=st.integers(1, 300),
       dt=st.sampled_from(DTYPES))
def test_page_of_is_a_partition(r, c, dt):
    lay = paging.layout_for((r, c), np.dtype(dt), "B")
    seen = {}
    for rr in range(0, r, lay.tile_r):
        for cc in range(0, c, lay.tile_c):
            pid = lay.page_of(rr, cc)
            assert 0 <= pid < lay.n_pages
            assert pid not in seen
            seen[pid] = (rr, cc)
    assert len(seen) == lay.n_pages


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 100), n=st.integers(1, 100), k=st.integers(1, 400))
def test_schedule_covers_every_output_tile_once(m, n, k):
    counts = streaming.tile_counts(m, n, k, np.float32)
    seen = {}
    for op in streaming.schedule(m, n, k, np.float32):
        key = (op.i, op.j)
        if op.first_k:
            assert key not in seen
            seen[key] = 0
        seen[key] += 1
    assert len(seen) == counts["out_tiles"]
    assert all(v == counts["k_steps"] for v in seen.values())


@settings(max_examples=10, deadline=None)
@given(w=st.sampled_from([8, 16, 32]), l=st.integers(4, 2048),
       s=st.sampled_from([1, 2, 4]))
def test_overlap_bound_below_asymptote(w, l, s):
    req = overlap.required_bandwidth(w, l, 1e9, s)
    asym = overlap.asymptotic_bandwidth(w, 1e9, s)
    assert req < asym
    # monotone increasing in L (fill/drain slack shrinks)
    assert overlap.required_bandwidth(w, l + 1, 1e9, s) >= req


def test_streamed_gemm_matches_numpy_all_modes():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((33, 100)).astype(np.float32)
    b = rng.standard_normal((100, 41)).astype(np.float32)
    for mode in MemoryMode:
        out, store = streaming.gemm_streamed(a, b, mode, cache_pages=4)
        np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)
    # DM streams everything; DC caches some; DevMem streams nothing
    _, dm = streaming.gemm_streamed(a, b, MemoryMode.DM)
    _, dc = streaming.gemm_streamed(a, b, MemoryMode.DC, cache_pages=64)
    _, dv = streaming.gemm_streamed(a, b, MemoryMode.DEVMEM)
    assert dm.stats.host_to_device_bytes >= dc.stats.host_to_device_bytes
    assert dv.stats.host_to_device_bytes == 0
