import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import Adafactor, AdamW, cosine_schedule


def _quad_losses(opt, steps=60):
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    state = opt.init(params)
    lr = cosine_schedule(0.3, 5, steps)
    losses = []
    for s in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt.update(grads, state, params, lr(s))
        losses.append(float(jnp.mean((params["w"] - target) ** 2)))
    return losses


def test_adamw_converges_quadratic():
    losses = _quad_losses(AdamW(weight_decay=0.0))
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_converges_quadratic():
    losses = _quad_losses(Adafactor())
    assert losses[-1] < 0.1 * losses[0]


def test_adafactor_state_is_factored():
    opt = Adafactor()
    st = opt.init({"w": jnp.zeros((64, 128))})
    slots = st["slots"]["w"]
    assert slots["vr"].shape == (64,) and slots["vc"].shape == (128,)


def test_optimizer_state_axes_congruent():
    from repro.configs import get_reduced
    from repro.models.model import Model
    m = Model(get_reduced("qwen2_0_5b"))
    params = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    for opt in (AdamW(), Adafactor()):
        st = jax.eval_shape(opt.init, params)
        ax = opt.state_axes(m.param_axes())
        # structure congruence: same tree paths resolve
        jax.tree.map(lambda *_: None, st, ax,
                     is_leaf=lambda x: isinstance(x, tuple))
