"""The faithful-reproduction scorecard: every paper headline must PASS."""
import pytest

from repro.accesys.calibration import validate


@pytest.fixture(scope="module")
def claims():
    return validate(fast=True)


def test_all_fast_claims_pass(claims):
    failing = [c.row() for c in claims if not c.ok]
    assert not failing, "\n".join(failing)


def test_table9_rows_within_12pct(claims):
    rows = [c for c in claims if c.name.startswith("table9")]
    assert len(rows) == 6
    for c in rows:
        assert c.ok, c.row()


@pytest.mark.slow
def test_full_claims_including_fig10_fig13():
    failing = [c.row() for c in validate(fast=False) if not c.ok]
    assert not failing, "\n".join(failing)
