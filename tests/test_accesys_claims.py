"""The faithful-reproduction scorecard: every paper headline must PASS.

The simulator now times ``StreamPlan`` event graphs; the single-GEMM
claims go through the same plan the functional executor runs, and the
end-to-end claims additionally cover composed multi-layer transformer
plans (the paper's BERT/ViT-class forward passes).
"""
import numpy as np
import pytest

from repro.accesys.calibration import validate
from repro.accesys.components import DRAM
from repro.accesys.pipeline import replay, simulate_gemm
from repro.accesys.system import default_system
from repro.core import plan as P


@pytest.fixture(scope="module")
def claims():
    return validate(fast=True)


def test_all_fast_claims_pass(claims):
    failing = [c.row() for c in claims if not c.ok]
    assert not failing, "\n".join(failing)


def test_table9_rows_within_12pct(claims):
    rows = [c for c in claims if c.name.startswith("table9")]
    assert len(rows) == 6
    for c in rows:
        assert c.ok, c.row()


@pytest.mark.slow
def test_full_claims_including_fig10_fig13():
    failing = [c.row() for c in validate(fast=False) if not c.ok]
    assert not failing, "\n".join(failing)


# ------------------------------------------------- plan-based simulator
# Pinned pre-refactor outputs: the plan-based replayer must reproduce
# the original hand-rolled pipeline bit-for-bit (modulo float summation
# order).  (total_s, tlb_lookups, tlb_misses, ptw_walks.)
SEED_GEMM_NUMBERS = {
    ("int8", 512, "DM"): (1.000546582376e-03, 5120, 3136, 1152),
    ("int8", 512, "DC"): (6.151879396860e-04, 5120, 3136, 1152),
    ("int8", 512, "DevMem"): (9.272243448276e-04, 5120, 3136, 1152),
    ("int32", 1024, "DM"): (3.149002630646e-02, 135168, 70656, 6144),
    ("int32", 1024, "DevMem"): (2.914377735627e-02, 135168, 70656, 6144),
    ("fp16", 512, "DC"): (1.135804546039e-03, 9216, 5248, 1280),
}


@pytest.mark.parametrize("dtype,n,mode", sorted(SEED_GEMM_NUMBERS))
def test_simulate_gemm_unchanged_vs_seed(dtype, n, mode):
    total, lookups, misses, walks = SEED_GEMM_NUMBERS[(dtype, n, mode)]
    r = simulate_gemm(default_system(mode, dtype=dtype), n, n, n)
    assert abs(r.total_s - total) / total < 1e-9, (r.total_s, total)
    assert (r.tlb_lookups, r.tlb_misses, r.ptw_walks) == \
        (lookups, misses, walks)


def test_simulator_and_executor_share_the_plan():
    """simulate_gemm replays the exact event stream gemm_streamed
    executes: same builder, same loop order, same page keys."""
    from repro.core import streaming
    M = N = K = 96
    plan = P.gemm_plan(M, N, K, "int8")
    r_plan = replay(default_system("DC"), plan)
    r_gemm = simulate_gemm(default_system("DC"), M, N, K, "int8")
    assert r_plan.total_s == pytest.approx(r_gemm.total_s, rel=1e-12)
    # and the functional executor consumes the same plan's pages
    rng = np.random.default_rng(0)
    a = rng.integers(-10, 10, (M, K)).astype(np.int8)
    b = rng.integers(-10, 10, (K, N)).astype(np.int8)
    from repro.core.modes import MemoryMode
    outs, store = streaming.execute_plan(plan, {"a": a, "b": b},
                                         MemoryMode.DM)
    counts = plan.counts()
    assert store.stats.lookups == counts["dma_in"]["a"] \
        + counts["dma_in"]["b"]


@pytest.mark.parametrize("mode,dram", [("DM", None), ("DC", None),
                                       ("DevMem", "HBM2")])
def test_composed_multilayer_replay_has_fig2_buckets(mode, dram):
    plan = P.model_plan(32, 64, 2, 512, 2, "int8")
    cfg = default_system(mode, dram=DRAM(dram) if dram else None)
    r = replay(cfg, plan)
    b = r.buckets()
    assert set(b) == {"descriptor", "translation", "transfer",
                      "compute", "drain", "host", "collective"}
    assert r.total_s > 0 and r.compute_s > 0 and r.host_s > 0
    assert all(v >= 0 for v in b.values())


def test_composed_mode_ordering_weight_heavy():
    """End-to-end latency on a weight-heavy stack: streaming everything
    over the link (DM) >= link+LLC (DC) >= on-card HBM2 (DevMem) — i.e.
    performance DevMem >= DC >= DM, the paper's Fig.-12 ordering."""
    plan = P.model_plan(32, 64, 2, 512, 2, "int8")
    t_dm = replay(default_system("DM"), plan).total_s
    t_dc = replay(default_system("DC"), plan).total_s
    t_dev = replay(default_system("DevMem", dram=DRAM("HBM2")),
                   plan).total_s
    assert t_dm >= t_dc >= t_dev, (t_dm, t_dc, t_dev)


def test_composed_replay_scales_with_depth():
    one = replay(default_system("DC"), P.model_plan(32, 64, 2, 256, 1,
                                                    "int8")).total_s
    three = replay(default_system("DC"), P.model_plan(32, 64, 2, 256, 3,
                                                      "int8")).total_s
    assert 2.0 < three / one < 3.5
