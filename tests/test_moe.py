import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models.moe import apply_moe, moe_pspecs
from repro.models.params import init_tree


def _naive_moe(p, x, cfg):
    """Per-token loop oracle (lossless routing)."""
    B, T, d = x.shape
    m = cfg.moe
    xt = np.asarray(x.reshape(B * T, d), np.float32)
    logits = xt @ np.asarray(p["router"], np.float32)
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = np.asarray(top_p / top_p.sum(-1, keepdims=True))
    top_e = np.asarray(top_e)
    wg = np.asarray(p["wi_gate"], np.float32)
    wu = np.asarray(p["wi_up"], np.float32)
    wo = np.asarray(p["wo"], np.float32)
    out = np.zeros_like(xt)
    for i in range(xt.shape[0]):
        for j in range(m.top_k):
            e = int(top_e[i, j])
            h = (xt[i] @ wg[e])
            h = h / (1 + np.exp(-h)) * (xt[i] @ wu[e])
            out[i] += top_p[i, j] * (h @ wo[e])
    if m.n_shared_experts:
        sg = np.asarray(p["shared_wi_gate"], np.float32)
        su = np.asarray(p["shared_wi_up"], np.float32)
        so = np.asarray(p["shared_wo"], np.float32)
        h = xt @ sg
        h = h / (1 + np.exp(-h)) * (xt @ su)
        out += h @ so
    return out.reshape(B, T, d)


def test_moe_lossless_matches_naive():
    cfg = get_reduced("qwen2_moe_a2_7b")
    p = init_tree(moe_pspecs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.5
    y, aux = apply_moe(p, x, cfg, capacity=16)   # n tokens => lossless
    want = _naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-3, atol=2e-3)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_gracefully():
    cfg = get_reduced("qwen2_moe_a2_7b")
    p = init_tree(moe_pspecs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    y, _ = apply_moe(p, x, cfg, capacity_factor=0.5)
    assert jnp.isfinite(y).all()


def test_local_dispatch_matches_global_lossless():
    """Row-local dispatch (the collective-free hillclimb variant) must
    agree with the global path when routing is lossless."""
    import jax.numpy as jnp
    from repro.models import tuning as TU
    cfg = get_reduced("qwen2_moe_a2_7b")
    p = init_tree(moe_pspecs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, cfg.d_model),
                          jnp.float32) * 0.5
    y_global, _ = apply_moe(p, x, cfg, capacity=24)
    with TU.tuning_context(TU.Tuning(moe_local_dispatch=True)):
        y_local, _ = apply_moe(p, x, cfg, capacity=8 * cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_global),
                               rtol=2e-3, atol=2e-3)
