"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, paged_attention, streaming_gemm
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,n,k", [(64, 128, 128), (100, 200, 300),
                                   (256, 256, 512), (33, 257, 129)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_gemm_matches_ref(m, n, k, dtype):
    a = jax.random.normal(KEY, (m, k), jnp.dtype(dtype))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.dtype(dtype))
    out = streaming_gemm(a, b, bm=32, bn=128, bk=128, interpret=True)
    want = ref.gemm_ref(a, b)
    tol = 2e-2 if dtype == "bfloat16" else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_gemm_int8_exact():
    a = jax.random.randint(KEY, (64, 256), -127, 127, jnp.int8)
    b = jax.random.randint(jax.random.PRNGKey(1), (256, 128), -127, 127,
                           jnp.int8)
    out = streaming_gemm(a, b, bm=32, bn=128, bk=128, interpret=True)
    want = ref.gemm_ref(a, b, jnp.int8)
    np.testing.assert_array_equal(np.asarray(out, np.int32),
                                  np.asarray(want, np.int32))


@pytest.mark.parametrize("tq,tk,h,kh,d", [(128, 128, 4, 2, 32),
                                          (64, 256, 8, 8, 64),
                                          (96, 96, 6, 1, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(tq, tk, h, kh, d, causal):
    if not causal and tq != tk:
        pytest.skip("non-causal requires equal block-divisible kv")
    q = jax.random.normal(KEY, (2, tq, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, tk, kh, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, tk, kh, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=32, bk=32,
                          interpret=True)
    g = h // kh
    qf = q.reshape(2, tq, kh, g, d).transpose(0, 2, 3, 1, 4).reshape(-1, tq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(-1, tk, d), g, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(-1, tk, d), g, axis=0)
    want = ref.flash_ref(qf, kf, vf, causal).reshape(2, kh, g, tq, d) \
        .transpose(0, 3, 1, 2, 4).reshape(2, tq, h, d)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("b,h,kh,d,page,mp", [(3, 8, 2, 32, 16, 4),
                                              (2, 4, 4, 64, 8, 6),
                                              (1, 16, 1, 16, 32, 2)])
def test_paged_matches_ref(b, h, kh, d, page, mp):
    P = b * mp + 4
    q = jax.random.normal(KEY, (b, h, d), jnp.float32)
    kp = jax.random.normal(jax.random.PRNGKey(1), (P, page, kh, d),
                           jnp.float32)
    vp = jax.random.normal(jax.random.PRNGKey(2), (P, page, kh, d),
                           jnp.float32)
    table = jax.random.permutation(jax.random.PRNGKey(3), P)[:b * mp] \
        .reshape(b, mp).astype(jnp.int32)
    lens = jnp.asarray(
        np.random.default_rng(0).integers(1, page * mp, size=(b,)),
        jnp.int32)
    out = paged_attention(q, kp, vp, table, lens, interpret=True)
    want = ref.paged_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


def test_paged_matches_contiguous_decode():
    """Paged kernel == the model's contiguous decode attention."""
    from repro.models.layers import decode_attention
    b, h, kh, d, page, mp = 2, 8, 2, 32, 16, 4
    P = b * mp
    q = jax.random.normal(KEY, (b, h, d), jnp.float32)
    kp = jax.random.normal(jax.random.PRNGKey(1), (P, page, kh, d), jnp.float32)
    vp = jax.random.normal(jax.random.PRNGKey(2), (P, page, kh, d), jnp.float32)
    table = jnp.arange(P, dtype=jnp.int32).reshape(b, mp)
    lens = jnp.asarray([17, 61], jnp.int32)
    paged = paged_attention(q, kp, vp, table, lens, interpret=True)
    k = kp[table].reshape(b, mp * page, kh, d)
    v = vp[table].reshape(b, mp * page, kh, d)
    contig = decode_attention(q, k, v, lens)
    np.testing.assert_allclose(paged, contig, rtol=3e-5, atol=3e-5)
