"""Streaming chunked trace replay: parity, determinism, censoring.

The tentpole contract under test: ``replay_trace_streamed`` is
BITWISE identical (rtol 0, every ``GemmResult`` field, all three
memory modes) to the monolithic ``replay_trace`` at any chunk size —
including chunk sizes that split a request's prefill chunks and
decode steps across replay chunks — while touching only O(chunk)
state at a time.  Plus the open-loop serving machinery the scale
unlocks: seeded arrival processes, chunked-prefill admission, prefix
caching, and censored percentile edge cases.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.accesys.pipeline import (_SCRATCH_POOL, release_scratch,
                                    replay_trace, replay_trace_streamed)
from repro.core import plan as plan_ir
from repro.core.scenario import MODES, Scenario, system_for
from repro.serving.engine import Request, ServingEngine, arrival_times
from repro.serving.sim_report import ServingAccumulator, fold_requests


def _cfgs():
    return [system_for(Scenario(model="serve", mode=m)) for m in MODES]


def _requests(n, seed=7, max_new_lo=1, max_new_hi=8,
              prompt_lo=4, prompt_hi=20):
    rng = np.random.default_rng(seed)
    return [Request(
        uid=i,
        prompt=rng.integers(1, 250,
                            size=int(rng.integers(prompt_lo,
                                                  prompt_hi))
                            ).astype(np.int32),
        max_new_tokens=int(rng.integers(max_new_lo, max_new_hi)))
        for i in range(n)]


def _open_loop_engine(**kw):
    from repro.configs import get_reduced
    kw.setdefault("slots", 3)
    kw.setdefault("max_seq", 64)
    kw.setdefault("kv_page_tokens", 8)
    return ServingEngine(get_reduced("qwen2_0_5b"), plan_only=True,
                         **kw)


def _open_loop_trace(n_requests, *, seed=7, qps=200.0, engine_kw=None,
                     run_kw=None, req_kw=None):
    eng = _open_loop_engine(**(engine_kw or {}))
    arr = arrival_times("poisson", n_requests, qps, seed=3)
    eng.run_open_loop(_requests(n_requests, seed=seed,
                                **(req_kw or {})), arr,
                      prefill_chunk_tokens=8, **(run_kw or {}))
    return eng


def _assert_bitwise(res_a, per_a, res_b, per_b, label=""):
    for f in dataclasses.fields(res_a):
        a, b = getattr(res_a, f.name), getattr(res_b, f.name)
        assert a == b, (label, f.name, a, b)
    assert np.array_equal(per_a, per_b), (label, "per_plan")


# ================================================== bitwise parity
class TestStreamedParity:
    def test_matches_monolithic_28_requests(self):
        """The 28-request open-loop trace, random chunk sizes that
        split requests mid-flight, every field, all three modes."""
        eng = _open_loop_trace(28)
        plans = [r.plan for r in eng.trace]
        cfgs = _cfgs()
        mono = [replay_trace(c, plans) for c in cfgs]
        rng = np.random.default_rng(0)
        sizes = [1, *rng.integers(50, 5000, size=3), 10**9]
        for chunk in sizes:
            res, pers = replay_trace_streamed(cfgs, plans,
                                              chunk_events=int(chunk))
            for (mr, mp), r, p, c in zip(mono, res, pers, cfgs):
                _assert_bitwise(mr, mp, r, p,
                                label=f"chunk={chunk} mode={c.mode}")

    def test_matches_monolithic_1k_requests(self):
        """>= 1k requests — the scale the streaming path exists for —
        still bitwise at a mid-request chunk size, all modes."""
        eng = _open_loop_trace(
            1000, qps=2000.0,
            engine_kw=dict(slots=4, max_seq=32),
            run_kw=dict(est_step_s=1e-4,
                        est_prefill_s_per_token=1e-5),
            req_kw=dict(max_new_lo=1, max_new_hi=3,
                        prompt_lo=4, prompt_hi=10))
        plans = [r.plan for r in eng.trace]
        n_ev = sum(len(p.events) for p in plans)
        assert len(plans) >= 1000 and n_ev > 200_000
        cfgs = _cfgs()
        mono = [replay_trace(c, plans) for c in cfgs]
        res, pers = replay_trace_streamed(cfgs, plans,
                                          chunk_events=32_768)
        for (mr, mp), r, p, c in zip(mono, res, pers, cfgs):
            _assert_bitwise(mr, mp, r, p, label=c.mode)

    def test_matches_on_scenario_serve_trace(self):
        """The JAX-engine closed-loop scenario trace (the seed's
        existing serve path) prices identically when streamed."""
        from repro.core.scenario import _serve_trace
        trace, sched = _serve_trace(Scenario(model="serve"))
        cfg = _cfgs()[0]
        mres, mper = replay_trace(cfg, sched)
        sres, sper = replay_trace_streamed(
            cfg, [pl for pl, _ in sched.segments], chunk_events=700)
        _assert_bitwise(mres, mper, sres, sper)

    def test_config_dedup_and_single_cfg_form(self):
        eng = _open_loop_trace(6)
        plans = [r.plan for r in eng.trace]
        dm, dc, dev = _cfgs()
        dm2 = _cfgs()[0]
        res, pers = replay_trace_streamed([dm, dc, dm2], plans,
                                          chunk_events=999)
        _assert_bitwise(res[0], pers[0], res[2], pers[2])
        assert res[0] is not res[2]       # fanned out, not aliased
        one, per1 = replay_trace_streamed(dm, plans, chunk_events=999)
        _assert_bitwise(res[0], pers[0], one, per1)

    def test_callable_factory_two_pass(self):
        """A zero-arg factory (the O(chunk)-memory form) discovers the
        footprint on pass 1 and prices on pass 2 — same result as a
        materialized list with an explicit footprint."""
        eng = _open_loop_trace(8)
        plans = [r.plan for r in eng.trace]
        foot = plan_ir.trace_footprint(plans)
        cfg = _cfgs()[1]
        a = replay_trace_streamed(cfg, lambda: iter(plans),
                                  chunk_events=512)
        b = replay_trace_streamed(cfg, plans, footprint_pages=foot,
                                  chunk_events=512)
        _assert_bitwise(a[0], a[1], b[0], b[1])

    def test_rejects_sampled_and_empty(self):
        from repro.core.plan import gemm_plan
        sampled = gemm_plan(512, 512, 4096, np.int8,
                            sample_stride=4)
        assert sampled.sampled_steps != sampled.total_steps
        cfg = _cfgs()[0]
        with pytest.raises(ValueError, match="exact"):
            replay_trace_streamed(cfg, [sampled])
        with pytest.raises(ValueError, match="plan"):
            replay_trace_streamed(cfg, [])


# ============================================== arrival determinism
class TestArrivals:
    @pytest.mark.parametrize("kind", ["poisson", "bursty", "diurnal"])
    def test_seeded_determinism(self, kind):
        a = arrival_times(kind, 500, 25.0, seed=11)
        b = arrival_times(kind, 500, 25.0, seed=11)
        assert np.array_equal(a, b)
        c = arrival_times(kind, 500, 25.0, seed=12)
        assert not np.array_equal(a, c)
        assert np.all(np.diff(a) >= 0) and a[0] >= 0
        # mean offered rate in the right ballpark
        rate = 500 / a[-1]
        assert 25.0 / 3 < rate < 25.0 * 3

    def test_bad_inputs(self):
        with pytest.raises(ValueError):
            arrival_times("weibull", 10, 1.0)
        with pytest.raises(ValueError):
            arrival_times("poisson", 10, 0.0)

    def test_open_loop_trace_determinism(self):
        """Same seed => identical trace (record kinds, uids, plan
        names, event counts and page ids)."""
        runs = []
        for _ in range(2):
            eng = _open_loop_trace(
                10, engine_kw=dict(prefix_tokens=16,
                                   prefix_caching=True))
            runs.append([
                (r.kind, r.uids, r.arrival_event, r.n_tokens,
                 r.plan.name, len(r.plan.events),
                 tuple(ev.page for ev in r.plan.events[:5]))
                for r in eng.trace])
        assert runs[0] == runs[1]


# ================================================ censored reports
class TestCensoredReport:
    def test_prefill_only_requests(self):
        """max_new_tokens=1 requests decode zero tokens: tpot is nan,
        counted, percentiles never crash."""
        eng2 = _open_loop_engine(slots=2)
        reqs = _requests(6, seed=5, max_new_lo=1, max_new_hi=2)
        eng2.run_open_loop(reqs, np.zeros(6), prefill_chunk_tokens=8)
        cfg = _cfgs()[0]
        from repro.serving.sim_report import simulate_serving_trace
        rep = simulate_serving_trace(cfg, eng2.trace)
        p = rep.percentiles()
        assert p["n_prefill_only"] == len(reqs)
        assert p["n_in_flight"] == 0
        assert all(math.isnan(r.tpot_s) for r in rep.requests)
        assert math.isnan(p["tpot_p99_us"])
        assert not math.isnan(p["ttft_p99_us"])

    def test_in_flight_censoring(self):
        """Truncating the run mid-flight censors unfinished requests:
        no TPOT contribution, nan TTFT for still-prefilling uids, and
        the counter reports them."""
        eng = _open_loop_engine(slots=2)
        reqs = _requests(8, seed=9, max_new_lo=6, max_new_hi=12)
        eng.run_open_loop(reqs, np.zeros(8), prefill_chunk_tokens=8,
                          max_steps=6)
        live = eng.unfinished_uids()
        assert live                       # truncation left work behind
        cfg = _cfgs()[0]
        from repro.serving.sim_report import simulate_serving_trace
        rep = simulate_serving_trace(cfg, eng.trace, in_flight=live)
        p = rep.percentiles()
        assert p["n_in_flight"] == sum(r.censored for r in rep.requests)
        assert p["n_in_flight"] > 0
        for r in rep.requests:
            if r.censored:
                assert math.isnan(r.tpot_s)
        # uncensored folding of the same truncated trace would skew:
        # the censored report must not include truncated decodes
        rep_skewed = simulate_serving_trace(cfg, eng.trace)
        n_tpot = sum(0 if math.isnan(r.tpot_s) else 1
                     for r in rep.requests)
        n_tpot_skewed = sum(0 if math.isnan(r.tpot_s) else 1
                            for r in rep_skewed.requests)
        assert n_tpot <= n_tpot_skewed

    def test_accumulator_matches_direct_fold(self):
        """Streaming accumulator (metadata teed off a generator) folds
        identically to fold_requests over the retained trace."""
        eng = _open_loop_trace(10)
        per = np.linspace(1e-6, 2e-6, len(eng.trace))
        direct = fold_requests(eng.trace, per, in_flight=())
        acc = ServingAccumulator()
        for _ in acc.wrap(iter(eng.trace)):
            pass
        streamed = fold_requests(acc.meta, per, in_flight=())
        assert direct == streamed


# ======================================== prefix caching & spans
class TestPrefixAndSpans:
    def test_prefill_span_default_identity(self):
        """span=(0, T) produces the byte-identical plan the builder
        has always produced."""
        tbl = np.arange(10, 16, dtype=np.int32)
        kw = dict(n_q_heads=4, d_model=64, d_ff=128, n_layers=2)
        full = plan_ir.prefill_plan(tbl, 44, 8, 2, 16, 2, **kw)
        spanned = plan_ir.prefill_plan(tbl, 44, 8, 2, 16, 2,
                                       span=(0, 44), **kw)
        assert len(full.events) == len(spanned.events)
        assert full.macs == spanned.macs
        for a, b in zip(full.events, spanned.events):
            assert (a.kind, a.page, a.nbytes, a.lane, a.deps, a.op) \
                == (b.kind, b.page, b.nbytes, b.lane, b.deps, b.op)

    def test_prefill_span_chunks_cover_full_macs(self):
        """Chunked spans attend the same causal structure: summed MACs
        equal the monolithic prefill's."""
        tbl = np.arange(10, 16, dtype=np.int32)
        kw = dict(n_q_heads=4, d_model=64, d_ff=128, n_layers=1)
        full = plan_ir.prefill_plan(tbl, 44, 8, 2, 16, 2, **kw)
        chunks = [plan_ir.prefill_plan(tbl, 44, 8, 2, 16, 2,
                                       span=(s0, s1), **kw)
                  for s0, s1 in ((0, 16), (16, 32), (32, 44))]
        assert sum(c.macs for c in chunks) == full.macs
        with pytest.raises(ValueError):
            plan_ir.prefill_plan(tbl, 44, 8, 2, 16, 2, span=(3, 16),
                                 **kw)
        with pytest.raises(ValueError):
            plan_ir.prefill_plan(tbl, 44, 8, 2, 16, 2, span=(0, 15),
                                 **kw)

    def test_reserve_prefix_pages_outlive_requests(self):
        from repro.serving.kv_cache import PagedCacheConfig, PageTable
        t = PageTable(PagedCacheConfig(
            n_pages=16, page_tokens=8, n_kv_heads=2, head_dim=16,
            max_pages_per_seq=8, dtype="float16"), max_seqs=2)
        pfx = t.reserve_prefix(2)
        assert len(pfx) == 2 and t.pages_in_use == 2
        assert t.alloc_seq(0, 32, prefix=pfx)
        assert list(t.tables[0, :2]) == list(pfx)
        assert int(t.shared[0]) == 2 and int(t.held[0]) == 4
        assert t.pages_in_use == 4       # 2 shared + 2 own
        t.free_seq(0)
        # own pages returned, shared pages still reserved
        assert t.pages_in_use == 2

    def test_prefix_caching_shrinks_trace(self):
        n = 10
        arr = arrival_times("poisson", n, 100.0, seed=3)
        traces = {}
        for caching in (False, True):
            eng = _open_loop_engine(prefix_tokens=16,
                                    prefix_caching=caching)
            eng.run_open_loop(_requests(n), arr,
                              prefill_chunk_tokens=8)
            assert eng.n_finished == n
            traces[caching] = eng.trace
        # cached: one shared prefix record replaces per-request spans
        assert len(traces[True]) < len(traces[False])
        assert traces[True][0].uids == (-1,)
        assert all(r.uids != (-1,) for r in traces[False])
        cfg = _cfgs()[1]
        tot = {c: replay_trace(cfg, [r.plan for r in tr])[0].total_s
               for c, tr in traces.items()}
        assert tot[True] < tot[False]     # the measurable reuse win


# ==================================================== scratch pool
class TestScratchPool:
    def test_release_scratch(self):
        from repro.accesys.pipeline import replay_batch
        from repro.core.plan import gemm_plan
        pl = gemm_plan(256, 256, 512, np.int8)
        replay_batch(_cfgs(), pl)
        assert _SCRATCH_POOL          # batched pricing leaves scratch
        freed = release_scratch()
        assert freed > 0 and not _SCRATCH_POOL
        assert release_scratch() == 0
