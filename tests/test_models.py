"""Per-arch smoke + the cache-consistency invariant: prefill+decode
logits must match the full-sequence forward (validates every cache
layout: GQA, MLA latent, mamba/rwkv state, whisper cross-attn)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.configs.base import ShapeConfig
from repro.models import transformer as T
from repro.models.model import Model, make_concrete_batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_smoke(arch):
    cfg = get_reduced(arch)
    m = Model(cfg, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    tb = make_concrete_batch(cfg, ShapeConfig("t", "train", 64, 2))
    loss, metrics = jax.jit(m.loss)(params, tb)
    assert jnp.isfinite(loss)
    assert 2.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """logits(prefill(t[:n])) ≈ logits(forward(t[:n])) and one decode
    step advances identically to a longer prefill."""
    cfg = get_reduced(arch)
    m = Model(cfg, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    B, Tq, S = 2, 32, 48
    pb = make_concrete_batch(cfg, ShapeConfig("p", "prefill", Tq, B))
    cache, logits_prefill = jax.jit(lambda p, b: m.prefill(p, b, S))(
        params, pb)
    assert jnp.isfinite(logits_prefill).all()
    if cfg.family == "audio" or cfg.embedding_inputs:
        return  # decode continuity needs token prompts
    if cfg.family != "moe":
        # forward gives the same last-position logits (moe differs by
        # design: training drops tokens at capacity, serving is lossless)
        h, _, _ = T.forward_train(params, cfg, pb, "none")
        from repro.models import layers as L
        hl = L.apply_norm(params["final_norm"], h[:, -1:], cfg)[:, 0]
        logits_fwd = T.lm_head(params, cfg, hl)
        np.testing.assert_allclose(
            np.asarray(logits_prefill, np.float32),
            np.asarray(logits_fwd, np.float32), rtol=0.1, atol=0.15)
    # decode continuity: prefill(t[:T-1]) + decode(t[T-1]) == prefill(t)
    toks = pb["tokens"]
    pb_short = {"tokens": toks[:, :-1]}
    cache_s, _ = jax.jit(lambda p, b: m.prefill(p, b, S))(params, pb_short)
    cache_d, logits_dec = jax.jit(m.decode_step)(params, cache_s,
                                                 toks[:, -1])
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_prefill, np.float32), rtol=0.12, atol=0.2)


def test_mtp_loss_present():
    cfg = get_reduced("deepseek_v3_671b")
    m = Model(cfg, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    tb = make_concrete_batch(cfg, ShapeConfig("t", "train", 32, 2))
    loss, metrics = m.loss(params, tb)
    assert "mtp" in metrics and jnp.isfinite(metrics["mtp"])


def test_int8_kv_cache_decode_close_to_bf16():
    """INT8 paged KV (the beyond-paper bandwidth optimization) must stay
    numerically close to the bf16 cache path."""
    from repro.models import tuning as TU
    cfg = get_reduced("qwen1_5_32b")
    m = Model(cfg, remat="none")
    params = m.init(jax.random.PRNGKey(0))
    pb = make_concrete_batch(cfg, ShapeConfig("p", "prefill", 24, 2))
    cache, _ = m.prefill(params, pb, 40)
    tok = pb["tokens"][:, -1]
    _, logits_bf16 = m.decode_step(params, cache, tok)
    with TU.tuning_context(TU.Tuning(kv_cache_quant=True)):
        cache_q, _ = m.prefill(params, pb, 40)
        assert cache_q["layers"]["k"].dtype == jnp.int8
        _, logits_q = m.decode_step(params, cache_q, tok)
    # logits agree to quantization tolerance
    np.testing.assert_allclose(np.asarray(logits_q, np.float32),
                               np.asarray(logits_bf16, np.float32),
                               rtol=0.12, atol=0.25)
