"""End-to-end system behaviour: fault tolerance (crash → restart →
bit-identical data replay), straggler watchdog, elastic remesh restore,
and loss actually falling on the synthetic corpus."""
import subprocess
import sys
import os

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import RunConfig, ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.train_loop import Trainer, TrainerConfig


def _run(tmp, cfg=None, steps=14, inject=None, compression=False):
    cfg = cfg or get_reduced("qwen2_0_5b")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 64, 4),
                    remat="none", gradient_compression=compression)
    tr = Trainer(run, make_host_mesh(1, 1),
                 TrainerConfig(ckpt_dir=str(tmp), ckpt_every=5,
                               lr_base=5e-3, lr_warmup=2, lr_total=200),
                 inject_failure_at=inject)
    return tr, run


@pytest.mark.slow
def test_loss_falls(tmp_path):
    tr, _ = _run(tmp_path)
    out = tr.train(14)
    assert out["final_loss"] < out["losses"][0]


@pytest.mark.slow
def test_crash_restart_resumes_exactly(tmp_path):
    tr, _ = _run(tmp_path / "a", inject=11)
    with pytest.raises(RuntimeError, match="injected node failure"):
        tr.train(30)
    # a fresh trainer resumes from the step-9 checkpoint and continues
    tr2, _ = _run(tmp_path / "a")
    out2 = tr2.train(16)
    # uninterrupted reference run
    tr3, _ = _run(tmp_path / "b")
    out3 = tr3.train(16)
    # the resumed run replays steps 10..15 on identical data: the final
    # losses must agree to float tolerance
    np.testing.assert_allclose(out2["final_loss"], out3["final_loss"],
                               rtol=5e-3)


def test_deterministic_data_replay():
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    c = SyntheticCorpus(DataConfig(vocab_size=100, seq_len=16,
                                   global_batch=2))
    b1, b2 = c.batch(7), c.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(c.batch(8)["tokens"], b1["tokens"])


@pytest.mark.slow
def test_elastic_remesh_restore_subprocess(tmp_path):
    """Save on a (2,2) mesh, restore+step on a (4,1) mesh: elastic."""
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, {str(os.path.join(os.path.dirname(__file__), '..', 'src'))!r})
import jax, numpy as np
from repro.configs import get_reduced
from repro.configs.base import RunConfig, ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.train_loop import Trainer, TrainerConfig

cfg = get_reduced("qwen1_5_32b")
run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 32, 4), remat="none")
tc = TrainerConfig(ckpt_dir={str(tmp_path)!r}, ckpt_every=4, lr_base=5e-3, lr_warmup=2)
tr_a = Trainer(run, make_host_mesh(2, 2), tc)
out_a = tr_a.train(8)
# node loss: rebuild on a different mesh topology, restore, keep going
tr_b = Trainer(run, make_host_mesh(4, 1), tc)
state, start = tr_b.restore_or_init()
assert start == 8, start
out_b = tr_b.train(12)
assert out_b["losses"], "no steps ran after elastic restore"
print("ELASTIC_OK", out_b["final_loss"])
"""
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=560)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_straggler_watchdog_fires(tmp_path, monkeypatch):
    tr, _ = _run(tmp_path)
    orig = tr.step_fn
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        out = orig(state, batch)
        if calls["n"] == 10:
            import time
            jax.block_until_ready(out)
            time.sleep(1.0)
        return out

    tr.step_fn = slow_step
    out = tr.train(14)
    assert out["stragglers"], "watchdog should flag the slow step"
