"""The paper's DM/DC/DevMem trichotomy at model scale: stream a layer
stack's weights from host memory with one-layer-ahead prefetch and
compare the three placement modes' traffic and wall time.

    PYTHONPATH=src python examples/offload_streaming.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.modes import MemoryMode
from repro.core.offload import LayerStreamer


def main():
    L, d, b = 24, 512, 8
    stacked = {
        "wi": jax.random.normal(jax.random.PRNGKey(0), (L, d, 4 * d),
                                jnp.bfloat16) * 0.02,
        "wo": jax.random.normal(jax.random.PRNGKey(1), (L, 4 * d, d),
                                jnp.bfloat16) * 0.02,
    }
    x = jax.random.normal(jax.random.PRNGKey(2), (b, d), jnp.bfloat16)

    @jax.jit
    def layer(p, x):
        return x + jax.nn.gelu(x @ p["wi"]) @ p["wo"]

    print(f"{L} layers x {sum(v.size for v in jax.tree.leaves(stacked))//L/1e6:.1f}M params/layer")
    for mode in (MemoryMode.DEVMEM, MemoryMode.DM, MemoryMode.DC):
        streamer = LayerStreamer(stacked, L, mode, cache_layers=8)
        out, rep = streamer.run(layer, x, prefetch=1)
        print(f"{mode.value:7s} wall={rep.wall_s*1e3:8.2f}ms "
              f"streamed={rep.bytes_streamed/1e6:7.1f}MB "
              f"hits={streamer.stats.cache_hits}")
    print("DevMem: resident; DM: every layer streamed; DC: LRU keeps "
          "hot layers — the paper's Fig. 1 modes at layer granularity.")


if __name__ == "__main__":
    main()
