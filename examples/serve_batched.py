"""END-TO-END DRIVER (the paper's kind is inference): serve a small LM
under continuous batching with batched requests; report throughput,
time-to-first-token, and per-request latency — the serving analogue of
the paper's end-to-end transformer evaluation.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2-0.5b \
        --requests 16 --slots 4 --new-tokens 12
"""
import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=10)
    ap.add_argument("--max-seq", type=int, default=96)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    print(f"serving {cfg.name} ({cfg.n_params()/1e6:.2f}M params, "
          f"reduced config) with {args.slots} slots")
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=args.slots,
                        max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        r = Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size - 1,
                                        int(rng.integers(4, 16))
                                        ).astype(np.int32),
                    max_new_tokens=args.new_tokens)
        reqs.append(r)
        eng.submit(r)
    stats = eng.run_until_drained()
    ttft = [r.first_token_s - r.submitted_s for r in reqs]
    lat = [r.done_s - r.submitted_s for r in reqs]
    print(f"throughput : {stats.tokens_per_s:8.1f} tok/s "
          f"({stats.tokens_out} tokens in {stats.wall_s:.2f}s)")
    print(f"TTFT       : p50={np.percentile(ttft, 50)*1e3:7.1f}ms "
          f"p95={np.percentile(ttft, 95)*1e3:7.1f}ms")
    print(f"latency    : p50={np.percentile(lat, 50)*1e3:7.1f}ms "
          f"p95={np.percentile(lat, 95)*1e3:7.1f}ms")
    print(f"decode steps={stats.decode_steps} prefills={stats.prefills}")


if __name__ == "__main__":
    main()
