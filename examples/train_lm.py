"""Train an LM on the synthetic corpus with checkpoints + restart.

Default is a fast reduced config; pass --d-model/--layers/--steps to
scale up (e.g. ~100M: --d-model 768 --layers 12 --seq 512 --batch 8).

    PYTHONPATH=src python examples/train_lm.py --steps 50
"""
import argparse
import dataclasses
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_reduced
from repro.configs.base import RunConfig, ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.train_loop import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model,
                                  d_ff=4 * args.d_model)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    print(f"training {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"seq={args.seq} batch={args.batch}")
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("train", "train", args.seq,
                                      args.batch),
                    remat="none",
                    gradient_compression=args.compress_grads)
    tr = Trainer(run, make_host_mesh(1, 1),
                 TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=20,
                               lr_base=3e-3, lr_warmup=10,
                               lr_total=max(args.steps, 100)))
    out = tr.train(args.steps)
    print(f"loss: {out['losses'][0]:.4f} -> {out['final_loss']:.4f} "
          f"({len(out['losses'])} steps; "
          f"{len(out['stragglers'])} straggler events)")
    print(f"checkpoints: {tr.ckpt.all_steps()} (restart resumes exactly)")


if __name__ == "__main__":
    main()
