"""Quickstart: build a small LM, train a few steps on the synthetic
corpus, serve it, then simulate it on the streaming accelerator via
the Scenario API — the whole public API in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_reduced
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.design_space import DesignSpace
from repro.core.scenario import Scenario, simulate, tune
from repro.launch.mesh import make_host_mesh
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_reduced("qwen2_0_5b")
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("quick", "train", 64, 4),
                    remat="none")
    trainer = Trainer(run, make_host_mesh(1, 1),
                      TrainerConfig(ckpt_dir="/tmp/repro_quickstart",
                                    ckpt_every=10, lr_base=5e-3,
                                    lr_warmup=2, lr_total=100))
    out = trainer.train(20)
    print(f"[train] loss {out['losses'][0]:.3f} -> {out['final_loss']:.3f}")

    # reuse the trained weights for serving
    state, _ = trainer.restore_or_init()
    eng = ServingEngine(cfg, state["params"], slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(1, 250, 8).astype(np.int32),
                           max_new_tokens=8))
    stats = eng.run_until_drained()
    print(f"[serve] {stats.tokens_out} tokens at "
          f"{stats.tokens_per_s:.1f} tok/s "
          f"({stats.prefills} prefills, {stats.decode_steps} decode steps)")

    # what-if simulation: the same model on the paper's streaming
    # accelerator, per memory mode — any configs/ name works here
    for mode in ("DM", "DC", "DevMem"):
        res = simulate(Scenario(model=cfg.name, mode=mode, seq=64))
        b = res.buckets()
        print(f"[simulate] {res.label} {mode:7s} "
              f"total={res.total_s*1e6:8.1f}us "
              f"compute={b['compute']:.1%} host={b['host']:.1%}")

    # co-design search: price a knob space against the workload in one
    # config-batched replay per plan geometry, Pareto front included
    space = DesignSpace(sa_w=(8, 16), page_bytes=(4096,),
                        buffer_kb=(20, 72), tlb_entries=(16, 64),
                        mode=("DM", "DC", "DevMem"))
    res = tune(Scenario(model=cfg.name, seq=64), space)
    best = res.best
    print(f"[tune] {len(res.points)} points at "
          f"{res.configs_per_s:.0f} configs/s -> "
          f"best {best.point.label()} "
          f"({best.total_s*1e6:.1f}us, "
          f"{len(res.pareto)} on the latency/area Pareto front)")


if __name__ == "__main__":
    main()
