"""Paged-KV decode with the SMMU-style Pallas kernel: allocate a page
pool, fill it from mixed-length sequences, and decode through
``kernels.paged_attention`` (interpret mode on CPU) — verifying against
contiguous attention.

    PYTHONPATH=src python examples/paged_serving.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import paged_attention
from repro.models.layers import decode_attention
from repro.serving.kv_cache import PagedCacheConfig, PagedKVCache


def main():
    cfg = PagedCacheConfig(n_pages=64, page_tokens=16, n_kv_heads=2,
                           head_dim=32, max_pages_per_seq=8,
                           dtype="float32")
    cache = PagedKVCache(cfg, max_seqs=3)
    rng = jax.random.PRNGKey(0)
    lens = [23, 57, 100]
    for slot, T in enumerate(lens):
        assert cache.alloc_seq(slot, T)
        k = jax.random.normal(jax.random.fold_in(rng, slot),
                              (T, 2, 32), jnp.float32)
        cache.write_prompt(slot, k, k * 0.5)
    print(f"pool: {cache.pages_in_use}/{cfg.n_pages} pages in use "
          f"({cfg.page_bytes}B per K page)")

    slots = np.arange(3)
    q = jax.random.normal(jax.random.PRNGKey(9), (3, 8, 32), jnp.float32)
    kp, vp, table, lens_dev = cache.device_views(slots)
    out = paged_attention(q, kp, vp, table, lens_dev, interpret=True)

    # oracle: gather pages into contiguous caches
    k = kp[table].reshape(3, -1, 2, 32)
    v = vp[table].reshape(3, -1, 2, 32)
    want = decode_attention(q, k, v, lens_dev)
    err = float(jnp.abs(out - want).max())
    print(f"paged kernel vs contiguous attention: max |err| = {err:.2e}")
    assert err < 1e-4
    # append a decode step's KV and grow across a page boundary
    cache.append_token(slots, q[:, :2], q[:, :2] * 0.5)
    print(f"after append: lens={cache.lens[:3].tolist()} "
          f"pages={cache.pages_in_use}")
    print("page-table indirection == the paper's SMMU, serving edition.")


if __name__ == "__main__":
    main()
