"""Gradient compression for the slow (cross-pod DCN) axis.

INT8 per-tensor quantization with ERROR FEEDBACK: the quantization
residual is carried to the next step, so compression introduces no
asymptotic bias (Karimireddy et al., 2019). Two entry points:

  * ``compress``/``decompress`` + ``init_ef`` — pure functions fused
    into the train step (grads are compressed before the optimizer; on
    a multi-pod mesh XLA then all-reduces the int8-quantized values).
  * ``compressed_psum`` — explicit shard_map psum over a named axis for
    the hand-scheduled variant.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def init_ef(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress(grads, ef):
    """-> (quantized int8 tree, scales tree, new error-feedback tree)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _q(x)
        deq = q.astype(jnp.float32) * scale
        return q, scale, x - deq
    flat = jax.tree.map(one, grads, ef,
                        is_leaf=lambda x: hasattr(x, "dtype"))
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    qs = jax.tree.map(lambda t: t[0], flat, is_leaf=is3)
    scales = jax.tree.map(lambda t: t[1], flat, is_leaf=is3)
    new_ef = jax.tree.map(lambda t: t[2], flat, is_leaf=is3)
    return qs, scales, new_ef


def decompress(qs, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)


def apply_compression(grads, ef):
    """Round-trip (the in-jit form): returns (grads', new_ef) where
    grads' are the dequantized int8 values — exactly what the other pods
    would receive over the wire."""
    qs, scales, new_ef = compress(grads, ef)
    return decompress(qs, scales), new_ef


def compressed_psum(x, axis: str):
    """INT8-compressed psum over a named mesh axis via shard_map: each
    participant sends 1/4 the bytes of fp32 across the DCN."""
    q, scale = _q(x.astype(jnp.float32))
    qsum = jax.lax.psum(q.astype(jnp.int32), axis)
    smax = jax.lax.pmax(scale, axis)
    return qsum.astype(jnp.float32) * smax
