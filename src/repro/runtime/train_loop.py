"""Fault-tolerant training loop.

Composes the substrate: deterministic data (resume = pure function of
step), atomic+async checkpoints, elastic restore onto the current mesh,
a step watchdog (straggler mitigation), and optional INT8+error-feedback
gradient compression fused into the step.

Failure model (single-process CPU realization of the multi-pod design):
  * crash/restart — the trainer restores the latest atomic checkpoint
    and replays from the exact step (tested by killing mid-run);
  * straggler — steps slower than ``watchdog_factor`` × trailing median
    are logged and counted; on a real pod the same hook triggers the
    coordinator's slow-host eviction + elastic remesh, which here is
    realized as restore-onto-a-different-mesh (see tests).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.data.pipeline import make_loader
from repro.launch.steps import build_train_step
from repro.optim import get_optimizer
from repro.runtime import compression as GC


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 20
    keep: int = 3
    watchdog_factor: float = 3.0
    log_every: int = 10
    seed: int = 0
    lr_base: float = 3e-4
    lr_warmup: int = 200
    lr_total: int = 10000


class Trainer:
    def __init__(self, run: RunConfig, mesh, tcfg: TrainerConfig,
                 inject_failure_at: Optional[int] = None):
        self.run = run
        self.mesh = mesh
        self.tcfg = tcfg
        self.inject_failure_at = inject_failure_at
        self.built = build_train_step(run, mesh, lr_base=tcfg.lr_base,
                              lr_warmup=tcfg.lr_warmup,
                              lr_total=tcfg.lr_total)
        if run.gradient_compression:
            self._wrap_compression()
        self.step_fn = jax.jit(self.built.fn,
                               in_shardings=self.built.in_shardings,
                               out_shardings=self.built.out_shardings,
                               donate_argnums=self.built.donate_argnums)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.straggler_events: list[int] = []
        self.metrics_log: list[dict] = []

    def _wrap_compression(self):
        base_fn = self.built.fn
        run, mesh = self.run, self.mesh
        # re-build a step whose grads pass through int8+EF before the
        # optimizer — see runtime.compression
        from repro.launch import steps as S
        from repro.models import model as M
        from repro.optim import cosine_schedule
        model = M.Model(run.model, remat=run.remat)
        opt = get_optimizer(run.optimizer)
        lr_fn = cosine_schedule(self.tcfg.lr_base, self.tcfg.lr_warmup,
                                self.tcfg.lr_total)

        def train_step(state, batch):
            def loss_fn(p):
                return model.loss(p, batch)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"])
            grads, new_ef = GC.apply_compression(grads, state["ef"])
            lr = lr_fn(state["opt"]["step"])
            new_params, new_opt, om = opt.update(
                grads, state["opt"], state["params"], lr)
            return ({"params": new_params, "opt": new_opt, "ef": new_ef},
                    {**metrics, **om, "loss": loss, "lr": lr})

        # extend shardings with the EF tree (same layout as params)
        p_sh = self.built.in_shardings[0]["params"]
        state_sh = {"params": p_sh,
                    "opt": self.built.in_shardings[0]["opt"],
                    "ef": p_sh}
        self.built = dataclasses.replace(
            self.built, fn=S._ctx_wrap(train_step, mesh,
                                       S.make_rules(run, mesh)),
            in_shardings=(state_sh, self.built.in_shardings[1]),
            out_shardings=(state_sh, None))

    # ------------------------------------------------------------ state
    def init_state(self):
        from repro.models import model as M
        model = M.Model(self.run.model, remat=self.run.remat)
        opt = get_optimizer(self.run.optimizer)
        params = model.init(jax.random.PRNGKey(self.tcfg.seed))
        state = {"params": params, "opt": opt.init(params)}
        if self.run.gradient_compression:
            state["ef"] = GC.init_ef(params)
        sh = self.built.in_shardings[0]
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            state, sh)

    def restore_or_init(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return self.init_state(), 0
        abstract = jax.eval_shape(self.init_state)
        state, step = self.ckpt.restore(
            abstract, shardings=self.built.in_shardings[0])
        return state, step + 1

    # ------------------------------------------------------------- run
    def train(self, num_steps: int) -> dict:
        state, start = self.restore_or_init()
        batch_sh = self.built.in_shardings[1]
        loader = make_loader(self.run.model, self.run.shape, batch_sh,
                             start_step=start, seed=self.tcfg.seed)
        durations: list[float] = []
        losses = []
        try:
            with self.mesh:
                for step, batch in loader:
                    if step >= num_steps:
                        break
                    t0 = time.perf_counter()
                    state, metrics = self.step_fn(state, batch)
                    loss = float(metrics["loss"])
                    dt = time.perf_counter() - t0
                    losses.append(loss)
                    self.metrics_log.append(
                        {"step": step, "loss": loss, "s": dt})
                    # --------------- straggler watchdog
                    if len(durations) >= 5:
                        med = statistics.median(durations[-20:])
                        if dt > self.tcfg.watchdog_factor * med:
                            self.straggler_events.append(step)
                    durations.append(dt)
                    # --------------- checkpoint + injected failure
                    if (step + 1) % self.tcfg.ckpt_every == 0:
                        self.ckpt.save(state, step)
                    if self.inject_failure_at is not None and \
                            step == self.inject_failure_at:
                        raise RuntimeError(
                            f"injected node failure at step {step}")
        finally:
            loader.close()
        self.ckpt.wait()
        return {"final_loss": losses[-1] if losses else float("nan"),
                "losses": losses, "stragglers": self.straggler_events}
