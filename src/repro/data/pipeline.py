"""Deterministic synthetic data pipeline.

Design goals for the fault-tolerance story:
  * ``batch(step)`` is a PURE function of (seed, step) — after a restart
    the stream resumes bit-identically from the checkpointed step with
    no data-loader state to save;
  * batches are sharded host→device against the mesh via NamedSharding;
  * a small look-ahead prefetcher overlaps host generation with device
    compute (jax async dispatch).

The corpus is a Zipf-distributed token stream with injected
(copy/induction) structure so tiny models actually learn something
measurable in the examples.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2
    induction: bool = True     # repeat-structure so loss can fall


class SyntheticCorpus:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        """Deterministic (tokens, labels) for this global step."""
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step]))
        v = max(c.vocab_size - 2, 2)
        z = rng.zipf(c.zipf_a, size=(c.global_batch, c.seq_len + 1))
        toks = (z % v).astype(np.int32) + 1
        if c.induction and c.seq_len >= 8:
            # copy structure: second half repeats the first half
            half = (c.seq_len + 1) // 2
            toks[:, half:2 * half] = toks[:, :half]
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].astype(np.int32)}


class ShardedLoader:
    """Wraps a corpus: shards each batch onto the mesh, prefetches ahead."""

    def __init__(self, corpus: SyntheticCorpus, shardings: dict,
                 start_step: int = 0, prefetch: int = 2):
        self.corpus = corpus
        self.shardings = shardings
        self._step = start_step
        self._prefetch = prefetch
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _put_device(self, step: int):
        host = self.corpus.batch(step)
        dev = {k: jax.device_put(v, self.shardings.get(k))
               for k, v in host.items()}
        return step, dev

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._put_device(step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()


def make_loader(model_cfg: ModelConfig, shape: ShapeConfig, shardings,
                start_step: int = 0, seed: int = 1234) -> ShardedLoader:
    corpus = SyntheticCorpus(DataConfig(
        vocab_size=model_cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed))
    return ShardedLoader(corpus, shardings, start_step)
