from repro.optim.optimizers import (  # noqa: F401
    Adafactor,
    AdamW,
    cosine_schedule,
    get_optimizer,
)
