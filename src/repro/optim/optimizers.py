"""Sharded optimizers: AdamW (fp32 moments) and Adafactor (factored 2nd
moment — the memory-sane choice for the 100B+ training cells).

Pure-pytree API:
    opt.init(params) -> state            (eval_shape-able)
    opt.update(grads, state, params, lr) -> (new_params, new_state)
    opt.state_axes(param_axes) -> logical-axes tree congruent with state
Global-norm clipping is fused into ``update``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _clip_by_global_norm(grads, max_norm):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr):
        grads, gnorm = _clip_by_global_norm(grads, self.clip_norm)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t

        def upd(g, m, v, p):
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": m, "v": v, "step": step}, {"grad_norm": gnorm}

    def state_axes(self, param_axes):
        return {"m": param_axes, "v": param_axes, "step": ()}


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored second-moment optimizer (Shazeer & Stern 2018), no
    momentum: O(params/row + params/col) state instead of 2×params."""
    decay: float = 0.8
    eps: float = 1e-30
    clip_norm: float = 1.0
    weight_decay: float = 0.0
    min_dim_factored: int = 2

    def _factored(self, shape) -> bool:
        return len(shape) >= self.min_dim_factored

    def init(self, params):
        def st(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(st, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr):
        grads, gnorm = _clip_by_global_norm(grads, self.clip_norm)
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-self.decay)

        def upd(g, sl, p):
            g2 = jnp.square(g) + self.eps
            if self._factored(p.shape):
                vr = beta * sl["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * sl["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], self.eps))
                u = g * jax.lax.rsqrt(jnp.maximum(denom, self.eps))
                new_sl = {"vr": vr, "vc": vc}
            else:
                v = beta * sl["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, self.eps))
                new_sl = {"v": v}
            # update clipping (RMS <= 1), per the paper
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return new_sl, (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        is_slot = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        sl_leaves = jax.tree.flatten(state["slots"], is_leaf=is_slot)[0]
        out = [upd(g, sl, p) for g, sl, p in
               zip(g_leaves, sl_leaves, p_leaves)]
        slots = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_p = jax.tree.unflatten(treedef, [o[1] for o in out])
        return new_p, {"slots": slots, "step": step}, {"grad_norm": gnorm}

    def state_axes(self, param_axes):
        def ax(a):
            if len(a) >= self.min_dim_factored:
                return {"vr": a[:-1], "vc": a[:-2] + a[-1:]}
            return {"v": a}
        return {"slots": jax.tree.map(
                    ax, param_axes,
                    is_leaf=lambda x: isinstance(x, tuple) and all(
                        isinstance(e, (str, type(None))) for e in x)),
                "step": ()}


def get_optimizer(name: str, **kw):
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise KeyError(name)
