"""Transformer workload traces (paper §4.2): BERT-Medium/Base/Large and
ViT-Base/Large/Huge as sequences of GEMM calls + non-GEMM host work.
All GEMMs inside attention and FFN blocks are offloaded to MatrixFlow;
softmax/layernorm/activations stay on the host CPU (paper §4.2).
"""
from __future__ import annotations

import dataclasses

from repro.configs.paper_models import PAPER_MODELS


@dataclasses.dataclass(frozen=True)
class GemmCall:
    m: int
    n: int
    k: int
    count: int
    cls: str            # FF1 | FF2 | MHA | Proj


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    gemms: tuple
    nongemm_elems: int          # host-side elementwise work (elements)
    seq: int

    @property
    def total_macs(self) -> int:
        return sum(g.m * g.n * g.k * g.count for g in self.gemms)


def transformer_trace(name: str) -> Workload:
    cfg = PAPER_MODELS[name]
    S = cfg.max_train_seq
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    L = cfg.n_layers
    f = cfg.d_ff
    gemms = (
        GemmCall(S, 3 * d, d, L, "Proj"),        # fused QKV projection
        GemmCall(S, S, hd, L * h, "MHA"),        # QK^T per head
        GemmCall(S, hd, S, L * h, "MHA"),        # PV per head
        GemmCall(S, d, d, L, "Proj"),            # output projection
        GemmCall(S, f, d, L, "FF1"),
        GemmCall(S, d, f, L, "FF2"),
    )
    # softmax + 2×layernorm + gelu + residuals per layer (host side)
    nongemm = L * (h * S * S + 2 * S * d + S * f + 2 * S * d)
    return Workload(name, gemms, nongemm, S)


MICRO_SIZES = (64, 128, 256, 512, 1024, 2048)


def micro_gemm(n: int) -> Workload:
    return Workload(f"gemm{n}", (GemmCall(n, n, n, 1, "GEMM"),), 0, n)
