from repro.accesys import components, pipeline, system, workloads  # noqa: F401
