from repro.accesys import components, pipeline, system, workloads  # noqa: F401
from repro.accesys.pipeline import replay, simulate_gemm  # noqa: F401
