from repro.accesys import components, pipeline, system, workloads  # noqa: F401
from repro.accesys.pipeline import (replay, replay_compiled,  # noqa: F401
                                    simulate_gemm)
