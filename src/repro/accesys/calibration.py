"""Paper-claim validation: every headline number, checked in one place.

``validate()`` returns a list of (claim, paper_value, simulated, ok)
tuples; ``tests/test_accesys_claims.py`` asserts them and
``benchmarks`` renders them. Tolerances are deliberately explicit —
this is the faithful-reproduction scorecard.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.accesys import workloads as W
from repro.accesys.components import DRAM, PCIeLink
from repro.accesys.pipeline import SystemConfig, simulate_gemm
from repro.accesys.system import (CPUModel, TICSAT_SPEEDUP, SMAUG_SPEEDUP,
                                  default_system, pcie_for_bw,
                                  run_transformer_accel,
                                  run_transformer_cpu)

PAPER_TABLE9 = {"bert-medium": 453.9, "bert-base": 633.7,
                "bert-large": 698.2, "vit-base-16": 327.9,
                "vit-large-16": 392.0, "vit-huge-14": 427.6}


@dataclasses.dataclass
class Claim:
    name: str
    paper: float
    simulated: float
    rel_tol: float

    @property
    def ok(self) -> bool:
        lo = self.paper * (1 - self.rel_tol)
        hi = self.paper * (1 + self.rel_tol)
        return lo <= self.simulated <= hi

    def row(self) -> str:
        mark = "PASS" if self.ok else "MISS"
        return (f"{self.name:55s} paper={self.paper:10.2f} "
                f"sim={self.simulated:10.2f} ±{self.rel_tol*100:3.0f}% "
                f"{mark}")


def translation_overhead_diff(n: int, dtype: str = "int32") -> float:
    """Differential translation overhead: (T - T_without_SMMU_cost)/T."""
    cfg = default_system("DC", dtype=dtype)
    t1 = simulate_gemm(cfg, n, n, n).total_s
    cfg0 = default_system("DC", dtype=dtype)
    cfg0.smmu.base_walk_cycles = 0.0
    cfg0.smmu.deep_walk_cycles = 0.0
    cfg0.smmu.l2_fill_cycles = 0.0
    cfg0.smmu.hit_cycles = 0.0
    t0 = simulate_gemm(cfg0, n, n, n).total_s
    return (t1 - t0) / t1


def validate(fast: bool = False) -> list[Claim]:
    cpu = CPUModel()
    claims: list[Claim] = []

    # --- Fig 7b: 512^3 INT8 GEMM, DC mode vs single core: ~400x
    r = simulate_gemm(default_system("DC"), 512, 512, 512)
    base = cpu.gemm_time(512 ** 3, "int8")
    claims.append(Claim("gemm512.int8.DC speedup vs 1-core (Fig7b)",
                        400.0, base / r.total_s, 0.15))
    # OMP saturates 20-30x; Neon < 10x
    claims.append(Claim("gemm512 OMP-256t speedup (Fig7b ~20-30x)", 25.0,
                        base / cpu.gemm_time(512 ** 3, "int8", threads=256),
                        0.25))
    claims.append(Claim("gemm512 Neon speedup (Fig7b <10x)", 7.0,
                        base / cpu.gemm_time(512 ** 3, "int8", simd=True),
                        0.35))

    # --- Table 9 end-to-end speedups
    worst_ratio = 0.0
    for name, paper in PAPER_TABLE9.items():
        wl = W.transformer_trace(name)
        acc = run_transformer_accel(default_system("DC"), wl)
        b = run_transformer_cpu(wl)
        claims.append(Claim(f"table9.{name} e2e speedup", paper,
                            b.total_s / acc.total_s, 0.12))
        mt = run_transformer_cpu(wl, threads=256)
        worst_ratio = max(worst_ratio, mt.total_s / acc.total_s)
    # up to 22x vs the multithreaded CPU
    claims.append(Claim("max speedup vs 64-thread CPU (~22x)", 22.0,
                        worst_ratio, 0.25))

    # --- Fig 12: PCIe scaling + DevMem comparison (ViT-Huge)
    wl = W.transformer_trace("vit-huge-14")
    t = {bw: run_transformer_accel(
        default_system("DC", pcie=pcie_for_bw(bw)), wl).total_s
        for bw in (2, 8, 64)}
    dev = run_transformer_accel(
        default_system("DevMem", dram=DRAM("HBM2"), pcie=pcie_for_bw(64)),
        wl).total_s
    claims.append(Claim("fig12 speedup 2->8 GB/s (~2.5x)", 2.5,
                        t[2] / t[8], 0.15))
    claims.append(Claim("fig12 speedup 2->64 GB/s (~3-3.4x)", 3.2,
                        t[2] / t[64], 0.15))
    claims.append(Claim("fig12 host-64GB/s vs DevMem ViT-Huge (1.13x)",
                        1.13, dev / t[64], 0.08))

    # --- Fig 10: packet size optimum at 256 B
    def link_time(pkt, gb_s=8.0):
        cfg = default_system("DM", pcie=pcie_for_bw(gb_s, packet=pkt))
        return simulate_gemm(cfg, 2048, 2048, 2048).total_s
    if not fast:
        t64, t256, t4096 = (link_time(p) for p in (64, 256, 4096))
        claims.append(Claim("fig10 64B vs 256B slowdown (~12%)", 1.12,
                            t64 / t256, 0.08))
        claims.append(Claim("fig10 4096B vs 256B slowdown low-bw (~1.36x)",
                            1.36, t4096 / t256, 0.20))

    # --- §5.2.2 bandwidth vs latency sensitivity
    import dataclasses as _dc
    base_cfg = default_system("DevMem", dram=DRAM("HBM2"))
    t50 = simulate_gemm(_dc.replace(base_cfg, dram=_make_bw_dram(50e9)),
                        2048, 2048, 2048).total_s
    t256 = simulate_gemm(_dc.replace(base_cfg, dram=_make_bw_dram(256e9)),
                         2048, 2048, 2048).total_s
    claims.append(Claim("bw 50->256 GB/s extra gain (<=2-3%)", 0.017,
                        (t50 - t256) / t50, 2.0))
    tl12 = simulate_gemm(_dc.replace(
        base_cfg, dram=DRAM("HBM2", latency_ns=12.0)), 2048, 2048, 2048
        ).total_s
    tl36 = simulate_gemm(_dc.replace(
        base_cfg, dram=DRAM("HBM2", latency_ns=36.0)), 2048, 2048, 2048
        ).total_s
    claims.append(Claim("3x DRAM latency slowdown (<=~4.9%)", 0.049,
                        (tl36 - tl12) / tl12, 1.2))

    # --- Fig 13: non-GEMM crossover (host overtakes DevMem beyond ~35%,
    # larger share needed on slower links)
    if not fast:
        c64 = nongemm_crossover(64)
        c2 = nongemm_crossover(2)
        claims.append(Claim("fig13 crossover @64GB/s (>~35%)", 0.43,
                            c64, 0.30))
        claims.append(Claim("fig13 slower link needs larger share (c2/c64)",
                            1.4, c2 / max(c64, 1e-9), 0.35))

    # --- Table 8: translation overhead U-shape
    small = translation_overhead_diff(64)
    mid = translation_overhead_diff(1024)
    big = translation_overhead_diff(2048)
    claims.append(Claim("table8 overhead@1024 (~1%)", 0.01, mid, 6.0))
    claims.append(Claim("table8 overhead@2048 (~6.5%)", 0.065, big, 0.9))
    claims.append(Claim("table8 U-shape: 2048 > 1024 (ratio>2)", 6.49,
                        big / max(mid, 1e-9), 0.95))
    claims.append(Claim("table8 small>mid (cold-miss regime)", 6.0,
                        small / max(mid, 1e-9), 0.98))
    return claims


def _make_bw_dram(bw: float) -> DRAM:
    """A synthetic DRAM tech with the requested bandwidth."""
    from repro.accesys import components as C
    name = f"SYN{int(bw/1e9)}"
    C.DRAM_TECH[name] = (2, 128, bw, 2000)
    return DRAM(name)


def nongemm_crossover(pcie_gb_s: float = 64.0) -> float:
    """Fig 13: the non-GEMM fraction at which a host-memory system
    overtakes DevMem. Returns the crossover fraction."""
    from repro.configs.paper_models import VIT_BASE  # noqa: F401
    wl = W.transformer_trace("vit-base-16")
    lo, hi = 0.0, 0.95
    for _ in range(18):
        frac = 0.5 * (lo + hi)
        scaled = scale_nongemm(wl, frac)
        # int32 — the paper's end-to-end precision; the link actually
        # binds, so DevMem wins the pure-GEMM limit (Fig. 13)
        host = run_transformer_accel(
            default_system("DC", dtype="int32",
                           pcie=pcie_for_bw(pcie_gb_s)), scaled)
        dev = run_transformer_accel(
            default_system("DevMem", dtype="int32", dram=DRAM("HBM2"),
                           pcie=pcie_for_bw(pcie_gb_s)), scaled)
        if host.total_s < dev.total_s:
            hi = frac
        else:
            lo = frac
    return 0.5 * (lo + hi)


def scale_nongemm(wl: W.Workload, frac: float) -> W.Workload:
    """Scale host-side elementwise work so it is `frac` of the
    ACCELERATED (DevMem) runtime — Fig. 13's x-axis."""
    cpu = CPUModel()
    dev = run_transformer_accel(
        default_system("DevMem", dtype="int32", dram=DRAM("HBM2")),
        W.Workload(wl.name, wl.gemms, 0, wl.seq))
    target = frac / max(1 - frac, 1e-6) * dev.gemm_s
    elems = int(target / (cpu.nongemm_cycles_per_elem / cpu.freq))
    return W.Workload(wl.name, wl.gemms, elems, wl.seq)


if __name__ == "__main__":
    for c in validate():
        print(c.row())
    print(f"nonGEMM crossover @64GB/s: {nongemm_crossover():.2f} "
          f"(paper: host wins beyond ~5-35%)")
