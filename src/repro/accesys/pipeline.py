"""Event-driven StreamPlan replayer (paper Fig. 2 + Fig. 6).

``replay`` times ANY ``core.plan.StreamPlan`` — single GEMMs, paged
attention, composed N-layer transformer models, expert-routed MoE
layers, scan-structured SSM layers, paged-KV decode steps, or
steady-state-sampled ``PlanSchedule``s — against the
component models: DMA-in on two read channels (lane 0 = A, lane 1 = B),
SA compute with double buffering (transfers for step t+1 overlap compute
of step t), host-side ops, and DMA-out draining behind the next tile's
compute.  It produces end-to-end latency plus the Fig.-2 latency buckets
(descriptor / translation / transfer / compute / drain) and TLB stats
(Table 8).

``simulate_gemm`` keeps its historical signature but is now a thin
wrapper: build the (possibly steady-state-sampled) Algorithm-1 plan and
replay it — the SAME plan ``core.streaming.gemm_streamed`` executes
functionally.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.accesys.components import (DMAEngine, DRAM, LLC, PCIeLink,
                                      SMMU, SystolicArray)
from repro.core import plan as P
from repro.core import streaming

# behavioural host rate for plan-level host ops (softmax/LN/gelu):
# matches system.CPUModel.nongemm_cycles_per_elem at 1 GHz
HOST_S_PER_ELEM = 0.8e-9


@dataclasses.dataclass
class GemmResult:
    total_s: float
    compute_s: float
    transfer_s: float            # serialized transfer demand
    exposed_transfer_s: float    # transfer time NOT hidden by compute
    descriptor_s: float
    translation_s: float
    tlb_lookups: int
    tlb_misses: int
    ptw_walks: int
    macs: int
    host_s: float = 0.0          # host-side op time (composed plans)
    drain_s: float = 0.0         # DMA-out tail not hidden by compute

    @property
    def translation_overhead(self) -> float:
        return self.translation_s / max(self.total_s, 1e-30)

    @property
    def gops(self) -> float:
        return 2.0 * self.macs / max(self.total_s, 1e-30) / 1e9

    def buckets(self) -> dict:
        """Fig.-2 latency buckets, as shares of total."""
        t = max(self.total_s, 1e-30)
        return {"descriptor": self.descriptor_s / t,
                "translation": self.translation_s / t,
                "transfer": self.exposed_transfer_s / t,
                "compute": self.compute_s / t,
                "drain": self.drain_s / t,
                "host": self.host_s / t}


# keep the historical name but make the generality explicit
ReplayResult = GemmResult


@dataclasses.dataclass
class SystemConfig:
    sa: SystolicArray = dataclasses.field(default_factory=SystolicArray)
    pcie: PCIeLink = dataclasses.field(default_factory=PCIeLink)
    dram: DRAM = dataclasses.field(default_factory=DRAM)
    dma: DMAEngine = dataclasses.field(default_factory=DMAEngine)
    smmu: SMMU = dataclasses.field(default_factory=SMMU)
    llc: LLC = dataclasses.field(default_factory=LLC)
    mode: str = "DC"                   # DM | DC | DevMem
    page_bytes: int = 4096

    def path_time(self, nbytes: int, page_id, footprint_pages: int):
        """(transfer_s, translation_s) along the selected datapath."""
        trans = self.smmu.access(page_id, footprint_pages)
        if self.mode == "DevMem":
            # arrow (6): on-card memory — no PCIe crossing
            return self.dram.transfer_time(nbytes), trans
        link = self.pcie.transfer_time(nbytes)
        if self.mode == "DC" and self.llc.access(page_id):
            # arrows (2,4): LLC hit — the coherent root-complex path
            # coalesces repeated reads of cache-hot pages, so the
            # endpoint sees only a fraction of the full serialization
            mem = self.llc.hit_time(nbytes)
            link *= 0.25
        else:
            mem = self.dram.transfer_time(nbytes)  # arrows (3,5)/(5)
        return link + mem, trans


@dataclasses.dataclass
class _Trace:
    """Raw replay timeline state + bucket accumulators (unscaled)."""
    t_sa_free: float = 0.0
    t_out_free: float = 0.0
    t_dma_free: float = 0.0
    compute_s: float = 0.0
    transfer_s: float = 0.0
    exposed_s: float = 0.0
    desc_s: float = 0.0
    trans_s: float = 0.0
    host_s: float = 0.0

    @property
    def makespan(self) -> float:
        return max(self.t_sa_free, self.t_out_free)


def _replay_events(cfg: SystemConfig, events, footprint_pages: int,
                   host_s_per_elem: float = HOST_S_PER_ELEM,
                   tr: Optional[_Trace] = None) -> _Trace:
    """Walk the event list against the component models.

    Double buffering: a COMPUTE's input DMA group is charged against the
    input-DMA channel timeline, so the fetch for step t+1 runs during
    step t's compute; only the excess surfaces as exposed transfer.
    DMA-out uses the write channels and drains behind compute.

    Passing an existing ``tr`` continues its timeline — the schedule
    replayer walks steady-state windows back-to-back on one clock, so
    drain tails and DMA-engine occupancy overlap the next window's
    compute exactly as they do in an exact composed replay.
    """
    tr = tr if tr is not None else _Trace()
    pending: list = []             # (lane, transfer_s, translation_s)

    def drain_pending() -> float:
        """Charge the queued DMA_IN group against the input-DMA
        timeline; returns when its data is ready on-chip."""
        nonlocal pending
        d = len(pending) * cfg.dma.descriptor_time() \
            / cfg.dma.read_channels
        tr.desc_s += d
        lanes: dict = {}
        for lane, t, _ in pending:
            lanes[lane] = lanes.get(lane, 0.0) + t
        if cfg.dma.read_channels >= len(lanes):
            tin = d + max(lanes.values())
        else:
            tin = d + sum(t for _, t, _ in pending)
        ready = max(tr.t_dma_free, 0.0) + tin \
            + sum(x for _, _, x in pending)
        tr.t_dma_free = ready
        pending = []
        return ready

    for ev in events:
        if ev.kind is P.EventKind.DMA_IN:
            t, x = cfg.path_time(ev.nbytes, ev.page, footprint_pages)
            pending.append((ev.lane, t, x))
            tr.transfer_s += t
            tr.trans_s += x
        elif ev.kind is P.EventKind.COMPUTE and ev.unit == "sa":
            ready = drain_pending() if pending else 0.0
            start = max(ready, tr.t_sa_free)
            tr.exposed_s += max(0.0, ready - tr.t_sa_free)
            tile = cfg.sa.tile_time(ev.meta["depth"])
            tr.t_sa_free = start + tile
            tr.compute_s += tile
        elif ev.kind is P.EventKind.COMPUTE:
            # host op: waits for fetches in flight and for the producing
            # C tiles to drain, then runs on the CPU while the
            # accelerator idles (paper §4.2)
            if pending:                  # pages fetched for host use
                ready = drain_pending()
                tr.exposed_s += max(0.0, ready - tr.t_sa_free)
                tr.t_sa_free = max(tr.t_sa_free, ready)
            th = ev.meta["elems"] * host_s_per_elem
            tr.t_sa_free = max(tr.t_sa_free, tr.t_out_free) + th
            tr.host_s += th
        else:                       # DMA_OUT
            tc, xc = cfg.path_time(ev.nbytes, ev.page, footprint_pages)
            tr.desc_s += cfg.dma.descriptor_time()
            tr.trans_s += xc
            tr.transfer_s += tc
            tr.t_out_free = max(tr.t_out_free, tr.t_sa_free) + tc
    if pending:                     # trailing fetches no compute consumed
        ready = drain_pending()
        tr.exposed_s += max(0.0, ready - tr.t_sa_free)
        tr.t_sa_free = max(tr.t_sa_free, ready)
    return tr


def _result(cfg: SystemConfig, tr: _Trace, macs: int, n_calls: int,
            scale: float = 1.0) -> GemmResult:
    control = n_calls * (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9
    total = max(tr.t_sa_free, tr.t_out_free) * scale + control
    return GemmResult(
        total_s=total,
        compute_s=tr.compute_s * scale,
        transfer_s=tr.transfer_s * scale,
        exposed_transfer_s=tr.exposed_s * scale,
        descriptor_s=tr.desc_s * scale,
        translation_s=tr.trans_s * scale,
        tlb_lookups=int(cfg.smmu.lookups * scale),
        tlb_misses=int(cfg.smmu.misses * scale),
        ptw_walks=int(cfg.smmu.walks * scale),
        macs=macs,
        host_s=tr.host_s * scale,
        drain_s=max(0.0, tr.t_out_free - tr.t_sa_free) * scale)


def replay(cfg: SystemConfig, plan,
           host_s_per_elem: float = HOST_S_PER_ELEM,
           reset: bool = True,
           footprint_pages: Optional[int] = None) -> GemmResult:
    """Time an arbitrary StreamPlan end-to-end on this system config.

    Works for single-op plans, for composed multi-layer transformer /
    MoE / SSM / decode plans, and for ``PlanSchedule`` steady-state
    samples (dispatched to ``replay_schedule``); per-offloaded-call
    control cost (doorbell + completion IRQ) is charged
    ``plan.n_calls`` times.  ``footprint_pages`` overrides the
    SMMU-visible footprint (used when a window plan stands in for a
    much larger workload, so page-walk depth reflects the real one).
    """
    if isinstance(plan, P.PlanSchedule):
        return replay_schedule(cfg, plan, host_s_per_elem, reset,
                               footprint_pages)
    if reset:
        cfg.smmu.reset()
        cfg.llc.reset()
    scale = plan.total_steps / max(plan.sampled_steps, 1) \
        if plan.total_steps else 1.0
    foot = plan.footprint_pages if footprint_pages is None \
        else footprint_pages
    tr = _replay_events(cfg, plan.events, foot, host_s_per_elem)
    return _result(cfg, tr, plan.macs, plan.n_calls, scale)


def replay_schedule(cfg: SystemConfig, sched: P.PlanSchedule,
                    host_s_per_elem: float = HOST_S_PER_ELEM,
                    reset: bool = True,
                    footprint_pages: Optional[int] = None) -> GemmResult:
    """Steady-state replay of a ``PlanSchedule``: each segment's steady
    window is replayed ONCE against shared SMMU/LLC state and its
    timeline scaled by ``repeat`` (x the intra-GEMM sampling scale, for
    strided windows).  This is what lets a composed BERT-Base forward
    pass replay one layer's events instead of the full stack's while
    matching the exact replay to within a couple of percent."""
    if reset:
        cfg.smmu.reset()
        cfg.llc.reset()
    foot = sched.footprint_pages if footprint_pages is None \
        else footprint_pages
    total = compute = transfer = exposed = desc = trans = 0.0
    host = drain = control = 0.0
    lookups = misses = walks = 0.0
    macs = 0
    tr = _Trace()
    # Two passes on ONE continuous timeline: the first (weight 1) is the
    # cold-start window; the second (weight repeat-1) sees the
    # steady-state DMA/compute phase relationship — cold windows expose
    # more transfer than steady ones because the input-DMA timeline has
    # not yet fallen behind compute.  Per-key SMMU/LLC state is reset
    # between passes: in the exact replay every repeat owns fresh pages,
    # so key reuse across passes would fake translation hits.
    multi = any(rep > 1 for _, rep in sched.segments)
    for pass_no in range(2 if multi else 1):
        if pass_no == 1:
            cfg.smmu.reset()
            cfg.llc.reset()
        for pl, rep in sched.segments:
            weight = 1.0 if pass_no == 0 else float(rep - 1)
            lk0, ms0, wk0 = cfg.smmu.lookups, cfg.smmu.misses, \
                cfg.smmu.walks
            m0, c0, x0, e0 = tr.makespan, tr.compute_s, tr.transfer_s, \
                tr.exposed_s
            d0, tn0, h0 = tr.desc_s, tr.trans_s, tr.host_s
            dr0 = max(0.0, tr.t_out_free - tr.t_sa_free)
            _replay_events(cfg, pl.events, foot, host_s_per_elem, tr)
            scale = weight * (pl.total_steps / max(pl.sampled_steps, 1)
                              if pl.total_steps else 1.0)
            total += (tr.makespan - m0) * scale
            compute += (tr.compute_s - c0) * scale
            transfer += (tr.transfer_s - x0) * scale
            exposed += (tr.exposed_s - e0) * scale
            desc += (tr.desc_s - d0) * scale
            trans += (tr.trans_s - tn0) * scale
            host += (tr.host_s - h0) * scale
            drain += (max(0.0, tr.t_out_free - tr.t_sa_free) - dr0) \
                * scale
            control += pl.n_calls * weight * \
                (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9
            lookups += (cfg.smmu.lookups - lk0) * scale
            misses += (cfg.smmu.misses - ms0) * scale
            walks += (cfg.smmu.walks - wk0) * scale
            if pass_no == 0:
                macs += pl.macs * rep
    return GemmResult(
        total_s=total + control, compute_s=compute, transfer_s=transfer,
        exposed_transfer_s=exposed, descriptor_s=desc,
        translation_s=trans, tlb_lookups=int(lookups),
        tlb_misses=int(misses), ptw_walks=int(walks), macs=macs,
        host_s=host, drain_s=max(0.0, drain))


def simulate_gemm(cfg: SystemConfig, M: int, N: int, K: int,
                  dtype: Optional[str] = None,
                  max_steps: int = 400_000) -> GemmResult:
    """Replay Algorithm 1 for one GEMM.  For very large problems the
    plan is built steady-state-sampled and scaled."""
    dtype = dtype or cfg.sa.dtype
    np_name = P.np_dtype_for(dtype)
    counts = streaming.tile_counts(M, N, K, np_name,
                                   page_bytes=cfg.page_bytes)
    stride = max(1, counts["inner_steps"] // max_steps)
    plan = P.gemm_plan(M, N, K, np_name, page_bytes=cfg.page_bytes,
                       sample_stride=stride)
    return replay(cfg, plan)
