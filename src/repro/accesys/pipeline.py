"""Event-driven StreamPlan replayer (paper Fig. 2 + Fig. 6).

``replay`` times ANY ``core.plan.StreamPlan`` — single GEMMs, paged
attention, composed N-layer transformer models, expert-routed MoE
layers, scan-structured SSM layers, paged-KV decode steps, or
steady-state-sampled ``PlanSchedule``s — against the
component models: DMA-in on two read channels (lane 0 = A, lane 1 = B),
SA compute with double buffering (transfers for step t+1 overlap compute
of step t), host-side ops, and DMA-out draining behind the next tile's
compute.  It produces end-to-end latency plus the Fig.-2 latency buckets
(descriptor / translation / transfer / compute / drain) and TLB stats
(Table 8).

``simulate_gemm`` keeps its historical signature but is now a thin
wrapper: build the (possibly steady-state-sampled) Algorithm-1 plan and
replay it — the SAME plan ``core.streaming.gemm_streamed`` executes
functionally.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.accesys.components import (DMAEngine, DRAM, LLC, PCIeLink,
                                      SMMU, SystolicArray)
from repro.core import plan as P
from repro.core import streaming

# behavioural host rate for plan-level host ops (softmax/LN/gelu):
# matches system.CPUModel.nongemm_cycles_per_elem at 1 GHz
HOST_S_PER_ELEM = 0.8e-9

# replay engine selection: "auto" uses the compiled (array-form) engine
# once a plan is big enough to amortize the vectorized passes, and the
# event loop below that; "event" / "compiled" force one engine
DEFAULT_ENGINE = "auto"
COMPILED_MIN_EVENTS = 3000


@dataclasses.dataclass
class GemmResult:
    total_s: float
    compute_s: float
    transfer_s: float            # serialized transfer demand
    exposed_transfer_s: float    # transfer time NOT hidden by compute
    descriptor_s: float
    translation_s: float
    tlb_lookups: int
    tlb_misses: int
    ptw_walks: int
    macs: int
    host_s: float = 0.0          # host-side op time (composed plans)
    drain_s: float = 0.0         # DMA-out tail not hidden by compute

    @property
    def translation_overhead(self) -> float:
        return self.translation_s / max(self.total_s, 1e-30)

    @property
    def gops(self) -> float:
        return 2.0 * self.macs / max(self.total_s, 1e-30) / 1e9

    def buckets(self) -> dict:
        """Fig.-2 latency buckets, as shares of total."""
        t = max(self.total_s, 1e-30)
        return {"descriptor": self.descriptor_s / t,
                "translation": self.translation_s / t,
                "transfer": self.exposed_transfer_s / t,
                "compute": self.compute_s / t,
                "drain": self.drain_s / t,
                "host": self.host_s / t}


# keep the historical name but make the generality explicit
ReplayResult = GemmResult


@dataclasses.dataclass
class SystemConfig:
    sa: SystolicArray = dataclasses.field(default_factory=SystolicArray)
    pcie: PCIeLink = dataclasses.field(default_factory=PCIeLink)
    dram: DRAM = dataclasses.field(default_factory=DRAM)
    dma: DMAEngine = dataclasses.field(default_factory=DMAEngine)
    smmu: SMMU = dataclasses.field(default_factory=SMMU)
    llc: LLC = dataclasses.field(default_factory=LLC)
    mode: str = "DC"                   # DM | DC | DevMem
    page_bytes: int = 4096

    def path_time(self, nbytes: int, page_id, footprint_pages: int):
        """(transfer_s, translation_s) along the selected datapath."""
        trans = self.smmu.access(page_id, footprint_pages)
        if self.mode == "DevMem":
            # arrow (6): on-card memory — no PCIe crossing
            return self.dram.transfer_time(nbytes), trans
        link = self.pcie.transfer_time(nbytes)
        if self.mode == "DC" and self.llc.access(page_id):
            # arrows (2,4): LLC hit — the coherent root-complex path
            # coalesces repeated reads of cache-hot pages, so the
            # endpoint sees only a fraction of the full serialization
            mem = self.llc.hit_time(nbytes)
            link *= 0.25
        else:
            mem = self.dram.transfer_time(nbytes)  # arrows (3,5)/(5)
        return link + mem, trans


@dataclasses.dataclass
class _Trace:
    """Raw replay timeline state + bucket accumulators (unscaled)."""
    t_sa_free: float = 0.0
    t_out_free: float = 0.0
    t_dma_free: float = 0.0
    compute_s: float = 0.0
    transfer_s: float = 0.0
    exposed_s: float = 0.0
    desc_s: float = 0.0
    trans_s: float = 0.0
    host_s: float = 0.0

    @property
    def makespan(self) -> float:
        return max(self.t_sa_free, self.t_out_free)


def _replay_events(cfg: SystemConfig, events, footprint_pages: int,
                   host_s_per_elem: float = HOST_S_PER_ELEM,
                   tr: Optional[_Trace] = None) -> _Trace:
    """Walk the event list against the component models.

    Double buffering: a COMPUTE's input DMA group is charged against the
    input-DMA channel timeline, so the fetch for step t+1 runs during
    step t's compute; only the excess surfaces as exposed transfer.
    DMA-out uses the write channels and drains behind compute.

    Passing an existing ``tr`` continues its timeline — the schedule
    replayer walks steady-state windows back-to-back on one clock, so
    drain tails and DMA-engine occupancy overlap the next window's
    compute exactly as they do in an exact composed replay.
    """
    tr = tr if tr is not None else _Trace()
    pending: list = []             # (lane, transfer_s, translation_s)

    def drain_pending() -> float:
        """Charge the queued DMA_IN group against the input-DMA
        timeline; returns when its data is ready on-chip."""
        nonlocal pending
        d = len(pending) * cfg.dma.descriptor_time() \
            / cfg.dma.read_channels
        tr.desc_s += d
        lanes: dict = {}
        for lane, t, _ in pending:
            lanes[lane] = lanes.get(lane, 0.0) + t
        if cfg.dma.read_channels >= len(lanes):
            tin = d + max(lanes.values())
        else:
            tin = d + sum(t for _, t, _ in pending)
        ready = max(tr.t_dma_free, 0.0) + tin \
            + sum(x for _, _, x in pending)
        tr.t_dma_free = ready
        pending = []
        return ready

    for ev in events:
        if ev.kind is P.EventKind.DMA_IN:
            t, x = cfg.path_time(ev.nbytes, ev.page, footprint_pages)
            pending.append((ev.lane, t, x))
            tr.transfer_s += t
            tr.trans_s += x
        elif ev.kind is P.EventKind.COMPUTE and ev.unit == "sa":
            ready = drain_pending() if pending else 0.0
            start = max(ready, tr.t_sa_free)
            tr.exposed_s += max(0.0, ready - tr.t_sa_free)
            tile = cfg.sa.tile_time(ev.meta["depth"])
            tr.t_sa_free = start + tile
            tr.compute_s += tile
        elif ev.kind is P.EventKind.COMPUTE:
            # host op: waits for fetches in flight and for the producing
            # C tiles to drain, then runs on the CPU while the
            # accelerator idles (paper §4.2)
            if pending:                  # pages fetched for host use
                ready = drain_pending()
                tr.exposed_s += max(0.0, ready - tr.t_sa_free)
                tr.t_sa_free = max(tr.t_sa_free, ready)
            th = ev.meta["elems"] * host_s_per_elem
            tr.t_sa_free = max(tr.t_sa_free, tr.t_out_free) + th
            tr.host_s += th
        else:                       # DMA_OUT
            tc, xc = cfg.path_time(ev.nbytes, ev.page, footprint_pages)
            tr.desc_s += cfg.dma.descriptor_time()
            tr.trans_s += xc
            tr.transfer_s += tc
            tr.t_out_free = max(tr.t_out_free, tr.t_sa_free) + tc
    if pending:                     # trailing fetches no compute consumed
        ready = drain_pending()
        tr.exposed_s += max(0.0, ready - tr.t_sa_free)
        tr.t_sa_free = max(tr.t_sa_free, ready)
    return tr


def _result(cfg: SystemConfig, tr: _Trace, macs: int, n_calls: int,
            scale: float = 1.0) -> GemmResult:
    control = n_calls * (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9
    total = max(tr.t_sa_free, tr.t_out_free) * scale + control
    return GemmResult(
        total_s=total,
        compute_s=tr.compute_s * scale,
        transfer_s=tr.transfer_s * scale,
        exposed_transfer_s=tr.exposed_s * scale,
        descriptor_s=tr.desc_s * scale,
        translation_s=tr.trans_s * scale,
        tlb_lookups=int(cfg.smmu.lookups * scale),
        tlb_misses=int(cfg.smmu.misses * scale),
        ptw_walks=int(cfg.smmu.walks * scale),
        macs=macs,
        host_s=tr.host_s * scale,
        drain_s=max(0.0, tr.t_out_free - tr.t_sa_free) * scale)


def _use_compiled(engine: Optional[str], n_events: int,
                  reset: bool) -> bool:
    engine = engine or DEFAULT_ENGINE
    if engine == "event" or not reset:
        return False                 # continuing a live timeline/state
    if engine == "compiled":
        return True
    assert engine == "auto", engine
    return n_events >= COMPILED_MIN_EVENTS


def replay(cfg: SystemConfig, plan,
           host_s_per_elem: float = HOST_S_PER_ELEM,
           reset: bool = True,
           footprint_pages: Optional[int] = None,
           engine: Optional[str] = None) -> GemmResult:
    """Time an arbitrary StreamPlan end-to-end on this system config.

    Works for single-op plans, for composed multi-layer transformer /
    MoE / SSM / decode plans, and for ``PlanSchedule`` steady-state
    samples (dispatched to ``replay_schedule``); per-offloaded-call
    control cost (doorbell + completion IRQ) is charged
    ``plan.n_calls`` times.  ``footprint_pages`` overrides the
    SMMU-visible footprint (used when a window plan stands in for a
    much larger workload, so page-walk depth reflects the real one).

    ``engine`` selects the replayer: ``"event"`` walks Python event
    objects one by one; ``"compiled"`` runs the array-form engine over
    ``plan.compile()`` (numerically interchangeable, ~10-100x faster
    on composed plans); ``"auto"`` (default) picks by plan size.  With
    ``reset=False`` the event engine is always used — only it can
    continue a live timeline/cache state (results are identical either
    way, by the parity suite).
    """
    if isinstance(plan, P.PlanSchedule):
        return replay_schedule(cfg, plan, host_s_per_elem, reset,
                               footprint_pages, engine)
    if _use_compiled(engine, len(plan.events), reset):
        return replay_compiled(cfg, plan, host_s_per_elem,
                               footprint_pages)
    if reset:
        cfg.smmu.reset()
        cfg.llc.reset()
    scale = plan.total_steps / max(plan.sampled_steps, 1) \
        if plan.total_steps else 1.0
    foot = plan.footprint_pages if footprint_pages is None \
        else footprint_pages
    tr = _replay_events(cfg, plan.events, foot, host_s_per_elem)
    return _result(cfg, tr, plan.macs, plan.n_calls, scale)


def replay_schedule(cfg: SystemConfig, sched: P.PlanSchedule,
                    host_s_per_elem: float = HOST_S_PER_ELEM,
                    reset: bool = True,
                    footprint_pages: Optional[int] = None,
                    engine: Optional[str] = None) -> GemmResult:
    """Steady-state replay of a ``PlanSchedule``: each segment's steady
    window is replayed ONCE against shared SMMU/LLC state and its
    timeline scaled by ``repeat`` (x the intra-GEMM sampling scale, for
    strided windows).  This is what lets a composed BERT-Base forward
    pass replay one layer's events instead of the full stack's while
    matching the exact replay to within a couple of percent."""
    if _use_compiled(engine, sched.sampled_events, reset):
        return replay_schedule_compiled(cfg, sched, host_s_per_elem,
                                        footprint_pages)
    if reset:
        cfg.smmu.reset()
        cfg.llc.reset()
    foot = sched.footprint_pages if footprint_pages is None \
        else footprint_pages
    total = compute = transfer = exposed = desc = trans = 0.0
    host = drain = control = 0.0
    lookups = misses = walks = 0.0
    macs = 0
    tr = _Trace()
    # Two passes on ONE continuous timeline: the first (weight 1) is the
    # cold-start window; the second (weight repeat-1) sees the
    # steady-state DMA/compute phase relationship — cold windows expose
    # more transfer than steady ones because the input-DMA timeline has
    # not yet fallen behind compute.  Per-key SMMU/LLC state is reset
    # between passes: in the exact replay every repeat owns fresh pages,
    # so key reuse across passes would fake translation hits.
    multi = any(rep > 1 for _, rep in sched.segments)
    for pass_no in range(2 if multi else 1):
        if pass_no == 1:
            cfg.smmu.reset()
            cfg.llc.reset()
        for pl, rep in sched.segments:
            weight = 1.0 if pass_no == 0 else float(rep - 1)
            lk0, ms0, wk0 = cfg.smmu.lookups, cfg.smmu.misses, \
                cfg.smmu.walks
            m0, c0, x0, e0 = tr.makespan, tr.compute_s, tr.transfer_s, \
                tr.exposed_s
            d0, tn0, h0 = tr.desc_s, tr.trans_s, tr.host_s
            dr0 = max(0.0, tr.t_out_free - tr.t_sa_free)
            _replay_events(cfg, pl.events, foot, host_s_per_elem, tr)
            scale = weight * (pl.total_steps / max(pl.sampled_steps, 1)
                              if pl.total_steps else 1.0)
            total += (tr.makespan - m0) * scale
            compute += (tr.compute_s - c0) * scale
            transfer += (tr.transfer_s - x0) * scale
            exposed += (tr.exposed_s - e0) * scale
            desc += (tr.desc_s - d0) * scale
            trans += (tr.trans_s - tn0) * scale
            host += (tr.host_s - h0) * scale
            drain += (max(0.0, tr.t_out_free - tr.t_sa_free) - dr0) \
                * scale
            control += pl.n_calls * weight * \
                (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9
            lookups += (cfg.smmu.lookups - lk0) * scale
            misses += (cfg.smmu.misses - ms0) * scale
            walks += (cfg.smmu.walks - wk0) * scale
            if pass_no == 0:
                macs += pl.macs * rep
    return GemmResult(
        total_s=total + control, compute_s=compute, transfer_s=transfer,
        exposed_transfer_s=exposed, descriptor_s=desc,
        translation_s=trans, tlb_lookups=int(lookups),
        tlb_misses=int(misses), ptw_walks=int(walks), macs=macs,
        host_s=host, drain_s=max(0.0, drain))


# ===================================================================
# Compiled (array-form) replay engine
# ===================================================================
# The event loop above dispatches on one Python ``Event`` object per
# iteration; the engine below replays the SAME timeline over the
# pre-resolved arrays of a ``core.plan.CompiledPlan``: one vectorized
# SMMU/LLC stack-distance pass prices the whole page trace, DMA-in
# groups reduce to per-op lane sums, and the double-buffer recurrence
# (input-DMA channel vs SA busy time vs DMA-out drain) runs over float
# arrays.  Results match ``replay`` to float tolerance for every
# workload and mode — exact composed replays stop being the slow path.

def _resolve_access_times(cfg: SystemConfig, cp, foot: int):
    """(transfer_s, translation_s) per DMA access — the batch
    counterpart of ``SystemConfig.path_time`` over the whole trace."""
    x = cfg.smmu.access_many(cp.trace_ids, foot, memo=cp.memo,
                             keys=cp.page_keys)
    nb = cp.trace_nbytes
    dlat = cfg.dram.latency_ns * 1e-9
    dbw = cfg.dram.bandwidth * cfg.dram.stream_efficiency
    if cfg.mode == "DevMem":
        return dlat + nb / dbw, x
    link = nb / cfg.pcie.effective_bw
    mem = dlat + nb / dbw
    if cfg.mode == "DC":
        hit = cfg.llc.access_many(cp.trace_ids, memo=cp.memo,
                                  keys=cp.page_keys)
        llc_t = cfg.llc.hit_latency_ns * 1e-9 + nb / cfg.llc.hit_bw
        return np.where(hit, link * 0.25 + llc_t, link + mem), x
    return link + mem, x


def _group_reduce(cfg: SystemConfig, cp, t: np.ndarray, x: np.ndarray):
    """Per-op drain-group quantities: pending count, descriptor time,
    channel-limited input time (``tin``), translation sum, plus the
    per-op DMA_OUT transfer times."""
    is_out = cp.trace_is_out
    in_t, in_x = t[~is_out], x[~is_out]
    ge = cp.grp_end
    gs = np.concatenate([[0], ge[:-1]]) if ge.size else ge

    def gsum(v):
        c = np.concatenate([[0.0], np.cumsum(v)])
        return c[ge] - c[gs]

    sx = gsum(in_x)
    tot_t = gsum(in_t)
    lanes = np.unique(cp.in_lane)
    if lanes.size <= 1:
        lane_max = tot_t
    else:
        lane_max = np.max(np.stack(
            [gsum(np.where(cp.in_lane == ln, in_t, 0.0))
             for ln in lanes]), axis=0)
    npend = ge - gs
    has_p = npend > 0
    d = npend * cfg.dma.descriptor_time() / cfg.dma.read_channels
    tin = d + np.where(cfg.dma.read_channels >= cp.n_lanes,
                       lane_max, tot_t)
    # input-DMA channel timeline: advances only when a group drains;
    # interleave tin/sx so the float op order matches the event loop's
    # ``(t_dma + tin) + sum(x)``
    z = np.zeros(2 * len(ge))
    z[0::2] = np.where(has_p, tin, 0.0)
    z[1::2] = np.where(has_p, sx, 0.0)
    ready = np.cumsum(z)[1::2]
    out_idx = np.cumsum(cp.op_kind == P.OP_OUT) - 1
    tc = np.where(cp.op_kind == P.OP_OUT,
                  t[is_out][np.maximum(out_idx, 0)]
                  if is_out.any() else 0.0, 0.0)
    return has_p, d, sx, ready, tc


def _op_amounts(cfg: SystemConfig, cp, tc: np.ndarray,
                host_s_per_elem: float) -> np.ndarray:
    """The one scalar each op adds to its timeline: SA tile time, host
    op time, or DMA_OUT transfer time."""
    k = cp.op_kind
    val = np.where(k == P.OP_SA,
                   (cp.op_val + 2 * (cfg.sa.w - 1)) / cfg.sa.freq, 0.0)
    val = np.where(k == P.OP_HOST, cp.op_val * host_s_per_elem, val)
    return np.where(k == P.OP_OUT, tc, val)


def _run_ops_loop(opk, has_p, ready, val, t_sa, t_out):
    """Reference scalar recurrence — fastest for small op streams and
    the literal transcription of the event loop's timeline updates."""
    n = len(opk)
    tsa_a = np.empty(n)
    tout_a = np.empty(n)
    exp_a = np.zeros(n)
    opk_l, hp_l = opk.tolist(), has_p.tolist()
    rdy_l, val_l = ready.tolist(), val.tolist()
    for g in range(n):
        k = opk_l[g]
        if k == P.OP_OUT:
            if t_sa > t_out:
                t_out = t_sa
            t_out += val_l[g]
        else:
            if hp_l[g]:
                r = rdy_l[g]
                if r > t_sa:
                    exp_a[g] = r - t_sa
                    t_sa = r
            if k == P.OP_HOST:
                if t_out > t_sa:
                    t_sa = t_out
            if k != P.OP_TAIL:
                t_sa += val_l[g]
        tsa_a[g] = t_sa
        tout_a[g] = t_out
    return tsa_a, tout_a, exp_a, t_sa, t_out


def _run_ops_vec(opk, has_p, ready, val, t_sa, t_out):
    """Vectorized recurrence: host ops and stream drains are the only
    points where the SA timeline reads the DMA-out timeline, so the op
    stream splits into segments that reduce to cumulative sums plus
    running maxima (the max-plus closed form of the double-buffer
    recurrence)."""
    n = opk.size
    tsa_a = np.empty(n)
    tout_a = np.empty(n)
    exp_a = np.zeros(n)
    barrier = np.nonzero((opk == P.OP_HOST) | (opk == P.OP_TAIL))[0]
    starts = np.concatenate([[0], barrier + 1])
    ends = np.concatenate([barrier, [n]])
    for s0, s1 in zip(starts, ends):
        s0, s1 = int(s0), int(s1)
        if s1 > s0:
            k = opk[s0:s1]
            v = val[s0:s1]
            sa = np.nonzero(k == P.OP_SA)[0]
            out = np.nonzero(k == P.OP_OUT)[0]
            tsa_seg = None
            if sa.size:
                tiles = v[sa]
                pre = np.cumsum(tiles)
                r = np.where(has_p[s0:s1][sa], ready[s0:s1][sa],
                             -np.inf)
                q = r - np.concatenate([[0.0], pre[:-1]])
                run = np.maximum.accumulate(q)
                tsa_seg = pre + np.maximum(t_sa, run)
                prev_run = np.maximum(
                    t_sa, np.concatenate([[-np.inf], run[:-1]]))
                exp_a[s0:s1][sa] = np.maximum(q - prev_run, 0.0)
            sa_cum = np.cumsum(k == P.OP_SA) - 1
            tsa_sl = np.where(
                sa_cum >= 0,
                tsa_seg[np.maximum(sa_cum, 0)] if tsa_seg is not None
                else t_sa, t_sa)
            tout_seg = None
            if out.size:
                tcs = v[out]
                tcum = np.cumsum(tcs)
                p = tsa_sl[out] - np.concatenate([[0.0], tcum[:-1]])
                tout_seg = tcum + np.maximum(
                    t_out, np.maximum.accumulate(p))
            out_cum = np.cumsum(k == P.OP_OUT) - 1
            tout_sl = np.where(
                out_cum >= 0,
                tout_seg[np.maximum(out_cum, 0)] if tout_seg is not None
                else t_out, t_out)
            tsa_a[s0:s1] = tsa_sl
            tout_a[s0:s1] = tout_sl
            t_sa = float(tsa_sl[-1])
            t_out = float(tout_sl[-1])
        if s1 < n:                           # the barrier op itself
            g = s1
            if has_p[g]:
                r = ready[g]
                if r > t_sa:
                    exp_a[g] = r - t_sa
                    t_sa = r
            if opk[g] == P.OP_HOST:
                if t_out > t_sa:
                    t_sa = t_out
                t_sa += val[g]
            tsa_a[g] = t_sa
            tout_a[g] = t_out
    return tsa_a, tout_a, exp_a, t_sa, t_out


def _run_ops(opk, has_p, ready, val, t_sa=0.0, t_out=0.0,
             force: Optional[str] = None):
    use_vec = (opk.size >= 2048) if force is None else (force == "vec")
    fn = _run_ops_vec if use_vec else _run_ops_loop
    return fn(opk, has_p, ready, val, t_sa, t_out)


def _compiled_arrays(cfg: SystemConfig, cp, foot: int,
                     host_s_per_elem: float):
    t, x = _resolve_access_times(cfg, cp, foot)
    has_p, d, sx, ready, tc = _group_reduce(cfg, cp, t, x)
    val = _op_amounts(cfg, cp, tc, host_s_per_elem)
    return t, x, has_p, d, ready, val


def replay_compiled(cfg: SystemConfig, plan,
                    host_s_per_elem: float = HOST_S_PER_ELEM,
                    footprint_pages: Optional[int] = None,
                    _recur: Optional[str] = None) -> GemmResult:
    """Array-form replay of a StreamPlan: numerically interchangeable
    with ``replay(engine="event")`` but runs over the compiled plan's
    pre-resolved float arrays instead of per-event object dispatch.
    Always starts from reset SMMU/LLC state (use the event engine to
    continue a live timeline)."""
    if isinstance(plan, P.PlanSchedule):
        return replay_schedule_compiled(cfg, plan, host_s_per_elem,
                                        footprint_pages, _recur)
    cfg.smmu.reset()
    cfg.llc.reset()
    cp = plan.compile()
    foot = plan.footprint_pages if footprint_pages is None \
        else footprint_pages
    t, x, has_p, d, ready, val = _compiled_arrays(cfg, cp, foot,
                                                  host_s_per_elem)
    k = cp.op_kind
    _, _, exp_a, t_sa, t_out = _run_ops(k, has_p, ready, val,
                                        force=_recur)
    tr = _Trace(
        t_sa_free=t_sa, t_out_free=t_out,
        compute_s=float(val[k == P.OP_SA].sum()),
        transfer_s=float(t.sum()),
        exposed_s=float(exp_a.sum()),
        desc_s=float(d[has_p].sum())
        + float((k == P.OP_OUT).sum()) * cfg.dma.descriptor_time(),
        trans_s=float(x.sum()),
        host_s=float(val[k == P.OP_HOST].sum()))
    scale = plan.total_steps / max(plan.sampled_steps, 1) \
        if plan.total_steps else 1.0
    return _result(cfg, tr, plan.macs, plan.n_calls, scale)


def replay_schedule_compiled(cfg: SystemConfig, sched: P.PlanSchedule,
                             host_s_per_elem: float = HOST_S_PER_ELEM,
                             footprint_pages: Optional[int] = None,
                             _recur: Optional[str] = None) -> GemmResult:
    """Compiled counterpart of ``replay_schedule``: the two sampling
    passes run over ONE concatenated op stream (pass 1 repeats pass 0's
    arrays on the continuing timeline — per-key SMMU/LLC state resets
    between passes, and both passes start that state empty, so the
    per-access times are identical), with per-segment deltas read off
    the op trajectories at the recorded boundaries."""
    cfg.smmu.reset()
    cfg.llc.reset()
    cp = sched.compile()
    foot = sched.footprint_pages if footprint_pages is None \
        else footprint_pages
    t, x, has_p, d, ready, val = _compiled_arrays(cfg, cp, foot,
                                                  host_s_per_elem)
    k = cp.op_kind
    multi = any(rep > 1 for _, rep in sched.segments)
    n_ops = k.size
    if multi:                       # pass 1 = same ops, timeline continues
        k2 = np.concatenate([k, k])
        has_p2 = np.concatenate([has_p, has_p])
        adv_total = ready[-1] if n_ops else 0.0
        ready2 = np.concatenate([ready, ready + adv_total])
        val2 = np.concatenate([val, val])
    else:
        k2, has_p2, ready2, val2 = k, has_p, ready, val
    tsa_a, tout_a, exp_a, _, _ = _run_ops(k2, has_p2, ready2, val2,
                                          force=_recur)

    # op-index boundaries of every (pass, segment) on the run timeline
    bounds2 = np.concatenate([[0], cp.seg_op]) if not multi else \
        np.concatenate([[0], cp.seg_op, n_ops + cp.seg_op])

    def snaps(per_op, init=0.0):
        return np.concatenate([[init], per_op])[bounds2]

    # cumulative per-op / per-access contributions (identical for both
    # passes — only the timeline-dependent ones use the doubled run)
    def cum_at(per_item, bounds):
        c = np.concatenate([[0.0], np.cumsum(per_item)])
        return c[np.concatenate([[0], bounds])]

    comp_c = cum_at(np.where(k == P.OP_SA, val, 0.0), cp.seg_op)
    host_c = cum_at(np.where(k == P.OP_HOST, val, 0.0), cp.seg_op)
    desc_c = cum_at(np.where(has_p, d, 0.0)
                    + np.where(k == P.OP_OUT,
                               cfg.dma.descriptor_time(), 0.0),
                    cp.seg_op)
    xfer_c = cum_at(t, cp.seg_trace)
    trans_c = cum_at(x, cp.seg_trace)
    tlb_miss, miss_pos, walk_sub = cfg.smmu.tlb_walk_masks(cp.trace_ids,
                                                           cp.memo)
    walk_mask = np.zeros(cp.trace_ids.size, bool)
    walk_mask[miss_pos[walk_sub]] = True
    miss_c = cum_at(tlb_miss.astype(np.float64), cp.seg_trace)
    walk_c = cum_at(walk_mask.astype(np.float64), cp.seg_trace)
    look_c = np.concatenate([[0], cp.seg_trace]).astype(np.float64)
    # timeline-dependent snapshots per (pass, segment boundary)
    tsa_s = snaps(tsa_a)
    tout_s = snaps(tout_a)
    mks_s = np.maximum(tsa_s, tout_s)
    drain_s_snap = np.maximum(0.0, tout_s - tsa_s)
    exp_s = np.concatenate([[0.0], np.cumsum(exp_a)])[bounds2]

    total = compute = transfer = exposed = desc = trans = 0.0
    host = drain = control = 0.0
    lookups = misses = walks = 0.0
    macs = 0
    nseg = len(sched.segments)
    for pass_no in range(2 if multi else 1):
        for si, (pl, rep) in enumerate(sched.segments):
            weight = 1.0 if pass_no == 0 else float(rep - 1)
            scale = weight * (pl.total_steps / max(pl.sampled_steps, 1)
                              if pl.total_steps else 1.0)
            tb = pass_no * nseg + si        # timeline boundary index
            total += (mks_s[tb + 1] - mks_s[tb]) * scale
            compute += (comp_c[si + 1] - comp_c[si]) * scale
            transfer += (xfer_c[si + 1] - xfer_c[si]) * scale
            exposed += (exp_s[tb + 1] - exp_s[tb]) * scale
            desc += (desc_c[si + 1] - desc_c[si]) * scale
            trans += (trans_c[si + 1] - trans_c[si]) * scale
            host += (host_c[si + 1] - host_c[si]) * scale
            drain += (drain_s_snap[tb + 1] - drain_s_snap[tb]) * scale
            control += pl.n_calls * weight * \
                (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9
            lookups += (look_c[si + 1] - look_c[si]) * scale
            misses += (miss_c[si + 1] - miss_c[si]) * scale
            walks += (walk_c[si + 1] - walk_c[si]) * scale
            if pass_no == 0:
                macs += pl.macs * rep
    return GemmResult(
        total_s=total + control, compute_s=compute, transfer_s=transfer,
        exposed_transfer_s=exposed, descriptor_s=desc,
        translation_s=trans, tlb_lookups=int(lookups),
        tlb_misses=int(misses), ptw_walks=int(walks), macs=macs,
        host_s=host, drain_s=max(0.0, drain))


def replay_trace(cfg: SystemConfig, plans,
                 host_s_per_elem: float = HOST_S_PER_ELEM,
                 footprint_pages: Optional[int] = None,
                 engine: Optional[str] = None):
    """Price an entire sequence of plans (e.g. a recorded serving
    trace: prefills + per-step decode plans) as ONE replay on one
    continuous timeline — shared SMMU/LLC state and shared page-id
    interning across plans, so cross-step KV-page reuse is visible to
    the translation and cache models instead of every step starting
    cold.  Returns ``(aggregate GemmResult, per-plan seconds)`` where
    the per-plan array reads each plan's contribution (its makespan
    delta plus its own doorbell/IRQ control time) off the trajectory
    at the recorded segment boundaries — the attribution the serving
    report folds back onto requests.  ``sum(per_plan) == total_s``.

    ``plans`` is a sequence of StreamPlans or a ``PlanSchedule`` whose
    repeats are all 1 (build the schedule once and pass it to share the
    compiled form and its trace-intrinsic LRU analysis across memory
    modes).  Trace replay is exact: steady-state-sampled plans are
    rejected.  The SMMU footprint defaults to the number of DISTINCT
    pages the whole trace touches (the union, not the per-plan sum —
    steps re-touch the same resident pool)."""
    if isinstance(plans, P.PlanSchedule):
        sched = plans
    else:
        sched = P.PlanSchedule("trace", [(p, 1) for p in plans])
    if not sched.segments:
        raise ValueError("replay_trace() needs at least one plan")
    for pl, rep in sched.segments:
        if rep != 1:
            raise ValueError(
                f"replay_trace() needs repeat-1 segments, got "
                f"({pl.name}, {rep}) — use replay_schedule for "
                "steady-state sampling")
        if pl.sampled_steps != pl.total_steps:
            raise ValueError(
                f"trace replay is exact; plan {pl.name} is "
                "steady-state sampled")
    cp = sched.compile()
    foot = len(cp.page_keys) if footprint_pages is None \
        else footprint_pages
    ctrl_unit = (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9
    n_calls = np.array([pl.n_calls for pl, _ in sched.segments],
                       np.float64)
    macs = sum(pl.macs for pl, _ in sched.segments)
    cfg.smmu.reset()
    cfg.llc.reset()
    if not _use_compiled(engine, cp.n_events, True):
        tr = _Trace()
        per = np.empty(len(sched.segments))
        prev = 0.0
        for i, (pl, _) in enumerate(sched.segments):
            _replay_events(cfg, pl.events, foot, host_s_per_elem, tr)
            per[i] = tr.makespan - prev
            prev = tr.makespan
        res = _result(cfg, tr, macs, int(n_calls.sum()))
        return res, per + n_calls * ctrl_unit
    t, x, has_p, d, ready, val = _compiled_arrays(cfg, cp, foot,
                                                  host_s_per_elem)
    k = cp.op_kind
    tsa_a, tout_a, exp_a, t_sa, t_out = _run_ops(k, has_p, ready, val)
    mks = np.maximum(tsa_a, tout_a)
    bounds = np.concatenate([[0], cp.seg_op])
    per = np.diff(np.concatenate([[0.0], mks])[bounds])
    tr = _Trace(
        t_sa_free=t_sa, t_out_free=t_out,
        compute_s=float(val[k == P.OP_SA].sum()),
        transfer_s=float(t.sum()),
        exposed_s=float(exp_a.sum()),
        desc_s=float(d[has_p].sum())
        + float((k == P.OP_OUT).sum()) * cfg.dma.descriptor_time(),
        trans_s=float(x.sum()),
        host_s=float(val[k == P.OP_HOST].sum()))
    res = _result(cfg, tr, macs, int(n_calls.sum()))
    return res, per + n_calls * ctrl_unit


def simulate_gemm(cfg: SystemConfig, M: int, N: int, K: int,
                  dtype: Optional[str] = None,
                  max_steps: int = 400_000,
                  engine: Optional[str] = None) -> GemmResult:
    """Replay Algorithm 1 for one GEMM.  For very large problems the
    plan is built steady-state-sampled and scaled.  The plan itself is
    memoized (``gemm_plan_cached``) so benchmark sweeps stop rebuilding
    identical loop nests row after row."""
    dtype = dtype or cfg.sa.dtype
    np_name = P.np_dtype_for(dtype)
    counts = streaming.tile_counts(M, N, K, np_name,
                                   page_bytes=cfg.page_bytes)
    stride = max(1, counts["inner_steps"] // max_steps)
    plan = P.gemm_plan_cached(M, N, K, np_name,
                              page_bytes=cfg.page_bytes,
                              sample_stride=stride)
    return replay(cfg, plan, engine=engine)
