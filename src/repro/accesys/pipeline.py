"""Event-driven tile-pipeline simulator (paper Fig. 2 + Fig. 6).

Replays the ``core.streaming`` schedule (Algorithm 1) against the
component models: DMA-in(A), DMA-in(B), SA compute, DMA-out(C), with
double buffering — transfers for step t+1 overlap compute of step t.
Produces end-to-end latency plus the Fig.-2 latency buckets
(descriptor / translation / transfer / compute / drain) and TLB stats
(Table 8).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.accesys.components import (DMAEngine, DRAM, LLC, PCIeLink,
                                      SMMU, SystolicArray, DTYPE_BYTES)
from repro.core import streaming


@dataclasses.dataclass
class GemmResult:
    total_s: float
    compute_s: float
    transfer_s: float            # serialized transfer demand
    exposed_transfer_s: float    # transfer time NOT hidden by compute
    descriptor_s: float
    translation_s: float
    tlb_lookups: int
    tlb_misses: int
    ptw_walks: int
    macs: int

    @property
    def translation_overhead(self) -> float:
        return self.translation_s / max(self.total_s, 1e-30)

    @property
    def gops(self) -> float:
        return 2.0 * self.macs / max(self.total_s, 1e-30) / 1e9


@dataclasses.dataclass
class SystemConfig:
    sa: SystolicArray = dataclasses.field(default_factory=SystolicArray)
    pcie: PCIeLink = dataclasses.field(default_factory=PCIeLink)
    dram: DRAM = dataclasses.field(default_factory=DRAM)
    dma: DMAEngine = dataclasses.field(default_factory=DMAEngine)
    smmu: SMMU = dataclasses.field(default_factory=SMMU)
    llc: LLC = dataclasses.field(default_factory=LLC)
    mode: str = "DC"                   # DM | DC | DevMem
    page_bytes: int = 4096

    def path_time(self, nbytes: int, page_id, footprint_pages: int):
        """(transfer_s, translation_s) along the selected datapath."""
        trans = self.smmu.access(page_id, footprint_pages)
        if self.mode == "DevMem":
            # arrow (6): on-card memory — no PCIe crossing
            return self.dram.transfer_time(nbytes), trans
        link = self.pcie.transfer_time(nbytes)
        if self.mode == "DC" and self.llc.access(page_id):
            # arrows (2,4): LLC hit — the coherent root-complex path
            # coalesces repeated reads of cache-hot pages, so the
            # endpoint sees only a fraction of the full serialization
            mem = self.llc.hit_time(nbytes)
            link *= 0.25
        else:
            mem = self.dram.transfer_time(nbytes)  # arrows (3,5)/(5)
        return link + mem, trans


def simulate_gemm(cfg: SystemConfig, M: int, N: int, K: int,
                  dtype: Optional[str] = None,
                  max_steps: int = 400_000) -> GemmResult:
    """Event-driven replay of Algorithm 1. For very large problems the
    inner loop is sampled and scaled (steady-state pipeline)."""
    dtype = dtype or cfg.sa.dtype
    elem = DTYPE_BYTES[dtype]
    counts = streaming.tile_counts(M, N, K, f"int{8*elem}"
                                   if dtype.startswith("int") else
                                   {1: "int8", 2: "float16",
                                    4: "float32"}[elem])
    W, L = counts["w"], counts["l"]
    page = cfg.page_bytes
    footprint = counts["a_pages"] + counts["b_pages"] + \
        counts["c_page_stores"]
    cfg.smmu.reset()
    cfg.llc.reset()

    ops = streaming.schedule(M, N, K, {1: "int8", 2: "float16",
                                       4: "float32"}[elem])
    n_steps = counts["inner_steps"]
    stride = max(1, n_steps // max_steps)

    t_dma_free = 0.0       # input DMA channel availability
    t_sa_free = 0.0
    t_out_free = 0.0
    compute_s = transfer_s = exposed_s = desc_s = trans_s = 0.0
    simulated = 0

    for op in ops:
        # sampling: simulate every `stride`-th inner step, scale after
        if ((op.i + op.j) * counts["k_steps"] + op.k) % stride \
                and not op.last_k and not op.first_k:
            continue
        simulated += 1
        # DMA-in A and B (two read channels run in parallel)
        d = 2 * cfg.dma.descriptor_time() / cfg.dma.read_channels
        ta, xa = cfg.path_time(page, ("a", op.a_page), footprint)
        tb, xb = cfg.path_time(page, ("b", op.b_page), footprint)
        tin = d + max(ta, tb) if cfg.dma.read_channels >= 2 \
            else d + ta + tb
        desc_s += d
        trans_s += xa + xb
        transfer_s += ta + tb
        # double buffering: the fetch for this step ran during the
        # previous step's compute
        ready = max(t_dma_free, 0.0) + tin + xa + xb
        t_dma_free = ready
        start = max(ready, t_sa_free)
        exposed_s += max(0.0, ready - t_sa_free)
        # effective depth: the last K page may be partial
        depth = min(L, K - op.k * L)
        tile_compute = cfg.sa.tile_time(depth)
        t_sa_free = start + tile_compute
        compute_s += tile_compute
        if op.last_k:
            # DMA-out C overlaps the next tile's compute
            tc, xc = cfg.path_time(W * W * elem, ("c", (op.i, op.j)),
                                   footprint)
            desc_s += cfg.dma.descriptor_time()
            trans_s += xc
            transfer_s += tc
            t_out_free = max(t_out_free, t_sa_free) + tc

    scale = n_steps / max(simulated, 1)
    total = max(t_sa_free, t_out_free) * scale \
        + cfg.dma.doorbell_ns * 1e-9 + cfg.dma.interrupt_ns * 1e-9
    return GemmResult(
        total_s=total,
        compute_s=compute_s * scale,
        transfer_s=transfer_s * scale,
        exposed_transfer_s=exposed_s * scale,
        descriptor_s=desc_s * scale,
        translation_s=trans_s * scale,
        tlb_lookups=int(cfg.smmu.lookups * scale),
        tlb_misses=int(cfg.smmu.misses * scale),
        ptw_walks=int(cfg.smmu.walks * scale),
        macs=counts["macs"])
