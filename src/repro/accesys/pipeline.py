"""Event-driven StreamPlan replayer (paper Fig. 2 + Fig. 6).

``replay`` times ANY ``core.plan.StreamPlan`` — single GEMMs, paged
attention, composed N-layer transformer models, expert-routed MoE
layers, scan-structured SSM layers, paged-KV decode steps, or
steady-state-sampled ``PlanSchedule``s — against the
component models: DMA-in on two read channels (lane 0 = A, lane 1 = B),
SA compute with double buffering (transfers for step t+1 overlap compute
of step t), host-side ops, and DMA-out draining behind the next tile's
compute.  It produces end-to-end latency plus the Fig.-2 latency buckets
(descriptor / translation / transfer / compute / drain) and TLB stats
(Table 8).

``simulate_gemm`` keeps its historical signature but is now a thin
wrapper: build the (possibly steady-state-sampled) Algorithm-1 plan and
replay it — the SAME plan ``core.streaming.gemm_streamed`` executes
functionally.
"""
from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.accesys.components import (DMAEngine, DRAM, Fabric, LLC,
                                      LRUStreamState, PCIeLink, SMMU,
                                      SystolicArray, _lru_trace_memo)
from repro.core import plan as P
from repro.core import streaming

# behavioural host rate for plan-level host ops (softmax/LN/gelu):
# matches system.CPUModel.nongemm_cycles_per_elem at 1 GHz
HOST_S_PER_ELEM = 0.8e-9

# replay engine selection: "auto" uses the compiled (array-form) engine
# once a plan is big enough to amortize the vectorized passes, and the
# event loop below that; "event" / "compiled" force one engine
DEFAULT_ENGINE = "auto"
COMPILED_MIN_EVENTS = 3000


@dataclasses.dataclass
class GemmResult:
    total_s: float
    compute_s: float
    transfer_s: float            # serialized transfer demand
    exposed_transfer_s: float    # transfer time NOT hidden by compute
    descriptor_s: float
    translation_s: float
    tlb_lookups: int
    tlb_misses: int
    ptw_walks: int
    macs: int
    host_s: float = 0.0          # host-side op time (composed plans)
    drain_s: float = 0.0         # DMA-out tail not hidden by compute
    coll_s: float = 0.0          # inter-device collective time (multidev)

    @property
    def translation_overhead(self) -> float:
        return self.translation_s / max(self.total_s, 1e-30)

    @property
    def gops(self) -> float:
        return 2.0 * self.macs / max(self.total_s, 1e-30) / 1e9

    def buckets(self) -> dict:
        """Fig.-2 latency buckets, as shares of total."""
        t = max(self.total_s, 1e-30)
        return {"descriptor": self.descriptor_s / t,
                "translation": self.translation_s / t,
                "transfer": self.exposed_transfer_s / t,
                "compute": self.compute_s / t,
                "drain": self.drain_s / t,
                "host": self.host_s / t,
                "collective": self.coll_s / t}


# keep the historical name but make the generality explicit
ReplayResult = GemmResult


@dataclasses.dataclass
class SystemConfig:
    sa: SystolicArray = dataclasses.field(default_factory=SystolicArray)
    pcie: PCIeLink = dataclasses.field(default_factory=PCIeLink)
    dram: DRAM = dataclasses.field(default_factory=DRAM)
    dma: DMAEngine = dataclasses.field(default_factory=DMAEngine)
    smmu: SMMU = dataclasses.field(default_factory=SMMU)
    llc: LLC = dataclasses.field(default_factory=LLC)
    fabric: Fabric = dataclasses.field(default_factory=Fabric)
    mode: str = "DC"                   # DM | DC | DevMem
    page_bytes: int = 4096

    def path_time(self, nbytes: int, page_id, footprint_pages: int):
        """(transfer_s, translation_s) along the selected datapath."""
        trans = self.smmu.access(page_id, footprint_pages)
        if self.mode == "DevMem":
            # arrow (6): on-card memory — no PCIe crossing
            return self.dram.transfer_time(nbytes), trans
        link = self.pcie.transfer_time(nbytes)
        if self.mode == "DC" and self.llc.access(page_id):
            # arrows (2,4): LLC hit — the coherent root-complex path
            # coalesces repeated reads of cache-hot pages, so the
            # endpoint sees only a fraction of the full serialization
            mem = self.llc.hit_time(nbytes)
            link *= 0.25
        else:
            mem = self.dram.transfer_time(nbytes)  # arrows (3,5)/(5)
        return link + mem, trans


@dataclasses.dataclass
class _Trace:
    """Raw replay timeline state + bucket accumulators (unscaled)."""
    t_sa_free: float = 0.0
    t_out_free: float = 0.0
    t_dma_free: float = 0.0
    compute_s: float = 0.0
    transfer_s: float = 0.0
    exposed_s: float = 0.0
    desc_s: float = 0.0
    trans_s: float = 0.0
    host_s: float = 0.0
    coll_s: float = 0.0

    @property
    def makespan(self) -> float:
        return max(self.t_sa_free, self.t_out_free)


def _replay_events(cfg: SystemConfig, events, footprint_pages: int,
                   host_s_per_elem: float = HOST_S_PER_ELEM,
                   tr: Optional[_Trace] = None) -> _Trace:
    """Walk the event list against the component models.

    Double buffering: a COMPUTE's input DMA group is charged against the
    input-DMA channel timeline, so the fetch for step t+1 runs during
    step t's compute; only the excess surfaces as exposed transfer.
    DMA-out uses the write channels and drains behind compute.

    Passing an existing ``tr`` continues its timeline — the schedule
    replayer walks steady-state windows back-to-back on one clock, so
    drain tails and DMA-engine occupancy overlap the next window's
    compute exactly as they do in an exact composed replay.
    """
    tr = tr if tr is not None else _Trace()
    pending: list = []             # (lane, transfer_s, translation_s)

    def drain_pending() -> float:
        """Charge the queued DMA_IN group against the input-DMA
        timeline; returns when its data is ready on-chip."""
        nonlocal pending
        d = len(pending) * cfg.dma.descriptor_time() \
            / cfg.dma.read_channels
        tr.desc_s += d
        lanes: dict = {}
        for lane, t, _ in pending:
            lanes[lane] = lanes.get(lane, 0.0) + t
        if cfg.dma.read_channels >= len(lanes):
            tin = d + max(lanes.values())
        else:
            tin = d + sum(t for _, t, _ in pending)
        ready = max(tr.t_dma_free, 0.0) + tin \
            + sum(x for _, _, x in pending)
        tr.t_dma_free = ready
        pending = []
        return ready

    for ev in events:
        if ev.kind is P.EventKind.DMA_IN:
            t, x = cfg.path_time(ev.nbytes, ev.page, footprint_pages)
            pending.append((ev.lane, t, x))
            tr.transfer_s += t
            tr.trans_s += x
        elif ev.kind is P.EventKind.COMPUTE and ev.unit == "sa":
            ready = drain_pending() if pending else 0.0
            start = max(ready, tr.t_sa_free)
            tr.exposed_s += max(0.0, ready - tr.t_sa_free)
            tile = cfg.sa.tile_time(ev.meta["depth"])
            tr.t_sa_free = start + tile
            tr.compute_s += tile
        elif ev.kind is P.EventKind.COMPUTE:
            # host op: waits for fetches in flight and for the producing
            # C tiles to drain, then runs on the CPU while the
            # accelerator idles (paper §4.2)
            if pending:                  # pages fetched for host use
                ready = drain_pending()
                tr.exposed_s += max(0.0, ready - tr.t_sa_free)
                tr.t_sa_free = max(tr.t_sa_free, ready)
            th = ev.meta["elems"] * host_s_per_elem
            tr.t_sa_free = max(tr.t_sa_free, tr.t_out_free) + th
            tr.host_s += th
        elif ev.kind is P.EventKind.COLLECTIVE:
            # inter-device exchange hop: a barrier on this rank's
            # timeline priced on the dedicated fabric link — no page
            # traffic on the host<->device path, and pending fetches
            # of the NEXT op keep prefetching underneath it (they
            # drain at that op, exactly as across a DMA_OUT)
            tc = cfg.fabric.hop_time(ev.nbytes)
            tr.t_sa_free = max(tr.t_sa_free, tr.t_out_free) + tc
            tr.coll_s += tc
        else:                       # DMA_OUT
            tc, xc = cfg.path_time(ev.nbytes, ev.page, footprint_pages)
            tr.desc_s += cfg.dma.descriptor_time()
            tr.trans_s += xc
            tr.transfer_s += tc
            tr.t_out_free = max(tr.t_out_free, tr.t_sa_free) + tc
    if pending:                     # trailing fetches no compute consumed
        ready = drain_pending()
        tr.exposed_s += max(0.0, ready - tr.t_sa_free)
        tr.t_sa_free = max(tr.t_sa_free, ready)
    return tr


def _result(cfg: SystemConfig, tr: _Trace, macs: int, n_calls: int,
            scale: float = 1.0) -> GemmResult:
    control = n_calls * (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9
    total = max(tr.t_sa_free, tr.t_out_free) * scale + control
    return GemmResult(
        total_s=total,
        compute_s=tr.compute_s * scale,
        transfer_s=tr.transfer_s * scale,
        exposed_transfer_s=tr.exposed_s * scale,
        descriptor_s=tr.desc_s * scale,
        translation_s=tr.trans_s * scale,
        tlb_lookups=int(cfg.smmu.lookups * scale),
        tlb_misses=int(cfg.smmu.misses * scale),
        ptw_walks=int(cfg.smmu.walks * scale),
        macs=macs,
        host_s=tr.host_s * scale,
        drain_s=max(0.0, tr.t_out_free - tr.t_sa_free) * scale,
        coll_s=tr.coll_s * scale)


def _use_compiled(engine: Optional[str], n_events: int,
                  reset: bool) -> bool:
    engine = engine or DEFAULT_ENGINE
    if engine == "event" or not reset:
        return False                 # continuing a live timeline/state
    if engine == "compiled":
        return True
    assert engine == "auto", engine
    return n_events >= COMPILED_MIN_EVENTS


def replay(cfg: SystemConfig, plan,
           host_s_per_elem: float = HOST_S_PER_ELEM,
           reset: bool = True,
           footprint_pages: Optional[int] = None,
           engine: Optional[str] = None) -> GemmResult:
    """Time an arbitrary StreamPlan end-to-end on this system config.

    Works for single-op plans, for composed multi-layer transformer /
    MoE / SSM / decode plans, and for ``PlanSchedule`` steady-state
    samples (dispatched to ``replay_schedule``); per-offloaded-call
    control cost (doorbell + completion IRQ) is charged
    ``plan.n_calls`` times.  ``footprint_pages`` overrides the
    SMMU-visible footprint (used when a window plan stands in for a
    much larger workload, so page-walk depth reflects the real one).

    ``engine`` selects the replayer: ``"event"`` walks Python event
    objects one by one; ``"compiled"`` runs the array-form engine over
    ``plan.compile()`` (numerically interchangeable, ~10-100x faster
    on composed plans); ``"auto"`` (default) picks by plan size.  With
    ``reset=False`` the event engine is always used — only it can
    continue a live timeline/cache state (results are identical either
    way, by the parity suite).
    """
    if isinstance(plan, P.PlanSchedule):
        return replay_schedule(cfg, plan, host_s_per_elem, reset,
                               footprint_pages, engine)
    if _use_compiled(engine, len(plan.events), reset):
        return replay_compiled(cfg, plan, host_s_per_elem,
                               footprint_pages)
    if reset:
        cfg.smmu.reset()
        cfg.llc.reset()
    scale = plan.total_steps / max(plan.sampled_steps, 1) \
        if plan.total_steps else 1.0
    foot = plan.footprint_pages if footprint_pages is None \
        else footprint_pages
    tr = _replay_events(cfg, plan.events, foot, host_s_per_elem)
    return _result(cfg, tr, plan.macs, plan.n_calls, scale)


def _schedule_passes(unit_ctrl, segments, seg_delta,
                     on_pass_reset=None, zero=0.0):
    """The two-pass steady-window accumulation shared by the event,
    compiled and config-batched schedule replayers.

    Two passes on ONE continuous timeline: the first (weight 1) is the
    cold-start window; the second (weight repeat-1) sees the
    steady-state DMA/compute phase relationship — cold windows expose
    more transfer than steady ones because the input-DMA timeline has
    not yet fallen behind compute.  ``on_pass_reset`` runs between the
    passes (per-key SMMU/LLC reset: in the exact replay every repeat
    owns fresh pages, so key reuse across passes would fake translation
    hits).  ``seg_delta(pass_no, si, pl)`` yields a segment's unscaled
    deltas for the 12 accumulated quantities (total, compute, transfer,
    exposed, desc, trans, host, coll, drain, lookups, misses, walks) —
    each a scalar, or a per-config array when ``zero`` is one.
    ``unit_ctrl`` is the per-call doorbell+IRQ time.  Returns
    (accumulators, control, macs)."""
    multi = any(rep > 1 for _, rep in segments)
    acc = [zero] * 12
    control = zero
    macs = 0
    for pass_no in range(2 if multi else 1):
        if pass_no == 1 and on_pass_reset is not None:
            on_pass_reset()
        for si, (pl, rep) in enumerate(segments):
            weight = 1.0 if pass_no == 0 else float(rep - 1)
            scale = weight * (pl.total_steps / max(pl.sampled_steps, 1)
                              if pl.total_steps else 1.0)
            acc = [a + dv * scale
                   for a, dv in zip(acc, seg_delta(pass_no, si, pl))]
            control = control + pl.n_calls * weight * unit_ctrl
            if pass_no == 0:
                macs += pl.macs * rep
    return acc, control, macs


def _passes_result(acc, control, macs: int) -> GemmResult:
    (total, compute, transfer, exposed, desc, trans, host, coll,
     drain, lookups, misses, walks) = acc
    return GemmResult(
        total_s=total + control, compute_s=compute, transfer_s=transfer,
        exposed_transfer_s=exposed, descriptor_s=desc,
        translation_s=trans, tlb_lookups=int(lookups),
        tlb_misses=int(misses), ptw_walks=int(walks), macs=macs,
        host_s=host, drain_s=max(0.0, drain), coll_s=coll)


def replay_schedule(cfg: SystemConfig, sched: P.PlanSchedule,
                    host_s_per_elem: float = HOST_S_PER_ELEM,
                    reset: bool = True,
                    footprint_pages: Optional[int] = None,
                    engine: Optional[str] = None) -> GemmResult:
    """Steady-state replay of a ``PlanSchedule``: each segment's steady
    window is replayed ONCE against shared SMMU/LLC state and its
    timeline scaled by ``repeat`` (x the intra-GEMM sampling scale, for
    strided windows).  This is what lets a composed BERT-Base forward
    pass replay one layer's events instead of the full stack's while
    matching the exact replay to within a couple of percent."""
    if _use_compiled(engine, sched.sampled_events, reset):
        return replay_schedule_compiled(cfg, sched, host_s_per_elem,
                                        footprint_pages)
    if reset:
        cfg.smmu.reset()
        cfg.llc.reset()
    foot = sched.footprint_pages if footprint_pages is None \
        else footprint_pages
    tr = _Trace()

    def seg_delta(pass_no, si, pl):
        lk0, ms0, wk0 = cfg.smmu.lookups, cfg.smmu.misses, \
            cfg.smmu.walks
        m0, c0, x0, e0 = tr.makespan, tr.compute_s, tr.transfer_s, \
            tr.exposed_s
        d0, tn0, h0, cl0 = tr.desc_s, tr.trans_s, tr.host_s, tr.coll_s
        dr0 = max(0.0, tr.t_out_free - tr.t_sa_free)
        _replay_events(cfg, pl.events, foot, host_s_per_elem, tr)
        return (tr.makespan - m0, tr.compute_s - c0,
                tr.transfer_s - x0, tr.exposed_s - e0,
                tr.desc_s - d0, tr.trans_s - tn0, tr.host_s - h0,
                tr.coll_s - cl0,
                max(0.0, tr.t_out_free - tr.t_sa_free) - dr0,
                cfg.smmu.lookups - lk0, cfg.smmu.misses - ms0,
                cfg.smmu.walks - wk0)

    def reset_state():
        cfg.smmu.reset()
        cfg.llc.reset()

    acc, control, macs = _schedule_passes(
        (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9,
        sched.segments, seg_delta, on_pass_reset=reset_state)
    return _passes_result(acc, control, macs)


# ===================================================================
# Compiled (array-form) replay engine
# ===================================================================
# The event loop above dispatches on one Python ``Event`` object per
# iteration; the engine below replays the SAME timeline over the
# pre-resolved arrays of a ``core.plan.CompiledPlan``: one vectorized
# SMMU/LLC stack-distance pass prices the whole page trace, DMA-in
# groups reduce to per-op lane sums, and the double-buffer recurrence
# (input-DMA channel vs SA busy time vs DMA-out drain) runs over float
# arrays.  Results match ``replay`` to float tolerance for every
# workload and mode — exact composed replays stop being the slow path.

def _resolve_access_times(cfg: SystemConfig, cp, foot: int):
    """(transfer_s, translation_s) per DMA access — the batch
    counterpart of ``SystemConfig.path_time`` over the whole trace."""
    x = cfg.smmu.access_many(cp.trace_ids, foot, memo=cp.memo,
                             keys=cp.page_keys)
    nb = cp.trace_nbytes
    dlat = cfg.dram.latency_ns * 1e-9
    dbw = cfg.dram.bandwidth * cfg.dram.stream_efficiency
    if cfg.mode == "DevMem":
        return dlat + nb / dbw, x
    link = nb / cfg.pcie.effective_bw
    mem = dlat + nb / dbw
    if cfg.mode == "DC":
        hit = cfg.llc.access_many(cp.trace_ids, memo=cp.memo,
                                  keys=cp.page_keys)
        llc_t = cfg.llc.hit_latency_ns * 1e-9 + nb / cfg.llc.hit_bw
        return np.where(hit, link * 0.25 + llc_t, link + mem), x
    return link + mem, x


def _grp_starts(cp) -> np.ndarray:
    gs = cp.memo.get("gs")
    if gs is None:
        ge = cp.grp_end
        gs = np.concatenate([[0], ge[:-1]]) if ge.size else ge
        cp.memo["gs"] = gs
    return gs


def _seg_sum(v: np.ndarray, s: np.ndarray, e: np.ndarray) -> np.ndarray:
    """Per-segment sums of ``v`` over contiguous tiling segments
    ``[s[i], e[i])``.  Each segment is reduced left-to-right over its
    OWN elements only (``np.add.reduceat``), matching the event loop's
    per-group ``sum`` — and, unlike a diff-of-prefix-cumsum, the value
    of a segment does not depend on anything outside it, so a trace
    priced in chunks produces bitwise the same per-op sums as the
    monolithic pass."""
    out = np.zeros(s.size)
    ne = np.nonzero(e > s)[0]
    if ne.size:
        # non-empty segments tile v exactly (empties have s == e), so
        # reduceat over their starts reduces each segment in isolation
        out[ne] = np.add.reduceat(v, s[ne])
    return out


def _gsum(cp, v: np.ndarray) -> np.ndarray:
    """Sum of the per-access quantity ``v`` over each op's drain
    group."""
    return _seg_sum(v, _grp_starts(cp), cp.grp_end)


def _pending_counts(cp):
    """(npend, has_p) per op — trace-intrinsic, cached on the plan."""
    npend = cp.memo.get("npend")
    if npend is None:
        npend = cp.grp_end - _grp_starts(cp)
        cp.memo["npend"] = npend
        cp.memo["hasp"] = npend > 0
    return npend, cp.memo["hasp"]


def _inout_positions(cp):
    """(input, output) access index arrays — trace-intrinsic."""
    pos = cp.memo.get("inout_pos")
    if pos is None:
        is_out = cp.trace_is_out
        pos = (np.nonzero(~is_out)[0], np.nonzero(is_out)[0])
        cp.memo["inout_pos"] = pos
    return pos


def _group_xlat_sum(cp, x: np.ndarray) -> np.ndarray:
    """Per-op translation sum — depends only on the SMMU row."""
    return _gsum(cp, np.take(x, _inout_positions(cp)[0]))


def _group_path_sums(cp, t: np.ndarray):
    """Per-op input totals, lane maxima and DMA_OUT transfer times —
    depend only on the datapath (transfer) row."""
    in_pos, out_pos = _inout_positions(cp)
    in_t = np.take(t, in_pos)
    tot_t = _gsum(cp, in_t)
    lanes = cp.memo.get("lanes")
    if lanes is None:
        lanes = np.unique(cp.in_lane)
        cp.memo["lanes"] = lanes
        cp.memo["lane_masks"] = [cp.in_lane == ln for ln in lanes]
    if lanes.size <= 1:
        lane_max = tot_t
    else:
        # lane-compacted per-group sums: each lane's accesses are
        # packed contiguously, so its per-group spans tile the packed
        # array and ``_seg_sum`` reduces each group in isolation
        pack = cp.memo.get("lane_pack")
        if pack is None:
            pack = []
            for m_ in cp.memo["lane_masks"]:
                cnt = np.empty(m_.size + 1, np.int64)
                cnt[0] = 0
                np.cumsum(m_, out=cnt[1:])
                pack.append((np.nonzero(m_)[0],
                             cnt[_grp_starts(cp)], cnt[cp.grp_end]))
            cp.memo["lane_pack"] = pack
        lane_max = None
        for pos, si, ei in pack:
            s_ = _seg_sum(np.take(in_t, pos), si, ei)
            lane_max = s_ if lane_max is None \
                else np.maximum(lane_max, s_)
    out_ops = cp.memo.get("out_ops")
    if out_ops is None:
        out_ops = np.nonzero(cp.op_kind == P.OP_OUT)[0]
        cp.memo["out_ops"] = out_ops
    tc = np.zeros(cp.op_kind.size)
    if out_pos.size:
        tc[out_ops] = np.take(t, out_pos)[:out_ops.size]
    return tot_t, lane_max, tc


def _group_reduce(cfg: SystemConfig, cp, t: np.ndarray, x: np.ndarray,
                  *, sums=None):
    """Per-op drain-group quantities: pending count, descriptor time,
    channel-limited input time (``tin``), translation sum, plus the
    per-op DMA_OUT transfer times.  When ``sums`` is given (batched
    path), the per-access reductions already computed for configs
    sharing this SMMU/datapath row pair are reused."""
    if sums is None:
        sums = (_group_xlat_sum(cp, x), _group_path_sums(cp, t))
    sx, (tot_t, lane_max, tc) = sums
    ge = cp.grp_end
    npend, has_p = _pending_counts(cp)
    d = npend * cfg.dma.descriptor_time() / cfg.dma.read_channels
    tin = d + np.where(cfg.dma.read_channels >= cp.n_lanes,
                       lane_max, tot_t)
    # input-DMA channel timeline: advances only when a group drains;
    # interleave tin/sx so the float op order matches the event loop's
    # ``(t_dma + tin) + sum(x)``
    z = np.zeros(2 * len(ge))
    z[0::2] = np.where(has_p, tin, 0.0)
    z[1::2] = np.where(has_p, sx, 0.0)
    ready = np.cumsum(z)[1::2]
    return has_p, d, sx, ready, tc


def _op_amounts_base(cfg: SystemConfig, cp,
                     host_s_per_elem: float) -> np.ndarray:
    """SA tile + host + collective op amounts — depend only on the SA
    variant and the fabric (the host term is config-independent)."""
    k = cp.op_kind
    val = np.where(k == P.OP_SA,
                   cfg.sa.passes * (cp.op_val + 2 * (cfg.sa.w - 1))
                   / cfg.sa.freq, 0.0)
    val = np.where(k == P.OP_HOST, cp.op_val * host_s_per_elem, val)
    return np.where(k == P.OP_COLL,
                    cp.op_val / cfg.fabric.link.effective_bw
                    + cfg.fabric.hop_latency_ns * 1e-9, val)


def _op_amounts(cfg: SystemConfig, cp, tc: np.ndarray,
                host_s_per_elem: float, base=None) -> np.ndarray:
    """The one scalar each op adds to its timeline: SA tile time, host
    op time, or DMA_OUT transfer time."""
    if base is None:
        base = _op_amounts_base(cfg, cp, host_s_per_elem)
    return np.where(cp.op_kind == P.OP_OUT, tc, base)


def _run_ops_loop(opk, has_p, ready, val, t_sa, t_out):
    """Reference scalar recurrence — fastest for small op streams and
    the literal transcription of the event loop's timeline updates."""
    n = len(opk)
    tsa_a = np.empty(n)
    tout_a = np.empty(n)
    exp_a = np.zeros(n)
    opk_l, hp_l = opk.tolist(), has_p.tolist()
    rdy_l, val_l = ready.tolist(), val.tolist()
    for g in range(n):
        k = opk_l[g]
        if k == P.OP_OUT:
            if t_sa > t_out:
                t_out = t_sa
            t_out += val_l[g]
        else:
            if hp_l[g]:
                r = rdy_l[g]
                if r > t_sa:
                    exp_a[g] = r - t_sa
                    t_sa = r
            if k == P.OP_HOST or k == P.OP_COLL:
                if t_out > t_sa:
                    t_sa = t_out
            if k != P.OP_TAIL:
                t_sa += val_l[g]
        tsa_a[g] = t_sa
        tout_a[g] = t_out
    return tsa_a, tout_a, exp_a, t_sa, t_out


def _run_ops_vec(opk, has_p, ready, val, t_sa, t_out):
    """Vectorized recurrence: host/collective ops and stream drains are
    the only points where the SA timeline reads the DMA-out timeline, so
    the op stream splits into segments that reduce to cumulative sums
    plus running maxima (the max-plus closed form of the double-buffer
    recurrence)."""
    n = opk.size
    tsa_a = np.empty(n)
    tout_a = np.empty(n)
    exp_a = np.zeros(n)
    barrier = np.nonzero((opk == P.OP_HOST) | (opk == P.OP_COLL)
                         | (opk == P.OP_TAIL))[0]
    starts = np.concatenate([[0], barrier + 1])
    ends = np.concatenate([barrier, [n]])
    for s0, s1 in zip(starts, ends):
        s0, s1 = int(s0), int(s1)
        if s1 > s0:
            k = opk[s0:s1]
            v = val[s0:s1]
            sa = np.nonzero(k == P.OP_SA)[0]
            out = np.nonzero(k == P.OP_OUT)[0]
            tsa_seg = None
            if sa.size:
                tiles = v[sa]
                pre = np.cumsum(tiles)
                r = np.where(has_p[s0:s1][sa], ready[s0:s1][sa],
                             -np.inf)
                q = r - np.concatenate([[0.0], pre[:-1]])
                run = np.maximum.accumulate(q)
                tsa_seg = pre + np.maximum(t_sa, run)
                prev_run = np.maximum(
                    t_sa, np.concatenate([[-np.inf], run[:-1]]))
                exp_a[s0:s1][sa] = np.maximum(q - prev_run, 0.0)
            sa_cum = np.cumsum(k == P.OP_SA) - 1
            tsa_sl = np.where(
                sa_cum >= 0,
                tsa_seg[np.maximum(sa_cum, 0)] if tsa_seg is not None
                else t_sa, t_sa)
            tout_seg = None
            if out.size:
                tcs = v[out]
                tcum = np.cumsum(tcs)
                p = tsa_sl[out] - np.concatenate([[0.0], tcum[:-1]])
                tout_seg = tcum + np.maximum(
                    t_out, np.maximum.accumulate(p))
            out_cum = np.cumsum(k == P.OP_OUT) - 1
            tout_sl = np.where(
                out_cum >= 0,
                tout_seg[np.maximum(out_cum, 0)] if tout_seg is not None
                else t_out, t_out)
            tsa_a[s0:s1] = tsa_sl
            tout_a[s0:s1] = tout_sl
            t_sa = float(tsa_sl[-1])
            t_out = float(tout_sl[-1])
        if s1 < n:                           # the barrier op itself
            g = s1
            if has_p[g]:
                r = ready[g]
                if r > t_sa:
                    exp_a[g] = r - t_sa
                    t_sa = r
            if opk[g] == P.OP_HOST or opk[g] == P.OP_COLL:
                if t_out > t_sa:
                    t_sa = t_out
                t_sa += val[g]
            tsa_a[g] = t_sa
            tout_a[g] = t_out
    return tsa_a, tout_a, exp_a, t_sa, t_out


def _run_ops(opk, has_p, ready, val, t_sa=0.0, t_out=0.0,
             force: Optional[str] = None):
    use_vec = (opk.size >= 2048) if force is None else (force == "vec")
    fn = _run_ops_vec if use_vec else _run_ops_loop
    return fn(opk, has_p, ready, val, t_sa, t_out)


def _compiled_arrays(cfg: SystemConfig, cp, foot: int,
                     host_s_per_elem: float):
    t, x = _resolve_access_times(cfg, cp, foot)
    has_p, d, sx, ready, tc = _group_reduce(cfg, cp, t, x)
    val = _op_amounts(cfg, cp, tc, host_s_per_elem)
    return t, x, has_p, d, ready, val


def replay_compiled(cfg: SystemConfig, plan,
                    host_s_per_elem: float = HOST_S_PER_ELEM,
                    footprint_pages: Optional[int] = None,
                    _recur: Optional[str] = None) -> GemmResult:
    """Array-form replay of a StreamPlan: numerically interchangeable
    with ``replay(engine="event")`` but runs over the compiled plan's
    pre-resolved float arrays instead of per-event object dispatch.
    Always starts from reset SMMU/LLC state (use the event engine to
    continue a live timeline)."""
    if isinstance(plan, P.PlanSchedule):
        return replay_schedule_compiled(cfg, plan, host_s_per_elem,
                                        footprint_pages, _recur)
    cfg.smmu.reset()
    cfg.llc.reset()
    cp = plan.compile()
    foot = plan.footprint_pages if footprint_pages is None \
        else footprint_pages
    t, x, has_p, d, ready, val = _compiled_arrays(cfg, cp, foot,
                                                  host_s_per_elem)
    k = cp.op_kind
    _, _, exp_a, t_sa, t_out = _run_ops(k, has_p, ready, val,
                                        force=_recur)
    tr = _Trace(
        t_sa_free=t_sa, t_out_free=t_out,
        compute_s=float(val[k == P.OP_SA].sum()),
        transfer_s=float(t.sum()),
        exposed_s=float(exp_a.sum()),
        desc_s=float(d[has_p].sum())
        + float((k == P.OP_OUT).sum()) * cfg.dma.descriptor_time(),
        trans_s=float(x.sum()),
        host_s=float(val[k == P.OP_HOST].sum()),
        coll_s=float(val[k == P.OP_COLL].sum()))
    scale = plan.total_steps / max(plan.sampled_steps, 1) \
        if plan.total_steps else 1.0
    return _result(cfg, tr, plan.macs, plan.n_calls, scale)


def replay_schedule_compiled(cfg: SystemConfig, sched: P.PlanSchedule,
                             host_s_per_elem: float = HOST_S_PER_ELEM,
                             footprint_pages: Optional[int] = None,
                             _recur: Optional[str] = None) -> GemmResult:
    """Compiled counterpart of ``replay_schedule``: the two sampling
    passes run over ONE concatenated op stream (pass 1 repeats pass 0's
    arrays on the continuing timeline — per-key SMMU/LLC state resets
    between passes, and both passes start that state empty, so the
    per-access times are identical), with per-segment deltas read off
    the op trajectories at the recorded boundaries."""
    cfg.smmu.reset()
    cfg.llc.reset()
    cp = sched.compile()
    foot = sched.footprint_pages if footprint_pages is None \
        else footprint_pages
    t, x, has_p, d, ready, val = _compiled_arrays(cfg, cp, foot,
                                                  host_s_per_elem)
    k = cp.op_kind
    multi = any(rep > 1 for _, rep in sched.segments)
    n_ops = k.size
    if multi:                       # pass 1 = same ops, timeline continues
        k2 = np.concatenate([k, k])
        has_p2 = np.concatenate([has_p, has_p])
        adv_total = ready[-1] if n_ops else 0.0
        ready2 = np.concatenate([ready, ready + adv_total])
        val2 = np.concatenate([val, val])
    else:
        k2, has_p2, ready2, val2 = k, has_p, ready, val
    tsa_a, tout_a, exp_a, _, _ = _run_ops(k2, has_p2, ready2, val2,
                                          force=_recur)

    # op-index boundaries of every (pass, segment) on the run timeline
    bounds2 = np.concatenate([[0], cp.seg_op]) if not multi else \
        np.concatenate([[0], cp.seg_op, n_ops + cp.seg_op])

    def snaps(per_op, init=0.0):
        return np.concatenate([[init], per_op])[bounds2]

    # cumulative per-op / per-access contributions (identical for both
    # passes — only the timeline-dependent ones use the doubled run)
    def cum_at(per_item, bounds):
        c = np.concatenate([[0.0], np.cumsum(per_item)])
        return c[np.concatenate([[0], bounds])]

    comp_c = cum_at(np.where(k == P.OP_SA, val, 0.0), cp.seg_op)
    host_c = cum_at(np.where(k == P.OP_HOST, val, 0.0), cp.seg_op)
    coll_c = cum_at(np.where(k == P.OP_COLL, val, 0.0), cp.seg_op)
    desc_c = cum_at(np.where(has_p, d, 0.0)
                    + np.where(k == P.OP_OUT,
                               cfg.dma.descriptor_time(), 0.0),
                    cp.seg_op)
    xfer_c = cum_at(t, cp.seg_trace)
    trans_c = cum_at(x, cp.seg_trace)
    tlb_miss, miss_pos, walk_sub = cfg.smmu.tlb_walk_masks(cp.trace_ids,
                                                           cp.memo)
    walk_mask = np.zeros(cp.trace_ids.size, bool)
    walk_mask[miss_pos[walk_sub]] = True
    miss_c = cum_at(tlb_miss.astype(np.float64), cp.seg_trace)
    walk_c = cum_at(walk_mask.astype(np.float64), cp.seg_trace)
    look_c = np.concatenate([[0], cp.seg_trace]).astype(np.float64)
    # timeline-dependent snapshots per (pass, segment boundary)
    tsa_s = snaps(tsa_a)
    tout_s = snaps(tout_a)
    mks_s = np.maximum(tsa_s, tout_s)
    drain_s_snap = np.maximum(0.0, tout_s - tsa_s)
    exp_s = np.concatenate([[0.0], np.cumsum(exp_a)])[bounds2]

    nseg = len(sched.segments)

    def seg_delta(pass_no, si, pl):
        tb = pass_no * nseg + si            # timeline boundary index
        return (mks_s[tb + 1] - mks_s[tb],
                comp_c[si + 1] - comp_c[si],
                xfer_c[si + 1] - xfer_c[si],
                exp_s[tb + 1] - exp_s[tb],
                desc_c[si + 1] - desc_c[si],
                trans_c[si + 1] - trans_c[si],
                host_c[si + 1] - host_c[si],
                coll_c[si + 1] - coll_c[si],
                drain_s_snap[tb + 1] - drain_s_snap[tb],
                look_c[si + 1] - look_c[si],
                miss_c[si + 1] - miss_c[si],
                walk_c[si + 1] - walk_c[si])

    acc, control, macs = _schedule_passes(
        (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9,
        sched.segments, seg_delta)
    return _passes_result(acc, control, macs)


def replay_trace(cfg: SystemConfig, plans,
                 host_s_per_elem: float = HOST_S_PER_ELEM,
                 footprint_pages: Optional[int] = None,
                 engine: Optional[str] = None):
    """Price an entire sequence of plans (e.g. a recorded serving
    trace: prefills + per-step decode plans) as ONE replay on one
    continuous timeline — shared SMMU/LLC state and shared page-id
    interning across plans, so cross-step KV-page reuse is visible to
    the translation and cache models instead of every step starting
    cold.  Returns ``(aggregate GemmResult, per-plan seconds)`` where
    the per-plan array reads each plan's contribution (its makespan
    delta plus its own doorbell/IRQ control time) off the trajectory
    at the recorded segment boundaries — the attribution the serving
    report folds back onto requests.  ``sum(per_plan) == total_s``.

    ``plans`` is a sequence of StreamPlans or a ``PlanSchedule`` whose
    repeats are all 1 (build the schedule once and pass it to share the
    compiled form and its trace-intrinsic LRU analysis across memory
    modes).  Trace replay is exact: steady-state-sampled plans are
    rejected.  The SMMU footprint defaults to the number of DISTINCT
    pages the whole trace touches (the union, not the per-plan sum —
    steps re-touch the same resident pool)."""
    if isinstance(plans, P.PlanSchedule):
        sched = plans
    else:
        sched = P.PlanSchedule("trace", [(p, 1) for p in plans])
    if not sched.segments:
        raise ValueError("replay_trace() needs at least one plan")
    for pl, rep in sched.segments:
        if rep != 1:
            raise ValueError(
                f"replay_trace() needs repeat-1 segments, got "
                f"({pl.name}, {rep}) — use replay_schedule for "
                "steady-state sampling")
        if pl.sampled_steps != pl.total_steps:
            raise ValueError(
                f"trace replay is exact; plan {pl.name} is "
                "steady-state sampled")
    cp = sched.compile()
    foot = len(cp.page_keys) if footprint_pages is None \
        else footprint_pages
    ctrl_unit = (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9
    n_calls = np.array([pl.n_calls for pl, _ in sched.segments],
                       np.float64)
    macs = sum(pl.macs for pl, _ in sched.segments)
    cfg.smmu.reset()
    cfg.llc.reset()
    if not _use_compiled(engine, cp.n_events, True):
        tr = _Trace()
        per = np.empty(len(sched.segments))
        prev = 0.0
        for i, (pl, _) in enumerate(sched.segments):
            _replay_events(cfg, pl.events, foot, host_s_per_elem, tr)
            per[i] = tr.makespan - prev
            prev = tr.makespan
        res = _result(cfg, tr, macs, int(n_calls.sum()))
        return res, per + n_calls * ctrl_unit
    # the monolithic compiled path IS the streamed core run on one
    # chunk — one code path, so chunked replay is bitwise-identical
    st = _TraceStream([cfg.smmu.tlb_entries])
    _stream_chunk([cfg], cp, [pl for pl, _ in sched.segments], foot,
                  host_s_per_elem, st)
    results, pers = _stream_results([cfg], st, foot)
    return results[0], pers[0]


# ===================================================================
# Config-batched pricing
# ===================================================================
# A design-space sweep prices the SAME compiled plan under many
# ``SystemConfig``s.  Everything trace-intrinsic (page interning, LRU
# stack distances, drain-group structure, barrier layout) is already
# shared through ``cp.memo``; what differs per config factors into a
# handful of row families — translation times (SMMU parameters),
# transfer times (datapath), group reductions (DMA engine), op amounts
# (SA) — and most sweep axes leave most families untouched.
# ``replay_batch`` therefore dedups each family (reusing the scalar
# helpers above row by row, so the per-config float operations are
# IDENTICAL to ``replay_compiled``'s), then evaluates the max-plus
# timeline recurrence once over a (configs × ops) matrix.

def _smmu_row_key(s: SMMU, foot: int) -> tuple:
    return ("smmu", s.tlb_entries, s.l2_entries, s.hit_cycles,
            s.l2_fill_cycles, s.freq, s.walk_cycles(foot))


def _path_row_key(cfg: SystemConfig) -> tuple:
    d = (cfg.dram.latency_ns, cfg.dram.bandwidth,
         cfg.dram.stream_efficiency)
    if cfg.mode == "DevMem":
        return ("DevMem", d)
    if cfg.mode == "DC":
        return ("DC", d, cfg.pcie.effective_bw, cfg.llc.capacity_pages,
                cfg.llc.hit_latency_ns, cfg.llc.hit_bw)
    return (cfg.mode, d, cfg.pcie.effective_bw)


def _dma_row_key(dma: DMAEngine) -> tuple:
    return ("dma", dma.descriptor_ns, dma.read_channels)


def _sa_row_key(sa: SystolicArray) -> tuple:
    return ("sa", sa.dtype, sa.w, sa.tile_w)


def _amount_row_key(cfg: SystemConfig) -> tuple:
    """Key of the SA/host/collective op-amount row: the SA variant plus
    the fabric (collective hops price on the fabric link)."""
    return (_sa_row_key(cfg.sa), cfg.fabric.row_key())


def _price_key(cfg: SystemConfig, foot: int) -> tuple:
    """Configs with equal keys produce identical results for any plan —
    the batch replays one representative per key."""
    return (_smmu_row_key(cfg.smmu, foot), _path_row_key(cfg),
            _dma_row_key(cfg.dma), _amount_row_key(cfg),
            cfg.dma.doorbell_ns, cfg.dma.interrupt_ns)


def _xlat_row(smmu: SMMU, cp, foot: int):
    """Per-access translation seconds + whole-trace (lookups, misses,
    walks) + the mask handles — ``SMMU.access_many``'s arithmetic
    without its state/counter side effects."""
    tlb_miss, miss_pos, walk_sub = smmu.tlb_walk_masks(cp.trace_ids,
                                                       cp.memo)
    cyc = np.full(cp.trace_ids.size, float(smmu.hit_cycles))
    cyc[miss_pos] += smmu.l2_fill_cycles
    cyc[miss_pos[walk_sub]] += smmu.walk_cycles(foot)
    stats = (int(cp.trace_ids.size), int(miss_pos.size),
             int(walk_sub.sum()))
    return cyc / smmu.freq, stats, (tlb_miss, miss_pos, walk_sub)


def _transfer_row(cfg: SystemConfig, cp, cache: dict = None) -> np.ndarray:
    """Per-access transfer seconds — ``_resolve_access_times``'s
    datapath arithmetic without touching the LLC object.  ``cache``
    (batched path) shares the per-access component arrays between path
    rows that differ only in one stage (e.g. LLC capacity)."""
    nb = cp.trace_nbytes
    if cache is None:
        cache = {}
    dbw = cfg.dram.bandwidth * cfg.dram.stream_efficiency
    mk = ("mem", cfg.dram.latency_ns, dbw)
    mem = cache.get(mk)
    if mem is None:
        mem = cache[mk] = cfg.dram.latency_ns * 1e-9 + nb / dbw
    if cfg.mode == "DevMem":
        return mem
    lk = ("link", cfg.pcie.effective_bw)
    link = cache.get(lk)
    if link is None:
        link = cache[lk] = nb / cfg.pcie.effective_bw
    lm = cache.get(("lm", lk, mk))
    if lm is None:
        lm = cache[("lm", lk, mk)] = link + mem
    if cfg.mode == "DC":
        prev, sd = _lru_trace_memo(cp.memo, cp.trace_ids)
        hit = (prev >= 0) & (sd < cfg.llc.capacity_pages)
        hk = ("llc", lk, cfg.llc.hit_latency_ns, cfg.llc.hit_bw)
        ht = cache.get(hk)
        if ht is None:
            llc_t = cfg.llc.hit_latency_ns * 1e-9 + nb / cfg.llc.hit_bw
            ht = cache[hk] = link * 0.25 + llc_t
        return np.where(hit, ht, lm)
    return lm


@dataclasses.dataclass
class _Rows:
    """One config's pricing rows — deduped, shared by reference.
    ``base`` (SA/host amounts, per SA key) and ``tc`` (DMA_OUT
    amounts, per path key) compose to ``val``; the plan path works on
    the components and leaves ``val`` unbuilt."""
    sk: tuple
    pk: tuple
    gk: tuple
    vk: tuple
    x: np.ndarray
    stats: tuple
    masks: tuple
    t: np.ndarray
    has_p: np.ndarray
    d: np.ndarray
    ready: np.ndarray
    base: np.ndarray
    tc: np.ndarray
    val: np.ndarray


def _batch_rows(cfgs, cp, foot: int, host_s_per_elem: float,
                need_val: bool = True,
                ready_carry: Optional[dict] = None) -> list:
    xrows: dict = {}
    trows: dict = {}
    grows: dict = {}
    vrows: dict = {}
    srows: dict = {}            # sk -> per-op translation sums
    prows: dict = {}            # pk -> per-op path sums
    brows: dict = {}            # sa key -> SA/host op-amount base
    drows: dict = {}            # dma key -> per-op descriptor time
    tinrows: dict = {}          # (dma, pk) -> masked input time
    sxmrows: dict = {}          # sk -> masked translation sum
    tcache: dict = {}
    out = []
    for cfg in cfgs:
        sk = _smmu_row_key(cfg.smmu, foot)
        pk = _path_row_key(cfg)
        gk = (sk, pk, _dma_row_key(cfg.dma))
        if sk not in xrows:
            xrows[sk] = _xlat_row(cfg.smmu, cp, foot)
            srows[sk] = _group_xlat_sum(cp, xrows[sk][0])
        if pk not in trows:
            trows[pk] = _transfer_row(cfg, cp, cache=tcache)
            prows[pk] = _group_path_sums(cp, trows[pk])
        x, stats, masks = xrows[sk]
        if gk not in grows:
            # ``_group_reduce``'s assembly, with the descriptor /
            # channel-limited-input / translation components shared at
            # their own key granularity (same float op order)
            npend, hp = _pending_counts(cp)
            dk = gk[2]
            d = drows.get(dk)
            if d is None:
                d = drows[dk] = (npend * cfg.dma.descriptor_time()
                                 / cfg.dma.read_channels)
            tinm = tinrows.get((dk, pk))
            if tinm is None:
                tot_t, lane_max, _ = prows[pk]
                tin = d + np.where(
                    cfg.dma.read_channels >= cp.n_lanes,
                    lane_max, tot_t)
                tinm = tinrows[dk, pk] = np.where(hp, tin, 0.0)
            sxm = sxmrows.get(sk)
            if sxm is None:
                sxm = sxmrows[sk] = np.where(hp, srows[sk], 0.0)
            if ready_carry is None:
                z = np.empty(2 * hp.size)
                z[0::2] = tinm
                z[1::2] = sxm
                ready = np.cumsum(z)[1::2]
            else:
                # continued cumsum: the carried partial sum becomes
                # the first element, so every addition happens in the
                # same left-to-right order as one monolithic cumsum —
                # the ready values (and the 0.0-carry first chunk)
                # stay bitwise identical to the unchunked pass
                z = np.empty(2 * hp.size + 1)
                z[0] = ready_carry.get(gk, 0.0)
                z[1::2] = tinm
                z[2::2] = sxm
                ready = np.cumsum(z)[2::2]
                if ready.size:
                    ready_carry[gk] = float(ready[-1])
            grows[gk] = (hp, d, srows[sk], ready, prows[pk][2])
        has_p, d, _, ready, _ = grows[gk]
        ak = _amount_row_key(cfg)
        vk = (ak, pk)
        if ak not in brows:
            brows[ak] = _op_amounts_base(cfg, cp, host_s_per_elem)
        if need_val and vk not in vrows:
            # tc depends only on the transfer row, so any gk with this
            # pk yields the same values
            vrows[vk] = _op_amounts(cfg, cp, prows[pk][2],
                                    host_s_per_elem, base=brows[ak])
        out.append(_Rows(sk, pk, gk, vk, x, stats, masks, trows[pk],
                         has_p, d, ready, brows[ak], prows[pk][2],
                         vrows.get(vk)))
    return out


def _run_ops_vec_batch(opk, has_p, ready, val, t_sa, t_out):
    """``_run_ops_vec`` with a leading config axis: the barrier layout
    (host ops / stream drains) is trace-intrinsic, so one pass over the
    segments prices every config at once — the per-segment closed forms
    become axis-1 cumulative sums, running maxima and gathers.  Per
    config row the float operations (and hence the results) are
    identical to the scalar vectorized recurrence."""
    B, n = val.shape
    tsa_a = np.empty((B, n))
    tout_a = np.empty((B, n))
    exp_a = np.zeros((B, n))
    t_sa = np.asarray(t_sa, np.float64).copy()
    t_out = np.asarray(t_out, np.float64).copy()
    barrier = np.nonzero((opk == P.OP_HOST) | (opk == P.OP_COLL)
                         | (opk == P.OP_TAIL))[0]
    starts = np.concatenate([[0], barrier + 1])
    ends = np.concatenate([barrier, [n]])
    for s0, s1 in zip(starts, ends):
        s0, s1 = int(s0), int(s1)
        if s1 > s0:
            k = opk[s0:s1]
            v = val[:, s0:s1]
            sa = np.nonzero(k == P.OP_SA)[0]
            out = np.nonzero(k == P.OP_OUT)[0]
            tsa_seg = None
            if sa.size:
                tiles = v[:, sa]
                pre = np.cumsum(tiles, axis=1)
                r = np.where(has_p[s0:s1][sa][None, :],
                             ready[:, s0:s1][:, sa], -np.inf)
                q = r - np.concatenate(
                    [np.zeros((B, 1)), pre[:, :-1]], axis=1)
                run = np.maximum.accumulate(q, axis=1)
                tsa_seg = pre + np.maximum(t_sa[:, None], run)
                prev_run = np.maximum(
                    t_sa[:, None],
                    np.concatenate([np.full((B, 1), -np.inf),
                                    run[:, :-1]], axis=1))
                exp_a[:, s0:s1][:, sa] = np.maximum(q - prev_run, 0.0)
            sa_cum = np.cumsum(k == P.OP_SA) - 1
            tsa_sl = np.where(
                sa_cum[None, :] >= 0,
                tsa_seg[:, np.maximum(sa_cum, 0)]
                if tsa_seg is not None else t_sa[:, None],
                t_sa[:, None])
            tout_seg = None
            if out.size:
                tcs = v[:, out]
                tcum = np.cumsum(tcs, axis=1)
                p = tsa_sl[:, out] - np.concatenate(
                    [np.zeros((B, 1)), tcum[:, :-1]], axis=1)
                tout_seg = tcum + np.maximum(
                    t_out[:, None], np.maximum.accumulate(p, axis=1))
            out_cum = np.cumsum(k == P.OP_OUT) - 1
            tout_sl = np.where(
                out_cum[None, :] >= 0,
                tout_seg[:, np.maximum(out_cum, 0)]
                if tout_seg is not None else t_out[:, None],
                t_out[:, None])
            tsa_a[:, s0:s1] = tsa_sl
            tout_a[:, s0:s1] = tout_sl
            t_sa = tsa_sl[:, -1].copy()
            t_out = tout_sl[:, -1].copy()
        if s1 < n:                           # the barrier op itself
            g = s1
            if has_p[g]:
                r = ready[:, g]
                m = r > t_sa
                exp_a[m, g] = (r - t_sa)[m]
                t_sa = np.where(m, r, t_sa)
            if opk[g] == P.OP_HOST or opk[g] == P.OP_COLL:
                t_sa = np.maximum(t_sa, t_out) + val[:, g]
            tsa_a[:, g] = t_sa
            tout_a[:, g] = t_out
    return tsa_a, tout_a, exp_a, t_sa, t_out


# ===================================================================
# Streaming chunked trace replay
# ===================================================================
# ``replay_trace`` materializes one CompiledPlan (plus its memoized
# stack-distance passes) for the whole trace — fine at 78k events,
# unaffordable at the multi-million-event traces an open-loop serving
# run produces.  The streamed path prices the trace chunk by chunk
# (chunks split only at plan boundaries, where the unconditional
# OP_TAIL pins a recurrence barrier) while carrying exact cross-chunk
# state: LRU stacks for the uTLB / L2-TLB / LLC (``LRUStreamState``
# prefix replay), the input-DMA ready cumsum per group key, the
# per-timeline (t_sa, t_out) max-plus frontier, and continued-cumsum
# bucket accumulators.  Every carried quantity reproduces the
# monolithic float operations in the same left-to-right order, so the
# results are bitwise identical to ``replay_trace`` at ANY chunk size
# while peak incremental allocations stay bounded by the chunk.

def _chain_sum(carry: float, arr: np.ndarray) -> float:
    """Left-to-right continued sum ``(((carry + a0) + a1) + ...)``.
    Unlike ``arr.sum()`` (pairwise), chaining per-chunk partial sums
    this way yields the same float no matter where the trace was
    chunked."""
    if arr.size == 0:
        return carry
    z = np.empty(arr.size + 1)
    z[0] = carry
    z[1:] = arr
    return float(np.cumsum(z)[-1])


class _TraceStream:
    """Cross-chunk carried state of one streamed trace replay."""

    def __init__(self, tes):
        self.lru = LRUStreamState()        # page-id LRU (uTLB + LLC)
        self.l2 = {te: LRUStreamState() for te in tes}
        self.tes = tes                     # distinct uTLB reaches
        self.ready = {}                    # gk -> ready cumsum carry
        self.tl = {}         # (gk, vk) -> [t_sa, t_out, last mks]
        self.keys = None                   # timeline key order
        self.chain = {}                    # bucket key -> chained sum
        self.stats = {}      # sk -> [lookups, misses, walks]
        self.n_out = 0
        self.n_events = 0
        self.macs = 0
        self.n_calls = []                  # per plan
        self.per = []        # per-chunk (timelines, plans) mks deltas


def _stream_seed_memo(cp, st: _TraceStream) -> None:
    """Seed a chunk's trace-intrinsic memo from the carried LRU state,
    so every downstream consumer (``tlb_walk_masks``, the LLC hit mask
    via ``_lru_trace_memo``) reads globally-exact prev/stack-distance
    arrays without knowing about chunking.  A no-op when the compile
    was already analyzed (the cached single-chunk path)."""
    if "prev" in cp.memo:
        return
    ids = cp.trace_ids
    prev, sd = st.lru.analyze(ids)
    cp.memo["prev"], cp.memo["sd"] = prev, sd
    for te in st.tes:
        miss = ~((prev >= 0) & (sd < te))
        mp = np.nonzero(miss)[0]
        sub_prev, sub_sd = st.l2[te].analyze(ids[mp])
        cp.memo[("l2", te)] = (mp, sub_prev, sub_sd)


def _stream_chunk(cfgs, cp, batch, foot: int, host_s_per_elem: float,
                  st: _TraceStream) -> None:
    """Price one compiled chunk for every config and fold the results
    into the carried accumulators."""
    _stream_seed_memo(cp, st)
    rows = _batch_rows(cfgs, cp, foot, host_s_per_elem,
                       ready_carry=st.ready)
    tl_idx, tl_rows = _unique_timelines(rows)
    keys = list(tl_idx)
    if st.keys is None:
        st.keys = keys
        for key in keys:
            st.tl[key] = [0.0, 0.0, 0.0]
    elif keys != st.keys:    # fixed cfgs+foot => chunk-invariant keys
        raise AssertionError("timeline keys changed across chunks")
    ready_m = np.stack([r.ready for r in tl_rows])
    val_m = np.stack([r.val for r in tl_rows])
    t_sa0 = np.array([st.tl[key][0] for key in keys])
    t_out0 = np.array([st.tl[key][1] for key in keys])
    tsa_a, tout_a, exp_a, tsa_f, tout_f = _run_ops_vec_batch(
        cp.op_kind, rows[0].has_p, ready_m, val_m, t_sa0, t_out0)
    # per-plan makespan deltas: every plan ends in an OP_TAIL barrier,
    # so chunk-local snapshots at plan bounds equal the monolithic ones
    mks = np.maximum(tsa_a, tout_a)
    mb = mks[:, cp.seg_op - 1]
    prevcol = np.array([st.tl[key][2] for key in keys])[:, None]
    st.per.append(np.diff(np.concatenate([prevcol, mb], axis=1),
                          axis=1))
    k = cp.op_kind
    done: set = set()
    for r in rows:
        tkey = (r.gk, r.vk)
        for key, arr in (
                (("c", r.vk[0]), r.base[k == P.OP_SA]),
                (("t", r.pk), r.t),
                (("d", r.gk), r.d[r.has_p]),
                (("x", r.sk), r.x),
                (("h",), r.base[k == P.OP_HOST]),
                (("l", r.vk[0]), r.base[k == P.OP_COLL]),
                (("e", tkey), exp_a[tl_idx[tkey]])):
            if key not in done:
                done.add(key)
                st.chain[key] = _chain_sum(st.chain.get(key, 0.0), arr)
        if ("s", r.sk) not in done:
            done.add(("s", r.sk))
            acc = st.stats.setdefault(r.sk, [0, 0, 0])
            for q in range(3):
                acc[q] += r.stats[q]
    for j, key in enumerate(keys):
        st.tl[key] = [float(tsa_f[j]), float(tout_f[j]),
                      float(mb[j, -1])]
    st.n_out += int((k == P.OP_OUT).sum())
    st.n_events += cp.n_events
    for pl in batch:
        st.macs += pl.macs
        st.n_calls.append(pl.n_calls)


def _stream_results(cfgs, st: _TraceStream, foot: int):
    """Per-config ``GemmResult``s + per-plan second arrays from a
    finished ``_TraceStream`` — the same assembly ``_result`` /
    ``_plan_batch_results`` perform, read off the carried
    accumulators."""
    per_all = np.concatenate(st.per, axis=1)
    n_calls = np.asarray(st.n_calls, np.float64)
    total_calls = int(n_calls.sum())
    tl_pos = {key: j for j, key in enumerate(st.keys)}
    results, pers = [], []
    for cfg in cfgs:
        sk = _smmu_row_key(cfg.smmu, foot)
        pk = _path_row_key(cfg)
        gk = (sk, pk, _dma_row_key(cfg.dma))
        vk = (_amount_row_key(cfg), pk)
        tkey = (gk, vk)
        tsa_f, tout_f, _ = st.tl[tkey]
        lk, ms, wk = st.stats[sk]
        ctrl_unit = (cfg.dma.doorbell_ns +
                     cfg.dma.interrupt_ns) * 1e-9
        results.append(GemmResult(
            total_s=max(tsa_f, tout_f) + total_calls * ctrl_unit,
            compute_s=st.chain[("c", vk[0])],
            transfer_s=st.chain[("t", pk)],
            exposed_transfer_s=st.chain[("e", tkey)],
            descriptor_s=st.chain[("d", gk)]
            + st.n_out * cfg.dma.descriptor_time(),
            translation_s=st.chain[("x", sk)],
            tlb_lookups=lk, tlb_misses=ms, ptw_walks=wk,
            macs=st.macs,
            host_s=st.chain[("h",)],
            drain_s=max(0.0, tout_f - tsa_f),
            coll_s=st.chain[("l", vk[0])]))
        pers.append(per_all[tl_pos[tkey]] + n_calls * ctrl_unit)
    return results, pers


def replay_trace_streamed(cfgs, plans,
                          host_s_per_elem: float = HOST_S_PER_ELEM,
                          footprint_pages: Optional[int] = None,
                          chunk_events: int = 262_144):
    """Price a (possibly very long) trace of plans in O(chunk) memory.

    ``cfgs`` is one ``SystemConfig`` or a sequence of them — every
    extra config reuses each chunk's trace analysis through the
    config-batched row dedup, so a DM/DC/DevMem sweep over a 10k-request
    trace costs one streaming pass.  ``plans`` is a sequence of
    repeat-1 ``StreamPlan``s, a repeat-1 ``PlanSchedule``, or a
    zero-argument callable returning a fresh plan iterable — the
    bounded-memory form: it is called once to measure the page
    footprint (skipped when ``footprint_pages`` is given) and once
    more to price, and at no point is more than one chunk of compiled
    arrays (plus the carried LRU state) live.

    Returns ``(results, per_plan)`` lists aligned with ``cfgs`` — or
    ``(result, per)`` when a single config was passed — bitwise
    identical to the monolithic ``replay_trace`` at ANY
    ``chunk_events`` (chunks split at plan boundaries; the carried
    LRU / ready / max-plus state reproduces the monolithic float
    operations in order)."""
    single = isinstance(cfgs, SystemConfig)
    cfg_list = [cfgs] if single else list(cfgs)
    if not cfg_list:
        raise ValueError("replay_trace_streamed() needs >= 1 config")
    if isinstance(plans, P.PlanSchedule):
        segs = plans.segments
        for pl, rep in segs:
            if rep != 1:
                raise ValueError(
                    f"replay_trace_streamed() needs repeat-1 "
                    f"segments, got ({pl.name}, {rep})")

        def factory():
            return (pl for pl, _ in segs)
    elif callable(plans):
        factory = plans
    else:
        seq = list(plans)

        def factory():
            return iter(seq)

    def checked():
        for pl in factory():
            if pl.sampled_steps != pl.total_steps:
                raise ValueError(
                    f"trace replay is exact; plan {pl.name} is "
                    "steady-state sampled")
            yield pl

    foot = footprint_pages if footprint_pages is not None \
        else P.trace_footprint(checked())
    # configs with equal price keys replay once, like replay_batch
    uniq: "OrderedDict[tuple, int]" = OrderedDict()
    slot = []
    reps = []
    for cfg in cfg_list:
        key = _price_key(cfg, foot)
        if key not in uniq:
            uniq[key] = len(reps)
            reps.append(cfg)
        slot.append(uniq[key])
    st = _TraceStream(sorted({c.smmu.tlb_entries for c in reps}))
    for cp, batch in P.compile_trace_chunks(checked(), chunk_events):
        _stream_chunk(reps, cp, batch, foot, host_s_per_elem, st)
    if st.keys is None:
        raise ValueError("replay_trace_streamed() needs >= 1 plan")
    rres, rper = _stream_results(reps, st, foot)
    results = [rres[s] if slot.count(s) == 1 else
               dataclasses.replace(rres[s]) for s in slot]
    pers = [rper[s] if slot.count(s) == 1 else rper[s].copy()
            for s in slot]
    if single:
        return results[0], pers[0]
    return results, pers


def _segment_bundle(cp):
    """Trace-intrinsic segment structure for the sums-only batched
    recurrence — barrier layout plus per-segment SA/OUT spans and the
    SA index preceding each OUT op — computed once per compiled plan
    and cached in its memo."""
    b = cp.memo.get("segb")
    if b is None:
        opk = cp.op_kind
        barrier = np.nonzero((opk == P.OP_HOST) | (opk == P.OP_COLL) |
                             (opk == P.OP_TAIL))[0]
        starts = np.concatenate([[0], barrier + 1])
        ends = np.concatenate([barrier, [opk.size]])
        sa_all = np.nonzero(opk == P.OP_SA)[0]
        out_all = np.nonzero(opk == P.OP_OUT)[0]
        cnt = np.cumsum(opk == P.OP_SA) - 1
        sa_lo = np.searchsorted(sa_all, starts)
        seg_of_out = np.searchsorted(starts, out_all,
                                     side="right") - 1
        idx_rel = cnt[out_all] - sa_lo[seg_of_out]
        b = (barrier, sa_all, out_all,
             sa_lo.tolist(), np.searchsorted(sa_all, ends).tolist(),
             np.searchsorted(out_all, starts).tolist(),
             np.searchsorted(out_all, ends).tolist(),
             np.maximum(idx_rel, 0), idx_rel < 0,
             ((opk[barrier] == P.OP_HOST) |
              (opk[barrier] == P.OP_COLL)).tolist())
        cp.memo["segb"] = b
    return b


_SCRATCH_POOL: dict = {}
_SCRATCH_CAP_BYTES = 512 << 20      # pool size that triggers a purge

if hasattr(os, "register_at_fork"):
    # sweep workers fork mid-sweep: the child must start with an empty
    # per-process pool instead of aliasing (copy-on-write) the parent's
    # peak scratch — its own release_scratch() then frees its own pages
    os.register_at_fork(after_in_child=_SCRATCH_POOL.clear)


def release_scratch() -> int:
    """Free the persistent batched-pricing scratch arrays and return
    the number of bytes released.  ``tune()`` / ``sweep_load()`` call
    this after their pricing phase so back-to-back searches don't hold
    each other's peak scratch; safe to call any time (the pool refills
    on demand)."""
    freed = sum(v.nbytes for v in _SCRATCH_POOL.values())
    _SCRATCH_POOL.clear()
    return freed


def _scratch(tag, shape):
    """Persistent scratch for the batched recurrence: the big
    (rows x positions) arrays exceed the allocator's mmap threshold,
    so reusing them across calls avoids a page-fault sweep per sweep.
    Callers fully overwrite every buffer they request.  The pool is
    bounded: allocating past ``_SCRATCH_CAP_BYTES`` purges it first
    (``release_scratch()`` frees it explicitly)."""
    a = _SCRATCH_POOL.get((tag, shape))
    if a is None:
        if sum(v.nbytes for v in _SCRATCH_POOL.values()) > \
                _SCRATCH_CAP_BYTES:
            _SCRATCH_POOL.clear()
        a = np.empty(shape)
        _SCRATCH_POOL[tag, shape] = a
    return a


def _run_ops_vec_batch_sums(cp, has_p, ready_rows, base_rows,
                            tc_rows, ir, ia, ip):
    """Sums-only leading-axis recurrence for the StreamPlan batch path.

    Same per-row float operations as ``_run_ops_vec`` (so per-config
    results match the sequential vectorized path), but materializes NO
    (rows × ops) trajectory arrays — only the exposed-transfer sum and
    the final timeline values each config needs.  SA/OUT positions are
    gathered globally once, so per-segment math runs on contiguous
    views; cumulative sums run on the unique component rows — op
    amounts at SA positions depend only on the SA key (``base_rows``),
    at OUT positions only on the path key (``tc_rows``), and at
    barrier ops on neither — and expand to the ``B`` timeline rows
    (``ir``/``ia``/``ip`` index maps) only for the coupled recurrence
    terms, keeping working sets cache-resident."""
    (barrier, sa_all, out_all, sa_lo, sa_hi, out_lo, out_hi,
     idx_clip, idx_neg, bar_host) = _segment_bundle(cp)
    A, Pk, R = len(base_rows), len(tc_rows), len(ready_rows)
    buf = _scratch
    base_sa = buf("base_sa", (A, sa_all.size))
    tc_out = buf("tc_out", (Pk, out_all.size))
    readys_sa = buf("readys_sa", (R, sa_all.size))
    for j, v in enumerate(base_rows):
        np.take(v, sa_all, out=base_sa[j])
    for j, v in enumerate(tc_rows):
        np.take(v, out_all, out=tc_out[j])
    for j, r in enumerate(ready_rows):
        np.take(r, sa_all, out=readys_sa[j])
    readys_sa[:, ~has_p[sa_all]] = -np.inf   # where(has_p, ready, -inf)
    B = ir.size
    n_sa = sa_all.size
    # prefix sums of the SA op amounts, restarted at each barrier,
    # materialized once over the full (compact) SA stream
    pre_full = buf("pre_full", (A, n_sa))
    sa_starts = []
    for i in range(len(sa_lo)):
        a0, a1 = sa_lo[i], sa_hi[i]
        if a1 > a0:
            sa_starts.append(a0)
            np.cumsum(base_sa[:, a0:a1], axis=1,
                      out=pre_full[:, a0:a1])
    sa_starts = np.asarray(sa_starts, dtype=np.int64)
    # fused expand + pre-subtraction into timeline rows: each column
    # is ready minus the prefix sum up to its previous SA op;
    # segment-start columns (no predecessor) keep the plain ready.
    # Per-segment views of this are consumed exactly once, in place.
    q_all = buf("q_all", (B, n_sa))
    for j in range(B):
        np.subtract(readys_sa[ir[j], 1:], pre_full[ia[j], :-1],
                    out=q_all[j, 1:])
        q_all[j, sa_starts] = readys_sa[ir[j], sa_starts]
    # barrier-op amounts are path independent but DO vary with the
    # amount row (collective hops price per fabric): expand per
    # timeline row via the base index map
    bar_val = np.stack([b[barrier] for b in base_rows])[ia]
    readys_bar = np.stack([r[barrier] for r in ready_rows])[ir]
    hp_bar = has_p[barrier].tolist()
    t_sa = np.zeros(B)
    t_out = np.zeros(B)
    exp_sum = np.zeros(B)

    # ``ia`` is sorted, so rows sharing a base cumsum row form
    # contiguous blocks the segment math can broadcast over
    blocks = []
    s = 0
    for j in range(1, B + 1):
        if j == B or ia[j] != ia[s]:
            blocks.append((s, j, int(ia[s])))
            s = j
    for i in range(len(sa_lo)):
        a0, a1 = sa_lo[i], sa_hi[i]
        o0, o1 = out_lo[i], out_hi[i]
        run = None
        if a1 > a0:
            m = a1 - a0
            q = q_all[:, a0:a1]
            # seeding col 0 with max(q_0, t_sa) makes the running max
            # max(t_sa, run) directly; the SA completion times are
            # pre + run, whose one-step increments are exactly the
            # exposed-transfer terms max(q_i - max(t_sa, run_{i-1}), 0)
            np.maximum(q[:, 0], t_sa, out=q[:, 0])
            run = np.maximum.accumulate(q, axis=1, out=q)
            e = buf("e", (B, m))
            np.subtract(run[:, 0], t_sa, out=e[:, 0])
            np.subtract(run[:, 1:], run[:, :-1], out=e[:, 1:])
            exp_sum += e.sum(axis=1)
            # pre + run is only ever read at the DMA_OUT wait columns
            # and the final column — gather there instead of another
            # full (rows x m) pass
        if o1 > o0:
            mo = o1 - o0
            tcum_u = np.cumsum(tc_out[:, o0:o1], axis=1,
                               out=buf("tcu", (Pk, mo)))
            tcum = np.take(tcum_u, ip, axis=0,
                           out=buf("tc", (B, mo)))
            p = buf("p", (B, mo))
            if run is not None:
                idx = a0 + idx_clip[o0:o1]
                np.take(run, idx_clip[o0:o1], axis=1, out=p)
                pre_idx = np.take(pre_full, idx, axis=1,
                                  out=buf("pre_idx", (A, mo)))
                for g0, g1, a in blocks:
                    p[g0:g1] += pre_idx[a]
                np.copyto(p, t_sa[:, None],
                          where=idx_neg[None, o0:o1])
            else:
                np.copyto(p, t_sa[:, None])
            p[:, 1:] -= tcum[:, :-1]         # p[:, 0] -= 0.0 is a no-op
            t_out = tcum[:, -1] + np.maximum(t_out, p.max(axis=1))
        if run is not None:
            t_sa = run[:, -1].copy()
            for g0, g1, a in blocks:
                t_sa[g0:g1] += pre_full[a, a1 - 1]
        if i < barrier.size:                 # the barrier op itself
            if hp_bar[i]:
                r = readys_bar[:, i]
                m = r > t_sa
                exp_sum += np.where(m, r - t_sa, 0.0)
                t_sa = np.where(m, r, t_sa)
            if bar_host[i]:
                t_sa = np.maximum(t_sa, t_out) + bar_val[:, i]
    return exp_sum, t_sa, t_out


def _unique_timelines(rows):
    """Configs sharing (group, op-amount) rows share one recurrence."""
    tl_idx: "OrderedDict[tuple, int]" = OrderedDict()
    tl_rows = []
    for r in rows:
        key = (r.gk, r.vk)
        if key not in tl_idx:
            tl_idx[key] = len(tl_rows)
            tl_rows.append(r)
    return tl_idx, tl_rows


def _unique_rows(tl_rows):
    """The unique ready (by group key), SA/host-amount (by SA key) and
    DMA_OUT-amount (by path key) rows among the timeline rows — kept
    as row lists; the recurrence gathers just the positions it needs —
    plus per-timeline index maps."""
    gk_ix: dict = {}
    ak_ix: dict = {}
    pk_ix: dict = {}
    ready_rows: list = []
    base_rows: list = []
    tc_rows: list = []
    ir, ia, ip = [], [], []
    for r in tl_rows:
        ak = r.vk[0]
        if r.gk not in gk_ix:
            gk_ix[r.gk] = len(ready_rows)
            ready_rows.append(r.ready)
        if ak not in ak_ix:
            ak_ix[ak] = len(base_rows)
            base_rows.append(r.base)
        if r.pk not in pk_ix:
            pk_ix[r.pk] = len(tc_rows)
            tc_rows.append(r.tc)
        ir.append(gk_ix[r.gk])
        ia.append(ak_ix[ak])
        ip.append(pk_ix[r.pk])
    return (ready_rows, base_rows, tc_rows, np.asarray(ir),
            np.asarray(ia), np.asarray(ip))


def _plan_batch_results(cfgs, rows, plan, cp, max_chunk_elems):
    k = cp.op_kind
    n_ops = int(k.size)
    scale = plan.total_steps / max(plan.sampled_steps, 1) \
        if plan.total_steps else 1.0
    n_out = int((k == P.OP_OUT).sum())
    has_p = rows[0].has_p
    _, tl_rows = _unique_timelines(rows)
    # group timelines sharing an SA base row so the recurrence can
    # broadcast each unique cumsum row over a contiguous row block
    tl_rows.sort(key=lambda r: r.vk[0])
    tl_idx = {(r.gk, r.vk): j for j, r in enumerate(tl_rows)}
    ready_rows, base_rows, tc_rows, ir_all, ia_all, ip_all = \
        _unique_rows(tl_rows)
    exp_sum = np.empty(len(tl_rows))
    t_sa = np.empty(len(tl_rows))
    t_out = np.empty(len(tl_rows))
    chunk = max(1, max_chunk_elems // max(n_ops, 1))
    for lo in range(0, len(tl_rows), chunk):
        B = len(tl_rows[lo:lo + chunk])
        es, ts, to = _run_ops_vec_batch_sums(
            cp, has_p, ready_rows, base_rows, tc_rows,
            ir_all[lo:lo + B], ia_all[lo:lo + B], ip_all[lo:lo + B])
        exp_sum[lo:lo + B] = es
        t_sa[lo:lo + B] = ts
        t_out[lo:lo + B] = to
    sums: dict = {}

    def row_sum(key, arr, mask=None):
        if key not in sums:
            sums[key] = float(arr.sum()) if mask is None \
                else float(arr[mask].sum())
        return sums[key]

    results = []
    for cfg, r in zip(cfgs, rows):
        ti = tl_idx[(r.gk, r.vk)]
        tsa_f, tout_f = float(t_sa[ti]), float(t_out[ti])
        lk, ms, wk = r.stats
        control = plan.n_calls * (cfg.dma.doorbell_ns +
                                  cfg.dma.interrupt_ns) * 1e-9
        results.append(GemmResult(
            total_s=max(tsa_f, tout_f) * scale + control,
            compute_s=row_sum(("c", r.vk[0]), r.base,
                              k == P.OP_SA) * scale,
            transfer_s=row_sum(("t", r.pk), r.t) * scale,
            exposed_transfer_s=float(exp_sum[ti]) * scale,
            descriptor_s=(row_sum(("d", r.gk), r.d, r.has_p)
                          + n_out * cfg.dma.descriptor_time()) * scale,
            translation_s=row_sum(("x", r.sk), r.x) * scale,
            tlb_lookups=int(lk * scale), tlb_misses=int(ms * scale),
            ptw_walks=int(wk * scale), macs=plan.macs,
            host_s=row_sum(("h",), r.base, k == P.OP_HOST) * scale,
            drain_s=max(0.0, tout_f - tsa_f) * scale,
            coll_s=row_sum(("l", r.vk[0]), r.base,
                           k == P.OP_COLL) * scale))
    return results


def _schedule_batch_results(cfgs, rows, sched, cp, max_chunk_elems):
    k = cp.op_kind
    n_ops = int(k.size)
    multi = any(rep > 1 for _, rep in sched.segments)
    has_p = rows[0].has_p
    if multi:
        k2 = np.concatenate([k, k])
        has_p2 = np.concatenate([has_p, has_p])
    else:
        k2, has_p2 = k, has_p
    bounds2 = np.concatenate([[0], cp.seg_op]) if not multi else \
        np.concatenate([[0], cp.seg_op, n_ops + cp.seg_op])

    def cum_at(per_item, bounds):
        c = np.concatenate([[0.0], np.cumsum(per_item)])
        return c[np.concatenate([[0], bounds])]

    look_c = np.concatenate([[0], cp.seg_trace]).astype(np.float64)
    tl_idx, tl_rows = _unique_timelines(rows)
    nb2 = int(bounds2.size)
    tsa_s = np.empty((len(tl_rows), nb2))
    tout_s = np.empty((len(tl_rows), nb2))
    exp_s = np.empty((len(tl_rows), nb2))
    n2 = 2 * n_ops if multi else n_ops
    chunk = max(1, max_chunk_elems // max(n2, 1))
    for lo in range(0, len(tl_rows), chunk):
        sub = tl_rows[lo:lo + chunk]
        B = len(sub)
        if multi:   # pass 1 = same ops, timeline continues
            ready = np.stack(
                [np.concatenate(
                    [r.ready,
                     r.ready + (r.ready[-1] if n_ops else 0.0)])
                 for r in sub])
            val = np.stack([np.concatenate([r.val, r.val])
                            for r in sub])
        else:
            ready = np.stack([r.ready for r in sub])
            val = np.stack([r.val for r in sub])
        tsa_a, tout_a, exp_a, _, _ = _run_ops_vec_batch(
            k2, has_p2, ready, val, np.zeros(B), np.zeros(B))
        z = np.zeros((B, 1))
        tsa_s[lo:lo + B] = np.concatenate([z, tsa_a],
                                          axis=1)[:, bounds2]
        tout_s[lo:lo + B] = np.concatenate([z, tout_a],
                                           axis=1)[:, bounds2]
        exp_s[lo:lo + B] = np.concatenate(
            [z, np.cumsum(exp_a, axis=1)], axis=1)[:, bounds2]
    cums: dict = {}

    def row_cum(key, fn):
        if key not in cums:
            cums[key] = fn()
        return cums[key]

    nseg = len(sched.segments)
    results = []
    for cfg, r in zip(cfgs, rows):
        ti = tl_idx[(r.gk, r.vk)]
        tsa_r, tout_r = tsa_s[ti], tout_s[ti]
        mks_s = np.maximum(tsa_r, tout_r)
        drain_snap = np.maximum(0.0, tout_r - tsa_r)
        exp_r = exp_s[ti]
        comp_c = row_cum(("c", r.vk), lambda: cum_at(
            np.where(k == P.OP_SA, r.val, 0.0), cp.seg_op))
        host_c = row_cum(("h", r.vk), lambda: cum_at(
            np.where(k == P.OP_HOST, r.val, 0.0), cp.seg_op))
        coll_c = row_cum(("l", r.vk), lambda: cum_at(
            np.where(k == P.OP_COLL, r.val, 0.0), cp.seg_op))
        desc_c = row_cum(("d", r.gk), lambda: cum_at(
            np.where(r.has_p, r.d, 0.0)
            + np.where(k == P.OP_OUT, cfg.dma.descriptor_time(), 0.0),
            cp.seg_op))
        xfer_c = row_cum(("t", r.pk),
                         lambda: cum_at(r.t, cp.seg_trace))
        trans_c = row_cum(("x", r.sk),
                          lambda: cum_at(r.x, cp.seg_trace))

        def miss_walk():
            tlb_miss, miss_pos, walk_sub = r.masks
            walk_mask = np.zeros(cp.trace_ids.size, bool)
            walk_mask[miss_pos[walk_sub]] = True
            return (cum_at(tlb_miss.astype(np.float64), cp.seg_trace),
                    cum_at(walk_mask.astype(np.float64), cp.seg_trace))

        miss_c, walk_c = row_cum(("mw", r.sk), miss_walk)

        def seg_delta(pass_no, si, pl):
            tb = pass_no * nseg + si    # timeline boundary index
            return (mks_s[tb + 1] - mks_s[tb],
                    comp_c[si + 1] - comp_c[si],
                    xfer_c[si + 1] - xfer_c[si],
                    exp_r[tb + 1] - exp_r[tb],
                    desc_c[si + 1] - desc_c[si],
                    trans_c[si + 1] - trans_c[si],
                    host_c[si + 1] - host_c[si],
                    coll_c[si + 1] - coll_c[si],
                    drain_snap[tb + 1] - drain_snap[tb],
                    look_c[si + 1] - look_c[si],
                    miss_c[si + 1] - miss_c[si],
                    walk_c[si + 1] - walk_c[si])

        acc, control, macs = _schedule_passes(
            (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns) * 1e-9,
            sched.segments, seg_delta)
        results.append(_passes_result(acc, control, macs))
    return results


def replay_batch(cfgs, plan,
                 host_s_per_elem: float = HOST_S_PER_ELEM,
                 footprint_pages: Optional[int] = None,
                 max_chunk_elems: int = 32_000_000) -> list:
    """Price a batch of ``SystemConfig``s against ONE plan (or
    ``PlanSchedule``) in a single vectorized pass.

    Returns one ``GemmResult`` per config, in order, equal to what a
    sequential ``replay_compiled(cfg, plan)`` sweep returns (the
    per-config float operations are the same, so parity holds to
    rtol<=1e-9 on every field — asserted by the property suite).
    Pricing is PURE: unlike the sequential entry points the configs'
    SMMU/LLC objects are neither reset nor mutated, and the
    trace-intrinsic analysis cached on ``plan.compile().memo`` is
    shared across all of them.  ``max_chunk_elems`` bounds the
    (configs × ops) work matrices, chunking very large sweeps."""
    cfgs = list(cfgs)
    if not cfgs:
        return []
    cp = plan.compile()
    foot = plan.footprint_pages if footprint_pages is None \
        else footprint_pages
    # full-result dedup: a structured grid varies one knob at a time,
    # so many configs price identically — replay one representative
    uniq: "OrderedDict[tuple, int]" = OrderedDict()
    reps: list = []
    slot = []
    for cfg in cfgs:
        key = _price_key(cfg, foot)
        if key not in uniq:
            uniq[key] = len(reps)
            reps.append(cfg)
        slot.append(uniq[key])
    sched = isinstance(plan, P.PlanSchedule)
    rows = _batch_rows(reps, cp, foot, host_s_per_elem,
                       need_val=sched)
    if sched:
        ures = _schedule_batch_results(reps, rows, plan, cp,
                                       max_chunk_elems)
    else:
        ures = _plan_batch_results(reps, rows, plan, cp,
                                   max_chunk_elems)
    return [dataclasses.replace(ures[s]) for s in slot]


def simulate_gemm(cfg: SystemConfig, M: int, N: int, K: int,
                  dtype: Optional[str] = None,
                  max_steps: int = 400_000,
                  engine: Optional[str] = None) -> GemmResult:
    """Replay Algorithm 1 for one GEMM.  For very large problems the
    plan is built steady-state-sampled and scaled.  The plan itself is
    memoized (``gemm_plan_cached``) so benchmark sweeps stop rebuilding
    identical loop nests row after row."""
    dtype = dtype or cfg.sa.dtype
    np_name = P.np_dtype_for(dtype)
    counts = streaming.tile_counts(M, N, K, np_name,
                                   page_bytes=cfg.page_bytes)
    stride = max(1, counts["inner_steps"] // max_steps)
    plan = P.gemm_plan_cached(M, N, K, np_name,
                              page_bytes=cfg.page_bytes,
                              sample_stride=stride)
    return replay(cfg, plan, engine=engine)
