"""Gem5-AcceSys analogue — component models.

Cycle-calibrated (not cycle-accurate) models of every box in the paper's
Fig. 1: the PCIe link with TLP packetization, the multi-channel DMA
engine, the SMMU (64-entry TLB + page walker), DRAM technologies
(Table 7), the LLC for DC mode, and the MatrixFlow systolic array
(Table 6). The pipeline simulator in ``pipeline.py`` composes them over
the tile schedule from ``core.streaming``.
"""
from __future__ import annotations

import collections
import dataclasses
import math

# ----------------------------------------------------------------- SA
# Table 6 (post-synthesis PPA; fixed-point @1 GHz, floating @0.6 GHz)
SA_VARIANTS = {
    # name: (freq_hz, area_um2, power_mw, peak_gops)
    ("int8", 4): (1.0e9, 16_186, 7.464, 32.0),
    ("int8", 16): (1.0e9, 186_875, 84.550, 512.0),
    ("int16", 4): (1.0e9, 24_989, 11.813, 32.0),
    ("int16", 16): (1.0e9, 397_558, 149.419, 512.0),
    ("int32", 4): (1.0e9, 73_483, 33.302, 32.0),
    ("int32", 16): (1.0e9, 1_163_841, 392.978, 512.0),
    ("fp8", 4): (0.6e9, 8_806, 2.251, 19.2),
    ("fp8", 16): (0.6e9, 142_816, 34.557, 307.2),
    ("fp16", 4): (0.6e9, 22_802, 5.580, 19.2),
    ("fp16", 16): (0.6e9, 363_805, 83.655, 307.2),
    ("fp32", 4): (0.6e9, 62_693, 16.938, 19.2),
    ("fp32", 16): (0.6e9, 1_032_820, 258.173, 307.2),
}

DTYPE_BYTES = {"int8": 1, "int16": 2, "int32": 4,
               "fp8": 1, "fp16": 2, "fp32": 4}


@dataclasses.dataclass(frozen=True)
class SystolicArray:
    dtype: str = "int8"
    w: int = 16

    @property
    def freq(self) -> float:
        return SA_VARIANTS[(self.dtype, self.w)][0]

    @property
    def peak_gops(self) -> float:
        return SA_VARIANTS[(self.dtype, self.w)][3]

    def tile_cycles(self, l: int) -> int:
        """Output-stationary W×W tile over depth l: l + fill/drain."""
        return l + 2 * (self.w - 1)

    def tile_time(self, l: int) -> float:
        return self.tile_cycles(l) / self.freq


# ---------------------------------------------------------------- PCIe
@dataclasses.dataclass(frozen=True)
class PCIeLink:
    """lanes × gbps_per_lane with TLP packetization effects (Fig. 10).

    efficiency(packet): payload / (payload + header) captures the 64 B
    penalty; an on-chip TLP pipeline depth limits outstanding packets, so
    very large TLPs (4096 B) stall the pipeline when serialization time
    exceeds the window — worst at low link speeds (paper: +36 %)."""
    lanes: int = 16
    gbps_per_lane: float = 64.0      # Gen6 ×16 = 128 GB/s (paper baseline)
    packet_bytes: int = 256
    header_bytes: int = 26          # TLP+DLLP+framing overhead
    pipeline_ns: float = 180.0      # per-TLP processing window
    encoding: float = 128.0 / 130.0

    @property
    def raw_bw(self) -> float:      # B/s, one direction
        return self.lanes * self.gbps_per_lane * 1e9 / 8 * self.encoding

    def efficiency(self) -> float:
        p = self.packet_bytes
        payload_eff = p / (p + self.header_bytes)
        # serialization of one TLP vs the pipeline window: once a packet
        # takes longer than the window, the link pipeline bubbles
        ser_ns = (p + self.header_bytes) / self.raw_bw * 1e9
        stall = max(0.0, ser_ns - self.pipeline_ns) / max(ser_ns, 1e-9)
        return payload_eff * (1.0 - 0.55 * stall)

    @property
    def effective_bw(self) -> float:
        return self.raw_bw * self.efficiency()

    def transfer_time(self, nbytes: int) -> float:
        return nbytes / self.effective_bw


# ---------------------------------------------------------------- DRAM
# Table 7: tech -> (channels, data_width_bits, bandwidth B/s, data rate)
DRAM_TECH = {
    "DDR3": (1, 64, 12.8e9, 1600),
    "DDR4": (1, 64, 19.2e9, 2400),
    "DDR5": (2, 32, 25.6e9, 3200),
    "GDDR6": (2, 64, 32.0e9, 2000),
    "HBM2": (2, 128, 64.0e9, 2000),
}


@dataclasses.dataclass(frozen=True)
class DRAM:
    tech: str = "DDR3"
    latency_ns: float = 12.0
    stream_efficiency: float = 0.87     # bank/queueing losses on bursts

    @property
    def bandwidth(self) -> float:
        return DRAM_TECH[self.tech][2]

    def transfer_time(self, nbytes: int) -> float:
        return self.latency_ns * 1e-9 + \
            nbytes / (self.bandwidth * self.stream_efficiency)


# ---------------------------------------------------------------- SMMU
@dataclasses.dataclass
class SMMU:
    """Two-level TLB + walk cache + page walker (Table 8).

    The 64-entry uTLB backs onto a larger L2 TLB: most uTLB misses fill
    from L2 / the walk cache in ~10–25 cycles (the paper's mean
    translation times), and only L2 misses pay a full multi-level walk
    (~180–368 cycles, deeper as the footprint outgrows the reach)."""
    tlb_entries: int = 64
    l2_entries: int = 8192
    l2_fill_cycles: float = 12.0
    base_walk_cycles: float = 180.0     # few-page working sets
    deep_walk_cycles: float = 368.0     # >reach thrash regime
    freq: float = 1.0e9
    hit_cycles: float = 1.0

    def __post_init__(self):
        self._tlb: "collections.OrderedDict" = collections.OrderedDict()
        self._l2: "collections.OrderedDict" = collections.OrderedDict()
        self.lookups = 0
        self.misses = 0
        self.walks = 0

    def reset(self):
        self._tlb.clear()
        self._l2.clear()
        self.lookups = self.misses = self.walks = 0

    def walk_cycles(self, footprint_pages: int) -> float:
        if footprint_pages <= self.l2_entries:
            return self.base_walk_cycles
        scale = min(1.0, math.log2(footprint_pages / self.l2_entries) / 3.0)
        return self.base_walk_cycles + scale * (self.deep_walk_cycles -
                                                self.base_walk_cycles)

    def _touch(self, cache, key, cap) -> bool:
        if key in cache:
            cache.move_to_end(key)
            return True
        cache[key] = True
        while len(cache) > cap:
            cache.popitem(last=False)
        return False

    def access(self, page_id, footprint_pages: int) -> float:
        """Translate one page access; returns seconds."""
        self.lookups += 1
        if self._touch(self._tlb, page_id, self.tlb_entries):
            return self.hit_cycles / self.freq
        self.misses += 1
        if self._touch(self._l2, page_id, self.l2_entries):
            return (self.hit_cycles + self.l2_fill_cycles) / self.freq
        self.walks += 1
        return (self.hit_cycles + self.l2_fill_cycles +
                self.walk_cycles(footprint_pages)) / self.freq


# ---------------------------------------------------------------- DMA
@dataclasses.dataclass(frozen=True)
class DMAEngine:
    read_channels: int = 2
    write_channels: int = 2
    burst_bytes: int = 1024
    descriptor_ns: float = 45.0     # enqueue+fetch one descriptor
    doorbell_ns: float = 400.0      # MMIO write (per offloaded call)
    interrupt_ns: float = 4000.0    # MSI + IRQ + driver completion

    def descriptor_time(self) -> float:
        return self.descriptor_ns * 1e-9


# ---------------------------------------------------------------- LLC
@dataclasses.dataclass
class LLC:
    """Shared last-level cache for DC mode, page-granular LRU."""
    size_bytes: int = 2 * 1024 * 1024
    page_bytes: int = 4096
    hit_latency_ns: float = 18.0
    hit_bw: float = 64e9

    def __post_init__(self):
        self._lru: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    @property
    def capacity_pages(self) -> int:
        return self.size_bytes // self.page_bytes

    def reset(self):
        self._lru.clear()
        self.hits = self.misses = 0

    def access(self, page_id) -> bool:
        """Returns hit?"""
        if page_id in self._lru:
            self.hits += 1
            self._lru.move_to_end(page_id)
            return True
        self.misses += 1
        self._lru[page_id] = True
        while len(self._lru) > self.capacity_pages:
            self._lru.popitem(last=False)
        return False

    def hit_time(self, nbytes: int) -> float:
        return self.hit_latency_ns * 1e-9 + nbytes / self.hit_bw
