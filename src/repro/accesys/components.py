"""Gem5-AcceSys analogue — component models.

Cycle-calibrated (not cycle-accurate) models of every box in the paper's
Fig. 1: the PCIe link with TLP packetization, the multi-channel DMA
engine, the SMMU (64-entry TLB + page walker), DRAM technologies
(Table 7), the LLC for DC mode, and the MatrixFlow systolic array
(Table 6). The pipeline simulator in ``pipeline.py`` composes them over
the tile schedule from ``core.streaming``.
"""
from __future__ import annotations

import collections
import dataclasses
import math

import numpy as np


# ---------------------------------------------------- batch LRU machinery
def prev_occurrence(ids: np.ndarray) -> np.ndarray:
    """``prev[i]`` = index of the previous access to ``ids[i]`` in the
    trace (-1 for a first access).  Vectorized: a stable argsort groups
    equal ids in access order, so each access's predecessor is its left
    neighbour within its group."""
    n = int(ids.size)
    prev = np.full(n, -1, np.int64)
    if n == 0:
        return prev
    order = np.argsort(ids, kind="stable")
    si = ids[order]
    same = si[1:] == si[:-1]
    prev[order[1:][same]] = order[:-1][same]
    return prev


def lru_stack_distances(prev: np.ndarray) -> np.ndarray:
    """Exact LRU stack distance of every access, in one vectorized
    divide-and-conquer pass over the trace.

    ``sd[i]`` = number of DISTINCT ids touched strictly between access
    ``i`` and the previous access to the same id (``n`` — effectively
    infinite — for first accesses).  A move-to-front LRU of capacity C
    hits access ``i`` iff ``prev[i] >= 0 and sd[i] < C``, so this one
    trace-intrinsic pass prices every cache in the hierarchy (uTLB,
    L2 TLB, LLC) at once.

    Method: an access ``j`` is the first in-window touch of its id iff
    its own previous access predates the window, so
    ``sd[i] = #{j in (prev[i], i) : prev[j] <= prev[i]}``.  That count
    is accumulated by merge-sort D&C over ``prev``: at each level every
    still-active access sitting in the right half of its pair-block
    ranks, via one global ``searchsorted`` into the block-sorted left
    halves, the in-window ``j`` it gains from the left half.  The level
    at which the window start enters the block is an access's last
    contribution (the ``j <= prev[i]`` overcount is subtracted in
    closed form — every such ``j`` satisfies ``prev[j] < j``), so the
    active set shrinks as reuse distances resolve and the sweep stops
    as soon as none remain.
    """
    n = int(prev.size)
    sd = np.full(n, n, np.int64)
    if n == 0:
        return sd
    act = np.nonzero(prev >= 0)[0]
    sd[act] = 0
    if act.size == 0:
        return sd
    nbits = max(1, int(n - 1).bit_length())
    size = 1 << nbits
    big = np.int64(n + 2)
    a = np.full(size, n + 1, np.int32)          # sort keys; pad = +inf
    a[:n] = (prev + 1).astype(np.int32)
    thr = prev[act] + 1
    for lev in range(nbits):
        block = np.int64(1 << lev)
        pair = block << 1
        on_right = (act & block) != 0
        if np.any(on_right):
            q = act[on_right]
            pid = q >> (lev + 1)
            if block <= 16:
                # tiny left blocks: rank by direct gathered compares —
                # cheaper than a global searchsorted at the dense levels
                gath = a.reshape(-1, pair)[:, :block][pid]
                cnt = (gath <= thr[on_right][:, None]).sum(
                    axis=1, dtype=np.int64)
            else:
                left = a.reshape(-1, pair)[:, :block].astype(np.int64)
                left += (np.arange(left.shape[0], dtype=np.int64)
                         * big)[:, None]
                cnt = np.searchsorted(left.ravel(),
                                      pid * big + thr[on_right],
                                      side="right") - pid * block
            pstart = q & ~(pair - 1)
            pq = prev[q]
            crossed = pq >= pstart
            cnt[crossed] -= pq[crossed] - pstart[crossed] + 1
            sd[q] += cnt
            live = ~crossed
            act = np.concatenate([act[~on_right], q[live]])
            thr = np.concatenate([thr[~on_right], thr[on_right][live]])
            if act.size == 0:
                break                            # all reuses resolved
        a = np.sort(a.reshape(-1, int(pair)), axis=1,
                    kind="stable").ravel()
    return sd


def _lru_trace_memo(memo, ids):
    """Trace-intrinsic (parameter-independent) prev/stack-distance
    arrays, cached in ``memo`` across replays of the same trace."""
    if "prev" not in memo:
        memo["prev"] = prev_occurrence(ids)
        memo["sd"] = lru_stack_distances(memo["prev"])
    return memo["prev"], memo["sd"]


def _mru_ids(memo, key, ids):
    """Distinct ids of a trace ordered oldest-to-newest by last touch —
    trace-intrinsic, so cached in ``memo`` like the stack distances."""
    if key not in memo:
        uniq, ridx = np.unique(ids[::-1], return_index=True)
        memo[key] = uniq[np.argsort(ids.size - 1 - ridx)]
    return memo[key]


def _rebuild_lru_state(od, mru, keys, cap):
    """Reconstruct the OrderedDict an equivalent sequential sweep would
    leave behind: the ``cap`` most-recently-used distinct ids, oldest
    first."""
    od.clear()
    if keys is None:
        return
    for pid in mru[-cap:].tolist():
        od[keys[pid]] = True


def _mru_of(ids):
    """Distinct ids of ``ids`` ordered LRU -> MRU (oldest last touch
    first) — the complete LRU state any capacity's stack leaves behind."""
    if ids.size == 0:
        return np.empty(0, ids.dtype)
    uniq, ridx = np.unique(ids[::-1], return_index=True)
    return uniq[np.argsort(ids.size - 1 - ridx)]


class LRUStreamState:
    """Resumable exact LRU analysis for a trace processed in chunks.

    Carries the distinct ids seen so far in LRU->MRU order.  For each
    chunk, the carried ids are prefix-replayed in front of the chunk
    (one access each, oldest first): any chunk access whose previous
    occurrence falls before the chunk start then hits its carried id at
    exactly the stack distance the monolithic trace would have produced
    (the carried prefix IS the LRU stack at the chunk boundary, so the
    distinct-ids-since-last-touch count is preserved for EVERY
    capacity at once).  First-ever accesses keep ``prev == -1``.  The
    per-chunk ``(prev, sd)`` slices are therefore bitwise-equal to the
    corresponding slices of a single whole-trace analysis wherever a
    consumer tests ``(prev >= 0) & (sd < capacity)`` — prev indices
    that point into the replayed prefix stay ``>= 0``, which is all the
    hit/miss masks ever read.

    The empty-carry path returns the chunk's own arrays unmodified, so
    a single-chunk stream is literally the monolithic computation.
    """

    __slots__ = ("mru",)

    def __init__(self):
        self.mru = np.empty(0, np.int64)

    def analyze(self, ids):
        """(prev, sd) for ``ids`` as the monolithic trace would see
        them; advances the carried LRU state past this chunk."""
        m = int(self.mru.size)
        ext = ids if m == 0 else \
            np.concatenate([self.mru.astype(ids.dtype, copy=False), ids])
        prev = prev_occurrence(ext)
        sd = lru_stack_distances(prev)
        self.mru = _mru_of(ext)
        return (prev, sd) if m == 0 else (prev[m:], sd[m:])

# ----------------------------------------------------------------- SA
# Table 6 (post-synthesis PPA; fixed-point @1 GHz, floating @0.6 GHz)
SA_VARIANTS = {
    # name: (freq_hz, area_um2, power_mw, peak_gops)
    ("int8", 4): (1.0e9, 16_186, 7.464, 32.0),
    ("int8", 16): (1.0e9, 186_875, 84.550, 512.0),
    ("int16", 4): (1.0e9, 24_989, 11.813, 32.0),
    ("int16", 16): (1.0e9, 397_558, 149.419, 512.0),
    ("int32", 4): (1.0e9, 73_483, 33.302, 32.0),
    ("int32", 16): (1.0e9, 1_163_841, 392.978, 512.0),
    ("fp8", 4): (0.6e9, 8_806, 2.251, 19.2),
    ("fp8", 16): (0.6e9, 142_816, 34.557, 307.2),
    ("fp16", 4): (0.6e9, 22_802, 5.580, 19.2),
    ("fp16", 16): (0.6e9, 363_805, 83.655, 307.2),
    ("fp32", 4): (0.6e9, 62_693, 16.938, 19.2),
    ("fp32", 16): (0.6e9, 1_032_820, 258.173, 307.2),
}

DTYPE_BYTES = {"int8": 1, "int16": 2, "int32": 4,
               "fp8": 1, "fp16": 2, "fp32": 4}


def sa_variant(dtype: str, w: int) -> tuple:
    """(freq_hz, area_um2, power_mw, peak_gops) for a W×W array.

    Widths in Table 6 are returned verbatim.  Other widths follow the
    table's own scaling: frequency is set by the MAC pipeline (the
    dtype), not the width; peak = 2·W² MACs/cycle; area and power obey
    the power law the two synthesized points define (log-log
    interpolation, anchored at W=16 so the paper baseline is exact)."""
    v = SA_VARIANTS.get((dtype, w))
    if v is not None:
        return v
    lo = SA_VARIANTS[(dtype, 4)]
    hi = SA_VARIANTS[(dtype, 16)]
    freq = hi[0]

    def powlaw(a4: float, a16: float) -> float:
        alpha = math.log(a16 / a4) / math.log(4.0)
        return a16 * (w / 16.0) ** alpha

    return (freq, powlaw(lo[1], hi[1]), powlaw(lo[2], hi[2]),
            2.0 * w * w * freq / 1e9)


@dataclasses.dataclass(frozen=True)
class SystolicArray:
    """MatrixFlow-style output-stationary W×W array.  ``tile_w`` is the
    row-block size the plan layer streams (``paging.SA_DIM``): an array
    narrower than the streamed tile sweeps it in ``ceil(tile_w/w)²``
    output-stationary passes, so pricing a 16-row-tiled plan on an
    8×8 array honestly charges 4 passes per tile instead of pretending
    the tile fits."""
    dtype: str = "int8"
    w: int = 16
    tile_w: int = 16               # streamed tile rows (paging.SA_DIM)

    @property
    def freq(self) -> float:
        return sa_variant(self.dtype, self.w)[0]

    @property
    def area_um2(self) -> float:
        return sa_variant(self.dtype, self.w)[1]

    @property
    def power_mw(self) -> float:
        return sa_variant(self.dtype, self.w)[2]

    @property
    def peak_gops(self) -> float:
        return sa_variant(self.dtype, self.w)[3]

    @property
    def passes(self) -> int:
        """Output-stationary sweeps needed per streamed tile."""
        return (-(-self.tile_w // self.w)) ** 2

    def tile_cycles(self, l: int) -> int:
        """One streamed tile over depth l: passes × (l + fill/drain)."""
        return self.passes * (l + 2 * (self.w - 1))

    def tile_time(self, l: int) -> float:
        return self.tile_cycles(l) / self.freq


# ---------------------------------------------------------------- PCIe
@dataclasses.dataclass(frozen=True)
class PCIeLink:
    """lanes × gbps_per_lane with TLP packetization effects (Fig. 10).

    efficiency(packet): payload / (payload + header) captures the 64 B
    penalty; an on-chip TLP pipeline depth limits outstanding packets, so
    very large TLPs (4096 B) stall the pipeline when serialization time
    exceeds the window — worst at low link speeds (paper: +36 %)."""
    lanes: int = 16
    gbps_per_lane: float = 64.0      # Gen6 ×16 = 128 GB/s (paper baseline)
    packet_bytes: int = 256
    header_bytes: int = 26          # TLP+DLLP+framing overhead
    pipeline_ns: float = 180.0      # per-TLP processing window
    encoding: float = 128.0 / 130.0

    @property
    def raw_bw(self) -> float:      # B/s, one direction
        return self.lanes * self.gbps_per_lane * 1e9 / 8 * self.encoding

    def efficiency(self) -> float:
        p = self.packet_bytes
        payload_eff = p / (p + self.header_bytes)
        # serialization of one TLP vs the pipeline window: once a packet
        # takes longer than the window, the link pipeline bubbles
        ser_ns = (p + self.header_bytes) / self.raw_bw * 1e9
        stall = max(0.0, ser_ns - self.pipeline_ns) / max(ser_ns, 1e-9)
        return payload_eff * (1.0 - 0.55 * stall)

    @property
    def effective_bw(self) -> float:
        return self.raw_bw * self.efficiency()

    def transfer_time(self, nbytes: int) -> float:
        return nbytes / self.effective_bw


# -------------------------------------------------------------- fabric
FABRIC_TOPOLOGIES = ("ring", "alltoall")


@dataclasses.dataclass(frozen=True)
class Fabric:
    """Inter-device interconnect for multi-device sharded plans: p
    symmetric accelerators joined by dedicated PCIe-class links (one
    per neighbour, separate from the host<->device streaming link), a
    topology that decides how a collective decomposes into per-hop
    transfers at plan-build time (``core.multidev``), and a per-hop
    launch latency.  Timing reuses the PCIeLink model verbatim: one
    collective hop of B bytes costs ``hop_time(B)`` on the rank's own
    fabric lane."""
    link: PCIeLink = PCIeLink()
    topology: str = "ring"          # ring | alltoall
    hop_latency_ns: float = 500.0   # per-hop launch/sync latency

    def __post_init__(self):
        if self.topology not in FABRIC_TOPOLOGIES:
            raise ValueError(
                f"unknown fabric topology {self.topology!r}; valid: "
                f"{FABRIC_TOPOLOGIES}")

    def hop_time(self, nbytes) -> float:
        """One inter-device hop: link serialization + launch latency
        (vectorizes over an nbytes array, like the replayer's paths)."""
        return nbytes / self.link.effective_bw \
            + self.hop_latency_ns * 1e-9

    def row_key(self) -> tuple:
        """The pricing-relevant identity (topology acts at plan build,
        not at pricing) — part of the batched replayer's row dedup."""
        return ("fab", self.link.effective_bw, self.hop_latency_ns)


# ---------------------------------------------------------------- DRAM
# Table 7: tech -> (channels, data_width_bits, bandwidth B/s, data rate)
DRAM_TECH = {
    "DDR3": (1, 64, 12.8e9, 1600),
    "DDR4": (1, 64, 19.2e9, 2400),
    "DDR5": (2, 32, 25.6e9, 3200),
    "GDDR6": (2, 64, 32.0e9, 2000),
    "HBM2": (2, 128, 64.0e9, 2000),
}


@dataclasses.dataclass(frozen=True)
class DRAM:
    tech: str = "DDR3"
    latency_ns: float = 12.0
    stream_efficiency: float = 0.87     # bank/queueing losses on bursts

    @property
    def bandwidth(self) -> float:
        return DRAM_TECH[self.tech][2]

    def transfer_time(self, nbytes: int) -> float:
        return self.latency_ns * 1e-9 + \
            nbytes / (self.bandwidth * self.stream_efficiency)


# ---------------------------------------------------------------- SMMU
@dataclasses.dataclass
class SMMU:
    """Two-level TLB + walk cache + page walker (Table 8).

    The 64-entry uTLB backs onto a larger L2 TLB: most uTLB misses fill
    from L2 / the walk cache in ~10–25 cycles (the paper's mean
    translation times), and only L2 misses pay a full multi-level walk
    (~180–368 cycles, deeper as the footprint outgrows the reach)."""
    tlb_entries: int = 64
    l2_entries: int = 8192
    l2_fill_cycles: float = 12.0
    base_walk_cycles: float = 180.0     # few-page working sets
    deep_walk_cycles: float = 368.0     # >reach thrash regime
    freq: float = 1.0e9
    hit_cycles: float = 1.0

    def __post_init__(self):
        self._tlb: "collections.OrderedDict" = collections.OrderedDict()
        self._l2: "collections.OrderedDict" = collections.OrderedDict()
        self.lookups = 0
        self.misses = 0
        self.walks = 0

    def reset(self):
        self._tlb.clear()
        self._l2.clear()
        self.lookups = self.misses = self.walks = 0

    def walk_cycles(self, footprint_pages: int) -> float:
        if footprint_pages <= self.l2_entries:
            return self.base_walk_cycles
        scale = min(1.0, math.log2(footprint_pages / self.l2_entries) / 3.0)
        return self.base_walk_cycles + scale * (self.deep_walk_cycles -
                                                self.base_walk_cycles)

    def _touch(self, cache, key, cap) -> bool:
        if key in cache:
            cache.move_to_end(key)
            return True
        cache[key] = True
        while len(cache) > cap:
            cache.popitem(last=False)
        return False

    def access(self, page_id, footprint_pages: int) -> float:
        """Translate one page access; returns seconds."""
        self.lookups += 1
        if self._touch(self._tlb, page_id, self.tlb_entries):
            return self.hit_cycles / self.freq
        self.misses += 1
        if self._touch(self._l2, page_id, self.l2_entries):
            return (self.hit_cycles + self.l2_fill_cycles) / self.freq
        self.walks += 1
        return (self.hit_cycles + self.l2_fill_cycles +
                self.walk_cycles(footprint_pages)) / self.freq

    # ------------------------------------------------------ batch path
    def tlb_walk_masks(self, ids: np.ndarray, memo: dict):
        """(uTLB-miss mask over the trace, walk mask over the uTLB-miss
        subsequence) — the exact hit/miss sequence a sequential sweep
        from reset state would produce, computed from the trace's stack
        distances.  ``memo`` caches the trace-intrinsic arrays; only
        the capacity comparisons depend on this SMMU's parameters."""
        prev, sd = _lru_trace_memo(memo, ids)
        tlb_miss = ~((prev >= 0) & (sd < self.tlb_entries))
        key = ("l2", self.tlb_entries)
        if key not in memo:
            miss_pos = np.nonzero(tlb_miss)[0]
            sub_prev = prev_occurrence(ids[miss_pos])
            memo[key] = (miss_pos, sub_prev,
                         lru_stack_distances(sub_prev))
        miss_pos, sub_prev, sub_sd = memo[key]
        walk_sub = ~((sub_prev >= 0) & (sub_sd < self.l2_entries))
        return tlb_miss, miss_pos, walk_sub

    def access_many(self, ids: np.ndarray, footprint_pages: int,
                    memo: dict, keys=None) -> np.ndarray:
        """Batch counterpart of ``access`` over a whole interned page-id
        trace: per-access translation seconds, identical to a sequential
        sweep from reset state (counters updated; final LRU state
        reconstructed when ``keys`` maps ids back to page keys)."""
        assert not self._tlb and not self._l2, \
            "access_many requires reset SMMU state"
        tlb_miss, miss_pos, walk_sub = self.tlb_walk_masks(ids, memo)
        self.lookups += int(ids.size)
        self.misses += int(miss_pos.size)
        self.walks += int(walk_sub.sum())
        # one cached per-access time array, replaced when the SMMU
        # parameters change — mode sweeps over one config reuse it,
        # parameter sweeps do not accumulate one array per config
        tkey = (self.tlb_entries, self.l2_entries, self.hit_cycles,
                self.l2_fill_cycles, self.freq,
                self.walk_cycles(footprint_pages))
        if memo.get("xlat", (None,))[0] != tkey:
            cyc = np.full(ids.size, float(self.hit_cycles))
            cyc[miss_pos] += self.l2_fill_cycles
            cyc[miss_pos[walk_sub]] += self.walk_cycles(footprint_pages)
            memo["xlat"] = (tkey, cyc / self.freq)
        _rebuild_lru_state(self._tlb, _mru_ids(memo, "mru", ids), keys,
                           self.tlb_entries)
        _rebuild_lru_state(self._l2,
                           _mru_ids(memo, ("mru_l2", self.tlb_entries),
                                    ids[miss_pos]),
                           keys, self.l2_entries)
        return memo["xlat"][1]


# ---------------------------------------------------------------- DMA
@dataclasses.dataclass(frozen=True)
class DMAEngine:
    read_channels: int = 2
    write_channels: int = 2
    burst_bytes: int = 1024
    descriptor_ns: float = 45.0     # enqueue+fetch one descriptor
    doorbell_ns: float = 400.0      # MMIO write (per offloaded call)
    interrupt_ns: float = 4000.0    # MSI + IRQ + driver completion

    def descriptor_time(self) -> float:
        return self.descriptor_ns * 1e-9


# ---------------------------------------------------------------- LLC
@dataclasses.dataclass
class LLC:
    """Shared last-level cache for DC mode, page-granular LRU."""
    size_bytes: int = 2 * 1024 * 1024
    page_bytes: int = 4096
    hit_latency_ns: float = 18.0
    hit_bw: float = 64e9

    def __post_init__(self):
        self._lru: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    @property
    def capacity_pages(self) -> int:
        return self.size_bytes // self.page_bytes

    def reset(self):
        self._lru.clear()
        self.hits = self.misses = 0

    def access(self, page_id) -> bool:
        """Returns hit?"""
        if page_id in self._lru:
            self.hits += 1
            self._lru.move_to_end(page_id)
            return True
        self.misses += 1
        self._lru[page_id] = True
        while len(self._lru) > self.capacity_pages:
            self._lru.popitem(last=False)
        return False

    def hit_time(self, nbytes: int) -> float:
        return self.hit_latency_ns * 1e-9 + nbytes / self.hit_bw

    # ------------------------------------------------------ batch path
    def access_many(self, ids: np.ndarray, memo: dict,
                    keys=None) -> np.ndarray:
        """Batch counterpart of ``access``: the exact hit mask of a
        sequential sweep from reset state, from the same trace-intrinsic
        stack distances the SMMU pass uses (one ``memo`` per trace
        serves the whole component hierarchy)."""
        assert not self._lru, "access_many requires reset LLC state"
        prev, sd = _lru_trace_memo(memo, ids)
        hit = (prev >= 0) & (sd < self.capacity_pages)
        nh = int(hit.sum())
        self.hits += nh
        self.misses += int(ids.size) - nh
        _rebuild_lru_state(self._lru, _mru_ids(memo, "mru", ids), keys,
                           self.capacity_pages)
        return hit
