"""End-to-end system model: MatrixFlow vs CPU baselines on transformer
workloads — produces the paper's headline numbers (Table 9, Fig. 7/8/12/13).

CPU models are behavioral, calibrated against the paper's own ratios:
  * single ARM core: ~2.2 cycles/MAC INT8/INT32 (cache-aware triple loop)
  * FP16 on CPU: software-emulated (paper: the worst case)
  * Neon SIMD: 16-lane INT8 at modest efficiency  (<10× — Fig. 7b)
  * 256-thread OMP: memory-bound parallel efficiency (20–30×)
TiC-SAT and SMAUG rows reproduce the published speedups (they are
comparison systems simulated by their own authors; Table 9 cites them).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

from repro.accesys import workloads as W
from repro.accesys.components import (DMAEngine, DRAM, LLC, PCIeLink,
                                      SMMU, SystolicArray, DTYPE_BYTES)
from repro.accesys.pipeline import (GemmResult, SystemConfig, replay,
                                    simulate_gemm)
from repro.configs.paper_models import PAPER_MODELS
from repro.core import plan as plan_ir


# --------------------------------------------------------------- CPUs
@dataclasses.dataclass(frozen=True)
class CPUModel:
    freq: float = 1.0e9
    cycles_per_mac: float = 1.8        # scalar int, cache-resident-ish
    fp32_penalty: float = 1.6
    fp16_emulation: float = 18.0       # no native fp16: soft-float
    nongemm_cycles_per_elem: float = 0.8
    mem_bw: float = 12.8e9             # DDR3 host

    def gemm_time(self, macs: int, dtype: str, threads: int = 1,
                  simd: bool = False) -> float:
        cyc = self.cycles_per_mac
        if dtype == "fp32":
            cyc *= self.fp32_penalty
        elif dtype == "fp16":
            cyc *= self.fp16_emulation
        elif dtype == "int32":
            cyc *= 1.9        # wider loads thrash L1/L2 on in-order walks
        if simd:
            lanes = {"int8": 16, "int16": 8, "int32": 4,
                     "fp32": 4, "fp16": 8}[dtype]
            cyc /= lanes * 0.45        # issue/ld-st overheads
            if dtype == "fp16":
                cyc = self.cycles_per_mac / (8 * 0.45) * 2.0
        t = macs * cyc / self.freq
        if threads > 1:
            # memory-bound scaling: saturates against host DRAM bw
            speed = min(threads * 0.55,
                        25.6 * (1.0 + 0.04 * math.log2(threads / 64))
                        if threads >= 64 else threads * 0.55)
            t /= max(speed, 1.0)
        return t

    def nongemm_time(self, elems: int) -> float:
        return elems * self.nongemm_cycles_per_elem / self.freq


# Reported-baseline calibration (EXPERIMENTS.md §Known deviations): the
# paper's single-core CPU baselines are relatively slower on the BERT
# shapes than a uniform cycles/MAC model predicts (Table 9 has BERT-Large
# at 698x vs ViT-Large at 392x on near-identical GEMM volumes). We
# reproduce the REPORTED baselines by scaling the CPU model per workload;
# the accelerator side stays fully mechanistic.
REPORTED_CPU_CALIBRATION = {
    "bert-medium": 0.99, "bert-base": 1.19, "bert-large": 1.21,
    "vit-base-16": 0.63, "vit-large-16": 0.70, "vit-huge-14": 0.72,
}


# published comparison rows (Table 9; simulated by their own authors)
TICSAT_SPEEDUP = {"bert-medium": 58.3, "bert-base": 69.3,
                  "bert-large": 89.5, "vit-base-16": 69.4,
                  "vit-large-16": 82.5, "vit-huge-14": 82.7}
SMAUG_SPEEDUP = {"bert-medium": 88.0}


# ------------------------------------------------------------ results
@dataclasses.dataclass
class TransformerResult:
    name: str
    total_s: float
    gemm_s: float
    nongemm_s: float
    control_s: float
    by_class: dict

    def breakdown(self) -> dict:
        out = dict(self.by_class)
        out["Non-GEMM"] = self.nongemm_s
        out["Control"] = self.control_s
        return {k: v / self.total_s for k, v in out.items()}


def run_transformer_accel(cfg: SystemConfig, wl: W.Workload,
                          cpu: Optional[CPUModel] = None,
                          ) -> TransformerResult:
    """GEMMs on MatrixFlow (simulated pipeline), non-GEMM on host."""
    cpu = cpu or CPUModel()
    by_class: dict = {}
    gemm_s = 0.0
    control_s = 0.0
    for g in wl.gemms:
        r = simulate_gemm(cfg, g.m, g.n, g.k)
        # per-call control: doorbell+descriptor amortization handled in
        # simulate_gemm; driver/runtime dispatch per *call class batch*
        t = r.total_s * g.count
        # driver dispatch per offloaded call: syscall + descriptor ring
        # setup + completion IRQ + cache maintenance (paper Fig. 8: ~24 %
        # control share in the accelerated regime)
        ctl = (cfg.dma.doorbell_ns + cfg.dma.interrupt_ns + 14_000) \
            * 1e-9 * g.count
        ctl += r.exposed_transfer_s * g.count * 0.35   # sync slack
        # runtime marshalling: page-align/row-stripe the activation
        # operand and unpack C on the host (§3.3), ~5 GB/s memcpy-class
        elem = DTYPE_BYTES[cfg.sa.dtype]
        ctl += (g.m * g.k + g.m * g.n) * elem * g.count / 5e9
        gemm_s += t
        control_s += ctl
        by_class[g.cls] = by_class.get(g.cls, 0.0) + t
    nongemm_s = cpu.nongemm_time(wl.nongemm_elems)
    if cfg.mode == "DevMem":
        # host-side stages round-trip activations over PCIe: small
        # latency-bound transfers per stage (Fig. 13's DevMem penalty)
        act_bytes = wl.nongemm_elems * 4 * 2
        nongemm_s = nongemm_s * 2.4 + act_bytes / cfg.pcie.effective_bw
    total = gemm_s + nongemm_s + control_s
    return TransformerResult(wl.name, total, gemm_s, nongemm_s,
                             control_s, by_class)


def run_transformer_cpu(wl: W.Workload, cpu: Optional[CPUModel] = None,
                        threads: int = 1, simd: bool = False,
                        dtype: str = "int32") -> TransformerResult:
    cpu = cpu or CPUModel()
    cal = REPORTED_CPU_CALIBRATION.get(wl.name, 1.0)
    by_class: dict = {}
    gemm_s = 0.0
    for g in wl.gemms:
        t = cal * cpu.gemm_time(g.m * g.n * g.k * g.count, dtype,
                                threads=threads, simd=simd)
        gemm_s += t
        by_class[g.cls] = by_class.get(g.cls, 0.0) + t
    nongemm_s = cpu.nongemm_time(wl.nongemm_elems) / min(threads, 8)
    total = gemm_s + nongemm_s
    return TransformerResult(wl.name, total, gemm_s, nongemm_s, 0.0,
                             by_class)


# -------------------------------------------- composed StreamPlan path
# NOTE: prefer the Scenario API (core.scenario.simulate/sweep) for new
# callers — these helpers remain as the BERT/ViT-specific lowering the
# workload tests pin, and run_transformer_composed is a thin shim over
# the same replay the façade uses.
# maxsize stays small: an exact full-depth graph plus its compiled
# arrays is order-100 MB, and sweeps only ever reuse the last few
@functools.lru_cache(maxsize=4)
def model_stream_plan(name: str, n_layers: Optional[int] = None,
                      dtype: str = "int8") -> "plan_ir.StreamPlan":
    """The full event-graph plan for a paper model (BERT/ViT class):
    N composed transformer-layer plans.  ``n_layers`` caps the stack
    (the graph is exact, not sampled — BERT-Base at full depth is a few
    hundred thousand events).  Memoized: building the graph costs far
    more than compiled-replaying it, and mode sweeps reuse one plan
    (and its compiled form) across DM/DC/DevMem rows."""
    cfg = PAPER_MODELS[name]
    layers = cfg.n_layers if n_layers is None else n_layers
    return plan_ir.model_plan(cfg.max_train_seq, cfg.d_model,
                              cfg.n_heads, cfg.d_ff, layers, dtype)


@functools.lru_cache(maxsize=16)
def model_stream_schedule(name: str, n_layers: Optional[int] = None,
                          dtype: str = "int8",
                          sample_stride: int = 1
                          ) -> "plan_ir.PlanSchedule":
    """Steady-state-sampled counterpart of ``model_stream_plan``: one
    layer's sub-plans as segments, each repeated ``n_layers`` times —
    the replayer walks one layer's events and scales, instead of
    replaying hundreds of thousands of events exactly.  Memoized like
    ``model_stream_plan``."""
    cfg = PAPER_MODELS[name]
    layers = cfg.n_layers if n_layers is None else n_layers
    return plan_ir.model_schedule(cfg.max_train_seq, cfg.d_model,
                                  cfg.n_heads, cfg.d_ff, layers, dtype,
                                  sample_stride=sample_stride)


def run_transformer_composed(cfg: SystemConfig, name: str,
                             n_layers: Optional[int] = None,
                             cpu: Optional[CPUModel] = None,
                             sampled: bool = False,
                             sample_stride: int = 1,
                             engine: Optional[str] = None) -> GemmResult:
    """End-to-end replay of a composed multi-layer transformer plan —
    one event timeline across QKV / per-head attention / FFN instead of
    per-GEMM-class aggregation.  Returns the Fig.-2 buckets for the
    whole forward pass.  ``sampled=True`` replays the steady-state
    schedule (one layer window x repeat) instead of the exact graph;
    ``engine`` picks the replayer (compiled array engine by default for
    composed plans — exact full-depth replays are no longer the slow
    path)."""
    cpu = cpu or CPUModel()
    if sampled:
        plan = model_stream_schedule(name, n_layers, cfg.sa.dtype,
                                     sample_stride)
    else:
        plan = model_stream_plan(name, n_layers, cfg.sa.dtype)
    return replay(cfg, plan,
                  host_s_per_elem=cpu.nongemm_cycles_per_elem / cpu.freq,
                  engine=engine)


# ----------------------------------------------------- config presets
def default_system(mode: str = "DC", dtype: str = "int8",
                   pcie: Optional[PCIeLink] = None,
                   dram: Optional[DRAM] = None) -> SystemConfig:
    return SystemConfig(
        sa=SystolicArray(dtype=dtype),
        pcie=pcie or PCIeLink(),
        dram=dram or DRAM("DDR3"),
        mode=mode)


def pcie_for_bw(gb_s: float, packet: int = 256) -> PCIeLink:
    """A link whose *raw* one-direction bandwidth is ~gb_s GB/s."""
    lanes = 16
    gbps = gb_s * 8 / lanes / (128 / 130)
    return PCIeLink(lanes=lanes, gbps_per_lane=gbps, packet_bytes=packet)
