"""deepseek-v3-671b [arXiv:2412.19437; hf]

61L d_model=7168 128H (GQA kv=128) d_ff=2048 vocab=129280,
MoE: 1 shared + 256 routed experts, top-8; MLA; MTP head.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    moe=MoEConfig(n_routed_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, first_dense_layers=3),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mtp=True,
    source="arXiv:2412.19437; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=256,
        moe=MoEConfig(n_routed_experts=8, top_k=2, d_ff_expert=48,
                      n_shared_experts=1, first_dense_layers=1),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        mtp=True,
        vocab_pad_multiple=16,
    )
