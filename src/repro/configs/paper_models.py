"""The paper's own evaluation models (BERT / ViT) — used by the accesys
workload traces (Figs 7-13, Tables 8-9) and runnable as encoder configs.
"""
from repro.configs.base import ModelConfig


def _encoder(name: str, n_layers: int, d_model: int, n_heads: int,
             seq: int) -> ModelConfig:
    return ModelConfig(
        name=name, family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_heads, d_ff=4 * d_model,
        vocab_size=30522, norm="layernorm", act="gelu", glu=False,
        rope="none", max_train_seq=seq,
    )


# BERT family (seq 128 in the paper); ViT family (224^2 -> 196(+1) patches)
BERT_MEDIUM = _encoder("bert-medium", 8, 512, 8, 128)
BERT_BASE = _encoder("bert-base", 12, 768, 12, 128)
BERT_LARGE = _encoder("bert-large", 24, 1024, 16, 128)
VIT_BASE = _encoder("vit-base-16", 12, 768, 12, 197)
VIT_LARGE = _encoder("vit-large-16", 24, 1024, 16, 197)
VIT_HUGE = _encoder("vit-huge-14", 32, 1280, 16, 257)

PAPER_MODELS = {
    m.name: m for m in
    [BERT_MEDIUM, BERT_BASE, BERT_LARGE, VIT_BASE, VIT_LARGE, VIT_HUGE]
}
