"""rwkv6-7b (Finch) [arXiv:2404.05892; hf]

32L d_model=4096, attention-free (data-dependent decay linear attention,
head_size=64 -> 64 time-mix heads), d_ff=14336, vocab=65536.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,           # d_model / head_size(64)
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    norm="layernorm",
    rope="none",
    ssm=SSMConfig(d_state=64, head_dim=64),
    source="arXiv:2404.05892; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-reduced", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, norm="layernorm", rope="none",
        ssm=SSMConfig(d_state=16, head_dim=16), vocab_pad_multiple=16,
    )
