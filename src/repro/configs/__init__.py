"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact published full config;
``get_reduced(arch_id)`` returns a same-family tiny config for CPU smoke
tests (small layers/width, few experts, tiny vocab).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    shapes_for,
    skip_reason,
)

ARCH_IDS = [
    "qwen2_moe_a2_7b",
    "deepseek_v3_671b",
    "qwen1_5_32b",
    "qwen2_0_5b",
    "chatglm3_6b",
    "granite_20b",
    "internvl2_2b",
    "whisper_tiny",
    "zamba2_7b",
    "rwkv6_7b",
]

# CLI-friendly aliases (--arch qwen2-moe-a2.7b etc.)
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen2-0.5b": "qwen2_0_5b",
})


def _module(arch: str):
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES) + ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()
