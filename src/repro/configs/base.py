"""Config dataclasses for models, shapes, meshes, and runtime policies.

Every assigned architecture gets one module in this package defining
``CONFIG: ModelConfig`` with the exact published numbers, plus a
``reduced()`` constructor used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class MoEConfig:
    n_routed_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    # layers [0, first_dense_layers) use a dense FFN instead of MoE
    # (deepseek-v3 uses 3 dense layers before the MoE stack).
    first_dense_layers: int = 0
    router_aux_coef: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (deepseek-v3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (zamba2) / RWKV6 state-space parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64          # SSM head dim (mamba2) / rwkv head size
    # zamba2: one shared attention block applied every `attn_every` mamba layers
    attn_every: int = 6


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope: str = "full"           # full | 2d | none
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu"            # silu | gelu
    glu: bool = True             # gated FFN (SwiGLU/GeGLU) vs plain MLP
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    mtp: bool = False            # multi-token-prediction head (deepseek-v3)
    # encoder-decoder (whisper): n_layers == decoder layers
    n_encoder_layers: int = 0
    # modality frontend stub: inputs are precomputed embeddings, not token ids
    embedding_inputs: bool = False
    # vocab padding so TP shards divide evenly; logits beyond vocab_size masked
    vocab_pad_multiple: int = 256
    # attention flavor for long context: "full" | "sliding"
    max_train_seq: int = 8192
    source: str = ""             # provenance tag [source; tier]

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encoder_decoder(self) -> bool:
        return self.n_encoder_layers > 0

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, h = self.d_model, self.resolved_head_dim
        n_q = self.n_heads * h
        n_kv = self.n_kv_heads * h
        emb = self.padded_vocab * d
        head = 0 if self.tie_embeddings else self.padded_vocab * d
        per_layer = 0
        if self.family == "ssm":                      # rwkv6-style
            d_inner = d
            per_layer += 6 * d * d                    # r,k,v,g,o + decay proj
            per_layer += d * self.d_ff + self.d_ff * d
        else:
            if self.mla is not None:
                m = self.mla
                per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                    m.qk_nope_head_dim + m.qk_rope_head_dim)
                per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                per_layer += m.kv_lora_rank * self.n_heads * (
                    m.qk_nope_head_dim + m.v_head_dim)
                per_layer += self.n_heads * m.v_head_dim * d
            else:
                per_layer += d * (n_q + 2 * n_kv) + n_q * d
            ff_mult = 3 if self.glu else 2
            if self.moe is not None:
                moe_ff = ff_mult * d * self.moe.d_ff_expert
                per_layer += self.moe.n_routed_experts * moe_ff
                per_layer += self.moe.n_shared_experts * moe_ff
                per_layer += d * self.moe.n_routed_experts  # router
            else:
                per_layer += ff_mult * d * self.d_ff
        shared = 0
        if self.family == "hybrid" and self.ssm is not None:
            d_inner = self.ssm.expand * d
            per_layer = 2 * d * d_inner + d_inner * d + d_inner * self.ssm.d_conv
            # zamba2: ONE shared attention+MLP block reused every attn_every
            # layers (weights counted once).
            shared = d * (n_q + 2 * n_kv) + n_q * d + 3 * d * self.d_ff
        total = emb + head + self.n_layers * per_layer + shared
        if self.is_encoder_decoder:
            total += self.n_encoder_layers * per_layer
        return int(total)


@dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class RunConfig:
    """Everything the launcher needs beyond the model itself."""
    model: ModelConfig
    shape: ShapeConfig
    # distribution
    multi_pod: bool = False
    remat: str = "full"          # none | dots | full
    scan_layers: bool = True
    optimizer: str = "adamw"     # adamw | adafactor
    param_dtype: str = "bfloat16"
    # paper technique knobs (core/)
    memory_mode: str = "DC"      # DM | DC | DevMem
    page_bytes: int = 4096
    double_buffer: bool = True
    # beyond-paper perf knobs (hillclimbing)
    use_flash: bool = True
    shard_cache_seq: bool = False   # context parallelism for decode caches
    gradient_compression: bool = False
    q_chunk: int = 512
    kv_chunk: int = 1024
    ce_chunk: int = 512
    ssm_chunk: int = 16
    kv_cache_quant: bool = False
    moe_cap_axis: str = ""          # "data" shards MoE capacity dim
    moe_local_dispatch: bool = False
    fsdp: bool = True               # False: TP-only weights (replicated
                                    # over data) — kills per-layer weight
                                    # gather/activation reduce collectives


def shapes_for(model: ModelConfig) -> list[str]:
    """The shape cells that are *runnable* for this architecture.

    All 40 cells exist; this marks which are skipped (recorded, per spec,
    rather than silently dropped).
    """
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if model.sub_quadratic:
        out.append("long_500k")
    return out


def skip_reason(model: ModelConfig, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not model.sub_quadratic:
        return "pure full-attention arch: 500k dense KV walk per decoded token is not sub-quadratic (DESIGN.md §Arch-applicability)"
    return None
