"""granite-20b [arXiv:2405.04324; hf]

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152 — code model.
d_ff = 4*d_model (non-gated MLP, gelu) with multi-query attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    norm="layernorm",
    act="gelu",
    glu=False,
    source="arXiv:2405.04324; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=256,
        vocab_size=256, norm="layernorm", act="gelu", glu=False,
        vocab_pad_multiple=16,
    )
