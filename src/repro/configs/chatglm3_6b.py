"""chatglm3-6b [arXiv:2406.12793; hf]

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 — RoPE-2d, GQA.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    qkv_bias=True,
    rope="2d",
    source="arXiv:2406.12793; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, qkv_bias=True, rope="2d", vocab_pad_multiple=16,
    )
