"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936,
MoE: 4 shared + 60 routed experts, top-4 routing.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(n_routed_experts=60, top_k=4, d_ff_expert=1408,
                  n_shared_experts=4),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=256, qkv_bias=True,
        moe=MoEConfig(n_routed_experts=8, top_k=2, d_ff_expert=96,
                      n_shared_experts=1),
        vocab_pad_multiple=16,
    )
