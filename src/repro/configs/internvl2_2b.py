"""internvl2-2b [arXiv:2404.16821; hf]

LM backbone (InternLM2-1.8B): 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 (padded to 92672 for TP). InternViT frontend is a stub per
spec: ``input_specs()`` provides precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    embedding_inputs=True,
    source="arXiv:2404.16821; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b-reduced", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=250, embedding_inputs=True, vocab_pad_multiple=16,
    )
