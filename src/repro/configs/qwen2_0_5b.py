"""qwen2-0.5b [arXiv:2407.10671; hf]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 — GQA, QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    source="arXiv:2407.10671; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, qkv_bias=True, tie_embeddings=True,
        vocab_pad_multiple=16,
    )
