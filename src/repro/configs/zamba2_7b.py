"""zamba2-7b [arXiv:2411.15242; unverified]

Hybrid: 81 Mamba2 layers (d_state=64) with a SHARED full-attention block
(32H, kv=32, d_model=3584) applied every 6 layers; per-layer MLP d_ff=14336;
vocab=32000.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, attn_every=6),
    source="arXiv:2411.15242; unverified",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-reduced", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, attn_every=2),
        vocab_pad_multiple=16,
    )
