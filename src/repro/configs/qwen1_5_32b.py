"""qwen1.5-32b [hf:Qwen/Qwen1.5-0.5B; hf]

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064 — QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=256, qkv_bias=True, vocab_pad_multiple=16,
    )
