"""whisper-tiny [arXiv:2212.04356; unverified]

Enc-dec: 4L encoder + 4L decoder, d_model=384 6H (kv=6) d_ff=1536
vocab=51865 (padded 51968). Conv audio frontend is a stub: encoder
inputs are precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    glu=False,
    rope="none",
    embedding_inputs=True,
    source="arXiv:2212.04356; unverified",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-reduced", family="audio",
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=250, norm="layernorm", act="gelu", glu=False,
        rope="none", embedding_inputs=True, vocab_pad_multiple=16,
    )
