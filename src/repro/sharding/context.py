"""Trace-time sharding context: lets model code emit
``with_sharding_constraint`` on activations using *logical* axis names,
without threading mesh/rules through every function signature.

GSPMD does not reliably propagate shardings into ``lax.scan``/``lax.map``
bodies (we measured 16× replicated compute in chunked attention without
constraints), so the model sprinkles ``shard(x, axes)`` at loop-body
boundaries. Outside a context (unit tests, smoke runs) it is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding import logical as LG

_STATE = threading.local()


def current():
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules):
    prev = current()
    _STATE.ctx = (mesh, rules,
                  dict(zip(mesh.axis_names, mesh.devices.shape)))
    try:
        yield
    finally:
        _STATE.ctx = prev


def shard(x, axes):
    """Constrain activation ``x`` to the logical ``axes`` under the active
    mesh context (no-op without one). ``axes`` length must match x.ndim."""
    ctx = current()
    if ctx is None:
        return x
    mesh, rules, ms = ctx
    spec = LG.spec_for(axes, x.shape, rules, ms)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
