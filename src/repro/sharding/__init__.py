from repro.sharding import logical  # noqa: F401
