"""Logical-axis sharding rules (MaxText-style).

Every parameter / activation dimension carries a *logical* name
("embed", "heads", "mlp", "vocab", "expert", "batch", "cache_seq", ...).
A rule table maps logical names to physical mesh axes; ``spec_for``
resolves a tuple of logical names into a ``PartitionSpec`` while
enforcing (a) each mesh axis is claimed at most once, and (b) a dim is
only sharded if its size divides the mesh-axis extent (GSPMD would pad
otherwise, silently wasting memory — we prefer replication + an entry in
the roofline notes).

Physical axes: ``("pod", "data", "model")`` multi-pod, ``("data",
"model")`` single-pod. Weights are FSDP-sharded over ``data`` and
TP-sharded over ``model``; ``pod`` is pure data-parallel over DCN.
"""
from __future__ import annotations

import math
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Default logical->physical mapping. Tuples mean "shard over several axes".
# Order in PRIORITY decides who wins when two dims of one tensor want the
# same mesh axis (first claim wins, later claims are dropped).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),                # activations' seq dim: unsharded by default
    # decode KV/state cache seq dim: claims `model` ONLY when the kv-head
    # dims could not (GQA with few KV heads) — see PRIORITY
    "cache_seq": ("model",),
    "cache_batch": ("pod", "data"),
    # weights
    "expert": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv": ("model",),        # fused heads*head_dim projections
    "seq_q": ("model",),      # query-chunk dim: claims model ONLY when the
                              # head dims could not (GQA with few heads) —
                              # sequence-parallel attention fallback
    "vocab": ("model",),
    "mlp": ("model",),
    "embed": ("data",),       # FSDP axis for weights
    "embed_act": (),          # activations' model dim
    "head_dim": (),
    "state": (),
    "layers": (),             # stacked-scan leading dim
    "conv": (),
    "lora": (),               # MLA latent dims
    "moe_cap": (),            # MoE capacity dim (hillclimb: -> data)
}

# Context-parallel variant for long_500k decode (batch=1): shard the cache
# sequence instead of batch, keep heads on model.
LONG_CONTEXT_OVERRIDES: dict[str, tuple[str, ...]] = {
    "batch": (),
    "cache_batch": (),
    "cache_seq": ("data",),
}

PRIORITY = [
    "expert", "heads", "qkv", "kv_heads", "seq_q", "vocab", "mlp",
    "moe_cap", "cache_seq", "cache_batch", "batch", "embed", "seq",
    "embed_act", "head_dim", "state", "layers", "conv", "lora",
]
_PRIO = {n: i for i, n in enumerate(PRIORITY)}


def make_rules(multi_pod: bool, long_context: bool = False,
               overrides: Optional[Mapping[str, tuple[str, ...]]] = None,
               ) -> dict[str, tuple[str, ...]]:
    rules = dict(DEFAULT_RULES)
    if long_context:
        rules.update(LONG_CONTEXT_OVERRIDES)
    if overrides:
        rules.update(overrides)
    if not multi_pod:
        rules = {k: tuple(a for a in v if a != "pod") for k, v in rules.items()}
    return rules


def spec_for(axes: Sequence[Optional[str]],
             shape: Sequence[int],
             rules: Mapping[str, tuple[str, ...]],
             mesh_shape: Mapping[str, int]) -> P:
    """Resolve logical axes + concrete shape into a PartitionSpec."""
    assert len(axes) == len(shape), (axes, shape)
    # Claim mesh axes in priority order.
    order = sorted(range(len(axes)),
                   key=lambda i: _PRIO.get(axes[i] or "", len(PRIORITY)))
    taken: set[str] = set()
    out: list = [None] * len(axes)
    for i in order:
        name = axes[i]
        if name is None:
            continue
        want = [a for a in rules.get(name, ()) if a in mesh_shape]
        got: list[str] = []
        extent = 1
        for a in want:
            if a in taken:
                continue
            if shape[i] % (extent * mesh_shape[a]) != 0:
                continue   # would need padding: replicate instead
            got.append(a)
            extent *= mesh_shape[a]
        if got:
            taken.update(got)
            out[i] = tuple(got) if len(got) > 1 else got[0]
    return P(*out)


def sharding_for(axes: Sequence[Optional[str]], shape: Sequence[int],
                 rules: Mapping[str, tuple[str, ...]], mesh: Mesh,
                 ) -> NamedSharding:
    return NamedSharding(mesh, spec_for(axes, shape, rules, dict(zip(mesh.axis_names, mesh.devices.shape))))


def tree_specs(axes_tree, shape_tree, rules, mesh_shape):
    """Map spec_for over congruent pytrees of logical-axes tuples / shapes."""
    return jax.tree.map(
        lambda axes, shp: spec_for(axes, shp, rules, mesh_shape),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )
