"""StreamPlan — the event-graph IR unifying streaming (paper Figs. 2/6).

One typed event graph of ``DMA_IN`` / ``COMPUTE`` / ``DMA_OUT`` events —
carrying page ids, byte counts, dependency edges and double-buffer lane
assignments — is the single source of truth for the paper's Algorithm-1
loop nest.  Two consumers share it:

  * ``core.streaming.execute_plan`` — the *functional* executor: runs the
    plan tile-by-tile through a mode-aware ``PageStore`` (DM / DC /
    DevMem) and returns numerical results plus metered traffic;
  * ``accesys.pipeline.replay`` — the *timing* replayer: replays the same
    events against the PCIe/DRAM/SMMU/LLC component models and returns
    the Fig.-2 latency buckets.

Builders cover the paper's GEMM (Algorithm 1), paged attention
(QK^T -> softmax -> PV streaming over KV pages), full transformer
layers / N-layer models composed from per-op plans, expert-routed MoE
FFN layers (``moe_layer_plan`` — per-expert page sets sized by router
capacity, mirroring ``models/moe.py``), scan-structured SSM layers
(``ssm_layer_plan`` — chunked linear attention with a state-carry
dependency chain, mirroring ``models/ssm.py``), batched decode steps
over a paged KV cache (``decode_step_plan`` — DMA_IN page ids taken
verbatim from a live page table; GQA q-head fan-out and multi-layer
composition), and prompt prefills over the same pool pages
(``prefill_plan`` — chunked causal QK/PV over freshly written pages
plus weight-streaming GEMMs).

``PlanSchedule`` is the steady-state-sampled view of a long composed
plan: a list of (steady-window sub-plan, repeat count) segments.  The
replayer times each window once and scales by its repeat count, so a
full BERT-Base forward pass replays one layer's events instead of
twelve layers' worth.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import functools
from typing import Iterator, Mapping, Optional, Sequence

import numpy as np

from repro.core import paging

# accesys dtype names <-> element sizes; tile geometry depends only on
# the element size, so each size maps onto one canonical numpy dtype.
ELEM_BYTES = {"int8": 1, "int16": 2, "int32": 4,
              "fp8": 1, "fp16": 2, "fp32": 4}
_NP_FOR_ELEM = {1: "int8", 2: "float16", 4: "float32"}


def np_dtype_for(dtype) -> str:
    """Canonical numpy dtype name for an accesys or numpy dtype."""
    if isinstance(dtype, str) and dtype in ELEM_BYTES:
        return _NP_FOR_ELEM[ELEM_BYTES[dtype]]
    return _NP_FOR_ELEM[paging.dtype_bytes(dtype)]


def elem_bytes_for(dtype) -> int:
    if isinstance(dtype, str) and dtype in ELEM_BYTES:
        return ELEM_BYTES[dtype]
    return paging.dtype_bytes(dtype)


class EventKind(enum.Enum):
    DMA_IN = "DMA_IN"
    COMPUTE = "COMPUTE"
    DMA_OUT = "DMA_OUT"
    COLLECTIVE = "COLLECTIVE"      # inter-device exchange hop (multidev)


@dataclasses.dataclass(frozen=True)
class Event:
    """One node of the stream graph.

    ``page`` is a ``(tensor_name, page_id)`` key — the same key the
    PageStore, SMMU TLB and LLC see, so functional and timing runs touch
    identical page streams.  ``lane`` is the DMA-channel / double-buffer
    lane (A-operand lane 0, B-operand lane 1; ``meta["buf"]`` carries the
    ping-pong buffer index).  ``deps`` are eids that must complete first
    (data edges; resource serialization is the replayer's job).
    """
    eid: int
    kind: EventKind
    nbytes: int = 0
    page: Optional[tuple] = None
    deps: tuple = ()
    lane: int = 0
    op: str = ""
    unit: str = "sa"              # COMPUTE: "sa" (accelerator) | "host"
    meta: Mapping = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class TensorSpec:
    rows: int
    cols: int
    roles: set                     # subset of {"A", "B", "C", "P"}
    kind: str = "input"            # input | weight | intermediate | output
    # role "P" (paged): pre-paged pool tensor (e.g. a KV-cache pool);
    # ``pages`` is the number of distinct pool pages the plan touches.
    pages: Optional[int] = None


@dataclasses.dataclass
class StreamPlan:
    """A topologically-ordered event list plus its tensor registry."""
    name: str
    dtype: str                     # canonical numpy dtype name
    page_bytes: int
    events: list
    tensors: dict                  # name -> TensorSpec
    macs: int = 0
    n_calls: int = 0               # offloaded launches (doorbell+IRQ each)
    total_steps: int = 0           # inner steps the plan logically covers
    sampled_steps: int = 0         # steps materialized (== total unless sampled)
    exact_events: int = 0          # events the unsampled plan would hold
                                   # (0 -> len(events); see n_exact_events)

    @property
    def n_exact_events(self) -> int:
        return self.exact_events or len(self.events)

    # ------------------------------------------------------------ info
    @property
    def footprint_pages(self) -> int:
        """Pages the SMMU can see: per tensor, one page set per role
        (a tensor produced as C tiles and re-consumed as an A operand
        occupies both page namespaces, exactly as the replayer keys them).
        Computed once per instance — plans are immutable after
        ``validate()``, and every replay re-reads this.
        """
        cached = self.__dict__.get("_footprint_pages")
        if cached is None:
            cached = 0
            for spec in self.tensors.values():
                for role in spec.roles:
                    cached += self._role_pages(spec, role)
            self.__dict__["_footprint_pages"] = cached
        return cached

    def _role_pages(self, spec: TensorSpec, role: str) -> int:
        if role == "P":
            return spec.pages or 0
        if role == "C":
            w = paging.SA_DIM
            return (-(-spec.rows // w)) * (-(-spec.cols // w))
        lay = paging.layout_for((spec.rows, spec.cols), self.dtype, role,
                                self.page_bytes)
        return lay.n_pages

    def counts(self) -> dict:
        """Event statistics (page loads per tensor, computes, stores)."""
        loads: dict = {}
        stores: dict = {}
        sa = host = coll = coll_bytes = 0
        for ev in self.events:
            if ev.kind is EventKind.DMA_IN:
                loads[ev.page[0]] = loads.get(ev.page[0], 0) + 1
            elif ev.kind is EventKind.DMA_OUT:
                stores[ev.page[0]] = stores.get(ev.page[0], 0) + 1
            elif ev.kind is EventKind.COLLECTIVE:
                coll += 1
                coll_bytes += ev.nbytes
            elif ev.unit == "sa":
                sa += 1
            else:
                host += 1
        out = {"dma_in": loads, "dma_out": stores,
               "sa_computes": sa, "host_computes": host,
               "n_events": len(self.events)}
        if coll:
            out["collectives"] = coll
            out["collective_bytes"] = coll_bytes
        return out

    def validate(self) -> None:
        """Events must be topologically ordered with in-plan deps."""
        seen: set = set()
        for ev in self.events:
            assert ev.eid not in seen, f"duplicate eid {ev.eid}"
            for d in ev.deps:
                assert d in seen, f"event {ev.eid} depends on unseen {d}"
            seen.add(ev.eid)

    def compile(self) -> "CompiledPlan":
        """Array-form view of this plan for the compiled replayer —
        built once per plan instance and cached on it (the memoized
        plan builders make that cache effective across benchmark
        sweeps)."""
        c = self.__dict__.get("_compiled")
        if c is None:
            c = _compile_events([self.events])
            self.__dict__["_compiled"] = c
        return c


# ------------------------------------------------- compiled (array) form
OP_SA, OP_HOST, OP_OUT, OP_TAIL, OP_COLL = 1, 2, 3, 4, 5


@dataclasses.dataclass
class CompiledPlan:
    """Structure-of-arrays form of a replayable event stream.

    Event kinds, DMA lanes, byte counts, SA depths and host element
    counts become flat NumPy arrays; page keys are interned to dense
    int ids so the SMMU/LLC models can price the whole access trace in
    one vectorized stack-distance pass.  The replay timeline collapses
    to a sequence of *ops* — SA computes, host computes, DMA-outs and
    end-of-stream drains — each owning the contiguous run of DMA-in
    events it consumes (``grp_end``), which is exactly the
    double-buffer grouping the event-loop replayer discovers
    dynamically.  ``seg_op`` / ``seg_trace`` mark sub-stream boundaries
    so a ``PlanSchedule``'s segments can be replayed on one continuous
    timeline with per-segment deltas read off afterwards.  ``memo``
    caches trace-intrinsic LRU results (stack distances do not depend
    on any cache parameter), so one compile serves every mode and
    system config.
    """
    n_events: int
    page_keys: list               # interned page id -> event .page key
    trace_ids: np.ndarray         # int32 per DMA access, event order
    trace_nbytes: np.ndarray      # float64 per DMA access
    trace_is_out: np.ndarray      # bool per DMA access (DMA_OUT)
    in_lane: np.ndarray           # int16 per DMA_IN (trace subsequence)
    op_kind: np.ndarray           # int8 per op (OP_*)
    op_val: np.ndarray            # float64: SA depth | host elems | 0
    grp_end: np.ndarray           # int64 per op: DMA_INs consumed so far
    n_lanes: np.ndarray           # int16 per op: distinct pending lanes
    seg_op: np.ndarray            # int64 cumulative op count per stream
    seg_trace: np.ndarray         # int64 cumulative DMA count per stream
    memo: dict = dataclasses.field(default_factory=dict)

    @property
    def n_ops(self) -> int:
        return int(self.op_kind.size)

    def relabel(self, page_map: dict) -> "CompiledPlan":
        """Cheap page-id relabel: a new ``CompiledPlan`` for the same
        event structure under renamed page keys (``page_map`` maps old
        key -> new key, identity for unmapped keys).  Every
        page-id-independent array (byte counts, lanes, op kinds,
        drain-group boundaries, segment marks) and every
        page-id-independent ``memo`` entry is shared BY REFERENCE;
        only the interned-id column is re-derived — and when the
        relabel is injective (the usual case: distinct physical pages)
        even ``trace_ids`` is shared, making an instance O(pages
        touched), not O(events).  Keys that collapse (e.g. shared
        prefix pages mapped into several slots) re-intern in
        first-access order, exactly as compiling the relabeled events
        would."""
        keys = [page_map.get(key, key) for key in self.page_keys]
        intern: dict = {}
        out_keys: list = []
        ids = _reintern_skeleton(self, keys, intern, out_keys)
        return dataclasses.replace(
            self, page_keys=out_keys, trace_ids=ids,
            memo=_geometry_memo(self.memo))


def _compile_events(streams: Sequence[list], intern: dict = None,
                    page_keys: list = None) -> CompiledPlan:
    """Lower one or more event lists (a plan, or a schedule's segment
    plans back-to-back) into a ``CompiledPlan``.  Pending DMA_INs
    attach to the next COMPUTE regardless of interleaved DMA_OUTs, and
    each stream ends with an ``OP_TAIL`` barrier that drains trailing
    fetches — the same grouping ``_replay_events`` applies event by
    event.  Passing a shared ``intern``/``page_keys`` pair threads one
    page-id namespace through successive calls, so a long trace can be
    compiled chunk by chunk while cross-chunk page reuse stays visible
    to the LRU analyses."""
    if intern is None:
        intern = {}
    if page_keys is None:
        page_keys = []
    t_ids: list = []
    t_nb: list = []
    t_out: list = []
    in_lane: list = []
    opk: list = []
    opv: list = []
    gend: list = []
    nl: list = []
    seg_op: list = []
    seg_trace: list = []
    n_events = 0
    consumed = 0
    for events in streams:
        n_events += len(events)
        glanes: set = set()
        for ev in events:
            k = ev.kind
            if k is EventKind.DMA_IN:
                pid = intern.get(ev.page)
                if pid is None:
                    pid = intern[ev.page] = len(page_keys)
                    page_keys.append(ev.page)
                t_ids.append(pid)
                t_nb.append(ev.nbytes)
                t_out.append(False)
                in_lane.append(ev.lane)
                glanes.add(ev.lane)
            elif k is EventKind.COMPUTE:
                if ev.unit == "sa":
                    opk.append(OP_SA)
                    opv.append(float(ev.meta["depth"]))
                else:
                    opk.append(OP_HOST)
                    opv.append(float(ev.meta["elems"]))
                nl.append(len(glanes))
                glanes = set()
                consumed = len(in_lane)
                gend.append(consumed)
            elif k is EventKind.COLLECTIVE:
                # one inter-device exchange hop: no page traffic on the
                # host<->device path (the fabric owns dedicated links),
                # just a fabric-priced barrier op on the timeline —
                # pending fetches of the NEXT op keep prefetching
                # underneath it, exactly like a DMA_OUT drain
                opk.append(OP_COLL)
                opv.append(float(ev.nbytes))
                gend.append(consumed)
                nl.append(0)
            else:                                  # DMA_OUT
                pid = intern.get(ev.page)
                if pid is None:
                    pid = intern[ev.page] = len(page_keys)
                    page_keys.append(ev.page)
                t_ids.append(pid)
                t_nb.append(ev.nbytes)
                t_out.append(True)
                opk.append(OP_OUT)
                opv.append(0.0)
                gend.append(consumed)
                nl.append(0)
        # every stream ends with a drain barrier, pending fetches or
        # not: an empty tail is numerically inert (nothing pending, and
        # its ready value is already folded into t_sa), but it pins a
        # segment boundary at every plan end, which is what lets a
        # chunked compile+replay of the same streams stay bitwise equal
        # to the monolithic one
        opk.append(OP_TAIL)
        opv.append(0.0)
        nl.append(len(glanes))
        consumed = len(in_lane)
        gend.append(consumed)
        seg_op.append(len(opk))
        seg_trace.append(len(t_ids))
    return CompiledPlan(
        n_events=n_events, page_keys=page_keys,
        trace_ids=np.asarray(t_ids, np.int32),
        trace_nbytes=np.asarray(t_nb, np.float64),
        trace_is_out=np.asarray(t_out, bool),
        in_lane=np.asarray(in_lane, np.int16),
        op_kind=np.asarray(opk, np.int8),
        op_val=np.asarray(opv, np.float64),
        grp_end=np.asarray(gend, np.int64),
        n_lanes=np.asarray(nl, np.int16),
        seg_op=np.asarray(seg_op, np.int64),
        seg_trace=np.asarray(seg_trace, np.int64))


# --------------------------------------------------- plan templating
# ``CompiledPlan.memo`` entries derived ONLY from event structure (op
# kinds, DMA lanes, drain-group and segment boundaries) — safe to share
# by reference between a template skeleton and every relabeled
# instance.  Everything else ("prev"/"sd" stack distances, "mru"
# orders, ("l2", te) subset analyses, ...) is derived from the interned
# page-id column and must be recomputed per instance.
_GEOMETRY_MEMO_KEYS = ("gs", "npend", "hasp", "inout_pos", "lanes",
                       "lane_masks", "lane_pack", "out_ops", "segb")


def _geometry_memo(memo: dict) -> dict:
    return {k: memo[k] for k in _GEOMETRY_MEMO_KEYS if k in memo}


def _reintern_skeleton(sk: "CompiledPlan", keys: list, intern: dict,
                       page_keys: list) -> np.ndarray:
    """Re-derive a skeleton's interned-id column under relabeled page
    keys (``keys`` index-aligned with ``sk.page_keys``), interning into
    the caller's namespace — the shared chunk namespace during trace
    assembly, or a fresh one for a standalone instance compile.
    Returns the global ``trace_ids`` column; when the namespace started
    empty and no keys collapse, the skeleton's own column is shared by
    reference (the relabel is then pure bookkeeping)."""
    base = len(page_keys)
    l2g = np.empty(len(keys), np.int32)
    for i, key in enumerate(keys):
        pid = intern.get(key)
        if pid is None:
            pid = intern[key] = len(page_keys)
            page_keys.append(key)
        l2g[i] = pid
    if base == 0 and len(page_keys) == len(keys):
        return sk.trace_ids            # identity relabel: 0..n-1 again
    return l2g[sk.trace_ids]


def _plan_n_events(p) -> int:
    n = getattr(p, "n_events", None)
    return len(p.events) if n is None else int(n)


def _compiled_part(p, intern: dict, page_keys: list) -> tuple:
    """One plan's compiled columns with globally interned page ids —
    spliced from the template skeleton when the plan is a
    ``TemplatedPlan`` (no event graph is materialized), compiled from
    the event list otherwise."""
    sk = getattr(p, "skeleton", None)
    if sk is not None:
        ids = _reintern_skeleton(sk, p.inst_keys, intern, page_keys)
        return (ids, sk.trace_nbytes, sk.trace_is_out, sk.in_lane,
                sk.op_kind, sk.op_val, sk.grp_end, sk.n_lanes,
                sk.seg_op, sk.seg_trace, sk.n_events)
    c = _compile_events([p.events], intern, page_keys)
    return (c.trace_ids, c.trace_nbytes, c.trace_is_out, c.in_lane,
            c.op_kind, c.op_val, c.grp_end, c.n_lanes, c.seg_op,
            c.seg_trace, c.n_events)


def _concat_parts(parts: list, page_keys: list) -> CompiledPlan:
    """Concatenate per-plan compiled columns (page ids already global)
    into one ``CompiledPlan`` — ``grp_end`` shifts by the DMA_INs of
    the preceding plans, ``seg_op``/``seg_trace`` by their op/access
    counts, reproducing ``_compile_events`` over the same plans' event
    lists bit for bit (every value is the same int/float in the same
    position; only the walk that produced it differs)."""
    t_ids: list = []
    t_nb: list = []
    t_out: list = []
    lanes: list = []
    opk: list = []
    opv: list = []
    gend: list = []
    nl: list = []
    sop: list = []
    strc: list = []
    in_off = op_off = tr_off = 0
    n_events = 0
    for (ids, nb, out, lane, kind, val, ge, nlanes, so, st, nev) \
            in parts:
        t_ids.append(ids)
        t_nb.append(nb)
        t_out.append(out)
        lanes.append(lane)
        opk.append(kind)
        opv.append(val)
        gend.append(ge + in_off if in_off else ge)
        nl.append(nlanes)
        sop.append(so + op_off if op_off else so)
        strc.append(st + tr_off if tr_off else st)
        in_off += lane.size
        op_off += kind.size
        tr_off += ids.size
        n_events += nev
    cat = (lambda xs: xs[0]) if len(parts) == 1 else np.concatenate
    return CompiledPlan(
        n_events=n_events, page_keys=page_keys,
        trace_ids=cat(t_ids), trace_nbytes=cat(t_nb),
        trace_is_out=cat(t_out), in_lane=cat(lanes),
        op_kind=cat(opk), op_val=cat(opv), grp_end=cat(gend),
        n_lanes=cat(nl), seg_op=cat(sop), seg_trace=cat(strc))


def _compile_plans(plans: Sequence, intern: dict = None,
                   page_keys: list = None) -> CompiledPlan:
    """Compile a batch of plans into one ``CompiledPlan``, splicing
    templated instances from their skeletons and walking raw plans'
    events — bitwise-identical to ``_compile_events`` over everyone's
    event lists."""
    if intern is None:
        intern = {}
        page_keys = []
    if not any(getattr(p, "skeleton", None) is not None for p in plans):
        return _compile_events([p.events for p in plans], intern,
                               page_keys)
    return _concat_parts([_compiled_part(p, intern, page_keys)
                          for p in plans], page_keys)


def trace_footprint(plans) -> int:
    """Distinct page keys a sequence of plans touches — the global
    address-space footprint the SMMU walk model needs before a chunked
    replay can price its first chunk.  Accepts any iterable of
    ``StreamPlan``s or ``TemplatedPlan``s (a generator is consumed);
    templated instances contribute their relabeled key slots directly,
    without materializing events."""
    seen: set = set()
    for p in plans:
        keys = getattr(p, "inst_keys", None)
        if keys is not None:
            seen.update(keys)
            continue
        for ev in p.events:
            if ev.kind is not EventKind.COMPUTE and ev.page is not None:
                seen.add(ev.page)
    return len(seen)


def compile_trace_chunks(plans, chunk_events: int = 262_144):
    """Compile a (possibly unbounded) sequence of plans into bounded
    ``CompiledPlan`` chunks, splitting only at plan boundaries.

    Yields ``(compiled_chunk, plan_batch)`` pairs.  All chunks share
    ONE page-id namespace (the same ``intern``/``page_keys`` objects
    thread through every compile), so cross-chunk and cross-request
    page reuse — the prefix-caching / KV-pool-recycling signal —
    survives chunking; only the compiled arrays themselves are
    chunk-sized.  ``plans`` may be a generator: at most one chunk of
    plans is held at a time.  ``TemplatedPlan`` instances are spliced
    from their compiled skeletons (an array concatenation plus a
    per-unique-page re-intern), so a fully templated trace compiles in
    O(unique structure) instead of O(events)."""
    if chunk_events < 1:
        raise ValueError(f"chunk_events must be >= 1: {chunk_events}")
    intern: dict = {}
    page_keys: list = []
    batch: list = []
    n = 0
    for p in plans:
        batch.append(p)
        n += _plan_n_events(p)
        if n >= chunk_events:
            yield _compile_plans(batch, intern, page_keys), batch
            batch, n = [], 0
    if batch:
        yield _compile_plans(batch, intern, page_keys), batch


class TemplatedPlan:
    """A template instance: one geometry's compiled skeleton plus this
    step's page-key relabel — the ``(template_key, page_map)`` record
    the serving engine emits instead of a fresh event graph.

    Duck-types ``StreamPlan`` for every replay-path consumer (name /
    dtype / page_bytes / macs / n_calls / step counters), while
    ``compile_trace_chunks`` / ``trace_footprint`` /
    ``PlanSchedule.compile`` splice the skeleton arrays directly.
    Anything that genuinely needs the event graph (the functional
    executor, the event-engine parity path, event-level invariants)
    still works: ``.events`` lazily re-runs the original builder with
    this instance's real page ids and caches the result, so the
    materialized plan is exactly what the non-templated path would
    have recorded."""

    total_steps = 0
    sampled_steps = 0
    exact_events = 0

    __slots__ = ("skeleton", "inst_keys", "name", "dtype", "page_bytes",
                 "macs", "n_calls", "_build", "_plan", "_compiled")

    def __init__(self, skeleton: CompiledPlan, inst_keys: list, *,
                 name: str, dtype: str, page_bytes: int, macs: int,
                 n_calls: int, build):
        self.skeleton = skeleton
        self.inst_keys = inst_keys    # relabeled skeleton.page_keys
        self.name = name
        self.dtype = dtype
        self.page_bytes = page_bytes
        self.macs = macs
        self.n_calls = n_calls
        self._build = build
        self._plan = None
        self._compiled = None

    @property
    def n_events(self) -> int:
        return self.skeleton.n_events

    @property
    def n_exact_events(self) -> int:
        return self.skeleton.n_events

    def materialize(self) -> StreamPlan:
        """The full event-graph ``StreamPlan`` this instance stands
        for (the builder re-run with the real page ids) — cached."""
        p = self._plan
        if p is None:
            p = self._plan = self._build()
        return p

    @property
    def events(self) -> list:
        return self.materialize().events

    @property
    def tensors(self) -> dict:
        return self.materialize().tensors

    @property
    def footprint_pages(self) -> int:
        return self.materialize().footprint_pages

    def counts(self) -> dict:
        return self.materialize().counts()

    def validate(self) -> None:
        pass                  # structure was validated at template time

    def compile(self) -> CompiledPlan:
        """Standalone compiled form: the skeleton re-interned under
        this instance's keys (collapsing duplicates in first-access
        order), sharing every geometry array and page-id-independent
        memo entry with the skeleton — identical arrays to compiling
        the freshly built plan."""
        c = self._compiled
        if c is None:
            intern: dict = {}
            page_keys: list = []
            ids = _reintern_skeleton(self.skeleton, self.inst_keys,
                                     intern, page_keys)
            c = dataclasses.replace(
                self.skeleton, page_keys=page_keys, trace_ids=ids,
                memo=_geometry_memo(self.skeleton.memo))
            self._compiled = c
        return c


class PlanTemplate:
    """Compile-once, instance-many plan templating (the tentpole of
    O(unique structure) trace construction).

    A serving trace is thousands of structurally identical plans:
    every decode step at a given page-table composition, every prefill
    at a given (prompt, span) shape, every swap of n pages — only the
    pool page ids (and the swap tag) differ step to step.  A template
    builds and compiles the plan ONCE per geometry, with canonical
    page ids ``0..n-1``, then hands out ``TemplatedPlan`` instances
    whose construction cost is one dict lookup plus an O(pages
    touched) key relabel.  Slot-bearing names and per-request uids
    never enter the geometry key (they don't change the compiled
    arrays); score/output scratch keys relabel to themselves, exactly
    as the raw builders reuse them across steps."""

    def __init__(self, maxsize: int = 512):
        self._cache: collections.OrderedDict = collections.OrderedDict()
        self._maxsize = maxsize
        self.hits = 0
        self.misses = 0

    def clear(self) -> None:
        self._cache.clear()
        self.hits = self.misses = 0

    def _skeleton(self, key, build):
        ent = self._cache.get(key)
        if ent is None:
            self.misses += 1
            plan = build()
            ent = (plan.compile(), plan)
            self._cache[key] = ent
            if len(self._cache) > self._maxsize:
                self._cache.popitem(last=False)
        else:
            self.hits += 1
            self._cache.move_to_end(key)
        return ent

    @staticmethod
    def _pool_relabel(sk: CompiledPlan, pools, idmap: dict) -> list:
        """Relabel the skeleton's key slots: canonical pool-page ids
        map through ``idmap`` inside the named pool namespaces; every
        other key (score/output scratch, weight and activation pages)
        is shared across instances on purpose."""
        keys = []
        for key in sk.page_keys:
            t, p = key
            a = idmap.get(p) if t in pools else None
            keys.append(key if a is None else (t, a))
        return keys

    # ------------------------------------------------------- builders
    def decode_step(self, page_tables, lens, page_tokens: int,
                    n_kv_heads: int, head_dim: int, elem: int, *,
                    n_q_heads: Optional[int] = None, n_layers: int = 1,
                    out: str = "decode_out",
                    name: str = "decode_step") -> TemplatedPlan:
        tables = [tuple(int(p) for p in t) for t in page_tables]
        lens = [int(ln) for ln in lens]
        npgs = tuple(len(t) for t in tables)
        HQ = n_kv_heads if n_q_heads is None else n_q_heads
        key = ("decode", npgs, page_tokens, n_kv_heads, head_dim, elem,
               HQ, n_layers, out)
        canon: list = []
        c = 0
        for npg in npgs:
            canon.append(tuple(range(c, c + npg)))
            c += npg
        sk, skp = self._skeleton(key, lambda: decode_step_plan(
            canon, [npg * page_tokens for npg in npgs], page_tokens,
            n_kv_heads, head_dim, elem, n_q_heads=n_q_heads,
            n_layers=n_layers, out=out, name=name))
        idmap: dict = {}
        for ct, at in zip(canon, tables):
            for cp_, ap in zip(ct, at):
                idmap[cp_] = ap
        pools = set()
        for i in range(n_layers):
            P = f"L{i}." if n_layers > 1 else ""
            pools.update((P + "k", P + "v"))
        build = lambda: decode_step_plan(
            tables, lens, page_tokens, n_kv_heads, head_dim, elem,
            n_q_heads=n_q_heads, n_layers=n_layers, out=out, name=name)
        return TemplatedPlan(
            sk, self._pool_relabel(sk, pools, idmap), name=name,
            dtype=skp.dtype, page_bytes=skp.page_bytes, macs=skp.macs,
            n_calls=skp.n_calls, build=build)

    def prefill(self, page_table, prompt_len: int, page_tokens: int,
                n_kv_heads: int, head_dim: int, elem: int, *,
                n_q_heads: Optional[int] = None,
                d_model: Optional[int] = None,
                d_ff: Optional[int] = None, n_layers: int = 1,
                span: Optional[tuple] = None,
                out: str = "prefill_out",
                name: str = "prefill") -> TemplatedPlan:
        T = int(prompt_len)
        npg = -(-T // page_tokens)
        tbl = tuple(int(p) for p in page_table)[:npg]
        if len(tbl) != npg:
            raise ValueError(
                f"page_table holds {len(page_table)} pages but a "
                f"{T}-token prompt needs {npg}")
        sp = None if span is None else (int(span[0]), int(span[1]))
        HQ = n_kv_heads if n_q_heads is None else n_q_heads
        key = ("prefill", T, sp, page_tokens, n_kv_heads, head_dim,
               elem, HQ, d_model, d_ff, n_layers, out)
        sk, skp = self._skeleton(key, lambda: prefill_plan(
            tuple(range(npg)), T, page_tokens, n_kv_heads, head_dim,
            elem, n_q_heads=n_q_heads, d_model=d_model, d_ff=d_ff,
            n_layers=n_layers, span=sp, out=out, name=name))
        idmap = dict(zip(range(npg), tbl))
        pools = set()
        for i in range(n_layers):
            P = f"L{i}." if n_layers > 1 else ""
            pools.update((P + "k", P + "v"))
        build = lambda: prefill_plan(
            tbl, T, page_tokens, n_kv_heads, head_dim, elem,
            n_q_heads=n_q_heads, d_model=d_model, d_ff=d_ff,
            n_layers=n_layers, span=sp, out=out, name=name)
        s0, s1 = (0, T) if sp is None else sp
        tag = "" if sp is None else f".{s0}-{s1}"
        return TemplatedPlan(
            sk, self._pool_relabel(sk, pools, idmap),
            name=f"{name}{T}t{n_layers}l{tag}", dtype=skp.dtype,
            page_bytes=skp.page_bytes, macs=skp.macs,
            n_calls=skp.n_calls, build=build)

    def swap(self, n_pages: int, page_tokens: int, n_kv_heads: int,
             head_dim: int, elem: int, *, direction: str, tag,
             n_layers: int = 1) -> TemplatedPlan:
        key = ("swap", n_pages, direction, n_layers, page_tokens,
               n_kv_heads, head_dim, elem)
        sk, skp = self._skeleton(key, lambda: swap_plan(
            n_pages, page_tokens, n_kv_heads, head_dim, elem,
            direction=direction, tag=0, n_layers=n_layers))
        # every skeleton key is (ns, (0, j)) — retag the host region
        inst_keys = [(t, (tag, p[1])) for t, p in sk.page_keys]
        build = lambda: swap_plan(
            n_pages, page_tokens, n_kv_heads, head_dim, elem,
            direction=direction, tag=tag, n_layers=n_layers)
        return TemplatedPlan(
            sk, inst_keys, name=f"swap_{direction}.u{tag}",
            dtype=skp.dtype, page_bytes=skp.page_bytes, macs=skp.macs,
            n_calls=skp.n_calls, build=build)


# Process-global template store: geometry keys are fully qualified
# (page/head/layer shapes, element size, span, output name), so one
# cache safely serves every engine in the process; forked sweep
# workers inherit a read-only snapshot and grow their own entries.
PLAN_TEMPLATES = PlanTemplate()


# --------------------------------------------------------------- compose
def concat(plans: Sequence[StreamPlan], name: str = "composed",
           barrier: bool = True) -> StreamPlan:
    """Sequential composition: renumber eids, merge tensor registries,
    and (with ``barrier``) add a dependency edge from each sub-plan's
    last event to the next sub-plan's first — activations produced by
    op N feed op N+1."""
    if not plans:
        raise ValueError("concat() needs at least one sub-plan")
    events: list = []
    tensors: dict = {}
    macs = n_calls = total = sampled = exact = 0
    offset = 0
    prev_last: Optional[int] = None
    dtype = plans[0].dtype
    page_bytes = plans[0].page_bytes
    for p in plans:
        assert p.dtype == dtype and p.page_bytes == page_bytes, \
            (p.name, p.dtype, p.page_bytes)
        for name_, spec in p.tensors.items():
            if name_ in tensors:
                t = tensors[name_]
                assert (t.rows, t.cols) == (spec.rows, spec.cols), \
                    f"tensor {name_} redeclared with a different shape"
                t.roles |= spec.roles
                if spec.kind != "input":
                    t.kind = spec.kind
                if spec.pages:
                    t.pages = max(t.pages or 0, spec.pages)
            else:
                tensors[name_] = TensorSpec(spec.rows, spec.cols,
                                            set(spec.roles), spec.kind,
                                            spec.pages)
        for idx, ev in enumerate(p.events):
            deps = tuple(d + offset for d in ev.deps)
            if barrier and idx == 0 and prev_last is not None:
                deps = (prev_last,) + deps
            events.append(dataclasses.replace(
                ev, eid=ev.eid + offset, deps=deps))
        if p.events:
            prev_last = events[-1].eid
            offset = events[-1].eid + 1
        macs += p.macs
        n_calls += p.n_calls
        total += p.total_steps
        sampled += p.sampled_steps
        exact += p.n_exact_events
    return StreamPlan(name, dtype, page_bytes, events, tensors,
                      macs=macs, n_calls=n_calls,
                      total_steps=total, sampled_steps=sampled,
                      exact_events=exact)


# ----------------------------------------------------- sampled schedules
@dataclasses.dataclass
class PlanSchedule:
    """Steady-state-sampled view of a composed plan.

    ``segments`` is an ordered list of ``(StreamPlan, repeat)`` pairs:
    each sub-plan is a steady window replayed once and scaled by its
    repeat count (N identical transformer layers -> one layer's
    sub-plans, each repeated N times).  The replayer walks segments
    sequentially against shared SMMU/LLC state, so within-window page
    reuse is timed exactly while the cross-repeat steady state is
    assumed — the approximation that keeps a BERT-Base replay at tens of
    thousands of events instead of hundreds of thousands.
    """
    name: str
    segments: list                 # [(StreamPlan, int repeat)]

    @property
    def macs(self) -> int:
        return sum(p.macs * r for p, r in self.segments)

    @property
    def n_calls(self) -> int:
        return sum(p.n_calls * r for p, r in self.segments)

    @property
    def footprint_pages(self) -> int:
        """SMMU-visible pages of the FULL (unsampled) workload: every
        repeat owns its own tensors (layer i's weights are distinct
        pages from layer j's), so windows count once per repeat.
        Cached per instance (schedules are immutable after
        ``validate()``, like the plans they hold)."""
        cached = self.__dict__.get("_footprint_pages")
        if cached is None:
            cached = sum(p.footprint_pages * r for p, r in self.segments)
            self.__dict__["_footprint_pages"] = cached
        return cached

    @property
    def sampled_events(self) -> int:
        return sum(len(p.events) for p, _ in self.segments)

    @property
    def exact_events(self) -> int:
        return sum(p.n_exact_events * r for p, r in self.segments)

    def validate(self) -> None:
        for p, r in self.segments:
            assert r >= 1, (p.name, r)
            p.validate()

    def compile(self) -> "CompiledPlan":
        """One compiled stream over the schedule's segments back to
        back (page interning shared, segment boundaries recorded), so
        the compiled replayer can walk a whole sampling pass on one
        continuous timeline — cached on the schedule instance.
        Templated segments splice their skeletons (no event graphs)."""
        c = self.__dict__.get("_compiled")
        if c is None:
            c = _compile_plans([p for p, _ in self.segments])
            self.__dict__["_compiled"] = c
        return c


# ------------------------------------------------------------- Algorithm 1
@dataclasses.dataclass(frozen=True)
class TileStep:
    """One inner-loop step of Algorithm 1 (i, j output tile; k depth)."""
    i: int
    j: int
    k: int
    a_page: int
    b_page: int
    first_k: bool
    last_k: bool
    depth: int                     # effective K depth (last page may be partial)


def gemm_tile_steps(M: int, N: int, K: int, dtype,
                    page_bytes: int = paging.PAGE_BYTES,
                    order: str = "jik") -> Iterator[TileStep]:
    """The paper's loop nest — THE single source of the loop order.
    Default ``jik`` keeps the current B column (K/L pages) hot in the LLC
    across the i-sweep (§3.3 'blocking improves cache utilization');
    ``ijk`` is the naive un-co-designed baseline."""
    la = paging.layout_for((M, K), np_dtype_for(dtype), "A", page_bytes)
    lb = paging.layout_for((K, N), np_dtype_for(dtype), "B", page_bytes)
    W, L = la.tile_r, la.tile_c
    ni, nj, kk = -(-M // W), -(-N // W), -(-K // L)
    outer, inner = (range(nj), range(ni)) if order == "jik" \
        else (range(ni), range(nj))
    for o in outer:
        for p in inner:
            i, j = (p, o) if order == "jik" else (o, p)
            for k in range(kk):
                yield TileStep(
                    i, j, k,
                    a_page=la.page_of(i * W, k * L),
                    b_page=lb.page_of(k * L, j * W),
                    first_k=(k == 0), last_k=(k == kk - 1),
                    depth=min(L, K - k * L))


def gemm_plan(M: int, N: int, K: int, dtype, *,
              a: str = "a", b: str = "b", c: str = "c",
              order: str = "jik",
              page_bytes: int = paging.PAGE_BYTES,
              sample_stride: int = 1,
              a_kind: str = "input", b_kind: str = "input",
              c_kind: str = "output",
              name: Optional[str] = None) -> StreamPlan:
    """Algorithm-1 GEMM as an event graph: per inner step, DMA-in one A
    page (lane 0) and one B page (lane 1), one W×W×depth compute
    depending on both (and on the previous k step of the same output
    tile — the output-stationary accumulator chain), and after the last
    k a DMA-out of the W×W C tile.

    ``sample_stride > 1`` materializes only every stride-th steady-state
    step (first/last k always kept) for very large problems; the
    replayer scales by ``total_steps / sampled_steps``.
    """
    np_dt = np_dtype_for(dtype)
    elem = paging.dtype_bytes(np_dt)
    la = paging.layout_for((M, K), np_dt, "A", page_bytes)
    W = la.tile_r
    kk = -(-K // la.tile_c)
    events: list = []
    eid = 0
    chain = -1                     # previous compute eid of this (i, j)
    sampled = 0
    for st in gemm_tile_steps(M, N, K, np_dt, page_bytes, order):
        if sample_stride > 1 and ((st.i + st.j) * kk + st.k) \
                % sample_stride and not st.last_k and not st.first_k:
            continue
        sampled += 1
        ea = Event(eid, EventKind.DMA_IN, nbytes=page_bytes,
                   page=(a, st.a_page), lane=0, op="load",
                   meta={"buf": st.k & 1})
        eb = Event(eid + 1, EventKind.DMA_IN, nbytes=page_bytes,
                   page=(b, st.b_page), lane=1, op="load",
                   meta={"buf": st.k & 1})
        deps = (ea.eid, eb.eid) if st.first_k \
            else (ea.eid, eb.eid, chain)
        ec = Event(eid + 2, EventKind.COMPUTE, deps=deps, op="gemm",
                   unit="sa",
                   meta={"i": st.i, "j": st.j, "k": st.k,
                         "depth": st.depth, "first_k": st.first_k,
                         "last_k": st.last_k, "w": W,
                         "a": a, "b": b, "c": c,
                         "a_page": st.a_page, "b_page": st.b_page})
        events += [ea, eb, ec]
        chain = ec.eid
        eid += 3
        if st.last_k:
            events.append(Event(eid, EventKind.DMA_OUT,
                                nbytes=W * W * elem,
                                page=(c, (st.i, st.j)),
                                deps=(ec.eid,), op="store"))
            eid += 1
    ni, nj = -(-M // W), -(-N // W)
    tensors = {a: TensorSpec(M, K, {"A"}, a_kind),
               b: TensorSpec(K, N, {"B"}, b_kind),
               c: TensorSpec(M, N, {"C"}, c_kind)}
    return StreamPlan(name or f"gemm{M}x{N}x{K}", np_dt, page_bytes,
                      events, tensors, macs=M * N * K, n_calls=1,
                      total_steps=ni * nj * kk, sampled_steps=sampled,
                      exact_events=ni * nj * (3 * kk + 1))


# ------------------------------------------------------ memoized builders
@functools.lru_cache(maxsize=64)
def gemm_tile_steps_cached(M: int, N: int, K: int, dtype,
                           page_bytes: int = paging.PAGE_BYTES,
                           order: str = "jik") -> tuple:
    """Materialized ``gemm_tile_steps`` — benchmark sweeps walk the
    same loop nests row after row."""
    return tuple(gemm_tile_steps(M, N, K, dtype, page_bytes, order))


@functools.lru_cache(maxsize=256)
def gemm_plan_cached(M: int, N: int, K: int, dtype, *,
                     page_bytes: int = paging.PAGE_BYTES,
                     sample_stride: int = 1,
                     order: str = "jik") -> StreamPlan:
    """Memoized Algorithm-1 plan with canonical tensor names.  Sweeps
    (``bench_gemm_size``, ``bench_interconnect``, TLB/packet/memory
    sweeps, calibration) re-request identical geometries per mode and
    per link config; the cached plan also carries its compiled form
    and its LRU trace analysis across those calls.  Callers must not
    mutate the returned plan."""
    return gemm_plan(M, N, K, dtype, order=order, page_bytes=page_bytes,
                     sample_stride=sample_stride)


# ------------------------------------------------------------- host ops
def host_plan(op: str, inputs: Sequence[str], output: Optional[str],
              out_shape: Optional[tuple], elems: int, dtype,
              page_bytes: int = paging.PAGE_BYTES,
              meta: Optional[dict] = None,
              out_kind: str = "intermediate",
              outs: Optional[Sequence[tuple]] = None) -> StreamPlan:
    """A single host-side COMPUTE event (softmax / layernorm / gelu /
    slice / concat / add / transpose — the paper keeps these on the CPU,
    §4.2).  ``elems`` sizes the replayer's host-time model.

    ``outs`` (a sequence of ``(name, (rows, cols))`` pairs) declares a
    multi-output op — e.g. MoE dispatch producing one routed buffer per
    expert, or an SSM scan chunk producing (chunk output, carry state);
    the executor stores every named result."""
    m = {"inputs": tuple(inputs), "out": output, "elems": elems}
    if outs is not None:
        m["outs"] = tuple(n for n, _ in outs)
    m.update(meta or {})
    ev = Event(0, EventKind.COMPUTE, op=op, unit="host", meta=m)
    tensors = {}
    if output is not None and out_shape is not None:
        tensors[output] = TensorSpec(out_shape[0], out_shape[1], set(),
                                     out_kind)
    for name_, shape in (outs or ()):
        tensors[name_] = TensorSpec(shape[0], shape[1], set(), out_kind)
    return StreamPlan(f"host.{op}", np_dtype_for(dtype), page_bytes,
                      [ev], tensors)


# ---------------------------------------------------------- collectives
def collective_plan(op: str, hop_bytes: Sequence[int], dtype,
                    page_bytes: int = paging.PAGE_BYTES, *,
                    lane: int = 0, meta: Optional[dict] = None,
                    name: Optional[str] = None) -> StreamPlan:
    """One rank's share of an inter-device collective as a chain of
    per-hop COLLECTIVE events (ring all-gather: p-1 hops of B/p bytes
    each; all-to-all over a full crossbar: p-1 peer transfers; ...).
    The TOPOLOGY decides the hop decomposition at plan-build time —
    ``core.multidev`` owns those builders — so the replayer prices each
    hop as one transfer on the rank's dedicated fabric link, with no
    page traffic on the host<->device path.  ``lane`` tags the fabric
    link the hops ride (rank-tagged collective lanes)."""
    events = [Event(i, EventKind.COLLECTIVE, nbytes=int(nb),
                    deps=(i - 1,) if i else (), lane=lane, op=op,
                    unit="link", meta={"hop": i, **(meta or {})})
              for i, nb in enumerate(hop_bytes)]
    return StreamPlan(name or f"coll.{op}", np_dtype_for(dtype),
                      page_bytes, events, {})


# ----------------------------------------------------------- attention
def _attention_plans(S: int, d_head: int, dtype, *,
                     q: str = "q", kT: str = "kT", v: str = "v",
                     out: str = "attn", prefix: str = "",
                     page_bytes: int = paging.PAGE_BYTES,
                     sample_stride: int = 1) -> list:
    """The three attention sub-plans, kept separate so schedules can
    stride the GEMMs without the stride scale bleeding into the host
    softmax's time."""
    scores, p = prefix + "scores", prefix + "p"
    return [
        gemm_plan(S, S, d_head, dtype, a=q, b=kT, c=scores,
                  c_kind="intermediate", page_bytes=page_bytes,
                  sample_stride=sample_stride),
        host_plan("softmax", (scores,), p, (S, S), S * S, dtype,
                  page_bytes),
        gemm_plan(S, d_head, S, dtype, a=p, b=v, c=out,
                  c_kind="intermediate", page_bytes=page_bytes,
                  sample_stride=sample_stride),
    ]


def attention_plan(S: int, d_head: int, dtype, *,
                   q: str = "q", kT: str = "kT", v: str = "v",
                   out: str = "attn", prefix: str = "",
                   page_bytes: int = paging.PAGE_BYTES,
                   sample_stride: int = 1) -> StreamPlan:
    """Paged attention for one head: QK^T streamed over K pages, host
    softmax, then PV streamed over V pages (paper §4.2: MHA GEMMs on the
    accelerator, softmax on the host)."""
    return concat(_attention_plans(S, d_head, dtype, q=q, kT=kT, v=v,
                                   out=out, prefix=prefix,
                                   page_bytes=page_bytes,
                                   sample_stride=sample_stride),
                  name=f"attention{S}x{d_head}")


# ----------------------------------------------- transformer layer / model
def _transformer_layer_plans(S: int, d_model: int, n_heads: int,
                             d_ff: int, dtype, *, x: str = "x",
                             layer: int = 0, out: Optional[str] = None,
                             page_bytes: int = paging.PAGE_BYTES,
                             sample_stride: int = 1) -> list:
    """The ordered sub-plans of one encoder layer — shared by the exact
    composed plan (``transformer_layer_plan``) and the steady-state
    schedule (``model_schedule``, which keeps the sub-plans as separate
    segments so strided GEMM sampling scales independently of the
    unsampled host ops)."""
    P = f"L{layer}."
    hd = d_model // n_heads
    dt = dtype
    ss = sample_stride
    plans = [gemm_plan(S, 3 * d_model, d_model, dt, a=x, b=P + "wqkv",
                       c=P + "qkv", b_kind="weight", sample_stride=ss,
                       c_kind="intermediate", page_bytes=page_bytes)]
    head_outs = []
    for h in range(n_heads):
        qh, kh, vh = P + f"q{h}", P + f"kT{h}", P + f"v{h}"
        oh = P + f"o{h}"
        plans += [
            host_plan("slice_cols", (P + "qkv",), qh, (S, hd), S * hd, dt,
                      page_bytes, {"start": h * hd, "stop": (h + 1) * hd}),
            host_plan("slice_cols", (P + "qkv",), kh, (hd, S), S * hd, dt,
                      page_bytes, {"start": d_model + h * hd,
                                   "stop": d_model + (h + 1) * hd,
                                   "transpose": True}),
            host_plan("slice_cols", (P + "qkv",), vh, (S, hd), S * hd, dt,
                      page_bytes, {"start": 2 * d_model + h * hd,
                                   "stop": 2 * d_model + (h + 1) * hd}),
        ] + _attention_plans(S, hd, dt, q=qh, kT=kh, v=vh, out=oh,
                             prefix=P + f"h{h}.", page_bytes=page_bytes,
                             sample_stride=ss)
        head_outs.append(oh)
    out = out or P + "out"
    plans += [
        host_plan("concat_cols", tuple(head_outs), P + "attn",
                  (S, d_model), S * d_model, dt, page_bytes),
        gemm_plan(S, d_model, d_model, dt, a=P + "attn", b=P + "wo",
                  c=P + "proj", b_kind="weight", c_kind="intermediate",
                  page_bytes=page_bytes, sample_stride=ss),
        host_plan("add", (x, P + "proj"), P + "res1", (S, d_model),
                  S * d_model, dt, page_bytes),
        host_plan("layernorm", (P + "res1",), P + "ln1", (S, d_model),
                  2 * S * d_model, dt, page_bytes),
        gemm_plan(S, d_ff, d_model, dt, a=P + "ln1", b=P + "w1",
                  c=P + "ff1", b_kind="weight", c_kind="intermediate",
                  page_bytes=page_bytes, sample_stride=ss),
        host_plan("gelu", (P + "ff1",), P + "g", (S, d_ff), S * d_ff, dt,
                  page_bytes),
        gemm_plan(S, d_model, d_ff, dt, a=P + "g", b=P + "w2",
                  c=P + "ff2", b_kind="weight", c_kind="intermediate",
                  page_bytes=page_bytes, sample_stride=ss),
        host_plan("add", (P + "ln1", P + "ff2"), P + "res2", (S, d_model),
                  S * d_model, dt, page_bytes),
        host_plan("layernorm", (P + "res2",), out, (S, d_model),
                  2 * S * d_model, dt, page_bytes,
                  out_kind="output"),
    ]
    return plans


def transformer_layer_plan(S: int, d_model: int, n_heads: int, d_ff: int,
                           dtype, *, x: str = "x", layer: int = 0,
                           out: Optional[str] = None,
                           page_bytes: int = paging.PAGE_BYTES,
                           sample_stride: int = 1) -> StreamPlan:
    """One post-LN encoder layer (BERT/ViT-class) as a composed plan:
    QKV projection -> per-head paged attention -> output projection ->
    residual+LN -> FFN (FF1, gelu, FF2) -> residual+LN.  GEMMs stream
    through the accelerator; everything else is host work."""
    plans = _transformer_layer_plans(
        S, d_model, n_heads, d_ff, dtype, x=x, layer=layer, out=out,
        page_bytes=page_bytes, sample_stride=sample_stride)
    return concat(plans, name=f"layer{layer}")


def model_plan(S: int, d_model: int, n_heads: int, d_ff: int,
               n_layers: int, dtype, *, x: str = "x",
               page_bytes: int = paging.PAGE_BYTES) -> StreamPlan:
    """N stacked encoder layers; layer i's output tensor feeds layer
    i+1.  This is the plan the accesys replayer times end-to-end."""
    plans = []
    inp = x
    for i in range(n_layers):
        plans.append(transformer_layer_plan(
            S, d_model, n_heads, d_ff, dtype, x=inp, layer=i,
            page_bytes=page_bytes))
        inp = f"L{i}.out"
    return concat(plans, name=f"transformer{n_layers}x{d_model}")


def model_schedule(S: int, d_model: int, n_heads: int, d_ff: int,
                   n_layers: int, dtype, *, x: str = "x",
                   page_bytes: int = paging.PAGE_BYTES,
                   sample_stride: int = 1) -> PlanSchedule:
    """Steady-state-sampled counterpart of ``model_plan``: the layer
    stack is homogeneous, so one layer is the steady window — each of
    its sub-plans becomes a segment repeated ``n_layers`` times.  With
    ``sample_stride > 1`` the GEMM segments are additionally
    steady-state sampled inside the window; host-op segments are never
    strided, so their time scales only by the repeat count."""
    plans = _transformer_layer_plans(
        S, d_model, n_heads, d_ff, dtype, x=x, layer=0,
        page_bytes=page_bytes, sample_stride=sample_stride)
    return PlanSchedule(f"transformer{n_layers}x{d_model}~sampled",
                        [(p, n_layers) for p in plans])


def layer_weights(d_model: int, d_ff: int, layer: int = 0) -> dict:
    """Shapes of the weight tensors one layer plan expects — handy for
    building executor inputs."""
    P = f"L{layer}."
    return {P + "wqkv": (d_model, 3 * d_model),
            P + "wo": (d_model, d_model),
            P + "w1": (d_model, d_ff),
            P + "w2": (d_ff, d_model)}


# ------------------------------------------------------------- MoE layer
def _moe_layer_plans(n_tokens: int, d_model: int, n_experts: int,
                     top_k: int, d_ff: int, dtype, *,
                     capacity: Optional[int] = None,
                     capacity_factor: float = 1.25,
                     act: str = "silu", x: str = "x", layer: int = 0,
                     out: Optional[str] = None,
                     page_bytes: int = paging.PAGE_BYTES,
                     sample_stride: int = 1) -> list:
    from repro.models.moe import routed_capacity
    P = f"M{layer}."
    C = routed_capacity(n_tokens * top_k, n_experts, capacity,
                        capacity_factor)
    dt = dtype
    ss = sample_stride
    logits = P + "logits"
    plans = [
        gemm_plan(n_tokens, n_experts, d_model, dt, a=x,
                  b=P + "router", c=logits, b_kind="weight",
                  c_kind="intermediate", page_bytes=page_bytes,
                  sample_stride=ss),
        host_plan("moe_dispatch", (x, logits), None, None,
                  n_experts * C * d_model, dt, page_bytes,
                  meta={"E": n_experts, "k": top_k, "C": C},
                  outs=[(P + f"e{e}.buf", (C, d_model))
                        for e in range(n_experts)]),
    ]
    for e in range(n_experts):
        E = P + f"e{e}."
        plans += [
            gemm_plan(C, d_ff, d_model, dt, a=E + "buf", b=E + "wg",
                      c=E + "g", b_kind="weight", c_kind="intermediate",
                      page_bytes=page_bytes, sample_stride=ss),
            gemm_plan(C, d_ff, d_model, dt, a=E + "buf", b=E + "wu",
                      c=E + "u", b_kind="weight", c_kind="intermediate",
                      page_bytes=page_bytes, sample_stride=ss),
            host_plan("act_mul", (E + "g", E + "u"), E + "h",
                      (C, d_ff), 2 * C * d_ff, dt, page_bytes,
                      meta={"act": act}),
            gemm_plan(C, d_model, d_ff, dt, a=E + "h", b=E + "wo",
                      c=E + "y", b_kind="weight", c_kind="intermediate",
                      page_bytes=page_bytes, sample_stride=ss),
        ]
    out = out or P + "out"
    plans.append(host_plan(
        "moe_combine",
        (logits,) + tuple(P + f"e{e}.y" for e in range(n_experts)),
        out, (n_tokens, d_model), n_tokens * top_k * d_model, dt,
        page_bytes, meta={"E": n_experts, "k": top_k, "C": C},
        out_kind="output"))
    return plans


def moe_layer_plan(n_tokens: int, d_model: int, n_experts: int,
                   top_k: int, d_ff: int, dtype, *,
                   capacity: Optional[int] = None,
                   capacity_factor: float = 1.25,
                   act: str = "silu", x: str = "x", layer: int = 0,
                   out: Optional[str] = None,
                   page_bytes: int = paging.PAGE_BYTES) -> StreamPlan:
    """Expert-routed FFN layer mirroring ``models/moe.py`` grouped-GEMM
    dispatch: router GEMM on the accelerator, host-side top-k sort /
    capacity-C dispatch into per-expert buffers, then per expert the
    gated-FFN GEMM triple (wi_gate, wi_up, wo) over its fixed-capacity
    buffer, and a host combine weighted by the routing probs.

    Every expert streams exactly its capacity-C page set (the routed
    buffers are page-aligned fixed-shape blocks, the activation-side
    analogue of the paper's tiles), so the plan's per-expert page
    traffic is statically known — sum of expert page sets == pages of
    the E x C routed token block.  For strided steady-state sampling
    use ``moe_schedule``: a single strided plan would scale its
    unsampled host ops by the GEMM stride."""
    from repro.models.moe import routed_capacity
    plans = _moe_layer_plans(n_tokens, d_model, n_experts, top_k, d_ff,
                             dtype, capacity=capacity,
                             capacity_factor=capacity_factor, act=act,
                             x=x, layer=layer, out=out,
                             page_bytes=page_bytes)
    C = routed_capacity(n_tokens * top_k, n_experts, capacity,
                        capacity_factor)
    return concat(plans, name=f"moe{layer}.{n_experts}x{C}x{d_ff}")


def moe_schedule(n_tokens: int, d_model: int, n_experts: int,
                 top_k: int, d_ff: int, n_layers: int, dtype, *,
                 capacity: Optional[int] = None,
                 capacity_factor: float = 1.25, act: str = "silu",
                 x: str = "x",
                 page_bytes: int = paging.PAGE_BYTES,
                 sample_stride: int = 1) -> PlanSchedule:
    """Steady-state-sampled N-layer MoE FFN stack: one layer's
    sub-plans as segments repeated ``n_layers`` times, GEMM segments
    optionally strided — host ops stay separate segments so their time
    scales only by the repeat count."""
    plans = _moe_layer_plans(n_tokens, d_model, n_experts, top_k, d_ff,
                             dtype, capacity=capacity,
                             capacity_factor=capacity_factor, act=act,
                             x=x, layer=0, page_bytes=page_bytes,
                             sample_stride=sample_stride)
    return PlanSchedule(f"moe_x{n_layers}~sampled",
                        [(p, n_layers) for p in plans])


def moe_layer_weights(d_model: int, n_experts: int, d_ff: int,
                      layer: int = 0) -> dict:
    """Shapes of the weight tensors ``moe_layer_plan`` expects."""
    P = f"M{layer}."
    w = {P + "router": (d_model, n_experts)}
    for e in range(n_experts):
        w[P + f"e{e}.wg"] = (d_model, d_ff)
        w[P + f"e{e}.wu"] = (d_model, d_ff)
        w[P + f"e{e}.wo"] = (d_ff, d_model)
    return w


# ------------------------------------------------------------- SSM layer
def _ssm_layer_plans(T: int, d_model: int, n_heads: int, dtype, *,
                     chunk: int = 16, x: str = "x", layer: int = 0,
                     out: Optional[str] = None, inclusive: bool = True,
                     page_bytes: int = paging.PAGE_BYTES,
                     sample_stride: int = 1) -> list:
    P = f"S{layer}."
    N = d_model // n_heads
    dt = dtype
    ss = sample_stride
    plans = [
        gemm_plan(T, d_model, d_model, dt, a=x, b=P + "wr", c=P + "r",
                  b_kind="weight", c_kind="intermediate",
                  page_bytes=page_bytes, sample_stride=ss),
        gemm_plan(T, d_model, d_model, dt, a=x, b=P + "wk", c=P + "k",
                  b_kind="weight", c_kind="intermediate",
                  page_bytes=page_bytes, sample_stride=ss),
        gemm_plan(T, d_model, d_model, dt, a=x, b=P + "wv", c=P + "v",
                  b_kind="weight", c_kind="intermediate",
                  page_bytes=page_bytes, sample_stride=ss),
    ]
    nc = -(-T // chunk)
    state = P + "s0"
    chunk_outs = []
    for c in range(nc):
        t0, t1 = c * chunk, min(T, (c + 1) * chunk)
        o, s = P + f"c{c}.o", P + f"c{c}.s"
        plans.append(host_plan(
            "ssm_scan", (P + "r", P + "k", P + "v", P + "logw", state),
            None, None, (t1 - t0) * n_heads * N * N, dt, page_bytes,
            meta={"t0": t0, "t1": t1, "H": n_heads, "N": N,
                  "inclusive": inclusive},
            outs=[(o, (t1 - t0, d_model)), (s, (n_heads * N, N))]))
        state = s
        chunk_outs.append(o)
    out = out or P + "out"
    plans += [
        host_plan("concat_rows", tuple(chunk_outs), P + "scan",
                  (T, d_model), T * d_model, dt, page_bytes),
        gemm_plan(T, d_model, d_model, dt, a=P + "scan", b=P + "wo",
                  c=out, b_kind="weight", c_kind="output",
                  page_bytes=page_bytes, sample_stride=ss),
    ]
    # register the caller-supplied scan inputs on the first sub-plan so
    # both the concat plan and schedule segments know their shapes
    plans[0].tensors[P + "logw"] = TensorSpec(T, d_model, set(), "input")
    plans[0].tensors[P + "s0"] = TensorSpec(n_heads * N, N, set(),
                                            "input")
    return plans


def ssm_layer_plan(T: int, d_model: int, n_heads: int, dtype, *,
                   chunk: int = 16, x: str = "x", layer: int = 0,
                   out: Optional[str] = None, inclusive: bool = True,
                   page_bytes: int = paging.PAGE_BYTES) -> StreamPlan:
    """Scan-structured SSM layer mirroring ``models/ssm.py``: r/k/v
    projections stream through the accelerator, then the sequence is
    processed in pages (chunks) by host-side chunked linear attention —
    each chunk's COMPUTE depends on the previous chunk's carry state
    (the O(state) recurrence that replaces a giant KV cache), forming
    an explicit scan dependency chain — and the gathered outputs feed
    the output projection GEMM.

    Caller supplies ``S{layer}.logw`` (per-token log-decay, (T, d)) and
    ``S{layer}.s0`` (initial state, (H*N, N)) alongside ``x`` and the
    weights from ``ssm_layer_weights``.  For strided steady-state
    sampling use ``ssm_schedule`` (host scan ops must not inherit the
    GEMM stride scale)."""
    plans = _ssm_layer_plans(T, d_model, n_heads, dtype, chunk=chunk,
                             x=x, layer=layer, out=out,
                             inclusive=inclusive, page_bytes=page_bytes)
    return concat(plans, name=f"ssm{layer}.{T}x{d_model}c{chunk}")


def ssm_schedule(T: int, d_model: int, n_heads: int, n_layers: int,
                 dtype, *, chunk: int = 16, x: str = "x",
                 inclusive: bool = True,
                 page_bytes: int = paging.PAGE_BYTES,
                 sample_stride: int = 1) -> PlanSchedule:
    """Steady-state-sampled N-layer SSM stack; see ``moe_schedule``."""
    plans = _ssm_layer_plans(T, d_model, n_heads, dtype, chunk=chunk,
                             x=x, layer=0, inclusive=inclusive,
                             page_bytes=page_bytes,
                             sample_stride=sample_stride)
    return PlanSchedule(f"ssm_x{n_layers}~sampled",
                        [(p, n_layers) for p in plans])


def ssm_layer_weights(d_model: int, layer: int = 0) -> dict:
    """Shapes of the weight tensors ``ssm_layer_plan`` expects."""
    P = f"S{layer}."
    return {P + w: (d_model, d_model) for w in ("wr", "wk", "wv", "wo")}


# ------------------------------------------------------------ decode step
def decode_step_plan(page_tables: Sequence[Sequence[int]],
                     lens: Sequence[int], page_tokens: int,
                     n_kv_heads: int, head_dim: int, elem: int, *,
                     n_q_heads: Optional[int] = None,
                     n_layers: int = 1,
                     q: str = "q", k: str = "k", v: str = "v",
                     out: str = "decode_out",
                     scale: Optional[float] = None,
                     name: str = "decode_step") -> StreamPlan:
    """One batched decode step over a paged KV cache: for every active
    sequence, DMA-in its K pages (ids taken VERBATIM from the page
    table, so plan page traffic equals the pool pages actually
    resident), one QK^T tile per page on the accelerator, drain the
    score blocks, host masked-softmax over the valid length, then the
    PV accumulation streamed over the V pages and one output drain.

    ``page_tables[b]`` lists the pool page ids sequence b holds;
    ``lens[b]`` is its valid token count; ``elem`` is the KV element
    size in bytes.  The plan's ``page_bytes`` is the KV page size, and
    total DMA_IN bytes == n_layers * 2 * sum(held_pages) * page_bytes —
    the bytes actually resident for the batch.

    GQA (``n_q_heads > n_kv_heads``): each KV page is fetched ONCE and
    the q-head fan-out becomes ``n_q_heads / n_kv_heads`` extra SA
    passes over the loaded page (pass g covers the contiguous q-head
    block ``[g*KH, (g+1)*KH)``, each q head reading kv head
    ``h // group``) — KV bytes stay accounted per KV head while compute
    and score traffic scale with the query heads.

    ``n_layers > 1`` composes one per-layer plan (tensor names prefixed
    ``L{i}.``, so each layer's KV pool pages occupy their own SMMU
    namespace, exactly as the per-layer device pools would) via
    ``concat`` — the exact multi-layer step.  ``decode_step_schedule``
    is the steady-state-sampled counterpart (one layer window x
    repeat)."""
    pt, KH, hd = page_tokens, n_kv_heads, head_dim
    HQ = KH if n_q_heads is None else n_q_heads
    assert HQ % KH == 0, (HQ, KH)
    group = HQ // KH
    if n_layers > 1:
        plans = [decode_step_plan(
            page_tables, lens, pt, KH, hd, elem, n_q_heads=HQ,
            q=f"L{i}.{q}", k=f"L{i}.{k}", v=f"L{i}.{v}",
            out=f"L{i}.{out}", scale=scale, name=f"{name}.L{i}")
            for i in range(n_layers)]
        return concat(plans, name=name)
    page_bytes = pt * KH * hd * elem
    np_dt = _NP_FOR_ELEM[elem]
    scale = scale if scale is not None else hd ** -0.5
    events: list = []
    eid = 0
    macs = 0
    B = len(page_tables)
    tensors = {q: TensorSpec(B, HQ * hd, set(), "input"),
               out: TensorSpec(B * HQ, hd, {"C"}, "output")}
    k_pages: set = set()
    v_pages: set = set()
    for b, (tbl, ln) in enumerate(zip(page_tables, lens)):
        tbl = [int(p) for p in tbl]
        npg = len(tbl)
        if npg == 0:
            continue
        scores, p = f"{out}.s{b}", f"{out}.p{b}"
        tensors[scores] = TensorSpec(HQ, npg * pt, set(), "intermediate")
        tensors[p] = TensorSpec(HQ, npg * pt, set(), "intermediate")
        for pi, pid in enumerate(tbl):
            k_pages.add(pid)
            ek = Event(eid, EventKind.DMA_IN, nbytes=page_bytes,
                       page=(k, pid), lane=0, op="load")
            eid += 1
            for g in range(group):
                ec = Event(eid, EventKind.COMPUTE, deps=(ek.eid,),
                           op="attn_qk", unit="sa",
                           meta={"q": q, "k": k, "page": pid, "slot": b,
                                 "page_idx": pi, "heads": KH,
                                 "head_dim": hd, "pt": pt, "depth": hd,
                                 "scores": scores, "g": g,
                                 "q0": g * KH, "n_q": HQ,
                                 "group": group})
                eo = Event(eid + 1, EventKind.DMA_OUT,
                           nbytes=KH * pt * elem,
                           page=(scores, (g, pi)), deps=(ec.eid,),
                           op="store", meta={"at": (g * KH, pi * pt)})
                events += [ec, eo] if g else [ek, ec, eo]
                eid += 2
        sm = Event(eid, EventKind.COMPUTE, deps=(eid - 1,),
                   op="masked_softmax", unit="host",
                   meta={"inputs": (scores,), "out": p,
                         "elems": HQ * npg * pt, "valid": int(ln),
                         "scale": scale})
        events.append(sm)
        eid += 1
        chain = [None] * group
        for pi, pid in enumerate(tbl):
            v_pages.add(pid)
            ev = Event(eid, EventKind.DMA_IN, nbytes=page_bytes,
                       page=(v, pid), lane=1, op="load")
            eid += 1
            for g in range(group):
                deps = (ev.eid, sm.eid) if chain[g] is None \
                    else (ev.eid, sm.eid, chain[g])
                ec = Event(eid, EventKind.COMPUTE, deps=deps,
                           op="attn_pv", unit="sa",
                           meta={"p": p, "v": v, "page": pid, "slot": b,
                                 "page_idx": pi, "heads": KH,
                                 "head_dim": hd, "pt": pt, "depth": pt,
                                 "out": out, "g": g, "q0": g * KH,
                                 "n_q": HQ, "group": group,
                                 "first": pi == 0,
                                 "last": pi == npg - 1})
                events += [ec] if g else [ev, ec]
                chain[g] = ec.eid
                eid += 1
        for g in range(group):
            events.append(Event(eid, EventKind.DMA_OUT,
                                nbytes=KH * hd * elem,
                                page=(out, (b, g)),
                                deps=(chain[g],), op="store",
                                meta={"at": (b * HQ + g * KH, 0)}))
            eid += 1
        macs += npg * pt * HQ * hd * 2         # QK^T + PV per page
    tensors[k] = TensorSpec(len(k_pages) * pt, KH * hd, {"P"}, "input",
                            pages=len(k_pages))
    tensors[v] = TensorSpec(len(v_pages) * pt, KH * hd, {"P"}, "input",
                            pages=len(v_pages))
    return StreamPlan(name, np_dt, page_bytes, events, tensors,
                      macs=macs, n_calls=1)


def decode_step_schedule(page_tables: Sequence[Sequence[int]],
                         lens: Sequence[int], page_tokens: int,
                         n_kv_heads: int, head_dim: int, elem: int,
                         n_layers: int, *,
                         n_q_heads: Optional[int] = None,
                         out: str = "decode_out",
                         scale: Optional[float] = None,
                         name: str = "decode_step") -> PlanSchedule:
    """Steady-state-sampled N-layer decode step: the layer stack is
    homogeneous (every layer streams the same page-table composition),
    so ONE layer's step plan is the steady window, repeated
    ``n_layers`` times — layer i's pool pages are physically distinct
    from layer j's, which is exactly the schedule footprint rule
    (windows count once per repeat)."""
    layer = decode_step_plan(page_tables, lens, page_tokens, n_kv_heads,
                             head_dim, elem, n_q_heads=n_q_heads,
                             out=out, scale=scale,
                             name=f"{name}.layer")
    return PlanSchedule(f"{name}_x{n_layers}~sampled",
                        [(layer, n_layers)])


# ------------------------------------------------------------- prefill
def prefill_plan(page_table: Sequence[int], prompt_len: int,
                 page_tokens: int, n_kv_heads: int, head_dim: int,
                 elem: int, *,
                 n_q_heads: Optional[int] = None,
                 d_model: Optional[int] = None,
                 d_ff: Optional[int] = None,
                 n_layers: int = 1,
                 x: str = "prompt", k: str = "k", v: str = "v",
                 out: str = "prefill_out",
                 scale: Optional[float] = None,
                 span: Optional[tuple] = None,
                 name: str = "prefill") -> StreamPlan:
    """One request's prompt prefill over the SAME ``PageTable`` pages a
    decode step streams: per layer, a weight-streaming QKV projection
    GEMM (Algorithm 1), DMA-out of the freshly produced K/V into the
    sequence's pool pages (ids verbatim from the page table), then
    chunked causal attention — the prompt is processed in page-sized
    query chunks, each chunk streaming the KV pages written so far
    (QK^T per page per q-head group, host masked-softmax over the
    causal length, PV accumulation) — followed by the output-projection
    and FFN weight-streaming GEMMs.

    ``page_table`` lists the pool page ids the sequence holds (the
    prompt occupies the first ``ceil(prompt_len / page_tokens)`` of
    them); causality is modeled at chunk granularity (chunk i attends
    to the first ``(i+1) * page_tokens`` positions).  Multi-layer plans
    prefix all tensor names ``L{i}.`` so each layer's weights and KV
    pages own their SMMU namespace; layer i's output feeds layer i+1.

    ``span=(t0, t1)`` restricts the plan to prefilling query tokens
    ``[t0, t1)`` of the prompt — chunked-prefill admission splits a
    long prompt into successive span plans over the SAME page table,
    each attending over every KV page written so far (pages ``[0,
    ceil(t1 / page_tokens))``), so earlier chunks' pool pages are
    re-streamed exactly as a later decode step would re-stream them.
    ``t0`` must be page-aligned; ``t1`` page-aligned or the prompt
    end.  The default span ``(0, prompt_len)`` produces the identical
    plan this builder has always produced.
    """
    pt, KH, hd = page_tokens, n_kv_heads, head_dim
    HQ = KH if n_q_heads is None else n_q_heads
    assert HQ % KH == 0, (HQ, KH)
    group = HQ // KH
    T = int(prompt_len)
    npg = -(-T // pt)
    tbl = [int(p) for p in page_table][:npg]
    if len(tbl) != npg:
        raise ValueError(
            f"page_table holds {len(page_table)} pages but a "
            f"{T}-token prompt needs {npg}")
    s0, s1 = (0, T) if span is None else (int(span[0]), int(span[1]))
    if not (0 <= s0 < s1 <= T) or s0 % pt or (s1 != T and s1 % pt):
        raise ValueError(
            f"span {span} invalid for a {T}-token prompt with "
            f"{pt}-token pages (start page-aligned, end page-aligned "
            f"or the prompt end)")
    c0, c1 = s0 // pt, -(-s1 // pt)
    Tq = s1 - s0                        # query tokens this plan covers
    dm = d_model if d_model is not None else HQ * hd
    dff = d_ff if d_ff is not None else 4 * dm
    page_bytes = pt * KH * hd * elem
    np_dt = _NP_FOR_ELEM[elem]
    scale = scale if scale is not None else hd ** -0.5

    def layer_plans(P: str, x_in: str, out_name: str) -> list:
        kt, vt = P + k, P + v
        plans = [gemm_plan(Tq, (HQ + 2 * KH) * hd, dm, np_dt, a=x_in,
                           b=P + "wqkv", c=P + "qkv", b_kind="weight",
                           c_kind="intermediate", page_bytes=page_bytes)]
        # write the freshly projected K/V into the sequence's pool
        # pages — the same physical pages every later decode step (and
        # every later chunk of this prefill) streams back in
        events: list = []
        eid = 0
        for pid in tbl[c0:c1]:
            for pool in (kt, vt):
                events.append(Event(eid, EventKind.DMA_OUT,
                                    nbytes=page_bytes,
                                    page=(pool, pid), op="store"))
                eid += 1
        kv_spec = lambda: TensorSpec(npg * pt, KH * hd, {"P"},
                                     "intermediate", pages=npg)
        plans.append(StreamPlan(P + "kv_write", np_dt, page_bytes,
                                events, {kt: kv_spec(), vt: kv_spec()}))
        # chunked causal attention over the written pages
        events = []
        eid = 0
        macs = 0
        attn = P + "attn"
        # rows = this span's query tokens (the wo GEMM consumes the
        # same Tq-row view); store offsets below are span-relative
        tensors = {attn: TensorSpec(Tq, HQ * hd, {"C"}, "intermediate"),
                   kt: kv_spec(), vt: kv_spec()}
        for ci in range(c0, c1):
            t1 = min(s1, (ci + 1) * pt)
            qt = t1 - ci * pt
            kv_upto = ci + 1
            scores, p = P + f"c{ci}.s", P + f"c{ci}.p"
            tensors[scores] = TensorSpec(HQ * qt, kv_upto * pt, set(),
                                         "intermediate")
            tensors[p] = TensorSpec(HQ * qt, kv_upto * pt, set(),
                                    "intermediate")
            for pi in range(kv_upto):
                ek = Event(eid, EventKind.DMA_IN, nbytes=page_bytes,
                           page=(kt, tbl[pi]), lane=0, op="load")
                eid += 1
                for g in range(group):
                    ec = Event(eid, EventKind.COMPUTE, deps=(ek.eid,),
                               op="prefill_qk", unit="sa",
                               meta={"chunk": ci, "page_idx": pi,
                                     "heads": KH, "q_tokens": qt,
                                     "depth": hd, "g": g})
                    eo = Event(eid + 1, EventKind.DMA_OUT,
                               nbytes=KH * qt * pt * elem,
                               page=(scores, (g, pi)), deps=(ec.eid,),
                               op="store",
                               meta={"at": (g * KH * qt, pi * pt)})
                    events += [ec, eo] if g else [ek, ec, eo]
                    eid += 2
            sm = Event(eid, EventKind.COMPUTE, deps=(eid - 1,),
                       op="masked_softmax", unit="host",
                       meta={"inputs": (scores,), "out": p,
                             "elems": HQ * qt * kv_upto * pt,
                             "valid": t1, "scale": scale})
            events.append(sm)
            eid += 1
            chain = [None] * group
            for pi in range(kv_upto):
                ev = Event(eid, EventKind.DMA_IN, nbytes=page_bytes,
                           page=(vt, tbl[pi]), lane=1, op="load")
                eid += 1
                for g in range(group):
                    deps = (ev.eid, sm.eid) if chain[g] is None \
                        else (ev.eid, sm.eid, chain[g])
                    ec = Event(eid, EventKind.COMPUTE, deps=deps,
                               op="prefill_pv", unit="sa",
                               meta={"chunk": ci, "page_idx": pi,
                                     "heads": KH, "q_tokens": qt,
                                     "depth": pt, "g": g,
                                     "first": pi == 0,
                                     "last": pi == kv_upto - 1})
                    events += [ec] if g else [ev, ec]
                    chain[g] = ec.eid
                    eid += 1
            for g in range(group):
                events.append(Event(eid, EventKind.DMA_OUT,
                                    nbytes=KH * qt * hd * elem,
                                    page=(attn, (ci, g)),
                                    deps=(chain[g],), op="store",
                                    meta={"at": (ci * pt - s0,
                                                 g * KH * hd)}))
                eid += 1
            macs += qt * HQ * kv_upto * pt * hd * 2
        plans.append(StreamPlan(P + "chunked_attn", np_dt, page_bytes,
                                events, tensors, macs=macs, n_calls=1))
        plans += [
            gemm_plan(Tq, dm, HQ * hd, np_dt, a=attn, b=P + "wo",
                      c=P + "proj", b_kind="weight",
                      c_kind="intermediate", page_bytes=page_bytes),
            host_plan("layernorm", (P + "proj",), P + "ln", (Tq, dm),
                      2 * Tq * dm, np_dt, page_bytes),
            gemm_plan(Tq, dff, dm, np_dt, a=P + "ln", b=P + "w1",
                      c=P + "ff1", b_kind="weight",
                      c_kind="intermediate", page_bytes=page_bytes),
            host_plan("gelu", (P + "ff1",), P + "g", (Tq, dff),
                      Tq * dff, np_dt, page_bytes),
            gemm_plan(Tq, dm, dff, np_dt, a=P + "g", b=P + "w2",
                      c=out_name, b_kind="weight", c_kind="output",
                      page_bytes=page_bytes),
        ]
        return plans

    plans: list = []
    inp = x
    for i in range(n_layers):
        P = f"L{i}." if n_layers > 1 else ""
        out_name = f"L{i}.{out}" if n_layers > 1 and i < n_layers - 1 \
            else out
        plans += layer_plans(P, inp, out_name)
        inp = out_name
    tag = "" if span is None else f".{s0}-{s1}"
    return concat(plans, name=f"{name}{T}t{n_layers}l{tag}")


# ---------------------------------------------------------------- swap
SWAP_LANE = 2      # DMA channel for KV swap traffic (A=0, B=1)


def swap_plan(n_pages: int, page_tokens: int, n_kv_heads: int,
              head_dim: int, elem: int, *, direction: str, tag,
              n_layers: int = 1, k: str = "k", v: str = "v",
              name: Optional[str] = None) -> StreamPlan:
    """Page-aligned KV swap between the device pool and host memory —
    the preemption path priced as ordinary DMA traffic.

    ``direction="out"`` emits one DMA_OUT per resident K and V page
    per layer (the victim's KV streamed to a host swap region);
    ``direction="in"`` emits the matching DMA_INs on resume.  Swap
    pages live in their own SMMU namespace (``L{i}.k.swap`` /
    ``L{i}.v.swap``) keyed ``(tag, page_index)`` — ``tag`` (the
    request uid) makes the host region stable across a request's
    swap-out/swap-in pair, so the LLC/TLB models see the swap-in
    re-touch exactly the pages the swap-out wrote, and a second
    preemption of the same request reuses its region.  Swap-in DMAs
    ride a dedicated lane (``SWAP_LANE``) so they group as their own
    transfer stream, not as attention operand traffic.

    The result is an exact repeat-1 ``StreamPlan`` like every other
    serving record, so swap-bearing traces flow through
    ``replay_trace`` / ``replay_trace_streamed`` unchanged (and stay
    bitwise-identical under chunking)."""
    if direction not in ("out", "in"):
        raise ValueError(f"direction must be 'out' or 'in': {direction}")
    if n_pages < 1:
        raise ValueError(f"swap_plan needs >= 1 page, got {n_pages}")
    page_bytes = page_tokens * n_kv_heads * head_dim * elem
    np_dt = _NP_FOR_ELEM[elem]
    kind = EventKind.DMA_OUT if direction == "out" else EventKind.DMA_IN
    events: list = []
    tensors: dict = {}
    eid = 0
    for i in range(n_layers):
        P = f"L{i}." if n_layers > 1 else ""
        for pool in (P + k, P + v):
            ns = pool + ".swap"
            tensors[ns] = TensorSpec(n_pages * page_tokens,
                                     n_kv_heads * head_dim, {"P"},
                                     "intermediate", pages=n_pages)
            for j in range(n_pages):
                events.append(Event(
                    eid, kind, nbytes=page_bytes, page=(ns, (tag, j)),
                    lane=SWAP_LANE, op=f"swap_{direction}"))
                eid += 1
    if name is None:
        name = f"swap_{direction}.u{tag}"
    return StreamPlan(name, np_dt, page_bytes, events, tensors,
                      n_calls=1)
