"""StreamPlan — the event-graph IR unifying streaming (paper Figs. 2/6).

One typed event graph of ``DMA_IN`` / ``COMPUTE`` / ``DMA_OUT`` events —
carrying page ids, byte counts, dependency edges and double-buffer lane
assignments — is the single source of truth for the paper's Algorithm-1
loop nest.  Two consumers share it:

  * ``core.streaming.execute_plan`` — the *functional* executor: runs the
    plan tile-by-tile through a mode-aware ``PageStore`` (DM / DC /
    DevMem) and returns numerical results plus metered traffic;
  * ``accesys.pipeline.replay`` — the *timing* replayer: replays the same
    events against the PCIe/DRAM/SMMU/LLC component models and returns
    the Fig.-2 latency buckets.

Builders cover the paper's GEMM (Algorithm 1), paged attention
(QK^T -> softmax -> PV streaming over KV pages), and full transformer
layers / N-layer models composed from per-op plans — which is what lets
the accesys simulator produce end-to-end BERT/ViT-class numbers instead
of per-GEMM ones.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, Mapping, Optional, Sequence

from repro.core import paging

# accesys dtype names <-> element sizes; tile geometry depends only on
# the element size, so each size maps onto one canonical numpy dtype.
ELEM_BYTES = {"int8": 1, "int16": 2, "int32": 4,
              "fp8": 1, "fp16": 2, "fp32": 4}
_NP_FOR_ELEM = {1: "int8", 2: "float16", 4: "float32"}


def np_dtype_for(dtype) -> str:
    """Canonical numpy dtype name for an accesys or numpy dtype."""
    if isinstance(dtype, str) and dtype in ELEM_BYTES:
        return _NP_FOR_ELEM[ELEM_BYTES[dtype]]
    return _NP_FOR_ELEM[paging.dtype_bytes(dtype)]


def elem_bytes_for(dtype) -> int:
    if isinstance(dtype, str) and dtype in ELEM_BYTES:
        return ELEM_BYTES[dtype]
    return paging.dtype_bytes(dtype)


class EventKind(enum.Enum):
    DMA_IN = "DMA_IN"
    COMPUTE = "COMPUTE"
    DMA_OUT = "DMA_OUT"


@dataclasses.dataclass(frozen=True)
class Event:
    """One node of the stream graph.

    ``page`` is a ``(tensor_name, page_id)`` key — the same key the
    PageStore, SMMU TLB and LLC see, so functional and timing runs touch
    identical page streams.  ``lane`` is the DMA-channel / double-buffer
    lane (A-operand lane 0, B-operand lane 1; ``meta["buf"]`` carries the
    ping-pong buffer index).  ``deps`` are eids that must complete first
    (data edges; resource serialization is the replayer's job).
    """
    eid: int
    kind: EventKind
    nbytes: int = 0
    page: Optional[tuple] = None
    deps: tuple = ()
    lane: int = 0
    op: str = ""
    unit: str = "sa"              # COMPUTE: "sa" (accelerator) | "host"
    meta: Mapping = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class TensorSpec:
    rows: int
    cols: int
    roles: set                     # subset of {"A", "B", "C"}
    kind: str = "input"            # input | weight | intermediate | output


@dataclasses.dataclass
class StreamPlan:
    """A topologically-ordered event list plus its tensor registry."""
    name: str
    dtype: str                     # canonical numpy dtype name
    page_bytes: int
    events: list
    tensors: dict                  # name -> TensorSpec
    macs: int = 0
    n_calls: int = 0               # offloaded launches (doorbell+IRQ each)
    total_steps: int = 0           # inner steps the plan logically covers
    sampled_steps: int = 0         # steps materialized (== total unless sampled)

    # ------------------------------------------------------------ info
    @property
    def footprint_pages(self) -> int:
        """Pages the SMMU can see: per tensor, one page set per role
        (a tensor produced as C tiles and re-consumed as an A operand
        occupies both page namespaces, exactly as the replayer keys them).
        """
        total = 0
        for spec in self.tensors.values():
            for role in spec.roles:
                total += self._role_pages(spec, role)
        return total

    def _role_pages(self, spec: TensorSpec, role: str) -> int:
        if role == "C":
            w = paging.SA_DIM
            return (-(-spec.rows // w)) * (-(-spec.cols // w))
        lay = paging.layout_for((spec.rows, spec.cols), self.dtype, role,
                                self.page_bytes)
        return lay.n_pages

    def counts(self) -> dict:
        """Event statistics (page loads per tensor, computes, stores)."""
        loads: dict = {}
        stores: dict = {}
        sa = host = 0
        for ev in self.events:
            if ev.kind is EventKind.DMA_IN:
                loads[ev.page[0]] = loads.get(ev.page[0], 0) + 1
            elif ev.kind is EventKind.DMA_OUT:
                stores[ev.page[0]] = stores.get(ev.page[0], 0) + 1
            elif ev.unit == "sa":
                sa += 1
            else:
                host += 1
        return {"dma_in": loads, "dma_out": stores,
                "sa_computes": sa, "host_computes": host,
                "n_events": len(self.events)}

    def validate(self) -> None:
        """Events must be topologically ordered with in-plan deps."""
        seen: set = set()
        for ev in self.events:
            assert ev.eid not in seen, f"duplicate eid {ev.eid}"
            for d in ev.deps:
                assert d in seen, f"event {ev.eid} depends on unseen {d}"
            seen.add(ev.eid)


# --------------------------------------------------------------- compose
def concat(plans: Sequence[StreamPlan], name: str = "composed",
           barrier: bool = True) -> StreamPlan:
    """Sequential composition: renumber eids, merge tensor registries,
    and (with ``barrier``) add a dependency edge from each sub-plan's
    last event to the next sub-plan's first — activations produced by
    op N feed op N+1."""
    if not plans:
        raise ValueError("concat() needs at least one sub-plan")
    events: list = []
    tensors: dict = {}
    macs = n_calls = total = sampled = 0
    offset = 0
    prev_last: Optional[int] = None
    dtype = plans[0].dtype
    page_bytes = plans[0].page_bytes
    for p in plans:
        assert p.dtype == dtype and p.page_bytes == page_bytes, \
            (p.name, p.dtype, p.page_bytes)
        for name_, spec in p.tensors.items():
            if name_ in tensors:
                t = tensors[name_]
                assert (t.rows, t.cols) == (spec.rows, spec.cols), \
                    f"tensor {name_} redeclared with a different shape"
                t.roles |= spec.roles
                if spec.kind != "input":
                    t.kind = spec.kind
            else:
                tensors[name_] = TensorSpec(spec.rows, spec.cols,
                                            set(spec.roles), spec.kind)
        for idx, ev in enumerate(p.events):
            deps = tuple(d + offset for d in ev.deps)
            if barrier and idx == 0 and prev_last is not None:
                deps = (prev_last,) + deps
            events.append(dataclasses.replace(
                ev, eid=ev.eid + offset, deps=deps))
        if p.events:
            prev_last = events[-1].eid
            offset = events[-1].eid + 1
        macs += p.macs
        n_calls += p.n_calls
        total += p.total_steps
        sampled += p.sampled_steps
    return StreamPlan(name, dtype, page_bytes, events, tensors,
                      macs=macs, n_calls=n_calls,
                      total_steps=total, sampled_steps=sampled)


# ------------------------------------------------------------- Algorithm 1
@dataclasses.dataclass(frozen=True)
class TileStep:
    """One inner-loop step of Algorithm 1 (i, j output tile; k depth)."""
    i: int
    j: int
    k: int
    a_page: int
    b_page: int
    first_k: bool
    last_k: bool
    depth: int                     # effective K depth (last page may be partial)


def gemm_tile_steps(M: int, N: int, K: int, dtype,
                    page_bytes: int = paging.PAGE_BYTES,
                    order: str = "jik") -> Iterator[TileStep]:
    """The paper's loop nest — THE single source of the loop order.
    Default ``jik`` keeps the current B column (K/L pages) hot in the LLC
    across the i-sweep (§3.3 'blocking improves cache utilization');
    ``ijk`` is the naive un-co-designed baseline."""
    la = paging.layout_for((M, K), np_dtype_for(dtype), "A", page_bytes)
    lb = paging.layout_for((K, N), np_dtype_for(dtype), "B", page_bytes)
    W, L = la.tile_r, la.tile_c
    ni, nj, kk = -(-M // W), -(-N // W), -(-K // L)
    outer, inner = (range(nj), range(ni)) if order == "jik" \
        else (range(ni), range(nj))
    for o in outer:
        for p in inner:
            i, j = (p, o) if order == "jik" else (o, p)
            for k in range(kk):
                yield TileStep(
                    i, j, k,
                    a_page=la.page_of(i * W, k * L),
                    b_page=lb.page_of(k * L, j * W),
                    first_k=(k == 0), last_k=(k == kk - 1),
                    depth=min(L, K - k * L))


def gemm_plan(M: int, N: int, K: int, dtype, *,
              a: str = "a", b: str = "b", c: str = "c",
              order: str = "jik",
              page_bytes: int = paging.PAGE_BYTES,
              sample_stride: int = 1,
              a_kind: str = "input", b_kind: str = "input",
              c_kind: str = "output",
              name: Optional[str] = None) -> StreamPlan:
    """Algorithm-1 GEMM as an event graph: per inner step, DMA-in one A
    page (lane 0) and one B page (lane 1), one W×W×depth compute
    depending on both (and on the previous k step of the same output
    tile — the output-stationary accumulator chain), and after the last
    k a DMA-out of the W×W C tile.

    ``sample_stride > 1`` materializes only every stride-th steady-state
    step (first/last k always kept) for very large problems; the
    replayer scales by ``total_steps / sampled_steps``.
    """
    np_dt = np_dtype_for(dtype)
    elem = paging.dtype_bytes(np_dt)
    la = paging.layout_for((M, K), np_dt, "A", page_bytes)
    W = la.tile_r
    kk = -(-K // la.tile_c)
    events: list = []
    eid = 0
    chain = -1                     # previous compute eid of this (i, j)
    sampled = 0
    for st in gemm_tile_steps(M, N, K, np_dt, page_bytes, order):
        if sample_stride > 1 and ((st.i + st.j) * kk + st.k) \
                % sample_stride and not st.last_k and not st.first_k:
            continue
        sampled += 1
        ea = Event(eid, EventKind.DMA_IN, nbytes=page_bytes,
                   page=(a, st.a_page), lane=0, op="load",
                   meta={"buf": st.k & 1})
        eb = Event(eid + 1, EventKind.DMA_IN, nbytes=page_bytes,
                   page=(b, st.b_page), lane=1, op="load",
                   meta={"buf": st.k & 1})
        deps = (ea.eid, eb.eid) if st.first_k \
            else (ea.eid, eb.eid, chain)
        ec = Event(eid + 2, EventKind.COMPUTE, deps=deps, op="gemm",
                   unit="sa",
                   meta={"i": st.i, "j": st.j, "k": st.k,
                         "depth": st.depth, "first_k": st.first_k,
                         "last_k": st.last_k, "w": W,
                         "a": a, "b": b, "c": c,
                         "a_page": st.a_page, "b_page": st.b_page})
        events += [ea, eb, ec]
        chain = ec.eid
        eid += 3
        if st.last_k:
            events.append(Event(eid, EventKind.DMA_OUT,
                                nbytes=W * W * elem,
                                page=(c, (st.i, st.j)),
                                deps=(ec.eid,), op="store"))
            eid += 1
    ni, nj = -(-M // W), -(-N // W)
    tensors = {a: TensorSpec(M, K, {"A"}, a_kind),
               b: TensorSpec(K, N, {"B"}, b_kind),
               c: TensorSpec(M, N, {"C"}, c_kind)}
    return StreamPlan(name or f"gemm{M}x{N}x{K}", np_dt, page_bytes,
                      events, tensors, macs=M * N * K, n_calls=1,
                      total_steps=ni * nj * kk, sampled_steps=sampled)


# ------------------------------------------------------------- host ops
def host_plan(op: str, inputs: Sequence[str], output: Optional[str],
              out_shape: Optional[tuple], elems: int, dtype,
              page_bytes: int = paging.PAGE_BYTES,
              meta: Optional[dict] = None,
              out_kind: str = "intermediate") -> StreamPlan:
    """A single host-side COMPUTE event (softmax / layernorm / gelu /
    slice / concat / add / transpose — the paper keeps these on the CPU,
    §4.2).  ``elems`` sizes the replayer's host-time model."""
    m = {"inputs": tuple(inputs), "out": output, "elems": elems}
    m.update(meta or {})
    ev = Event(0, EventKind.COMPUTE, op=op, unit="host", meta=m)
    tensors = {}
    if output is not None and out_shape is not None:
        tensors[output] = TensorSpec(out_shape[0], out_shape[1], set(),
                                     out_kind)
    return StreamPlan(f"host.{op}", np_dtype_for(dtype), page_bytes,
                      [ev], tensors)


# ----------------------------------------------------------- attention
def attention_plan(S: int, d_head: int, dtype, *,
                   q: str = "q", kT: str = "kT", v: str = "v",
                   out: str = "attn", prefix: str = "",
                   page_bytes: int = paging.PAGE_BYTES) -> StreamPlan:
    """Paged attention for one head: QK^T streamed over K pages, host
    softmax, then PV streamed over V pages (paper §4.2: MHA GEMMs on the
    accelerator, softmax on the host)."""
    scores, p = prefix + "scores", prefix + "p"
    return concat([
        gemm_plan(S, S, d_head, dtype, a=q, b=kT, c=scores,
                  c_kind="intermediate", page_bytes=page_bytes),
        host_plan("softmax", (scores,), p, (S, S), S * S, dtype,
                  page_bytes),
        gemm_plan(S, d_head, S, dtype, a=p, b=v, c=out,
                  c_kind="intermediate", page_bytes=page_bytes),
    ], name=f"attention{S}x{d_head}")


# ----------------------------------------------- transformer layer / model
def transformer_layer_plan(S: int, d_model: int, n_heads: int, d_ff: int,
                           dtype, *, x: str = "x", layer: int = 0,
                           out: Optional[str] = None,
                           page_bytes: int = paging.PAGE_BYTES
                           ) -> StreamPlan:
    """One post-LN encoder layer (BERT/ViT-class) as a composed plan:
    QKV projection -> per-head paged attention -> output projection ->
    residual+LN -> FFN (FF1, gelu, FF2) -> residual+LN.  GEMMs stream
    through the accelerator; everything else is host work."""
    P = f"L{layer}."
    hd = d_model // n_heads
    dt = dtype
    plans = [gemm_plan(S, 3 * d_model, d_model, dt, a=x, b=P + "wqkv",
                       c=P + "qkv", b_kind="weight",
                       c_kind="intermediate", page_bytes=page_bytes)]
    head_outs = []
    for h in range(n_heads):
        qh, kh, vh = P + f"q{h}", P + f"kT{h}", P + f"v{h}"
        oh = P + f"o{h}"
        plans += [
            host_plan("slice_cols", (P + "qkv",), qh, (S, hd), S * hd, dt,
                      page_bytes, {"start": h * hd, "stop": (h + 1) * hd}),
            host_plan("slice_cols", (P + "qkv",), kh, (hd, S), S * hd, dt,
                      page_bytes, {"start": d_model + h * hd,
                                   "stop": d_model + (h + 1) * hd,
                                   "transpose": True}),
            host_plan("slice_cols", (P + "qkv",), vh, (S, hd), S * hd, dt,
                      page_bytes, {"start": 2 * d_model + h * hd,
                                   "stop": 2 * d_model + (h + 1) * hd}),
            attention_plan(S, hd, dt, q=qh, kT=kh, v=vh, out=oh,
                           prefix=P + f"h{h}.", page_bytes=page_bytes),
        ]
        head_outs.append(oh)
    out = out or P + "out"
    plans += [
        host_plan("concat_cols", tuple(head_outs), P + "attn",
                  (S, d_model), S * d_model, dt, page_bytes),
        gemm_plan(S, d_model, d_model, dt, a=P + "attn", b=P + "wo",
                  c=P + "proj", b_kind="weight", c_kind="intermediate",
                  page_bytes=page_bytes),
        host_plan("add", (x, P + "proj"), P + "res1", (S, d_model),
                  S * d_model, dt, page_bytes),
        host_plan("layernorm", (P + "res1",), P + "ln1", (S, d_model),
                  2 * S * d_model, dt, page_bytes),
        gemm_plan(S, d_ff, d_model, dt, a=P + "ln1", b=P + "w1",
                  c=P + "ff1", b_kind="weight", c_kind="intermediate",
                  page_bytes=page_bytes),
        host_plan("gelu", (P + "ff1",), P + "g", (S, d_ff), S * d_ff, dt,
                  page_bytes),
        gemm_plan(S, d_model, d_ff, dt, a=P + "g", b=P + "w2",
                  c=P + "ff2", b_kind="weight", c_kind="intermediate",
                  page_bytes=page_bytes),
        host_plan("add", (P + "ln1", P + "ff2"), P + "res2", (S, d_model),
                  S * d_model, dt, page_bytes),
        host_plan("layernorm", (P + "res2",), out, (S, d_model),
                  2 * S * d_model, dt, page_bytes,
                  out_kind="output"),
    ]
    return concat(plans, name=f"layer{layer}")


def model_plan(S: int, d_model: int, n_heads: int, d_ff: int,
               n_layers: int, dtype, *, x: str = "x",
               page_bytes: int = paging.PAGE_BYTES) -> StreamPlan:
    """N stacked encoder layers; layer i's output tensor feeds layer
    i+1.  This is the plan the accesys replayer times end-to-end."""
    plans = []
    inp = x
    for i in range(n_layers):
        plans.append(transformer_layer_plan(
            S, d_model, n_heads, d_ff, dtype, x=inp, layer=i,
            page_bytes=page_bytes))
        inp = f"L{i}.out"
    return concat(plans, name=f"transformer{n_layers}x{d_model}")


def layer_weights(d_model: int, d_ff: int, layer: int = 0) -> dict:
    """Shapes of the weight tensors one layer plan expects — handy for
    building executor inputs."""
    P = f"L{layer}."
    return {P + "wqkv": (d_model, 3 * d_model),
            P + "wo": (d_model, d_model),
            P + "w1": (d_model, d_ff),
            P + "w2": (d_ff, d_model)}
