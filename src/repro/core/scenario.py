"""Scenario API — the declarative front door to the streaming simulator.

A ``Scenario`` names a workload (any ``configs/*.py`` ``ModelConfig``,
one of the paper's BERT/ViT models, or a synthetic workload class) plus
the knobs that make it runnable — dtype, seq/batch, memory mode,
replay engine, sampling policy, serving parameters — and
``simulate(scenario)`` lowers it to a ``StreamPlan``/``PlanSchedule``,
replays it against the accesys component models, and returns a typed
``SimResult`` (Fig.-2 buckets, TLB stats, events/sec, per-request
percentiles when serving, stable ``to_json()`` schema).  ``sweep``
runs many scenarios with shared plan/compile caching, so a DM/DC/DevMem
sweep builds (and compiles) each plan once.

The lowering is registry-driven: ``WORKLOAD_REGISTRY`` maps a config
*family* to a layer-class stack builder —

  * ``dense`` / ``vlm`` — GQA/MQA attention + (gated or plain) MLP;
  * ``moe``   — attention (MLA-aware for deepseek-v3) + expert-routed
    FFN, honoring ``MoEConfig.first_dense_layers`` (dense layers first)
    and ``n_shared_experts`` (an always-on dense expert branch);
  * ``ssm``   — rwkv-style chunked-scan time mix + channel-mix FFN;
  * ``hybrid``— zamba2: mamba2 layers with the shared attention+MLP
    block inserted every ``SSMConfig.attn_every`` layers;
  * ``audio`` — whisper: encoder self-attention layers plus decoder
    layers with cross-attention over the encoder memory.

A heterogeneous stack (zamba2's mamba/attention interleave) lowers to
ONE steady window per layer *class*, each with its own repeat count —
the heterogeneous-schedule follow-on of the steady-state sampling work.
Unknown scenario names raise ``UnsupportedScenario`` with a
did-you-mean hint; unknown families raise it too (never ``KeyError``).
"""
from __future__ import annotations

import dataclasses
import difflib
import functools
import os
import time
from collections import OrderedDict
from typing import Callable, Optional, Sequence

from repro.core import multidev as MD
from repro.core import paging
from repro.core import plan as plan_ir
from repro.core.plan import (PlanSchedule, StreamPlan, concat, gemm_plan,
                             host_plan)

PAGE_BYTES = paging.PAGE_BYTES
MODES = ("DM", "DC", "DevMem")
ENGINES = ("auto", "event", "compiled", "both")

# tiny-but-representative geometry for the synthetic workload classes
# (override any of these through ``Scenario.params``)
MOE_SHAPE = dict(n_tokens=64, d_model=128, n_experts=8, top_k=2,
                 d_ff=256, capacity_factor=1.25)
SSM_SHAPE = dict(T=128, d_model=128, n_heads=4, chunk=16)
DECODE_SHAPE = dict(n_pages=64, page_tokens=8, n_kv_heads=4,
                    head_dim=32, max_pages_per_seq=8,
                    prompt_lens=(20, 9, 33), churn=((1, 12),),
                    n_q_heads=None)
SERVE_SHAPE = dict(arch="qwen2_0_5b", slots=2, n_requests=5,
                   max_new_tokens=6, max_seq=48, prompt_lo=8,
                   prompt_hi=8, seed=0)


class UnsupportedScenario(ValueError):
    """Raised for unknown scenario names / model families — always with
    the valid alternatives spelled out, never a bare ``KeyError``."""


def as_params(**kw) -> tuple:
    """Workload-shape overrides as the hashable ``Scenario.params``
    form: a sorted tuple of (key, value) pairs."""
    return tuple(sorted(kw.items()))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One declarative simulator run.  ``model`` is any name from
    ``scenario_names()``: a config-zoo ``ModelConfig`` name (full or
    ``-reduced``), a paper model (``bert-base`` …), a workload-class
    alias (``bert``/``vit``), or a synthetic class (``moe``/``ssm``/
    ``decode``/``serve``).  ``params`` carries per-class shape
    overrides (see ``as_params``)."""
    model: str
    dtype: str = "int8"            # int8|int16|int32|fp8|fp16|fp32
    mode: str = "DC"               # DM | DC | DevMem
    seq: Optional[int] = None      # tokens = batch * seq (default: per-model)
    batch: int = 1
    n_layers: Optional[int] = None # cap the layer stack
    sampling: str = "sampled"      # sampled | exact
    sample_stride: int = 1         # stride GEMM inner loops of windows
    engine: str = "auto"           # auto | event | compiled | both
    devmem_dram: str = "HBM2"      # DRAM tech for DevMem mode
    page_bytes: int = PAGE_BYTES   # streaming page/tile granularity
    params: tuple = ()             # workload-class overrides (as_params)
    tp: int = 1                    # tensor-parallel degree (model axis)
    ep: int = 1                    # expert-parallel degree (MoE only)
    fabric: str = "ring"           # interconnect "topo[:GB/s[:hop_ns]]"
    pcie_gb_s: Optional[float] = None  # host-link bandwidth override

    def __post_init__(self):
        if self.mode not in MODES:
            raise UnsupportedScenario(
                f"unknown memory mode {self.mode!r}; valid: {MODES}")
        if self.page_bytes < 256 or \
                self.page_bytes & (self.page_bytes - 1):
            raise UnsupportedScenario(
                f"page_bytes must be a power of two >= 256, got "
                f"{self.page_bytes}")
        if self.dtype not in plan_ir.ELEM_BYTES:
            raise UnsupportedScenario(
                f"unknown dtype {self.dtype!r}; valid: "
                f"{sorted(plan_ir.ELEM_BYTES)}")
        if self.sampling not in ("sampled", "exact"):
            raise UnsupportedScenario(
                f"unknown sampling policy {self.sampling!r}; valid: "
                "('sampled', 'exact')")
        if self.engine not in ENGINES:
            raise UnsupportedScenario(
                f"unknown engine {self.engine!r}; valid: {ENGINES}")
        for deg, nm in ((self.tp, "tp"), (self.ep, "ep")):
            if not isinstance(deg, int) or deg < 1:
                raise UnsupportedScenario(
                    f"{nm} must be an int >= 1, got {deg!r}")
        try:
            MD.parse_fabric(self.fabric)
        except (TypeError, ValueError) as e:
            raise UnsupportedScenario(
                f"bad fabric spec {self.fabric!r}: {e}") from None
        if self.pcie_gb_s is not None and not self.pcie_gb_s > 0:
            raise UnsupportedScenario(
                f"pcie_gb_s must be positive, got {self.pcie_gb_s!r}")

    def param_dict(self) -> dict:
        return dict(self.params)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["params"] = {k: list(v) if isinstance(v, tuple) else v
                       for k, v in self.params}
        return d


# ------------------------------------------------------------ SimResult
@dataclasses.dataclass
class SimResult:
    """Typed result of one ``simulate()`` run — the single artifact
    every benchmark and the CLI consume.  ``result`` keeps the raw
    accesys ``GemmResult`` for parity checks; ``to_json()`` is the
    stable serialization (schema ``simresult/v1``)."""
    scenario: Scenario
    label: str                     # plan/schedule name
    mode: str
    engine: str                    # engine actually used
    result: object                 # accesys.pipeline.GemmResult
    events_replayed: int
    events_total: int
    wall_s: float                  # replay wall-clock on this host
    serving: Optional[dict] = None # percentiles + trace stats (serve)
    sampling_error: Optional[dict] = None   # see sampling_error()

    SCHEMA = "simresult/v1"

    @property
    def total_s(self) -> float:
        return self.result.total_s

    def buckets(self) -> dict:
        return self.result.buckets()

    @property
    def events_per_s(self) -> float:
        return self.events_replayed / max(self.wall_s, 1e-9)

    @property
    def sampling_speedup(self) -> float:
        return self.events_total / max(self.events_replayed, 1)

    def to_json(self) -> dict:
        r = self.result
        return {
            "schema": self.SCHEMA,
            "scenario": self.scenario.to_json(),
            "label": self.label,
            "mode": self.mode,
            "engine": self.engine,
            "total_us": r.total_s * 1e6,
            "buckets": {k: round(v, 9) for k, v in r.buckets().items()},
            "tlb": {"lookups": r.tlb_lookups, "misses": r.tlb_misses,
                    "walks": r.ptw_walks},
            "macs": r.macs,
            "gops": round(r.gops, 3),
            "events": {"replayed": self.events_replayed,
                       "total": self.events_total,
                       "speedup": round(self.sampling_speedup, 2)},
            "wall_s": round(self.wall_s, 6),
            "events_per_s": round(self.events_per_s, 1),
            "serving": self.serving,
            "sampling_error": self.sampling_error,
        }


def assert_parity(a: SimResult, b: SimResult, rtol: float = 1e-9):
    """Every ``GemmResult`` field of two runs of the same scenario must
    agree to ``rtol`` — the compiled-vs-event engine contract."""
    for f in dataclasses.fields(a.result):
        va, vb = getattr(a.result, f.name), getattr(b.result, f.name)
        if not (va == vb or (isinstance(va, float) and
                             abs(va - vb) <= rtol * max(abs(vb), 1e-30))):
            raise AssertionError(
                f"engine parity violated for {a.label} [{a.mode}]: "
                f"{f.name} {a.engine}={va!r} {b.engine}={vb!r}")


# =============================================================== lowering
# Layer-class stacks: a family lowerer turns a ModelConfig into an
# ordered list of _Layer instances; _stack_plan composes them into an
# exact plan (interleaved, activations chained) or a steady-state
# PlanSchedule (one window per layer CLASS, repeated by class count).

@dataclasses.dataclass(frozen=True)
class _Layer:
    cls: str                       # layer-class key ("layer", "mamba", …)
    build: Callable                # (idx:int, x:str, out:str) -> [StreamPlan]


def _norm_plan(src: str, out: str, S: int, d: int, dt, norm: str,
               pb: int, out_kind: str = "intermediate") -> StreamPlan:
    return host_plan(norm, (src,), out, (S, d), 2 * S * d, dt, pb,
                     out_kind=out_kind)


@dataclasses.dataclass(frozen=True)
class _Shard:
    """The sharding context a config stack lowers under — one RANK's
    view of a tp/ep-partitioned model.  Set (and restored) around
    ``_build_plan`` for config scenarios; the layer builders read it to
    shrink head/ffn/expert extents per ``sharding.logical``'s rule
    table and to insert the Megatron-style collectives (all-gather of
    the block input, reduce-scatter of the block output, all-to-all
    around MoE dispatch/combine).  ``tp == ep == 1`` is the identity:
    every builder takes the exact unsharded code path, so a degree-1
    "sharded" plan is bitwise the unsharded plan.  Because symmetric
    ranks never bind ``replay_multidev``'s barrier, pricing ONE rank's
    plan through the ordinary single-plan engines is exact for the
    whole homogeneous TP/EP group."""
    tp: int = 1
    ep: int = 1
    topology: str = "ring"


_SHARD = _Shard()


def _attn_plans(cfg, S: int, dt, P: str, x: str, out: str, ss: int,
                pb: int, *, kv_src: Optional[str] = None,
                S_kv: Optional[int] = None) -> list:
    """GQA/MQA (and MLA, for deepseek-v3) attention sub-block:
    projections -> per-q-head paged attention over shared per-kv-head
    K/V -> output projection -> residual + norm, ending at ``out``.
    ``kv_src`` switches to cross-attention: queries come from ``x``,
    keys/values from the ``kv_src`` memory tensor of ``S_kv`` rows."""
    hd = cfg.resolved_head_dim
    HQ, KH = cfg.n_heads, cfg.n_kv_heads
    tp, topo = _SHARD.tp, _SHARD.topology
    if tp > 1:
        # shard iff spec_for's rule table would: q heads must divide;
        # kv heads shard with them or stay replicated (MQA/GQA) when
        # the local q heads still group evenly over the full KV set
        HQ_l = MD.tp_split(HQ, "heads", tp)
        KH_l = MD.tp_split(KH, "kv_heads", tp)
        if HQ_l is None:
            tp = 1                     # replicate the whole block
        elif KH_l is not None:
            HQ, KH = HQ_l, KH_l
        elif HQ_l % KH == 0:
            HQ = HQ_l                  # shard q heads, replicate KV
        else:
            tp = 1
    group = HQ // KH
    Sk = S if S_kv is None else S_kv
    d = cfg.d_model
    plans: list = []
    if tp > 1:
        # Megatron cut: ranks hold S/tp rows of x — all-gather the
        # block input before the projections, reduce-scatter the
        # partial output projection before the residual add
        shard = S * d * plan_ir.ELEM_BYTES[dt] // tp
        ag = MD.ag_plan(shard, tp, topo, dt, page_bytes=pb,
                        name=P + f"ag.p{tp}")
        if ag is not None:
            plans.append(ag)
    mla = getattr(cfg, "mla", None) if kv_src is None else None
    if mla is not None:
        q_hd = mla.qk_nope_head_dim + mla.qk_rope_head_dim
        v_hd = mla.v_head_dim
        plans += [
            gemm_plan(S, mla.q_lora_rank, d, dt, a=x, b=P + "wq_a",
                      c=P + "q_lat", b_kind="weight",
                      c_kind="intermediate", page_bytes=pb,
                      sample_stride=ss),
            gemm_plan(S, HQ * q_hd, mla.q_lora_rank, dt, a=P + "q_lat",
                      b=P + "wq_b", c=P + "q", b_kind="weight",
                      c_kind="intermediate", page_bytes=pb,
                      sample_stride=ss),
            # the joint down-projection splits into its two outputs —
            # the compressed KV latent (consumed by wk_b/wv_b) and the
            # shared rope key (concatenated into k directly) — so
            # kv_lat's declared shape matches what its consumers read
            gemm_plan(S, mla.kv_lora_rank, d, dt,
                      a=x, b=P + "wkv_a", c=P + "kv_lat",
                      b_kind="weight", c_kind="intermediate",
                      page_bytes=pb, sample_stride=ss),
            gemm_plan(S, mla.qk_rope_head_dim, d, dt,
                      a=x, b=P + "wk_rope", c=P + "k_rope",
                      b_kind="weight", c_kind="intermediate",
                      page_bytes=pb, sample_stride=ss),
            gemm_plan(Sk, KH * q_hd, mla.kv_lora_rank, dt,
                      a=P + "kv_lat", b=P + "wk_b", c=P + "k",
                      b_kind="weight", c_kind="intermediate",
                      page_bytes=pb, sample_stride=ss),
            gemm_plan(Sk, KH * v_hd, mla.kv_lora_rank, dt,
                      a=P + "kv_lat", b=P + "wv_b", c=P + "v",
                      b_kind="weight", c_kind="intermediate",
                      page_bytes=pb, sample_stride=ss),
        ]
        q_src, k_src, v_src = P + "q", P + "k", P + "v"
        q_base = lambda h: h * q_hd
        k_base = lambda kv: kv * q_hd
        v_base = lambda kv: kv * v_hd
    elif kv_src is not None:
        q_hd = v_hd = hd
        plans += [
            gemm_plan(S, HQ * hd, d, dt, a=x, b=P + "wq", c=P + "q",
                      b_kind="weight", c_kind="intermediate",
                      page_bytes=pb, sample_stride=ss),
            gemm_plan(Sk, 2 * KH * hd, d, dt, a=kv_src, b=P + "wkv",
                      c=P + "kv", b_kind="weight",
                      c_kind="intermediate", page_bytes=pb,
                      sample_stride=ss),
        ]
        q_src, k_src, v_src = P + "q", P + "kv", P + "kv"
        q_base = lambda h: h * hd
        k_base = lambda kv: kv * hd
        v_base = lambda kv: KH * hd + kv * hd
    else:
        q_hd = v_hd = hd
        plans.append(
            gemm_plan(S, (HQ + 2 * KH) * hd, d, dt, a=x, b=P + "wqkv",
                      c=P + "qkv", b_kind="weight",
                      c_kind="intermediate", page_bytes=pb,
                      sample_stride=ss))
        q_src = k_src = v_src = P + "qkv"
        q_base = lambda h: h * hd
        k_base = lambda kv: HQ * hd + kv * hd
        v_base = lambda kv: (HQ + KH) * hd + kv * hd
    head_outs = []
    for h in range(HQ):
        kv = h // group
        qh, oh = P + f"q{h}", P + f"o{h}"
        kT, vh = P + f"kT{kv}", P + f"v{kv}"
        plans.append(host_plan(
            "slice_cols", (q_src,), qh, (S, q_hd), S * q_hd, dt, pb,
            {"start": q_base(h), "stop": q_base(h) + q_hd}))
        if h % group == 0:
            plans += [
                host_plan("slice_cols", (k_src,), kT, (q_hd, Sk),
                          Sk * q_hd, dt, pb,
                          {"start": k_base(kv),
                           "stop": k_base(kv) + q_hd,
                           "transpose": True}),
                host_plan("slice_cols", (v_src,), vh, (Sk, v_hd),
                          Sk * v_hd, dt, pb,
                          {"start": v_base(kv),
                           "stop": v_base(kv) + v_hd}),
            ]
        sc, pr = P + f"h{h}.scores", P + f"h{h}.p"
        plans += [
            gemm_plan(S, Sk, q_hd, dt, a=qh, b=kT, c=sc,
                      c_kind="intermediate", page_bytes=pb,
                      sample_stride=ss),
            host_plan("softmax", (sc,), pr, (S, Sk), S * Sk, dt, pb),
            gemm_plan(S, v_hd, Sk, dt, a=pr, b=vh, c=oh,
                      c_kind="intermediate", page_bytes=pb,
                      sample_stride=ss),
        ]
        head_outs.append(oh)
    plans += [
        host_plan("concat_cols", tuple(head_outs), P + "attn",
                  (S, HQ * v_hd), S * HQ * v_hd, dt, pb),
        gemm_plan(S, d, HQ * v_hd, dt, a=P + "attn", b=P + "wo",
                  c=P + "proj", b_kind="weight", c_kind="intermediate",
                  page_bytes=pb, sample_stride=ss),
    ]
    if tp > 1:
        rs = MD.rs_plan(S * d * plan_ir.ELEM_BYTES[dt] // tp, tp, topo,
                        dt, page_bytes=pb, name=P + f"rs.p{tp}")
        if rs is not None:
            plans.append(rs)
    plans += [
        host_plan("add", (x, P + "proj"), P + "res_a", (S, d),
                  S * d, dt, pb),
        _norm_plan(P + "res_a", out, S, d, dt, cfg.norm, pb),
    ]
    return plans


def _mlp_body(cfg, S: int, d_ff: int, dt, P: str, x: str, out: str,
              ss: int, pb: int) -> list:
    """Gated (SwiGLU/GeGLU) or plain MLP producing ``out`` — the
    FFN GEMM/activation body WITHOUT the residual/norm tail, shared by
    the per-layer FFN and MoE shared-expert branches so their plan
    accounting can never diverge."""
    d = cfg.d_model
    tp, topo = _SHARD.tp, _SHARD.topology
    if tp > 1:
        d_ff_l = MD.tp_split(d_ff, "mlp", tp)
        if d_ff_l is None:
            tp = 1                     # indivisible width: replicate
        else:
            d_ff = d_ff_l
    plans: list = []
    if tp > 1:
        shard = S * d * plan_ir.ELEM_BYTES[dt] // tp
        ag = MD.ag_plan(shard, tp, topo, dt, page_bytes=pb,
                        name=P + f"ag.p{tp}")
        if ag is not None:
            plans.append(ag)
    if cfg.glu:
        plans += [
            gemm_plan(S, d_ff, d, dt, a=x, b=P + "w1", c=P + "gate",
                      b_kind="weight", c_kind="intermediate",
                      page_bytes=pb, sample_stride=ss),
            gemm_plan(S, d_ff, d, dt, a=x, b=P + "w3", c=P + "up",
                      b_kind="weight", c_kind="intermediate",
                      page_bytes=pb, sample_stride=ss),
            host_plan("act_mul", (P + "gate", P + "up"), P + "h",
                      (S, d_ff), 2 * S * d_ff, dt, pb,
                      meta={"act": cfg.act}),
        ]
    else:
        plans += [
            gemm_plan(S, d_ff, d, dt, a=x, b=P + "w1", c=P + "ff1",
                      b_kind="weight", c_kind="intermediate",
                      page_bytes=pb, sample_stride=ss),
            host_plan(cfg.act, (P + "ff1",), P + "h", (S, d_ff),
                      S * d_ff, dt, pb),
        ]
    plans.append(
        gemm_plan(S, d, d_ff, dt, a=P + "h", b=P + "w2", c=out,
                  b_kind="weight", c_kind="intermediate",
                  page_bytes=pb, sample_stride=ss))
    if tp > 1:
        rs = MD.rs_plan(S * d * plan_ir.ELEM_BYTES[dt] // tp, tp, topo,
                        dt, page_bytes=pb, name=P + f"rs.p{tp}")
        if rs is not None:
            plans.append(rs)
    return plans


def _ffn_plans(cfg, S: int, d_ff: int, dt, P: str, x: str, out: str,
               ss: int, pb: int, out_kind: str = "output") -> list:
    """Gated (SwiGLU/GeGLU) or plain MLP + residual + norm."""
    d = cfg.d_model
    plans = _mlp_body(cfg, S, d_ff, dt, P, x, P + "ff", ss, pb)
    plans += [
        host_plan("add", (x, P + "ff"), P + "res_f", (S, d), S * d,
                  dt, pb),
        _norm_plan(P + "res_f", out, S, d, dt, cfg.norm, pb,
                   out_kind=out_kind),
    ]
    return plans


def _dense_layer(cfg, S, dt, ss, pb, cls_name="layer"):
    def build(idx, x, out):
        P = f"{cls_name}{idx}."
        plans = _attn_plans(cfg, S, dt, P, x, P + "ln_a", ss, pb)
        plans += _ffn_plans(cfg, S, cfg.d_ff, dt, P, P + "ln_a", out,
                            ss, pb)
        return plans
    return _Layer(cls_name, build)


def _moe_layer(cfg, S, dt, ss, pb):
    mo = cfg.moe

    def build(idx, x, out):
        P = f"moe{idx}."
        plans = _attn_plans(cfg, S, dt, P, x, P + "ln_a", ss, pb)
        moe_out = P + "moe_y" if mo.n_shared_experts else P + "ff"
        ep, topo = _SHARD.ep, _SHARD.topology
        E_local = mo.n_routed_experts
        capacity = None
        if ep > 1:
            from repro.models.moe import routed_capacity
            # each rank hosts E/ep experts but keeps the GLOBAL
            # per-expert capacity (dispatch rebalances tokens across
            # ranks, it does not shrink an expert's buffer)
            E_local = MD.ep_shard_plan(ep, mo.n_routed_experts)
            capacity = routed_capacity(S * mo.top_k,
                                       mo.n_routed_experts, None, 1.25)
        mp = plan_ir._moe_layer_plans(
            S, cfg.d_model, E_local, mo.top_k,
            mo.d_ff_expert, dt, capacity=capacity, act=cfg.act,
            x=P + "ln_a", layer=idx, out=moe_out, page_bytes=pb,
            sample_stride=ss)
        if ep > 1:
            # a2a dispatch rides between host dispatch and the expert
            # GEMMs; combine between the last expert and host combine.
            # Each rank exchanges its (p-1)/p share of the routed
            # token block — dispatch and combine volumes are equal.
            shard = S * mo.top_k * cfg.d_model * \
                plan_ir.ELEM_BYTES[dt] // ep
            colls = [MD.a2a_plan(shard, ep, topo, dt,
                                 op="a2a_dispatch", page_bytes=pb,
                                 name=P + f"a2a_d.p{ep}"),
                     MD.a2a_plan(shard, ep, topo, dt,
                                 op="a2a_combine", page_bytes=pb,
                                 name=P + f"a2a_c.p{ep}")]
            disp, comb = colls
            if disp is not None:
                mp = mp[:2] + [disp] + mp[2:]
            if comb is not None:
                mp = mp[:-1] + [comb, mp[-1]]
        plans += mp
        if mo.n_shared_experts:
            # the always-on shared-expert branch: one dense gated FFN
            # of width n_shared * d_ff_expert over every token —
            # the SAME MLP body the per-layer FFN builds
            d_se = mo.n_shared_experts * mo.d_ff_expert
            SP = P + "se."
            plans += _mlp_body(cfg, S, d_se, dt, SP, P + "ln_a",
                               SP + "y", ss, pb)
            plans.append(
                host_plan("add", (moe_out, SP + "y"), P + "ff",
                          (S, cfg.d_model), S * cfg.d_model, dt, pb))
        plans += [
            host_plan("add", (P + "ln_a", P + "ff"), P + "res_f",
                      (S, cfg.d_model), S * cfg.d_model, dt, pb),
            _norm_plan(P + "res_f", out, S, cfg.d_model, dt, cfg.norm,
                       pb, out_kind="output"),
        ]
        return plans
    return _Layer("moe", build)


def _ssm_layer(cfg, S, dt, ss, pb):
    """rwkv-style attention-free block: chunked-scan time mix (the
    ``ssm_layer_plan`` machinery, mirroring ``models/ssm.py``) followed
    by the channel-mix FFN."""
    hd = cfg.ssm.head_dim if cfg.ssm is not None else \
        cfg.resolved_head_dim
    n_heads = max(1, cfg.d_model // hd)
    chunk = max(1, min(16, S))

    def build(idx, x, out):
        P = f"ssm{idx}."
        plans = plan_ir._ssm_layer_plans(
            S, cfg.d_model, n_heads, dt, chunk=chunk, x=x, layer=idx,
            out=P + "mix", page_bytes=pb, sample_stride=ss)
        plans += [
            host_plan("add", (x, P + "mix"), P + "res_t",
                      (S, cfg.d_model), S * cfg.d_model, dt, pb),
            _norm_plan(P + "res_t", P + "ln_t", S, cfg.d_model, dt,
                       cfg.norm, pb),
        ]
        plans += _ffn_plans(cfg, S, cfg.d_ff, dt, P, P + "ln_t", out,
                            ss, pb)
        return plans
    return _Layer("ssm", build)


def _mamba_layer(cfg, S, dt, ss, pb):
    """mamba2 block (zamba2): in-projection GEMM, host conv+act, the
    chunked selective scan with an explicit state-carry chain, gating,
    and the out-projection GEMM."""
    sm = cfg.ssm
    d_in = sm.expand * cfg.d_model
    H, N = max(1, d_in // sm.head_dim), sm.head_dim
    chunk = max(1, min(16, S))

    def build(idx, x, out):
        P = f"mamba{idx}."
        plans = [
            gemm_plan(S, 2 * d_in, cfg.d_model, dt, a=x, b=P + "win",
                      c=P + "xz", b_kind="weight",
                      c_kind="intermediate", page_bytes=pb,
                      sample_stride=ss),
            host_plan("conv_act", (P + "xz",), P + "u", (S, d_in),
                      S * d_in * sm.d_conv, dt, pb,
                      meta={"d_conv": sm.d_conv}),
        ]
        nc = -(-S // chunk)
        state = P + "s0"
        chunk_outs = []
        for c in range(nc):
            t0, t1 = c * chunk, min(S, (c + 1) * chunk)
            o, s = P + f"c{c}.o", P + f"c{c}.s"
            plans.append(host_plan(
                "ssm_scan", (P + "u", state), None, None,
                (t1 - t0) * H * N * N, dt, pb,
                meta={"t0": t0, "t1": t1, "H": H, "N": N},
                outs=[(o, (t1 - t0, d_in)), (s, (H * N, N))]))
            state = s
            chunk_outs.append(o)
        plans += [
            host_plan("concat_rows", tuple(chunk_outs), P + "scan",
                      (S, d_in), S * d_in, dt, pb),
            host_plan("gate", (P + "xz", P + "scan"), P + "g",
                      (S, d_in), 2 * S * d_in, dt, pb),
            gemm_plan(S, cfg.d_model, d_in, dt, a=P + "g",
                      b=P + "wout", c=P + "proj", b_kind="weight",
                      c_kind="intermediate", page_bytes=pb,
                      sample_stride=ss),
            host_plan("add", (x, P + "proj"), P + "res",
                      (S, cfg.d_model), S * cfg.d_model, dt, pb),
            _norm_plan(P + "res", out, S, cfg.d_model, dt, cfg.norm,
                       pb, out_kind="output"),
        ]
        plans[0].tensors[P + "s0"] = plan_ir.TensorSpec(H * N, N, set(),
                                                        "input")
        return plans
    return _Layer("mamba", build)


def _dec_layer(cfg, S, dt, ss, pb):
    """whisper decoder layer: causal self-attention, cross-attention
    over the encoder memory (``P+"mem"``), then the FFN."""
    def build(idx, x, out):
        P = f"dec{idx}."
        plans = _attn_plans(cfg, S, dt, P + "sa.", x, P + "ln_a", ss,
                            pb)
        plans += _attn_plans(cfg, S, dt, P + "xa.", P + "ln_a",
                             P + "ln_x", ss, pb, kv_src=P + "mem",
                             S_kv=S)
        plans += _ffn_plans(cfg, S, cfg.d_ff, dt, P, P + "ln_x", out,
                            ss, pb)
        return plans
    return _Layer("dec", build)


# family -> (cfg, S, dtype, n_layers, sample_stride, page_bytes)
#        -> ordered list of _Layer instances
def _dense_stack(cfg, S, dt, n_layers, ss, pb):
    return [_dense_layer(cfg, S, dt, ss, pb)] * n_layers


def _moe_stack(cfg, S, dt, n_layers, ss, pb):
    first = min(cfg.moe.first_dense_layers, n_layers)
    dense = _dense_layer(cfg, S, dt, ss, pb, cls_name="dense")
    moe = _moe_layer(cfg, S, dt, ss, pb)
    return [dense] * first + [moe] * (n_layers - first)


def _ssm_stack(cfg, S, dt, n_layers, ss, pb):
    return [_ssm_layer(cfg, S, dt, ss, pb)] * n_layers


def _hybrid_stack(cfg, S, dt, n_layers, ss, pb):
    """zamba2: ``n_layers`` mamba blocks with the shared attention+MLP
    block inserted after every ``attn_every`` of them."""
    mamba = _mamba_layer(cfg, S, dt, ss, pb)
    attn = _dense_layer(cfg, S, dt, ss, pb, cls_name="attn")
    every = max(1, cfg.ssm.attn_every if cfg.ssm else 6)
    stack = []
    for i in range(n_layers):
        stack.append(mamba)
        if (i + 1) % every == 0:
            stack.append(attn)
    return stack


def _audio_stack(cfg, S, dt, n_layers, ss, pb):
    # Scenario.n_layers caps BOTH stacks (like every other family caps
    # its whole stack): n_layers=1 -> 1 encoder + 1 decoder block
    enc = _dense_layer(cfg, S, dt, ss, pb, cls_name="enc")
    dec = _dec_layer(cfg, S, dt, ss, pb)
    return [enc] * min(cfg.n_encoder_layers, n_layers) + \
        [dec] * n_layers


WORKLOAD_REGISTRY = {
    "dense": _dense_stack,
    "vlm": _dense_stack,           # LM backbone; frontend is a stub
    "moe": _moe_stack,
    "ssm": _ssm_stack,
    "hybrid": _hybrid_stack,
    "audio": _audio_stack,
}


def _config_stack(cfg, S, dt, n_layers, ss, pb):
    lower = WORKLOAD_REGISTRY.get(cfg.family)
    if lower is None:
        raise UnsupportedScenario(
            f"model family {cfg.family!r} (config {cfg.name!r}) has no "
            f"workload lowering; supported families: "
            f"{sorted(WORKLOAD_REGISTRY)}")
    return lower(cfg, S, dt, n_layers, ss, pb)


def _stack_plan(name: str, stack: Sequence[_Layer], exact: bool):
    """Compose a layer-class stack: exact = every instance materialized
    in order, activations chained; sampled = one steady window per
    layer CLASS, repeated by that class's instance count (heterogeneous
    stacks keep one window per class — zamba2's mamba/attention
    interleave becomes two windows with repeats 4 and 2, say)."""
    if not stack:
        raise UnsupportedScenario(f"{name}: empty layer stack")
    if exact:
        plans = []
        inp = "x"
        for i, layer in enumerate(stack):
            out = "out" if i == len(stack) - 1 else f"B{i}.out"
            plans += layer.build(i, inp, out)
            inp = out
        return concat(plans, name=f"{name}.x{len(stack)}")
    classes: "OrderedDict[str, list]" = OrderedDict()
    for layer in stack:
        classes.setdefault(layer.cls, [layer, 0])[1] += 1
    segments = []
    for cls, (layer, count) in classes.items():
        window = layer.build(0, f"{cls}.win_in", f"{cls}.win_out")
        segments += [(p, count) for p in window]
    tag = ",".join(f"{c}:{n}" for c, (_, n) in classes.items())
    return PlanSchedule(f"{name}~sampled({tag})", segments)


# ============================================================== registry
@dataclasses.dataclass(frozen=True)
class _Target:
    kind: str                      # "config" | "moe" | "ssm" | "decode"
                                   # | "serve" | "gemm"
    config: object = None          # ModelConfig for kind == "config"
    default_seq: int = 128


@functools.lru_cache(maxsize=1)
def _targets() -> dict:
    from repro.configs import ARCH_IDS, get_config, get_reduced
    from repro.configs.paper_models import PAPER_MODELS
    out: dict = {}
    for name, cfg in PAPER_MODELS.items():
        out[name] = _Target("config", cfg,
                            default_seq=cfg.max_train_seq)
    for arch in ARCH_IDS:
        for cfg, seq in ((get_config(arch), 128),
                         (get_reduced(arch), 64)):
            out[cfg.name] = _Target("config", cfg, default_seq=seq)
    out["bert"] = out["bert-base"]
    out["vit"] = out["vit-base-16"]
    for kind in ("moe", "ssm", "decode", "serve", "gemm"):
        out[kind] = _Target(kind)
    return out


def scenario_names() -> list:
    """Every name ``Scenario.model`` accepts, sorted."""
    return sorted(_targets())


def resolve(name: str) -> _Target:
    """Name -> lowering target, or ``UnsupportedScenario`` with a
    did-you-mean hint and the full valid list."""
    table = _targets()
    t = table.get(name)
    if t is not None:
        return t
    close = difflib.get_close_matches(name, table, n=3, cutoff=0.5)
    hint = f" — did you mean {', '.join(map(repr, close))}?" if close \
        else ""
    raise UnsupportedScenario(
        f"unknown scenario model {name!r}{hint}  Valid scenarios: "
        f"{', '.join(sorted(table))}")


def smoke_matrix() -> list:
    """One reduced scenario per model family (generated from the
    registry — this is the CI simulate-smoke matrix) plus the synthetic
    decode class."""
    from repro.configs import ARCH_IDS, get_reduced
    by_family: "OrderedDict[str, str]" = OrderedDict()
    for arch in ARCH_IDS:
        cfg = get_reduced(arch)
        by_family.setdefault(cfg.family, cfg.name)
    out = [Scenario(model=name, seq=32, engine="both")
           for name in by_family.values()]
    out.append(Scenario(model="decode", dtype="fp16", engine="both"))
    return out


# ============================================================ plan cache
_PLAN_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_PLAN_CACHE_MAX = 8
_TRACE_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_TRACE_CACHE_MAX = 2
cache_hits = 0
cache_misses = 0


def clear_caches():
    """Drop cached plans/serving traces (exact full-depth plans plus
    their compiled arrays are order-100 MB)."""
    global cache_hits, cache_misses
    from repro.accesys.pipeline import release_scratch
    _PLAN_CACHE.clear()
    _TRACE_CACHE.clear()
    release_scratch()
    cache_hits = cache_misses = 0


def _reset_caches_after_fork():
    # a forked sweep worker must not inherit the parent's LRU state:
    # cached compiled plans are order-100 MB of copy-on-write pages and
    # the child's own churn would silently dirty them — start empty and
    # let each process fill (and release) its own caches
    global cache_hits, cache_misses
    _PLAN_CACHE.clear()
    _TRACE_CACHE.clear()
    cache_hits = cache_misses = 0


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_reset_caches_after_fork)


def _pool_executor(workers: int):
    """A ``ProcessPoolExecutor`` for sweep fan-out, or ``None`` for the
    inline path.  Prefers the fork start method (workers inherit the
    imported module graph; the at-fork hooks above give each child
    empty caches and an empty scratch pool) and falls back to the
    platform default where fork is unavailable."""
    if workers <= 1:
        return None
    import concurrent.futures
    import multiprocessing
    try:
        ctx = multiprocessing.get_context("fork")
    except ValueError:
        ctx = multiprocessing.get_context()
    return concurrent.futures.ProcessPoolExecutor(
        max_workers=workers, mp_context=ctx)


def _cache_get(cache: OrderedDict, key):
    """LRU read: a hit refreshes recency, so an interleaved sweep
    cannot evict its own hot plan."""
    hit = cache.get(key)
    if hit is not None:
        cache.move_to_end(key)
    return hit


def _cache_put(cache: OrderedDict, maxsize: int, key, value):
    cache[key] = value
    cache.move_to_end(key)     # overwriting an old key refreshes it too
    while len(cache) > maxsize:
        cache.popitem(last=False)


def _plan_key(sc: Scenario) -> tuple:
    # mode / engine / devmem_dram excluded: a DM/DC/DevMem (or
    # engine-parity) sweep reuses one plan and its compiled form.
    # Fabric/host-link BANDWIDTH and hop latency are pricing-time knobs
    # (excluded too — a bandwidth sweep reuses one plan); the fabric
    # TOPOLOGY changes the collective hop decomposition, so it is part
    # of the plan identity along with the tp/ep degrees.
    return (sc.model, sc.dtype, sc.seq, sc.batch, sc.n_layers,
            sc.sampling, sc.sample_stride, sc.page_bytes, sc.params,
            sc.tp, sc.ep, MD.parse_fabric(sc.fabric).topology)


def _decode_table(p: dict, np_dt: str):
    """A churned driver-side ``PageTable`` (no device pools, no JAX on
    this path) whose page ids feed the decode plan verbatim."""
    from repro.serving.kv_cache import PagedCacheConfig, PageTable
    import numpy as np
    cfg = PagedCacheConfig(
        n_pages=p["n_pages"], page_tokens=p["page_tokens"],
        n_kv_heads=p["n_kv_heads"], head_dim=p["head_dim"],
        max_pages_per_seq=p["max_pages_per_seq"], dtype=np_dt)
    pt = PageTable(cfg, max_seqs=len(p["prompt_lens"]))
    for slot, ln in enumerate(p["prompt_lens"]):
        if not pt.alloc_seq(slot, ln) or not pt.note_tokens(slot, ln):
            raise UnsupportedScenario(
                f"decode scenario: KV pool too small for slot {slot} "
                f"({ln} tokens; params={p})")
    for slot, ln in (p.get("churn") or ()):
        pt.free_seq(slot)
        if not pt.alloc_seq(slot, ln) or not pt.note_tokens(slot, ln):
            raise UnsupportedScenario(
                f"decode scenario: KV pool too small for readmitted "
                f"slot {slot} ({ln} tokens)")
    return pt, np.dtype(np_dt).itemsize


def _merge_params(kind: str, defaults: dict, p: dict) -> dict:
    """Overlay scenario params on a workload class's shape defaults —
    unknown keys raise (a typo'd override must never silently leave
    the default in place)."""
    bad = sorted(set(p) - set(defaults))
    if bad:
        raise UnsupportedScenario(
            f"unknown {kind} scenario params {bad}; valid keys: "
            f"{sorted(defaults)}")
    return {**defaults, **p}


def _check_sharding(sc: Scenario, target: _Target):
    """tp/ep degrees shard model-config stacks only, and only the
    families whose blocks the partitioner understands."""
    if sc.tp == 1 and sc.ep == 1:
        return
    if target.kind != "config":
        raise UnsupportedScenario(
            f"tp/ep sharding applies to model-config scenarios only, "
            f"not the {target.kind!r} workload class")
    cfg = target.config
    if sc.tp > 1 and cfg.family in ("ssm", "hybrid"):
        raise UnsupportedScenario(
            f"tp>1 unsupported for family {cfg.family!r} "
            f"({cfg.name!r}): the selective-scan state is not "
            "head-partitionable in this lowering")
    if sc.ep > 1 and cfg.family != "moe":
        raise UnsupportedScenario(
            f"ep>1 requires a MoE config; {cfg.name!r} has family "
            f"{cfg.family!r}")


def _build_plan(sc: Scenario, target: _Target):
    """Lower a (non-serve) scenario to its plan or schedule.  Returns
    (plan_or_schedule, label, events_replayed, events_total)."""
    _check_sharding(sc, target)
    exact = sc.sampling == "exact"
    ss = sc.sample_stride
    p = {**sc.param_dict()}
    if target.kind == "config" and p:
        raise UnsupportedScenario(
            f"config scenario {sc.model!r} takes no params (got "
            f"{sorted(p)}); use seq/batch/n_layers/dtype instead")
    if target.kind == "config":
        cfg = target.config
        S = (sc.seq or target.default_seq) * sc.batch
        n_layers = sc.n_layers or cfg.n_layers
        global _SHARD
        saved = _SHARD
        _SHARD = _Shard(sc.tp, sc.ep,
                        MD.parse_fabric(sc.fabric).topology)
        try:
            stack = _config_stack(cfg, S, sc.dtype, n_layers, ss,
                                  sc.page_bytes)
            plan = _stack_plan(cfg.name, stack, exact)
        finally:
            _SHARD = saved
    elif target.kind == "gemm":
        from repro.core.streaming import tile_counts
        sh = _merge_params("gemm", dict(m=1024, n=1024, k=1024), p)
        m, n, k = sh["m"], sh["n"], sh["k"]
        np_name = plan_ir.np_dtype_for(sc.dtype)
        counts = tile_counts(m, n, k, np_name,
                             page_bytes=sc.page_bytes)
        # same auto-sampling rule as pipeline.simulate_gemm, so the
        # pinned seed GEMM numbers hold through this path too
        stride = 1 if exact else \
            max(ss, counts["inner_steps"] // 400_000, 1)
        plan = plan_ir.gemm_plan_cached(m, n, k, np_name,
                                        page_bytes=sc.page_bytes,
                                        sample_stride=stride)
    elif target.kind == "moe":
        sh = _merge_params("moe", MOE_SHAPE, p)
        n_layers = sc.n_layers or 2
        if exact:
            plan = concat(
                [plan_ir.moe_layer_plan(
                    sh["n_tokens"], sh["d_model"], sh["n_experts"],
                    sh["top_k"], sh["d_ff"], sc.dtype,
                    capacity_factor=sh["capacity_factor"], layer=i,
                    x="x" if i == 0 else f"M{i-1}.out",
                    page_bytes=sc.page_bytes)
                 for i in range(n_layers)], name=f"moe_x{n_layers}")
        else:
            plan = plan_ir.moe_schedule(
                sh["n_tokens"], sh["d_model"], sh["n_experts"],
                sh["top_k"], sh["d_ff"], n_layers, sc.dtype,
                capacity_factor=sh["capacity_factor"],
                page_bytes=sc.page_bytes, sample_stride=ss)
    elif target.kind == "ssm":
        sh = _merge_params("ssm", SSM_SHAPE, p)
        n_layers = sc.n_layers or 2
        if exact:
            plan = concat(
                [plan_ir.ssm_layer_plan(
                    sh["T"], sh["d_model"], sh["n_heads"], sc.dtype,
                    chunk=sh["chunk"], layer=i,
                    x="x" if i == 0 else f"S{i-1}.out",
                    page_bytes=sc.page_bytes)
                 for i in range(n_layers)], name=f"ssm_x{n_layers}")
        else:
            plan = plan_ir.ssm_schedule(
                sh["T"], sh["d_model"], sh["n_heads"], n_layers,
                sc.dtype, chunk=sh["chunk"],
                page_bytes=sc.page_bytes, sample_stride=ss)
    elif target.kind == "decode":
        sh = _merge_params("decode", DECODE_SHAPE, p)
        np_dt = plan_ir.np_dtype_for(sc.dtype)
        pt, elem = _decode_table(sh, np_dt)
        slots = list(range(len(sh["prompt_lens"])))
        tables = [pt.tables[s, :int(pt.held[s])] for s in slots]
        lens = [int(pt.lens[s]) for s in slots]
        n_layers = sc.n_layers or 1
        if exact or n_layers == 1:
            plan = plan_ir.decode_step_plan(
                tables, lens, sh["page_tokens"], sh["n_kv_heads"],
                sh["head_dim"], elem, n_q_heads=sh["n_q_heads"],
                n_layers=n_layers)
        else:
            plan = plan_ir.decode_step_schedule(
                tables, lens, sh["page_tokens"], sh["n_kv_heads"],
                sh["head_dim"], elem, n_layers,
                n_q_heads=sh["n_q_heads"])
    else:
        raise UnsupportedScenario(
            f"scenario kind {target.kind!r} has no plan lowering")
    if isinstance(plan, PlanSchedule):
        return plan, plan.name, plan.sampled_events, plan.exact_events
    return plan, plan.name, len(plan.events), plan.n_exact_events


def _plan_for(sc: Scenario, target: _Target):
    global cache_hits, cache_misses
    key = _plan_key(sc)
    hit = _cache_get(_PLAN_CACHE, key)
    if hit is not None:
        cache_hits += 1
        return hit
    cache_misses += 1
    built = _build_plan(sc, target)
    _cache_put(_PLAN_CACHE, _PLAN_CACHE_MAX, key, built)
    return built


def _serve_trace(sc: Scenario):
    """Run the reduced continuous-batching engine with plan recording
    and cache (trace, schedule) — the engine run (JAX) dwarfs replay
    cost, and every memory mode prices the same trace."""
    global cache_hits, cache_misses
    sh = _merge_params("serve", SERVE_SHAPE, sc.param_dict())
    key = tuple(sorted(sh.items()))
    hit = _cache_get(_TRACE_CACHE, key)
    if hit is not None:
        cache_hits += 1
        return hit
    cache_misses += 1
    import jax
    import numpy as np
    from repro.configs import get_reduced
    from repro.models.model import Model
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sim_report import trace_schedule
    cfg = get_reduced(sh["arch"])
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(sh["seed"])
    eng = ServingEngine(cfg, params, slots=sh["slots"],
                        max_seq=sh["max_seq"], record_plans=True)
    lo, hi = sh["prompt_lo"], sh["prompt_hi"]
    for i in range(sh["n_requests"]):
        size = lo if lo >= hi else int(rng.integers(lo, hi))
        eng.submit(Request(
            uid=i, prompt=rng.integers(1, 250, size=size
                                       ).astype(np.int32),
            max_new_tokens=sh["max_new_tokens"]))
    eng.run_until_drained(max_steps=10 * sh["n_requests"] *
                          sh["max_new_tokens"] + 1000)
    out = (eng.trace, trace_schedule(eng.trace))
    _cache_put(_TRACE_CACHE, _TRACE_CACHE_MAX, key, out)
    return out


# ================================================================ façade
def _resolved_engine(engine: Optional[str], n_events: int) -> str:
    """The engine a fresh (reset=True) replay of ``n_events`` actually
    uses — the single place SimResult labels resolve ``auto`` through
    the pipeline's own size rule."""
    if engine is not None:
        return engine
    from repro.accesys.pipeline import _use_compiled
    return "compiled" if _use_compiled("auto", n_events, True) \
        else "event"


def system_for(sc: Scenario):
    """The accesys ``SystemConfig`` a scenario runs on."""
    from repro.accesys.components import DRAM
    from repro.accesys.system import default_system
    dtype = "fp16" if resolve(sc.model).kind == "serve" else sc.dtype
    dram = DRAM(sc.devmem_dram) if sc.mode == "DevMem" else None
    from repro.accesys.system import pcie_for_bw
    pcie = pcie_for_bw(sc.pcie_gb_s) if sc.pcie_gb_s is not None \
        else None
    cfg = default_system(sc.mode, dtype=dtype, pcie=pcie, dram=dram)
    cfg.fabric = MD.parse_fabric(sc.fabric)
    if sc.page_bytes != cfg.page_bytes:
        cfg.page_bytes = sc.page_bytes
        cfg.llc = dataclasses.replace(cfg.llc,
                                      page_bytes=sc.page_bytes)
    return cfg


def scenario_plan(sc: Scenario):
    """Public lowering hook: (plan_or_schedule, label, events_replayed,
    events_total).  Serve scenarios lower to the recorded trace's
    repeat-1 schedule."""
    target = resolve(sc.model)
    if target.kind == "serve":
        _check_sharding(sc, target)
        _, sched = _serve_trace(sc)
        return sched, sched.name, sched.sampled_events, \
            sched.sampled_events
    return _plan_for(sc, target)


def _simulate_serve(sc: Scenario, engine: Optional[str],
                    host_s_per_elem: Optional[float]) -> SimResult:
    from repro.accesys.pipeline import HOST_S_PER_ELEM
    from repro.serving.sim_report import simulate_serving_trace
    trace, sched = _serve_trace(sc)
    cfg = system_for(sc)
    t0 = time.perf_counter()
    rep = simulate_serving_trace(
        cfg, trace, sched=sched,
        host_s_per_elem=host_s_per_elem or HOST_S_PER_ELEM,
        engine=engine)
    wall = time.perf_counter() - t0
    decode_steps = sum(1 for r in trace if r.kind == "decode")
    decode_s = sum(s for s, r in zip(rep.per_event_s, trace)
                   if r.kind == "decode")
    serving = dict(rep.percentiles())
    serving.update({
        "decode_steps": decode_steps,
        "prefills": len(trace) - decode_steps,
        "sim_us_per_decode_step":
            decode_s * 1e6 / max(decode_steps, 1),
        "prefill_share": 1.0 - decode_s / max(rep.total_s, 1e-30),
    })
    return SimResult(
        scenario=sc, label=f"serve_trace({len(trace)} records)",
        mode=sc.mode,
        engine=_resolved_engine(engine, sched.sampled_events),
        result=rep.result,
        events_replayed=sched.sampled_events,
        events_total=sched.sampled_events, wall_s=wall,
        serving=serving)


def simulate(sc: Scenario, *,
             host_s_per_elem: Optional[float] = None) -> SimResult:
    """Lower ``sc`` to a plan, replay it on the scenario's system
    config, and return a ``SimResult``.  ``engine="both"`` runs the
    compiled AND event engines, asserts field-exact parity (rtol
    1e-9), and returns the compiled result tagged ``both``."""
    if sc.engine == "both":
        a = simulate(dataclasses.replace(sc, engine="compiled"),
                     host_s_per_elem=host_s_per_elem)
        b = simulate(dataclasses.replace(sc, engine="event"),
                     host_s_per_elem=host_s_per_elem)
        assert_parity(a, b)
        a.engine = "both"
        return a
    engine = None if sc.engine == "auto" else sc.engine
    target = resolve(sc.model)
    if target.kind == "serve":
        _check_sharding(sc, target)
        return _simulate_serve(sc, engine, host_s_per_elem)
    from repro.accesys.pipeline import HOST_S_PER_ELEM, replay
    plan, label, replayed, total = _plan_for(sc, target)
    cfg = system_for(sc)
    t0 = time.perf_counter()
    result = replay(cfg, plan,
                    host_s_per_elem=host_s_per_elem or HOST_S_PER_ELEM,
                    engine=engine)
    wall = time.perf_counter() - t0
    return SimResult(scenario=sc, label=label, mode=sc.mode,
                     engine=_resolved_engine(engine, replayed),
                     result=result, events_replayed=replayed,
                     events_total=total, wall_s=wall)


def sweep(scenarios: Sequence[Scenario], *,
          host_s_per_elem: Optional[float] = None,
          tp_degrees: Optional[Sequence[int]] = None) -> list:
    """Simulate many scenarios.  Scenarios that differ only in memory
    mode / engine / DevMem DRAM (or fabric/host-link bandwidth) share
    one lowered plan (and its compiled form and trace-intrinsic LRU
    analysis) through the plan cache — the paper's design-space sweeps
    in one call.  ``tp_degrees`` crosses every scenario with a list of
    tensor-parallel degrees (the TP-degree axis of the multi-device
    sweep)."""
    if tp_degrees:
        scenarios = [dataclasses.replace(sc, tp=tp)
                     for sc in scenarios for tp in tp_degrees]
    return [simulate(sc, host_s_per_elem=host_s_per_elem)
            for sc in scenarios]


# ========================================================= design search
@dataclasses.dataclass
class TunedPoint:
    """One scored design-space candidate."""
    point: object                  # design_space.DesignPoint
    result: object                 # accesys GemmResult
    area_um2: float                # accelerator-silicon area proxy
    score: float                   # objective value (lower is better)
    on_pareto: bool = False        # latency-vs-area non-dominated

    @property
    def total_s(self) -> float:
        return self.result.total_s

    def to_json(self) -> dict:
        return {"point": dataclasses.asdict(self.point),
                "label": self.point.label(),
                "total_us": self.total_s * 1e6,
                "area_mm2": self.area_um2 / 1e6,
                "score": self.score,
                "on_pareto": self.on_pareto}


@dataclasses.dataclass
class TuneResult:
    """Result of one ``tune()`` search: every scored point (input
    order), the latency-vs-area Pareto frontier, and the sweep
    throughput the config-batched replayer achieved."""
    scenario: Scenario
    objective: str
    points: list                   # [TunedPoint]
    n_infeasible: int              # filtered before pricing
    wall_s: float

    SCHEMA = "tuneresult/v1"

    @property
    def pareto(self) -> list:
        return [tp for tp in self.points if tp.on_pareto]

    @property
    def best(self) -> TunedPoint:
        return min(self.points, key=lambda tp: tp.score)

    @property
    def configs_per_s(self) -> float:
        return len(self.points) / max(self.wall_s, 1e-9)

    def to_json(self) -> dict:
        return {"schema": self.SCHEMA,
                "scenario": self.scenario.to_json(),
                "objective": self.objective,
                "n_points": len(self.points),
                "n_infeasible": self.n_infeasible,
                "wall_s": round(self.wall_s, 6),
                "configs_per_s": round(self.configs_per_s, 1),
                "best": self.best.to_json(),
                "pareto": [tp.to_json() for tp in self.pareto],
                "points": [tp.to_json() for tp in self.points]}


def _tune_group(payload: tuple) -> list:
    """Price one (dtype, page_bytes) tune group: lower the scenario
    once and config-batch-replay every design point of the group.
    Module-level and plain-data in/out (Scenario + DesignPoints in,
    GemmResults out) so ``tune(workers=N)`` can fan groups over a
    process pool; scoring stays in the parent, so the objective
    callable never needs to be picklable."""
    sc, dt, pb, points, hpe, in_worker = payload
    from repro.accesys.pipeline import release_scratch, replay_batch
    from repro.core import design_space as DS
    plan, _, _, _ = _plan_for(
        dataclasses.replace(sc, dtype=dt, page_bytes=pb),
        resolve(sc.model))
    results = replay_batch(
        [DS.system_for_point(p) for p in points], plan,
        host_s_per_elem=hpe)
    if in_worker:
        release_scratch()      # workers drop their scratch before exit
    return results


def tune(sc: Scenario, space=None, objective="latency", *,
         host_s_per_elem: Optional[float] = None,
         workers: int = 1) -> TuneResult:
    """Search a co-design knob space against one workload: lower ``sc``
    once per distinct (dtype, page_bytes) — those change the plan — and
    price every ``DesignPoint`` of each group in ONE config-batched
    replay (``replay_batch``), so an N-point sweep costs one trace
    analysis plus a vectorized pricing pass instead of N replays.

    ``space`` is a ``design_space.DesignSpace`` (default:
    ``default_space()``) or an explicit iterable of ``DesignPoint``s;
    infeasible points (buffer budget too small for the streaming
    schedule) are filtered and counted.  ``objective`` is ``"latency"``
    or a callable ``(point, result) -> float`` (lower is better); the
    latency-vs-area Pareto frontier is marked regardless of objective.
    Per-point results equal a sequential ``simulate()`` of the same
    configuration at rtol 1e-9 — DM/DC/DevMem orderings match
    ``sweep()``.

    ``workers > 1`` fans the per-(dtype, page_bytes) groups over a
    process pool (each worker prices its groups with its own scratch
    pool and releases it on the way out); results and ordering are
    identical to ``workers=1``."""
    from repro.accesys.pipeline import HOST_S_PER_ELEM
    from repro.core import design_space as DS
    target = resolve(sc.model)
    if target.kind == "serve":
        raise UnsupportedScenario(
            "tune() prices plan/schedule scenarios; serve traces have "
            "per-request semantics — sweep() them per config instead")
    if space is None:
        space = DS.default_space()
    pts = list(space.grid()) if isinstance(space, DS.DesignSpace) \
        else [p.canonical() for p in space]
    n_bad = sum(1 for p in pts if not p.feasible)
    pts = [p for p in pts if p.feasible]
    if not pts:
        raise UnsupportedScenario(
            "design space has no feasible points (buffer_kb below "
            "every point's required_buffer_kb)")
    if callable(objective):
        score_fn = objective
        obj_name = getattr(objective, "__name__", "custom")
    elif objective == "latency":
        def score_fn(point, r):
            return r.total_s
        obj_name = "latency"
    else:
        raise UnsupportedScenario(
            f"unknown tune objective {objective!r}; valid: 'latency' "
            "or a callable (point, result) -> float")
    t0 = time.perf_counter()
    groups: "OrderedDict[tuple, list]" = OrderedDict()
    for i, p in enumerate(pts):
        groups.setdefault((p.dtype, p.page_bytes), []).append(i)
    scored: list = [None] * len(pts)
    hpe = host_s_per_elem or HOST_S_PER_ELEM
    ex = _pool_executor(min(workers, len(groups)))
    payloads = [(sc, dt, pb, [pts[i] for i in idxs], hpe, ex is not None)
                for (dt, pb), idxs in groups.items()]
    try:
        group_results = list(ex.map(_tune_group, payloads)) \
            if ex is not None else [_tune_group(p) for p in payloads]
    finally:
        if ex is not None:
            ex.shutdown()
    for idxs, results in zip(groups.values(), group_results):
        for i, r in zip(idxs, results):
            scored[i] = TunedPoint(
                point=pts[i], result=r,
                area_um2=DS.point_area_um2(pts[i]),
                score=score_fn(pts[i], r))
    wall = time.perf_counter() - t0
    from repro.accesys.pipeline import release_scratch
    release_scratch()          # batched pricing holds peak scratch
    for i in DS.pareto_front((tp.total_s, tp.area_um2)
                             for tp in scored):
        scored[i].on_pareto = True
    return TuneResult(scenario=sc, objective=obj_name, points=scored,
                      n_infeasible=n_bad, wall_s=wall)


def sampling_error(sc: Scenario, *,
                   host_s_per_elem: Optional[float] = None) -> SimResult:
    """Steady-state sampling error bars: run ``sc`` sampled AND exact
    (compiled engine makes the exact run cheap) and return the sampled
    ``SimResult`` with ``sampling_error`` filled in — per-total and
    per-bucket relative error vs the exact replay."""
    sampled = simulate(dataclasses.replace(sc, sampling="sampled"),
                       host_s_per_elem=host_s_per_elem)
    exact = simulate(dataclasses.replace(sc, sampling="exact"),
                     host_s_per_elem=host_s_per_elem)
    eb, sb = exact.result.buckets(), sampled.result.buckets()
    sampled.sampling_error = {
        "exact_total_us": exact.total_s * 1e6,
        "sampled_total_us": sampled.total_s * 1e6,
        "rel_err_total": abs(sampled.total_s - exact.total_s)
            / max(exact.total_s, 1e-30),
        "abs_err_bucket_shares": {k: abs(sb[k] - eb[k]) for k in eb},
        "events_exact": exact.events_replayed,
        "events_sampled": sampled.events_replayed,
        "events_ratio": exact.events_replayed
            / max(sampled.events_replayed, 1),
    }
    return sampled


# ============================================================ load sweep
LOAD_SHAPE = dict(arch="qwen2_0_5b", slots=4, max_seq=96,
                  prompt_lo=8, prompt_hi=24, max_new_tokens=8,
                  prefill_chunk_tokens=16, kv_page_tokens=8,
                  prefix_tokens=0, seed=0, kv_pool_pages=None)


@dataclasses.dataclass
class LoadPoint:
    """One (offered QPS, memory mode) cell of a load sweep."""
    qps: float                     # offered arrival rate
    mode: str
    percentiles: dict              # ServingSimReport.percentiles()
    total_s: float                 # simulated time to drain the trace
    n_finished: int
    n_records: int
    n_events: int
    drained: bool = True           # False: hit max_steps with work left

    @property
    def goodput_qps(self) -> float:
        return self.n_finished / max(self.total_s, 1e-30)

    def to_json(self) -> dict:
        return {"qps": self.qps, "mode": self.mode,
                "total_s": self.total_s,
                "goodput_qps": self.goodput_qps,
                "n_finished": self.n_finished,
                "n_records": self.n_records,
                "n_events": self.n_events,
                "drained": self.drained, **self.percentiles}


@dataclasses.dataclass
class LoadSweepResult:
    """Offered-QPS vs tail-latency curves per memory mode, the
    saturation knee per mode, and (when a shared prefix is configured)
    the prefix-caching on/off delta at the reference load."""
    arch: str
    arrivals: str
    qps: tuple                     # ascending offered-rate grid
    modes: tuple
    n_requests: int
    points: list                   # [LoadPoint], qps-major, mode order
    knee_qps: dict                 # mode -> first saturated qps | None
    calibration: dict              # est_step_s / est_prefill_s_per_token
    prefix_delta: Optional[dict] = None   # mode -> on/off tails
    wall_s: float = 0.0
    preempt: str = "none"          # preemption policy the sweep ran with
    kv_pool_pages: Optional[int] = None   # actual pool cap (None: full)

    SCHEMA = "loadsweep/v1"

    def curve(self, mode: str) -> list:
        return [pt for pt in self.points if pt.mode == mode]

    def to_json(self) -> dict:
        return {"schema": self.SCHEMA, "arch": self.arch,
                "arrivals": self.arrivals, "qps": list(self.qps),
                "modes": list(self.modes),
                "n_requests": self.n_requests,
                "knee_qps": self.knee_qps,
                "calibration": self.calibration,
                "prefix_delta": self.prefix_delta,
                "wall_s": round(self.wall_s, 3),
                "preempt": self.preempt,
                "kv_pool_pages": self.kv_pool_pages,
                "points": [pt.to_json() for pt in self.points]}


def _run_load_point(payload: tuple) -> list:
    """Price ONE offered rate across every memory mode: rebuild the
    engine and system configs from the plain-data payload (picklable,
    so ``sweep_load(workers=N)`` can fan rates over a process pool),
    run the two-pass streamed replay, and return the per-mode
    ``LoadPoint`` list.  Pure in the payload — a workers=N sweep is
    byte-identical to workers=1, which runs this same function
    inline."""
    import numpy as np
    from repro.accesys.pipeline import (release_scratch,
                                        replay_trace_streamed)
    from repro.configs import get_reduced
    from repro.core.plan import _plan_n_events, trace_footprint
    from repro.serving.engine import Request, ServingEngine, arrival_times
    from repro.serving.sim_report import ServingAccumulator

    (sh, pool, modes, arrivals, n_requests, open_kw, hpe,
     chunk_events, lam, caching, templated, in_worker) = payload
    cfg_model = get_reduced(sh["arch"])
    sys_cfgs = [system_for(Scenario(model="serve", mode=m))
                for m in modes]

    def mk_engine() -> ServingEngine:
        return ServingEngine(
            cfg_model, slots=sh["slots"], max_seq=sh["max_seq"],
            plan_only=True, kv_page_tokens=sh["kv_page_tokens"],
            kv_pool_pages=pool, templated=templated,
            prefix_tokens=sh["prefix_tokens"], prefix_caching=caching)

    def mk_requests() -> list:
        rng = np.random.default_rng(sh["seed"] + 1)
        lo, hi = sh["prompt_lo"], sh["prompt_hi"]
        return [Request(
            uid=i,
            prompt=rng.integers(
                1, 250,
                size=lo if lo >= hi else int(rng.integers(lo, hi))
            ).astype(np.int32),
            max_new_tokens=sh["max_new_tokens"])
            for i in range(n_requests)]

    arr = arrival_times(arrivals, n_requests, lam, seed=sh["seed"])
    eng1 = mk_engine()
    counts = {"records": 0, "events": 0}

    def plans_pass1():
        for rec in eng1.open_loop_records(mk_requests(), arr,
                                          **open_kw):
            counts["records"] += 1
            counts["events"] += _plan_n_events(rec.plan)
            yield rec.plan
    foot = trace_footprint(plans_pass1())
    acc = ServingAccumulator()
    eng2 = mk_engine()

    def plans_pass2():
        return (rec.plan for rec in acc.wrap(
            eng2.open_loop_records(mk_requests(), arr, **open_kw)))
    results, pers = replay_trace_streamed(
        sys_cfgs, plans_pass2, host_s_per_elem=hpe,
        footprint_pages=foot, chunk_events=chunk_events)
    live = eng2.unfinished_uids()
    pts = [LoadPoint(
        qps=lam, mode=m, percentiles=rep.percentiles(),
        total_s=rep.total_s, n_finished=eng2.n_finished,
        n_records=counts["records"], n_events=counts["events"],
        drained=eng2.stats.drained)
        for m, rep in zip(modes, (
            acc.report(m, r, p, live)
            for m, r, p in zip(modes, results, pers)))]
    if in_worker:
        release_scratch()      # workers drop their scratch before exit
    return pts


def sweep_load(qps=None, *, n_requests: int = 1000,
               arrivals: str = "poisson", modes=MODES,
               prefix_caching: bool = True,
               chunk_events: int = 262_144, knee_factor: float = 3.0,
               max_steps: int = 1_000_000,
               preempt: str = "none", stall_budget_s: float = 0.0,
               host_s_per_elem: Optional[float] = None,
               workers: int = 1, templated: bool = True,
               **shape) -> LoadSweepResult:
    """Capacity-plan an open-loop serving workload: drive the
    plan-only engine at each offered rate in ``qps`` (auto: a grid
    bracketing the calibrated service capacity), stream every trace
    through ONE chunked multi-mode replay
    (``replay_trace_streamed`` — O(chunk) memory, all memory modes in
    a single pass), and fold the priced durations back onto requests.

    Returns offered-QPS vs TTFT/TPOT p50/p95/p99 curves per memory
    mode plus the saturation knee — the first grid rate whose TTFT
    p99 exceeds ``knee_factor`` x the unloaded (lowest-rate) baseline.
    With ``prefix_tokens`` set in ``shape``, the main curves run with
    ``prefix_caching`` as given and the opposite setting is measured
    once at the reference (lowest) rate — the on/off delta.

    ``preempt`` ("lifo" | "longest") sweeps the swap-thrash regime:
    unless ``kv_pool_pages`` is given in ``shape``, the KV pool is
    capped well below the all-slots worst case so admission stalls
    past ``stall_budget_s`` trigger preemption + KV swap-to-host, and
    the grid is extended (bounded doubling) until every mode has at
    least one priced point STRICTLY past its knee — the curve the
    report's swap/queue percentiles and preemption counts describe.

    The engine's admission clock is calibrated from a small probe
    trace priced on the DC system; reported latencies always come
    from the replay itself, never from the estimates.

    ``workers > 1`` fans the offered-rate grid over a process pool
    (each worker re-derives its traces and prices with its own scratch
    pool, released on the way out); the grid extensions and the prefix
    delta stay sequential because they depend on earlier points.  The
    result is byte-identical to ``workers=1``, and — since templated
    plans replay bitwise identically — to ``templated=False``, which
    rebuilds every plan as a fresh event graph (the pre-templating
    path, kept for benchmarking the template speedup)."""
    import numpy as np
    from repro.accesys.pipeline import (HOST_S_PER_ELEM, release_scratch,
                                        replay_trace)
    from repro.configs import get_reduced
    from repro.serving.engine import Request, ServingEngine

    t0 = time.perf_counter()
    sh = _merge_params("load", LOAD_SHAPE, shape)
    hpe = host_s_per_elem or HOST_S_PER_ELEM
    modes = tuple(modes)
    cfg_model = get_reduced(sh["arch"])

    pool = sh["kv_pool_pages"]
    if pool is None and preempt != "none":
        # pressured default: without a cap the full pool never defers
        # and no preemption can ever fire — cap it at ~60% of the
        # worst case while guaranteeing any single request still fits
        pt = sh["kv_page_tokens"]
        longest = sh["prompt_lo"] if sh["prompt_lo"] >= sh["prompt_hi"] \
            else sh["prompt_hi"] - 1
        worst = min(sh["prefix_tokens"] + longest
                    + sh["max_new_tokens"], sh["max_seq"])
        worst_pages = -(-worst // pt)
        pool = sh["prefix_tokens"] // pt + max(
            worst_pages + 1, int(sh["slots"] * worst_pages * 0.6))

    def mk_engine(caching: bool) -> ServingEngine:
        return ServingEngine(
            cfg_model, slots=sh["slots"], max_seq=sh["max_seq"],
            plan_only=True, kv_page_tokens=sh["kv_page_tokens"],
            kv_pool_pages=pool, templated=templated,
            prefix_tokens=sh["prefix_tokens"], prefix_caching=caching)

    def mk_requests(n: int) -> list:
        rng = np.random.default_rng(sh["seed"] + 1)
        lo, hi = sh["prompt_lo"], sh["prompt_hi"]
        return [Request(
            uid=i,
            prompt=rng.integers(
                1, 250,
                size=lo if lo >= hi else int(rng.integers(lo, hi))
            ).astype(np.int32),
            max_new_tokens=sh["max_new_tokens"])
            for i in range(n)]

    # ---- calibrate the admission clock on a small priced probe (DC)
    probe = mk_engine(prefix_caching and sh["prefix_tokens"] > 0)
    probe.run_open_loop(
        mk_requests(min(8, n_requests)), np.zeros(min(8, n_requests)),
        prefill_chunk_tokens=sh["prefill_chunk_tokens"])
    dc = system_for(Scenario(model="serve", mode="DC"))
    _, probe_per = replay_trace(dc, [r.plan for r in probe.trace],
                                host_s_per_elem=hpe)
    dec = [s for s, r in zip(probe_per, probe.trace)
           if r.kind == "decode"]
    pft = [(s, r.n_tokens) for s, r in zip(probe_per, probe.trace)
           if r.kind == "prefill" and r.n_tokens]
    est_step = float(np.mean(dec)) if dec else 1e-4
    est_pf = float(sum(s for s, _ in pft)
                   / max(sum(n for _, n in pft), 1))
    mean_prompt = sh["prefix_tokens"] + \
        (sh["prompt_lo"] + max(sh["prompt_lo"], sh["prompt_hi"] - 1)) / 2
    cap_qps = 1.0 / (est_pf * mean_prompt
                     + est_step * sh["max_new_tokens"] / sh["slots"])
    if qps is None:
        qps = tuple(round(cap_qps * f, 3)
                    for f in (0.25, 0.5, 1.0, 2.0, 4.0))
    qps = tuple(sorted(float(q) for q in qps))
    open_kw = dict(est_step_s=est_step, est_prefill_s_per_token=est_pf,
                   prefill_chunk_tokens=sh["prefill_chunk_tokens"],
                   max_steps=max_steps, preempt=preempt,
                   stall_budget_s=stall_budget_s)

    ex = _pool_executor(workers)

    def price(lams, caching: bool) -> list:
        """Per-mode LoadPoints for each rate in ``lams``, in order —
        inline when serial, fanned over the pool otherwise."""
        payloads = [(sh, pool, modes, arrivals, n_requests, open_kw,
                     hpe, chunk_events, lam, caching, templated,
                     ex is not None)
                    for lam in lams]
        if ex is None:
            return [_run_load_point(p) for p in payloads]
        return list(ex.map(_run_load_point, payloads))

    caching_main = prefix_caching and sh["prefix_tokens"] > 0
    points: list = []
    try:
        for mode_pts in price(qps, caching_main):
            points += mode_pts

        def compute_knee() -> dict:
            knee = {}
            for m in modes:
                curve = [pt for pt in points if pt.mode == m]
                base = curve[0].percentiles["ttft_p99_us"]
                knee[m] = next(
                    (pt.qps for pt in curve
                     if pt.percentiles["ttft_p99_us"]
                     > knee_factor * base), None)
            return knee

        knee = compute_knee()
        # preemption sweeps must price the thrash regime: keep doubling
        # the top rate (bounded) until every mode has a grid point
        # STRICTLY past its knee
        extensions = 0
        while preempt != "none" and extensions < 3 and any(
                knee[m] is None or knee[m] >= qps[-1] for m in modes):
            lam = round(qps[-1] * 2.0, 3)
            qps = qps + (lam,)
            points += price((lam,), caching_main)[0]
            knee = compute_knee()
            extensions += 1
        prefix_delta = None
        if sh["prefix_tokens"] > 0:
            other = price((qps[0],), not caching_main)[0]
            prefix_delta = {}
            for pt_main, pt_other in zip(
                    [pt for pt in points if pt.qps == qps[0]], other):
                on, off = (pt_main, pt_other) if caching_main else \
                    (pt_other, pt_main)
                prefix_delta[pt_main.mode] = {
                    "ttft_p99_us_on": on.percentiles["ttft_p99_us"],
                    "ttft_p99_us_off": off.percentiles["ttft_p99_us"],
                    "total_s_on": on.total_s,
                    "total_s_off": off.total_s,
                    "records_on": on.n_records,
                    "records_off": off.n_records}
    finally:
        if ex is not None:
            ex.shutdown()
    release_scratch()
    return LoadSweepResult(
        arch=sh["arch"], arrivals=arrivals, qps=qps, modes=modes,
        n_requests=n_requests, points=points, knee_qps=knee,
        calibration={"est_step_s": est_step,
                     "est_prefill_s_per_token": est_pf,
                     "capacity_qps_est": cap_qps},
        prefix_delta=prefix_delta,
        wall_s=time.perf_counter() - t0,
        preempt=preempt, kv_pool_pages=pool)
