"""Streaming GEMM orchestration (Algorithm 1 + Fig. 6).

``BlockMatrixMultiply``: the paper's tile-by-tile GEMM over page-aligned
tiles, expressed as a pipeline of (DMA-in A, DMA-in B, compute,
DMA-out C) events. Two consumers:
  * functional execution (via the Pallas kernel or jnp) for tests and
    the offload examples — mode-aware through ``PageStore``;
  * the event *schedule* itself, which accesys' pipeline simulator
    replays against PCIe/DRAM/SMMU models to produce the paper's
    latency numbers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paging
from repro.core.modes import MemoryMode, PageStore


@dataclasses.dataclass(frozen=True)
class TileOp:
    """One inner-loop step of Algorithm 1 (i, j output tile; k depth)."""
    i: int
    j: int
    k: int
    a_page: int
    b_page: int
    first_k: bool
    last_k: bool


def schedule(M: int, N: int, K: int, dtype,
             page_bytes: int = paging.PAGE_BYTES,
             order: str = "jik") -> Iterator[TileOp]:
    """Yield the paper's loop nest (Algorithm 1) with a cache-aware loop
    order (§3.3 'blocking improves cache utilization'): the default
    ``jik`` keeps the current B column (K/L pages) hot in the LLC across
    the i-sweep while the A operand (usually activations, small) stays
    LLC-resident — so in DC mode each page crosses the link ~once.
    ``ijk`` is the naive order (used as the un-co-designed baseline)."""
    la = paging.layout_for((M, K), dtype, "A", page_bytes)
    lb = paging.layout_for((K, N), dtype, "B", page_bytes)
    W = la.tile_r
    L = la.tile_c
    ni, nj, kk = -(-M // W), -(-N // W), -(-K // L)
    outer, inner = (range(nj), range(ni)) if order == "jik" \
        else (range(ni), range(nj))
    for o in outer:
        for p in inner:
            i, j = (p, o) if order == "jik" else (o, p)
            for k in range(kk):
                yield TileOp(
                    i, j, k,
                    a_page=la.page_of(i * W, k * L),
                    b_page=lb.page_of(k * L, j * W),
                    first_k=(k == 0), last_k=(k == kk - 1))


def gemm_streamed(a: np.ndarray, b: np.ndarray, mode: MemoryMode,
                  page_bytes: int = paging.PAGE_BYTES,
                  cache_pages: int = 512):
    """Run Algorithm 1 tile-by-tile through a mode-aware PageStore.

    Returns (result, PageStore) — the store's TrafficStats carry the
    measured host↔device traffic and cache behaviour per mode.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    la = paging.layout_for((M, K), a.dtype, "A", page_bytes)
    lb = paging.layout_for((K, N), b.dtype, "B", page_bytes)
    a_pages = paging.pack_pages(jnp.asarray(a), la)
    b_pages = paging.pack_pages(jnp.asarray(b), lb)
    store = PageStore(
        {("a", int(i)): a_pages[i] for i in range(la.n_pages)} |
        {("b", int(i)): b_pages[i] for i in range(lb.n_pages)},
        mode, cache_pages=cache_pages)

    W, L = la.tile_r, la.tile_c
    acc_dtype = jnp.int32 if jnp.issubdtype(a_pages.dtype, jnp.integer) \
        else jnp.float32
    gr, gc = -(-M // W), -(-N // W)
    out = np.zeros((gr * W, gc * W), np.float64)
    for i in range(gr):
        for j in range(gc):
            acc = jnp.zeros((W, W), acc_dtype)
            for k in range(-(-K // L)):
                at = store.get(("a", la.page_of(i * W, k * L)))
                # one B page is the full (L × W) block for this (k, j)
                bt = store.get(("b", lb.page_of(k * L, j * W)))
                acc = acc + jnp.dot(at, bt, preferred_element_type=acc_dtype)
            out[i * W:(i + 1) * W, j * W:(j + 1) * W] = np.asarray(acc)
    return out[:M, :N], store


def tile_counts(M: int, N: int, K: int, dtype,
                page_bytes: int = paging.PAGE_BYTES) -> dict:
    """Closed-form tile/page statistics for the accesys simulator."""
    la = paging.layout_for((M, K), dtype, "A", page_bytes)
    lb = paging.layout_for((K, N), dtype, "B", page_bytes)
    W, L = la.tile_r, la.tile_c
    out_tiles = (-(-M // W)) * (-(-N // W))
    k_steps = -(-K // L)
    return {
        "w": W, "l": L,
        "out_tiles": out_tiles,
        "k_steps": k_steps,
        "inner_steps": out_tiles * k_steps,
        "a_pages": la.n_pages, "b_pages": lb.n_pages,
        "a_page_loads": out_tiles * k_steps,
        "b_page_loads": out_tiles * k_steps,
        "c_page_stores": out_tiles,
        "macs": M * N * K,
    }
