"""Functional StreamPlan execution (Algorithm 1 + Fig. 6).

``execute_plan`` is the mode-aware *executor* half of the co-design: it
walks a ``core.plan.StreamPlan`` event graph — the same one the accesys
timing replayer consumes — fetching pages through a ``PageStore`` (DM /
DC / DevMem traffic semantics), running W×W×depth systolic tile GEMMs on
``DMA_IN`` pages, host ops (softmax / layernorm / gelu / ...) on
materialized tensors, and assembling ``DMA_OUT`` tiles into outputs.

``gemm_streamed`` is now a thin wrapper: build the Algorithm-1 plan,
execute it.  There is exactly one loop nest in the codebase
(``plan.gemm_tile_steps``); ``schedule()`` remains as the generator view
of it for compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paging
from repro.core import plan as P
from repro.core.modes import MemoryMode, PageStore


@dataclasses.dataclass(frozen=True)
class TileOp:
    """One inner-loop step of Algorithm 1 (i, j output tile; k depth)."""
    i: int
    j: int
    k: int
    a_page: int
    b_page: int
    first_k: bool
    last_k: bool


def schedule(M: int, N: int, K: int, dtype,
             page_bytes: int = paging.PAGE_BYTES,
             order: str = "jik") -> Iterator[TileOp]:
    """Compatibility view of ``plan.gemm_tile_steps`` — the single
    source of the paper's loop nest and its cache-aware ``jik`` order."""
    for st in P.gemm_tile_steps(M, N, K, dtype, page_bytes, order):
        yield TileOp(st.i, st.j, st.k, st.a_page, st.b_page,
                     st.first_k, st.last_k)


# ------------------------------------------------------------- host ops
def _slice_cols(x, meta):
    out = x[:, meta["start"]:meta["stop"]]
    return out.T if meta.get("transpose") else out


def _layernorm(x, eps: float = 1e-5):
    x = np.asarray(x, np.float64)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / np.sqrt(var + eps)


_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def _act_mul(xs, m):
    return np.asarray(_ACTS[m["act"]](jnp.asarray(xs[0], jnp.float32))
                      * jnp.asarray(xs[1], jnp.float32))


def _moe_route(logits, k: int, C: int):
    """Replicates ``models.moe.apply_moe`` global dispatch: softmax ->
    top-k -> stable sort by expert -> capacity-C keep mask.  Returns
    (e_sorted, tok_sorted, pos_in_e, keep, sorted norm'd probs).
    Dispatch and combine each recompute this deterministically — host
    ops stay stateless functions of (inputs, meta), which matters more
    than one redundant O(n*k log) sort in a reference executor."""
    probs = jax.nn.softmax(jnp.asarray(logits, jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    n, E = logits.shape
    flat_e = np.asarray(top_e).reshape(-1)
    flat_tok = np.repeat(np.arange(n), k)
    flat_p = np.asarray(top_p, np.float64).reshape(-1)
    order = np.argsort(flat_e, kind="stable")
    e_sorted = flat_e[order]
    counts = np.bincount(flat_e, minlength=E)
    starts = np.cumsum(counts) - counts
    pos_in_e = np.arange(n * k) - starts[e_sorted]
    return (e_sorted, flat_tok[order], pos_in_e, pos_in_e < C,
            flat_p[order])


def _moe_dispatch(xs, m):
    x = np.asarray(xs[0], np.float64)
    e_sorted, tok_sorted, pos, keep, _ = _moe_route(xs[1], m["k"], m["C"])
    bufs = [np.zeros((m["C"], x.shape[1])) for _ in range(m["E"])]
    for i in np.nonzero(keep)[0]:
        bufs[e_sorted[i]][pos[i]] = x[tok_sorted[i]]
    return tuple(bufs)


def _moe_combine(xs, m):
    e_sorted, tok_sorted, pos, keep, p_sorted = \
        _moe_route(xs[0], m["k"], m["C"])
    ys = [np.asarray(y, np.float64) for y in xs[1:]]
    out = np.zeros((xs[0].shape[0], ys[0].shape[1]))
    for i in np.nonzero(keep)[0]:
        out[tok_sorted[i]] += p_sorted[i] * ys[e_sorted[i]][pos[i]]
    return out


def _ssm_scan(xs, m):
    from repro.models.ssm import scan_chunk_2d
    t0, t1 = m["t0"], m["t1"]
    r, k, v, logw, state = xs
    out, s = scan_chunk_2d(r[t0:t1], k[t0:t1], v[t0:t1], logw[t0:t1],
                           state, m["H"], m["N"],
                           inclusive=m["inclusive"])
    return np.asarray(out), np.asarray(s)


def _masked_softmax(xs, m):
    s = np.asarray(xs[0], np.float64) * m["scale"]
    valid = m["valid"]
    s[:, valid:] = -np.inf
    e = np.exp(s - s.max(-1, keepdims=True))
    e[:, valid:] = 0.0
    return e / np.maximum(e.sum(-1, keepdims=True), 1e-30)


_HOST_OPS = {
    "softmax": lambda xs, m: np.asarray(jax.nn.softmax(
        jnp.asarray(xs[0], jnp.float32), axis=-1)),
    "gelu": lambda xs, m: np.asarray(jax.nn.gelu(
        jnp.asarray(xs[0], jnp.float32))),
    "layernorm": lambda xs, m: np.asarray(_layernorm(xs[0])),
    "add": lambda xs, m: xs[0] + xs[1],
    "slice_cols": lambda xs, m: _slice_cols(xs[0], m),
    "concat_cols": lambda xs, m: np.concatenate(xs, axis=1),
    "concat_rows": lambda xs, m: np.concatenate(xs, axis=0),
    "transpose": lambda xs, m: xs[0].T,
    "act_mul": _act_mul,
    "moe_dispatch": _moe_dispatch,
    "moe_combine": _moe_combine,
    "ssm_scan": _ssm_scan,
    "masked_softmax": _masked_softmax,
}


# -------------------------------------------------------------- executor
def execute_plan(plan: P.StreamPlan, tensors: dict, mode: MemoryMode,
                 cache_pages: int = 512, paged: dict = None):
    """Run a StreamPlan numerically through a mode-aware PageStore.

    ``tensors`` maps input/weight tensor names to host arrays; returns
    ``(outputs, store)`` where ``outputs`` maps every produced tensor
    name to its materialized array and the store's TrafficStats carry
    the measured host<->device traffic per mode.

    ``paged`` maps pre-paged pool tensor names (role "P", e.g. a KV
    cache) to ``{page_id: page array}`` — those pages stream through
    the store under their POOL page ids, exactly as the page table
    names them, instead of being re-packed from a dense matrix.
    """
    np_dt = np.dtype(plan.dtype)
    acc_dtype = jnp.int32 if np.issubdtype(np_dt, np.integer) \
        else jnp.float32
    store = PageStore({}, mode, cache_pages=cache_pages)
    paged = paged or {}
    packed: set = set()
    layouts: dict = {}
    mats: dict = dict(tensors)     # materialized full tensors (host side)
    out_bufs: dict = {}            # C-tile assembly buffers (padded)
    acc: dict = {}                 # (c, i, j) -> on-device accumulator
    buf: dict = {}                 # fetched pages awaiting their compute
    produced: set = set()

    def ensure_packed(name: str) -> None:
        if name in packed:
            return
        if name in paged:          # pool tensor: pages come pre-cut
            store.add_pages({(name, int(pid)): np.asarray(arr)
                             for pid, arr in paged[name].items()})
            packed.add(name)
            return
        spec = plan.tensors[name]
        if "P" in spec.roles:
            # pool page ids come verbatim from a page table; a dense
            # repack would index a different page grid entirely
            raise ValueError(
                f"pool tensor {name!r} must be supplied via `paged=`")
        if {"A", "B"} <= spec.roles:
            # page ids for A (row-major) and B (row-striped) layouts
            # index different page grids; one physical page set cannot
            # serve both.  Builders avoid this by materializing a copy
            # under a second name (e.g. via a "transpose" host op).
            raise NotImplementedError(
                f"tensor {name!r} is consumed as both an A and a B "
                "operand; give the B-side consumer its own tensor name")
        role = "A" if "A" in spec.roles else "B"
        lay = paging.layout_for((spec.rows, spec.cols), np_dt, role,
                                plan.page_bytes)
        arr = np.asarray(materialize(name)).astype(np_dt)
        pages = paging.pack_pages(jnp.asarray(arr), lay)
        store.add_pages({(name, int(i)): pages[i]
                         for i in range(lay.n_pages)})
        layouts[name] = lay
        packed.add(name)

    def materialize(name: str):
        if name not in mats:
            spec = plan.tensors[name]
            mats[name] = out_bufs.pop(name)[:spec.rows, :spec.cols]
        return mats[name]

    for ev in plan.events:
        if ev.kind is P.EventKind.DMA_IN:
            ensure_packed(ev.page[0])
            buf[ev.page] = store.get(ev.page)
        elif ev.kind is P.EventKind.COMPUTE and ev.unit == "sa":
            m = ev.meta
            if ev.op == "attn_qk":     # q_b x one K page -> score block
                # GQA: pass g covers the contiguous q-head block
                # [q0, q0+heads); q head h reads kv head h // group
                # (group == 1 is plain MHA).  The page is fetched once
                # per (slot, page) — the LAST pass pops it.
                g = m.get("g", 0)
                grp = m.get("group", 1)
                key_pg = (m["k"], m["page"])
                page = np.asarray(buf.pop(key_pg) if g == grp - 1
                                  else buf[key_pg], np.float32)
                q0 = m.get("q0", 0)
                qb = np.asarray(materialize(m["q"]))[m["slot"]] \
                    .reshape(m.get("n_q", m["heads"]), m["head_dim"]) \
                    [q0:q0 + m["heads"]].astype(np.float32)
                kv_idx = (q0 + np.arange(m["heads"])) // grp
                acc[(m["scores"], g, m["page_idx"])] = \
                    jnp.einsum("hd,thd->ht", qb, page[:, kv_idx, :])
            elif ev.op == "attn_pv":   # prob block x one V page, accum
                g = m.get("g", 0)
                grp = m.get("group", 1)
                key_pg = (m["v"], m["page"])
                page = np.asarray(buf.pop(key_pg) if g == grp - 1
                                  else buf[key_pg], np.float32)
                pt = m["pt"]
                q0 = m.get("q0", 0)
                pb = np.asarray(materialize(m["p"]))[
                    q0:q0 + m["heads"],
                    m["page_idx"] * pt:(m["page_idx"] + 1) * pt
                ].astype(np.float32)
                kv_idx = (q0 + np.arange(m["heads"])) // grp
                part = jnp.einsum("ht,thd->hd", pb, page[:, kv_idx, :])
                key = (m["out"], m["slot"], g)
                acc[key] = part if m["first"] else acc[key] + part
            elif ev.op in ("prefill_qk", "prefill_pv"):
                raise NotImplementedError(
                    "prefill plans are timing-only: chunked prefill "
                    "attention has no functional executor yet (replay "
                    "them with accesys.pipeline.replay/replay_trace)")
            else:                      # gemm: one W×W×depth tile step
                at = buf.pop((m["a"], m["a_page"]))
                bt = buf.pop((m["b"], m["b_page"]))
                key = (m["c"], m["i"], m["j"])
                part = jnp.dot(at, bt, preferred_element_type=acc_dtype)
                acc[key] = part if m["first_k"] else acc[key] + part
        elif ev.kind is P.EventKind.COMPUTE:
            m = ev.meta
            ins = [np.asarray(materialize(n)) for n in m["inputs"]]
            res = _HOST_OPS[ev.op](ins, m)
            for name, r in zip(m.get("outs") or (m["out"],),
                               res if "outs" in m else (res,)):
                mats[name] = np.asarray(r)
                produced.add(name)
        elif ev.kind is P.EventKind.COLLECTIVE:
            # inter-device exchange hop: timing-only (the single-rank
            # functional executor already holds every rank's data; the
            # replayer prices the fabric crossing)
            continue
        else:                       # DMA_OUT: drain one accumulated tile
            if not isinstance(ev.page[1], tuple):
                raise NotImplementedError(
                    f"DMA_OUT to pool page {ev.page!r} (e.g. a prefill "
                    "kv_write) is timing-only — no functional executor")
            name, (i, j) = ev.page
            spec = plan.tensors[name]
            w = paging.SA_DIM
            if name not in out_bufs:
                gr, gc = -(-spec.rows // w), -(-spec.cols // w)
                out_bufs[name] = np.zeros((gr * w, gc * w), np.float64)
            tile = np.asarray(acc.pop((name, i, j)))
            r0, c0 = ev.meta.get("at", (i * w, j * w))
            out_bufs[name][r0:r0 + tile.shape[0],
                           c0:c0 + tile.shape[1]] = tile
            produced.add(name)
    outputs = {n: np.asarray(materialize(n)) for n in produced}
    return outputs, store


def gemm_streamed(a: np.ndarray, b: np.ndarray, mode: MemoryMode,
                  page_bytes: int = paging.PAGE_BYTES,
                  cache_pages: int = 512,
                  order: str = "jik"):
    """Run Algorithm 1 tile-by-tile through a mode-aware PageStore, by
    executing the same ``StreamPlan`` the accesys simulator replays
    (cache-aware ``jik`` order included).

    Returns (result, PageStore) — the store's TrafficStats carry the
    measured host<->device traffic and cache behaviour per mode.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    plan = P.gemm_plan(M, N, K, a.dtype, page_bytes=page_bytes,
                       order=order)
    outs, store = execute_plan(plan, {"a": a, "b": b}, mode,
                               cache_pages=cache_pages)
    return outs["c"], store


def tile_counts(M: int, N: int, K: int, dtype,
                page_bytes: int = paging.PAGE_BYTES) -> dict:
    """Closed-form tile/page statistics for the accesys simulator."""
    la = paging.layout_for((M, K), dtype, "A", page_bytes)
    lb = paging.layout_for((K, N), dtype, "B", page_bytes)
    W, L = la.tile_r, la.tile_c
    out_tiles = (-(-M // W)) * (-(-N // W))
    k_steps = -(-K // L)
    return {
        "w": W, "l": L,
        "out_tiles": out_tiles,
        "k_steps": k_steps,
        "inner_steps": out_tiles * k_steps,
        "a_pages": la.n_pages, "b_pages": lb.n_pages,
        "a_page_loads": out_tiles * k_steps,
        "b_page_loads": out_tiles * k_steps,
        "c_page_stores": out_tiles,
        "macs": M * N * K,
    }
