"""Functional StreamPlan execution (Algorithm 1 + Fig. 6).

``execute_plan`` is the mode-aware *executor* half of the co-design: it
walks a ``core.plan.StreamPlan`` event graph — the same one the accesys
timing replayer consumes — fetching pages through a ``PageStore`` (DM /
DC / DevMem traffic semantics), running W×W×depth systolic tile GEMMs on
``DMA_IN`` pages, host ops (softmax / layernorm / gelu / ...) on
materialized tensors, and assembling ``DMA_OUT`` tiles into outputs.

``gemm_streamed`` is now a thin wrapper: build the Algorithm-1 plan,
execute it.  There is exactly one loop nest in the codebase
(``plan.gemm_tile_steps``); ``schedule()`` remains as the generator view
of it for compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paging
from repro.core import plan as P
from repro.core.modes import MemoryMode, PageStore


@dataclasses.dataclass(frozen=True)
class TileOp:
    """One inner-loop step of Algorithm 1 (i, j output tile; k depth)."""
    i: int
    j: int
    k: int
    a_page: int
    b_page: int
    first_k: bool
    last_k: bool


def schedule(M: int, N: int, K: int, dtype,
             page_bytes: int = paging.PAGE_BYTES,
             order: str = "jik") -> Iterator[TileOp]:
    """Compatibility view of ``plan.gemm_tile_steps`` — the single
    source of the paper's loop nest and its cache-aware ``jik`` order."""
    for st in P.gemm_tile_steps(M, N, K, dtype, page_bytes, order):
        yield TileOp(st.i, st.j, st.k, st.a_page, st.b_page,
                     st.first_k, st.last_k)


# ------------------------------------------------------------- host ops
def _slice_cols(x, meta):
    out = x[:, meta["start"]:meta["stop"]]
    return out.T if meta.get("transpose") else out


_HOST_OPS = {
    "softmax": lambda xs, m: np.asarray(jax.nn.softmax(
        jnp.asarray(xs[0], jnp.float32), axis=-1)),
    "gelu": lambda xs, m: np.asarray(jax.nn.gelu(
        jnp.asarray(xs[0], jnp.float32))),
    "layernorm": lambda xs, m: np.asarray(_layernorm(xs[0])),
    "add": lambda xs, m: xs[0] + xs[1],
    "slice_cols": lambda xs, m: _slice_cols(xs[0], m),
    "concat_cols": lambda xs, m: np.concatenate(xs, axis=1),
    "transpose": lambda xs, m: xs[0].T,
}


def _layernorm(x, eps: float = 1e-5):
    x = np.asarray(x, np.float64)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / np.sqrt(var + eps)


# -------------------------------------------------------------- executor
def execute_plan(plan: P.StreamPlan, tensors: dict, mode: MemoryMode,
                 cache_pages: int = 512):
    """Run a StreamPlan numerically through a mode-aware PageStore.

    ``tensors`` maps input/weight tensor names to host arrays; returns
    ``(outputs, store)`` where ``outputs`` maps every produced tensor
    name to its materialized array and the store's TrafficStats carry
    the measured host<->device traffic per mode.
    """
    np_dt = np.dtype(plan.dtype)
    acc_dtype = jnp.int32 if np.issubdtype(np_dt, np.integer) \
        else jnp.float32
    store = PageStore({}, mode, cache_pages=cache_pages)
    packed: set = set()
    layouts: dict = {}
    mats: dict = dict(tensors)     # materialized full tensors (host side)
    out_bufs: dict = {}            # C-tile assembly buffers (padded)
    acc: dict = {}                 # (c, i, j) -> on-device accumulator
    buf: dict = {}                 # fetched pages awaiting their compute
    produced: set = set()

    def ensure_packed(name: str) -> None:
        if name in packed:
            return
        spec = plan.tensors[name]
        if {"A", "B"} <= spec.roles:
            # page ids for A (row-major) and B (row-striped) layouts
            # index different page grids; one physical page set cannot
            # serve both.  Builders avoid this by materializing a copy
            # under a second name (e.g. via a "transpose" host op).
            raise NotImplementedError(
                f"tensor {name!r} is consumed as both an A and a B "
                "operand; give the B-side consumer its own tensor name")
        role = "A" if "A" in spec.roles else "B"
        lay = paging.layout_for((spec.rows, spec.cols), np_dt, role,
                                plan.page_bytes)
        arr = np.asarray(materialize(name)).astype(np_dt)
        pages = paging.pack_pages(jnp.asarray(arr), lay)
        store.add_pages({(name, int(i)): pages[i]
                         for i in range(lay.n_pages)})
        layouts[name] = lay
        packed.add(name)

    def materialize(name: str):
        if name not in mats:
            spec = plan.tensors[name]
            mats[name] = out_bufs.pop(name)[:spec.rows, :spec.cols]
        return mats[name]

    for ev in plan.events:
        if ev.kind is P.EventKind.DMA_IN:
            ensure_packed(ev.page[0])
            buf[ev.page] = store.get(ev.page)
        elif ev.kind is P.EventKind.COMPUTE and ev.unit == "sa":
            m = ev.meta
            at = buf.pop((m["a"], m["a_page"]))
            bt = buf.pop((m["b"], m["b_page"]))
            key = (m["c"], m["i"], m["j"])
            part = jnp.dot(at, bt, preferred_element_type=acc_dtype)
            acc[key] = part if m["first_k"] else acc[key] + part
        elif ev.kind is P.EventKind.COMPUTE:
            m = ev.meta
            ins = [np.asarray(materialize(n)) for n in m["inputs"]]
            mats[m["out"]] = np.asarray(_HOST_OPS[ev.op](ins, m))
            produced.add(m["out"])
        else:                       # DMA_OUT: drain one W×W C tile
            name, (i, j) = ev.page
            spec = plan.tensors[name]
            w = paging.SA_DIM
            if name not in out_bufs:
                gr, gc = -(-spec.rows // w), -(-spec.cols // w)
                out_bufs[name] = np.zeros((gr * w, gc * w), np.float64)
            tile = np.asarray(acc.pop((name, i, j)))
            out_bufs[name][i * w:(i + 1) * w, j * w:(j + 1) * w] = tile
            produced.add(name)
    outputs = {n: np.asarray(materialize(n)) for n in produced}
    return outputs, store


def gemm_streamed(a: np.ndarray, b: np.ndarray, mode: MemoryMode,
                  page_bytes: int = paging.PAGE_BYTES,
                  cache_pages: int = 512,
                  order: str = "jik"):
    """Run Algorithm 1 tile-by-tile through a mode-aware PageStore, by
    executing the same ``StreamPlan`` the accesys simulator replays
    (cache-aware ``jik`` order included).

    Returns (result, PageStore) — the store's TrafficStats carry the
    measured host<->device traffic and cache behaviour per mode.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    plan = P.gemm_plan(M, N, K, a.dtype, page_bytes=page_bytes,
                       order=order)
    outs, store = execute_plan(plan, {"a": a, "b": b}, mode,
                               cache_pages=cache_pages)
    return outs["c"], store


def tile_counts(M: int, N: int, K: int, dtype,
                page_bytes: int = paging.PAGE_BYTES) -> dict:
    """Closed-form tile/page statistics for the accesys simulator."""
    la = paging.layout_for((M, K), dtype, "A", page_bytes)
    lb = paging.layout_for((K, N), dtype, "B", page_bytes)
    W, L = la.tile_r, la.tile_c
    out_tiles = (-(-M // W)) * (-(-N // W))
    k_steps = -(-K // L)
    return {
        "w": W, "l": L,
        "out_tiles": out_tiles,
        "k_steps": k_steps,
        "inner_steps": out_tiles * k_steps,
        "a_pages": la.n_pages, "b_pages": lb.n_pages,
        "a_page_loads": out_tiles * k_steps,
        "b_page_loads": out_tiles * k_steps,
        "c_page_stores": out_tiles,
        "macs": M * N * K,
    }
