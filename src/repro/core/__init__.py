from repro.core import modes, overlap, paging, streaming  # noqa: F401
