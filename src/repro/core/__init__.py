from repro.core import modes, overlap, paging, plan, streaming  # noqa: F401
