from repro.core import modes, overlap, paging, plan, scenario, \
    streaming  # noqa: F401
