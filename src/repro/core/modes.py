"""Memory-access modes (paper Fig. 1): DM / DC / DevMem, adapted to the
TPU host-offload setting.

  DM     — weights live in HOST memory; every use streams them to the
           device, no reuse cache (paper: DMA straight to DRAM, arrows
           3,5 — bypasses the LLC).
  DC     — like DM plus a device-side LRU page cache (the "LLC",
           arrows 2,4,5): hot tiles are served at device speed.
  DevMem — weights resident in device memory (arrow 6): no host traffic
           during compute, but host-side stages pay the crossing.

On real hardware the placement uses ``memory_kind="pinned_host"`` vs
``"device"``; on the CPU backend (no distinct host space) the semantics
are preserved and all traffic is metered, which is what the benchmarks
and the accesys simulator consume.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp


class MemoryMode(enum.Enum):
    DM = "DM"
    DC = "DC"
    DEVMEM = "DevMem"


def _has_host_memory_kind() -> bool:
    try:
        dev = jax.devices()[0]
        kinds = [m.kind for m in dev.addressable_memories()]
        return "pinned_host" in kinds
    except Exception:
        return False


def host_placement(x):
    """Place an array in host memory.

    We keep host-resident data as NUMPY arrays: genuinely host RAM on
    every backend, and it sidesteps jax's sticky <host> memory-space
    avals on sliced pinned_host buffers (device_put of a numpy array is
    the portable H2D DMA). On TPU deployments the ``pinned_host``
    memory-kind variant applies — see _has_host_memory_kind.
    """
    import numpy as np
    return np.asarray(jax.device_get(x))


def device_placement(x):
    return jax.device_put(x, jax.devices()[0])


@dataclasses.dataclass
class TrafficStats:
    host_to_device_bytes: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    lookups: int = 0

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / max(self.lookups, 1)


class PageStore:
    """Mode-aware page provider: the software half of the co-design.

    ``get(page_id)`` returns the page on-device, metering the traffic the
    chosen mode implies. DevMem: everything resident. DM: every access
    streams host→device. DC: LRU cache of ``cache_pages`` (the LLC).
    """

    def __init__(self, pages: dict, mode: MemoryMode,
                 cache_pages: int = 512):
        self.mode = mode
        self.stats = TrafficStats()
        self._page_bytes: dict = {}
        self._resident: dict = {} if mode is MemoryMode.DEVMEM else None
        self._host: dict = None if mode is MemoryMode.DEVMEM else {}
        self._cache: "collections.OrderedDict" = collections.OrderedDict()
        self._cache_pages = cache_pages
        self.add_pages(pages)

    def add_pages(self, pages: dict) -> None:
        """Register pages after construction — intermediates produced
        mid-plan (an upstream op's DMA-out becomes a downstream operand)
        land host-side in DM/DC and resident in DevMem."""
        self._page_bytes.update({k: int(v.size * v.dtype.itemsize)
                                 for k, v in pages.items()})
        if self.mode is MemoryMode.DEVMEM:
            self._resident.update({k: device_placement(v)
                                   for k, v in pages.items()})
        else:
            self._host.update({k: host_placement(v)
                               for k, v in pages.items()})

    def get(self, page_id):
        self.stats.lookups += 1
        if self.mode is MemoryMode.DEVMEM:
            return self._resident[page_id]
        if self.mode is MemoryMode.DC:
            if page_id in self._cache:
                self.stats.cache_hits += 1
                self._cache.move_to_end(page_id)
                return self._cache[page_id]
            self.stats.cache_misses += 1
        arr = device_placement(self._host[page_id])
        self.stats.host_to_device_bytes += self._page_bytes[page_id]
        if self.mode is MemoryMode.DC:
            self._cache[page_id] = arr
            while len(self._cache) > self._cache_pages:
                self._cache.popitem(last=False)
        return arr
