"""The paper's overlap bound (Eq. 1) and its TPU re-derivation.

Eq. 1 (paper): for a W×W output-stationary array at clock f consuming
A (W×L) and B (L×W) page tiles and draining C (W×W), transfers fully
hide behind compute iff

    S·(2WL + W²) / (η_io·BW) ≤ (L + 2(W−1)) / (η_sa·f)
    ⟹  BW ≥ S·f·(2WL + W²)/(L + 2(W−1)) · η_sa/η_io

Asymptotes (L→∞): BW∞ = 2·S·f·W → 32/64/128 GB/s for INT8/FP16/FP32 at
W=16, f=1 GHz — the paper's numbers, reproduced by tests.

TPU analogue: a (bm×bk)·(bk×bn) MXU block is compute-bound iff
    bytes/step / HBM_BW ≤ flops/step / peak  ⟺  intensity ≥ peak/HBM_BW
with intensity = 2·bm·bn·bk / S·(bm·bk + bk·bn + spill). Same algebra,
different constants; used to pick kernel block sizes.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class OverlapPoint:
    bw_required: float          # B/s to keep the array busy
    compute_s: float            # per-tile compute time
    transfer_s: float           # per-tile transfer time at bw_peak
    feasible: bool


def required_bandwidth(W: int, L: int, f: float, elem_bytes: int,
                       eta_sa: float = 1.0, eta_io: float = 1.0) -> float:
    """Eq. 1 right-hand side."""
    num = elem_bytes * f * (2 * W * L + W * W)
    den = L + 2 * (W - 1)
    return num / den * (eta_sa / eta_io)


def asymptotic_bandwidth(W: int, f: float, elem_bytes: int) -> float:
    """L→∞ limit of Eq. 1: 2·S·f·W."""
    return 2.0 * elem_bytes * f * W


def evaluate(W: int, L: int, f: float, elem_bytes: int, bw_peak: float,
             eta_sa: float = 1.0, eta_io: float = 1.0) -> OverlapPoint:
    bw_req = required_bandwidth(W, L, f, elem_bytes, eta_sa, eta_io)
    compute = (L + 2 * (W - 1)) / (eta_sa * f)
    transfer = elem_bytes * (2 * W * L + W * W) / (eta_io * bw_peak)
    return OverlapPoint(bw_req, compute, transfer, transfer <= compute)


def sram_doubling_delta(W: int, L: int, f: float, elem_bytes: int) -> float:
    """Relative CHANGE of the Eq.-1 bound when on-chip SRAM doubles
    (L → 2L). Positive: the requirement gets *tighter* — longer tiles
    amortize the fill/drain bubbles that previously gave the link slack.
    Paper: ≤1–3 % at the 16×16 / 4 KB / INT8 design point, i.e. doubling
    SRAM area+leakage buys nothing — the core argument for paged
    streaming over scratchpad reuse."""
    b1 = required_bandwidth(W, L, f, elem_bytes)
    b2 = required_bandwidth(W, 2 * L, f, elem_bytes)
    return (b2 - b1) / b1


def min_feasible_tile_len(W: int, f: float, elem_bytes: int,
                          bw_peak: float, max_l: int = 65536) -> int | None:
    """Smallest L whose Eq.-1 bound fits under bw_peak (None if even the
    asymptote exceeds the link — then the design is bandwidth-starved)."""
    if asymptotic_bandwidth(W, f, elem_bytes) > bw_peak:
        return None
    lo, hi = 1, max_l
    if required_bandwidth(W, hi, f, elem_bytes) > bw_peak:
        return None
    while lo < hi:
        mid = (lo + hi) // 2
        if required_bandwidth(W, mid, f, elem_bytes) <= bw_peak:
            hi = mid
        else:
            lo = mid + 1
    return lo


# ---------------------------------------------------------------------
# TPU re-derivation: block-level overlap for the streaming GEMM kernel
# ---------------------------------------------------------------------
def tpu_block_overlap(bm: int, bn: int, bk: int, elem_bytes: int,
                      peak_flops: float, hbm_bw: float) -> OverlapPoint:
    flops = 2.0 * bm * bn * bk
    bytes_in = (bm * bk + bk * bn) * elem_bytes
    compute = flops / peak_flops
    transfer = bytes_in / hbm_bw
    # required bandwidth so transfer == compute
    bw_req = bytes_in / compute
    return OverlapPoint(bw_req, compute, transfer, transfer <= compute)


def choose_gemm_blocks(M: int, N: int, K: int, dtype,
                       peak_flops: float = 197e12, hbm_bw: float = 819e9,
                       vmem_budget: int = 8 * 1024 * 1024,
                       page_bytes: int = 4096):
    """THE Pallas block chooser (the former ``paging.page_aligned_blocks``
    and the overlap-bound chooser, collapsed into one).

    Picks (bm, bn, bk) that are (a) page-aligned — every HBM->VMEM copy
    is a whole number of 4 KB pages, one descriptor per tile, (b)
    MXU-aligned (candidates are 128..2048 powers of two), (c) within
    the VMEM budget (A tile + B tile + fp32 C accumulator), and (d) the
    *smallest* such working set that is still compute-bound by the TPU
    overlap bound (Eq. 1 re-derived) — the paper's thesis: small
    buffers + streaming suffice once the bound is met.  If no candidate
    meets the bound (bandwidth-starved link) it falls back to the
    largest-reuse block that fits, greedily grown K-first to amortize
    the C flush.

    ``dtype`` may be a numpy/jax dtype or an element byte count.
    """
    from repro.core import paging
    s = dtype if isinstance(dtype, int) else paging.dtype_bytes(dtype)

    def fit(bm, bn, bk):
        return (bm * bk + bk * bn) * s + bm * bn * 4 <= vmem_budget

    def page_ok(bm, bn, bk):
        return (bm * bk * s) % page_bytes == 0 and \
            (bk * bn * s) % page_bytes == 0

    best = None
    cand_sizes = [128, 256, 512, 1024, 2048]
    for bm in cand_sizes:
        for bn in cand_sizes:
            for bk in cand_sizes:
                if bm > max(M, 128) or bn > max(N, 128) or bk > max(K, 128):
                    continue
                if not fit(bm, bn, bk) or not page_ok(bm, bn, bk):
                    continue
                pt = tpu_block_overlap(bm, bn, bk, s, peak_flops, hbm_bw)
                if not pt.feasible:
                    continue
                vmem = (bm * bk + bk * bn) * s + bm * bn * 4
                key = (vmem, -bk)          # smallest working set, deep K
                if best is None or key < best[0]:
                    best = (key, (bm, bn, bk))
    if best is not None:
        return best[1]
    # bandwidth-starved: maximize reuse instead — greedy doubling from
    # the MXU floor, K first (depth amortizes the C flush)
    bm = bn = bk = 128
    for _ in range(64):
        grew = False
        for dim in ("bk", "bm", "bn"):
            cand = dict(bm=bm, bn=bn, bk=bk)
            cand[dim] *= 2
            if cand["bm"] <= max(M, 128) and cand["bn"] <= max(N, 128) \
                    and cand["bk"] <= max(K, 128) and fit(**cand) \
                    and page_ok(**cand):
                bm, bn, bk = cand["bm"], cand["bn"], cand["bk"]
                grew = True
        if not grew:
            break
    assert page_ok(bm, bn, bk), (bm, bn, bk, s)
    return bm, bn, bk
