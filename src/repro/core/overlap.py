"""The paper's overlap bound (Eq. 1) and its TPU re-derivation.

Eq. 1 (paper): for a W×W output-stationary array at clock f consuming
A (W×L) and B (L×W) page tiles and draining C (W×W), transfers fully
hide behind compute iff

    S·(2WL + W²) / (η_io·BW) ≤ (L + 2(W−1)) / (η_sa·f)
    ⟹  BW ≥ S·f·(2WL + W²)/(L + 2(W−1)) · η_sa/η_io

Asymptotes (L→∞): BW∞ = 2·S·f·W → 32/64/128 GB/s for INT8/FP16/FP32 at
W=16, f=1 GHz — the paper's numbers, reproduced by tests.

TPU analogue: a (bm×bk)·(bk×bn) MXU block is compute-bound iff
    bytes/step / HBM_BW ≤ flops/step / peak  ⟺  intensity ≥ peak/HBM_BW
with intensity = 2·bm·bn·bk / S·(bm·bk + bk·bn + spill). Same algebra,
different constants; used to pick kernel block sizes.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class OverlapPoint:
    bw_required: float          # B/s to keep the array busy
    compute_s: float            # per-tile compute time
    transfer_s: float           # per-tile transfer time at bw_peak
    feasible: bool


def required_bandwidth(W: int, L: int, f: float, elem_bytes: int,
                       eta_sa: float = 1.0, eta_io: float = 1.0) -> float:
    """Eq. 1 right-hand side."""
    num = elem_bytes * f * (2 * W * L + W * W)
    den = L + 2 * (W - 1)
    return num / den * (eta_sa / eta_io)


def asymptotic_bandwidth(W: int, f: float, elem_bytes: int) -> float:
    """L→∞ limit of Eq. 1: 2·S·f·W."""
    return 2.0 * elem_bytes * f * W


def evaluate(W: int, L: int, f: float, elem_bytes: int, bw_peak: float,
             eta_sa: float = 1.0, eta_io: float = 1.0) -> OverlapPoint:
    bw_req = required_bandwidth(W, L, f, elem_bytes, eta_sa, eta_io)
    compute = (L + 2 * (W - 1)) / (eta_sa * f)
    transfer = elem_bytes * (2 * W * L + W * W) / (eta_io * bw_peak)
    return OverlapPoint(bw_req, compute, transfer, transfer <= compute)


def sram_doubling_delta(W: int, L: int, f: float, elem_bytes: int) -> float:
    """Relative CHANGE of the Eq.-1 bound when on-chip SRAM doubles
    (L → 2L). Positive: the requirement gets *tighter* — longer tiles
    amortize the fill/drain bubbles that previously gave the link slack.
    Paper: ≤1–3 % at the 16×16 / 4 KB / INT8 design point, i.e. doubling
    SRAM area+leakage buys nothing — the core argument for paged
    streaming over scratchpad reuse."""
    b1 = required_bandwidth(W, L, f, elem_bytes)
    b2 = required_bandwidth(W, 2 * L, f, elem_bytes)
    return (b2 - b1) / b1


def min_feasible_tile_len(W: int, f: float, elem_bytes: int,
                          bw_peak: float, max_l: int = 65536) -> int | None:
    """Smallest L whose Eq.-1 bound fits under bw_peak (None if even the
    asymptote exceeds the link — then the design is bandwidth-starved)."""
    if asymptotic_bandwidth(W, f, elem_bytes) > bw_peak:
        return None
    lo, hi = 1, max_l
    if required_bandwidth(W, hi, f, elem_bytes) > bw_peak:
        return None
    while lo < hi:
        mid = (lo + hi) // 2
        if required_bandwidth(W, mid, f, elem_bytes) <= bw_peak:
            hi = mid
        else:
            lo = mid + 1
    return lo


# ---------------------------------------------------------------------
# TPU re-derivation: block-level overlap for the streaming GEMM kernel
# ---------------------------------------------------------------------
def tpu_block_overlap(bm: int, bn: int, bk: int, elem_bytes: int,
                      peak_flops: float, hbm_bw: float) -> OverlapPoint:
    flops = 2.0 * bm * bn * bk
    bytes_in = (bm * bk + bk * bn) * elem_bytes
    compute = flops / peak_flops
    transfer = bytes_in / hbm_bw
    # required bandwidth so transfer == compute
    bw_req = bytes_in / compute
    return OverlapPoint(bw_req, compute, transfer, transfer <= compute)


def choose_gemm_blocks(M: int, N: int, K: int, elem_bytes: int,
                       peak_flops: float = 197e12, hbm_bw: float = 819e9,
                       vmem_budget: int = 8 * 1024 * 1024):
    """Pick (bm, bn, bk): smallest VMEM working set that is still
    compute-bound by the TPU overlap bound — the paper's thesis
    ('small buffers + streaming suffice once the bound is met')."""
    best = None
    cand_sizes = [128, 256, 512, 1024, 2048]
    for bm in cand_sizes:
        for bn in cand_sizes:
            for bk in cand_sizes:
                if bm > max(M, 128) or bn > max(N, 128) or bk > max(K, 128):
                    continue
                vmem = (bm * bk + bk * bn) * elem_bytes + bm * bn * 4
                if vmem > vmem_budget:
                    continue
                pt = tpu_block_overlap(bm, bn, bk, elem_bytes,
                                       peak_flops, hbm_bw)
                if not pt.feasible:
                    continue
                key = (vmem, -bk)          # smallest working set, deep K
                if best is None or key < best[0]:
                    best = (key, (bm, bn, bk))
    if best is None:                        # bandwidth-starved: max reuse
        return 512, 512, min(2048, max(K, 128))
    return best[1]
