"""Page-aligned tiling (paper §3.3).

The runtime partitions matrices into page-sized tiles: one tile = one OS
page = one DMA descriptor = at most one TLB lookup. Tile geometry follows
the paper exactly: W=16 rows, L columns such that W·L·S = page_bytes
(INT8 16×256, FP16/INT16 16×128, FP32/INT32 16×64 for 4 KB pages).

A is stored row-major per tile; B is stored ROW-STRIPED (by rows within
the tile, tiles laid out so the k-walk of B is contiguous) — avoiding the
strided column walk of Fig. 5 (top).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

PAGE_BYTES = 4096
SA_DIM = 16                     # paper's systolic array width W


def dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def tile_shape(dtype, page_bytes: int = PAGE_BYTES,
               rows: int = SA_DIM) -> tuple[int, int]:
    """(rows, cols) so one tile fills exactly one page."""
    cols = page_bytes // (rows * dtype_bytes(dtype))
    assert rows * cols * dtype_bytes(dtype) == page_bytes, \
        (dtype, page_bytes, rows)
    return rows, cols


@dataclasses.dataclass(frozen=True)
class PageLayout:
    """Blocked layout of an (R, C) matrix in page tiles."""
    rows: int
    cols: int
    tile_r: int
    tile_c: int
    row_striped: bool = False      # B-operand layout

    @property
    def grid(self) -> tuple[int, int]:
        return (-(-self.rows // self.tile_r), -(-self.cols // self.tile_c))

    @property
    def n_pages(self) -> int:
        g = self.grid
        return g[0] * g[1]

    @property
    def padded(self) -> tuple[int, int]:
        g = self.grid
        return (g[0] * self.tile_r, g[1] * self.tile_c)

    def page_of(self, r: int, c: int) -> int:
        """Linear page id holding element (r, c)."""
        ti, tj = r // self.tile_r, c // self.tile_c
        gr, gc = self.grid
        # row-striped B: pages laid out column-of-tiles-major so a k-walk
        # (down a tile column) is contiguous
        return (tj * gr + ti) if self.row_striped else (ti * gc + tj)

    def page_offset(self, r: int, c: int) -> int:
        """Byte-free offset (in elements) of (r, c) inside its page —
        row-major within the tile in BOTH layouts (that is the point:
        no strided access even when walking B by column-of-tiles)."""
        return (r % self.tile_r) * self.tile_c + (c % self.tile_c)


def layout_for(shape, dtype, operand: str = "A",
               page_bytes: int = PAGE_BYTES) -> PageLayout:
    """A pages are (W × L); B pages are the transposed (L × W) so that one
    A page × one B page yields a full W×W output block — B stored
    row-striped (row-major within the L×W tile, tiles k-contiguous)."""
    tr, tc = tile_shape(dtype, page_bytes)
    if operand.upper() == "B":
        return PageLayout(shape[0], shape[1], tc, tr, row_striped=True)
    return PageLayout(shape[0], shape[1], tr, tc, row_striped=False)


def pack_pages(x, layout: PageLayout):
    """(R, C) -> (n_pages, tile_r, tile_c): the streaming order the DMA
    engine sees; each [i] is one contiguous page."""
    pr, pc = layout.padded
    xp = jnp.pad(x, ((0, pr - layout.rows), (0, pc - layout.cols)))
    gr, gc = layout.grid
    t = xp.reshape(gr, layout.tile_r, gc, layout.tile_c)
    if layout.row_striped:
        t = t.transpose(2, 0, 1, 3)        # (gc, gr, tr, tc): k-contiguous
    else:
        t = t.transpose(0, 2, 1, 3)        # (gr, gc, tr, tc)
    return t.reshape(layout.n_pages, layout.tile_r, layout.tile_c)


def unpack_pages(pages, layout: PageLayout):
    gr, gc = layout.grid
    if layout.row_striped:
        t = pages.reshape(gc, gr, layout.tile_r, layout.tile_c) \
            .transpose(1, 2, 0, 3)
    else:
        t = pages.reshape(gr, gc, layout.tile_r, layout.tile_c) \
            .transpose(0, 2, 1, 3)
    x = t.reshape(layout.padded)
    return x[:layout.rows, :layout.cols]


# NOTE: the Pallas block chooser lives in ``core.overlap``
# (``choose_gemm_blocks``) — page alignment and the overlap bound are
# one decision, so there is exactly one chooser.
