"""Multi-device sharded plans: fabric parsing, TP/EP partitioning and
coupled N-rank replay.

A multi-device step is N per-rank ``StreamPlan``s that synchronize at
COLLECTIVE events (``core.plan.collective_plan`` hops priced on the
``accesys.components.Fabric`` link).  This module owns the three layers
on top of that event kind:

* **Partitioning** — ``tp_split`` / ``tp_shard_plan`` / ``ep_shard_plan``
  decide per-rank extents through the SAME logical rule table as
  ``sharding/logical.spec_for`` (a dim shards only when the rule maps it
  to the ``model`` axis AND the size divides the degree; otherwise it is
  replicated — never silently padded).
* **Collective lowering** — ``ag_plan`` / ``rs_plan`` / ``a2a_plan``
  build one rank's share of a collective as per-hop COLLECTIVE events.
  The topology decides the hop decomposition at plan-build time: a ring
  moves ``p-1`` chained hops of one shard each (total ``(p-1)/p`` of the
  full tensor — the classic ring AG/RS volume), a full crossbar
  (``alltoall``) issues the same byte volume as ONE descriptor chain
  paying a single hop latency.  Link bandwidth stays a pricing-time knob
  (``Fabric.link``), so one plan skeleton serves a whole fabric sweep.
* **Coupled replay** — ``replay_multidev`` prices N ranks as N coupled
  max-plus timelines: each rank's op stream runs independently between
  collectives, and at collective ``j`` every rank's SA timeline is
  raised to the across-rank barrier ``max_r max(t_sa_r, t_out_r)``
  before the hop time is added.  For symmetric ranks the barrier is a
  no-op and every rank's result coincides bitwise with a solo
  ``replay_compiled`` of its own plan — which is why ``Scenario`` can
  price a TP step through the ordinary single-plan path.

``rank_instances`` turns one compiled skeleton into N rank instances via
``CompiledPlan.relabel`` with rank-prefixed page maps (injective, so the
interned trace is shared by reference — an instance is O(pages), not
O(events)).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import paging
from repro.core import plan as P
from repro.sharding import logical

# accesys imports stay call-time: this module is imported by
# core.scenario (hence by the repro.core package init), and the accesys
# package init imports pipeline which imports repro.core — a top-level
# accesys import here would close that cycle mid-initialization.


# ------------------------------------------------------------- fabric
def parse_fabric(spec) -> Fabric:
    """Parse a fabric spec string into a ``Fabric``.

    Forms: ``"ring"`` | ``"alltoall"`` (default PCIe link), ``"ring:64"``
    (link bandwidth in GB/s), ``"ring:64:800"`` (+ per-hop latency in
    ns).  A ``Fabric`` passes through unchanged."""
    from repro.accesys.components import Fabric
    from repro.accesys.system import pcie_for_bw
    if isinstance(spec, Fabric):
        return spec
    parts = str(spec).split(":")
    topo = parts[0] or "ring"
    link = pcie_for_bw(float(parts[1])) if len(parts) > 1 \
        else Fabric().link
    hop = float(parts[2]) if len(parts) > 2 else Fabric().hop_latency_ns
    return Fabric(link=link, topology=topo, hop_latency_ns=hop)


# ------------------------------------------------- logical partitioning
# the simulator's mesh is single-pod: drop the pure data-parallel pod
# axis from the rule table, exactly like make_rules(multi_pod=False)
_MESH_RULES = logical.make_rules(multi_pod=False)


def tp_split(size: int, logical_name: str, p: int) -> Optional[int]:
    """Per-rank extent of a dim of ``size`` whose logical name is
    ``logical_name`` under TP degree ``p`` — or ``None`` when
    ``sharding.logical.spec_for`` would replicate it (rule table does
    not map the name to the ``model`` axis, or the size does not divide
    ``p``; GSPMD would pad, we replicate)."""
    spec = logical.spec_for((logical_name,), (size,), _MESH_RULES,
                            {"model": p})
    entry = spec[0] if len(spec) else None
    if entry is None:
        return None
    axes = entry if isinstance(entry, tuple) else (entry,)
    if "model" not in axes:
        return None
    return size // p


def tp_shard_plan(p: int, **dims) -> dict:
    """TP-partition a set of logically named dims (e.g. ``heads=32,
    kv_heads=8, mlp=11008``).  Returns ``{name: (per_rank, sharded)}``
    where replicated dims keep their full size — the decision is
    exactly ``spec_for``'s, so plan-level sharding can never drift from
    the logical rule table."""
    out = {}
    for name, size in dims.items():
        per = tp_split(size, name, p)
        out[name] = (size, False) if per is None else (per, True)
    return out


def ep_shard_plan(p: int, n_experts: int) -> int:
    """Experts per rank under EP degree ``p``.  Unlike TP dims, experts
    cannot fall back to replication (that would silently turn EP off),
    so an indivisible count is an error."""
    per = tp_split(n_experts, "expert", p)
    if per is None:
        raise ValueError(
            f"cannot expert-parallelize {n_experts} experts over "
            f"ep={p}: the 'expert' rule requires exact divisibility")
    return per


# ------------------------------------------------- collective builders
def _coll_hops(shard_bytes: int, p: int, topology: str) -> list:
    from repro.accesys.components import FABRIC_TOPOLOGIES
    if topology not in FABRIC_TOPOLOGIES:
        raise ValueError(f"unknown fabric topology {topology!r}; "
                         f"valid: {FABRIC_TOPOLOGIES}")
    if p <= 1 or shard_bytes <= 0:
        return []
    if topology == "alltoall":
        return [(p - 1) * shard_bytes]
    return [shard_bytes] * (p - 1)


def ag_plan(shard_bytes: int, p: int, topology: str, dtype,
            *, lane: int = 0, page_bytes: int = paging.PAGE_BYTES,
            name: Optional[str] = None) -> Optional[P.StreamPlan]:
    """One rank's share of an all-gather of ``p`` shards of
    ``shard_bytes`` each: ring = ``p-1`` chained hops of one shard
    (total ``(p-1)/p`` of the gathered tensor), crossbar = the same
    volume in one chain.  ``None`` when no wire crossing happens."""
    hops = _coll_hops(shard_bytes, p, topology)
    if not hops:
        return None
    return P.collective_plan("all_gather", hops, dtype, page_bytes,
                             lane=lane, meta={"p": p},
                             name=name or f"ag.p{p}")


def rs_plan(shard_bytes: int, p: int, topology: str, dtype,
            *, lane: int = 0, page_bytes: int = paging.PAGE_BYTES,
            name: Optional[str] = None) -> Optional[P.StreamPlan]:
    """Reduce-scatter: the byte volume mirrors the all-gather (ring
    ``(p-1)/p`` of the reduced tensor) — the reduction itself rides the
    SA/host ops that produced the partials."""
    hops = _coll_hops(shard_bytes, p, topology)
    if not hops:
        return None
    return P.collective_plan("reduce_scatter", hops, dtype, page_bytes,
                             lane=lane, meta={"p": p},
                             name=name or f"rs.p{p}")


def a2a_plan(shard_bytes: int, p: int, topology: str, dtype,
             *, op: str = "all_to_all", lane: int = 0,
             page_bytes: int = paging.PAGE_BYTES,
             name: Optional[str] = None) -> Optional[P.StreamPlan]:
    """All-to-all (MoE dispatch/combine): each rank keeps its own
    ``1/p`` and exchanges ``p-1`` peer blocks of ``shard_bytes`` —
    dispatch and combine volumes are equal by construction."""
    hops = _coll_hops(shard_bytes, p, topology)
    if not hops:
        return None
    return P.collective_plan(op, hops, dtype, page_bytes, lane=lane,
                             meta={"p": p}, name=name or f"{op}.p{p}")


# ----------------------------------------------------- rank instancing
def rank_instances(plan: P.StreamPlan, p: int,
                   tag: str = "r") -> list:
    """N per-rank ``CompiledPlan`` instances of one skeleton: rank 0 is
    the compile itself; rank ``r`` relabels every page key ``(name, i)``
    to ``(f"{tag}{r}.{name}", i)`` — injective, so the interned trace
    arrays are shared by reference and each rank prices an identical
    (but disjointly paged) timeline."""
    sk = plan.compile()
    out = [sk]
    for r in range(1, p):
        pmap = {key: (f"{tag}{r}.{key[0]}",) + tuple(key[1:])
                for key in sk.page_keys}
        out.append(sk.relabel(pmap))
    return out


# ----------------------------------------------------- coupled replay
def replay_multidev(cfg, plans: Sequence,
                    host_s_per_elem: Optional[float] = None,
                    footprint_pages: Optional[int] = None) -> list:
    """Price N per-rank plans as N coupled max-plus timelines.

    Every rank's op stream runs the ordinary double-buffer recurrence
    between collectives; collective ``j`` is a synchronization point —
    all ranks must have the same collective count — where each rank's
    SA timeline is raised to ``max_r max(t_sa_r, t_out_r)`` before its
    own hop time is added.  Returns one ``GemmResult`` per rank.  For
    symmetric ranks the barrier never binds and each result equals a
    solo ``replay_compiled`` of that rank's plan (property-tested), so
    single-plan pricing remains exact for homogeneous TP/EP."""
    from repro.accesys import pipeline as PL
    if host_s_per_elem is None:
        host_s_per_elem = PL.HOST_S_PER_ELEM
    states = []
    for pl in plans:
        cfg.smmu.reset()
        cfg.llc.reset()
        cp = pl.compile()
        foot = pl.footprint_pages if footprint_pages is None \
            else footprint_pages
        t, x, has_p, d, ready, val = PL._compiled_arrays(
            cfg, cp, foot, host_s_per_elem)
        k = cp.op_kind
        states.append({
            "pl": pl, "k": k, "has_p": has_p, "ready": ready,
            "val": val, "t": t, "x": x, "d": d,
            "coll": np.nonzero(k == P.OP_COLL)[0],
            "stats": (cfg.smmu.lookups, cfg.smmu.misses,
                      cfg.smmu.walks),
            "t_sa": 0.0, "t_out": 0.0, "exp": 0.0, "pos": 0})
    n_coll = {st["coll"].size for st in states}
    if len(n_coll) > 1:
        raise ValueError(
            f"ranks disagree on collective count {sorted(n_coll)}: "
            "multi-device plans must synchronize at the same barriers")

    def advance(st, stop):
        s0 = st["pos"]
        if stop > s0:
            _, _, exp_a, t_sa, t_out = PL._run_ops_loop(
                st["k"][s0:stop], st["has_p"][s0:stop],
                st["ready"][s0:stop], st["val"][s0:stop],
                st["t_sa"], st["t_out"])
            st["exp"] += float(exp_a.sum())
            st["t_sa"], st["t_out"] = t_sa, t_out
        st["pos"] = stop

    for j in range(n_coll.pop()):
        for st in states:
            advance(st, int(st["coll"][j]))
        barrier = max(max(st["t_sa"], st["t_out"]) for st in states)
        for st in states:
            g = int(st["coll"][j])
            st["t_sa"] = barrier + st["val"][g]
            st["pos"] = g + 1
    results = []
    for st in states:
        advance(st, st["k"].size)
        pl, k, val = st["pl"], st["k"], st["val"]
        scale = pl.total_steps / max(pl.sampled_steps, 1) \
            if pl.total_steps else 1.0
        control = pl.n_calls * (cfg.dma.doorbell_ns +
                                cfg.dma.interrupt_ns) * 1e-9
        lk, ms, wk = st["stats"]
        results.append(PL.GemmResult(
            total_s=max(st["t_sa"], st["t_out"]) * scale + control,
            compute_s=float(val[k == P.OP_SA].sum()) * scale,
            transfer_s=float(st["t"].sum()) * scale,
            exposed_transfer_s=st["exp"] * scale,
            descriptor_s=(float(st["d"][st["has_p"]].sum())
                          + float((k == P.OP_OUT).sum())
                          * cfg.dma.descriptor_time()) * scale,
            translation_s=float(st["x"].sum()) * scale,
            tlb_lookups=int(lk * scale), tlb_misses=int(ms * scale),
            ptw_walks=int(wk * scale), macs=pl.macs,
            host_s=float(val[k == P.OP_HOST].sum()) * scale,
            drain_s=max(0.0, st["t_out"] - st["t_sa"]) * scale,
            coll_s=float(val[k == P.OP_COLL].sum()) * scale))
    return results
