"""Layer-pipelined weight streaming (host → device) — the model-level
use of the paper's DM/DC/DevMem trichotomy: serve a model whose weights
live in host memory by prefetching layer ℓ+1 while layer ℓ computes
(double buffering at layer granularity = A0/A1 at page granularity).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.modes import (MemoryMode, TrafficStats, device_placement,
                              host_placement)


@dataclasses.dataclass
class StreamReport:
    mode: str
    layers: int
    bytes_streamed: int
    wall_s: float


class LayerStreamer:
    """Holds stacked per-layer params (leading dim = layer) in host
    memory (DM/DC) or device memory (DevMem) and applies a layer fn over
    them with one-layer-ahead prefetch."""

    def __init__(self, stacked_params, n_layers: int, mode: MemoryMode,
                 cache_layers: int = 0):
        self.mode = mode
        self.n_layers = n_layers
        place = device_placement if mode is MemoryMode.DEVMEM \
            else host_placement
        self._host = jax.tree.map(place, stacked_params)
        self._layer_bytes = sum(
            int(a.size * a.dtype.itemsize) // n_layers
            for a in jax.tree.leaves(stacked_params))
        self._cache: dict = {}
        self._cache_layers = cache_layers if mode is MemoryMode.DC else 0
        self.stats = TrafficStats()

    def _fetch(self, idx: int):
        self.stats.lookups += 1
        if self.mode is MemoryMode.DEVMEM:
            return jax.tree.map(lambda a: a[idx], self._host)
        if idx in self._cache:
            self.stats.cache_hits += 1
            return self._cache[idx]
        self.stats.cache_misses += 1
        layer = jax.tree.map(
            lambda a: device_placement(a[idx]), self._host)
        self.stats.host_to_device_bytes += self._layer_bytes
        if len(self._cache) < self._cache_layers:
            self._cache[idx] = layer
        return layer

    def run(self, layer_fn: Callable, x, prefetch: int = 1):
        """x -> layer_fn(params_i, x) for i in layers, with prefetch-ahead
        (jax async dispatch overlaps the device_put with compute)."""
        t0 = time.perf_counter()
        pending = [self._fetch(i) for i in range(min(prefetch + 1,
                                                     self.n_layers))]
        for i in range(self.n_layers):
            params_i = pending.pop(0)
            nxt = i + prefetch + 1
            if nxt < self.n_layers:
                pending.append(self._fetch(nxt))   # async H2D
            x = layer_fn(params_i, x)
        x = jax.block_until_ready(x)
        wall = time.perf_counter() - t0
        return x, StreamReport(self.mode.value, self.n_layers,
                               self.stats.host_to_device_bytes, wall)
