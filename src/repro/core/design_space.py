"""Co-design knob space for the system-accelerator search.

The paper's headline configuration — 16×16 SA, 4 KB pages, ~20 KB of
on-chip buffering, PCIe attach — is one point in the space Gem5-AcceSys
was built to explore.  A ``DesignPoint`` names one candidate along the
axes the component models price mechanistically (SA dimension, page
bytes, on-chip buffer budget, uTLB/L2-TLB reach, LLC capacity, memory
mode, PCIe lanes+generation, datatype); ``system_for_point`` lowers it
to an accesys ``SystemConfig`` and ``point_area_um2`` to the silicon
area proxy the Pareto frontier trades latency against.

``DesignSpace.grid()`` / ``.sample()`` enumerate candidates with the
infeasible ones (double-buffered pages + output tile no longer fit the
buffer budget) filtered out; ``scenario.tune`` prices a whole space
against one workload in a single config-batched replay.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Iterator, Optional, Sequence

from repro.accesys.components import (DMAEngine, DRAM, DRAM_TECH, LLC,
                                      PCIeLink, SMMU, SystolicArray,
                                      sa_variant)
from repro.accesys.pipeline import SystemConfig
from repro.core import paging
from repro.core.plan import ELEM_BYTES

# PCIe per-lane signalling rates (gbps) by generation
PCIE_GEN_GBPS = {3: 8.0, 4: 16.0, 5: 32.0, 6: 64.0}

# single-port SRAM area proxy (um^2 per byte, ~7 nm class) for the
# on-chip buffer — coarse, but it only has to rank buffer budgets
SRAM_UM2_PER_BYTE = 0.35

# accumulator width: the paper's SA keeps 32-bit partial sums
ACC_BYTES = 4


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One co-design candidate.  The defaults ARE the paper point —
    ``system_for_point(DesignPoint())`` equals ``default_system()``."""
    sa_w: int = 16                 # systolic array dimension (W x W)
    page_bytes: int = paging.PAGE_BYTES
    buffer_kb: int = 20            # on-chip staging SRAM budget
    tlb_entries: int = 64          # SMMU uTLB reach
    l2_entries: int = 8192         # SMMU L2 TLB reach
    llc_kb: int = 2048             # host LLC carve-out (DC mode)
    mode: str = "DC"               # DM | DC | DevMem
    pcie_lanes: int = 16
    pcie_gen: int = 6
    dtype: str = "int8"
    devmem_dram: str = "HBM2"      # DRAM tech for DevMem mode

    @property
    def required_buffer_kb(self) -> float:
        """Double-buffered A/B page staging plus one accumulator tile:
        the minimum SRAM the streaming schedule needs (the paper's
        16x16 / 4 KB point needs ~18 KB -> the 20 KB default)."""
        return (2 * 2 * self.page_bytes
                + 2 * self.sa_w * self.sa_w * ACC_BYTES) / 1024

    @property
    def feasible(self) -> bool:
        return self.buffer_kb >= self.required_buffer_kb

    def canonical(self) -> "DesignPoint":
        """Collapse don't-care axes so grid dedup (and the batched
        replayer's own config dedup) see identical points: DevMem DRAM
        tech only exists in DevMem mode, the LLC carve-out only in DC."""
        p = self
        if p.mode != "DevMem" and p.devmem_dram != "HBM2":
            p = dataclasses.replace(p, devmem_dram="HBM2")
        if p.mode != "DC" and p.llc_kb != 2048:
            p = dataclasses.replace(p, llc_kb=2048)
        return p

    def label(self) -> str:
        s = (f"{self.sa_w}x{self.sa_w}/{self.dtype} "
             f"pg{self.page_bytes // 1024}K buf{self.buffer_kb}K "
             f"tlb{self.tlb_entries} {self.mode}")
        if self.mode == "DC":
            s += f" llc{self.llc_kb}K"
        if self.mode == "DevMem":
            s += f" {self.devmem_dram}"
        s += f" x{self.pcie_lanes}g{self.pcie_gen}"
        return s


def system_for_point(p: DesignPoint) -> SystemConfig:
    """Lower a design point to the accesys component stack."""
    if p.mode not in ("DM", "DC", "DevMem"):
        raise ValueError(f"unknown memory mode {p.mode!r}")
    if p.dtype not in ELEM_BYTES:
        raise ValueError(f"unknown dtype {p.dtype!r}")
    if p.pcie_gen not in PCIE_GEN_GBPS:
        raise ValueError(f"unknown PCIe generation {p.pcie_gen!r}")
    if p.devmem_dram not in DRAM_TECH:
        raise ValueError(f"unknown DRAM tech {p.devmem_dram!r}")
    dram = DRAM(p.devmem_dram) if p.mode == "DevMem" else DRAM("DDR3")
    return SystemConfig(
        sa=SystolicArray(dtype=p.dtype, w=p.sa_w,
                         tile_w=paging.SA_DIM),
        pcie=PCIeLink(lanes=p.pcie_lanes,
                      gbps_per_lane=PCIE_GEN_GBPS[p.pcie_gen]),
        dram=dram,
        dma=DMAEngine(),
        smmu=SMMU(tlb_entries=p.tlb_entries, l2_entries=p.l2_entries),
        llc=LLC(size_bytes=p.llc_kb * 1024, page_bytes=p.page_bytes),
        mode=p.mode,
        page_bytes=p.page_bytes)


def point_area_um2(p: DesignPoint) -> float:
    """Accelerator-silicon area proxy: SA macro (synthesis-calibrated
    power law over W) + staging SRAM.  Host-side LLC/TLB are not the
    accelerator's silicon and stay out of the proxy."""
    return sa_variant(p.dtype, p.sa_w)[1] \
        + SRAM_UM2_PER_BYTE * p.buffer_kb * 1024


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Cartesian knob space.  ``grid()`` enumerates the feasible
    canonical points (duplicates from don't-care axes removed);
    ``sample(n, seed)`` draws a random feasible subset."""
    sa_w: Sequence[int] = (4, 8, 16, 32)
    page_bytes: Sequence[int] = (1024, 4096, 16384)
    buffer_kb: Sequence[int] = (20, 72, 132)
    tlb_entries: Sequence[int] = (16, 64, 256)
    l2_entries: Sequence[int] = (8192,)
    llc_kb: Sequence[int] = (2048,)
    mode: Sequence[str] = ("DM", "DC", "DevMem")
    pcie_lanes: Sequence[int] = (16,)
    pcie_gen: Sequence[int] = (6,)
    dtype: Sequence[str] = ("int8",)
    devmem_dram: Sequence[str] = ("HBM2",)

    _AXES = ("sa_w", "page_bytes", "buffer_kb", "tlb_entries",
             "l2_entries", "llc_kb", "mode", "pcie_lanes", "pcie_gen",
             "dtype", "devmem_dram")

    def grid(self) -> Iterator[DesignPoint]:
        seen = set()
        axes = [getattr(self, a) for a in self._AXES]
        for combo in itertools.product(*axes):
            p = DesignPoint(**dict(zip(self._AXES, combo))).canonical()
            if p.feasible and p not in seen:
                seen.add(p)
                yield p

    def sample(self, n: int, seed: int = 0) -> list:
        import numpy as np
        rng = np.random.default_rng(seed)
        axes = [getattr(self, a) for a in self._AXES]
        out, seen, tries = [], set(), 0
        while len(out) < n and tries < 100 * n:
            tries += 1
            combo = [ax[int(rng.integers(0, len(ax)))] for ax in axes]
            p = DesignPoint(**dict(zip(self._AXES, combo))).canonical()
            if p.feasible and p not in seen:
                seen.add(p)
                out.append(p)
        return out

    def size(self) -> int:
        return sum(1 for _ in self.grid())


def default_space() -> DesignSpace:
    """The paper-centric search space ``tune()`` uses when none is
    given — it contains the paper's 16x16 / 4 KB / 20 KB point."""
    return DesignSpace()


def bench_grid() -> list:
    """The deterministic 64-config sweep the design-space benchmark and
    the CI trajectory guard both price (kept here so the plain-script
    trajectory check and the benchmark can never drift apart):
    4 SA dims x 2 uTLB reaches x 2 LLC carve-outs x 2 PCIe gens x
    DM/DC.  One plan geometry (page_bytes fixed) -> one trace analysis
    shared by all 64 configs."""
    pts = [DesignPoint(sa_w=w, tlb_entries=tlb, llc_kb=llc,
                       pcie_gen=gen, mode=mode, buffer_kb=132)
           for w in (4, 8, 16, 32)
           for tlb in (16, 64)
           for llc in (1024, 4096)
           for gen in (5, 6)
           for mode in ("DM", "DC")]
    assert len(pts) == 64
    return pts


def pareto_front(scored: Iterable[tuple]) -> list:
    """Indices of the non-dominated (latency, area) points: a point is
    kept iff no other point is <= on both axes and < on one."""
    items = [(float(t), float(a), i) for i, (t, a) in enumerate(scored)]
    best: Optional[float] = None
    keep = []
    for t, a, i in sorted(items):
        if best is None or a < best:
            best = a
            keep.append(i)
    return sorted(keep)
