"""Sharded, atomic, async checkpointing with ELASTIC restore.

Layout: <dir>/step_<n>/ {meta.json, arrays.npz} written to a tmp dir and
atomically renamed — a crash mid-save never corrupts the latest
checkpoint. ``restore`` device_puts each leaf against the CURRENT mesh's
shardings, so a checkpoint saved on mesh A restores onto mesh B with a
different data-parallel extent (elastic rescale after node loss).

Async mode hands the (host-fetched) state to a writer thread so the next
step's compute overlaps the disk write — the checkpoint-side expression
of the paper's transfer/compute overlap.
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np


SEP = "/"


def _flatten(state):
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1) if async_save \
            else None
        self._pending: Optional[cf.Future] = None

    # ------------------------------------------------------------ save
    def save(self, state, step: int):
        flat, _ = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if self._pool is not None:
            self.wait()
            self._pending = self._pool.submit(self._write, host, step)
        else:
            self._write(host, step)

    def _write(self, host: dict, step: int):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # npz cannot round-trip ml_dtypes (bf16/fp8): widen on disk, the
        # true dtype is recorded in meta and re-applied on restore
        def disk(v):
            if v.dtype == ml_dtypes.bfloat16 or v.dtype.kind == "V":
                return v.astype(np.float32)
            return v
        np.savez(tmp / "arrays.npz",
                 **{k.replace("/", "__"): disk(v) for k, v in host.items()})
        (tmp / "meta.json").write_text(json.dumps({
            "step": step,
            "keys": sorted(host.keys()),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": {k: str(v.dtype) for k, v in host.items()},
        }))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)            # atomic publish
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "meta.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, abstract_state, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``abstract_state``; if
        ``shardings`` (a congruent tree) is given, each leaf is placed
        with it — the mesh may differ from the one that saved."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "arrays.npz")
        flat_abs, treedef = _flatten(abstract_state)
        sh_flat = None
        if shardings is not None:
            sh_flat, _ = _flatten(shardings)
        leaves = {}
        for key, aval in flat_abs.items():
            arr = data[key.replace("/", "__")]
            dt = aval.dtype
            if dt == ml_dtypes.bfloat16:
                arr = arr.astype(np.float32).astype(ml_dtypes.bfloat16)
            else:
                arr = arr.astype(dt)
            if sh_flat is not None and sh_flat.get(key) is not None:
                leaves[key] = jax.device_put(arr, sh_flat[key])
            else:
                leaves[key] = jax.device_put(arr)
        ordered = [leaves[k] for k in flat_abs.keys()]
        return jax.tree_util.tree_unflatten(treedef, ordered), step
