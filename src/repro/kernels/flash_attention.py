"""Flash attention (prefill/train) — streaming KV pages with O(block)
VMEM state: the attention-level expression of the paper's paged
streaming. Online softmax carried in VMEM scratch across the KV-inner
grid; causal upper blocks are skipped (no wasted DMA or MXU work —
the compute analogue of "only fetch pages you need").

Layout: q, k, v as (BH, T, D) (caller folds batch×heads; GQA callers
repeat KV heads). Grid: (BH, nq, nk) with nk innermost.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, nk: int, scale: float, causal: bool):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip blocks strictly above the diagonal
    run = (ki * bk <= qi * bq + bq - 1) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)               # (bq, D)
        k = k_ref[0].astype(jnp.float32)               # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_raw(q, k, v, *, bq: int = 256, bk: int = 512,
                        causal: bool = True, interpret: bool = False):
    """q: (BH, Tq, D); k, v: (BH, Tk, D). Tq % bq == Tk % bk == 0."""
    BH, Tq, D = q.shape
    _, Tk, _ = k.shape
    bq, bk = min(bq, Tq), min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, Tk, bq, bk)
    nq, nk = Tq // bq, Tk // bk
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
