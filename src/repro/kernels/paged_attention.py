"""Paged decode attention — the serving-side embodiment of the paper's
SMMU/page-table design: the KV cache lives in fixed-size pages, a
per-sequence page table provides the indirection, and the kernel walks
the table exactly like the SMMU translates 4 KB-aligned DMA bursts.

The page table rides in scalar-prefetch memory (SMEM) so the index_map
can "translate" page ids BEFORE the DMA of each K/V page is issued —
one translation per page, just like one TLB lookup per 4 KB tile in the
paper (§3.3).

Shapes:
  q:        (B, H, D)          one decode token per sequence
  k_pages:  (P, page, KH, D)   global page pool (P pages)
  v_pages:  (P, page, KH, D)
  table:    (B, max_pages)     page ids per sequence (int32)
  lens:     (B,)               current KV length per sequence
Output: (B, H, D).

Grid: (B, max_pages) — pages innermost; online softmax in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(table_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page: int, max_pages: int,
                  scale: float, n_kv: int):
    b, pi = pl.program_id(0), pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = lens_ref[b]
    n_pages_used = (seq_len + page - 1) // page

    @pl.when(pi < n_pages_used)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (H, D)
        k = k_ref[0].astype(jnp.float32)                 # (page, KH, D)
        v = v_ref[0]                                     # (page, KH, D)
        H, D = q.shape
        G = H // n_kv
        qg = q.reshape(n_kv, G, D)
        s = jnp.einsum("hgd,phd->hgp", qg, k,
                       preferred_element_type=jnp.float32) * scale
        pos = pi * page + jax.lax.broadcasted_iota(
            jnp.int32, (n_kv, G, page), 2)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_prev = m_ref[...].reshape(n_kv, G)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_ref[...].reshape(n_kv, G) * corr + p.sum(axis=-1)
        upd = jnp.einsum("hgp,phd->hgd", p.astype(jnp.float32),
                         v.astype(jnp.float32))
        acc = acc_ref[...].reshape(n_kv, G, D)
        acc_ref[...] = (acc * corr[..., None] + upd).reshape(H, D)
        m_ref[...] = m_new.reshape(H)
        l_ref[...] = l_new.reshape(H)

    @pl.when(pi == max_pages - 1)
    def _flush():
        H, D = q_ref[0].shape
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def paged_attention_raw(q, k_pages, v_pages, table, lens, *,
                        interpret: bool = False):
    B, H, D = q.shape
    P, page, KH, _ = k_pages.shape
    _, max_pages = table.shape
    scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(_paged_kernel, page=page,
                               max_pages=max_pages, scale=scale, n_kv=KH)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # (table, lens) land in SMEM
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, pi, table, lens: (b, 0, 0)),
            # the SMMU moment: translate page id -> pool slot in index_map
            pl.BlockSpec((1, page, KH, D),
                         lambda b, pi, table, lens: (table[b, pi], 0, 0, 0)),
            pl.BlockSpec((1, page, KH, D),
                         lambda b, pi, table, lens: (table[b, pi], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, pi, table, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, D), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(table, lens, q, k_pages, v_pages)
