"""Pure-jnp oracles for every kernel (the correctness ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gemm_ref(a, b, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    acc = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    return jnp.dot(a, b, preferred_element_type=acc).astype(out_dtype)


def flash_ref(q, k, v, causal=True):
    """q: (BH, Tq, D); k, v: (BH, Tk, D)."""
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.arange(Tq)[:, None] >= jnp.arange(Tk)[None, :]
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def paged_ref(q, k_pages, v_pages, table, lens):
    """Gather pages into contiguous caches, then masked attention."""
    B, H, D = q.shape
    P, page, KH, _ = k_pages.shape
    max_pages = table.shape[1]
    G = H // KH
    k = k_pages[table].reshape(B, max_pages * page, KH, D)
    v = v_pages[table].reshape(B, max_pages * page, KH, D)
    qg = q.reshape(B, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32)
                   ) / math.sqrt(D)
    valid = jnp.arange(max_pages * page)[None] < lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
