from repro.kernels.ops import (  # noqa: F401
    flash_attention,
    paged_attention,
    streaming_gemm,
)
