"""MatrixFlow streaming GEMM — the paper's core kernel, TPU-native.

Paper → TPU mapping:
  * 4 KB page-aligned A/B tiles, one DMA descriptor per tile
      → BlockSpec tiles, one pipeline copy per grid step (block bytes are
        kept page-multiple; see ``core.overlap.choose_gemm_blocks``, the
        unified page-aligned + overlap-bound block chooser)
  * A0/A1,B0/B1 double buffering ∥ systolic compute ∥ C drain (Fig. 6)
      → the Pallas grid pipeline double-buffers HBM→VMEM input copies
        against MXU compute automatically; C is written once per (i, j)
  * output-stationary 16×16 systolic accumulation
      → output-stationary fp32 VMEM accumulator over the K-inner grid
  * tiny on-chip SRAM (3×4 KB), storage lives in the system
      → minimal VMEM working set: one A tile + one B tile + one C
        accumulator; no weight residency assumed.

Grid: (M/bm, N/bn, K/bk), K innermost so the accumulator in VMEM scratch
carries partial sums across K steps (sequential grid on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                 out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...],
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def streaming_gemm_raw(a, b, *, bm: int, bn: int, bk: int,
                       out_dtype=None, interpret: bool = False):
    """a: (M, K), b: (K, N) with M % bm == N % bn == K % bk == 0."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        (a.shape, b.shape, bm, bn, bk)
    out_dtype = out_dtype or a.dtype
    acc_dtype = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) \
        else jnp.float32
    grid = (M // bm, N // bn, K // bk)
    kernel = functools.partial(_gemm_kernel, k_steps=grid[2],
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # A page tile
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # B page tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(a, b)
