"""Jit'd public wrappers around the Pallas kernels: padding to block
multiples, block-size selection via the paper's overlap bound
(core.overlap), GQA head folding, and interpret-mode fallback on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.overlap import choose_gemm_blocks
from repro.kernels.flash_attention import flash_attention_raw
from repro.kernels.paged_attention import paged_attention_raw
from repro.kernels.streaming_gemm import streaming_gemm_raw


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _round_up(x, m):
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def streaming_gemm(a, b, bm: int | None = None, bn: int | None = None,
                   bk: int | None = None,
                   interpret: bool | None = None):
    """Paged streaming GEMM with automatic padding to block multiples.

    Block sizes default to the unified page-aligned overlap-bound
    chooser (``core.overlap.choose_gemm_blocks``); pass explicit
    bm/bn/bk to override."""
    interpret = _auto_interpret(interpret)
    M, K = a.shape
    _, N = b.shape
    if bm is None or bn is None or bk is None:
        cm, cn, ck = choose_gemm_blocks(M, N, K, a.dtype)
        bm, bn, bk = bm or cm, bn or cn, bk or ck
    bm, bn, bk = min(bm, _round_up(M, 8)), min(bn, _round_up(N, 128)), \
        min(bk, _round_up(K, 128))
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    ap = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    bp = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    out = streaming_gemm_raw(ap, bp, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 256,
                    bk: int = 512, interpret: bool | None = None):
    """q: (B, Tq, H, D); k, v: (B, Tk, KH, D) — GQA folded internally."""
    interpret = _auto_interpret(interpret)
    B, Tq, H, D = q.shape
    _, Tk, KH, _ = k.shape
    G = H // KH
    # fold batch × kv-head × group -> BH; repeat kv per group
    qf = q.reshape(B, Tq, KH, G, D).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KH * G, Tq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(B * KH, Tk, D), G,
                    axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(B * KH, Tk, D), G,
                    axis=0)
    bq_, bk_ = min(bq, Tq), min(bk, Tk)
    Tqp, Tkp = _round_up(Tq, bq_), _round_up(Tk, bk_)
    qf = jnp.pad(qf, ((0, 0), (0, Tqp - Tq), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, Tkp - Tk), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, Tkp - Tk), (0, 0)))
    # padded KV rows must not contribute: they are masked by causal for
    # qpos < Tk; for non-causal, mask via a huge negative on padded keys
    if not causal and Tkp != Tk:
        raise NotImplementedError("pad-free Tk required for non-causal")
    out = flash_attention_raw(qf, kf, vf, bq=bq_, bk=bk_, causal=causal,
                              interpret=interpret)
    out = out[:, :Tq].reshape(B, KH, G, Tq, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Tq, H, D)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, table, lens,
                    interpret: bool | None = None):
    return paged_attention_raw(q, k_pages, v_pages, table, lens,
                               interpret=_auto_interpret(interpret))
