"""Unified model API used by the launcher, trainer, server, and dry-run.

``Model(cfg)`` exposes pure functions:
    init(rng) -> params                      (eval_shape-able)
    loss(params, batch) -> (scalar, metrics)
    prefill(params, batch, cache_seq) -> (cache, logits)
    decode_step(params, cache, tokens) -> (cache, logits)
    init_cache(batch, seq) / cache_axes() / param_axes()
``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every
model input (spec-only: no allocation), per the assigned shape cells.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decoding as D
from repro.models import transformer as T
from repro.models.params import axes_tree, init_tree

Params = Any

# modality-stub frontends provide this many encoder frames/patches per
# the spec ("input_specs() provides precomputed frame/patch embeddings").
AUDIO_FRAMES_TRAIN_FRACTION = 1.0


class Model:
    def __init__(self, cfg: ModelConfig, remat: str = "full"):
        self.cfg = cfg
        self.remat = remat
        self._pspecs = T.lm_pspecs(cfg)

    # ---------------- params
    def init(self, rng) -> Params:
        return init_tree(self._pspecs, rng)

    def param_axes(self):
        return axes_tree(self._pspecs)

    def abstract_params(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ---------------- train
    def loss(self, params, batch):
        cfg = self.cfg
        h, aux, _ = T.forward_train(params, cfg, batch, self.remat)
        ce = T.chunked_ce_loss(params, cfg, h, batch["labels"])
        total = ce + aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp:
            ml = T.mtp_loss(params, cfg, h, batch)
            total = total + 0.3 * ml
            metrics["mtp"] = ml
        return total, metrics

    # ---------------- serve
    def init_cache(self, batch: int, seq: int, enc_seq: int = 0):
        return D.init_cache(self.cfg, batch, seq, enc_seq)

    def cache_axes(self):
        return D.cache_axes(self.cfg)

    def prefill(self, params, batch, cache_seq: int):
        return D.prefill(params, self.cfg, batch, cache_seq, self.remat)

    def decode_step(self, params, cache, tokens):
        return D.decode_step(params, self.cfg, cache, tokens)


# =====================================================================
# ShapeDtypeStruct input stand-ins for the dry-run / AOT lowering
# =====================================================================
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for the given (arch × shape) cell — no allocation."""
    B, S = shape.global_batch, shape.seq_len
    f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        if cfg.family == "audio":
            return {"audio_frames": sds((B, S, cfg.d_model), bf16),
                    "tokens": sds((B, S), i32),
                    "labels": sds((B, S), i32)}
        if cfg.embedding_inputs:
            return {"embeddings": sds((B, S, cfg.d_model), bf16),
                    "labels": sds((B, S), i32)}
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}

    if shape.kind == "prefill":
        if cfg.family == "audio":
            # enc-dec: encoder consumes S frames, decoder prompt is S//8
            return {"audio_frames": sds((B, S, cfg.d_model), bf16),
                    "tokens": sds((B, max(S // 8, 16)), i32)}
        if cfg.embedding_inputs:
            return {"embeddings": sds((B, S, cfg.d_model), bf16)}
        return {"tokens": sds((B, S), i32)}

    # decode: one new token against a cache of length S
    return {"tokens": sds((B,), i32)}


def batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Logical axes for each input (resolved by sharding.logical)."""
    if shape.kind == "train":
        if cfg.family == "audio":
            return {"audio_frames": ("batch", "seq", "embed_act"),
                    "tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        if cfg.embedding_inputs:
            return {"embeddings": ("batch", "seq", "embed_act"),
                    "labels": ("batch", "seq")}
        return {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"audio_frames": ("batch", "seq", "embed_act"),
                    "tokens": ("batch", "seq")}
        if cfg.embedding_inputs:
            return {"embeddings": ("batch", "seq", "embed_act")}
        return {"tokens": ("batch", "seq")}
    return {"tokens": ("batch",)}


def make_concrete_batch(cfg: ModelConfig, shape: ShapeConfig, rng=None,
                        batch: Optional[int] = None,
                        seq: Optional[int] = None) -> dict:
    """Small concrete batch matching input_specs (smoke tests, examples)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    B = batch or shape.global_batch
    S = seq or shape.seq_len
    specs = input_specs(cfg, ShapeConfig(shape.name, shape.kind, S, B))
    out = {}
    for k, v in specs.items():
        r, rng = jax.random.split(rng)
        if jnp.issubdtype(v.dtype, jnp.integer):
            out[k] = jax.random.randint(r, v.shape, 0, cfg.vocab_size,
                                        dtype=v.dtype)
        else:
            out[k] = jax.random.normal(r, v.shape, v.dtype) * 0.02
    return out
