"""Trace-time performance-tuning context (the hillclimbing knobs).

Model code reads chunk sizes / cache dtypes from here so the launcher
can sweep them per (arch × shape) cell without touching architecture
configs. Defaults reproduce the baseline.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class Tuning:
    q_chunk: int = 512             # chunked-attention query page
    kv_chunk: int = 1024           # chunked-attention KV page
    ce_chunk: int = 512            # chunked cross-entropy T page
    ssm_chunk: int = 16            # linear-attention chunk
    kv_cache_quant: bool = False   # INT8 paged KV (per-token scales)
    moe_cap_axis: Optional[str] = None   # shard the MoE capacity dim
    moe_local_dispatch: bool = False     # row-local (batch-sharded) dispatch


DEFAULT = Tuning()


def get() -> Tuning:
    return getattr(_STATE, "tuning", DEFAULT)


@contextlib.contextmanager
def tuning_context(t: Tuning):
    prev = get()
    _STATE.tuning = t
    try:
        yield
    finally:
        _STATE.tuning = prev
