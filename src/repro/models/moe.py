"""Mixture-of-Experts FFN with sort-based grouped-GEMM dispatch.

Compile-friendly fixed-shape dispatch (no ragged ops):
  1. router softmax -> top-k (probs, expert ids)
  2. stable argsort of flat assignments groups tokens by expert
  3. scatter into an (E, C, d) buffer (capacity C, overflow dropped)
  4. one grouped einsum per FFN matmul over stacked expert weights
  5. gather back and combine with routing probs

Expert weights carry the "expert" logical axis -> TP/EP over the `model`
mesh axis. The buffers are the activation-side analogue of the paper's
page-aligned tiles: fixed-capacity contiguous blocks per expert instead
of scattered per-token traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_act
from repro.models.params import PSpec
from repro.sharding.context import shard


def routed_capacity(n_assignments: int, n_experts: int,
                    capacity: int | None,
                    capacity_factor: float = 1.25,
                    multiple: int = 8) -> int:
    """Per-expert buffer capacity C for ``n_assignments`` (= tokens x
    top_k) routed slots: capacity-factor sized (or explicit), rounded up
    to ``multiple`` and clamped to the assignment count.  The single
    source of the capacity rule — shared by both dispatch paths here and
    by ``core.plan.moe_layer_plan``, so plan page sets match the model's
    routed buffers exactly."""
    C = capacity if capacity is not None else \
        max(int(n_assignments / n_experts * capacity_factor), multiple)
    return min(-(-C // multiple) * multiple, n_assignments)


def moe_pspecs(cfg: ModelConfig):
    m, d, f = cfg.moe, cfg.d_model, cfg.moe.d_ff_expert
    E = m.n_routed_experts
    p = {
        "router": PSpec((d, E), ("embed", "expert"), scale=d ** -0.5),
        "wi_gate": PSpec((E, d, f), ("expert", "embed", "mlp")),
        "wi_up": PSpec((E, d, f), ("expert", "embed", "mlp")),
        "wo": PSpec((E, f, d), ("expert", "mlp", "embed")),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        p["shared_wi_gate"] = PSpec((d, fs), ("embed", "mlp"))
        p["shared_wi_up"] = PSpec((d, fs), ("embed", "mlp"))
        p["shared_wo"] = PSpec((fs, d), ("mlp", "embed"))
    return p


def apply_moe(p, x, cfg: ModelConfig, capacity_factor: float = 1.25,
              capacity: int | None = None):
    """x: (B, T, d) -> (y: (B, T, d), aux_loss: scalar).

    ``capacity`` overrides the capacity-factor sizing; pass ``capacity=n``
    (token count) at decode time for lossless routing.

    Dispatch strategies:
      * global (baseline): one argsort over all B*T tokens - simple, but
        GSPMD replicates the sorted token tensors and all-reduces 100s of
        GB per layer on a 256-chip mesh (measured);
      * row-local (Tuning.moe_local_dispatch): sort/bucket per batch row
        so every dispatch tensor keeps its `batch` sharding - no dispatch
        collectives; capacity is per-row (tokens compete within their own
        sequence - the standard EP formulation).
    """
    from repro.models import tuning as TU
    if TU.get().moe_local_dispatch and x.shape[1] > 1:
        return _apply_moe_local(p, x, cfg, capacity_factor, capacity)
    m = cfg.moe
    B, T, d = x.shape
    E, k = m.n_routed_experts, m.top_k
    xt = x.reshape(B * T, d)
    n = B * T

    logits = (xt @ p["router"]).astype(jnp.float32)          # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (n, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(0)                                        # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (n * k)
    aux = E * jnp.sum(me * ce) * m.router_aux_coef

    flat_e = top_e.reshape(-1)                                # (n*k,)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    flat_p = top_p.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(n * k) - starts[e_sorted]
    C = routed_capacity(n * k, E, capacity, capacity_factor)
    keep = pos_in_e < C

    # dispatch: (E, C, d)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[jnp.where(keep, e_sorted, E - 1),
                 jnp.where(keep, pos_in_e, C - 1)].set(
        jnp.where(keep[:, None], xt[tok_sorted], 0), mode="drop")
    from repro.models import tuning as TU
    cap_ax = "moe_cap" if TU.get().moe_cap_axis else None
    buf = shard(buf, ("expert", cap_ax, None))

    h = apply_act(jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"]), cfg) \
        * jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    h = shard(h, ("expert", cap_ax, "mlp"))
    y_e = jnp.einsum("ecf,efd->ecd", h, p["wo"])              # (E, C, d)
    y_e = shard(y_e, ("expert", cap_ax, None))

    # combine: gather expert outputs back to token order, weight by probs
    gathered = y_e[e_sorted, jnp.minimum(pos_in_e, C - 1)]    # (n*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    contrib = gathered * flat_p[order][:, None].astype(gathered.dtype)
    y = jnp.zeros((n, d), contrib.dtype).at[tok_sorted].add(contrib)

    if m.n_shared_experts:
        sh = apply_act(xt @ p["shared_wi_gate"], cfg) * (xt @ p["shared_wi_up"])
        y = y + sh @ p["shared_wo"]
    return y.reshape(B, T, d).astype(x.dtype), aux


def _apply_moe_local(p, x, cfg: ModelConfig, capacity_factor: float,
                     capacity):
    """Row-local dispatch: every tensor keeps the leading (batch) dim, so
    the whole dispatch/combine pipeline stays batch-sharded."""
    m = cfg.moe
    B, T, d = x.shape
    E, k = m.n_routed_experts, m.top_k

    logits = (x @ p["router"]).astype(jnp.float32)            # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (B,T,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    me = probs.mean((0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0) / (B * T * k)
    aux = E * jnp.sum(me * ce) * m.router_aux_coef

    nk = T * k
    flat_e = top_e.reshape(B, nk)                             # (B, T*k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(T), k)[None], (B, nk))
    flat_p = top_p.reshape(B, nk)

    order = jnp.argsort(flat_e, axis=-1, stable=True)         # (B, nk)
    e_sorted = jnp.take_along_axis(flat_e, order, -1)
    tok_sorted = jnp.take_along_axis(flat_tok, order, -1)
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(flat_e)
    starts = jnp.cumsum(counts, -1) - counts                  # (B, E)
    pos_in_e = jnp.arange(nk)[None] - jnp.take_along_axis(
        starts, e_sorted, -1)
    C = routed_capacity(nk, E, capacity, capacity_factor, multiple=4)
    keep = pos_in_e < C

    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, nk))
    x_sorted = shard(x[bidx, tok_sorted], ("batch", None, None))
    buf = jnp.zeros((B, E, C, d), x.dtype)
    buf = buf.at[bidx,
                 jnp.where(keep, e_sorted, E - 1),
                 jnp.where(keep, pos_in_e, C - 1)].set(
        jnp.where(keep[..., None], x_sorted, 0), mode="drop")
    buf = shard(buf, ("batch", "expert", None, None))

    # use-site weight gather: constrain expert weights to drop the FSDP
    # (`data`) shard here, so GSPMD all-gathers 22.5 GB of weights per
    # layer instead of all-reducing TBs of (B,E,C,f) partial activations
    # (measured 5140s -> the dominant term without this).
    wi_g = shard(p["wi_gate"], ("expert", None, "mlp"))
    wi_u = shard(p["wi_up"], ("expert", None, "mlp"))
    wo = shard(p["wo"], ("expert", "mlp", None))
    h = apply_act(jnp.einsum("becd,edf->becf", buf, wi_g), cfg) \
        * jnp.einsum("becd,edf->becf", buf, wi_u)
    h = shard(h, ("batch", "expert", None, "mlp"))
    y_e = jnp.einsum("becf,efd->becd", h, wo)                 # (B,E,C,d)
    y_e = shard(y_e, ("batch", "expert", None, None))

    gathered = shard(y_e[bidx, e_sorted, jnp.minimum(pos_in_e, C - 1)],
                     ("batch", None, None))
    gathered = jnp.where(keep[..., None], gathered, 0)
    contrib = shard(gathered * jnp.take_along_axis(
        flat_p, order, -1)[..., None].astype(gathered.dtype),
        ("batch", None, None))
    y = jnp.zeros((B, T, d), contrib.dtype).at[bidx, tok_sorted].add(
        contrib)
    y = shard(y, ("batch", "seq", None))

    if m.n_shared_experts:
        sh = apply_act(x @ p["shared_wi_gate"], cfg) * (
            x @ p["shared_wi_up"])
        y = y + sh @ p["shared_wo"]
    return y.astype(x.dtype), aux
