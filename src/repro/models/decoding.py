"""Prefill + single-token decode paths with scanned, stacked caches.

Cache layout (leading L = layer-stack dim, scanned):
  dense/vlm : {"layers": {"k","v": (L,B,S,KH,hd)}, "len": ()}
  mla       : {"layers": {"ckv": (L,B,S,r), "kr": (L,B,S,rope)}, "len": ()}
  moe       : dense caches + optional "dense_layers" stack (deepseek)
  hybrid    : {"layers": mamba-state, "shared": {"k","v": (I,B,S,KH,hd)},
               "len": ()} — I = number of shared-attention invocations
  ssm       : {"layers": rwkv-state, "len": ()}
  audio     : {"layers": self {"k","v"}, "cross": {"k","v": (L,B,Te,KH,hd)},
               "len": ()}
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import transformer as T
from repro.sharding.context import shard


# =====================================================================
# cache init + logical axes
# =====================================================================
def _attn_cache_zeros(cfg, n_layers, batch, seq, dtype=jnp.bfloat16):
    from repro.models import tuning as TU
    if cfg.mla:
        m = cfg.mla
        return {"ckv": jnp.zeros((n_layers, batch, seq, m.kv_lora_rank), dtype),
                "kr": jnp.zeros((n_layers, batch, seq, m.qk_rope_head_dim),
                                dtype)}
    KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if TU.get().kv_cache_quant:
        return {"k": jnp.zeros((n_layers, batch, seq, KH, hd), jnp.int8),
                "v": jnp.zeros((n_layers, batch, seq, KH, hd), jnp.int8),
                "k_scale": jnp.zeros((n_layers, batch, seq, KH),
                                     jnp.float16),
                "v_scale": jnp.zeros((n_layers, batch, seq, KH),
                                     jnp.float16)}
    return {"k": jnp.zeros((n_layers, batch, seq, KH, hd), dtype),
            "v": jnp.zeros((n_layers, batch, seq, KH, hd), dtype)}


def _attn_cache_axes(cfg):
    from repro.models import tuning as TU
    if cfg.mla:
        return {"ckv": ("layers", "cache_batch", "cache_seq", "lora"),
                "kr": ("layers", "cache_batch", "cache_seq", "head_dim")}
    ax = ("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim")
    out = {"k": ax, "v": ax}
    if TU.get().kv_cache_quant:
        sax = ("layers", "cache_batch", "cache_seq", "kv_heads")
        out["k_scale"] = sax
        out["v_scale"] = sax
    return out


def n_shared_invocations(cfg: ModelConfig) -> int:
    return -(-cfg.n_layers // cfg.ssm.attn_every)


def init_cache(cfg: ModelConfig, batch: int, seq: int, enc_seq: int = 0,
               dtype=jnp.bfloat16):
    fam = cfg.family
    cache: dict = {"len": jnp.zeros((batch,), jnp.int32)}
    if fam in ("dense", "vlm"):
        cache["layers"] = _attn_cache_zeros(cfg, cfg.n_layers, batch, seq, dtype)
    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        if nd:
            cache["dense_layers"] = _attn_cache_zeros(cfg, nd, batch, seq, dtype)
        cache["layers"] = _attn_cache_zeros(cfg, cfg.n_layers - nd, batch,
                                            seq, dtype)
    elif fam == "hybrid":
        st = SSM.init_mamba_state(cfg, batch, dtype)
        cache["layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), st)
        I = n_shared_invocations(cfg)
        KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cache["shared"] = {
            "k": jnp.zeros((I, batch, seq, KH, hd), dtype),
            "v": jnp.zeros((I, batch, seq, KH, hd), dtype)}
    elif fam == "ssm":
        st = SSM.init_rwkv_state(cfg, batch, dtype)
        cache["layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), st)
    elif fam == "audio":
        cache["layers"] = _attn_cache_zeros(cfg, cfg.n_layers, batch, seq, dtype)
        KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cache["cross"] = {
            "k": jnp.zeros((cfg.n_layers, batch, enc_seq, KH, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, enc_seq, KH, hd), dtype)}
    else:
        raise ValueError(fam)
    return cache


def cache_axes(cfg: ModelConfig):
    fam = cfg.family
    ax: dict = {"len": ("cache_batch",)}
    if fam in ("dense", "vlm"):
        ax["layers"] = _attn_cache_axes(cfg)
    elif fam == "moe":
        if cfg.moe.first_dense_layers:
            ax["dense_layers"] = _attn_cache_axes(cfg)
        ax["layers"] = _attn_cache_axes(cfg)
    elif fam == "hybrid":
        ax["layers"] = jax.tree.map(lambda a: ("layers",) + a,
                                    SSM.mamba_state_axes(cfg),
                                    is_leaf=lambda x: isinstance(x, tuple))
        a = ("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim")
        ax["shared"] = {"k": a, "v": a}
    elif fam == "ssm":
        ax["layers"] = jax.tree.map(lambda a: ("layers",) + a,
                                    SSM.rwkv_state_axes(cfg),
                                    is_leaf=lambda x: isinstance(x, tuple))
    elif fam == "audio":
        ax["layers"] = _attn_cache_axes(cfg)
        a = ("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim")
        ax["cross"] = {"k": a, "v": a}
    return ax


# =====================================================================
# decode bodies
# =====================================================================
def _attn_decode_layer(lp, x, cfg, cache_slice, pos):
    """Single-token attention+FFN for one layer. cache_slice: this layer's
    k/v (B,S,KH,hd) (or MLA latents). Returns (x', new_slice)."""
    x = shard(x, ("batch", "embed_act"))
    h = L.apply_norm(lp["ln1"], x, cfg)
    if cfg.mla:
        a, new = L.mla_decode(lp["attn"], h, cfg, {**cache_slice, "len": pos})
    else:
        a, new = L.attention_decode(lp["attn"], h, cfg,
                                    {**cache_slice, "len": pos})
    new.pop("len")
    return x + a, new


def _dense_decode_layer(lp, x, cfg, cache_slice, pos):
    x, new = _attn_decode_layer(lp, x, cfg, cache_slice, pos)
    h = L.apply_norm(lp["ln2"], x, cfg)
    return x + L.apply_mlp(lp["mlp"], h, cfg), new


def _moe_decode_layer(lp, x, cfg, cache_slice, pos):
    x, new = _attn_decode_layer(lp, x, cfg, cache_slice, pos)
    h = L.apply_norm(lp["ln2"], x, cfg)
    # lossless capacity (C = n tokens) at decode: no token dropping
    y, _ = MOE.apply_moe(lp["moe"], h[:, None], cfg, capacity=x.shape[0])
    return x + y[:, 0], new


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """tokens: (B,) int32 (or (B,d) embeddings for pure-embed families).
    Returns (new_cache, logits (B, V))."""
    fam = cfg.family
    x = params["embed"][tokens].astype(jnp.bfloat16)
    pos = cache["len"]
    new_cache = {"len": pos + 1}

    if fam in ("dense", "vlm"):
        def body(x, xs):
            lp, cs = xs
            y, new = _dense_decode_layer(lp, x, cfg, cs, pos)
            return y, new
        x, new = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = new
    elif fam == "moe":
        if cfg.moe.first_dense_layers:
            def dbody(x, xs):
                lp, cs = xs
                y, new = _dense_decode_layer(lp, x, cfg, cs, pos)
                return y, new
            x, newd = jax.lax.scan(dbody, x, (params["dense_layers"],
                                              cache["dense_layers"]))
            new_cache["dense_layers"] = newd
        def body(x, xs):
            lp, cs = xs
            y, new = _moe_decode_layer(lp, x, cfg, cs, pos)
            return y, new
        x, new = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = new
    elif fam == "hybrid":
        shared = params["shared_attn"]
        every = cfg.ssm.attn_every
        sk, sv = cache["shared"]["k"], cache["shared"]["v"]

        def body(carry, xs):
            x, idx, inv, sk, sv = carry
            lp, st = xs

            def with_attn(op):
                x, sk, sv, inv = op
                h = L.apply_norm(shared["ln"], x, cfg)
                a, new = L.attention_decode(
                    shared["attn"], h, cfg,
                    {"k": sk[inv], "v": sv[inv], "len": pos})
                sk = jax.lax.dynamic_update_index_in_dim(sk, new["k"], inv, 0)
                sv = jax.lax.dynamic_update_index_in_dim(sv, new["v"], inv, 0)
                return x + a, sk, sv, inv + 1

            x, sk, sv, inv = jax.lax.cond(
                idx % every == 0, with_attn, lambda op: op, (x, sk, sv, inv))
            h = L.apply_norm(lp["ln1"], x, cfg)
            m, new_st = SSM.mamba2_step(lp["mamba"], h, cfg, st)
            x = x + m
            h = L.apply_norm(lp["ln2"], x, cfg)
            x = x + L.apply_mlp(lp["mlp"], h, cfg)
            return (x, idx + 1, inv, sk, sv), new_st

        (x, _, _, sk, sv), new_states = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                   sk, sv), (params["layers"], cache["layers"]))
        new_cache["layers"] = new_states
        new_cache["shared"] = {"k": sk, "v": sv}
    elif fam == "ssm":
        def body(x, xs):
            lp, st = xs
            h = L.apply_norm(lp["ln1"], x, cfg)
            t, tstate = SSM.rwkv_time_mix_step(lp["time"], h, cfg, st["time"])
            x = x + t
            h = L.apply_norm(lp["ln2"], x, cfg)
            c, cshift = SSM.rwkv_channel_mix(lp["channel"], h,
                                             st["channel_shift"])
            return x + c, {"time": tstate, "channel_shift": cshift}
        x, new = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = new
    elif fam == "audio":
        x = x + params["pos_embed"][pos].astype(x.dtype)   # (B,d) gather
        Te = cache["cross"]["k"].shape[2]

        def body(x, xs):
            lp, cs, xk, xv = xs
            y, new = _attn_decode_layer(lp, x, cfg, cs, pos)
            h = L.apply_norm(lp["ln_x"], y, cfg)
            q = jnp.einsum("bd,dhk->bhk", h, lp["xattn"]["wq"])
            a = L.decode_attention(q, xk, xv, Te)
            y = y + jnp.einsum("bhk,hkd->bd", a, lp["xattn"]["wo"])
            h = L.apply_norm(lp["ln2"], y, cfg)
            return y + L.apply_mlp(lp["mlp"], h, cfg), new

        x, new = jax.lax.scan(body, x, (params["layers"], cache["layers"],
                                        cache["cross"]["k"],
                                        cache["cross"]["v"]))
        new_cache["layers"] = new
        new_cache["cross"] = cache["cross"]
    else:
        raise ValueError(fam)

    x = L.apply_norm(params["final_norm"], x[:, None], cfg)[:, 0]
    logits = T.lm_head(params, cfg, x)
    return new_cache, logits


# =====================================================================
# prefill: full-sequence forward that also fills the cache
# =====================================================================
def _attn_prefill_layer(lp, x, cfg, positions):
    h = L.apply_norm(lp["ln1"], x, cfg)
    if cfg.mla:
        a, (ckv, kr) = L.mla_train(lp["attn"], h, cfg, positions)
        return x + a, {"ckv": ckv, "kr": kr}
    a, (k, v) = L.attention_train(lp["attn"], h, cfg, positions)
    return x + a, {"k": k, "v": v}


def _pad_cache_seq(kv_tree, seq_total):
    """Pad per-layer (L,B,T,...) KV stacks up to the cache length S,
    quantizing to the INT8 paged layout when tuned."""
    from repro.models import tuning as TU
    def pad(a):
        pad_amt = seq_total - a.shape[2]
        cfgs = [(0, 0)] * a.ndim
        cfgs[2] = (0, pad_amt)
        return jnp.pad(a, cfgs)
    kv_tree = jax.tree.map(pad, kv_tree)
    if TU.get().kv_cache_quant and "k" in kv_tree:
        out = {}
        for name in ("k", "v"):
            a = kv_tree[name]
            sc = jnp.max(jnp.abs(a), -1) / 127.0 + 1e-8
            out[name] = jnp.round(a / sc[..., None]).astype(jnp.int8)
            out[name + "_scale"] = sc.astype(jnp.float16)
        return out
    return kv_tree


def prefill(params, cfg: ModelConfig, batch, cache_seq: int,
            remat: str = "full"):
    """Process the prompt, return (cache, last-token logits (B,V))."""
    fam = cfg.family
    if cfg.embedding_inputs and "embeddings" in batch:
        x = batch["embeddings"].astype(jnp.bfloat16)
    elif fam == "audio":
        x = None
    else:
        x = T.embed_tokens(params, cfg, batch["tokens"])

    if fam in ("dense", "vlm", "moe"):
        B, Tq, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(Tq), (B, Tq))
        cache: dict = {"len": jnp.full((B,), Tq, jnp.int32)}

        def run_stack(x, stack_params, is_moe):
            def body(x, lp):
                x, kv = _attn_prefill_layer(lp, x, cfg, positions)
                h = L.apply_norm(lp["ln2"], x, cfg)
                if is_moe:
                    # serving path: LOSSLESS routing (no capacity drops),
                    # consistent with the lossless decode step
                    y, _ = MOE.apply_moe(lp["moe"], h, cfg,
                                         capacity=B * Tq)
                else:
                    y = L.apply_mlp(lp["mlp"], h, cfg)
                return x + y, kv
            return jax.lax.scan(T._remat(body, remat), x, stack_params)

        if fam == "moe" and cfg.moe.first_dense_layers:
            x, kvd = run_stack(x, params["dense_layers"], False)
            cache["dense_layers"] = _pad_cache_seq(kvd, cache_seq)
        x, kv = run_stack(x, params["layers"], fam == "moe")
        cache["layers"] = _pad_cache_seq(kv, cache_seq)
    elif fam in ("ssm", "hybrid"):
        # recurrent prefill: run the train forward, but KEEP final states
        B, Tq, _ = x.shape
        cache = init_cache(cfg, B, cache_seq)
        cache["len"] = jnp.full((B,), Tq, jnp.int32)
        positions = jnp.broadcast_to(jnp.arange(Tq), (B, Tq))
        if fam == "ssm":
            st0 = SSM.init_rwkv_state(cfg, B, x.dtype)
            def body(x, xs):
                lp = xs
                y, st = T.rwkv_layer_fwd(lp, x, cfg, st0)
                return y, st
            x, states = jax.lax.scan(T._remat(body, remat), x,
                                     params["layers"])
            cache["layers"] = states
        else:
            shared = params["shared_attn"]
            every = cfg.ssm.attn_every
            st0 = SSM.init_mamba_state(cfg, B)
            sk, sv = cache["shared"]["k"], cache["shared"]["v"]

            def body(carry, lp):
                # shared KV caches live in the carry: only the I invocation
                # layers write (avoids materializing 81 layers of KV).
                x, idx, inv, sk, sv = carry

                def with_attn(op):
                    x, sk, sv, inv = op
                    h = L.apply_norm(shared["ln"], x, cfg)
                    a, (k, v) = L.attention_train(shared["attn"], h, cfg,
                                                  positions)
                    k = jnp.pad(k, ((0, 0), (0, cache_seq - Tq),
                                    (0, 0), (0, 0))).astype(sk.dtype)
                    v = jnp.pad(v, ((0, 0), (0, cache_seq - Tq),
                                    (0, 0), (0, 0))).astype(sv.dtype)
                    sk = jax.lax.dynamic_update_index_in_dim(sk, k, inv, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, v, inv, 0)
                    return x + a, sk, sv, inv + 1

                x, sk, sv, inv = jax.lax.cond(
                    idx % every == 0, with_attn, lambda op: op,
                    (x, sk, sv, inv))
                y, st = T.mamba_layer_fwd(lp, x, cfg, st0)
                return (y, idx + 1, inv, sk, sv), st

            (x, _, _, sk, sv), states = jax.lax.scan(
                T._remat(body, remat),
                (x, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                 sk, sv), params["layers"])
            cache["layers"] = states
            cache["shared"] = {"k": sk, "v": sv}
    elif fam == "audio":
        frames = batch["audio_frames"].astype(jnp.bfloat16)
        B, Te, _ = frames.shape
        pos_e = jnp.broadcast_to(jnp.arange(Te), (B, Te))
        enc_body = T._remat(lambda x, lp: (
            T.dense_layer_fwd_nocausal(lp, x, cfg, pos_e), None), remat)
        enc, _ = jax.lax.scan(enc_body, frames, params["enc_layers"])
        enc = L.apply_norm(params["enc_norm"], enc, cfg)

        tokens = batch["tokens"]
        Bd, Td = tokens.shape
        x = T.embed_tokens(params, cfg, tokens)
        x = x + params["pos_embed"][:Td].astype(x.dtype)
        pos_d = jnp.broadcast_to(jnp.arange(Td), (Bd, Td))

        def dec_body(x, lp):
            x, kv = _attn_prefill_layer(lp, x, cfg, pos_d)
            h = L.apply_norm(lp["ln_x"], x, cfg)
            kx = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wk"])
            vx = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wv"])
            a, _ = L.attention_train(lp["xattn"], h, cfg, pos_d,
                                     causal=False, kv=(kx, vx))
            x = x + a
            h = L.apply_norm(lp["ln2"], x, cfg)
            return x + L.apply_mlp(lp["mlp"], h, cfg), (kv, kx, vx)

        x, (kv, kxs, vxs) = jax.lax.scan(T._remat(dec_body, remat), x,
                                         params["layers"])
        cache = {"len": jnp.full((Bd,), Td, jnp.int32),
                 "layers": _pad_cache_seq(kv, cache_seq),
                 "cross": {"k": kxs, "v": vxs}}
    else:
        raise ValueError(fam)

    x = L.apply_norm(params["final_norm"], x[:, -1:], cfg)[:, 0]
    logits = T.lm_head(params, cfg, x)
    return cache, logits
