"""Model assembly: decoder LMs (dense/MoE/MLA), encoder-decoder (whisper),
hybrid (zamba2), and RWKV6 — all with scanned layer stacks so the lowered
HLO is one layer body + ``lax.scan`` regardless of depth.

Layer stacks are homogeneous per scan; heterogeneous stacks (deepseek's
3 dense + 58 MoE layers) become two consecutive scans. Zamba2's SHARED
attention block lives outside the scanned params and is applied every
``attn_every`` layers via ``lax.cond`` with a per-invocation KV cache.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.params import PSpec, stack
from repro.sharding.context import shard

Params = Any


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# =====================================================================
# per-family layer pspecs
# =====================================================================
def dense_layer_pspecs(cfg: ModelConfig, cross: bool = False):
    p = {"ln1": L.norm_pspec(cfg),
         "attn": (L.mla_pspecs(cfg) if cfg.mla else L.attention_pspecs(cfg)),
         "ln2": L.norm_pspec(cfg),
         "mlp": L.mlp_pspecs(cfg)}
    if cross:
        p["ln_x"] = L.norm_pspec(cfg)
        p["xattn"] = L.attention_pspecs(cfg)
    return p


def moe_layer_pspecs(cfg: ModelConfig):
    return {"ln1": L.norm_pspec(cfg),
            "attn": (L.mla_pspecs(cfg) if cfg.mla else L.attention_pspecs(cfg)),
            "ln2": L.norm_pspec(cfg),
            "moe": MOE.moe_pspecs(cfg)}


def rwkv_layer_pspecs(cfg: ModelConfig):
    p = SSM.rwkv_pspecs(cfg)
    return {"ln1": L.norm_pspec(cfg), "time": p["time"],
            "ln2": L.norm_pspec(cfg), "channel": p["channel"]}


def mamba_layer_pspecs(cfg: ModelConfig):
    return {"ln1": L.norm_pspec(cfg), "mamba": SSM.mamba2_pspecs(cfg),
            "ln2": L.norm_pspec(cfg), "mlp": L.mlp_pspecs(cfg)}


def lm_pspecs(cfg: ModelConfig):
    d, V = cfg.d_model, cfg.padded_vocab
    p: dict = {"embed": PSpec((V, d), ("vocab", "embed"), scale=0.02)}
    if not cfg.tie_embeddings:
        p["lm_head"] = PSpec((d, V), ("embed", "vocab"))
    p["final_norm"] = L.norm_pspec(cfg)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = stack(dense_layer_pspecs(cfg), cfg.n_layers)
    elif fam == "moe":
        nd = cfg.moe.first_dense_layers
        if nd:
            p["dense_layers"] = stack(dense_layer_pspecs(cfg), nd)
        p["layers"] = stack(moe_layer_pspecs(cfg), cfg.n_layers - nd)
    elif fam == "ssm":
        p["layers"] = stack(rwkv_layer_pspecs(cfg), cfg.n_layers)
    elif fam == "hybrid":
        p["layers"] = stack(mamba_layer_pspecs(cfg), cfg.n_layers)
        p["shared_attn"] = {"ln": L.norm_pspec(cfg),
                            "attn": L.attention_pspecs(cfg)}
    elif fam == "audio":
        p["enc_layers"] = stack(dense_layer_pspecs(cfg), cfg.n_encoder_layers)
        p["enc_norm"] = L.norm_pspec(cfg)
        p["layers"] = stack(dense_layer_pspecs(cfg, cross=True), cfg.n_layers)
        p["pos_embed"] = PSpec((cfg.max_train_seq * 8, d), (None, "embed"),
                               scale=0.02)
    else:
        raise ValueError(fam)
    if cfg.mtp:
        p["mtp"] = {"proj": PSpec((2 * d, d), ("embed", "embed_act")),
                    "block": dense_layer_pspecs(cfg),
                    "norm_h": L.norm_pspec(cfg), "norm_e": L.norm_pspec(cfg)}
    return p


# =====================================================================
# layer bodies (train / prefill path: full sequence)
# =====================================================================
def _attn_block(lp, x, cfg, positions):
    x = shard(x, ("batch", "seq", "embed_act"))
    h = L.apply_norm(lp["ln1"], x, cfg)
    if cfg.mla:
        a, _ = L.mla_train(lp["attn"], h, cfg, positions)
    else:
        a, _ = L.attention_train(lp["attn"], h, cfg, positions)
    return x + a


def dense_layer_fwd(lp, x, cfg, positions):
    x = _attn_block(lp, x, cfg, positions)
    h = L.apply_norm(lp["ln2"], x, cfg)
    return shard(x + L.apply_mlp(lp["mlp"], h, cfg),
                 ("batch", "seq", "embed_act"))


def moe_layer_fwd(lp, x, cfg, positions):
    x = _attn_block(lp, x, cfg, positions)
    h = L.apply_norm(lp["ln2"], x, cfg)
    y, aux = MOE.apply_moe(lp["moe"], h, cfg)
    return x + y, aux


def rwkv_layer_fwd(lp, x, cfg, state):
    h = L.apply_norm(lp["ln1"], x, cfg)
    t, tstate = SSM.rwkv_time_mix(lp["time"], h, cfg, state["time"])
    x = x + t
    h = L.apply_norm(lp["ln2"], x, cfg)
    c, cshift = SSM.rwkv_channel_mix(lp["channel"], h, state["channel_shift"])
    return x + c, {"time": tstate, "channel_shift": cshift}


def mamba_layer_fwd(lp, x, cfg, state):
    h = L.apply_norm(lp["ln1"], x, cfg)
    m, mstate = SSM.mamba2_forward(lp["mamba"], h, cfg, state)
    x = x + m
    h = L.apply_norm(lp["ln2"], x, cfg)
    return x + L.apply_mlp(lp["mlp"], h, cfg), mstate


# =====================================================================
# forward (train): tokens/embeddings -> final hidden states (+ aux)
# =====================================================================
def embed_tokens(params, cfg: ModelConfig, tokens):
    return params["embed"][tokens].astype(jnp.bfloat16)


def forward_train(params, cfg: ModelConfig, batch, remat="full"):
    """Returns (hidden (B,T,d), aux_loss scalar, extras dict)."""
    if cfg.family == "audio":
        return _forward_train_encdec(params, cfg, batch, remat)
    if cfg.embedding_inputs and "embeddings" in batch:
        x = batch["embeddings"].astype(jnp.bfloat16)
    else:
        x = embed_tokens(params, cfg, batch["tokens"])
    B, T, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    aux_total = jnp.zeros((), jnp.float32)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        body = _remat(lambda x, lp: (dense_layer_fwd(lp, x, cfg, positions),
                                     None), remat)
        x, _ = jax.lax.scan(body, x, params["layers"])
    elif fam == "moe":
        if cfg.moe.first_dense_layers:
            body = _remat(lambda x, lp: (
                dense_layer_fwd(lp, x, cfg, positions), None), remat)
            x, _ = jax.lax.scan(body, x, params["dense_layers"])
        def moe_body(x, lp):
            y, aux = moe_layer_fwd(lp, x, cfg, positions)
            return y, aux
        x, auxs = jax.lax.scan(_remat(moe_body, remat), x, params["layers"])
        aux_total = aux_total + auxs.sum()
    elif fam == "ssm":
        state0 = SSM.init_rwkv_state(cfg, B, x.dtype)
        def body(x, args):
            lp = args
            y, _ = rwkv_layer_fwd(lp, x, cfg, state0)
            return y, None
        x, _ = jax.lax.scan(_remat(body, remat), x, params["layers"])
    elif fam == "hybrid":
        st0 = SSM.init_mamba_state(cfg, B)
        shared = params["shared_attn"]
        every = cfg.ssm.attn_every
        def body(carry, args):
            x, idx = carry
            lp = args
            def with_attn(x):
                h = L.apply_norm(shared["ln"], x, cfg)
                a, _ = L.attention_train(shared["attn"], h, cfg, positions)
                return x + a
            x = jax.lax.cond(idx % every == 0, with_attn, lambda x: x, x)
            y, _ = mamba_layer_fwd(lp, x, cfg, st0)
            return (y, idx + 1), None
        body = _remat(body, remat)
        (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.int32)),
                                 params["layers"])
    else:
        raise ValueError(fam)
    x = L.apply_norm(params["final_norm"], x, cfg)
    return x, aux_total, {}


def _forward_train_encdec(params, cfg: ModelConfig, batch, remat):
    frames = batch["audio_frames"].astype(jnp.bfloat16)
    B, Te, d = frames.shape
    pos_e = jnp.broadcast_to(jnp.arange(Te), (B, Te))
    enc_body = _remat(lambda x, lp: (
        dense_layer_fwd_nocausal(lp, x, cfg, pos_e), None), remat)
    enc, _ = jax.lax.scan(enc_body, frames, params["enc_layers"])
    enc = L.apply_norm(params["enc_norm"], enc, cfg)

    tokens = batch["tokens"]
    Bd, Td = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    x = x + params["pos_embed"][:Td].astype(x.dtype)
    pos_d = jnp.broadcast_to(jnp.arange(Td), (Bd, Td))

    def dec_body(x, lp):
        x = _attn_block(lp, x, cfg, pos_d)
        h = L.apply_norm(lp["ln_x"], x, cfg)
        kx = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wk"])
        vx = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wv"])
        a, _ = L.attention_train(lp["xattn"], h, cfg, pos_d, causal=False,
                                 kv=(kx, vx))
        x = x + a
        h = L.apply_norm(lp["ln2"], x, cfg)
        return x + L.apply_mlp(lp["mlp"], h, cfg), None

    x, _ = jax.lax.scan(_remat(dec_body, remat), x, params["layers"])
    x = L.apply_norm(params["final_norm"], x, cfg)
    return x, jnp.zeros((), jnp.float32), {"encoder_out": enc}


def dense_layer_fwd_nocausal(lp, x, cfg, positions):
    h = L.apply_norm(lp["ln1"], x, cfg)
    a, _ = L.attention_train(lp["attn"], h, cfg, positions, causal=False)
    x = x + a
    h = L.apply_norm(lp["ln2"], x, cfg)
    return x + L.apply_mlp(lp["mlp"], h, cfg)


# =====================================================================
# loss (chunked cross-entropy: logits are streamed in T-pages, never
# materialized as (B, T, V) — the loss-level page streaming)
# =====================================================================
def lm_head(params, cfg: ModelConfig, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w.astype(h.dtype)


def chunked_ce_loss(params, cfg: ModelConfig, h, labels,
                    t_chunk: int = 0):
    """h: (B,T,d); labels: (B,T) int32 (-1 = ignore). Mean CE over valid."""
    from repro.models import tuning as TU
    B, T, d = h.shape
    V = cfg.padded_vocab
    t_chunk = min(t_chunk or TU.get().ce_chunk, T)
    pad = (-T) % t_chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (T + pad) // t_chunk
    hc = h.reshape(B, nc, t_chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, t_chunk).transpose(1, 0, 2)

    def one(args):
        hb, lb = args
        hb = shard(hb, ("batch", None, "embed_act"))
        logits = lm_head(params, cfg, hb).astype(jnp.float32)
        logits = shard(logits, ("batch", None, "vocab"))
        if cfg.padded_vocab != cfg.vocab_size:
            mask = jnp.arange(V) < cfg.vocab_size
            logits = jnp.where(mask, logits, L.NEG_INF)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        valid = (lb >= 0).astype(jnp.float32)
        return ((lse - gold) * valid).sum(), valid.sum()

    losses, counts = jax.lax.map(one, (hc, lc))
    return losses.sum() / jnp.maximum(counts.sum(), 1.0)


def mtp_loss(params, cfg: ModelConfig, h, batch):
    """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from
    [norm(h_t); norm(Emb(tok_{t+1}))]."""
    mp = params["mtp"]
    tokens, labels = batch["tokens"], batch["labels"]
    B, T = tokens.shape
    nxt = jnp.concatenate([tokens[:, 1:], tokens[:, :1] * 0], axis=1)
    e = embed_tokens(params, cfg, nxt)
    hh = jnp.concatenate([L.apply_norm(mp["norm_h"], h, cfg),
                          L.apply_norm(mp["norm_e"], e, cfg)], axis=-1)
    x = hh @ mp["proj"]
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = dense_layer_fwd(mp["block"], x, cfg, positions)
    lbl2 = jnp.concatenate([labels[:, 1:],
                            jnp.full_like(labels[:, :1], -1)], axis=1)
    return chunked_ce_loss(params, cfg, x, lbl2)
