"""State-space / linear-attention blocks: RWKV6 (Finch) and Mamba2.

Both are expressed through one *chunked* scan utility
(``chunked_linear_attention``): the sequence is processed in pages
(chunks) with O(state) carry — the SSM counterpart of the paper's paged
streaming (compute over one page while the recurrent state, not a giant
cache, carries history). Decode is the exact single-step recurrence.

RWKV6 time-mix (per head h, head size N):
    out_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ)
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ          (w_t data-dependent)
Mamba2 (SSD, scalar-per-head decay):
    S_t = a_t S_{t-1} + dt_t · x_t B_tᵀ ;  y_t = S_t C_t + D x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec
from repro.sharding.context import shard


# ------------------------------------------------------------------
# shared chunked kernel (vector decay per key-dim; rwkv "bonus" optional)
# ------------------------------------------------------------------
# Per-token log-decay clamp: keeps exp(±Σ logw) inside fp32 range for the
# factored chunk matmuls (chunk 16 × 5.0 = 80 < log(fp32_max) ≈ 88). The
# single-step recurrence and the ref oracle apply the same clamp, so the
# chunked and sequential paths agree bit-for-bit in semantics.
LOGW_MIN = -5.0
DEFAULT_CHUNK = 16


def chunked_linear_attention(r, k, v, logw, state, u=None,
                             chunk: int = DEFAULT_CHUNK,
                             inclusive: bool = False):
    """Chunkwise linear attention with per-(head,dim) decay.

    r, k, logw: (B,T,H,N); v: (B,T,H,M); state: (B,H,N,M).
    inclusive=False (RWKV): out_t reads S_{t-1}; the current token enters
      only through the ``u`` bonus diag.
    inclusive=True (Mamba2): out_t reads S_t (current token included,
      undecayed).
    Returns (out (B,T,H,M), final state fp32).
    """
    B, T, H, N = r.shape
    M = v.shape[-1]
    chunk = min(chunk, T)
    Torig = T
    pad = (-T) % chunk
    if pad:
        # zero k/v and logw=0 (decay 1) contribute nothing to state/out
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) *
                                 (a.ndim - 2))
        r, k, v, logw = zpad(r), zpad(k), zpad(v), zpad(logw)
        T = T + pad
    nc = T // chunk
    rc = r.reshape(B, nc, chunk, H, N)
    kc = k.reshape(B, nc, chunk, H, N)
    vc = v.reshape(B, nc, chunk, H, M)
    wc = jnp.clip(logw.astype(jnp.float32), LOGW_MIN, 0.0
                  ).reshape(B, nc, chunk, H, N)

    def step(S, xs):
        rb, kb, vb, wb = xs                     # (B,c,H,*)
        S = shard(S, ("batch", "heads", None, None))
        rb = shard(rb, ("batch", None, "heads", None))
        cum = jnp.cumsum(wb, axis=1)            # inclusive log-decay prods
        total = cum[:, -1]                      # (B,H,N)
        # exponent for r side: cum_t (inclusive) or cum_{t-1} (exclusive)
        r_exp = cum if inclusive else cum - wb
        r_dec = rb.astype(jnp.float32) * jnp.exp(r_exp)
        inter = jnp.einsum("bchn,bhnm->bchm", r_dec, S)
        # midpoint-normalized factorization: both score factors stay
        # within exp(±(chunk/2)·|LOGW_MIN|), doubling the safe chunk
        mid = cum[:, chunk // 2][:, None]
        r_mid = rb.astype(jnp.float32) * jnp.exp(r_exp - mid)
        k_dec = kb.astype(jnp.float32) * jnp.exp(mid - cum)
        scores = jnp.einsum("bchn,bdhn->bhcd", r_mid, k_dec)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool),
                        0 if inclusive else -1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        if u is not None:   # rwkv bonus for the current token
            diag = jnp.einsum("bchn,hn,bchn->bch",
                              rb.astype(jnp.float32), u,
                              kb.astype(jnp.float32))
            scores = scores + jnp.einsum("bch,ct->bhct", diag,
                                         jnp.eye(chunk, dtype=jnp.float32))
        intra = jnp.einsum("bhcd,bdhm->bchm", scores,
                           vb.astype(jnp.float32))
        out = inter + intra
        # state update: S' = diag(exp(total)) S + Σ_s (k_s exp(total-cum_s)) v_s
        k_fut = kb.astype(jnp.float32) * jnp.exp(total[:, None] - cum)
        S = jnp.exp(total)[..., None] * S + jnp.einsum(
            "bchn,bchm->bhnm", k_fut, vb.astype(jnp.float32))
        return S, out

    xs = (rc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), wc.transpose(1, 0, 2, 3, 4))
    state, outs = jax.lax.scan(step, state.astype(jnp.float32), xs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, T, H, M)[:, :Torig]
    return out.astype(v.dtype), state


def scan_chunk_2d(r, k, v, logw, state, H, N, inclusive=True, u=None):
    """One scan chunk over 2D operands — the adapter the StreamPlan
    executor's ``ssm_scan`` host op uses (``core.plan.ssm_layer_plan``).

    r, k, logw: (L, H*N); v: (L, H*M); state: (H*N, M).  Runs the SAME
    ``chunked_linear_attention`` kernel as the model forward (one chunk,
    batch 1), so plan execution and the model reference agree by
    construction.  Returns (out (L, H*M), new state (H*N, M)), fp32.
    """
    L = r.shape[0]
    M = v.shape[1] // H
    r4 = jnp.asarray(r, jnp.float32).reshape(1, L, H, N)
    k4 = jnp.asarray(k, jnp.float32).reshape(1, L, H, N)
    v4 = jnp.asarray(v, jnp.float32).reshape(1, L, H, M)
    w4 = jnp.asarray(logw, jnp.float32).reshape(1, L, H, N)
    s4 = jnp.asarray(state, jnp.float32).reshape(1, H, N, M)
    out, s = chunked_linear_attention(r4, k4, v4, w4, s4, u=u,
                                      chunk=L, inclusive=inclusive)
    return out.reshape(L, H * M), s.reshape(H * N, M)


def linear_attention_step(r, k, v, logw, state, u=None,
                          inclusive: bool = False):
    """Exact single-token recurrence. r,k,logw: (B,H,N); v: (B,H,M)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    kv = jnp.einsum("bhn,bhm->bhnm", kf, vf)
    decay = jnp.exp(jnp.clip(logw.astype(jnp.float32), LOGW_MIN, 0.0)
                    )[..., None]
    if inclusive:           # mamba2: update state, then read it
        state = decay * state + kv
        out = jnp.einsum("bhn,bhnm->bhm", rf, state)
    else:                   # rwkv: read S + u-bonus, then update
        bonus = jnp.einsum("hn,bhnm->bhnm", u, kv) if u is not None else 0.0
        out = jnp.einsum("bhn,bhnm->bhm", rf, state + bonus)
        state = decay * state + kv
    return out.astype(v.dtype), state


# ------------------------------------------------------------------
# RWKV6 block
# ------------------------------------------------------------------
def rwkv_pspecs(cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads
    N = d // H
    return {
        "time": {
            "wr": PSpec((d, d), ("embed", "qkv")),
            "wk": PSpec((d, d), ("embed", "qkv")),
            "wv": PSpec((d, d), ("embed", "qkv")),
            "wg": PSpec((d, d), ("embed", "qkv")),
            "ww": PSpec((d, d), ("embed", "qkv"), scale=0.01),
            "w_bias": PSpec((H, N), ("heads", "head_dim"), "zeros"),
            "u": PSpec((H, N), ("heads", "head_dim"), "zeros"),
            "wo": PSpec((d, d), ("qkv", "embed")),
            "mix": PSpec((5, d), (None, "embed_act"), "zeros"),
        },
        "channel": {
            "wk": PSpec((d, cfg.d_ff), ("embed", "mlp")),
            "wv": PSpec((cfg.d_ff, d), ("mlp", "embed")),
            "wr": PSpec((d, d), ("embed", "qkv")),
            "mix": PSpec((2, d), (None, "embed_act"), "zeros"),
        },
    }


def _token_shift(x, last):
    """shift right by one; `last` (B,d) is the previous sequence tail."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def rwkv_time_mix(p, x, cfg: ModelConfig, state, chunk=0):
    """x: (B,T,d); state: {"s": (B,H,N,N), "shift": (B,d)}."""
    from repro.models import tuning as TU
    chunk = chunk or TU.get().ssm_chunk
    B, T, d = x.shape
    H = cfg.n_heads
    N = d // H
    xs = _token_shift(x, state["shift"])
    mix = jax.nn.sigmoid(p["mix"])          # (5,d) in (0,1)
    def lerp(i):
        return x + (xs - x) * mix[i]
    # constrain projection outputs to (batch, seq, heads-on-model): GSPMD
    # otherwise replicates them and partial-sum all-reduces 1 GB
    # activations over `data` (measured: 99% of this cell's collectives)
    proj = lambda w: shard((lerp_cache.pop(0) @ w).reshape(B, T, H, N),
                           ("batch", "seq", "heads", None))
    lerp_cache = [lerp(i) for i in range(5)]
    r = proj(p["wr"])
    k = proj(p["wk"])
    v = proj(p["wv"])
    g = jax.nn.silu(shard(lerp_cache.pop(0) @ p["wg"],
                          ("batch", "seq", "qkv")))
    logw = -jnp.exp(proj(p["ww"]).astype(jnp.float32)
                    + p["w_bias"].astype(jnp.float32))
    out, s = chunked_linear_attention(r, k, v, logw, state["s"],
                                      u=p["u"].astype(jnp.float32),
                                      chunk=chunk)
    out = (out.reshape(B, T, d) * g) @ p["wo"]
    return out, {"s": s, "shift": x[:, -1]}


def rwkv_time_mix_step(p, x, cfg: ModelConfig, state):
    """x: (B,d) single token."""
    B, d = x.shape
    H = cfg.n_heads
    N = d // H
    xs = state["shift"]
    mix = jax.nn.sigmoid(p["mix"])
    def lerp(i):
        return x + (xs - x) * mix[i]
    r = (lerp(0) @ p["wr"]).reshape(B, H, N)
    k = (lerp(1) @ p["wk"]).reshape(B, H, N)
    v = (lerp(2) @ p["wv"]).reshape(B, H, N)
    g = jax.nn.silu(lerp(3) @ p["wg"])
    logw = -jnp.exp((lerp(4) @ p["ww"]).astype(jnp.float32).reshape(B, H, N)
                    + p["w_bias"].astype(jnp.float32))
    out, s = linear_attention_step(r, k, v, logw, state["s"],
                                   u=p["u"].astype(jnp.float32))
    out = (out.reshape(B, d) * g) @ p["wo"]
    return out, {"s": s, "shift": x}


def rwkv_channel_mix(p, x, state_shift):
    xs = _token_shift(x, state_shift) if x.ndim == 3 else state_shift
    mix = jax.nn.sigmoid(p["mix"])
    k = jax.nn.relu((x + (xs - x) * mix[0]) @ p["wk"]) ** 2
    r = jax.nn.sigmoid((x + (xs - x) * mix[1]) @ p["wr"])
    new_shift = x[:, -1] if x.ndim == 3 else x
    return r * (k @ p["wv"]), new_shift


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H = cfg.n_heads
    N = cfg.d_model // H
    return {
        "time": {"s": jnp.zeros((batch, H, N, N), jnp.float32),
                 "shift": jnp.zeros((batch, cfg.d_model), dtype)},
        "channel_shift": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv_state_axes(cfg: ModelConfig):
    return {
        "time": {"s": ("cache_batch", "heads", "head_dim", "head_dim"),
                 "shift": ("cache_batch", "embed_act")},
        "channel_shift": ("cache_batch", "embed_act"),
    }


# ------------------------------------------------------------------
# Mamba2 block (zamba2)
# ------------------------------------------------------------------
def mamba2_pspecs(cfg: ModelConfig):
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    nh = d_in // s.head_dim
    return {
        "in_proj": PSpec((d, 2 * d_in + 2 * s.d_state + nh),
                         ("embed", "qkv")),
        "conv_w": PSpec((s.d_conv, d_in + 2 * s.d_state), ("conv", "qkv")),
        "A_log": PSpec((nh,), ("heads",), "zeros"),
        "D": PSpec((nh,), ("heads",), "ones"),
        "dt_bias": PSpec((nh,), ("heads",), "zeros"),
        "out_proj": PSpec((d_in, d), ("qkv", "embed")),
        "norm_scale": PSpec((d_in,), ("embed_act",), "ones", dtype="float32"),
    }


def _mamba_split(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    return d_in, nh, s.d_state


def _causal_conv(x, w, conv_state=None):
    """depthwise causal conv along T. x: (B,T,C); w: (K,C)."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out, xp[:, -(K - 1):]


def mamba2_forward(p, x, cfg: ModelConfig, state, chunk=DEFAULT_CHUNK):
    """x: (B,T,d); state: {"s": (B,nh,N,hd), "conv": (B,K-1,C)}."""
    B, T, d = x.shape
    d_in, nh, N = _mamba_split(cfg)
    hd = cfg.ssm.head_dim
    zxbcdt = x @ p["in_proj"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,T,nh)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (nh,)
    logw = (dt * a)[..., None]                                    # (B,T,nh,1)
    xheads = xin.reshape(B, T, nh, hd)
    xh = xheads * dt[..., None].astype(xheads.dtype)
    # r=C (queries), k=B (keys): state is (B, nh, N, hd)
    r = jnp.broadcast_to(Cc[:, :, None, :], (B, T, nh, N))
    k = jnp.broadcast_to(Bc[:, :, None, :], (B, T, nh, N))
    logw = jnp.broadcast_to(logw, (B, T, nh, N))
    out, s = chunked_linear_attention(r, k, xh, logw, state["s"],
                                      chunk=chunk, inclusive=True)
    out = out + xheads * p["D"].astype(xheads.dtype)[:, None]
    out = out.reshape(B, T, d_in)
    # gated RMSNorm then out-projection
    varr = jnp.mean(jnp.square(out.astype(jnp.float32)), -1, keepdims=True)
    out = (out.astype(jnp.float32) * jax.lax.rsqrt(varr + 1e-5)
           * p["norm_scale"]).astype(x.dtype)
    out = out * jax.nn.silu(z)
    return out @ p["out_proj"], {"s": s, "conv": conv_state}


def mamba2_step(p, x, cfg: ModelConfig, state):
    out, st = mamba2_forward(p, x[:, None], cfg, state, chunk=1)
    return out[:, 0], st


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d_in, nh, N = _mamba_split(cfg)
    K = cfg.ssm.d_conv
    return {
        "s": jnp.zeros((batch, nh, N, cfg.ssm.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, d_in + 2 * N), dtype),
    }


def mamba_state_axes(cfg: ModelConfig):
    return {"s": ("cache_batch", "heads", "state", "head_dim"),
            "conv": ("cache_batch", "conv", "qkv")}
