"""Single-source-of-truth parameter declaration.

Modules declare a pytree of ``PSpec`` (shape + logical axes + init law).
From it we derive, congruently:
  * materialized parameters (``init_tree`` — pure, works under eval_shape),
  * logical-axes trees (``axes_tree``) that ``sharding.logical`` resolves
    into PartitionSpecs for the dry-run / pjit shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple
    axes: tuple            # logical axis names, same length as shape
    init: str = "normal"   # normal | zeros | ones
    scale: Optional[float] = None   # None => fan-in 1/sqrt(shape[-?])
    fan_axis: int = 0      # which axis is fan-in for default scaling
    dtype: Optional[str] = None     # override param dtype (e.g. fp32 norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def stack(tree, n: int):
    """Prepend a ("layers", n) scan dimension to every PSpec in tree."""
    return jax.tree.map(
        lambda p: PSpec((n,) + p.shape, ("layers",) + p.axes, p.init,
                        p.scale, p.fan_axis + 1, p.dtype),
        tree, is_leaf=is_pspec)


def init_tree(tree, rng, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_pspec)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for r, p in zip(rngs, leaves):
        dt = jnp.dtype(p.dtype) if p.dtype else dtype
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dt))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dt))
        else:
            scale = p.scale
            if scale is None:
                fan = max(int(p.shape[p.fan_axis]), 1)
                scale = fan ** -0.5
            out.append((jax.random.normal(r, p.shape, jnp.float32)
                        * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def axes_tree(tree):
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=is_pspec)


def shape_tree(tree):
    return jax.tree.map(lambda p: p.shape, tree, is_leaf=is_pspec)
