"""Core transformer layers: norms, RoPE, GQA/MQA/MLA attention, MLP.

Attention uses a *chunked online-softmax* implementation for train/prefill
(``chunked_attention``) — the pure-XLA expression of the paper's streaming
principle: KV is consumed in pages with O(page) local state instead of
materializing the T×T score matrix. On TPU the Pallas ``flash_attention``
kernel replaces it; the XLA path is the portable oracle and the dry-run
lowering path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec
from repro.models import tuning as TU
from repro.sharding.context import shard

NEG_INF = -1e30


# ---------------------------------------------------------------- norms
def norm_pspec(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": PSpec((d,), ("embed_act",), "ones", dtype="float32")}
    if cfg.norm == "layernorm":
        p["bias"] = PSpec((d,), ("embed_act",), "zeros", dtype="float32")
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------- rope
def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, cfg: ModelConfig, dim: Optional[int] = None):
    """x: (..., T, H, D) or (..., H, D) w/ scalar positions; rotates pairs.

    cfg.rope == "full": rotate all of head_dim; "2d" (chatglm): rotate the
    first half only; "none": identity.
    """
    if cfg.rope == "none":
        return x
    d = x.shape[-1]
    rot = d if cfg.rope == "full" else d // 2
    if dim is not None:
        rot = dim
    freqs = rope_freqs(rot, cfg.rope_theta)                    # (rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., rot/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    # broadcast across the head axis: positions are (..., T) while x is
    # (..., T, H, D) -> insert the H axis.
    cos, sin = cos[..., None, :], sin[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([y.astype(x.dtype), x[..., rot:]], axis=-1)


# ----------------------------------------------------- chunked attention
def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      q_chunk: int = 0, kv_chunk: int = 0,
                      kv_len=None):
    """Online-softmax attention, O(chunk) memory — streaming KV pages.

    q: (B, Tq, H, D); k, v: (B, Tk, KH, Dk/Dv) with H = KH * G (GQA).
    kv_len: optional (B,) valid KV length (for prefill into padded caches).
    Returns (B, Tq, H, Dv).
    """
    B, Tq, H, D = q.shape
    _, Tk, KH, Dv = v.shape
    G = H // KH
    t = TU.get()
    q_chunk = min(q_chunk or t.q_chunk, Tq)
    kv_chunk = min(kv_chunk or t.kv_chunk, Tk)
    nq, nk = -(-Tq // q_chunk), -(-Tk // kv_chunk)
    pad_q = nq * q_chunk - Tq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(D)
    qc = q.reshape(B, nq, q_chunk, KH, G, D)

    def q_block(args):
        qb, qi = args                                  # (B,qc,KH,G,D)
        qb = shard(qb, ("batch", "seq_q", "kv_heads", "heads", None))
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_block(carry, ki):
            acc, m, l = carry
            ks = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            vs = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            kv_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, ks,
                           preferred_element_type=jnp.float32) * scale
            s = shard(s, ("batch", "kv_heads", "heads", "seq_q", None))
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            mask = jnp.broadcast_to(mask, (B, 1, 1, q_chunk, kv_chunk))
            if kv_len is not None:
                mask &= (kv_pos[None, :] < kv_len[:, None]
                         )[:, None, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vs.dtype), vs,
                preferred_element_type=jnp.float32)
            acc = shard(acc, ("batch", "kv_heads", "heads", "seq_q", None))
            return (acc, m_new, l_new), None

        acc0 = shard(jnp.zeros((B, KH, G, q_chunk, Dv), jnp.float32),
                     ("batch", "kv_heads", "heads", "seq_q", None))
        m0 = jnp.full((B, KH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_block, (acc0, m0, l0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)            # (B,qc,KH,G,Dv)

    out = jax.lax.map(q_block, (qc.transpose(1, 0, 2, 3, 4, 5),
                                jnp.arange(nq)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Tq].astype(v.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, k_scale=None,
                     v_scale=None):
    """Single-token attention against a (padded) cache.

    q: (B, H, D); caches: (B, S, KH, D); cache_len: () or (B,) int32.
    k_scale/v_scale: (B, S, KH) dequant scales for INT8 caches.
    """
    B, H, D = q.shape
    _, S, KH, Dv = v_cache.shape
    G = H // KH
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg,
                   k_cache.astype(jnp.bfloat16)
                   if k_cache.dtype == jnp.int8 else k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if k_scale is not None:
        s = s * k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None]
    s = shard(s, ("batch", "kv_heads", "heads", "cache_seq"))
    pos = jnp.arange(S)
    cl = jnp.asarray(cache_len)
    if cl.ndim == 0:
        cl = jnp.broadcast_to(cl, (B,))
    valid = pos[None, :] < cl[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        pv = p * v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None]
        out = jnp.einsum("bhgk,bkhd->bhgd", pv.astype(jnp.bfloat16),
                         v_cache.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
        return out.reshape(B, H, Dv).astype(jnp.bfloat16)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Dv).astype(v_cache.dtype)


# ---------------------------------------------------------------- MLP
def mlp_pspecs(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    p = {"wo": PSpec((f, d), ("mlp", "embed"))}
    if cfg.glu:
        p["wi_gate"] = PSpec((d, f), ("embed", "mlp"))
        p["wi_up"] = PSpec((d, f), ("embed", "mlp"))
    else:
        p["wi"] = PSpec((d, f), ("embed", "mlp"))
        p["bi"] = PSpec((f,), ("mlp",), "zeros")
        p["bo"] = PSpec((d,), ("embed_act",), "zeros")
    return p


def apply_act(x, cfg: ModelConfig):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.glu:
        h = apply_act(x @ p["wi_gate"], cfg) * (x @ p["wi_up"])
        return h @ p["wo"]
    h = apply_act(x @ p["wi"] + p["bi"], cfg)
    return h @ p["wo"] + p["bo"]


# ------------------------------------------------------- GQA attention
def attention_pspecs(cfg: ModelConfig):
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": PSpec((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = PSpec((H, hd), ("heads", "head_dim"), "zeros")
        p["bk"] = PSpec((KH, hd), ("kv_heads", "head_dim"), "zeros")
        p["bv"] = PSpec((KH, hd), ("kv_heads", "head_dim"), "zeros")
    return p


def qkv_proj(p, x, cfg: ModelConfig):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def attention_train(p, x, cfg: ModelConfig, positions, causal=True,
                    kv=None):
    """Full-sequence attention (train / prefill). kv: optional external
    (k, v) for cross-attention (whisper decoder)."""
    q, k, v = (qkv_proj(p, x, cfg) if kv is None
               else (jnp.einsum("btd,dhk->bthk", x, p["wq"]) +
                     (p["bq"] if cfg.qkv_bias else 0), *kv))
    if kv is None:
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
    out = chunked_attention(q, k, v, causal=causal)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"]), (k, v)


def attention_decode(p, x, cfg: ModelConfig, cache):
    """x: (B, d) one token. cache: {"k","v": (B,S,KH,hd), "len": (B,)}.

    Per-sequence lengths: slot b's new KV lands at its own position —
    continuous batching serves mixed-progress sequences in one step."""
    B, d = x.shape
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    pos = cache["len"]                                    # (B,)
    q = apply_rope(q[:, None], pos[:, None], cfg)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], cfg)[:, 0]
    bidx = jnp.arange(B)
    if "k_scale" in cache:
        # INT8 paged KV: per-(token, kv-head) scales — halves the decode
        # bandwidth wall (the paper's INT8 streaming, applied to the KV)
        ks = jnp.max(jnp.abs(k), -1) / 127.0 + 1e-8
        vs = jnp.max(jnp.abs(v), -1) / 127.0 + 1e-8
        kq = jnp.round(k / ks[..., None]).astype(jnp.int8)
        vq = jnp.round(v / vs[..., None]).astype(jnp.int8)
        kc = cache["k"].at[bidx, pos].set(kq)
        vc = cache["v"].at[bidx, pos].set(vq)
        ksc = cache["k_scale"].at[bidx, pos].set(ks.astype(jnp.float16))
        vsc = cache["v_scale"].at[bidx, pos].set(vs.astype(jnp.float16))
        out = decode_attention(q, kc, vc, pos + 1,
                               k_scale=ksc, v_scale=vsc)
        new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc,
                     "len": pos + 1}
    else:
        kc = cache["k"].at[bidx, pos].set(k.astype(cache["k"].dtype))
        vc = cache["v"].at[bidx, pos].set(v.astype(cache["v"].dtype))
        out = decode_attention(q, kc, vc, pos + 1)
        new_cache = {"k": kc, "v": vc, "len": pos + 1}
    return jnp.einsum("bhk,hkd->bd", out, p["wo"]), new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, seq: int,
                         dtype=jnp.bfloat16):
    KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, seq, KH, hd), dtype),
        "v": jnp.zeros((batch, seq, KH, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_axes(cfg: ModelConfig):
    return {
        "k": ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
        "v": ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
        "len": (),
    }


# --------------------------------------------------------------- MLA
def mla_pspecs(cfg: ModelConfig):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": PSpec((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": norm_pspec(cfg, m.q_lora_rank),
        "wuq": PSpec((m.q_lora_rank, H, qk), ("lora", "heads", "head_dim")),
        "wdkv": PSpec((d, m.kv_lora_rank), ("embed", "lora")),
        "kv_norm": norm_pspec(cfg, m.kv_lora_rank),
        "wkr": PSpec((d, m.qk_rope_head_dim), ("embed", "head_dim")),
        "wuk": PSpec((m.kv_lora_rank, H, m.qk_nope_head_dim),
                     ("lora", "heads", "head_dim")),
        "wuv": PSpec((m.kv_lora_rank, H, m.v_head_dim),
                     ("lora", "heads", "head_dim")),
        "wo": PSpec((H, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


def mla_train(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    nope, rope_d = m.qk_nope_head_dim, m.qk_rope_head_dim
    cq = apply_norm(p["q_norm"], x @ p["wdq"], cfg)
    q = jnp.einsum("btr,rhk->bthk", cq, p["wuq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg, dim=rope_d)
    ckv = apply_norm(p["kv_norm"], x @ p["wdkv"], cfg)
    k_rope = apply_rope((x @ p["wkr"])[:, :, None, :], positions, cfg,
                        dim=rope_d)                      # (B,T,1,rope)
    k_nope = jnp.einsum("btr,rhk->bthk", ckv, p["wuk"])
    v = jnp.einsum("btr,rhk->bthk", ckv, p["wuv"])
    H = cfg.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, rope_d))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(q, k, v, causal=True)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"]), (ckv, k_rope[:, :, 0])


def mla_decode(p, x, cfg: ModelConfig, cache):
    """Absorbed-matrix MLA decode against the *compressed* latent cache.

    cache: {"ckv": (B,S,r), "kr": (B,S,rope), "len": ()}.
    score = q_nope·W_uk·ckv + q_rope·k_rope  (W_uk absorbed into q).
    """
    m = cfg.mla
    B = x.shape[0]
    nope, rope_d, r = m.qk_nope_head_dim, m.qk_rope_head_dim, m.kv_lora_rank
    pos = cache["len"]                                        # (B,)
    cq = apply_norm(p["q_norm"], x @ p["wdq"], cfg)
    q = jnp.einsum("br,rhk->bhk", cq, p["wuq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope[:, None], pos[:, None], cfg,
                        dim=rope_d)[:, 0]
    ckv_t = apply_norm(p["kv_norm"], x @ p["wdkv"], cfg)       # (B,r)
    kr_t = apply_rope((x @ p["wkr"])[:, None, None, :],
                      pos[:, None], cfg, dim=rope_d)[:, 0, 0]
    bidx = jnp.arange(B)
    ckv = cache["ckv"].at[bidx, pos].set(ckv_t.astype(cache["ckv"].dtype))
    kr = cache["kr"].at[bidx, pos].set(kr_t.astype(cache["kr"].dtype))
    # absorb W_uk:   q_lat (B,H,r)
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope, p["wuk"])
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhk,bsk->bhs", q_rope, kr,
                      preferred_element_type=jnp.float32))
    s = s / math.sqrt(nope + rope_d)
    valid = jnp.arange(ckv.shape[1])[None, :] < (pos + 1)[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pattn.astype(ckv.dtype), ckv)
    out = jnp.einsum("bhr,rhk->bhk", o_lat, p["wuv"])
    new_cache = {"ckv": ckv, "kr": kr, "len": pos + 1}
    return jnp.einsum("bhk,hkd->bd", out, p["wo"]), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, seq: int,
                   dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, seq, m.qk_rope_head_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def mla_cache_axes(cfg: ModelConfig):
    return {"ckv": ("cache_batch", "cache_seq", "lora"),
            "kr": ("cache_batch", "cache_seq", "head_dim"), "len": ()}
