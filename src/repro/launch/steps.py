"""Step builders: train_step / prefill_step / decode_step with full
NamedSharding in/out specs derived from logical axes. Used identically by
the real trainer/server and the dry-run (which only lowers + compiles).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.launch.mesh import mesh_shape_dict
from repro.models import model as M
from repro.optim import cosine_schedule, get_optimizer
from repro.sharding import logical as LG
from repro.models import tuning as TU
from repro.sharding.context import mesh_context


@dataclasses.dataclass
class BuiltStep:
    fn: Callable                 # jit-able python callable
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: tuple       # ShapeDtypeStructs (with shardings) to lower
    donate_argnums: tuple = ()


def _ctx_wrap(fn, mesh, rules, run: Optional[RunConfig] = None):
    """Activate the logical-sharding + tuning contexts whenever fn is
    traced, so model-level ``shard()`` constraints and chunk knobs
    resolve against this mesh / run."""
    t = TU.Tuning()
    if run is not None:
        t = TU.Tuning(q_chunk=run.q_chunk, kv_chunk=run.kv_chunk,
                      ce_chunk=run.ce_chunk, ssm_chunk=run.ssm_chunk,
                      kv_cache_quant=run.kv_cache_quant,
                      moe_cap_axis=run.moe_cap_axis or None,
                      moe_local_dispatch=run.moe_local_dispatch)

    @functools.wraps(fn)
    def wrapper(*a, **k):
        with mesh_context(mesh, rules), TU.tuning_context(t):
            return fn(*a, **k)
    return wrapper


def _run_tuning(run: RunConfig):
    return TU.tuning_context(TU.Tuning(
        q_chunk=run.q_chunk, kv_chunk=run.kv_chunk, ce_chunk=run.ce_chunk,
        ssm_chunk=run.ssm_chunk, kv_cache_quant=run.kv_cache_quant,
        moe_cap_axis=run.moe_cap_axis or None,
        moe_local_dispatch=run.moe_local_dispatch))


def _shardings(axes_tree, shapes_tree, rules, mesh):
    ms = mesh_shape_dict(mesh)
    def one(axes, shp):
        return NamedSharding(mesh, LG.spec_for(axes, shp, rules, ms))
    return jax.tree.map(
        one, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def _shapes_of(tree):
    return jax.tree.map(lambda a: tuple(a.shape), tree)


def _with_sharding(abstract, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings)


def make_rules(run: RunConfig, mesh: Mesh):
    long_ctx = run.shape.name == "long_500k" or (
        run.shape.is_decode and run.shape.global_batch <
        mesh_shape_dict(mesh).get("data", 1))
    overrides = {}
    if run.moe_cap_axis:
        overrides["moe_cap"] = (run.moe_cap_axis,)
    if not run.fsdp:
        overrides["embed"] = ()
    return LG.make_rules("pod" in mesh.axis_names, long_context=long_ctx,
                         overrides=overrides)


# =====================================================================
# train
# =====================================================================
def build_train_step(run: RunConfig, mesh: Mesh,
                     lr_base: float = 3e-4, lr_warmup: int = 200,
                     lr_total: int = 10000) -> BuiltStep:
    cfg = run.model
    model = M.Model(cfg, remat=run.remat)
    opt = get_optimizer(run.optimizer)
    lr_fn = cosine_schedule(lr_base, lr_warmup, lr_total)
    rules = make_rules(run, mesh)

    def train_step(state, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        lr = lr_fn(state["opt"]["step"])
        new_params, new_opt, opt_metrics = opt.update(
            grads, state["opt"], state["params"], lr)
        metrics = {**metrics, **opt_metrics, "loss": loss, "lr": lr}
        return {"params": new_params, "opt": new_opt}, metrics

    # abstract state + shardings
    aparams = model.abstract_params()
    aopt = jax.eval_shape(opt.init, aparams)
    p_axes = model.param_axes()
    o_axes = opt.state_axes(p_axes)
    state_ax = {"params": p_axes, "opt": o_axes}
    astate = {"params": aparams, "opt": aopt}
    state_sh = _shardings(state_ax, _shapes_of(astate), rules, mesh)

    ainputs = M.input_specs(cfg, run.shape)
    b_axes = M.batch_axes(cfg, run.shape)
    batch_sh = _shardings(b_axes, _shapes_of(ainputs), rules, mesh)

    metric_sh = None  # replicated scalars
    return BuiltStep(
        fn=_ctx_wrap(train_step, mesh, rules, run),
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metric_sh),
        abstract_inputs=(_with_sharding(astate, state_sh),
                         _with_sharding(ainputs, batch_sh)),
        donate_argnums=(0,),
    )


# =====================================================================
# serve: prefill + decode
# =====================================================================
def build_prefill_step(run: RunConfig, mesh: Mesh) -> BuiltStep:
    cfg, shape = run.model, run.shape
    model = M.Model(cfg, remat=run.remat)
    rules = make_rules(run, mesh)
    cache_seq = shape.seq_len

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_seq)

    aparams = model.abstract_params()
    p_sh = _shardings(model.param_axes(), _shapes_of(aparams), rules, mesh)
    ainputs = M.input_specs(cfg, shape)
    b_sh = _shardings(M.batch_axes(cfg, shape), _shapes_of(ainputs),
                      rules, mesh)
    enc_seq = max(shape.seq_len, 16)
    with _run_tuning(run):
        acache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, cache_seq,
                                     enc_seq))
        c_ax = model.cache_axes()
    c_sh = _shardings(c_ax, _shapes_of(acache), rules, mesh)
    logits_sh = NamedSharding(mesh, P())
    return BuiltStep(
        fn=_ctx_wrap(prefill_step, mesh, rules, run),
        in_shardings=(p_sh, b_sh),
        out_shardings=(c_sh, logits_sh),
        abstract_inputs=(_with_sharding(aparams, p_sh),
                         _with_sharding(ainputs, b_sh)),
    )


def build_decode_step(run: RunConfig, mesh: Mesh) -> BuiltStep:
    cfg, shape = run.model, run.shape
    model = M.Model(cfg, remat=run.remat)
    rules = make_rules(run, mesh)

    def decode_step(params, cache, tokens):
        new_cache, logits = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(
            logits[..., :cfg.vocab_size], axis=-1).astype(jnp.int32)
        return new_cache, next_tok

    aparams = model.abstract_params()
    p_sh = _shardings(model.param_axes(), _shapes_of(aparams), rules, mesh)
    B, S = shape.global_batch, shape.seq_len
    enc_seq = max(S // 8, 16) if cfg.family == "audio" else 16
    with _run_tuning(run):
        acache = jax.eval_shape(lambda: model.init_cache(B, S, enc_seq))
        c_ax = model.cache_axes()
    c_sh = _shardings(c_ax, _shapes_of(acache), rules, mesh)
    t_sh = NamedSharding(
        mesh, LG.spec_for(("batch",), (B,), rules, mesh_shape_dict(mesh)))
    return BuiltStep(
        fn=_ctx_wrap(decode_step, mesh, rules, run),
        in_shardings=(p_sh, c_sh, t_sh),
        out_shardings=(c_sh, t_sh),
        abstract_inputs=(_with_sharding(aparams, p_sh),
                         _with_sharding(acache, c_sh),
                         jax.ShapeDtypeStruct((B,), jnp.int32,
                                              sharding=t_sh)),
        donate_argnums=(1,),
    )


def build_step(run: RunConfig, mesh: Mesh) -> BuiltStep:
    if run.shape.kind == "train":
        return build_train_step(run, mesh)
    if run.shape.kind == "prefill":
        return build_prefill_step(run, mesh)
    return build_decode_step(run, mesh)
