"""Trip-count-aware cost analysis over compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop bodies ONCE,
so scanned layer stacks / chunked-attention loops / CE-chunk loops are
undercounted by their trip counts. This module re-derives FLOPs, bytes
and collective traffic by walking the HLO call graph and multiplying
loop bodies by ``backend_config.known_trip_count`` — making the numbers
faithful for scan-heavy programs. This is the project's dry-run profiler.

Cost conventions (mirroring HloCostAnalysis):
  * dot: 2 × |result| × (contracted extent)
  * elementwise / reduce / compare / select: |result| flops
  * bytes: per op = |result| + Σ |operands| (fusion internals excluded;
    DUS counts 2×|update| — in-place; gather/scatter count slices moved,
    not the whole table)
  * collectives: per-device payload bytes + ring-model effective bytes
    with the replica-group size parsed per op, × enclosing trip counts.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s+\((.*)\)\s+->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s+=\s+(.+?)\s+([a-z][\w\-]*)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true|false)_computation=(%[\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_REF_RE = re.compile(r"(%[\w.\-]+)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

ELEMENTWISE_SKIP = {"parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "after-all", "iota", "partition-id",
                    "replica-id"}

# Ops whose operand/result bytes count as HBM traffic. Pure elementwise /
# layout ops are assumed fused into neighbors on TPU (fusion-optimistic
# memory model); XLA:CPU leaves them unfused, which would otherwise
# inflate the memory term ~50×.
BYTES_OPS = {"dot", "convolution", "dynamic-slice",
             "dynamic-update-slice", "gather", "scatter", "concatenate",
             "pad", "reduce", "reduce-window", "sort", "custom-call",
             "fusion", "select-and-scatter", "cholesky", "triangular-solve"}

# Layout/dtype plumbing: no flops (free or fused on TPU).
ZERO_FLOP = {"broadcast", "transpose", "reshape", "convert", "copy",
             "slice", "pad", "concatenate", "reverse", "select",
             "dynamic-slice", "gather"}


def _shapes_in(text: str):
    return [(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(text)]


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _nelems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_shapes: list            # [(dtype, dims), ...]
    line: str


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0        # raw per-device payload
    coll_effective: float = 0.0    # ring-model wire bytes
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    coll_count: int = 0

    def __iadd__(self, o: "Costs"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        self.coll_effective += o.coll_effective
        for k, v in o.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v
        self.coll_count += o.coll_count
        return self

    def scaled(self, k: float) -> "Costs":
        return Costs(self.flops * k, self.bytes * k, self.coll_bytes * k,
                     self.coll_effective * k,
                     {a: b * k for a, b in self.coll_by_op.items()},
                     int(self.coll_count * k))


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Op]] = {}
        self.params: dict[str, dict[str, list]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: dict[str, Costs] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.endswith("{"):
                cur = hdr.group(1)
                self.computations[cur] = []
                self.params[cur] = {}
                if line.startswith("ENTRY"):
                    self.entry = cur
                # parameter shapes from the header
                for pm in re.finditer(r"(%?[\w.\-]+):\s+(\([^)]*\)|[a-z][a-z0-9]*\[[\d,]*\])",
                                      hdr.group(2)):
                    pname = pm.group(1)
                    if not pname.startswith("%"):
                        pname = "%" + pname
                    self.params[cur][pname] = _shapes_in(pm.group(2))
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, result, opcode, _rest = m.groups()
            self.computations[cur].append(
                Op(name, opcode, _shapes_in(result), line))

    # ---------------- symbol table for operand lookup
    def _symbols(self, comp: str) -> dict:
        tab = dict(self.params.get(comp, {}))
        for op in self.computations.get(comp, []):
            tab[op.name] = op.result_shapes
            # `parameter` ops also declare shapes inline
        return tab

    # ---------------- per-op costing
    def _op_costs(self, op: Op, sym: dict) -> Costs:
        c = Costs()
        code = op.opcode
        if code in ELEMENTWISE_SKIP:
            return c
        res_bytes = sum(_nbytes(d, s) for d, s in op.result_shapes)
        res_elems = sum(_nelems(s) for _, s in op.result_shapes)

        # operand bytes via symbol lookup (args before the attr section)
        argstr = op.line.split("(", 1)[1]
        argstr = argstr.split("), ")[0]
        operands = []
        for ref in _OPERAND_REF_RE.findall(argstr):
            if ref in sym:
                operands.append(sym[ref])

        def operand_bytes(i=None):
            sel = operands if i is None else operands[i:i + 1]
            return sum(_nbytes(d, s) for shapes in sel for d, s in shapes)

        if code == "dot":
            lhs = operands[0] if operands else []
            contract = 1
            mm = _CONTRACT_RE.search(op.line)
            if mm and lhs:
                dims = lhs[0][1].split(",") if lhs[0][1] else []
                for idx in (mm.group(1).split(",") if mm.group(1) else []):
                    contract *= int(dims[int(idx)])
            c.flops = 2.0 * res_elems * contract
            c.bytes = res_bytes + operand_bytes()
        elif code == "convolution":
            # rough: 2 × |result| × (window × in_channels) — parse window
            win = re.search(r"window=\{size=([\dx]+)", op.line)
            k = 1
            if win:
                for d in win.group(1).split("x"):
                    k *= int(d)
            in_ch = 1
            if operands and operands[1:]:
                kd = operands[1][0][1].split(",")
                in_ch = int(kd[-2]) if len(kd) >= 2 else 1
            c.flops = 2.0 * res_elems * k * in_ch
            c.bytes = res_bytes + operand_bytes()
        elif code in COLLECTIVE_OPS or any(
                code == x + "-start" for x in COLLECTIVE_OPS):
            base = code.replace("-start", "")
            nb = res_bytes
            g = self._group_size(op.line)
            ring = (g - 1) / g if g > 1 else 0.0
            if base == "all-reduce":
                eff = 2 * nb * ring
            elif base == "reduce-scatter":
                eff = nb * g * ring
            elif base == "collective-permute":
                eff = nb
            else:
                eff = nb * ring
            c.coll_bytes = nb
            c.coll_effective = eff
            c.coll_by_op = {base: float(nb)}
            c.coll_count = 1
            c.bytes = res_bytes + operand_bytes()
        elif code == "fusion":
            called = _CALLS_RE.search(op.line)
            inner_ops = []
            if called:
                inner = self.comp_costs(called.group(1))
                inner_ops = self.computations.get(called.group(1), [])
                c.flops = inner.flops
                c.coll_bytes = inner.coll_bytes
                c.coll_effective = inner.coll_effective
                c.coll_by_op = dict(inner.coll_by_op)
                c.coll_count = inner.coll_count
            # TPU-faithful fusion traffic:
            #  * a fused dynamic-update-slice is in-place: count 2× the
            #    update window, not the whole aliased buffer;
            #  * pure layout plumbing (a lone convert/broadcast/copy/
            #    transpose body) fuses into its consumer on TPU: free;
            #  * kLoop/kOutput fusions touch O(1) elems per output index:
            #    cap operand reads at result size;
            #  * kInput (reduce-rooted) fusions read operands in full.
            dus_ops = [o for o in inner_ops
                       if o.opcode == "dynamic-update-slice"]
            real_ops = [o for o in inner_ops
                        if o.opcode not in ELEMENTWISE_SKIP]
            if dus_ops:
                csym = self._symbols(called.group(1))
                upd = 0
                for o in dus_ops:
                    argstr = o.line.split("(", 1)[1]
                    refs = _OPERAND_REF_RE.findall(argstr)
                    if len(refs) >= 2 and refs[1] in csym:
                        upd += sum(_nbytes(d, s) for d, s in csym[refs[1]])
                c.bytes = 2 * upd
            elif len(real_ops) == 1 and real_ops[0].opcode in (
                    "convert", "broadcast", "copy", "transpose",
                    "reshape", "bitcast"):
                c.bytes = 0.0
            elif "kind=kInput" in op.line:
                c.bytes = res_bytes + operand_bytes()
            else:
                c.bytes = res_bytes + sum(
                    min(sum(_nbytes(d, s) for d, s in shapes), res_bytes)
                    for shapes in operands)
        elif code == "while":
            body = _BODY_RE.search(op.line)
            cond = _COND_RE.search(op.line)
            trip = 1
            tm = _TRIP_RE.search(op.line)
            if tm:
                trip = int(tm.group(1))
            inner = Costs()
            if body:
                inner += self.comp_costs(body.group(1))
            if cond:
                inner += self.comp_costs(cond.group(1))
            return inner.scaled(trip)
        elif code == "conditional":
            branches = []
            bm = _BRANCH_RE.search(op.line)
            if bm:
                branches = _OPERAND_REF_RE.findall(bm.group(1))
            else:
                branches = _TF_RE.findall(op.line)
            if branches:
                costs = [self.comp_costs(b) for b in branches]
                best = max(costs, key=lambda x: x.flops + x.bytes)
                c += best
            c.bytes += res_bytes
        elif code in ("call", "async-start"):
            called = _CALLS_RE.search(op.line) or re.search(
                r"to_apply=(%[\w.\-]+)", op.line)
            if called:
                c += self.comp_costs(called.group(1))
            c.bytes += res_bytes
        elif code == "dynamic-update-slice":
            upd = operand_bytes(1)
            c.bytes = 2 * upd
            c.flops = 0
        elif code == "scatter":
            c.bytes = 2 * operand_bytes(2) + operand_bytes(1)
        elif code in ("gather", "dynamic-slice"):
            c.bytes = 2 * res_bytes
        elif code == "custom-call":
            if "TopK" in op.line or "topk" in op.line:
                c.flops = 5.0 * res_elems
            c.bytes = res_bytes + operand_bytes()
        elif code == "sort":
            n = max(res_elems, 2)
            import math
            c.flops = n * math.log2(n)
            c.bytes = res_bytes + operand_bytes()
        else:
            # elementwise / reduce / broadcast / transpose / etc.
            c.flops = 0.0 if code in ZERO_FLOP else float(res_elems)
            c.bytes = res_bytes + operand_bytes()
        if code not in BYTES_OPS and code not in COLLECTIVE_OPS and \
                not any(code == x + "-start" for x in COLLECTIVE_OPS) and \
                code not in ("while", "conditional", "call", "async-start"):
            c.bytes = 0.0
        return c

    def _group_size(self, line: str) -> int:
        gm = _GROUPS_RE.search(line)
        if gm:
            return len(gm.group(1).split(","))
        im = _IOTA_RE.search(line)
        if im:
            return int(im.group(2))
        return 1

    def comp_costs(self, comp: str) -> Costs:
        comp = comp.strip()
        if comp in self._memo:
            return self._memo[comp]
        total = Costs()
        self._memo[comp] = total      # guard (HLO comps are acyclic)
        sym = self._symbols(comp)
        for op in self.computations.get(comp, []):
            total += self._op_costs(op, sym)
        return total

    def total(self) -> Costs:
        if not self.entry:
            # fall back: largest computation
            self.entry = max(self.computations,
                             key=lambda c: len(self.computations[c]))
        return self.comp_costs(self.entry)


def analyze(hlo_text: str) -> Costs:
    return HloModule(hlo_text).total()
