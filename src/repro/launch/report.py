"""Render the dry-run artifact directory into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report artifacts/dryrun
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def fmt_b(x):
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def load(outdir, mesh="single", tag="baseline"):
    recs = {}
    for p in sorted(Path(outdir).glob(f"*.{mesh}.{tag}.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def roofline_table(outdir, mesh="single", tag="baseline") -> str:
    recs = load(outdir, mesh, tag)
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "bytes/dev | fits HBM | useful/HLO | roofline |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | *skip* "
                         f"| — | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | ERROR | | | | | | | |")
            continue
        rl = r["roofline"]
        mem = r.get("memory_analysis", {})
        per_dev = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
                   + mem.get("output_bytes", 0) - mem.get("alias_bytes", 0))
        fits = "✓" if per_dev <= 16 * 1024 ** 3 else "✗"
        lines.append(
            f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} | "
            f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
            f"{rl['bottleneck']} | {fmt_b(per_dev)} | {fits} | "
            f"{rl['useful_ratio']:.3f} | {rl['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def summary(outdir, tag="baseline") -> str:
    out = []
    for mesh in ("single", "multi"):
        recs = load(outdir, mesh, tag)
        ok = sum(r["status"] == "ok" for r in recs.values())
        sk = sum(r["status"] == "skipped" for r in recs.values())
        er = sum(r["status"] == "error" for r in recs.values())
        out.append(f"{mesh}: {ok} ok / {sk} skipped / {er} errors "
                   f"({len(recs)} cells)")
    return "\n".join(out)


if __name__ == "__main__":
    outdir = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    tag = sys.argv[2] if len(sys.argv) > 2 else "baseline"
    print(summary(outdir, tag))
    print()
    print(roofline_table(outdir, "single", tag))
