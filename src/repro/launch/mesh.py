"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state. Single-pod: (16, 16) = 256 chips, axes
("data", "model"). Multi-pod: (2, 16, 16) = 512 chips, axes
("pod", "data", "model") — "pod" crosses the DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1,
                   pod: int = 0) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_shape_dict(mesh: jax.sharding.Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
