"""Roofline-term extraction from a compiled (dry-run) artifact.

Three terms per (arch × shape × mesh), in seconds:
    compute    = per-device HLO FLOPs / peak_FLOP/s
    memory     = per-device HLO bytes-accessed / HBM bandwidth
    collective = per-device collective bytes (ring-model effective) / ICI bw

`cost_analysis()` on the SPMD-partitioned module already reports
*per-device* flops/bytes (verified empirically), so no extra division by
chip count. Collective bytes are parsed from the optimized HLO text: for
each all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute we take the per-device result bytes and apply ring
cost factors over the parsed replica-group size.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

# ----- TPU v5e-class hardware constants (per chip) -----
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (effective, one direction)
DCN_BW = 25e9                # B/s per host, pod-to-pod
HBM_BYTES = 16 * 1024 ** 3   # 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9_]+)\[([\d,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    effective_bytes: float      # ring-model per-device bytes on the wire
    count: int

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_op.values()))


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    by_op: dict = {}
    effective = 0.0
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3).lower()
        nbytes = _shape_bytes(dtype, dims)
        # group size
        g = n_devices
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            im = _IOTA_RE.search(line)
            if im:
                g = int(im.group(2))
        g = max(g, 1)
        ring = (g - 1) / g
        if op == "all-reduce":
            eff = 2 * nbytes * ring          # reduce-scatter + all-gather
        elif op == "all-gather":
            eff = nbytes * ring              # result bytes gathered
        elif op == "reduce-scatter":
            eff = nbytes * g * ring          # operand = result × g
        elif op == "all-to-all":
            eff = nbytes * ring
        else:                                 # collective-permute
            eff = nbytes
        by_op[op] = by_op.get(op, 0.0) + nbytes
        effective += eff
        count += 1
    return CollectiveStats(by_op, effective, count)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6·N·D (train) / 2·N·D (inference), global
    useful_ratio: float           # model_flops / (flops_per_device × chips)
    memory_per_device_bytes: Optional[float] = None
    fits_hbm: Optional[bool] = None
    collective_count: int = 0
    step_time_s: float = 0.0      # max of the three terms (overlap ideal)
    roofline_fraction: float = 0.0  # useful compute time / step time

    def to_dict(self):
        return dataclasses.asdict(self)


def compute_roofline(cost: dict, hlo_text: str, n_devices: int,
                     model_flops: float,
                     memory_stats=None) -> Roofline:
    # XLA's cost_analysis() counts while bodies once; use the trip-count-
    # aware HLO walker instead (hlo_analysis) and keep XLA's numbers as a
    # cross-check lower bound.
    from repro.launch import hlo_analysis as HA
    hc = HA.analyze(hlo_text)
    flops = max(hc.flops, float(cost.get("flops", 0.0)))
    nbytes = max(hc.bytes, float(cost.get("bytes accessed", 0.0)))
    coll = CollectiveStats(hc.coll_by_op, hc.coll_effective, hc.coll_count)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    collective_s = coll.effective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mem_per_dev = None
    fits = None
    if memory_stats is not None:
        mem_per_dev = float(
            memory_stats.argument_size_in_bytes
            + memory_stats.output_size_in_bytes
            + memory_stats.temp_size_in_bytes
            - memory_stats.alias_size_in_bytes)
        fits = mem_per_dev <= HBM_BYTES
    useful = model_flops / max(flops * n_devices, 1.0)
    step = max(compute_s, memory_s, collective_s)
    useful_compute_s = (model_flops / n_devices) / PEAK_FLOPS_BF16
    return Roofline(
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_bytes=coll.effective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops, useful_ratio=useful,
        memory_per_device_bytes=mem_per_dev, fits_hbm=fits,
        collective_count=coll.count, step_time_s=step,
        roofline_fraction=useful_compute_s / max(step, 1e-30))


def model_flops_for(cfg, shape) -> float:
    """Useful model FLOPs for this cell: 6·N·D train, 2·N·D inference
    (N = active params, D = tokens processed globally)."""
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch        # decode: 1 token per seq


def active_params(cfg) -> float:
    """Per-token active parameter count (MoE: top-k + shared only)."""
    n = cfg.n_params()
    if cfg.moe is not None:
        m = cfg.moe
        ff_mult = 3 if cfg.glu else 2
        per_expert = ff_mult * cfg.d_model * m.d_ff_expert
        moe_layers = cfg.n_layers - m.first_dense_layers
        inactive = (m.n_routed_experts - m.top_k) * per_expert * moe_layers
        n = n - inactive
    return float(n)
