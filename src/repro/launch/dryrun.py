import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")
                           ).strip()
# ^ MUST run before any jax import: jax locks device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against the production mesh and extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh single multi --out artifacts/dryrun

Success criterion: ``.lower().compile()`` succeeds and
``memory_analysis()`` / ``cost_analysis()`` are recorded for every cell.
Skipped cells (long_500k × full-attention archs) are recorded with their
skip reason rather than silently dropped.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ALIASES, ARCH_IDS, SHAPES, get_config, skip_reason
from repro.configs.base import RunConfig
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             run_overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind}
    reason = skip_reason(cfg, shape_name)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    overrides = dict(run_overrides or {})
    # memory-sane optimizer default for the huge training cells
    if shape.kind == "train" and cfg.n_params() > 3e10:
        overrides.setdefault("optimizer", "adafactor")
    run = RunConfig(model=cfg, shape=shape, multi_pod=multi_pod, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    t0 = time.time()
    built = build_step(run, mesh)
    with mesh:
        jitted = jax.jit(built.fn,
                         in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate_argnums)
        lowered = jitted.lower(*built.abstract_inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    hlo = compiled.as_text()
    rl = RL.compute_roofline(cost, hlo, n_dev,
                             RL.model_flops_for(cfg, shape), mem)
    rec.update(status="ok", lower_s=round(t_lower, 2),
               compile_s=round(t_compile, 2), n_devices=n_dev,
               optimizer=run.optimizer, roofline=rl.to_dict())
    if mem is not None:
        rec["memory_analysis"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single"],
                    choices=["single", "multi"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--kv-chunk", type=int, default=0)
    ap.add_argument("--ce-chunk", type=int, default=0)
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--kv-cache-quant", action="store_true")
    ap.add_argument("--moe-cap-axis", default="")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--moe-local", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == ["all"] else [
        ALIASES.get(a, a) for a in args.arch]
    shapes = list(SHAPES) if args.shape == ["all"] else args.shape
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    overrides = {"remat": args.remat}
    if args.optimizer:
        overrides["optimizer"] = args.optimizer
    for field, val in (("q_chunk", args.q_chunk),
                       ("kv_chunk", args.kv_chunk),
                       ("ce_chunk", args.ce_chunk),
                       ("ssm_chunk", args.ssm_chunk)):
        if val:
            overrides[field] = val
    if args.kv_cache_quant:
        overrides["kv_cache_quant"] = True
    if args.moe_cap_axis:
        overrides["moe_cap_axis"] = args.moe_cap_axis
    if args.no_fsdp:
        overrides["fsdp"] = False
    if args.moe_local:
        overrides["moe_local_dispatch"] = True

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in args.mesh:
                multi = mesh_kind == "multi"
                name = f"{arch}.{shape}.{mesh_kind}.{args.tag}"
                path = outdir / f"{name}.json"
                try:
                    rec = run_cell(arch, shape, multi, overrides)
                except Exception as e:  # a failing cell is a bug: surface it
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                rec["tag"] = args.tag
                path.write_text(json.dumps(rec, indent=1))
                results.append(rec)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"{name:55s} OK  compile={rec['compile_s']:7.1f}s "
                          f"compute={r['compute_s']:.3e} "
                          f"memory={r['memory_s']:.3e} "
                          f"coll={r['collective_s']:.3e} "
                          f"bound={r['bottleneck']:10s} "
                          f"roofline={r['roofline_fraction']:.3f}",
                          flush=True)
                else:
                    print(f"{name:55s} {rec['status'].upper()} "
                          f"{rec.get('reason', rec.get('error', ''))[:90]}",
                          flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
