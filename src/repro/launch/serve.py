"""Serving launcher: continuous batching over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --reduced --requests 8 --slots 4
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.model import Model
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(args.seed))
    eng = ServingEngine(cfg, params, slots=args.slots,
                        max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        r = Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size - 1,
                                        int(rng.integers(4, 16))
                                        ).astype(np.int32),
                    max_new_tokens=args.new_tokens)
        reqs.append(r)
        eng.submit(r)
    st = eng.run_until_drained()
    ttft = [r.first_token_s - r.submitted_s for r in reqs]
    print(f"[{cfg.name}] {st.tokens_out} tokens "
          f"@ {st.tokens_per_s:.1f} tok/s; "
          f"TTFT p50={np.percentile(ttft, 50)*1e3:.0f}ms; "
          f"prefills={st.prefills} decode_steps={st.decode_steps}")


if __name__ == "__main__":
    main()
