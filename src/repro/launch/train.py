"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 30 --seq 128 --batch 8 --ckpt-dir /tmp/ckpt

Full-config multi-host launches use the same entry point with
``--mesh production``; on this CPU box the production mesh is validated
via the dry-run instead (repro.launch.dryrun).
"""
import argparse

from repro.configs import get_config, get_reduced
from repro.configs.base import RunConfig, ShapeConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.runtime.train_loop import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "production", "production-multi"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.mesh == "host":
        mesh = make_host_mesh(1, 1)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh.endswith("multi"))
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("train", "train", args.seq,
                                      args.batch),
                    multi_pod=args.mesh.endswith("multi"),
                    remat=args.remat, optimizer=args.optimizer,
                    gradient_compression=args.compress_grads)
    tr = Trainer(run, mesh, TrainerConfig(
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        lr_base=args.lr, lr_warmup=max(args.steps // 10, 2),
        lr_total=max(args.steps, 100)))
    out = tr.train(args.steps)
    print(f"[{cfg.name}] {len(out['losses'])} steps, "
          f"loss {out['losses'][0]:.4f} -> {out['final_loss']:.4f}, "
          f"stragglers={len(out['stragglers'])}, "
          f"checkpoints={tr.ckpt.all_steps()}")


if __name__ == "__main__":
    main()
