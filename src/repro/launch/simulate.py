"""Offline what-if simulation CLI — a thin front-end over the Scenario
API (``core.scenario``).

    PYTHONPATH=src python -m repro.launch.simulate --workload zamba2-7b-reduced
    PYTHONPATH=src python -m repro.launch.simulate --model bert-medium --layers 2
    PYTHONPATH=src python -m repro.launch.simulate --gemm 512 512 512
    PYTHONPATH=src python -m repro.launch.simulate --workload serve
    PYTHONPATH=src python -m repro.launch.simulate --list
    PYTHONPATH=src python -m repro.launch.simulate --smoke
    PYTHONPATH=src python -m repro.launch.simulate --model bert-base --tune

``--workload`` (and its historical alias ``--model``) accepts ANY name
from the scenario registry: every ``configs/*.py`` ``ModelConfig``
(full or ``-reduced``), the paper's BERT/ViT models, the workload-class
aliases (``bert``/``vit``), and the synthetic classes
(``moe``/``ssm``/``decode``/``serve``/``gemm``).  Unknown names get a
did-you-mean error listing the valid scenarios — resolution always goes
through the registry, never a partial name table.

Workloads replay steady-state sampled by default (one window per layer
CLASS x repeat — heterogeneous stacks like zamba2 sample each class
separately); ``--exact`` materializes the full composed event graph.
``--engine both`` replays on the compiled array engine AND the event
loop and asserts every result field agrees to rtol 1e-9.  ``--smoke``
runs the registry-generated CI matrix: one reduced scenario per model
family, engine parity on each.

``--tune`` searches the co-design knob space (``core.design_space``)
against the selected workload instead of replaying a single system:
every feasible point is priced with the config-batched replayer and
the latency-vs-area Pareto frontier is printed.  ``--tune-points N``
random-samples the space instead of enumerating the full grid.

``--workload serve --arrivals poisson`` switches the serve scenario
from draining a closed queue to an OPEN-loop load sweep
(``core.scenario.sweep_load``): seeded poisson/bursty/diurnal
arrivals at each ``--qps`` grid rate (auto-bracketed around the
calibrated capacity when omitted), ``--requests`` requests per
point, every trace priced across the memory modes in one chunked
streaming replay — printing offered QPS vs TTFT/TPOT p99 per mode
plus the saturation knee.  ``--preempt lifo|longest`` pressure-caps
the KV pool so admission stalls trigger preemption + KV swap-to-host
and extends the grid past the knee (the swap-thrash curve);
``--swap`` adds per-point preemption counts and swap-DMA / queue
tail columns.
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.core.scenario import (Scenario, SimResult, UnsupportedScenario,
                                 as_params, resolve, scenario_names,
                                 simulate, smoke_matrix)


def _fmt(res: SimResult) -> str:
    r = res.result
    b = r.buckets()
    shares = " ".join(f"{k}={v:5.1%}" for k, v in b.items())
    return f"total={r.total_s*1e6:10.1f}us  {shares}  " \
           f"tlb_miss={r.tlb_misses}  gops={r.gops:.1f}"


def _run_modes(sc: Scenario, modes, engine: str) -> None:
    """Simulate one scenario across memory modes, printing a row per
    (mode, engine); ``engine="both"`` prints both rows plus the parity
    confirmation ``simulate`` asserts internally."""
    header = None
    for mode in modes:
        engines = ("compiled", "event") if engine == "both" \
            else (engine,)
        results = {}
        for eng in engines:
            res = simulate(dataclasses.replace(sc, mode=mode,
                                               engine=eng))
            results[eng] = res
            if header is None:
                # serve replays a recorded trace exactly — the
                # sampling policy does not apply to it
                policy = "trace" if res.serving is not None \
                    else sc.sampling
                header = f"{res.label} ({policy}): events " \
                         f"replayed={res.events_replayed} " \
                         f"total={res.events_total} " \
                         f"({res.sampling_speedup:.1f}x fewer)"
                print(header)
            print(f"{res.label} {res.scenario.dtype} {mode:7s} "
                  f"{_fmt(res)}  [{res.engine}: "
                  f"wall={res.wall_s*1e3:.1f}ms "
                  f"{res.events_per_s:,.0f} ev/s]")
        last = results[engines[-1]]
        if last.serving is not None:    # once per mode, engines agree
            pct = last.serving
            print(f"serve {mode:7s} simulated latency: " + "  ".join(
                f"{k}={pct[k]:.1f}" for k in
                ("ttft_p50_us", "ttft_p95_us", "ttft_p99_us",
                 "tpot_p50_us", "tpot_p95_us", "tpot_p99_us")) +
                f"  requests={pct['requests']}")
        if engine == "both":
            from repro.core.scenario import assert_parity
            assert_parity(results["compiled"], results["event"])
            print(f"{results['compiled'].label} {mode}: compiled == "
                  f"event (all GemmResult fields, rtol<=1e-9)")


def _run_tune(sc: Scenario, n_points) -> int:
    """Price the co-design knob space against one workload and print
    the scored points, the latency-vs-area Pareto frontier and the
    batched-pricing throughput."""
    from repro.core.design_space import default_space
    from repro.core.scenario import tune

    space = default_space()
    points = space.sample(n_points, seed=0) \
        if n_points is not None else space
    res = tune(sc, points)
    print(f"tune {res.scenario.model} ({res.scenario.sampling}): "
          f"{len(res.points)} points scored in {res.wall_s:.2f}s "
          f"({res.configs_per_s:,.0f} configs/s, "
          f"{res.n_infeasible} infeasible filtered)")
    best = res.best
    shown = sorted(res.points, key=lambda tp: tp.score)[:10]
    for tp in shown:
        mark = "*" if tp is best else " "
        front = "pareto" if tp.on_pareto else "      "
        print(f" {mark} {front} {tp.point.label():44s} "
              f"total={tp.total_s * 1e6:9.1f}us "
              f"area={tp.area_um2 / 1e6:6.2f}mm2 "
              f"score={tp.score:.4g}")
    n_more = len(res.points) - len(shown)
    if n_more > 0:
        print(f"   ... {n_more} more points (lowest 10 scores shown)")
    print(f"pareto frontier: {len(res.pareto)} points; "
          f"best ({res.objective}): {best.point.label()}")
    return 0


def _run_load_sweep(args) -> int:
    """Open-loop load sweep over the memory modes: one line per
    (offered QPS, mode) plus the saturation knee per mode.  With
    ``--preempt`` the pool is pressure-capped and the grid extended
    past the knee; ``--swap`` adds the swap-thrash columns."""
    from repro.core.scenario import sweep_load
    res = sweep_load(qps=args.qps, n_requests=args.requests,
                     arrivals=args.arrivals, modes=tuple(args.modes),
                     prefix_tokens=args.prefix_tokens,
                     preempt=args.preempt,
                     stall_budget_s=args.stall_budget_us * 1e-6,
                     workers=args.workers)
    cal = res.calibration
    pool = f", pool={res.kv_pool_pages} pages" \
        if res.kv_pool_pages is not None else ""
    pre = f", preempt={res.preempt}{pool}" \
        if res.preempt != "none" else ""
    print(f"load sweep {res.arch} ({res.arrivals}, "
          f"{res.n_requests} requests/point{pre}): est capacity "
          f"{cal['capacity_qps_est']:,.0f} qps "
          f"(decode step {cal['est_step_s']*1e6:.1f}us); "
          f"wall {res.wall_s:.1f}s")
    for mode in res.modes:
        for pt in res.curve(mode):
            p = pt.percentiles
            cens = f" in_flight={p['n_in_flight']}" \
                if p["n_in_flight"] else ""
            swap = f" preempt={p['preemptions']:4d} " \
                   f"swap_p99={p['swap_p99_us']:7.1f}us " \
                   f"queue_p99={p['queue_p99_us']:9.1f}us" \
                if args.swap else ""
            print(f"  {mode:7s} qps={pt.qps:10,.1f} "
                  f"ttft_p99={p['ttft_p99_us']:9.1f}us "
                  f"tpot_p99={p['tpot_p99_us']:8.1f}us "
                  f"goodput={pt.goodput_qps:10,.1f}/s "
                  f"events={pt.n_events:,}{swap}{cens}")
        k = res.knee_qps[mode]
        print(f"  {mode:7s} saturation knee: " +
              (f"{k:,.1f} qps" if k else "not reached on this grid"))
    if res.prefix_delta:
        for mode, d in res.prefix_delta.items():
            print(f"  {mode:7s} prefix caching: ttft_p99 "
                  f"{d['ttft_p99_us_on']:.1f}us vs "
                  f"{d['ttft_p99_us_off']:.1f}us uncached "
                  f"({d['records_off'] - d['records_on']} prefill "
                  f"records saved)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", metavar="SCENARIO",
                    help="any scenario-registry name (see --list)")
    ap.add_argument("--model", metavar="SCENARIO",
                    help="historical alias of --workload")
    ap.add_argument("--gemm", type=int, nargs=3, metavar=("M", "N", "K"),
                    help="single Algorithm-1 GEMM instead of a model")
    ap.add_argument("--list", action="store_true",
                    help="print every valid scenario name and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="registry-generated CI matrix: one reduced "
                         "scenario per model family, engine parity")
    ap.add_argument("--layers", type=int, default=None,
                    help="cap the layer stack (default: full model)")
    ap.add_argument("--seq", type=int, default=None,
                    help="sequence length (default: per-model)")
    ap.add_argument("--dtype", default="int8",
                    choices=["int8", "int16", "int32", "fp8", "fp16",
                             "fp32"])
    ap.add_argument("--modes", nargs="+", default=["DM", "DC", "DevMem"],
                    choices=["DM", "DC", "DevMem"])
    ap.add_argument("--sample-stride", type=int, default=1,
                    help="additionally stride the GEMM inner loops of "
                         "the sampled window")
    ap.add_argument("--exact", action="store_true",
                    help="replay the full composed event graph instead "
                         "of the steady-state sample")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "event", "compiled", "both"],
                    help="replayer: compiled array engine vs Python "
                         "event loop ('both' checks parity)")
    ap.add_argument("--tune", action="store_true",
                    help="design-space search (core.design_space) over "
                         "the workload: batched pricing + Pareto front")
    ap.add_argument("--tune-points", type=int, default=None,
                    metavar="N",
                    help="random-sample the space to N points instead "
                         "of the full grid (seeded, deterministic)")
    ap.add_argument("--devmem-dram", default="HBM2",
                    help="DRAM tech for DevMem mode (paper Fig. 12)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard heads/FFN over "
                         "N ranks with all-gather/reduce-scatter at "
                         "the Megatron cut points (config models only)")
    ap.add_argument("--ep", type=int, default=1,
                    help="expert-parallel degree: shard MoE experts "
                         "over N ranks with all-to-all dispatch/combine")
    ap.add_argument("--fabric", default="ring",
                    metavar="TOPO[:GBS[:HOP_NS]]",
                    help="inter-accelerator fabric, e.g. 'ring', "
                         "'alltoall', 'ring:64', 'ring:64:800' "
                         "(topology, link GB/s, per-hop latency)")
    ap.add_argument("--pcie-gb-s", type=float, default=None,
                    help="override the host link's raw bandwidth (GB/s)")
    ap.add_argument("--arrivals", default=None,
                    choices=["poisson", "bursty", "diurnal"],
                    help="serve only: open-loop load sweep with this "
                         "arrival process (core.scenario.sweep_load)")
    ap.add_argument("--qps", type=float, nargs="+", default=None,
                    metavar="RATE",
                    help="offered-rate grid for --arrivals (default: "
                         "auto-bracketed around calibrated capacity)")
    ap.add_argument("--requests", type=int, default=200,
                    help="requests per load point for --arrivals "
                         "(default 200)")
    ap.add_argument("--prefix-tokens", type=int, default=0,
                    help="shared system-prompt tokens for --arrivals "
                         "(reports the prefix-caching on/off delta)")
    ap.add_argument("--preempt", default="none",
                    choices=["none", "lifo", "longest"],
                    help="serve only: preemption policy under memory "
                         "pressure — caps the KV pool and extends the "
                         "sweep past the knee (swap-thrash curve)")
    ap.add_argument("--stall-budget-us", type=float, default=0.0,
                    help="admission stall tolerated before preempting "
                         "a victim (default 0: preempt immediately)")
    ap.add_argument("--swap", action="store_true",
                    help="serve only: print per-point preemption / "
                         "swap-DMA / queue-delay tail columns")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width for --arrivals sweep "
                         "points (results identical to --workers 1)")
    args = ap.parse_args(argv)
    if args.list:
        print("\n".join(scenario_names()))
        return 0
    name = args.model or args.workload
    if args.smoke:
        for sc in smoke_matrix():
            sc = dataclasses.replace(sc, devmem_dram=args.devmem_dram)
            _run_modes(sc, args.modes, "both")
        print(f"smoke matrix OK: {len(smoke_matrix())} scenarios x "
              f"{len(args.modes)} modes, engine parity held")
        return 0
    if not name and not args.gemm:
        ap.error("one of --workload / --model / --gemm / --smoke / "
                 "--list is required")
    if args.layers is not None and args.layers < 1:
        ap.error("--layers must be >= 1")
    if args.sample_stride < 1:
        ap.error("--sample-stride must be >= 1")
    if args.tune_points is not None:
        if not args.tune:
            ap.error("--tune-points requires --tune")
        if args.tune_points < 1:
            ap.error("--tune-points must be >= 1")

    params = ()
    if args.gemm:
        name = "gemm"
        m, n, k = args.gemm
        params = as_params(m=m, n=n, k=k)
    try:
        target = resolve(name)
    except UnsupportedScenario as e:
        ap.error(str(e))
    if target.kind == "serve":
        args.dtype = "fp16"        # the engine's KV cache dtype decides
    if args.arrivals is None and (args.preempt != "none" or args.swap):
        ap.error("--preempt/--swap require --arrivals (load sweep)")
    if args.arrivals is not None:
        if target.kind != "serve":
            ap.error("--arrivals only applies to --workload serve")
        if args.requests < 1:
            ap.error("--requests must be >= 1")
        if args.stall_budget_us < 0:
            ap.error("--stall-budget-us must be >= 0")
        return _run_load_sweep(args)
    try:
        sc = Scenario(model=name, dtype=args.dtype, seq=args.seq,
                      n_layers=args.layers,
                      sampling="exact" if args.exact else "sampled",
                      sample_stride=args.sample_stride,
                      devmem_dram=args.devmem_dram, params=params,
                      tp=args.tp, ep=args.ep, fabric=args.fabric,
                      pcie_gb_s=args.pcie_gb_s)
        if args.tune:
            return _run_tune(sc, args.tune_points)
        _run_modes(sc, args.modes, args.engine)
    except UnsupportedScenario as e:
        ap.error(str(e))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
