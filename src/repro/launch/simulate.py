"""Offline what-if simulation CLI over the StreamPlan IR.

    PYTHONPATH=src python -m repro.launch.simulate --model bert-medium \
        --modes DM DC DevMem --layers 2
    PYTHONPATH=src python -m repro.launch.simulate --gemm 512 512 512
    PYTHONPATH=src python -m repro.launch.simulate --workload moe
    PYTHONPATH=src python -m repro.launch.simulate --workload decode

Builds the requested plan — a single Algorithm-1 GEMM, a composed
N-layer transformer forward pass, or one of the workload classes the
plan layer can express (``bert``/``vit`` dense encoders, ``moe``
expert-routed FFN stacks, ``ssm`` scan layers, ``decode`` paged-KV
decode steps, ``serve`` a recorded continuous-batching engine trace:
prefill + multi-layer GQA decode plans replayed batched, with
simulated per-request TTFT/TPOT percentiles printed per mode) — and
replays it against the accesys component models in each memory mode,
printing end-to-end latency and the Fig.-2 bucket shares.

Workloads replay steady-state sampled by default (one layer window x
repeat count; ``--sample-stride`` additionally strides the GEMM inner
loops); ``--exact`` materializes and replays the full composed event
graph.  The events-replayed vs events-total line makes the sampling
speedup visible.

``--engine`` selects the replayer: the compiled array engine (the
default for anything non-trivial) or the event loop; ``--engine both``
runs the two and asserts they agree to float tolerance — the parity
check CI runs per workload class.  Each mode row reports the replay
wall-clock and events/sec, so the compiled engine's speedup is
measured, not asserted.
"""
from __future__ import annotations

import argparse
import time

from repro.accesys.components import DRAM
from repro.accesys.pipeline import replay, simulate_gemm
from repro.accesys.system import (default_system, model_stream_plan,
                                  model_stream_schedule)
from repro.configs.paper_models import PAPER_MODELS
from repro.core import plan as plan_ir

WORKLOAD_MODELS = {"bert": "bert-base", "vit": "vit-base-16"}
WORKLOADS = ("bert", "vit", "moe", "ssm", "decode", "serve")

# tiny-but-representative geometry for the synthetic workload classes
MOE_SHAPE = dict(n_tokens=64, d_model=128, n_experts=8, top_k=2,
                 d_ff=256)
SSM_SHAPE = dict(T=128, d_model=128, n_heads=4, chunk=16)
DECODE_SHAPE = dict(n_pages=64, page_tokens=8, n_kv_heads=4,
                    head_dim=32, max_pages_per_seq=8,
                    prompt_lens=(20, 9, 33))


def _fmt(r) -> str:
    b = r.buckets()
    shares = " ".join(f"{k}={v:5.1%}" for k, v in b.items())
    return f"total={r.total_s*1e6:10.1f}us  {shares}  " \
           f"tlb_miss={r.tlb_misses}  gops={r.gops:.1f}"


def _decode_plan(dtype: str) -> "plan_ir.StreamPlan":
    """A decode step over a LIVE paged KV cache: admit a few sequences,
    append/retire to churn the free list, then plan from the real page
    tables."""
    import jax.numpy as jnp
    from repro.serving.kv_cache import PagedCacheConfig, PagedKVCache
    sh = DECODE_SHAPE
    np_dt = plan_ir.np_dtype_for(dtype)
    cfg = PagedCacheConfig(
        n_pages=sh["n_pages"], page_tokens=sh["page_tokens"],
        n_kv_heads=sh["n_kv_heads"], head_dim=sh["head_dim"],
        max_pages_per_seq=sh["max_pages_per_seq"], dtype=np_dt)
    cache = PagedKVCache(cfg, max_seqs=len(sh["prompt_lens"]))
    kv = lambda t: jnp.zeros((t, cfg.n_kv_heads, cfg.head_dim), np_dt)
    for slot, ln in enumerate(sh["prompt_lens"]):
        if not cache.alloc_seq(slot, ln):
            raise RuntimeError(f"KV pool too small for slot {slot}")
        cache.write_prompt(slot, kv(ln), kv(ln))
    cache.free_seq(1)                       # retire + readmit: churn
    if not cache.alloc_seq(1, sh["prompt_lens"][1] + 3):
        raise RuntimeError("KV pool too small for readmitted slot 1")
    cache.write_prompt(1, kv(sh["prompt_lens"][1] + 3),
                       kv(sh["prompt_lens"][1] + 3))
    return cache.decode_step_plan(list(range(len(sh["prompt_lens"]))))


# workload -> (exact layer-plan builder, schedule builder, name prefix)
_SYNTH = {
    "moe": (lambda dtype, i, x: plan_ir.moe_layer_plan(
                dtype=dtype, layer=i, x=x, **MOE_SHAPE),
            lambda dtype, layers, stride: plan_ir.moe_schedule(
                dtype=dtype, n_layers=layers, sample_stride=stride,
                **MOE_SHAPE),
            "M"),
    "ssm": (lambda dtype, i, x: plan_ir.ssm_layer_plan(
                dtype=dtype, layer=i, x=x, **SSM_SHAPE),
            lambda dtype, layers, stride: plan_ir.ssm_schedule(
                dtype=dtype, n_layers=layers, sample_stride=stride,
                **SSM_SHAPE),
            "S"),
}


def _serve_trace():
    """A short but real recorded serving trace: run the reduced-model
    continuous-batching engine with ``record_plans=True`` (prefill plan
    per admission + multi-layer GQA decode plan per step) and return
    ``engine.trace``.  KV plans are fp16 regardless of ``--dtype`` (the
    engine's cache dtype decides)."""
    import jax
    from repro.configs import get_reduced
    from repro.models.model import Model
    from repro.serving.engine import Request, ServingEngine
    cfg = get_reduced("qwen2_0_5b")
    params = Model(cfg, remat="none").init(jax.random.PRNGKey(0))
    import numpy as np
    rng = np.random.default_rng(0)
    eng = ServingEngine(cfg, params, slots=2, max_seq=48,
                        record_plans=True)
    for i in range(5):
        eng.submit(Request(
            uid=i, prompt=rng.integers(1, 250, size=8).astype(np.int32),
            max_new_tokens=6))
    eng.run_until_drained(max_steps=200)
    return eng.trace


def build_workload(workload: str, dtype: str, layers: int,
                   sample_stride: int, exact: bool):
    """Returns (plan-or-schedule, events_replayed, events_total).
    ``workload`` is a workload class or a PAPER_MODELS name."""
    if workload in WORKLOAD_MODELS or workload in PAPER_MODELS:
        name = WORKLOAD_MODELS.get(workload, workload)
        layers = layers or PAPER_MODELS[name].n_layers
        if exact:
            plan = model_stream_plan(name, layers, dtype)
            return plan, len(plan.events), plan.n_exact_events
        sched = model_stream_schedule(name, layers, dtype, sample_stride)
        return sched, sched.sampled_events, sched.exact_events
    if workload in _SYNTH:
        mk_layer, mk_sched, prefix = _SYNTH[workload]
        layers = layers or 2
        if exact:
            plan = plan_ir.concat(
                [mk_layer(dtype, i,
                          "x" if i == 0 else f"{prefix}{i-1}.out")
                 for i in range(layers)], name=f"{workload}_x{layers}")
            return plan, len(plan.events), plan.n_exact_events
        sched = mk_sched(dtype, layers, sample_stride)
        return sched, sched.sampled_events, sched.exact_events
    assert workload == "decode", workload
    plan = _decode_plan(dtype)
    return plan, len(plan.events), plan.n_exact_events


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", choices=sorted(PAPER_MODELS),
                    help="composed transformer forward pass")
    ap.add_argument("--workload", choices=WORKLOADS,
                    help="workload class over the plan layer "
                         "(steady-state sampled unless --exact)")
    ap.add_argument("--layers", type=int, default=None,
                    help="cap the layer stack (default: full model / 2)")
    ap.add_argument("--gemm", type=int, nargs=3, metavar=("M", "N", "K"),
                    help="single Algorithm-1 GEMM instead of a model")
    ap.add_argument("--dtype", default="int8",
                    choices=["int8", "int16", "int32", "fp8", "fp16",
                             "fp32"])
    ap.add_argument("--modes", nargs="+", default=["DM", "DC", "DevMem"],
                    choices=["DM", "DC", "DevMem"])
    ap.add_argument("--sample-stride", type=int, default=1,
                    help="additionally stride the GEMM inner loops of "
                         "the sampled window")
    ap.add_argument("--exact", action="store_true",
                    help="replay the full composed event graph instead "
                         "of the steady-state sample")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "event", "compiled", "both"],
                    help="replayer: compiled array engine vs Python "
                         "event loop ('both' checks parity)")
    ap.add_argument("--devmem-dram", default="HBM2",
                    help="DRAM tech for DevMem mode (paper Fig. 12)")
    args = ap.parse_args(argv)
    if not args.model and not args.gemm and not args.workload:
        ap.error("one of --model / --gemm / --workload is required")
    if args.layers is not None and args.layers < 1:
        ap.error("--layers must be >= 1")
    if args.sample_stride < 1:
        ap.error("--sample-stride must be >= 1")

    plan = None
    label = None
    serve_trace = None
    foot_override = None
    if args.workload == "serve":
        # a recorded engine trace: replayed batched as a repeat-1
        # schedule (parity machinery below applies unchanged), then
        # folded back onto requests per mode.  The SMMU footprint is
        # the UNION of pages the trace touches (steps re-stream the
        # same resident pool), matching replay_trace — not the
        # schedule default of summing per-record footprints.
        from repro.serving.sim_report import trace_schedule
        serve_trace = _serve_trace()
        plan = trace_schedule(serve_trace)
        foot_override = len(plan.compile().page_keys)
        replayed = total_ev = plan.sampled_events
        args.dtype = "fp16"               # KV/weight plans are fp16
        label = f"serve_trace({len(serve_trace)} records)"
    elif args.model or args.workload:
        wl = args.model or args.workload
        plan, replayed, total_ev = build_workload(
            wl, args.dtype, args.layers or 0, args.sample_stride,
            args.exact)
        label = f"{args.model} x{args.layers or PAPER_MODELS[args.model].n_layers}" \
            if args.model else getattr(plan, "name", wl)
    if plan is not None:
        speedup = total_ev / max(replayed, 1)
        kind = "exact" if args.exact else "sampled"
        print(f"{label} ({kind}): events replayed={replayed} "
              f"total={total_ev} ({speedup:.1f}x fewer)")

    for mode in args.modes:
        dram = DRAM(args.devmem_dram) if mode == "DevMem" else None
        cfg = default_system(mode, dtype=args.dtype, dram=dram)
        engines = ["compiled", "event"] if args.engine == "both" \
            else [args.engine]
        results = {}
        gname = None
        if args.gemm:
            m, n, k = args.gemm
            gname = f"gemm{m}x{n}x{k}"
            for eng in engines:
                t0 = time.perf_counter()
                results[eng] = simulate_gemm(
                    cfg, m, n, k, engine=None if eng == "auto" else eng)
                wall = time.perf_counter() - t0
                print(f"{gname} {args.dtype} {mode:7s} "
                      f"{_fmt(results[eng])}  "
                      f"[{eng}: wall={wall*1e3:.1f}ms]")
        else:
            for eng in engines:
                t0 = time.perf_counter()
                results[eng] = replay(cfg, plan, engine=eng,
                                      footprint_pages=foot_override)
                wall = time.perf_counter() - t0
                print(f"{label} {args.dtype} {mode:7s} "
                      f"{_fmt(results[eng])}  "
                      f"[{eng}: wall={wall*1e3:.1f}ms "
                      f"{replayed/max(wall, 1e-9):,.0f} ev/s]")
        if args.engine == "both":
            a, b = results["compiled"], results["event"]
            import dataclasses as _dc
            for f in _dc.fields(a):
                va, vb = getattr(a, f.name), getattr(b, f.name)
                if not (va == vb or (isinstance(va, float) and
                                     abs(va - vb) <= 1e-9 *
                                     max(abs(vb), 1e-30))):
                    raise SystemExit(
                        f"engine parity violated: {f.name} "
                        f"compiled={va!r} event={vb!r}")
            print(f"{gname or label} {mode}: compiled == event "
                  f"(all GemmResult fields, rtol<=1e-9)")
        if serve_trace is not None:
            from repro.serving.sim_report import simulate_serving_trace
            rep = simulate_serving_trace(cfg, serve_trace, sched=plan)
            pct = rep.percentiles()
            print(f"serve {mode:7s} simulated latency: " + "  ".join(
                f"{k}={pct[k]:.1f}" for k in
                ("ttft_p50_us", "ttft_p95_us", "ttft_p99_us",
                 "tpot_p50_us", "tpot_p95_us", "tpot_p99_us")) +
                f"  requests={pct['requests']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
