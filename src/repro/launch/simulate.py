"""Offline what-if simulation CLI over the StreamPlan IR.

    PYTHONPATH=src python -m repro.launch.simulate --model bert-medium \
        --modes DM DC DevMem --layers 2
    PYTHONPATH=src python -m repro.launch.simulate --gemm 512 512 512

Builds the requested plan (a single Algorithm-1 GEMM, or a composed
N-layer transformer forward pass) and replays it against the accesys
component models in each memory mode, printing end-to-end latency and
the Fig.-2 bucket shares.
"""
from __future__ import annotations

import argparse

from repro.accesys.components import DRAM
from repro.accesys.pipeline import simulate_gemm
from repro.accesys.system import (default_system, model_stream_plan,
                                  run_transformer_composed)
from repro.configs.paper_models import PAPER_MODELS


def _fmt(r) -> str:
    b = r.buckets()
    shares = " ".join(f"{k}={v:5.1%}" for k, v in b.items())
    return f"total={r.total_s*1e6:10.1f}us  {shares}  " \
           f"tlb_miss={r.tlb_misses}  gops={r.gops:.1f}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", choices=sorted(PAPER_MODELS),
                    help="composed transformer forward pass")
    ap.add_argument("--layers", type=int, default=None,
                    help="cap the layer stack (default: full model)")
    ap.add_argument("--gemm", type=int, nargs=3, metavar=("M", "N", "K"),
                    help="single Algorithm-1 GEMM instead of a model")
    ap.add_argument("--dtype", default="int8",
                    choices=["int8", "int16", "int32", "fp8", "fp16",
                             "fp32"])
    ap.add_argument("--modes", nargs="+", default=["DM", "DC", "DevMem"],
                    choices=["DM", "DC", "DevMem"])
    ap.add_argument("--devmem-dram", default="HBM2",
                    help="DRAM tech for DevMem mode (paper Fig. 12)")
    args = ap.parse_args(argv)
    if not args.model and not args.gemm:
        ap.error("one of --model / --gemm is required")
    if args.layers is not None and args.layers < 1:
        ap.error("--layers must be >= 1")

    for mode in args.modes:
        dram = DRAM(args.devmem_dram) if mode == "DevMem" else None
        cfg = default_system(mode, dtype=args.dtype, dram=dram)
        if args.gemm:
            m, n, k = args.gemm
            r = simulate_gemm(cfg, m, n, k)
            print(f"gemm{m}x{n}x{k} {args.dtype} {mode:7s} {_fmt(r)}")
        else:
            r = run_transformer_composed(cfg, args.model, args.layers)
            nl = args.layers or PAPER_MODELS[args.model].n_layers
            print(f"{args.model} x{nl} {args.dtype} {mode:7s} {_fmt(r)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
