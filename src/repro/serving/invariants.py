"""Conservation invariants for the serving engine under pressure.

Preemption and KV swap add state transitions (evict, re-queue,
resume) that are easy to get subtly wrong: a leaked page here, a
re-decoded token there, and the priced trace silently stops meaning
what the report claims.  This module is the executable contract —
``ServingEngine(... ).open_loop_records(debug_invariants=True)`` runs
``check_step`` every iteration and ``check_drained`` at the end, and
the fault-injection suite (``tests/test_preemption_swap.py``) runs
``check_trace_conservation`` over whole recorded traces:

* **Pool accounting** (every step): the free list, active slots' own
  pages, reserved prefix pages and fault-seized pages partition the
  pool — no double-frees, no leaks (``PageTable.validate``).
* **Slot/queue coherence** (every step): prefilling slots are a
  subset of occupied slots; a request is never simultaneously queued
  and running; swap state only exists for requests NOT in a slot.
* **Post-drain emptiness**: ``pages_in_use`` equals exactly the
  reserved prefix + seized pages (0 with neither), no swap state
  survives, every accepted request finished.
* **Token conservation** (trace-level): across any number of
  preemptions, every request's prefill chunks cover each prompt token
  EXACTLY once and it decodes EXACTLY its expected token count — work
  is moved by preemption, never lost or repeated.
"""
from __future__ import annotations


class InvariantViolation(AssertionError):
    """A serving-engine conservation invariant failed."""


def _fail(msg: str):
    raise InvariantViolation(msg)


def check_step(eng) -> None:
    """Per-iteration engine coherence + pool accounting."""
    t = eng._table
    try:
        t.validate()
    except AssertionError as e:
        _fail(f"pool accounting: {e}")
    occupied = {s for s, r in enumerate(eng.slot_req) if r is not None}
    if not set(eng._prefilling) <= occupied:
        _fail(f"prefilling slots {sorted(eng._prefilling)} not a "
              f"subset of occupied {sorted(occupied)}")
    running = {eng.slot_req[s].uid for s in occupied}
    queued = [r.uid for r in eng.queue]
    if len(queued) != len(set(queued)):
        _fail(f"duplicate uids in queue: {queued}")
    both = running & set(queued)
    if both:
        _fail(f"uids both running and queued: {sorted(both)}")
    swapped_running = set(eng._swapped) & running
    if swapped_running:
        _fail(f"uids running with live swap state: "
              f"{sorted(swapped_running)}")
    for s in occupied:
        if s not in eng._prefilling and int(eng._lens[s]) < 1:
            _fail(f"decoding slot {s} has no cached tokens")


def check_drained(eng) -> None:
    """Nothing survives a drained run but the permanent reservations."""
    if eng.queue or any(r is not None for r in eng.slot_req):
        _fail("check_drained on an engine with live work")
    t = eng._table
    expect = len(t._prefix) + len(t._seized)
    if t.pages_in_use != expect:
        _fail(f"post-drain pages_in_use={t.pages_in_use}, expected "
              f"{expect} (prefix {len(t._prefix)} + seized "
              f"{len(t._seized)}) — leaked "
              f"{t.pages_in_use - expect} pages")
    if eng._swapped:
        _fail(f"post-drain swap state survives for uids "
              f"{sorted(eng._swapped)}")
    if eng._prefilling:
        _fail(f"post-drain prefill state survives for slots "
              f"{sorted(eng._prefilling)}")
    check_step(eng)


def expected_decodes(req, prefix_tokens: int, max_seq: int) -> int:
    """Decode steps a finished request must have consumed: its
    max_new_tokens minus the prefill-emitted first token, clipped by
    the ``max_seq - 1`` retirement the engine enforces."""
    full = prefix_tokens + len(req.prompt)
    if full >= max_seq - 1:
        return 0                       # retired at end of prefill
    return max(0, min(req.max_new_tokens - 1, (max_seq - 1) - full))


def check_trace_conservation(trace, requests, *, prefix_tokens: int = 0,
                             prefix_cached: bool = False,
                             max_seq: int = 10**9,
                             unfinished=()) -> dict:
    """Fold a recorded trace and verify per-request work conservation
    across preemptions.  Returns the per-uid tallies for further
    assertions: ``{"prefill_tokens", "decodes", "swap_outs",
    "swap_ins", "swap_out_pages", "swap_in_pages"}`` keyed by uid.

    For every FINISHED request: prefill chunk ``n_tokens`` must sum to
    its prompt (+ the shared prefix when it is NOT cached) — each
    token prefilled exactly once no matter how many times the request
    was evicted mid-prefill — and decode records containing its uid
    must number ``expected_decodes`` exactly — each token decoded
    exactly once.  Swap records must pair up: every ``swap_out`` is
    matched by a later ``swap_in`` of the SAME page count (unfinished
    requests may hold one trailing unmatched ``swap_out``)."""
    pf: dict = {}
    dec: dict = {}
    so: dict = {}
    si: dict = {}
    so_pages: dict = {}
    si_pages: dict = {}
    pending_swap: dict = {}
    for rec in trace:
        if rec.kind == "prefill":
            uid = rec.uids[0] if rec.uids else -1
            if uid < 0:
                continue
            pf[uid] = pf.get(uid, 0) + rec.n_tokens
        elif rec.kind == "decode":
            for uid in rec.uids:
                dec[uid] = dec.get(uid, 0) + 1
        elif rec.kind == "swap_out":
            uid = rec.uids[0]
            so[uid] = so.get(uid, 0) + 1
            n = len(rec.plan.events) // _streams_per_page(rec.plan)
            so_pages[uid] = so_pages.get(uid, 0) + n
            if uid in pending_swap:
                _fail(f"uid {uid}: swap_out while already swapped out")
            pending_swap[uid] = n
        elif rec.kind == "swap_in":
            uid = rec.uids[0]
            si[uid] = si.get(uid, 0) + 1
            n = len(rec.plan.events) // _streams_per_page(rec.plan)
            si_pages[uid] = si_pages.get(uid, 0) + n
            if pending_swap.pop(uid, None) != n:
                _fail(f"uid {uid}: swap_in of {n} pages does not "
                      "match its pending swap_out")
        else:
            _fail(f"unknown record kind {rec.kind!r}")
    live = set(unfinished)
    for req in requests:
        uid = req.uid
        if uid in live:
            continue
        want_pf = len(req.prompt) + \
            (0 if prefix_cached else prefix_tokens)
        if pf.get(uid, 0) != want_pf:
            _fail(f"uid {uid}: prefilled {pf.get(uid, 0)} tokens, "
                  f"expected {want_pf} — preemption lost or repeated "
                  "prefill work")
        want_dec = expected_decodes(req, prefix_tokens, max_seq)
        if dec.get(uid, 0) != want_dec:
            _fail(f"uid {uid}: {dec.get(uid, 0)} decode steps, "
                  f"expected {want_dec} — a token was decoded "
                  "zero or twice across preemptions")
        if uid in pending_swap:
            _fail(f"uid {uid}: finished with an unmatched swap_out")
    return {uid: {"prefill_tokens": pf.get(uid, 0),
                  "decodes": dec.get(uid, 0),
                  "swap_outs": so.get(uid, 0),
                  "swap_ins": si.get(uid, 0),
                  "swap_out_pages": so_pages.get(uid, 0),
                  "swap_in_pages": si_pages.get(uid, 0)}
            for uid in set(pf) | set(dec) | set(so) | set(si)}


def _streams_per_page(plan) -> int:
    """A swap plan holds n_layers * 2 (K and V) events per page."""
    return max(1, len(plan.tensors))
