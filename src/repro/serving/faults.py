"""Deterministic overload / fault injection for the serving engine.

Three seeded pressure generators drive the property tests (and the CI
smoke job) that prove the engine degrades gracefully instead of
leaking, livelocking, or corrupting state:

* ``storm_arrivals`` — burst storms: whole cohorts of requests
  arriving at the same instant, separated by quiet gaps.  Far
  harsher than the ``bursty`` arrival process — the queue must grow
  and drain, never wedge.
* ``adversarial_requests`` — long-prompt mixes: a seeded blend of
  tiny requests and near-``max_seq`` monsters whose worst-case page
  reservations collide, maximizing deferrals and preemptions.
* ``PoolShrinkFault`` — mid-run pool shrinkage: a co-tenant seizes
  free KV pages at a scheduled step and returns them later, breaking
  the conservative-admission reservation out from under admitted
  requests (the only path that can make decode-time page growth
  fail — exercising the swap-out degradation instead of the
  ``RuntimeError``).

Everything is seeded and replayable: same seed => same storm, same
seizure schedule, same trace.  ``python -m repro.serving.faults
--seeds 0 1 2`` runs the smoke matrix with invariants on (the ci.yml
fault-injection job).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def storm_arrivals(n: int, qps: float, seed: int = 0, *,
                   storm_frac: float = 0.5,
                   storms: int = 4) -> np.ndarray:
    """``n`` sorted arrival times at mean rate ``qps`` where
    ``storm_frac`` of the requests land in ``storms`` zero-width
    spikes (every request in a spike arrives at the SAME instant) and
    the rest trickle as a Poisson stream — the worst realizable burst
    for an admission queue."""
    if not 0.0 <= storm_frac <= 1.0:
        raise ValueError(f"storm_frac must be in [0, 1]: {storm_frac}")
    rng = np.random.default_rng(seed)
    span = n / qps
    n_storm = int(n * storm_frac)
    trickle = np.sort(rng.uniform(0.0, span, size=n - n_storm))
    centers = np.sort(rng.uniform(0.0, span, size=max(storms, 1)))
    per = np.full(max(storms, 1), n_storm // max(storms, 1))
    per[:n_storm - int(per.sum())] += 1
    spikes = np.repeat(centers, per)
    return np.sort(np.concatenate([trickle, spikes]))


def adversarial_requests(n: int, seed: int = 0, *, max_seq: int = 64,
                         prefix_tokens: int = 0,
                         monster_frac: float = 0.25,
                         max_new_lo: int = 1,
                         max_new_hi: int = 8) -> list:
    """Seeded long-prompt mix: ``monster_frac`` of the requests carry
    prompts close to the ``max_seq`` budget (their conservative page
    reservations dominate the pool), the rest are small.  Interleaved
    in arrival order, so monsters repeatedly stall behind and preempt
    the small fry."""
    from repro.serving.engine import Request
    rng = np.random.default_rng(seed)
    budget = max_seq - prefix_tokens - max_new_hi
    if budget < 8:
        raise ValueError(
            f"max_seq={max_seq} leaves a {budget}-token prompt budget "
            "— too tight for an adversarial mix")
    reqs = []
    for i in range(n):
        if rng.random() < monster_frac:
            t = int(rng.integers(max(budget * 3 // 4, 4), budget + 1))
        else:
            t = int(rng.integers(4, max(budget // 4, 5)))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(1, 250, size=t).astype(np.int32),
            max_new_tokens=int(rng.integers(max_new_lo, max_new_hi))))
    return reqs


@dataclasses.dataclass
class PoolShrinkFault:
    """Seize ``n_pages`` free KV pages at engine step ``at_step`` and
    restore them at ``restore_step`` (never, if None) — deterministic
    mid-run memory loss.  Implements the ``on_step`` hook
    ``open_loop_records(faults=...)`` calls once per iteration."""
    at_step: int
    n_pages: int
    restore_step: int | None = None
    seized: int = 0
    restored: bool = False

    def on_step(self, eng, step: int) -> None:
        if step == self.at_step and not self.seized:
            self.seized = eng._table.seize_pages(self.n_pages)
        if self.restore_step is not None and step >= self.restore_step \
                and self.seized and not self.restored:
            eng._table.restore_pages()
            self.restored = True


@dataclasses.dataclass
class FaultSchedule:
    """Compose several faults into one ``on_step`` hook."""
    faults: list

    def on_step(self, eng, step: int) -> None:
        for f in self.faults:
            f.on_step(eng, step)


def overload_run(seed: int, *, n_requests: int = 60, slots: int = 3,
                 max_seq: int = 64, kv_page_tokens: int = 8,
                 preempt: str = "lifo", qps: float = 400.0,
                 pool_frac: float = 0.55, shrink_frac: float = 0.25,
                 max_steps: int = 50_000, arch: str = "qwen2_0_5b"):
    """One seeded overload scenario: storm arrivals x adversarial
    prompts x a mid-run pool shrink, on a pool deliberately too small
    for the worst case, with invariants checked EVERY step.  Returns
    ``(engine, requests)`` — the drained engine retains the trace for
    further assertions."""
    from repro.configs import get_reduced
    from repro.serving.engine import ServingEngine

    pages_per_seq = -(-max_seq // kv_page_tokens)
    pool = max(pages_per_seq + 1,
               int(slots * pages_per_seq * pool_frac))
    eng = ServingEngine(get_reduced(arch), plan_only=True, slots=slots,
                        max_seq=max_seq, kv_page_tokens=kv_page_tokens,
                        kv_pool_pages=pool)
    reqs = adversarial_requests(n_requests, seed, max_seq=max_seq)
    arr = storm_arrivals(n_requests, qps, seed)
    fault = PoolShrinkFault(at_step=10,
                            n_pages=max(1, int(pool * shrink_frac)),
                            restore_step=200 + 10 * seed)
    eng.run_open_loop(reqs, arr, prefill_chunk_tokens=kv_page_tokens,
                      est_step_s=1e-4, est_prefill_s_per_token=1e-5,
                      max_steps=max_steps, preempt=preempt,
                      faults=fault, debug_invariants=True)
    return eng, reqs


def main(argv=None) -> int:
    """Smoke the fault matrix: for each seed, run the overload
    scenario under both preemption policies with per-step invariants
    on, then check trace-level token conservation.  Exits non-zero on
    any violation — the ci.yml fault-injection job."""
    import argparse

    from repro.serving import invariants

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1, 2])
    ap.add_argument("--requests", type=int, default=60)
    args = ap.parse_args(argv)
    for seed in args.seeds:
        for policy in ("lifo", "longest"):
            eng, reqs = overload_run(seed, n_requests=args.requests,
                                     preempt=policy)
            if not eng.stats.drained:
                print(f"FAIL seed={seed} {policy}: not drained")
                return 1
            invariants.check_drained(eng)
            invariants.check_trace_conservation(
                eng.trace, reqs, max_seq=eng.max_seq)
            s = eng.stats
            print(f"seed={seed} {policy:7s}: {eng.n_finished} finished"
                  f", {s.preemptions} preemptions, {s.swapped_pages} "
                  f"pages swapped, {eng.deferred_admissions} deferrals"
                  f", {s.decode_steps} decode steps — invariants OK")
    print("fault-injection smoke OK")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
