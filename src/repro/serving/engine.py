"""Continuous-batching serving engine.

Slot-based continuous batching over the Model API: B decode slots run in
a single jitted decode step (per-slot cache lengths — mixed-progress
sequences in one batch); finished slots are recycled and newly admitted
requests are prefetched (prefilled) into their slot between decode
steps. This is the end-to-end driver the paper's inference setting
dictates (serve batched requests, GEMMs streamed, host orchestrates).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (T,) int32
    max_new_tokens: int = 16
    submitted_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    output: list = dataclasses.field(default_factory=list)
    # index into the engine's plan trace at submission time — the
    # simulated-arrival anchor for TTFT attribution (0 == trace start)
    arrival_event: int = 0


@dataclasses.dataclass
class PlanRecord:
    """One priced event of a recorded serving trace: a prompt prefill
    (one per admission, or one per chunk under chunked prefill) or a
    batched multi-layer decode step, tagged with the engine step index
    and the slot -> request-uid mapping so simulated time folds back
    onto individual requests.  ``uids == (-1,)`` marks the shared
    prefix-cache prefill, which belongs to no request.  ``swap_out`` /
    ``swap_in`` records carry a preempted request's KV traffic to and
    from host (``n_tokens`` = cached tokens at the swap point)."""
    kind: str             # "prefill" | "decode" | "swap_out" | "swap_in"
    step_idx: int                   # engine decode-step counter
    slots: tuple                    # slot ids this plan covers
    uids: tuple                     # request uid per slot
    plan: object                    # core.plan.StreamPlan
    arrival_event: int = 0          # prefill: requester's arrival index
    n_tokens: int = 0               # prefill: tokens this chunk covers


def arrival_times(kind: str, n: int, qps: float, seed: int = 0, *,
                  burst_factor: float = 4.0, burst_len: float = 16.0,
                  period_s: float = 60.0, depth: float = 0.8
                  ) -> np.ndarray:
    """Seeded open-loop arrival process: ``n`` absolute arrival times
    at a mean offered rate of ``qps`` requests/second.  Deterministic
    in ``(kind, n, qps, seed, shape params)``.

    - ``poisson``: i.i.d. exponential gaps (memoryless).
    - ``bursty``: exponential gaps scaled by alternating quiet/hot
      runs of geometric length ``burst_len`` — hot gaps shrink by
      ``burst_factor``, quiet gaps stretch to keep the mean rate.
    - ``diurnal``: gaps modulated by ``1 + depth*sin(2*pi*t/period_s)``
      — a load wave (period compressed to seconds so a 10k-request
      trace spans several cycles)."""
    if qps <= 0:
        raise ValueError(f"qps must be > 0, got {qps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, size=n)
    if kind == "poisson":
        pass
    elif kind == "bursty":
        lo = 1.0 / burst_factor
        hi = 2.0 - lo                 # quiet stretch preserving mean
        scale = np.empty(n)
        i, hot = 0, False
        while i < n:
            run = int(rng.geometric(1.0 / burst_len))
            scale[i:i + run] = lo if hot else hi
            i += run
            hot = not hot
        gaps *= scale
    elif kind == "diurnal":
        t = np.cumsum(gaps)
        rate = np.maximum(
            1.0 + depth * np.sin(2.0 * np.pi * t / period_s), 1e-3)
        gaps = gaps / rate
    else:
        raise ValueError(
            f"unknown arrival process {kind!r} — expected poisson, "
            "bursty, or diurnal")
    return np.cumsum(gaps)


@dataclasses.dataclass
class EngineStats:
    decode_steps: int = 0
    prefills: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0
    preemptions: int = 0
    swapped_pages: int = 0          # device pages moved host-ward
    # False when the run hit ``max_steps`` with work still queued or
    # in flight — a truncated sim must never masquerade as a complete
    # one (pair with ``unfinished_uids()`` to censor the report)
    drained: bool = True

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / max(self.wall_s, 1e-9)


class ServingEngine:
    """``record_plans=True`` shadows the dense decode cache with a
    driver-side ``PageTable`` (no device pools) and records a
    request-centric plan trace (``trace``): one ``prefill_plan`` per
    admission and one multi-layer GQA ``decode_step_plan`` per engine
    step, each tagged with ``(step_idx, slot -> uid)`` — page ids and
    valid lengths track the REAL batch composition (admissions,
    retirements, page churn) over the run, so one batched accesys
    replay prices the whole trace and folds simulated time back onto
    individual requests (``serving.sim_report``).

    Admission against the shadow pool is CONSERVATIVE: a request is
    admitted only if the free list can hold its maximum length
    (prompt + max_new_tokens, capped at ``max_seq``) on top of the
    worst-case remaining growth of every already-admitted request —
    so decode-time page growth can never fail and the engine never
    crashes mid-run on pool pressure.  Otherwise the request is
    DEFERRED at the head of the queue (FIFO order preserved) until
    retirements drain enough pages.  A request whose maximum length
    cannot fit even an empty pool raises ``ValueError`` at admission
    time (a configuration error deferral would turn into a livelock).
    ``kv_pool_pages`` caps the pool (default: every slot can grow to
    ``max_seq``, so only explicit caps ever defer)."""

    def __init__(self, cfg: ModelConfig, params=None, *, slots: int = 4,
                 max_seq: int = 256, eos_token: Optional[int] = None,
                 record_plans: bool = False, kv_page_tokens: int = 8,
                 kv_dtype: str = "float16",
                 kv_pool_pages: Optional[int] = None,
                 plan_only: bool = False, prefix_tokens: int = 0,
                 prefix_caching: bool = False, templated: bool = True):
        """``plan_only=True`` skips model/cache/jit construction
        entirely (``params`` unused) and drives the shadow PageTable
        alone — the open-loop capacity-planning mode, where generated
        token VALUES never matter and only the plan trace does.
        ``prefix_tokens`` prepends a shared system prompt to every
        request; with ``prefix_caching=True`` its pages are interned
        once per trace (``reserve_prefix``) and every request maps
        them read-only, otherwise each request re-prefills them.
        ``templated`` (default) emits template-instanced plan records
        — each decode/prefill/swap record is a compiled-skeleton
        page-id relabel instead of a fresh event graph, pricing
        bitwise-identically (``templated=False`` restores event-built
        records; ``.events`` on a templated record rebuilds them on
        demand)."""
        self.cfg = cfg
        self.plan_only = plan_only
        record_plans = record_plans or plan_only
        self.model = None if plan_only else Model(cfg, remat="none")
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos = eos_token
        self.cache = None if plan_only else \
            self.model.init_cache(slots, max_seq)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()
        self._next_tokens = np.zeros((slots,), np.int32)
        self._remaining = np.zeros((slots,), np.int32)
        self._lens = np.zeros((slots,), np.int32)   # plan-only mirror
        self.trace: list[PlanRecord] = []
        self.n_records = 0          # records emitted (trace + sinks)
        self.n_finished = 0
        self.deferred_admissions = 0
        self.sim_t = 0.0            # open-loop simulated clock
        self._sink: Optional[list] = None
        self._prefilling: dict = {}  # slot -> [req, done, total]
        # ---- preemption / swap state (open-loop path)
        self._preempt_policy = "none"
        self._stall_budget_s = 0.0
        self._debug_invariants = False
        self._defer_since: Optional[float] = None  # head's wait start
        self._swapped: dict = {}    # uid -> (n_pages, tokens, remaining,
        #                             prefill_total | None)
        self._progress: dict = {}   # slot -> tokens since (re)admission
        self._admit_seq: dict = {}  # slot -> admission order counter
        self._admit_counter = 0
        self._prefix_tokens = int(prefix_tokens)
        self._prefix_pages: Optional[np.ndarray] = None
        self._prefix_recorded = False
        self._table = None
        if record_plans:
            from repro.serving.kv_cache import (PagedCacheConfig,
                                                PageTable)
            pages_per_seq = -(-max_seq // kv_page_tokens)
            self._table = PageTable(
                PagedCacheConfig(
                    n_pages=kv_pool_pages or slots * pages_per_seq,
                    page_tokens=kv_page_tokens,
                    n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim,
                    max_pages_per_seq=pages_per_seq,
                    dtype=kv_dtype),
                max_seqs=slots, templated=templated)
        if self._prefix_tokens:
            if self._table is None:
                raise ValueError("prefix_tokens needs record_plans")
            if self._prefix_tokens % kv_page_tokens:
                raise ValueError(
                    f"prefix_tokens={prefix_tokens} must be a multiple "
                    f"of kv_page_tokens={kv_page_tokens} (chunked "
                    "prefill spans are page-aligned)")
            if prefix_caching:
                self._prefix_pages = self._table.reserve_prefix(
                    self._prefix_tokens // kv_page_tokens)

        if not plan_only:
            self._decode = jax.jit(self.model.decode_step)
            self._prefill1 = jax.jit(
                lambda p, b: self.model.prefill(p, b, max_seq))

    @property
    def step_plans(self) -> list:
        """The decode plans of the recorded trace, in step order
        (compatibility view of ``trace``)."""
        return [r.plan for r in self.trace if r.kind == "decode"]

    # ------------------------------------------------------------- API
    def _record(self, rec: PlanRecord) -> int:
        """Append a trace record (to the streaming sink when one is
        installed) and return its global index — the ``arrival_event``
        coordinate space."""
        idx = self.n_records
        self.n_records += 1
        (self.trace if self._sink is None else self._sink).append(rec)
        return idx

    def submit(self, req: Request):
        req.submitted_s = time.perf_counter()
        req.arrival_event = self.n_records
        self.queue.append(req)

    def _max_pages(self, req: Request) -> int:
        """Worst-case pages ``req`` can ever hold (shared prefix pages
        included): its final cache length is min(prefix + prompt +
        max_new_tokens - 1, max_seq - 1), padded to max_seq here for
        safety."""
        max_len = min(self._prefix_tokens + len(req.prompt)
                      + req.max_new_tokens, self.max_seq)
        return -(-max_len // self._table.cfg.page_tokens)

    def _can_admit(self, req: Request) -> bool:
        t = self._table
        need = self._max_pages(req)
        if need > min(t.cfg.n_pages, t.cfg.max_pages_per_seq):
            raise ValueError(
                f"request uid={req.uid} needs {need} KV pages at its "
                f"max length but the pool can never hold that "
                f"(n_pages={t.cfg.n_pages}, "
                f"max_pages_per_seq={t.cfg.max_pages_per_seq})")
        if self._prefix_pages is not None:
            need -= len(self._prefix_pages)   # shared pages are mapped
        # pages admitted slots may still claim while decoding
        growth = sum(self._max_pages(r) - int(t.held[s])
                     for s, r in enumerate(self.slot_req)
                     if r is not None)
        return len(t._free) >= need + growth

    def _admit(self):
        for slot in range(self.slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            if self._table is not None:
                if not self._can_admit(self.queue[0]):
                    # defer admission — the request stays queued until
                    # retirements free enough pages for its max length
                    self.deferred_admissions += 1
                    return
                if not self._table.alloc_seq(
                        slot, len(self.queue[0].prompt)):
                    raise RuntimeError(       # _can_admit guarantees it
                        "shadow KV table out of pages at admission")
            req = self.queue.popleft()
            cache1, logits = self._prefill1(
                self.params, {"tokens": jnp.asarray(req.prompt[None])})
            self.stats.prefills += 1
            # splice the single-seq cache into this slot
            self.cache = jax.tree.map(
                lambda full, one: (
                    full.at[:, slot].set(one[:, 0])
                    if full.ndim >= 2 and full.shape[1] == self.slots
                    else full),
                self.cache, cache1)
            self.cache["len"] = self.cache["len"].at[slot].set(
                cache1["len"][0])
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.first_token_s = time.perf_counter()
            req.output.append(tok)
            self._next_tokens[slot] = tok
            self._remaining[slot] = req.max_new_tokens - 1
            self.slot_req[slot] = req
            self.stats.tokens_out += 1
            if self._table is not None:
                if not self._table.note_tokens(
                        slot, int(self.cache["len"][slot])):
                    raise RuntimeError("shadow KV table out of pages")
                self._record(PlanRecord(
                    "prefill", self.stats.decode_steps, (slot,),
                    (req.uid,),
                    self._table.prefill_plan(
                        slot, len(req.prompt),
                        n_q_heads=self.cfg.n_heads,
                        d_model=self.cfg.d_model, d_ff=self.cfg.d_ff,
                        n_layers=self.cfg.n_layers),
                    arrival_event=req.arrival_event,
                    n_tokens=len(req.prompt)))

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.done_s = time.perf_counter()
        self.slot_req[slot] = None
        self.n_finished += 1
        self.cache["len"] = self.cache["len"].at[slot].set(0)
        if self._table is not None:
            self._table.free_seq(slot)

    def step(self):
        """One engine iteration: admit + one batched decode step."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        if self._table is not None:
            # the step streams each active slot's currently-resident KV
            # pages; the new token's KV lands before the next step
            self._record(PlanRecord(
                "decode", self.stats.decode_steps, tuple(active),
                tuple(self.slot_req[s].uid for s in active),
                self._table.decode_step_plan(
                    active, n_q_heads=self.cfg.n_heads,
                    n_layers=self.cfg.n_layers)))
        toks = jnp.asarray(self._next_tokens)
        self.cache, logits = self._decode(self.params, self.cache, toks)
        self.stats.decode_steps += 1
        if self._table is not None:
            for slot in active:
                if not self._table.note_tokens(
                        slot, int(self.cache["len"][slot])):
                    raise RuntimeError("shadow KV table out of pages")
        nxt = np.asarray(jnp.argmax(
            logits[:, :self.cfg.vocab_size], axis=-1), np.int32)
        for slot in active:
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.output.append(tok)
            self.stats.tokens_out += 1
            self._next_tokens[slot] = tok
            self._remaining[slot] -= 1
            hit_eos = self.eos is not None and tok == self.eos
            if self._remaining[slot] <= 0 or hit_eos or \
                    int(self.cache["len"][slot]) >= self.max_seq - 1:
                self._retire(slot)
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        t0 = time.perf_counter()
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        self.stats.wall_s = time.perf_counter() - t0
        # hitting max_steps with work left is a TRUNCATED run — flag
        # it so partial stats can't pass for a drained queue
        self.stats.drained = not self.queue and \
            all(r is None for r in self.slot_req)
        return self.stats

    # ------------------------------------------ open-loop (plan-only)
    def unfinished_uids(self) -> tuple:
        """Uids the engine has accepted but not retired — running,
        prefilling, or queued.  The censoring set for trace-end
        percentile reports."""
        live = [r.uid for r in self.slot_req if r is not None]
        live += [r.uid for r in self.queue]
        return tuple(live)

    def _pick_victim(self, exclude: Optional[int] = None
                     ) -> Optional[int]:
        """Choose a running slot to preempt, or None.  Only slots that
        have produced at least one token since (re)admission are
        eligible — preempting zero-progress work can livelock two
        large requests into evicting each other forever, while
        requiring progress guarantees every preemption cycle advances
        someone.  ``lifo``: most recently admitted (vLLM's default —
        the newest request has the least sunk cost); ``longest``: most
        own pages held (frees the most memory per eviction)."""
        cands = [s for s, r in enumerate(self.slot_req)
                 if r is not None and s != exclude
                 and self._progress.get(s, 0) > 0]
        if not cands:
            return None
        if self._preempt_policy == "lifo":
            return max(cands, key=lambda s: self._admit_seq[s])
        # "longest": frees the most device pages
        t = self._table
        return max(cands, key=lambda s: (int(t.held[s])
                                         - int(t.shared[s]),
                                         self._admit_seq[s]))

    def _preempt(self, slot: int):
        """Evict ``slot``: record the page-aligned swap-out of its
        written KV (``PageTable.swap_out`` frees the device pages),
        stash its exact progress for resume, and re-queue it directly
        BEHIND the queue head — the head's admission is the point of
        the eviction, and the victim resumes right after it."""
        req = self.slot_req[slot]
        pf = self._prefilling.pop(slot, None)
        if pf is not None:
            tokens, remaining, total = pf[1], -1, pf[2]
        else:
            tokens = int(self._lens[slot])
            remaining, total = int(self._remaining[slot]), None
        plan, n_swap = self._table.swap_out(
            slot, tokens, req.uid, n_layers=self.cfg.n_layers)
        if plan is not None:
            self._record(PlanRecord(
                "swap_out", self.stats.decode_steps, (slot,),
                (req.uid,), plan, arrival_event=req.arrival_event,
                n_tokens=tokens))
        self._swapped[req.uid] = (n_swap, tokens, remaining, total)
        self.slot_req[slot] = None
        self._lens[slot] = 0
        self._progress.pop(slot, None)
        self.stats.preemptions += 1
        self.stats.swapped_pages += n_swap
        if self.queue:
            head = self.queue.popleft()
            self.queue.appendleft(req)
            self.queue.appendleft(head)
        else:
            self.queue.appendleft(req)

    def _resume_or_start(self, slot: int, req: Request):
        """Bind ``req`` to ``slot``: allocate its device pages and
        either enter the chunked-prefill state machine (fresh request,
        or one preempted mid-prefill — it continues at the chunk
        boundary it stopped on) or rejoin the decode batch (preempted
        while decoding), recording the swap-in DMA first."""
        swap = self._swapped.pop(req.uid, None)
        full = self._prefix_tokens + len(req.prompt)
        alloc_tokens = full if swap is None else max(full, swap[1])
        if not self._table.alloc_seq(slot, alloc_tokens,
                                     prefix=self._prefix_pages):
            raise RuntimeError(       # _can_admit guarantees it
                "shadow KV table out of pages at admission")
        self.slot_req[slot] = req
        self._progress[slot] = 0
        self._admit_seq[slot] = self._admit_counter
        self._admit_counter += 1
        if swap is None:
            done = self._prefix_tokens \
                if self._prefix_pages is not None else 0
            self._prefilling[slot] = [req, done, full]
            return
        n_swap, tokens, remaining, total = swap
        if n_swap:
            self._record(PlanRecord(
                "swap_in", self.stats.decode_steps, (slot,),
                (req.uid,),
                self._table.swap_in_plan(n_swap, req.uid,
                                         n_layers=self.cfg.n_layers),
                arrival_event=req.arrival_event, n_tokens=tokens))
        if total is not None:            # was mid-prefill: continue it
            self._prefilling[slot] = [req, tokens, total]
        else:                            # was decoding: rejoin batch
            self._lens[slot] = tokens
            if not self._table.note_tokens(slot, tokens):
                raise RuntimeError(   # alloc_seq covered these pages
                    "swap-in lost pages the allocation reserved")
            self._remaining[slot] = remaining

    def _admit_open(self):
        """Open-loop admission: same conservative capacity check as
        ``_admit``, but the admitted request enters the chunked-prefill
        state machine instead of being prefilled whole — long prompts
        cost several engine steps, not one monolithic stall.

        With a preemption policy armed, a head deferred longer than
        the stall budget evicts victims (``_pick_victim``) until its
        conservative reservation fits — head-of-line blocking degrades
        into swap thrash instead of unbounded queueing."""
        for slot in range(self.slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            if not self._can_admit(self.queue[0]):
                if self._defer_since is None:
                    self._defer_since = self.sim_t
                if self._preempt_policy != "none" and \
                        self.sim_t - self._defer_since >= \
                        self._stall_budget_s:
                    while not self._can_admit(self.queue[0]):
                        victim = self._pick_victim()
                        if victim is None:
                            break
                        self._preempt(victim)
                if not self._can_admit(self.queue[0]):
                    self.deferred_admissions += 1
                    return
            self._defer_since = None
            req = self.queue.popleft()
            self._resume_or_start(slot, req)

    def _retire_open(self, slot: int):
        req = self.slot_req[slot]
        req.done_s = self.sim_t
        self.slot_req[slot] = None
        self.n_finished += 1
        self._lens[slot] = 0
        self._progress.pop(slot, None)
        self._table.free_seq(slot)

    def _prefill_chunk_open(self, slot: int, chunk: int,
                            est_prefill_s_per_token: float) -> float:
        """Advance one slot's chunked prefill by one page-aligned span
        and record its plan; on the last chunk the slot joins the
        decode batch (its ``first token`` is the prefill's)."""
        req, done, total = self._prefilling[slot]
        end = total if total - done <= chunk else done + chunk
        self._record(PlanRecord(
            "prefill", self.stats.decode_steps, (slot,), (req.uid,),
            self._table.prefill_plan(
                slot, total, span=(done, end),
                n_q_heads=self.cfg.n_heads, d_model=self.cfg.d_model,
                d_ff=self.cfg.d_ff, n_layers=self.cfg.n_layers),
            arrival_event=req.arrival_event, n_tokens=end - done))
        self.stats.prefills += 1
        self._progress[slot] = self._progress.get(slot, 0) + end - done
        if end == total:
            del self._prefilling[slot]
            self._lens[slot] = total
            if not self._table.note_tokens(slot, total):
                raise RuntimeError("shadow KV table out of pages")
            self._remaining[slot] = req.max_new_tokens - 1
            self.stats.tokens_out += 1
            if self._remaining[slot] <= 0 or total >= self.max_seq - 1:
                self._retire_open(slot)       # prefill-only request
        else:
            self._prefilling[slot][1] = end
        return est_prefill_s_per_token * (end - done)

    def _step_open(self, chunk: int, est_step_s: float,
                   est_prefill_s_per_token: float) -> float:
        """One open-loop engine iteration: advance every in-flight
        chunked prefill by one span, then one batched decode step over
        the slots not still prefilling.  Returns the simulated time
        this step consumed (the admission clock — reported latencies
        come from the accesys replay, not from these estimates)."""
        dt = 0.0
        for slot in sorted(self._prefilling):
            dt += self._prefill_chunk_open(slot, chunk,
                                           est_prefill_s_per_token)
        active = [s for s, r in enumerate(self.slot_req)
                  if r is not None and s not in self._prefilling]
        if active:
            self._record(PlanRecord(
                "decode", self.stats.decode_steps, tuple(active),
                tuple(self.slot_req[s].uid for s in active),
                self._table.decode_step_plan(
                    active, n_q_heads=self.cfg.n_heads,
                    n_layers=self.cfg.n_layers)))
            self.stats.decode_steps += 1
            dt += est_step_s
            for slot in active:
                self._lens[slot] += 1
                self._progress[slot] = self._progress.get(slot, 0) + 1
                self.stats.tokens_out += 1
                self._remaining[slot] -= 1
                grew = self._table.note_tokens(slot,
                                               int(self._lens[slot]))
                if self._remaining[slot] <= 0 or \
                        int(self._lens[slot]) >= self.max_seq - 1:
                    self._retire_open(slot)
                elif not grew:
                    # mid-decode page growth failed — only reachable
                    # when the pool shrank under us (fault injection
                    # seizing pages breaks the conservative admission
                    # reservation).  Degrade gracefully: swap this
                    # slot out and resume it when pages return,
                    # instead of crashing the run.
                    if self._preempt_policy == "none":
                        raise RuntimeError(
                            "shadow KV table out of pages")
                    self._preempt(slot)
        return dt

    def open_loop_records(self, requests, arrival_s, *,
                          est_step_s: float = 1e-3,
                          est_prefill_s_per_token: float = 1e-4,
                          prefill_chunk_tokens: int = 64,
                          max_steps: int = 1_000_000,
                          preempt: str = "none",
                          stall_budget_s: float = 0.0,
                          faults=None,
                          debug_invariants: bool = False):
        """Generator driving an OPEN-loop run — requests arrive on the
        ``arrival_s`` clock whether or not the engine keeps up (the
        queue grows past saturation) — yielding ``PlanRecord``s as they
        are produced WITHOUT retaining them, so a 10k-request trace can
        stream straight into ``replay_trace_streamed`` in O(chunk)
        memory.  Plan-only: token values are never computed; the
        ``est_*`` rates only advance the simulated admission clock
        (calibrate them from a small priced probe trace — reported
        TTFT/TPOT always come from the replay itself).

        ``preempt`` arms graceful degradation under memory pressure:
        when the queue head has been deferred for more than
        ``stall_budget_s`` of simulated time, a running victim
        (``"lifo"``: newest admission; ``"longest"``: most pages) is
        swapped out to host (priced ``swap_out``/``swap_in`` records)
        and re-queued behind the head.  ``faults`` is an optional
        object whose ``on_step(engine, step_idx)`` is called once per
        iteration (``serving.faults`` injects pool seizures there);
        ``debug_invariants`` runs the ``serving.invariants`` validator
        every step and at drain.

        Deterministic: same requests + arrivals => identical records.
        Use ``run_open_loop`` to retain the trace instead."""
        if not self.plan_only or self._table is None:
            raise ValueError(
                "open_loop_records() needs plan_only=True (the jitted "
                "model path is closed-loop only)")
        if preempt not in ("none", "lifo", "longest"):
            raise ValueError(
                f"unknown preemption policy {preempt!r} — expected "
                "none, lifo, or longest")
        if stall_budget_s < 0:
            raise ValueError(
                f"stall_budget_s must be >= 0: {stall_budget_s}")
        self._preempt_policy = preempt
        self._stall_budget_s = float(stall_budget_s)
        self._debug_invariants = bool(debug_invariants)
        if prefill_chunk_tokens % self._table.cfg.page_tokens:
            raise ValueError(
                f"prefill_chunk_tokens={prefill_chunk_tokens} must be "
                f"page-aligned ({self._table.cfg.page_tokens} tokens)")
        reqs = list(requests)
        arr = np.asarray(arrival_s, float)
        if len(reqs) != arr.size:
            raise ValueError(
                f"{len(reqs)} requests but {arr.size} arrival times")
        buf: list = []
        self._sink = buf
        try:
            if self._prefix_pages is not None and \
                    not self._prefix_recorded:
                self._prefix_recorded = True
                self._record(PlanRecord(
                    "prefill", 0, (), (-1,),
                    self._table.shared_prefill_plan(
                        self._prefix_pages, self._prefix_tokens,
                        n_q_heads=self.cfg.n_heads,
                        d_model=self.cfg.d_model, d_ff=self.cfg.d_ff,
                        n_layers=self.cfg.n_layers),
                    n_tokens=self._prefix_tokens))
            i = 0
            steps = 0
            while i < len(reqs) or self.queue or \
                    any(r is not None for r in self.slot_req):
                if steps >= max_steps:
                    break
                busy = self.queue or \
                    any(r is not None for r in self.slot_req)
                if not busy and arr[i] > self.sim_t:
                    self.sim_t = float(arr[i])    # idle: jump ahead
                while i < len(reqs) and arr[i] <= self.sim_t:
                    req = reqs[i]
                    self.submit(req)
                    req.submitted_s = float(arr[i])
                    i += 1
                if faults is not None:
                    faults.on_step(self, steps)
                self._admit_open()
                dt = self._step_open(prefill_chunk_tokens, est_step_s,
                                     est_prefill_s_per_token)
                self.sim_t += dt
                if dt == 0.0 and self.queue and i < len(reqs) and \
                        all(r is None for r in self.slot_req):
                    # fully stalled on admission (nothing running,
                    # head deferred): open-loop time still passes —
                    # jump to the next arrival so the queue keeps
                    # growing instead of the loop spinning in place
                    self.sim_t = max(self.sim_t, float(arr[i]))
                if self._debug_invariants:
                    from repro.serving import invariants
                    invariants.check_step(self)
                steps += 1
                yield from buf
                buf.clear()
            self.stats.drained = i >= len(reqs) and not self.queue \
                and all(r is None for r in self.slot_req)
            if self._debug_invariants and self.stats.drained:
                from repro.serving import invariants
                invariants.check_drained(self)
        finally:
            self._sink = None

    def run_open_loop(self, requests, arrival_s, **kw) -> EngineStats:
        """Open-loop run retaining the full trace (small-n paths and
        tests; the load sweep streams ``open_loop_records`` instead)."""
        for rec in self.open_loop_records(requests, arrival_s, **kw):
            self.trace.append(rec)
        return self.stats
