"""Continuous-batching serving engine.

Slot-based continuous batching over the Model API: B decode slots run in
a single jitted decode step (per-slot cache lengths — mixed-progress
sequences in one batch); finished slots are recycled and newly admitted
requests are prefetched (prefilled) into their slot between decode
steps. This is the end-to-end driver the paper's inference setting
dictates (serve batched requests, GEMMs streamed, host orchestrates).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (T,) int32
    max_new_tokens: int = 16
    submitted_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    output: list = dataclasses.field(default_factory=list)
    # index into the engine's plan trace at submission time — the
    # simulated-arrival anchor for TTFT attribution (0 == trace start)
    arrival_event: int = 0


@dataclasses.dataclass
class PlanRecord:
    """One priced event of a recorded serving trace: a prompt prefill
    (one per admission) or a batched multi-layer decode step, tagged
    with the engine step index and the slot -> request-uid mapping so
    simulated time folds back onto individual requests."""
    kind: str                       # "prefill" | "decode"
    step_idx: int                   # engine decode-step counter
    slots: tuple                    # slot ids this plan covers
    uids: tuple                     # request uid per slot
    plan: object                    # core.plan.StreamPlan
    arrival_event: int = 0          # prefill: requester's arrival index


@dataclasses.dataclass
class EngineStats:
    decode_steps: int = 0
    prefills: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / max(self.wall_s, 1e-9)


class ServingEngine:
    """``record_plans=True`` shadows the dense decode cache with a
    driver-side ``PageTable`` (no device pools) and records a
    request-centric plan trace (``trace``): one ``prefill_plan`` per
    admission and one multi-layer GQA ``decode_step_plan`` per engine
    step, each tagged with ``(step_idx, slot -> uid)`` — page ids and
    valid lengths track the REAL batch composition (admissions,
    retirements, page churn) over the run, so one batched accesys
    replay prices the whole trace and folds simulated time back onto
    individual requests (``serving.sim_report``).

    Admission against the shadow pool is CONSERVATIVE: a request is
    admitted only if the free list can hold its maximum length
    (prompt + max_new_tokens, capped at ``max_seq``) on top of the
    worst-case remaining growth of every already-admitted request —
    so decode-time page growth can never fail and the engine never
    crashes mid-run on pool pressure.  Otherwise the request is
    DEFERRED at the head of the queue (FIFO order preserved) until
    retirements drain enough pages.  A request whose maximum length
    cannot fit even an empty pool raises ``ValueError`` at admission
    time (a configuration error deferral would turn into a livelock).
    ``kv_pool_pages`` caps the pool (default: every slot can grow to
    ``max_seq``, so only explicit caps ever defer)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, eos_token: Optional[int] = None,
                 record_plans: bool = False, kv_page_tokens: int = 8,
                 kv_dtype: str = "float16",
                 kv_pool_pages: Optional[int] = None):
        self.cfg = cfg
        self.model = Model(cfg, remat="none")
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos = eos_token
        self.cache = self.model.init_cache(slots, max_seq)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()
        self._next_tokens = np.zeros((slots,), np.int32)
        self._remaining = np.zeros((slots,), np.int32)
        self.trace: list[PlanRecord] = []
        self.deferred_admissions = 0
        self._table = None
        if record_plans:
            from repro.serving.kv_cache import (PagedCacheConfig,
                                                PageTable)
            pages_per_seq = -(-max_seq // kv_page_tokens)
            self._table = PageTable(
                PagedCacheConfig(
                    n_pages=kv_pool_pages or slots * pages_per_seq,
                    page_tokens=kv_page_tokens,
                    n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim,
                    max_pages_per_seq=pages_per_seq,
                    dtype=kv_dtype),
                max_seqs=slots)

        self._decode = jax.jit(self.model.decode_step)
        self._prefill1 = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_seq))

    @property
    def step_plans(self) -> list:
        """The decode plans of the recorded trace, in step order
        (compatibility view of ``trace``)."""
        return [r.plan for r in self.trace if r.kind == "decode"]

    # ------------------------------------------------------------- API
    def submit(self, req: Request):
        req.submitted_s = time.perf_counter()
        req.arrival_event = len(self.trace)
        self.queue.append(req)

    def _max_pages(self, req: Request) -> int:
        """Worst-case pages ``req`` can ever hold: its final cache
        length is min(prompt + max_new_tokens - 1, max_seq - 1), padded
        to max_seq here for safety."""
        max_len = min(len(req.prompt) + req.max_new_tokens,
                      self.max_seq)
        return -(-max_len // self._table.cfg.page_tokens)

    def _can_admit(self, req: Request) -> bool:
        t = self._table
        need = self._max_pages(req)
        if need > min(t.cfg.n_pages, t.cfg.max_pages_per_seq):
            raise ValueError(
                f"request uid={req.uid} needs {need} KV pages at its "
                f"max length but the pool can never hold that "
                f"(n_pages={t.cfg.n_pages}, "
                f"max_pages_per_seq={t.cfg.max_pages_per_seq})")
        # pages admitted slots may still claim while decoding
        growth = sum(self._max_pages(r) - int(t.held[s])
                     for s, r in enumerate(self.slot_req)
                     if r is not None)
        return len(t._free) >= need + growth

    def _admit(self):
        for slot in range(self.slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            if self._table is not None:
                if not self._can_admit(self.queue[0]):
                    # defer admission — the request stays queued until
                    # retirements free enough pages for its max length
                    self.deferred_admissions += 1
                    return
                if not self._table.alloc_seq(
                        slot, len(self.queue[0].prompt)):
                    raise RuntimeError(       # _can_admit guarantees it
                        "shadow KV table out of pages at admission")
            req = self.queue.popleft()
            cache1, logits = self._prefill1(
                self.params, {"tokens": jnp.asarray(req.prompt[None])})
            self.stats.prefills += 1
            # splice the single-seq cache into this slot
            self.cache = jax.tree.map(
                lambda full, one: (
                    full.at[:, slot].set(one[:, 0])
                    if full.ndim >= 2 and full.shape[1] == self.slots
                    else full),
                self.cache, cache1)
            self.cache["len"] = self.cache["len"].at[slot].set(
                cache1["len"][0])
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.first_token_s = time.perf_counter()
            req.output.append(tok)
            self._next_tokens[slot] = tok
            self._remaining[slot] = req.max_new_tokens - 1
            self.slot_req[slot] = req
            self.stats.tokens_out += 1
            if self._table is not None:
                if not self._table.note_tokens(
                        slot, int(self.cache["len"][slot])):
                    raise RuntimeError("shadow KV table out of pages")
                self.trace.append(PlanRecord(
                    "prefill", self.stats.decode_steps, (slot,),
                    (req.uid,),
                    self._table.prefill_plan(
                        slot, len(req.prompt),
                        n_q_heads=self.cfg.n_heads,
                        d_model=self.cfg.d_model, d_ff=self.cfg.d_ff,
                        n_layers=self.cfg.n_layers),
                    arrival_event=req.arrival_event))

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.done_s = time.perf_counter()
        self.slot_req[slot] = None
        self.cache["len"] = self.cache["len"].at[slot].set(0)
        if self._table is not None:
            self._table.free_seq(slot)

    def step(self):
        """One engine iteration: admit + one batched decode step."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        if self._table is not None:
            # the step streams each active slot's currently-resident KV
            # pages; the new token's KV lands before the next step
            self.trace.append(PlanRecord(
                "decode", self.stats.decode_steps, tuple(active),
                tuple(self.slot_req[s].uid for s in active),
                self._table.decode_step_plan(
                    active, n_q_heads=self.cfg.n_heads,
                    n_layers=self.cfg.n_layers)))
        toks = jnp.asarray(self._next_tokens)
        self.cache, logits = self._decode(self.params, self.cache, toks)
        self.stats.decode_steps += 1
        if self._table is not None:
            for slot in active:
                if not self._table.note_tokens(
                        slot, int(self.cache["len"][slot])):
                    raise RuntimeError("shadow KV table out of pages")
        nxt = np.asarray(jnp.argmax(
            logits[:, :self.cfg.vocab_size], axis=-1), np.int32)
        for slot in active:
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.output.append(tok)
            self.stats.tokens_out += 1
            self._next_tokens[slot] = tok
            self._remaining[slot] -= 1
            hit_eos = self.eos is not None and tok == self.eos
            if self._remaining[slot] <= 0 or hit_eos or \
                    int(self.cache["len"][slot]) >= self.max_seq - 1:
                self._retire(slot)
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        t0 = time.perf_counter()
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        self.stats.wall_s = time.perf_counter() - t0
        return self.stats
