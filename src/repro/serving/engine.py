"""Continuous-batching serving engine.

Slot-based continuous batching over the Model API: B decode slots run in
a single jitted decode step (per-slot cache lengths — mixed-progress
sequences in one batch); finished slots are recycled and newly admitted
requests are prefetched (prefilled) into their slot between decode
steps. This is the end-to-end driver the paper's inference setting
dictates (serve batched requests, GEMMs streamed, host orchestrates).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (T,) int32
    max_new_tokens: int = 16
    submitted_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    output: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class EngineStats:
    decode_steps: int = 0
    prefills: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / max(self.wall_s, 1e-9)


class ServingEngine:
    """``record_plans=True`` shadows the dense decode cache with a
    driver-side ``PageTable`` (no device pools) and records one
    ``decode_step_plan`` per engine step — page ids and valid lengths
    track the REAL batch composition (admissions, retirements, page
    churn) over the run, so the accesys replayer can price a whole
    serving trace after the fact (``step_plans``)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, eos_token: Optional[int] = None,
                 record_plans: bool = False, kv_page_tokens: int = 8,
                 kv_dtype: str = "float16"):
        self.cfg = cfg
        self.model = Model(cfg, remat="none")
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos = eos_token
        self.cache = self.model.init_cache(slots, max_seq)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.queue: deque[Request] = deque()
        self.stats = EngineStats()
        self._next_tokens = np.zeros((slots,), np.int32)
        self._remaining = np.zeros((slots,), np.int32)
        self.step_plans: list = []
        self._table = None
        if record_plans:
            from repro.serving.kv_cache import (PagedCacheConfig,
                                                PageTable)
            pages_per_seq = -(-max_seq // kv_page_tokens)
            self._table = PageTable(
                PagedCacheConfig(
                    n_pages=slots * pages_per_seq,
                    page_tokens=kv_page_tokens,
                    n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim,
                    max_pages_per_seq=pages_per_seq,
                    dtype=kv_dtype),
                max_seqs=slots)

        self._decode = jax.jit(self.model.decode_step)
        self._prefill1 = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_seq))

    # ------------------------------------------------------------- API
    def submit(self, req: Request):
        req.submitted_s = time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            cache1, logits = self._prefill1(
                self.params, {"tokens": jnp.asarray(req.prompt[None])})
            self.stats.prefills += 1
            # splice the single-seq cache into this slot
            self.cache = jax.tree.map(
                lambda full, one: (
                    full.at[:, slot].set(one[:, 0])
                    if full.ndim >= 2 and full.shape[1] == self.slots
                    else full),
                self.cache, cache1)
            self.cache["len"] = self.cache["len"].at[slot].set(
                cache1["len"][0])
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.first_token_s = time.perf_counter()
            req.output.append(tok)
            self._next_tokens[slot] = tok
            self._remaining[slot] = req.max_new_tokens - 1
            self.slot_req[slot] = req
            self.stats.tokens_out += 1
            if self._table is not None:
                if not self._table.alloc_seq(slot, len(req.prompt)) \
                        or not self._table.note_tokens(
                            slot, int(self.cache["len"][slot])):
                    raise RuntimeError("shadow KV table out of pages")

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.done_s = time.perf_counter()
        self.slot_req[slot] = None
        self.cache["len"] = self.cache["len"].at[slot].set(0)
        if self._table is not None:
            self._table.free_seq(slot)

    def step(self):
        """One engine iteration: admit + one batched decode step."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        if self._table is not None:
            # the step streams each active slot's currently-resident KV
            # pages; the new token's KV lands before the next step
            self.step_plans.append(self._table.decode_step_plan(active))
        toks = jnp.asarray(self._next_tokens)
        self.cache, logits = self._decode(self.params, self.cache, toks)
        self.stats.decode_steps += 1
        if self._table is not None:
            for slot in active:
                if not self._table.note_tokens(
                        slot, int(self.cache["len"][slot])):
                    raise RuntimeError("shadow KV table out of pages")
        nxt = np.asarray(jnp.argmax(
            logits[:, :self.cfg.vocab_size], axis=-1), np.int32)
        for slot in active:
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.output.append(tok)
            self.stats.tokens_out += 1
            self._next_tokens[slot] = tok
            self._remaining[slot] -= 1
            hit_eos = self.eos is not None and tok == self.eos
            if self._remaining[slot] <= 0 or hit_eos or \
                    int(self.cache["len"][slot]) >= self.max_seq - 1:
                self._retire(slot)
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> EngineStats:
        t0 = time.perf_counter()
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        self.stats.wall_s = time.perf_counter() - t0
        return self.stats
