"""Paged KV cache — the SMMU/page-table design applied to serving.

A global pool of fixed-size pages (4 KB-aligned: page_tokens × KH × hd
× bytes is a page multiple) plus a per-sequence page table. Allocation
is host-side (free-list); the device only ever sees (pool, table, lens)
— exactly the paper's split: translation/orchestration in the system,
streaming compute in the accelerator. Consumed by
``kernels.paged_attention``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _np_itemsize(dtype) -> int:
    """Element size via numpy only — host bookkeeping (the "driver")
    must never touch JAX.  ml_dtypes supplies the numpy-registered
    bfloat16/fp8 types jax would otherwise resolve."""
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, str(dtype))).itemsize


@dataclasses.dataclass
class PagedCacheConfig:
    n_pages: int
    page_tokens: int
    n_kv_heads: int
    head_dim: int
    max_pages_per_seq: int
    dtype: str = "bfloat16"

    @property
    def page_bytes(self) -> int:
        return self.page_tokens * self.n_kv_heads * self.head_dim * \
            _np_itemsize(self.dtype)


class PageTable:
    """Host-side paged-KV bookkeeping alone — the "driver" half of the
    cache: free-list, per-sequence page tables and lengths, and the
    ``decode_step_plan`` builder.  Holds NO device pools (and never
    imports JAX state), so the serving engine can shadow its dense
    cache with one of these to emit a StreamPlan per decode step at
    bookkeeping cost."""

    def __init__(self, cfg: PagedCacheConfig, max_seqs: int,
                 templated: bool = False):
        self.cfg = cfg
        self.max_seqs = max_seqs
        # route the plan builders through core.plan.PLAN_TEMPLATES:
        # one compile per geometry, O(pages) page-id relabels per step
        self.templated = templated
        self._free = list(range(cfg.n_pages - 1, -1, -1))
        self.tables = np.zeros((max_seqs, cfg.max_pages_per_seq), np.int32)
        self.lens = np.zeros((max_seqs,), np.int32)
        self.held = np.zeros((max_seqs,), np.int32)   # pages per slot
        self.shared = np.zeros((max_seqs,), np.int32)  # leading shared
        self.active = np.zeros((max_seqs,), bool)
        self._prefix: list = []     # ids reserved by reserve_prefix
        self._seized: list = []     # ids removed by seize_pages (faults)

    # --------------------------------------------------- slot lifecycle
    def reserve_prefix(self, n_pages: int) -> np.ndarray:
        """Permanently pop ``n_pages`` from the free list and return
        their ids — the shared system-prompt pages of prefix caching.
        Sequences allocated with ``prefix=`` map these as their leading
        pages; ``free_seq`` never returns them (they outlive every
        request)."""
        if n_pages > len(self._free):
            raise ValueError(
                f"cannot reserve {n_pages} prefix pages: only "
                f"{len(self._free)} free")
        ids = [self._free.pop() for _ in range(n_pages)]
        self._prefix += ids
        return np.array(ids, np.int32)

    def alloc_seq(self, slot: int, prompt_len: int,
                  prefix: Optional[np.ndarray] = None) -> bool:
        n_pages = -(-max(prompt_len, 1) // self.cfg.page_tokens)
        k = 0 if prefix is None else min(len(prefix), n_pages)
        if n_pages - k > len(self._free) or \
                n_pages > self.cfg.max_pages_per_seq:
            return False
        self.tables[slot, :] = 0
        for i in range(k):
            self.tables[slot, i] = prefix[i]
        for i in range(k, n_pages):
            self.tables[slot, i] = self._free.pop()
        self.lens[slot] = 0
        self.held[slot] = n_pages
        self.shared[slot] = k
        self.active[slot] = True
        return True

    def free_seq(self, slot: int):
        for i in range(int(self.shared[slot]), int(self.held[slot])):
            self._free.append(int(self.tables[slot, i]))
        self.lens[slot] = 0
        self.held[slot] = 0
        self.shared[slot] = 0
        self.active[slot] = False

    def ensure_capacity(self, slot: int, new_len: int) -> bool:
        """Grow the table if the next token crosses a page boundary.
        Pages assigned before the free list runs dry stay recorded in
        ``held`` (no leak on a failed partial growth — ``free_seq``
        returns them)."""
        have = int(self.held[slot])
        need = -(-new_len // self.cfg.page_tokens)
        if need > self.cfg.max_pages_per_seq:
            return False
        while have < need:
            if not self._free:
                self.held[slot] = have
                return False
            self.tables[slot, have] = self._free.pop()
            have += 1
        self.held[slot] = have
        return True

    def note_tokens(self, slot: int, new_len: int) -> bool:
        """Record that ``slot`` now caches ``new_len`` tokens (growing
        its table across page boundaries as needed) — the data-free
        counterpart of ``write_prompt`` / ``append_token`` for shadow
        tables that only track composition."""
        if not self.ensure_capacity(slot, new_len):
            return False
        self.lens[slot] = new_len
        return True

    # ------------------------------------------------------------ swap
    def written_own_pages(self, slot: int, tokens: int) -> int:
        """Pages of ``slot``'s OWN (non-shared) table that hold written
        KV for the first ``tokens`` cached tokens — the page-aligned
        swap set.  Shared prefix pages are never swapped (they outlive
        every request)."""
        npg = -(-int(tokens) // self.cfg.page_tokens)
        return max(0, min(npg, int(self.held[slot]))
                   - int(self.shared[slot]))

    def swap_out(self, slot: int, tokens: int, tag, *,
                 n_layers: int = 1):
        """Preempt ``slot``: emit the page-aligned swap plan (DMA_OUT
        of every written own K/V page per layer to the host swap region
        keyed by ``tag``), then release ALL the slot's device pages
        back to the free list (``free_seq``).  Returns ``(plan,
        n_swapped_pages)``; ``plan`` is None when the slot has no
        written own pages (nothing to move — the pages are just
        freed)."""
        from repro.core import plan as plan_ir
        n_swap = self.written_own_pages(slot, tokens)
        plan = None
        if n_swap:
            build = plan_ir.PLAN_TEMPLATES.swap if self.templated \
                else plan_ir.swap_plan
            plan = build(
                n_swap, self.cfg.page_tokens, self.cfg.n_kv_heads,
                self.cfg.head_dim, _np_itemsize(self.cfg.dtype),
                direction="out", tag=tag, n_layers=n_layers)
        self.free_seq(slot)
        return plan, n_swap

    def swap_in_plan(self, n_pages: int, tag, *, n_layers: int = 1):
        """The resume half of ``swap_out``: DMA_IN of the ``n_pages``
        K/V pages previously written to ``tag``'s host swap region
        (same namespace and page keys, so the replay's LLC/TLB models
        see the round trip).  The caller re-allocates device pages
        (``alloc_seq``) separately — the restored data may land on
        different pool page ids."""
        from repro.core import plan as plan_ir
        build = plan_ir.PLAN_TEMPLATES.swap if self.templated \
            else plan_ir.swap_plan
        return build(
            n_pages, self.cfg.page_tokens, self.cfg.n_kv_heads,
            self.cfg.head_dim, _np_itemsize(self.cfg.dtype),
            direction="in", tag=tag, n_layers=n_layers)

    # ------------------------------------------------- fault injection
    def seize_pages(self, n: int) -> int:
        """Remove up to ``n`` pages from the free list (a co-tenant
        grabbing device memory mid-run — the fault-injection pool
        shrink).  Returns the number actually seized.  Seized pages
        stay accounted (``validate()`` treats them as their own
        partition) until ``restore_pages`` returns them."""
        n = min(int(n), len(self._free))
        for _ in range(n):
            self._seized.append(self._free.pop())
        return n

    def restore_pages(self, n: Optional[int] = None) -> int:
        """Return ``n`` seized pages (default: all) to the free list."""
        n = len(self._seized) if n is None else min(int(n),
                                                    len(self._seized))
        for _ in range(n):
            self._free.append(self._seized.pop())
        return n

    # ------------------------------------------------------ invariants
    def validate(self) -> None:
        """Pool-accounting check: the free list, every active slot's
        own pages, the reserved prefix pages, and the fault-seized
        pages must PARTITION ``range(n_pages)`` — no double-frees, no
        leaks, no aliased tables.  Raises ``AssertionError`` with the
        discrepancy; cheap enough to run every engine step in debug
        mode (O(pool))."""
        free = list(self._free)
        owned: list = []
        for s in range(self.max_seqs):
            held, sh = int(self.held[s]), int(self.shared[s])
            if not self.active[s]:
                assert held == 0 and sh == 0, \
                    f"inactive slot {s} still holds {held} pages"
                continue
            assert 0 <= sh <= held, (s, sh, held)
            own = [int(p) for p in self.tables[s, sh:held]]
            for p in self.tables[s, :sh]:
                assert int(p) in set(self._prefix), \
                    f"slot {s} shared page {int(p)} not a prefix page"
            owned += own
        parts = {"free": free, "owned": owned, "prefix": self._prefix,
                 "seized": self._seized}
        for label, part in parts.items():
            assert len(part) == len(set(part)), \
                f"duplicate page ids in {label}: {sorted(part)}"
        total = sum(len(p) for p in parts.values())
        union = set().union(*(set(p) for p in parts.values()))
        assert total == len(union), \
            "page partitions overlap: " + ", ".join(
                f"{a}∩{b}={sorted(set(parts[a]) & set(parts[b]))}"
                for a in parts for b in parts
                if a < b and set(parts[a]) & set(parts[b]))
        assert union == set(range(self.cfg.n_pages)), \
            f"pool leak: {sorted(set(range(self.cfg.n_pages)) - union)}" \
            f" unaccounted, {sorted(union - set(range(self.cfg.n_pages)))}" \
            " phantom"

    # ------------------------------------------------------- streaming
    def decode_step_plan(self, slots, out: str = "decode_out", *,
                         n_q_heads: Optional[int] = None,
                         n_layers: int = 1):
        """StreamPlan for one batched decode step over these slots —
        DMA_IN page ids taken verbatim from the live page tables, so
        the plan's page traffic IS the pool traffic (driver-side only:
        tables / lens / held, never any device pool).  ``n_q_heads``
        enables GQA fan-out over the shared KV pages; ``n_layers``
        composes the exact per-layer stack (this table's composition
        stands in for every layer's, as the real per-layer pools share
        one admission schedule)."""
        from repro.core import plan as plan_ir
        tables = [self.tables[s, :int(self.held[s])]
                  if self.active[s] else [] for s in slots]
        lens = [int(self.lens[s]) if self.active[s] else 0
                for s in slots]
        build = plan_ir.PLAN_TEMPLATES.decode_step if self.templated \
            else plan_ir.decode_step_plan
        return build(
            tables, lens, self.cfg.page_tokens, self.cfg.n_kv_heads,
            self.cfg.head_dim, _np_itemsize(self.cfg.dtype), out=out,
            n_q_heads=n_q_heads, n_layers=n_layers)

    def prefill_plan(self, slot: int, prompt_len: Optional[int] = None,
                     *, n_q_heads: Optional[int] = None,
                     d_model: Optional[int] = None,
                     d_ff: Optional[int] = None, n_layers: int = 1,
                     span: Optional[tuple] = None,
                     out: str = "prefill_out"):
        """StreamPlan for prefilling ``slot``'s prompt into the pages
        it holds (chunked causal QK/PV over the freshly written pool
        pages + weight-streaming GEMMs) — see
        ``core.plan.prefill_plan``.  ``span=(t0, t1)`` prefills only
        that page-aligned token window (chunked prefill: one long
        prompt split across engine steps)."""
        from repro.core import plan as plan_ir
        held = int(self.held[slot])
        if prompt_len is None:
            prompt_len = int(self.lens[slot]) or held * \
                self.cfg.page_tokens
        build = plan_ir.PLAN_TEMPLATES.prefill if self.templated \
            else plan_ir.prefill_plan
        return build(
            self.tables[slot, :held], prompt_len, self.cfg.page_tokens,
            self.cfg.n_kv_heads, self.cfg.head_dim,
            _np_itemsize(self.cfg.dtype), n_q_heads=n_q_heads,
            d_model=d_model, d_ff=d_ff, n_layers=n_layers, span=span,
            out=out, name=f"prefill.s{slot}")

    def shared_prefill_plan(self, pages: np.ndarray, prompt_len: int,
                            *, n_q_heads: Optional[int] = None,
                            d_model: Optional[int] = None,
                            d_ff: Optional[int] = None,
                            n_layers: int = 1, out: str = "prefix_out"):
        """StreamPlan prefilling a shared page run (the prefix-cache
        system prompt) that belongs to no slot — priced once per trace;
        every later request re-streams these pages during attention,
        which is where the cross-request LLC/TLB reuse win shows up."""
        from repro.core import plan as plan_ir
        build = plan_ir.PLAN_TEMPLATES.prefill if self.templated \
            else plan_ir.prefill_plan
        return build(
            np.asarray(pages, np.int32), prompt_len,
            self.cfg.page_tokens, self.cfg.n_kv_heads,
            self.cfg.head_dim, _np_itemsize(self.cfg.dtype),
            n_q_heads=n_q_heads, d_model=d_model, d_ff=d_ff,
            n_layers=n_layers, out=out, name="prefix")

    @property
    def pages_in_use(self) -> int:
        return self.cfg.n_pages - len(self._free)


class PagedKVCache(PageTable):
    """One layer's paged K/V pool + page tables for up to S sequences:
    the ``PageTable`` driver state plus the device-resident pools."""

    def __init__(self, cfg: PagedCacheConfig, max_seqs: int):
        super().__init__(cfg, max_seqs)
        shape = (cfg.n_pages, cfg.page_tokens, cfg.n_kv_heads,
                 cfg.head_dim)
        self.k_pages = jnp.zeros(shape, jnp.dtype(cfg.dtype))
        self.v_pages = jnp.zeros(shape, jnp.dtype(cfg.dtype))

    # --------------------------------------------------------- writes
    def write_prompt(self, slot: int, k: jnp.ndarray, v: jnp.ndarray):
        """k, v: (T, KH, hd) — scatter prompt KV into this slot's pages."""
        T = k.shape[0]
        if not self.ensure_capacity(slot, T):
            raise RuntimeError("out of KV pages")
        pt = self.cfg.page_tokens
        n_pages = -(-T // pt)
        pad = n_pages * pt - T
        kp = jnp.pad(k, ((0, pad), (0, 0), (0, 0))).reshape(
            n_pages, pt, *k.shape[1:])
        vp = jnp.pad(v, ((0, pad), (0, 0), (0, 0))).reshape(
            n_pages, pt, *v.shape[1:])
        idx = self.tables[slot, :n_pages]
        self.k_pages = self.k_pages.at[idx].set(kp.astype(self.k_pages.dtype))
        self.v_pages = self.v_pages.at[idx].set(vp.astype(self.v_pages.dtype))
        self.lens[slot] = T

    def append_token(self, slots: np.ndarray, k: jnp.ndarray,
                     v: jnp.ndarray):
        """k, v: (B, KH, hd) for the given slots; one token each."""
        pt = self.cfg.page_tokens
        for b, slot in enumerate(slots):
            if not self.active[slot]:
                continue
            new_len = int(self.lens[slot]) + 1
            if not self.ensure_capacity(slot, new_len):
                raise RuntimeError("out of KV pages")
        pages = np.array([
            self.tables[s, int(self.lens[s]) // pt] for s in slots],
            np.int32)
        offs = np.array([int(self.lens[s]) % pt for s in slots], np.int32)
        self.k_pages = self.k_pages.at[pages, offs].set(
            k.astype(self.k_pages.dtype))
        self.v_pages = self.v_pages.at[pages, offs].set(
            v.astype(self.v_pages.dtype))
        for s in slots:
            if self.active[s]:
                self.lens[s] += 1

    # ------------------------------------------------------- streaming
    def page_dicts(self, slots):
        """{page_id: page} views of the K and V pools for the pages the
        given slots hold — the ``paged`` input of ``execute_plan``."""
        pids = sorted({int(p) for s in slots if self.active[s]
                       for p in self.tables[s, :int(self.held[s])]})
        k = {p: np.asarray(self.k_pages[p]) for p in pids}
        v = {p: np.asarray(self.v_pages[p]) for p in pids}
        return k, v

    # ---------------------------------------------------------- reads
    def device_views(self, slots: np.ndarray):
        table = jnp.asarray(self.tables[slots])
        lens = jnp.asarray(self.lens[slots])
        return self.k_pages, self.v_pages, table, lens
