"""Request-centric serving simulation: fold one batched trace replay
back onto the recorded request timeline.

``ServingEngine(record_plans=True)`` leaves behind a plan trace — one
``prefill_plan`` per admission (one per CHUNK under chunked-prefill
admission) and one multi-layer decode plan per engine step, each
tagged ``(step_idx, slot -> uid)``.  This module prices the WHOLE
trace in one compiled replay (``accesys.pipeline.replay_trace``, or
the chunk-streamed ``replay_trace_streamed`` for open-loop scale —
shared page interning, one continuous timeline) and attributes the
per-record simulated durations to individual requests:

  * simulated TTFT — trace time at the request's prefill completion
    (the LAST prefill chunk emits the first token) minus its arrival
    time, so queueing/deferral delay is included;
  * simulated TPOT — (last decode-token time - prefill completion) /
    decoded tokens;
  * component split — per request, TTFT decomposes into queue /
    prefill / swap-stall and the decode phase into decode / swap /
    stall, with swap DMA (``swap_out``/``swap_in`` preemption
    records) and preemption counts attributed to their victim.

Edge cases are reported as CENSORED, never dropped silently or left
to skew the tails: a request still in flight when the trace ends
contributes no TPOT (its decode is truncated) and, if it never
finished prefilling, no TTFT either; prefill-only requests
(``max_new_tokens == 1``: zero decode steps) have ``tpot_s = nan``
and are counted.  ``percentiles()`` filters the nans and carries the
counts, so the p50/p95/p99 numbers a serving SLO speaks stay honest
at every load point — including past the saturation knee, where the
in-flight fraction grows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.accesys.pipeline import HOST_S_PER_ELEM, replay_trace
from repro.core import plan as plan_ir


@dataclasses.dataclass
class RequestSim:
    """Simulated latency of one served request, split into additive
    components: ``queue_s + prefill_s + swap_pre_s == ttft_s`` and
    (for decoded requests) ``decode_s + swap_post_s + stall_s`` spans
    first token -> last token, so end-to-end latency is exactly the
    sum of all six.  ``queue_s``/``stall_s`` are the residuals — time
    the request spent waiting on admission, deferral, or other
    requests' records; the other four are the request's own priced
    record durations."""
    uid: int
    ttft_s: float                  # arrival -> first token (nan if the
    #                                prefill never completed)
    tpot_s: float                  # per decoded token (nan if none or
    #                                censored)
    n_tokens: int                  # tokens attributed (prefill + decode)
    censored: bool = False         # still in flight at trace end
    queue_s: float = math.nan      # ttft share: waiting / others' turns
    prefill_s: float = math.nan    # ttft share: own prefill records
    swap_pre_s: float = math.nan   # ttft share: swap DMA before 1st tok
    decode_s: float = math.nan     # own decode records
    stall_s: float = math.nan      # decode-phase waiting on others
    swap_post_s: float = math.nan  # swap DMA after the first token
    e2e_s: float = math.nan        # arrival -> last attributed record
    n_preempt: int = 0             # times this request was evicted

    @property
    def swap_s(self) -> float:
        """Total swap DMA time attributed to this request."""
        return self.swap_pre_s + self.swap_post_s


class RecMeta(NamedTuple):
    """The O(1) per-record metadata request folding needs — what a
    streaming accumulator keeps when the plans themselves are not
    retained."""
    kind: str
    uids: tuple
    arrival_event: int


@dataclasses.dataclass
class ServingSimReport:
    mode: str
    total_s: float                 # simulated end-to-end trace time
    per_event_s: np.ndarray        # one duration per trace record
    requests: list                 # [RequestSim], submission order
    result: object                 # aggregate accesys GemmResult

    def percentiles(self) -> dict:
        """{ttft,tpot}_{p50,p95,p99}_us over the trace's requests,
        plus censoring counters: ``n_in_flight`` (still running or
        queued at trace end — no TPOT contribution) and
        ``n_prefill_only`` (finished with zero decode steps)."""
        ttft = np.array([r.ttft_s for r in self.requests])
        ttft = ttft[~np.isnan(ttft)]
        tpot = np.array([r.tpot_s for r in self.requests])
        tpot = tpot[~np.isnan(tpot)]
        swap = np.array([r.swap_s for r in self.requests
                         if not math.isnan(r.swap_s)])
        queue = np.array([r.queue_s for r in self.requests])
        queue = queue[~np.isnan(queue)]
        out = {"requests": len(self.requests),
               "n_in_flight": sum(r.censored for r in self.requests),
               "n_prefill_only": sum(
                   1 for r in self.requests
                   if not r.censored and r.n_tokens <= 1),
               "n_preempted": sum(r.n_preempt > 0
                                  for r in self.requests),
               "preemptions": sum(r.n_preempt for r in self.requests),
               "swap_s_total": float(sum(
                   r.swap_s for r in self.requests
                   if not math.isnan(r.swap_s)))}
        for label, arr in (("ttft", ttft), ("tpot", tpot),
                           ("swap", swap), ("queue", queue)):
            for p in (50, 95, 99):
                out[f"{label}_p{p}_us"] = float(
                    np.percentile(arr, p) * 1e6) if arr.size else \
                    math.nan
        return out


def trace_schedule(trace: Sequence) -> "plan_ir.PlanSchedule":
    """The trace as a repeat-1 ``PlanSchedule`` — build ONCE per trace
    and reuse across memory modes so the compiled form and its
    trace-intrinsic LRU analysis are shared."""
    return plan_ir.PlanSchedule("serve_trace",
                                [(r.plan, 1) for r in trace])


def fold_requests(trace: Sequence, per: np.ndarray,
                  in_flight: Sequence = ()) -> list:
    """Attribute per-record durations to requests.  ``trace`` is any
    sequence exposing ``kind / uids / arrival_event`` per record
    (``PlanRecord``s or ``RecMeta``s); ``per`` the matching replay
    durations; ``in_flight`` the uids the engine had not retired when
    the trace ended (``ServingEngine.unfinished_uids()``).

    Handles chunked prefills (a uid's arrival anchors at its FIRST
    prefill record, completion at its LAST — preemption may interleave
    ``swap_out``/``swap_in`` records between chunks), skips the shared
    prefix-cache record (``uid < 0`` — its duration stays on the
    timeline but belongs to no request), and censors in-flight
    requests: truncated decodes contribute no TPOT, and an in-flight
    request with no decode steps is conservatively treated as still
    prefilling (``ttft_s = nan``).

    Swap DMA records are attributed to their request and the latency
    split into additive components: before the first token,
    ``ttft = queue_s + prefill_s + swap_pre_s``; after it,
    ``last_tok - first_tok = decode_s + swap_post_s + stall_s``.
    ``queue_s``/``stall_s`` are residuals (time the request existed
    but its own records weren't running); both identities hold
    exactly and ``e2e_s`` is their sum."""
    cum = np.cumsum(per)
    arrival: dict = {}
    prefill_done: dict = {}
    prefill_last_i: dict = {}
    prefill_s: dict = {}
    last_tok: dict = {}
    n_decode: dict = {}
    decode_s: dict = {}
    swaps: dict = {}               # uid -> [(rec index, duration)]
    n_preempt: dict = {}
    order: list = []
    for i, rec in enumerate(trace):
        if rec.kind == "prefill":
            uid = rec.uids[0] if rec.uids else -1
            if uid < 0:          # shared prefix prefill: no request
                continue
            if uid not in arrival:
                order.append(uid)
                ae = rec.arrival_event
                arrival[uid] = float(cum[ae - 1]) if ae > 0 else 0.0
            prefill_done[uid] = float(cum[i])
            prefill_last_i[uid] = i
            prefill_s[uid] = prefill_s.get(uid, 0.0) + float(per[i])
        elif rec.kind in ("swap_out", "swap_in"):
            uid = rec.uids[0]
            # a request can be evicted before its first prefill chunk
            # ever ran? no — victims always have progress, so arrival
            # is already anchored; still, guard the fold
            if uid not in arrival:
                order.append(uid)
                ae = rec.arrival_event
                arrival[uid] = float(cum[ae - 1]) if ae > 0 else 0.0
            swaps.setdefault(uid, []).append((i, float(per[i])))
            if rec.kind == "swap_out":
                n_preempt[uid] = n_preempt.get(uid, 0) + 1
            last_tok[uid] = float(cum[i])
        else:                      # decode
            for uid in rec.uids:
                last_tok[uid] = float(cum[i])
                n_decode[uid] = n_decode.get(uid, 0) + 1
                decode_s[uid] = decode_s.get(uid, 0.0) + float(per[i])
    live = set(in_flight)
    requests = []
    for uid in order:
        nd = n_decode.get(uid, 0)
        cens = uid in live
        done = prefill_done.get(uid)
        tpot = (last_tok[uid] - done) / nd \
            if nd and not cens and done is not None else math.nan
        ttft = math.nan if done is None or (cens and nd == 0) else \
            done - arrival[uid]
        sim = RequestSim(
            uid=uid, ttft_s=ttft, tpot_s=tpot, n_tokens=1 + nd,
            censored=cens, n_preempt=n_preempt.get(uid, 0))
        if not math.isnan(ttft):
            pf_i = prefill_last_i[uid]
            sim.prefill_s = prefill_s[uid]
            sim.swap_pre_s = sum(d for i, d in swaps.get(uid, ())
                                 if i < pf_i)
            sim.queue_s = ttft - sim.prefill_s - sim.swap_pre_s
            if nd and not cens:
                sim.decode_s = decode_s[uid]
                sim.swap_post_s = sum(d for i, d in swaps.get(uid, ())
                                      if i > pf_i)
                span = last_tok[uid] - done
                sim.stall_s = span - sim.decode_s - sim.swap_post_s
                sim.e2e_s = last_tok[uid] - arrival[uid]
            elif not cens:         # prefill-only: no decode phase
                sim.decode_s = sim.swap_post_s = sim.stall_s = 0.0
                sim.e2e_s = ttft
        requests.append(sim)
    return requests


class ServingAccumulator:
    """Streaming counterpart of ``fold_requests``: tee the O(1) fold
    metadata off a record generator while the plans stream through to
    the replayer UNRETAINED, then fold the per-plan durations the
    replay returns.  Memory is O(records), never O(events) — the
    per-event timeline only ever exists one replay chunk at a time."""

    def __init__(self):
        self.meta: list = []

    def wrap(self, records):
        """Pass-through generator collecting fold metadata."""
        for rec in records:
            self.meta.append(RecMeta(rec.kind, rec.uids,
                                     rec.arrival_event))
            yield rec

    def report(self, mode: str, result, per: np.ndarray,
               in_flight: Sequence = ()) -> ServingSimReport:
        return ServingSimReport(
            mode=mode, total_s=result.total_s, per_event_s=per,
            requests=fold_requests(self.meta, per, in_flight),
            result=result)


def simulate_serving_trace(cfg, trace: Sequence, *,
                           host_s_per_elem: float = HOST_S_PER_ELEM,
                           engine: Optional[str] = None,
                           sched: Optional["plan_ir.PlanSchedule"]
                           = None,
                           in_flight: Sequence = ()
                           ) -> ServingSimReport:
    """Replay a recorded engine trace once (batched) on ``cfg`` and
    attribute simulated time to requests.  ``trace`` is
    ``ServingEngine.trace`` (a list of ``PlanRecord``)."""
    sched = sched if sched is not None else trace_schedule(trace)
    result, per = replay_trace(cfg, sched,
                               host_s_per_elem=host_s_per_elem,
                               engine=engine)
    return ServingSimReport(mode=cfg.mode, total_s=result.total_s,
                            per_event_s=per,
                            requests=fold_requests(trace, per,
                                                   in_flight),
                            result=result)
