"""Request-centric serving simulation: fold one batched trace replay
back onto the recorded request timeline.

``ServingEngine(record_plans=True)`` leaves behind a plan trace — one
``prefill_plan`` per admission (one per CHUNK under chunked-prefill
admission) and one multi-layer decode plan per engine step, each
tagged ``(step_idx, slot -> uid)``.  This module prices the WHOLE
trace in one compiled replay (``accesys.pipeline.replay_trace``, or
the chunk-streamed ``replay_trace_streamed`` for open-loop scale —
shared page interning, one continuous timeline) and attributes the
per-record simulated durations to individual requests:

  * simulated TTFT — trace time at the request's prefill completion
    (the LAST prefill chunk emits the first token) minus its arrival
    time, so queueing/deferral delay is included;
  * simulated TPOT — (last decode-token time - prefill completion) /
    decoded tokens.

Edge cases are reported as CENSORED, never dropped silently or left
to skew the tails: a request still in flight when the trace ends
contributes no TPOT (its decode is truncated) and, if it never
finished prefilling, no TTFT either; prefill-only requests
(``max_new_tokens == 1``: zero decode steps) have ``tpot_s = nan``
and are counted.  ``percentiles()`` filters the nans and carries the
counts, so the p50/p95/p99 numbers a serving SLO speaks stay honest
at every load point — including past the saturation knee, where the
in-flight fraction grows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.accesys.pipeline import HOST_S_PER_ELEM, replay_trace
from repro.core import plan as plan_ir


@dataclasses.dataclass
class RequestSim:
    """Simulated latency of one served request."""
    uid: int
    ttft_s: float                  # arrival -> first token (nan if the
    #                                prefill never completed)
    tpot_s: float                  # per decoded token (nan if none or
    #                                censored)
    n_tokens: int                  # tokens attributed (prefill + decode)
    censored: bool = False         # still in flight at trace end


class RecMeta(NamedTuple):
    """The O(1) per-record metadata request folding needs — what a
    streaming accumulator keeps when the plans themselves are not
    retained."""
    kind: str
    uids: tuple
    arrival_event: int


@dataclasses.dataclass
class ServingSimReport:
    mode: str
    total_s: float                 # simulated end-to-end trace time
    per_event_s: np.ndarray        # one duration per trace record
    requests: list                 # [RequestSim], submission order
    result: object                 # aggregate accesys GemmResult

    def percentiles(self) -> dict:
        """{ttft,tpot}_{p50,p95,p99}_us over the trace's requests,
        plus censoring counters: ``n_in_flight`` (still running or
        queued at trace end — no TPOT contribution) and
        ``n_prefill_only`` (finished with zero decode steps)."""
        ttft = np.array([r.ttft_s for r in self.requests])
        ttft = ttft[~np.isnan(ttft)]
        tpot = np.array([r.tpot_s for r in self.requests])
        tpot = tpot[~np.isnan(tpot)]
        out = {"requests": len(self.requests),
               "n_in_flight": sum(r.censored for r in self.requests),
               "n_prefill_only": sum(
                   1 for r in self.requests
                   if not r.censored and r.n_tokens <= 1)}
        for label, arr in (("ttft", ttft), ("tpot", tpot)):
            for p in (50, 95, 99):
                out[f"{label}_p{p}_us"] = float(
                    np.percentile(arr, p) * 1e6) if arr.size else \
                    math.nan
        return out


def trace_schedule(trace: Sequence) -> "plan_ir.PlanSchedule":
    """The trace as a repeat-1 ``PlanSchedule`` — build ONCE per trace
    and reuse across memory modes so the compiled form and its
    trace-intrinsic LRU analysis are shared."""
    return plan_ir.PlanSchedule("serve_trace",
                                [(r.plan, 1) for r in trace])


def fold_requests(trace: Sequence, per: np.ndarray,
                  in_flight: Sequence = ()) -> list:
    """Attribute per-record durations to requests.  ``trace`` is any
    sequence exposing ``kind / uids / arrival_event`` per record
    (``PlanRecord``s or ``RecMeta``s); ``per`` the matching replay
    durations; ``in_flight`` the uids the engine had not retired when
    the trace ended (``ServingEngine.unfinished_uids()``).

    Handles chunked prefills (a uid's arrival anchors at its FIRST
    prefill record, completion at its LAST), skips the shared
    prefix-cache record (``uid < 0`` — its duration stays on the
    timeline but belongs to no request), and censors in-flight
    requests: truncated decodes contribute no TPOT, and an in-flight
    request with no decode steps is conservatively treated as still
    prefilling (``ttft_s = nan``)."""
    cum = np.cumsum(per)
    arrival: dict = {}
    prefill_done: dict = {}
    last_tok: dict = {}
    n_decode: dict = {}
    order: list = []
    for i, rec in enumerate(trace):
        if rec.kind == "prefill":
            uid = rec.uids[0] if rec.uids else -1
            if uid < 0:          # shared prefix prefill: no request
                continue
            if uid not in arrival:
                order.append(uid)
                ae = rec.arrival_event
                arrival[uid] = float(cum[ae - 1]) if ae > 0 else 0.0
            prefill_done[uid] = float(cum[i])
        else:
            for uid in rec.uids:
                last_tok[uid] = float(cum[i])
                n_decode[uid] = n_decode.get(uid, 0) + 1
    live = set(in_flight)
    requests = []
    for uid in order:
        nd = n_decode.get(uid, 0)
        cens = uid in live
        tpot = (last_tok[uid] - prefill_done[uid]) / nd \
            if nd and not cens else math.nan
        ttft = math.nan if cens and nd == 0 else \
            prefill_done[uid] - arrival[uid]
        requests.append(RequestSim(
            uid=uid, ttft_s=ttft, tpot_s=tpot, n_tokens=1 + nd,
            censored=cens))
    return requests


class ServingAccumulator:
    """Streaming counterpart of ``fold_requests``: tee the O(1) fold
    metadata off a record generator while the plans stream through to
    the replayer UNRETAINED, then fold the per-plan durations the
    replay returns.  Memory is O(records), never O(events) — the
    per-event timeline only ever exists one replay chunk at a time."""

    def __init__(self):
        self.meta: list = []

    def wrap(self, records):
        """Pass-through generator collecting fold metadata."""
        for rec in records:
            self.meta.append(RecMeta(rec.kind, rec.uids,
                                     rec.arrival_event))
            yield rec

    def report(self, mode: str, result, per: np.ndarray,
               in_flight: Sequence = ()) -> ServingSimReport:
        return ServingSimReport(
            mode=mode, total_s=result.total_s, per_event_s=per,
            requests=fold_requests(self.meta, per, in_flight),
            result=result)


def simulate_serving_trace(cfg, trace: Sequence, *,
                           host_s_per_elem: float = HOST_S_PER_ELEM,
                           engine: Optional[str] = None,
                           sched: Optional["plan_ir.PlanSchedule"]
                           = None,
                           in_flight: Sequence = ()
                           ) -> ServingSimReport:
    """Replay a recorded engine trace once (batched) on ``cfg`` and
    attribute simulated time to requests.  ``trace`` is
    ``ServingEngine.trace`` (a list of ``PlanRecord``)."""
    sched = sched if sched is not None else trace_schedule(trace)
    result, per = replay_trace(cfg, sched,
                               host_s_per_elem=host_s_per_elem,
                               engine=engine)
    return ServingSimReport(mode=cfg.mode, total_s=result.total_s,
                            per_event_s=per,
                            requests=fold_requests(trace, per,
                                                   in_flight),
                            result=result)
