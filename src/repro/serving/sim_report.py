"""Request-centric serving simulation: fold one batched trace replay
back onto the recorded request timeline.

``ServingEngine(record_plans=True)`` leaves behind a plan trace — one
``prefill_plan`` per admission and one multi-layer decode plan per
engine step, each tagged ``(step_idx, slot -> uid)``.  This module
prices the WHOLE trace in one compiled replay
(``accesys.pipeline.replay_trace`` — shared page interning, one
continuous timeline) and attributes the per-event simulated durations
to individual requests:

  * simulated TTFT — trace time at the request's prefill completion
    (the prefill emits the first token) minus its arrival time, so
    queueing/deferral delay is included;
  * simulated TPOT — (last decode-token time - prefill completion) /
    decoded tokens.

``percentiles()`` reduces those per-request latencies to the
p50/p95/p99 numbers a serving SLO speaks — per memory mode, these are
the first user-facing latency figures the simulator emits.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.accesys.pipeline import HOST_S_PER_ELEM, replay_trace
from repro.core import plan as plan_ir


@dataclasses.dataclass
class RequestSim:
    """Simulated latency of one served request."""
    uid: int
    ttft_s: float                  # arrival -> first token (simulated)
    tpot_s: float                  # per decoded token (nan if none)
    n_tokens: int                  # tokens attributed (prefill + decode)


@dataclasses.dataclass
class ServingSimReport:
    mode: str
    total_s: float                 # simulated end-to-end trace time
    per_event_s: np.ndarray        # one duration per trace record
    requests: list                 # [RequestSim], submission order
    result: object                 # aggregate accesys GemmResult

    def percentiles(self) -> dict:
        """{ttft,tpot}_{p50,p95,p99}_us over the trace's requests."""
        ttft = np.array([r.ttft_s for r in self.requests])
        tpot = np.array([r.tpot_s for r in self.requests])
        tpot = tpot[~np.isnan(tpot)]
        out = {"requests": len(self.requests)}
        for label, arr in (("ttft", ttft), ("tpot", tpot)):
            for p in (50, 95, 99):
                out[f"{label}_p{p}_us"] = float(
                    np.percentile(arr, p) * 1e6) if arr.size else \
                    math.nan
        return out


def trace_schedule(trace: Sequence) -> "plan_ir.PlanSchedule":
    """The trace as a repeat-1 ``PlanSchedule`` — build ONCE per trace
    and reuse across memory modes so the compiled form and its
    trace-intrinsic LRU analysis are shared."""
    return plan_ir.PlanSchedule("serve_trace",
                                [(r.plan, 1) for r in trace])


def simulate_serving_trace(cfg, trace: Sequence, *,
                           host_s_per_elem: float = HOST_S_PER_ELEM,
                           engine: Optional[str] = None,
                           sched: Optional["plan_ir.PlanSchedule"]
                           = None) -> ServingSimReport:
    """Replay a recorded engine trace once (batched) on ``cfg`` and
    attribute simulated time to requests.  ``trace`` is
    ``ServingEngine.trace`` (a list of ``PlanRecord``)."""
    sched = sched if sched is not None else trace_schedule(trace)
    result, per = replay_trace(cfg, sched,
                               host_s_per_elem=host_s_per_elem,
                               engine=engine)
    cum = np.cumsum(per)
    arrival: dict = {}
    prefill_done: dict = {}
    last_tok: dict = {}
    n_decode: dict = {}
    order: list = []
    for i, rec in enumerate(trace):
        if rec.kind == "prefill":
            uid = rec.uids[0]
            order.append(uid)
            ae = rec.arrival_event
            arrival[uid] = float(cum[ae - 1]) if ae > 0 else 0.0
            prefill_done[uid] = float(cum[i])
        else:
            for uid in rec.uids:
                last_tok[uid] = float(cum[i])
                n_decode[uid] = n_decode.get(uid, 0) + 1
    requests = []
    for uid in order:
        nd = n_decode.get(uid, 0)
        tpot = (last_tok[uid] - prefill_done[uid]) / nd if nd else \
            math.nan
        requests.append(RequestSim(
            uid=uid, ttft_s=prefill_done[uid] - arrival[uid],
            tpot_s=tpot, n_tokens=1 + nd))
    return ServingSimReport(mode=cfg.mode, total_s=result.total_s,
                            per_event_s=per, requests=requests,
                            result=result)
