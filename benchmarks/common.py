"""Shared benchmark plumbing: CSV emission per the harness contract
(``name,us_per_call,derived``)."""
import csv
import os
import sys
import time
from pathlib import Path

OUTDIR = Path(os.environ.get("REPRO_BENCH_OUT", "artifacts/bench"))


def emit(rows, table_name):
    OUTDIR.mkdir(parents=True, exist_ok=True)
    path = OUTDIR / f"{table_name}.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["name", "us_per_call", "derived"])
        for r in rows:
            w.writerow(r)
    for r in rows:
        print(f"{table_name}.{r[0]},{r[1]},{r[2]}")
    return path


def timeit(fn, *args, warmup=1, iters=3):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out
