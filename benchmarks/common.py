"""Shared benchmark plumbing: CSV emission per the harness contract
(``name,us_per_call,derived``) plus the ``SimResult``-consuming helpers
every simulator benchmark formats its rows and artifacts with."""
import csv
import json
import os
import sys
import time
from pathlib import Path

OUTDIR = Path(os.environ.get("REPRO_BENCH_OUT", "artifacts/bench"))


def emit(rows, table_name):
    OUTDIR.mkdir(parents=True, exist_ok=True)
    path = OUTDIR / f"{table_name}.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["name", "us_per_call", "derived"])
        for r in rows:
            w.writerow(r)
    for r in rows:
        print(f"{table_name}.{r[0]},{r[1]},{r[2]}")
    return path


def timeit(fn, *args, warmup=1, iters=3):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


# ------------------------------------------------- SimResult consumers
def derived_str(res, keys=("host", "transfer"), extra="") -> str:
    """The ``derived`` CSV column from a ``SimResult``: the requested
    Fig.-2 bucket shares (``{k}_share=``), plus any caller extras."""
    b = res.buckets()
    parts = [f"{k}_share={b[k]:.3f}" for k in keys]
    if extra:
        parts.append(extra)
    return ";".join(parts)


def simresult_row(res, name=None, keys=("host", "transfer"),
                  extra="", events=False) -> tuple:
    """One emit() row from a ``SimResult``: name defaults to
    ``label.mode``; ``events=True`` appends the sampled/exact event
    counts."""
    if events:
        ev = f"events={res.events_replayed}/{res.events_total}"
        extra = f"{extra};{ev}" if extra else ev
    return (name or f"{res.label}.{res.mode}",
            round(res.total_s * 1e6, 1),
            derived_str(res, keys, extra))


def simresult_rows(results, namer=None, keys=("host", "transfer"),
                   extra=None, events=False) -> list:
    """Rows for a list of ``SimResult``s; ``namer(res)`` / ``extra(res)``
    customize per-row naming and the derived tail."""
    return [simresult_row(r,
                          name=namer(r) if namer else None,
                          keys=keys,
                          extra=extra(r) if extra else "",
                          events=events)
            for r in results]


def write_json_artifact(obj, name) -> Path:
    """Stable-schema JSON artifact next to the CSVs (SimResult
    ``to_json()`` payloads and friends)."""
    OUTDIR.mkdir(parents=True, exist_ok=True)
    path = OUTDIR / f"{name}.json"
    path.write_text(json.dumps(obj, indent=2) + "\n")
    return path
