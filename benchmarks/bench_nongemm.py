"""Fig. 13: performance vs non-GEMM fraction, host links vs DevMem."""
from repro.accesys import workloads as W
from repro.accesys.calibration import nongemm_crossover, scale_nongemm
from repro.accesys.components import DRAM
from repro.accesys.system import (default_system, pcie_for_bw,
                                  run_transformer_accel)
from benchmarks.common import emit


def main():
    rows = []
    wl = W.transformer_trace("vit-base-16")
    for frac in (0.05, 0.2, 0.35, 0.5, 0.65):
        scaled = scale_nongemm(wl, frac)
        dev = run_transformer_accel(
            default_system("DevMem", dtype="int32", dram=DRAM("HBM2")),
            scaled).total_s
        for bw in (8, 64):
            host = run_transformer_accel(
                default_system("DC", dtype="int32",
                               pcie=pcie_for_bw(bw)), scaled).total_s
            rows.append((f"frac{frac}.host{bw}GBs",
                         round(host * 1e6, 1),
                         f"norm_vs_devmem={dev / host:.3f}"))
    for bw in (64, 8, 2):
        rows.append((f"crossover.bw{bw}GBs", "-",
                     f"crossover_frac={nongemm_crossover(bw):.3f}"))
    emit(rows, "fig13_nongemm")


if __name__ == "__main__":
    main()
