"""Real wall-clock micro-benchmarks of the JAX-level streaming paths on
this host (CPU): chunked streaming attention vs naive attention, the
Eq.-1 overlap bound table, and engine serving throughput."""
import jax
import jax.numpy as jnp

from repro.core import overlap
from repro.models.layers import chunked_attention
from benchmarks.common import emit, timeit


def _naive_attn(q, k, v):
    import math
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(q.shape[-1])
    mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def main():
    rows = []
    B, T, H, D = 1, 1024, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, H, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, H, D), jnp.float32)
    us_naive, _ = timeit(jax.jit(_naive_attn), q, k, v)
    f = jax.jit(lambda a, b, c: chunked_attention(a, b, c, causal=True))
    us_chunk, _ = timeit(f, q, k, v)
    rows.append(("attn.naive_T1024", round(us_naive, 1), "materializes TxT"))
    rows.append(("attn.chunked_T1024", round(us_chunk, 1),
                 f"streaming pages; ratio={us_chunk/us_naive:.2f}"))
    # Eq. 1 overlap bound table
    for dtype, s in (("int8", 1), ("fp16", 2), ("fp32", 4)):
        bw = overlap.required_bandwidth(16, 4096 // (16 * s), 1e9, s)
        asym = overlap.asymptotic_bandwidth(16, 1e9, s)
        rows.append((f"overlap.{dtype}", "-",
                     f"required={bw/1e9:.1f}GB/s;asymptote={asym/1e9:.0f}GB/s"))
    emit(rows, "kernels_overlap")


if __name__ == "__main__":
    main()
