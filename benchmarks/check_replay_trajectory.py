"""CI wall-clock trajectory check for the compiled replay engine.

Rebuilds the exact BERT-Base composed plan, runs the compiled replay
across the DM/DC/DevMem sweep (the first mode pays the one-time trace
analysis, exactly as ``bench_replay.py`` measures it), and compares
the achieved events/sec against the committed ``BENCH_replay.json``
artifact.  Exits non-zero if throughput regressed by more than the
threshold (default 2x) — catching accidental de-vectorization of the
replay hot path without pinning absolute machine speed:

  * the committed events/sec is HOST-NORMALIZED before comparing —
    the event engine (a pure-Python object loop whose speed tracks the
    host, also recorded in the artifact) is re-measured on this
    machine and its ratio to the artifact's scales the expectation, so
    a CI runner 2x slower than the benchmark host does not fail the
    gate, while a compiled-path-only regression still does;
  * the compiled sweep is run twice (memo cleared in between, so both
    are cold like the artifact's) and the best wall-clock kept — one
    noisy neighbour doesn't flake the gate.

For the exact BERT-Base workload the check additionally guards the
config-batched design-space sweep: the deterministic 64-config
``design_space.bench_grid()`` is priced with ``replay_batch`` on the
warmed trace analysis (exactly what ``bench_design_space.py``
measures) and the achieved configs/sec is compared — host-normalized
the same way — against the committed ``BENCH_design_space.json``.

    PYTHONPATH=src python benchmarks/check_replay_trajectory.py
"""
import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

from repro.accesys.pipeline import replay
from repro.core.scenario import Scenario, scenario_plan, system_for

ARTIFACT = Path(__file__).resolve().parent.parent / "BENCH_replay.json"
DS_ARTIFACT = ARTIFACT.parent / "BENCH_design_space.json"
SERVE_ARTIFACT = ARTIFACT.parent / "BENCH_serving_scale.json"
MD_ARTIFACT = ARTIFACT.parent / "BENCH_multidev.json"
MODES = ("DM", "DC", "DevMem")

# artifact key -> the Scenario bench_replay.py lowered it from (only
# the composed BERT stacks are meaningful trajectory gates; the other
# artifact entries are too small to measure throughput regressions)
SCENARIOS = {
    "bert-base.exact": Scenario(model="bert-base", sampling="exact"),
    "bert-base.sampled": Scenario(model="bert-base"),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="max tolerated slowdown vs the artifact")
    ap.add_argument("--workload", default="bert-base.exact",
                    choices=sorted(SCENARIOS))
    args = ap.parse_args(argv)
    art = json.loads(ARTIFACT.read_text())[args.workload]
    committed_wall = sum(m["compiled_s"] for m in art["modes"].values())
    committed_evs = 3 * art["events"] / committed_wall

    # the same scenario lowering bench_replay.py seeds the artifact
    # with (the per-mode "sim" entries carry its simresult/v1 schema)
    sc = SCENARIOS[args.workload]
    plan, _, events, _ = scenario_plan(sc)
    if events != art["events"]:
        print(f"note: plan now holds {events} events "
              f"(artifact: {art['events']}) — builder changed; "
              f"comparing events/sec on the current plan")
    # host-speed calibration: the event engine's throughput on one
    # mode, here vs in the artifact
    t0 = time.perf_counter()
    replay(system_for(dataclasses.replace(sc, mode="DC")), plan,
           engine="event")
    host_evs = events / (time.perf_counter() - t0)
    host_factor = art["modes"]["DC"]["event_ev_per_s"] / host_evs
    expect_evs = committed_evs / host_factor
    wall = float("inf")
    for _ in range(2):                 # best-of-2: shrug off CI noise
        # each sweep starts cold, like the artifact's: the first mode
        # pays the one-time trace analysis, later modes reuse it
        plan.compile().memo.clear()
        t0 = time.perf_counter()
        for mode in MODES:
            replay(system_for(dataclasses.replace(sc, mode=mode)),
                   plan, engine="compiled")
        wall = min(wall, time.perf_counter() - t0)
    got_evs = 3 * events / wall
    ratio = expect_evs / max(got_evs, 1e-9)
    print(f"{args.workload}: {events} events, 3-mode compiled sweep "
          f"{wall:.3f}s -> {got_evs:,.0f} ev/s "
          f"(artifact {committed_evs:,.0f} ev/s, host factor "
          f"{host_factor:.2f}x -> expected {expect_evs:,.0f} ev/s, "
          f"slowdown {ratio:.2f}x, threshold {args.threshold:.1f}x)")
    if ratio > args.threshold:
        print("FAIL: compiled replay throughput regressed "
              f">{args.threshold:.1f}x vs BENCH_replay.json")
        return 1
    print("OK: replay wall-clock trajectory within threshold")

    if args.workload == "bert-base.exact" and DS_ARTIFACT.exists():
        from repro.accesys.pipeline import replay_batch
        from repro.core.design_space import bench_grid, system_for_point

        ds = json.loads(DS_ARTIFACT.read_text())
        cfgs = [system_for_point(p) for p in bench_grid()]
        # one untimed call pays the grid's one-time trace analysis
        # (uTLB reach variants etc.) — the artifact's batched number
        # prices a warm analysis too, after its sequential phase
        replay_batch(cfgs, plan)
        bwall = float("inf")
        for _ in range(2):             # best-of-2: shrug off CI noise
            t0 = time.perf_counter()
            replay_batch(cfgs, plan)
            bwall = min(bwall, time.perf_counter() - t0)
        got_cfg = len(cfgs) / bwall
        expect_cfg = ds["batched_cfg_per_s"] / host_factor
        bratio = expect_cfg / max(got_cfg, 1e-9)
        print(f"batched sweep: {len(cfgs)} configs priced in "
              f"{bwall:.3f}s -> {got_cfg:,.1f} cfg/s (artifact "
              f"{ds['batched_cfg_per_s']:,.1f} cfg/s, host factor "
              f"{host_factor:.2f}x -> expected {expect_cfg:,.1f} "
              f"cfg/s, slowdown {bratio:.2f}x, threshold "
              f"{args.threshold:.1f}x)")
        if bratio > args.threshold:
            print("FAIL: batched design-space sweep regressed "
                  f">{args.threshold:.1f}x vs BENCH_design_space.json")
            return 1
        print("OK: batched sweep configs/sec within threshold")

    if args.workload == "bert-base.exact" and SERVE_ARTIFACT.exists():
        # streamed serving-trace replay: regenerate the artifact's
        # deterministic 1k-request open-loop trace and re-price it
        # chunked for all three modes (replay wall only — generation
        # is measured by the artifact separately), host-normalized
        # against the committed BENCH_serving_scale.json
        from repro.accesys.pipeline import (release_scratch,
                                            replay_trace_streamed)
        from repro.core.plan import _plan_n_events
        try:
            from benchmarks.bench_serving_scale import (CHUNK_EVENTS,
                                                        record_stream,
                                                        stream_price)
        except ImportError:                # run as a bare script
            from bench_serving_scale import (CHUNK_EVENTS,
                                             record_stream,
                                             stream_price)

        sv = json.loads(SERVE_ARTIFACT.read_text())
        wl = sv["workloads"]["serve_1k"]
        cfgs = [system_for(Scenario(model="serve", mode=m))
                for m in MODES]
        _, gen = record_stream(wl["requests"], templated=False)
        plans = [rec.plan for rec in gen]
        n_ev = sum(_plan_n_events(p) for p in plans)
        if n_ev != wl["events"]:
            print(f"note: serve_1k trace now holds {n_ev} events "
                  f"(artifact: {wl['events']}) — engine changed; "
                  "comparing events/sec on the current trace")
        swall = float("inf")
        for _ in range(2):             # best-of-2: shrug off CI noise
            release_scratch()          # cold pool, like the artifact
            t0 = time.perf_counter()
            replay_trace_streamed(cfgs, plans,
                                  chunk_events=CHUNK_EVENTS)
            swall = min(swall, time.perf_counter() - t0)
        got_sevs = 3 * n_ev / swall
        expect_sevs = wl["events_per_s"] / host_factor
        sratio = expect_sevs / max(got_sevs, 1e-9)
        print(f"streamed serving replay: {n_ev} events, 3-mode "
              f"chunked pass {swall:.3f}s -> {got_sevs:,.0f} ev/s "
              f"(artifact {wl['events_per_s']:,.0f} ev/s, host factor "
              f"{host_factor:.2f}x -> expected {expect_sevs:,.0f} "
              f"ev/s, slowdown {sratio:.2f}x, threshold "
              f"{args.threshold:.1f}x)")
        if sratio > args.threshold:
            print("FAIL: streamed serving replay regressed "
                  f">{args.threshold:.1f}x vs BENCH_serving_scale.json")
            return 1
        print("OK: streamed serving replay within threshold")

        pwl = sv["workloads"].get("serve_preempt_1k")
        if pwl is not None:
            # swap-thrash variant: same deterministic trace on a
            # pressure-capped pool with preemption — the swap DMA
            # records ride the priced path, so a regression in the
            # swap lane shows up here and nowhere else
            try:
                from benchmarks.bench_serving_scale import (
                    PREEMPT_ENGINE_KW, PREEMPT_RUN_KW)
            except ImportError:
                from bench_serving_scale import (PREEMPT_ENGINE_KW,
                                                 PREEMPT_RUN_KW)
            eng, gen = record_stream(pwl["requests"],
                                     run_kw=PREEMPT_RUN_KW,
                                     templated=False,
                                     **PREEMPT_ENGINE_KW)
            plans = [rec.plan for rec in gen]
            if eng.stats.preemptions != pwl["preemptions"]:
                print(f"note: preempt trace now has "
                      f"{eng.stats.preemptions} preemptions (artifact:"
                      f" {pwl['preemptions']}) — engine changed")
            n_ev = sum(_plan_n_events(p) for p in plans)
            pswall = float("inf")
            for _ in range(2):
                release_scratch()
                t0 = time.perf_counter()
                replay_trace_streamed(cfgs, plans,
                                      chunk_events=CHUNK_EVENTS)
                pswall = min(pswall, time.perf_counter() - t0)
            got_pevs = 3 * n_ev / pswall
            expect_pevs = pwl["events_per_s"] / host_factor
            pratio = expect_pevs / max(got_pevs, 1e-9)
            print(f"preempt serving replay: {n_ev} events "
                  f"({eng.stats.preemptions} preemptions, "
                  f"{eng.stats.swapped_pages} pages swapped), 3-mode "
                  f"chunked pass {pswall:.3f}s -> {got_pevs:,.0f} ev/s"
                  f" (artifact {pwl['events_per_s']:,.0f} ev/s, host "
                  f"factor {host_factor:.2f}x -> expected "
                  f"{expect_pevs:,.0f} ev/s, slowdown {pratio:.2f}x, "
                  f"threshold {args.threshold:.1f}x)")
            if pratio > args.threshold:
                print("FAIL: preemption serving replay regressed "
                      f">{args.threshold:.1f}x vs "
                      "BENCH_serving_scale.json")
                return 1
            print("OK: preemption serving replay within threshold")

        twl = sv["workloads"].get("serve_10k_templated")
        if twl is not None:
            # template-instanced path: artifact-level same-host ratios
            # first (deterministic in CI — both sides of each ratio
            # were measured on the benchmark host)...
            if not twl.get("bitwise_match"):
                print("FAIL: artifact's templated row is not bitwise-"
                      "matched against the event-built serve_10k")
                return 1
            if twl["speedup_end_to_end"] < 5.0:
                print("FAIL: templated serve_10k end-to-end speedup "
                      f"{twl['speedup_end_to_end']}x < 5x acceptance")
                return 1
            ls = sv["workloads"].get("load_sweep_200")
            if ls is not None and ls["speedup_end_to_end"] < 3.0:
                print("FAIL: parallel load-sweep speedup "
                      f"{ls['speedup_end_to_end']}x < 3x acceptance")
                return 1
            # ...then a host-normalized >=2x guard on the row itself:
            # rebuild + price a templated 1k trace end to end (the 10k
            # row at 1/10 scale — events/sec is scale-free here) and
            # compare against the artifact row's end-to-end rate
            _, _, tcounts, tgen_s, tprice_s, _ = stream_price(
                1_000, cfgs, templated=True)
            release_scratch()
            got_tevs = 3 * tcounts["events"] / (tgen_s + tprice_s)
            art_tevs = 3 * twl["events"] / (twl["gen_s"]
                                            + twl["price_s_all_modes"])
            expect_tevs = art_tevs / host_factor
            tratio = expect_tevs / max(got_tevs, 1e-9)
            print(f"templated serving build+price: "
                  f"{tcounts['events']} events in "
                  f"{tgen_s + tprice_s:.3f}s -> {got_tevs:,.0f} ev/s "
                  f"(artifact {art_tevs:,.0f} ev/s, host factor "
                  f"{host_factor:.2f}x -> expected {expect_tevs:,.0f} "
                  f"ev/s, slowdown {tratio:.2f}x, threshold 2.0x)")
            if tratio > 2.0:
                print("FAIL: templated serving build+price regressed "
                      ">2x vs BENCH_serving_scale.json")
                return 1
            print("OK: templated serving build+price within threshold")

    if args.workload == "bert-base.exact" and MD_ARTIFACT.exists():
        # sharded-plan pricing: rebuild the reduced TP/EP gate
        # scenarios bench_multidev.py measured (importing its
        # GATE_SCENARIOS so the gate and artifact can't drift apart)
        # and re-price the same 3-mode compiled sweeps, best-of-2,
        # host-normalized against the committed BENCH_multidev.json
        try:
            from benchmarks.bench_multidev import GATE_SCENARIOS
        except ImportError:                # run as a bare script
            from bench_multidev import GATE_SCENARIOS

        md = json.loads(MD_ARTIFACT.read_text())["gate"]
        if list(md["scenarios"]) != [dict(kw) for kw in GATE_SCENARIOS]:
            print("note: multidev gate scenarios changed since the "
                  "artifact — comparing events/sec on the current set")
        mplans = []
        m_ev = 0
        for kw in GATE_SCENARIOS:
            msc = Scenario(engine="compiled", **kw)
            mplan, _, mev, _ = scenario_plan(msc)
            mplans.append((msc, mplan))
            m_ev += mev
        # self-calibrated host factor: the multidev gate records its
        # own event-engine rate, so it normalizes correctly even when
        # regenerated on a different host than BENCH_replay.json
        t0 = time.perf_counter()
        for msc, mplan in mplans:
            replay(system_for(dataclasses.replace(msc, mode="DC")),
                   mplan, engine="event")
        md_host = md["event_ev_per_s"] / (m_ev
                                          / (time.perf_counter() - t0))
        mwall = float("inf")
        for _ in range(2):             # best-of-2: shrug off CI noise
            for _, mplan in mplans:
                mplan.compile().memo.clear()
            t0 = time.perf_counter()
            for msc, mplan in mplans:
                for mode in MODES:
                    replay(system_for(dataclasses.replace(msc,
                                                          mode=mode)),
                           mplan, engine="compiled")
            mwall = min(mwall, time.perf_counter() - t0)
        got_mevs = 3 * m_ev / mwall
        expect_mevs = md["ev_per_s"] / md_host
        mratio = expect_mevs / max(got_mevs, 1e-9)
        print(f"multidev sharded pricing: {m_ev} events over "
              f"{len(mplans)} TP/EP plans, 3-mode compiled sweep "
              f"{mwall:.3f}s -> {got_mevs:,.0f} ev/s (artifact "
              f"{md['ev_per_s']:,.0f} ev/s, host factor "
              f"{md_host:.2f}x -> expected {expect_mevs:,.0f} "
              f"ev/s, slowdown {mratio:.2f}x, threshold "
              f"{args.threshold:.1f}x)")
        if mratio > args.threshold:
            print("FAIL: sharded-plan pricing regressed "
                  f">{args.threshold:.1f}x vs BENCH_multidev.json")
            return 1
        print("OK: sharded-plan pricing within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
