"""CI wall-clock trajectory check for the compiled replay engine.

Rebuilds the exact BERT-Base composed plan, runs the compiled replay
across the DM/DC/DevMem sweep (the first mode pays the one-time trace
analysis, exactly as ``bench_replay.py`` measures it), and compares
the achieved events/sec against the committed ``BENCH_replay.json``
artifact.  Exits non-zero if throughput regressed by more than the
threshold (default 2x) — catching accidental de-vectorization of the
replay hot path without pinning absolute machine speed:

  * the committed events/sec is HOST-NORMALIZED before comparing —
    the event engine (a pure-Python object loop whose speed tracks the
    host, also recorded in the artifact) is re-measured on this
    machine and its ratio to the artifact's scales the expectation, so
    a CI runner 2x slower than the benchmark host does not fail the
    gate, while a compiled-path-only regression still does;
  * the compiled sweep is run twice (memo cleared in between, so both
    are cold like the artifact's) and the best wall-clock kept — one
    noisy neighbour doesn't flake the gate.

    PYTHONPATH=src python benchmarks/check_replay_trajectory.py
"""
import argparse
import json
import sys
import time
from pathlib import Path

from repro.accesys.components import DRAM
from repro.accesys.pipeline import replay
from repro.accesys.system import default_system, model_stream_plan

ARTIFACT = Path(__file__).resolve().parent.parent / "BENCH_replay.json"
MODES = (("DM", None), ("DC", None), ("DevMem", "HBM2"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="max tolerated slowdown vs the artifact")
    ap.add_argument("--workload", default="bert-base.exact")
    args = ap.parse_args(argv)
    art = json.loads(ARTIFACT.read_text())[args.workload]
    committed_wall = sum(m["compiled_s"] for m in art["modes"].values())
    committed_evs = 3 * art["events"] / committed_wall

    plan = model_stream_plan("bert-base")
    events = len(plan.events)
    if events != art["events"]:
        print(f"note: plan now holds {events} events "
              f"(artifact: {art['events']}) — builder changed; "
              f"comparing events/sec on the current plan")
    # host-speed calibration: the event engine's throughput on one
    # mode, here vs in the artifact
    t0 = time.perf_counter()
    replay(default_system("DC"), plan, engine="event")
    host_evs = events / (time.perf_counter() - t0)
    host_factor = art["modes"]["DC"]["event_ev_per_s"] / host_evs
    expect_evs = committed_evs / host_factor
    wall = float("inf")
    for _ in range(2):                 # best-of-2: shrug off CI noise
        # each sweep starts cold, like the artifact's: the first mode
        # pays the one-time trace analysis, later modes reuse it
        plan.compile().memo.clear()
        t0 = time.perf_counter()
        for mode, dram in MODES:
            replay(default_system(
                mode, dram=DRAM(dram) if dram else None),
                plan, engine="compiled")
        wall = min(wall, time.perf_counter() - t0)
    got_evs = 3 * events / wall
    ratio = expect_evs / max(got_evs, 1e-9)
    print(f"{args.workload}: {events} events, 3-mode compiled sweep "
          f"{wall:.3f}s -> {got_evs:,.0f} ev/s "
          f"(artifact {committed_evs:,.0f} ev/s, host factor "
          f"{host_factor:.2f}x -> expected {expect_evs:,.0f} ev/s, "
          f"slowdown {ratio:.2f}x, threshold {args.threshold:.1f}x)")
    if ratio > args.threshold:
        print("FAIL: compiled replay throughput regressed "
              f">{args.threshold:.1f}x vs BENCH_replay.json")
        return 1
    print("OK: replay wall-clock trajectory within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
