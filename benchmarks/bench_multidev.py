"""Multi-device sharded-plan pricing: the TP/EP-degree x fabric x
memory-mode sweep behind the README table, plus the reduced-model gate
numbers ``check_replay_trajectory.py`` re-measures host-normalized.

Writes the usual CSV rows plus ``BENCH_multidev.json`` at the repo
root.  The full-size sweeps price ONE rank's sharded plan per point —
symmetric TP/EP ranks make the coupled barrier a no-op
(``core.multidev.replay_multidev`` property), so single-plan pricing is
exact for the whole group, and plans are shared across fabric
bandwidths (a bandwidth point re-prices, never re-lowers).

At these model scales collectives are almost fully hidden in existing
pipeline slack (exact replay prices identical totals across link
bandwidths), so cross-fabric deltas in the sampled rows sit inside the
steady-state window approximation (~0.1%, occasionally inverted);
read the fabric axis through coll_share, not total_us.

    PYTHONPATH=src python benchmarks/bench_multidev.py
"""
import dataclasses
import json
import time
from pathlib import Path

from repro.core import scenario as SC
from repro.core.scenario import Scenario, simulate

try:
    from benchmarks.common import emit
except ImportError:                    # run as a bare script
    from common import emit

JSON_PATH = Path("BENCH_multidev.json")
MODES = ("DM", "DC", "DevMem")
FABRICS = ("ring:16", "ring:64", "alltoall:64")

# full-size sweep axes: TP degrees at the model's EP, EP degrees at
# tp=1, one memory-mode sweep at the largest TP degree on ring:64
SWEEPS = (
    dict(model="deepseek-v3-671b", seq=32, sample_stride=16,
         tp_degrees=(1, 2, 4, 8), ep=8, ep_degrees=(2, 4, 8)),
    dict(model="qwen2-moe-a2.7b", seq=32, sample_stride=8,
         tp_degrees=(1, 2, 4), ep=4, ep_degrees=(1, 2, 4)),
)

# reduced scenarios the CI trajectory gate re-prices (a 3-mode
# compiled sweep each, best-of-2) — imported by
# check_replay_trajectory.py so the gate and the artifact can never
# disagree about what was measured
GATE_SCENARIOS = (
    dict(model="deepseek-v3-reduced", seq=64, tp=2, ep=2),
    dict(model="deepseek-v3-reduced", seq=64, tp=4),
    dict(model="qwen2-moe-a2.7b-reduced", seq=64, ep=4),
    dict(model="qwen2-0.5b-reduced", seq=64, tp=2),
)


def _point(sc: Scenario) -> dict:
    res = simulate(sc)
    b = res.buckets()
    return {"total_us": round(res.total_s * 1e6, 1),
            "coll_share": round(float(b["collective"]), 4),
            "transfer_share": round(float(b["transfer"]), 4),
            "events": res.events_replayed,
            "wall_s": round(res.wall_s, 4)}


def run_sweep(spec: dict) -> dict:
    base = Scenario(model=spec["model"], seq=spec["seq"],
                    sample_stride=spec["sample_stride"],
                    engine="compiled")
    rows = []
    for tp in spec["tp_degrees"]:
        for fab in FABRICS:
            sc = dataclasses.replace(base, tp=tp, ep=spec["ep"],
                                     fabric=fab)
            rows.append({"axis": "tp", "degree": tp, "ep": spec["ep"],
                         "fabric": fab, "mode": "DC",
                         **_point(sc)})
    for ep in spec["ep_degrees"]:
        for fab in FABRICS:
            sc = dataclasses.replace(base, ep=ep, fabric=fab)
            rows.append({"axis": "ep", "degree": ep, "ep": ep,
                         "fabric": fab, "mode": "DC", **_point(sc)})
    tp_max = spec["tp_degrees"][-1]
    for mode in MODES:
        sc = dataclasses.replace(base, tp=tp_max, ep=spec["ep"],
                                 fabric="ring:64", mode=mode)
        rows.append({"axis": "mode", "degree": tp_max,
                     "ep": spec["ep"], "fabric": "ring:64",
                     "mode": mode, **_point(sc)})
    SC.clear_caches()                  # full-size plans are ~100 MB
    return {"seq": spec["seq"], "sample_stride": spec["sample_stride"],
            "rows": rows}


def run_gate() -> dict:
    """Throughput of the sharded pricing path on reduced models: each
    gate scenario lowers once, then a 3-mode compiled sweep (first
    mode pays the one-time trace analysis), best-of-2 overall.  Also
    records the event engine's throughput on the same plans so the CI
    checker can host-normalize against THIS artifact (the bert-derived
    host factor would skew if this section is regenerated on a
    different machine than BENCH_replay.json)."""
    from repro.accesys.pipeline import replay
    from repro.core.scenario import scenario_plan, system_for
    scs = [Scenario(engine="compiled", **kw) for kw in GATE_SCENARIOS]
    plans = []
    events = 0
    for sc in scs:
        plan, _, ev, _ = scenario_plan(sc)
        plans.append((sc, plan))
        events += ev
    wall = float("inf")
    for _ in range(2):
        for _, plan in plans:
            plan.compile().memo.clear()
        t0 = time.perf_counter()
        for sc, plan in plans:
            for mode in MODES:
                replay(system_for(dataclasses.replace(sc, mode=mode)),
                       plan, engine="compiled")
        wall = min(wall, time.perf_counter() - t0)
    ewall = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        for sc, plan in plans:
            replay(system_for(dataclasses.replace(sc, mode="DC")),
                   plan, engine="event")
        ewall = min(ewall, time.perf_counter() - t0)
    SC.clear_caches()
    return {"scenarios": list(GATE_SCENARIOS), "events": events,
            "wall_s": round(wall, 4),
            "ev_per_s": round(3 * events / wall),
            "event_ev_per_s": round(events / ewall)}


def main():
    report = {"schema": "multidev/v1", "modes": list(MODES),
              "fabrics": list(FABRICS), "workloads": {}}
    csv_rows = []
    for spec in SWEEPS:
        wl = run_sweep(spec)
        report["workloads"][spec["model"]] = wl
        for r in wl["rows"]:
            csv_rows.append((
                f"{spec['model']}.{r['axis']}{r['degree']}."
                f"{r['fabric'].replace(':', '_')}.{r['mode']}",
                r["total_us"],
                f"coll_share={r['coll_share']};events={r['events']}"))
    report["gate"] = run_gate()
    csv_rows.append(("gate.reduced_sweep",
                     round(report["gate"]["wall_s"] * 1e6, 1),
                     f"ev_per_s={report['gate']['ev_per_s']};"
                     f"events={report['gate']['events']}"))
    emit(csv_rows, "multidev_sweep")
    JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {JSON_PATH} ({len(csv_rows)} rows)")


if __name__ == "__main__":
    main()
