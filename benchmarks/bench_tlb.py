"""Table 8: translation counts + overhead by matrix size (int32)."""
from repro.accesys.calibration import translation_overhead_diff
from repro.accesys.pipeline import simulate_gemm
from repro.accesys.system import default_system
from benchmarks.common import emit


def main():
    rows = []
    for n in (64, 128, 256, 512, 1024, 2048):
        cfg = default_system("DC", dtype="int32")
        r = simulate_gemm(cfg, n, n, n)
        ov = translation_overhead_diff(n)
        rows.append((f"n{n}", round(r.total_s * 1e6, 1),
                     f"lookups={r.tlb_lookups};misses={r.tlb_misses};"
                     f"walks={r.ptw_walks};overhead={ov*100:.2f}%"))
    emit(rows, "table8_tlb")


if __name__ == "__main__":
    main()
