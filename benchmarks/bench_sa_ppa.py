"""Table 6: systolic-array PPA + derived efficiency (static data check)."""
from repro.accesys.components import SA_VARIANTS
from benchmarks.common import emit


def main():
    rows = []
    for (dtype, w), (freq, area, power, gops) in SA_VARIANTS.items():
        gops_per_w = gops / (power / 1000.0)
        rows.append((f"{dtype}_{w}x{w}", "-",
                     f"freq={freq/1e9:.2f}GHz;area_um2={area};"
                     f"power_mW={power};peak={gops}GOPS;"
                     f"GOPS_per_W={gops_per_w:.0f}"))
    emit(rows, "table6_sa_ppa")


if __name__ == "__main__":
    main()
