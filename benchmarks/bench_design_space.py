"""Design-space sweep throughput: config-batched pricing
(``replay_batch``) vs sequential ``replay_compiled`` calls over the
deterministic 64-config ``design_space.bench_grid()`` on the exact
BERT-Base composed plan, plus a reduced ``tune()`` search demo.

Writes ``BENCH_design_space.json`` at the repo root — the trajectory
artifact ``check_replay_trajectory.py`` guards batched-sweep
configs/sec against.  Acceptance: the batched sweep is >= 10x faster
than the 64 sequential calls, at rtol <= 1e-9 parity on every result
field (both sides price the same warmed trace analysis; the batched
side additionally dedups shared row families across configs)."""
import dataclasses
import json
import time
from pathlib import Path

from repro.accesys.pipeline import replay_batch, replay_compiled
from repro.core import scenario as SC
from repro.core.design_space import (DesignSpace, bench_grid,
                                     system_for_point)
from repro.core.scenario import Scenario, scenario_plan, tune
from benchmarks.common import emit

JSON_PATH = Path("BENCH_design_space.json")


def _max_rel_err(a, b) -> float:
    worst = 0.0
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, int):
            assert va == vb, (f.name, va, vb)
        else:
            worst = max(worst, abs(va - vb) / max(abs(va), 1e-30))
    return worst


def main():
    sc = Scenario(model="bert-base", sampling="exact")
    t0 = time.perf_counter()
    plan, _, events, _ = scenario_plan(sc)
    build_s = time.perf_counter() - t0
    grid = bench_grid()
    cfgs = [system_for_point(p) for p in grid]
    # warm the shared (config-independent) trace analysis once so both
    # measurements below time PRICING, not the one-time analysis
    plan.compile().memo.clear()
    t0 = time.perf_counter()
    replay_compiled(cfgs[0], plan)
    analysis_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    seq = [replay_compiled(cfg, plan) for cfg in cfgs]
    sequential_s = time.perf_counter() - t0
    batched_s = float("inf")
    for _ in range(3):                 # best-of-3: shrug off noise
        t0 = time.perf_counter()
        batch = replay_batch(cfgs, plan)
        batched_s = min(batched_s, time.perf_counter() - t0)
    worst = max(_max_rel_err(a, b) for a, b in zip(seq, batch))
    assert worst <= 1e-9, f"batched/sequential parity broke: {worst}"
    speedup = sequential_s / max(batched_s, 1e-9)

    # reduced tune() search on the sampled scenario: the end-to-end
    # entry (grid -> plans per page size -> batched pricing -> Pareto)
    space = DesignSpace(sa_w=(8, 16, 32), page_bytes=(1024, 4096),
                        buffer_kb=(20, 72), tlb_entries=(16, 64),
                        llc_kb=(2048,), mode=("DM", "DC", "DevMem"))
    res = tune(Scenario(model="bert-base"), space)
    # parallel group pricing: same search fanned over 2 workers — the
    # per-(dtype, page_bytes) groups price in their own processes and
    # every scored point must match the serial run bitwise
    res_par = tune(Scenario(model="bert-base"), space, workers=2)
    tune_parity = max(_max_rel_err(a.result, b.result)
                      for a, b in zip(res.points, res_par.points))
    assert tune_parity == 0.0, \
        f"tune(workers=2) diverged from workers=1: {tune_parity}"

    report = {
        "workload": "bert-base.exact",
        "events": events,
        "build_s": round(build_s, 4),
        "analysis_s": round(analysis_s, 4),
        "n_configs": len(cfgs),
        "sequential_s": round(sequential_s, 4),
        "sequential_cfg_per_s":
            round(len(cfgs) / max(sequential_s, 1e-9), 2),
        "batched_s": round(batched_s, 4),
        "batched_cfg_per_s":
            round(len(cfgs) / max(batched_s, 1e-9), 2),
        "speedup": round(speedup, 2),
        "max_rel_err": worst,
        "tune": {
            "scenario": "bert-base.sampled",
            "n_points": len(res.points),
            "wall_s": round(res.wall_s, 4),
            "configs_per_s": round(res.configs_per_s, 1),
            "workers2_wall_s": round(res_par.wall_s, 4),
            "workers2_parity": tune_parity == 0.0,
            "best": res.best.to_json(),
            "pareto_size": len(res.pareto),
        },
        "_meta": {
            "note": "64-config design-space sweep on the exact "
                    "BERT-Base plan: sequential = 64 replay_compiled "
                    "calls, batched = one replay_batch; both price "
                    "the same warmed trace analysis; grid defined by "
                    "design_space.bench_grid()",
            "acceptance": "speedup >= 10x, parity rtol <= 1e-9",
        },
    }
    JSON_PATH.write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {JSON_PATH} (batched sweep speedup: "
          f"{report['speedup']}x, "
          f"{report['batched_cfg_per_s']} configs/s)")
    emit([
        ("sweep64.sequential", round(sequential_s / 64 * 1e6, 1),
         f"cfg_per_s={report['sequential_cfg_per_s']}"),
        ("sweep64.batched", round(batched_s / 64 * 1e6, 1),
         f"cfg_per_s={report['batched_cfg_per_s']};"
         f"speedup={report['speedup']}x"),
        ("tune.bert-base.sampled",
         round(res.wall_s / max(len(res.points), 1) * 1e6, 1),
         f"points={len(res.points)};pareto={len(res.pareto)};"
         f"best={res.best.point.label()}"),
    ], "design_space")
    # drop the exact full-depth graph (order-100 MB with its compiled
    # arrays) so the rest of a benchmarks/run.py session isn't pinning it
    SC.clear_caches()


if __name__ == "__main__":
    main()
