"""Fig. 7b: INT8 GEMM speedup vs matrix size (DC/DM/OMP/Neon)."""
from repro.accesys.pipeline import simulate_gemm
from repro.accesys.system import CPUModel, default_system
from benchmarks.common import emit


def main():
    cpu = CPUModel()
    rows = []
    for n in (256, 512, 1024, 2048):
        base = cpu.gemm_time(n ** 3, "int8")
        dc = simulate_gemm(default_system("DC"), n, n, n).total_s
        dm = simulate_gemm(default_system("DM"), n, n, n).total_s
        omp = cpu.gemm_time(n ** 3, "int8", threads=256)
        neon = cpu.gemm_time(n ** 3, "int8", simd=True)
        rows += [(f"n{n}.dc", round(dc * 1e6, 2), f"speedup={base/dc:.0f}x"),
                 (f"n{n}.dm", round(dm * 1e6, 2), f"speedup={base/dm:.0f}x"),
                 (f"n{n}.omp", round(omp * 1e6, 2), f"speedup={base/omp:.1f}x"),
                 (f"n{n}.neon", round(neon * 1e6, 2),
                  f"speedup={base/neon:.1f}x")]
    emit(rows, "fig7b_gemm_size")


if __name__ == "__main__":
    main()
